"""Multi-tenant serving benchmark: the model zoo behind one frontend.

The single-model benches answer "how much traffic can a deployment of
model M take"; this bench answers the production question the registry
exists for — N compiled models served *concurrently* through one
frontend with per-tenant ``(model, priority)`` lanes and weighted
round-robin fairness (:mod:`repro.serving.server`). Three blocks land in
``BENCH_serve_multi.json``:

* ``models`` — per tenant: calibrated steady fps, modeled Alg-1 fps,
  its share of the arrival mix, its derived SLO, and its armed miss
  rate at the aggregate knee;
* ``aggregate`` — the bracketing QPS sweep over the *combined* arrival
  stream (each probe splits the aggregate rate across tenants by share,
  draws one seeded schedule per tenant, tags and merge-sorts them into
  one interleaved stream): the knee is the max aggregate rate at which
  **every** tenant's interactive class holds its SLO, recorded against
  the harmonic aggregate capacity
  ``1 / sum(share_t / steady_t)`` (serving one mixed frame costs the
  share-weighted sum of per-tenant batch times on shared silicon);
* ``isolation`` — the headline fairness number, gated in CI: flood one
  tenant at 3x its own calibrated capacity while every other tenant
  trickles deadline-armed traffic at a sustainable 0.3x, and record the
  worst victim's armed miss rate. Per-tenant lanes + WRR + own-tenant
  admission pricing must keep that under the miss target — a flooded
  neighbour is the flooded tenant's problem.

  PYTHONPATH=src:. python benchmarks/serve_multi_bench.py --quick  # CI
  PYTHONPATH=src:. python benchmarks/serve_multi_bench.py          # full
"""

from __future__ import annotations

import argparse
import json
import platform
import time

import jax

from repro.core import workload as W
from repro.serving import (ProgramRegistry, ServerConfig, TrafficClass,
                           build_server, make_schedule, merge_schedules,
                           replay, tag_tenant)
from repro.serving.server import synthetic_stream

SCHEMA_VERSION = 1
DEFAULT_OUT = "BENCH_serve_multi.json"
DEFAULT_MISS_TARGET = 0.05
QUICK_MODELS = ["alexnet", "zf"]

# Derived per-tenant SLO: (K + 3) batch windows at the tenant's *solo*
# steady rate — the single-model convention — stretched by this factor
# because N tenants share the host's cores, so every tenant's effective
# window under concurrent load is wider than its solo calibration.
SLO_SCALE = 2.0


def _tenant_mix(name: str, slo_ms: float) -> tuple[TrafficClass, ...]:
    """Each tenant's 25/75 interactive/batch mix under tenant-scoped
    class names, so per-(tenant, class) outcomes stay separable in the
    shared FrontendStats."""
    return (TrafficClass(f"{name}:interactive", priority=1,
                         deadline_ms=slo_ms, share=0.25),
            TrafficClass(f"{name}:batch", priority=0, deadline_ms=None,
                         share=0.75))


def _armed_outcomes(stats, name: str) -> dict:
    """One tenant's interactive-class outcome row from a replay."""
    cs = stats.classes.get(f"{name}:interactive")
    if cs is None:
        return {"armed_submitted": 0, "armed_missed": 0,
                "armed_miss_rate": 0.0}
    missed = cs.expired + cs.rejected + cs.rejected_wait + cs.late
    return {
        "armed_submitted": cs.submitted,
        "armed_missed": missed,
        "armed_miss_rate": round(missed / cs.submitted, 4)
        if cs.submitted else 0.0,
    }


def run(emit, *, quick: bool = False, batch: int | None = None,
        frames: int | None = None, out: str = DEFAULT_OUT,
        models: list[str] | None = None, stages: int = 2,
        seed: int = 0, miss_target: float = DEFAULT_MISS_TARGET,
        refine_iters: int | None = None, max_factor: float = 4.0,
        flood_factor: float = 3.0, victim_factor: float = 0.3,
        verbose: bool = True) -> dict:
    if models is None:
        models = QUICK_MODELS if quick else list(W.CNN_MODELS)
    if len(models) < 2:
        raise ValueError(f"multi-tenant bench needs >= 2 models, got "
                         f"{models}")
    if batch is None:
        batch = 8 if quick else 16
    if refine_iters is None:
        refine_iters = 2 if quick else 3
    if not 0.0 < miss_target < 1.0:
        raise ValueError(f"miss_target={miss_target} not in (0, 1)")
    n_frames = frames if frames is not None else (6 + 2 * stages) * batch
    share = 1.0 / len(models)             # equal tenant shares

    registry = ProgramRegistry.compile(models, bits=8, seed=seed)
    streams = {m: synthetic_stream(m, n_frames, seed) for m in models}
    cfg = ServerConfig(batch=batch, stages=stages, seed=seed,
                       calib_frames=n_frames)
    srv = build_server(registry, cfg, streams=streams, verbose=verbose)
    try:
        steady = {m: srv.runtime(m).steady_fps for m in models}
        slo = {m: round(SLO_SCALE * (stages + 3) * 1e3 * batch
                        / max(steady[m], 1e-9), 1) for m in models}
        # Harmonic aggregate capacity: a share-weighted mixed frame
        # costs sum(share/steady_t) seconds of engine time.
        agg_steady = 1.0 / sum(share / max(steady[m], 1e-9)
                               for m in models)

        def _replay(rates: dict[str, float]) -> tuple:
            """One merged multi-tenant replay at per-tenant rates;
            returns (frontend stats, per-tenant armed outcome rows)."""
            fe = srv.open_frontend(dict(rates))
            scheds = [tag_tenant(
                make_schedule(len(streams[m]), rates[m],
                              _tenant_mix(m, slo[m]), seed=seed + i), m)
                for i, m in enumerate(models)]
            replay(fe, streams, merge_schedules(*scheds))
            fe.close()
            st = fe.stats_snapshot()
            return st, {m: _armed_outcomes(st, m) for m in models}

        def _probe(agg_rate: float) -> dict:
            st, per_tenant = _replay({m: share * agg_rate
                                      for m in models})
            worst = max(r["armed_miss_rate"] for r in per_tenant.values())
            row = {
                "arrival_fps": round(agg_rate, 3),
                "sustained": bool(worst < miss_target),
                "worst_armed_miss_rate": worst,
                "client_fps": round(st.fps, 3),
                "submitted": st.submitted,
                "completed": st.completed,
                "expired": st.expired,
                "rejected": st.rejected,
                "rejected_wait": st.rejected_wait,
                "failed": st.failed,
                "per_tenant": per_tenant,
            }
            if verbose:
                print(f"[serve_multi] probe {agg_rate:8.2f} qps agg: "
                      f"worst armed miss {worst:6.2%} "
                      f"({'sustained' if row['sustained'] else 'MISS'})")
            return row

        # Aggregate knee: bracket by doubling from 0.5x the harmonic
        # capacity while every tenant sustains, then bisect.
        probes: list[dict] = []
        cap = max_factor * agg_steady
        lo_rate, lo_row, hi_rate = None, None, None
        rate = 0.5 * agg_steady
        while hi_rate is None:
            row = _probe(rate)
            probes.append(row)
            if row["sustained"]:
                lo_rate, lo_row = rate, row
                if rate >= cap:
                    break
                rate = min(2 * rate, cap)
            else:
                hi_rate = rate
        if lo_rate is None:
            floor = 0.05 * agg_steady
            while lo_rate is None and rate / 2 >= floor:
                rate = rate / 2
                row = _probe(rate)
                probes.append(row)
                if row["sustained"]:
                    lo_rate, lo_row = rate, row
                else:
                    hi_rate = rate
        for _ in range(max(0, int(refine_iters))):
            if lo_rate is None or hi_rate is None or \
                    hi_rate / lo_rate < 1.05:
                break
            mid = (lo_rate + hi_rate) / 2
            row = _probe(mid)
            probes.append(row)
            if row["sustained"]:
                lo_rate, lo_row = mid, row
            else:
                hi_rate = mid

        # Isolation: flood tenant 0 at flood_factor x its own solo
        # capacity (armed mix included — the flood tenant's own misses
        # are expected and recorded); every other tenant trickles at a
        # sustainable victim_factor x. The gated headline is the worst
        # *victim* armed miss rate.
        flood_tenant = models[0]
        iso_rates = {m: (flood_factor * steady[m] if m == flood_tenant
                         else victim_factor * steady[m]) for m in models}
        _, iso = _replay(iso_rates)
        victims = {m: dict(iso[m], arrival_fps=round(iso_rates[m], 3))
                   for m in models if m != flood_tenant}
        victim_miss = max(r["armed_miss_rate"] for r in victims.values())

        data: dict = {
            "schema_version": SCHEMA_VERSION,
            "bench": "serve_multi",
            "quick": quick,
            "batch": batch,
            "frames": n_frames,
            "stages": stages,
            "seed": seed,              # replays every tenant's schedule
            "miss_target": miss_target,
            "slo_scale": SLO_SCALE,
            "max_factor": max_factor,
            "refine_iters": refine_iters,
            "tenant_share": round(share, 4),
            "device_count": jax.device_count(),
            "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
            "jax_version": jax.__version__,
            "backend": jax.devices()[0].platform,
            "host": platform.machine(),
            "models": {},
            "aggregate": {
                "agg_steady_fps": round(agg_steady, 3),
                "knee_qps": None if lo_rate is None else round(lo_rate, 3),
                "knee_of_agg_steady": (
                    None if lo_rate is None
                    else round(lo_rate / max(agg_steady, 1e-9), 4)),
                "knee_worst_armed_miss_rate": (
                    None if lo_row is None
                    else lo_row["worst_armed_miss_rate"]),
                "bracket_unsustained_qps": (
                    None if hi_rate is None else round(hi_rate, 3)),
                "probes": probes,
            },
            "isolation": {
                "flood_tenant": flood_tenant,
                "flood_factor": flood_factor,
                "victim_factor": victim_factor,
                "flood_armed_miss_rate": iso[flood_tenant]
                ["armed_miss_rate"],
                "victim_armed_miss_rate": victim_miss,
                "victims": victims,
            },
        }
        for m in models:
            rt = srv.runtime(m)
            data["models"][m] = {
                "steady_fps": round(steady[m], 3),
                "modeled_fps_alg1": round(rt.program.fps(), 3),
                "lat1_ms": (None if rt.lat1_s is None
                            else round(rt.lat1_s * 1e3, 3)),
                "share": round(share, 4),
                "slo_ms": slo[m],
                "knee": (None if lo_row is None
                         else dict(lo_row["per_tenant"][m],
                                   arrival_fps=round(share * lo_rate, 3))),
            }
            emit(f"serve_multi/{m}/steady_fps", 0.0,
                 f"{data['models'][m]['steady_fps']}fps|"
                 f"slo={slo[m]}ms")
    finally:
        srv.close()

    agg = data["aggregate"]
    emit("serve_multi/aggregate/knee_qps", 0.0,
         f"{agg['knee_qps']}qps|x{agg['knee_of_agg_steady']}_of_agg|"
         f"probes={len(agg['probes'])}")
    emit("serve_multi/isolation/victim_armed_miss_rate", 0.0,
         f"{victim_miss}|flood={flood_tenant}@{flood_factor}x")
    with open(out, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
    print(f"\n[serve_multi_bench] wrote {out} ({len(models)} tenants, "
          f"batch {batch}, agg knee "
          f"{agg['knee_qps']} qps, victim miss {victim_miss:.2%} "
          f"vs target {miss_target:.0%})")
    return data


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="two tenants (alexnet + zf), small batch "
                         "(CI bench-smoke)")
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--frames", type=int, default=None,
                    help="frames per tenant per probe (default: "
                         "(6 + 2*stages) * batch)")
    ap.add_argument("--stages", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0,
                    help="params/calibration/stream/schedule RNG seed")
    ap.add_argument("--miss-target", type=float,
                    default=DEFAULT_MISS_TARGET,
                    help="armed-class miss rate defining 'sustained' "
                         "and the isolation gate (default 0.05)")
    ap.add_argument("--max-factor", type=float, default=4.0,
                    help="sweep cap as a multiple of the harmonic "
                         "aggregate capacity (default 4)")
    ap.add_argument("--refine-iters", type=int, default=None,
                    help="bisection refinements (default 3, 2 quick)")
    ap.add_argument("--flood-factor", type=float, default=3.0,
                    help="isolation flood rate as a multiple of the "
                         "flooded tenant's solo steady fps (default 3)")
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--model", action="append", default=None,
                    choices=sorted(W.CNN_MODELS), dest="models",
                    help="repeatable; >= 2 required (default: "
                         "alexnet+zf quick, all four full)")
    args = ap.parse_args(argv)
    from benchmarks.run import print_csv
    csv: list[str] = []

    def emit(name, us, derived=""):
        csv.append(f"{name},{us:.1f},{derived}")

    run(emit, quick=args.quick, batch=args.batch, frames=args.frames,
        out=args.out, models=args.models, stages=args.stages,
        seed=args.seed, miss_target=args.miss_target,
        refine_iters=args.refine_iters, max_factor=args.max_factor,
        flood_factor=args.flood_factor)
    print_csv(csv)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
