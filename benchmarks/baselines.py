"""Models of the paper's comparison systems, used by table1.

[1] Qiu et al. (FPGA'16): *recurrent* architecture — one fixed Tn x Tm PE
    array processes layers sequentially; utilization suffers whenever a
    layer's (C, M) does not tile the fixed array.
[3] DNNBuilder (ICCAD'18): *pipeline* architecture, but channel parallelism
    must be a power of two and layer i's input parallelism must equal layer
    i-1's output parallelism — the constraints the paper's flexible buffer
    removes. Modeled as a constrained waterfill (binary search on the
    bottleneck, DP over the chained pow2 parallelisms).
"""

from __future__ import annotations

import math
from typing import Sequence

from repro.core.workload import LayerWorkload


def recurrent_efficiency(layers: Sequence[LayerWorkload], tn: int = 7,
                         tm: int = 64) -> tuple[float, float]:
    """[1]-style: returns (efficiency, cycles/frame) for a fixed Tn x Tm
    array running layers one-by-one (weights/acts streamed per tile)."""
    total_macs = 0
    cycles = 0.0
    for l in layers:
        if l.macs == 0:
            continue
        total_macs += l.macs
        if l.kind == "fc":
            cycles += math.ceil(l.C / tn) * math.ceil(l.M / tm)
        else:
            cycles += (math.ceil(l.C / tn) * math.ceil(l.M / tm)
                       * l.H * l.W * l.R * l.S)
    eff = total_macs / (tn * tm * cycles)
    return eff, cycles


_POW2 = [1, 2, 4, 8, 16, 32, 64, 128, 256]


def dnnbuilder_allocate(layers: Sequence[LayerWorkload], theta_total: int
                        ) -> tuple[int, float]:
    """[3]-style constrained allocation: per-conv-layer (C'_i, M'_i) powers
    of two with C'_i == M'_{i-1}; strict per-group scheduling (their buffer
    cannot pack partial channel groups). Returns (theta_used, frame_cycles).

    Solved optimally under the constraints: binary search on the bottleneck
    B; for each B a DP over the chained pow2 choice finds the min total
    theta. FC layers are allocated independently (no chain constraint).
    """
    convs = [l for l in layers if l.kind == "conv" and l.macs > 0]
    fcs = [l for l in layers if l.kind == "fc" and l.macs > 0]

    def conv_cycles(l, cp, mp):
        return l.H * l.W * math.ceil(l.C / cp) * math.ceil(l.M / mp)

    def feasible(bound):
        # DP over layers; state: M' of previous layer (pow2).
        state = {p: 0 for p in _POW2}           # prev M' -> min theta sum
        first = True
        for l in convs:
            new_state = {}
            for mp in _POW2:
                if mp > l.M:
                    continue
                best = None
                for cp_prev, acc in state.items():
                    cp = cp_prev if not first else min(l.C, cp_prev)
                    if cp > l.C:
                        continue
                    if conv_cycles(l, cp, mp) > bound:
                        continue
                    theta = cp * mp * l.R * l.S
                    cand = acc + theta
                    if best is None or cand < best:
                        best = cand
                if best is not None:
                    new_state[mp] = best
            if not new_state:
                return None
            state = new_state
            first = False
        conv_theta = min(state.values())
        fc_theta = 0
        for l in fcs:
            need = None
            for cp in _POW2:
                for mp in _POW2:
                    if cp <= l.C and mp <= l.M and \
                            math.ceil(l.C / cp) * math.ceil(l.M / mp) <= bound:
                        t = cp * mp
                        need = t if need is None else min(need, t)
            if need is None:
                return None
            fc_theta += need
        total = conv_theta + fc_theta
        return total if total <= theta_total else None

    lo = max(min(conv_cycles(l, min(l.C, 256), min(l.M, 256))
                 for l in convs), 1.0)
    hi = max(conv_cycles(l, 1, 1) for l in convs)
    best_bound, best_theta = hi, feasible(hi)
    for _ in range(60):
        mid = math.sqrt(lo * hi)
        got = feasible(mid)
        if got is not None:
            best_bound, best_theta, hi = mid, got, mid
        else:
            lo = mid
        if hi / lo < 1.0005:
            break
    return int(best_theta or 0), best_bound


def winograd_fused_model(layers: Sequence[LayerWorkload], theta: int = 824,
                         freq_hz: float = 100e6,
                         m_tile: int = 2) -> tuple[float, float]:
    """[2]-style fused pipeline with Winograd F(2x2, 3x3) convolution:
    3x3 stride-1 layers need 2.25x fewer multiplies (16 MACs per 4 outputs
    per channel pair vs 36); other layers run conventionally. Allocation is
    proportional (the paper notes [2]'s latency-oriented allocation loses
    efficiency; we model a 0.70 efficiency factor from its reported DSP
    efficiency). Returns (GOPS_effective, cycles/frame)."""
    eff = 0.696                     # [2]'s reported DSP efficiency
    total_macs = sum(l.macs for l in layers if l.macs > 0)
    hw_macs = 0.0
    for l in layers:
        if l.macs == 0:
            continue
        if l.kind == "conv" and l.R == 3 and l.stride == 1:
            hw_macs += l.macs / 2.25
        else:
            hw_macs += l.macs
    cycles = hw_macs / (theta * eff)
    gops_eff = 2 * total_macs * (freq_hz / cycles) / 1e9
    return gops_eff, cycles
