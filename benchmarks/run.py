"""Benchmark harness — one module per paper table/figure.

  table1    : paper Table I (4 CNNs on ZC706-class budget) + baselines
  serve     : measured-vs-modeled serving FPS (jitted batched executor
              vs eager loop vs Algorithm 1) -> BENCH_serve.json
  serve-async : single-jit vs K-stage pipelined serving (throughput +
              request latency percentiles) -> BENCH_serve_async.json
  serve-qos : mixed traffic classes at two arrival rates (per-class
              queueing/assembly/compute split, SLO miss + drop rates)
              -> BENCH_serve_qos.json
  serve-knee : bracketing absolute-QPS sweep; the knee (max sustained
              rate with interactive SLO miss < 1%) is the headline
              capacity number -> BENCH_serve_knee.json
  serve-multi : multi-tenant model zoo behind one frontend (aggregate
              mixed-traffic knee + tenant-isolation flood)
              -> BENCH_serve_multi.json
  serve-chaos : fault injection + adversarial traffic (replica kill /
              straggler / bus-drop replays gated on liveness, plus
              knee sweeps under hostile arrival processes)
              -> BENCH_serve_chaos.json
  import-smoke : compiler front door on examples/lenet.json (import ->
              cross-route golden check -> serve smoke); not part of
              ``all`` — it is a gate, not a measurement
  ablation  : allocator objectives (paper greedy / exact / waterfill)
              + pipeline stage balance on the TPU mesh
  roofline  : three-term roofline per (arch x shape x mesh) cell
  kernels   : Pallas kernel microbenches (interpret-mode correctness +
              wall time of the jnp oracle path on CPU)

Usage: ``python benchmarks/run.py [which] [--quick]`` where ``which`` is
one of the names above or ``all``. ``--quick`` runs the reduced CI
setting (AlexNet-only table1/serve). Prints ``name,us_per_call,derived``
CSV lines (one per measurement) plus human-readable tables.
"""

from __future__ import annotations

import argparse

_CSV: list[str] = []


def emit(name: str, us_per_call: float, derived: str = ""):
    line = f"{name},{us_per_call:.1f},{derived}"
    _CSV.append(line)


def print_csv(lines: list[str]) -> None:
    """The shared trailing CSV block every benchmark entry point prints
    (one format, one place — table1.main and serve_bench.main reuse it)."""
    print("\n== CSV ==")
    print("name,us_per_call,derived")
    for line in lines:
        print(line)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("which", nargs="?", default="all",
                    choices=("all", "table1", "serve", "serve-async",
                             "serve-qos", "serve-knee", "serve-multi",
                             "serve-chaos", "import-smoke", "ablation",
                             "roofline", "kernels"))
    ap.add_argument("--quick", action="store_true",
                    help="reduced CI setting (AlexNet-only, small batch)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="[serve-knee] pipeline replicas behind the "
                         "least-wait router")
    ap.add_argument("--replicas-sweep", default=None,
                    dest="replicas_sweep",
                    help="[serve-knee] comma list (e.g. 1,2,4): "
                         "knee-vs-R scaling sweep")
    ap.add_argument("--arrival", default="uniform",
                    choices=("uniform", "poisson"),
                    help="[serve-knee] 'poisson' adds a bursty "
                         "<model>:poisson row beside the uniform knee")
    args = ap.parse_args(argv)
    only = args.which

    if only in ("all", "table1"):
        from benchmarks import table1
        table1.run(emit, quick=args.quick)
    if only in ("all", "serve"):
        from benchmarks import serve_bench
        serve_bench.run(emit, quick=args.quick)
    if only in ("all", "serve-async"):
        from benchmarks import serve_async_bench
        serve_async_bench.run(emit, quick=args.quick)
    if only in ("all", "serve-qos"):
        from benchmarks import serve_qos_bench
        serve_qos_bench.run(emit, quick=args.quick)
    if only in ("all", "serve-knee"):
        from benchmarks import serve_knee_bench
        serve_knee_bench.run(
            emit, quick=args.quick, replicas=args.replicas,
            arrival=args.arrival,
            replicas_sweep=([int(r) for r in
                             args.replicas_sweep.split(",")]
                            if args.replicas_sweep else None))
    if only in ("all", "serve-multi"):
        from benchmarks import serve_multi_bench
        serve_multi_bench.run(emit, quick=args.quick)
    if only in ("all", "serve-chaos"):
        from benchmarks import serve_chaos_bench
        serve_chaos_bench.run(emit, quick=args.quick)
    if only == "import-smoke":
        import os
        import time

        from repro.launch.import_model import import_and_serve
        spec = os.path.join(os.path.dirname(__file__), os.pardir,
                            "examples", "lenet.json")
        t0 = time.perf_counter()
        r = import_and_serve(spec, serve_frames=6, batch=4, stages=1)
        emit("import_smoke.lenet", (time.perf_counter() - t0) * 1e6,
             f"completed={r['serve']['completed']}/6")
    if only in ("all", "ablation"):
        from benchmarks import ablation
        ablation.run_objectives(emit)
        ablation.run_stage_balance(emit)
    if only in ("all", "roofline"):
        from benchmarks import roofline
        roofline.run(emit, "pod")
        roofline.run(emit, "multipod")
    if only in ("all", "kernels"):
        from benchmarks import kernel_bench
        kernel_bench.run(emit)

    print_csv(_CSV)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
