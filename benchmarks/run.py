"""Benchmark harness — one module per paper table/figure.

  table1    : paper Table I (4 CNNs on ZC706-class budget) + baselines
  ablation  : allocator objectives (paper greedy / exact / waterfill)
  stage     : pipeline stage balance on the TPU mesh (flexibility claim)
  roofline  : three-term roofline per (arch x shape x mesh) cell
  kernels   : Pallas kernel microbenches (interpret-mode correctness +
              wall time of the jnp oracle path on CPU)

Prints ``name,us_per_call,derived`` CSV lines (one per measurement) plus
human-readable tables.
"""

from __future__ import annotations

import sys
import time

_CSV: list[str] = []


def emit(name: str, us_per_call: float, derived: str = ""):
    line = f"{name},{us_per_call:.1f},{derived}"
    _CSV.append(line)


def main() -> None:
    only = sys.argv[1] if len(sys.argv) > 1 else "all"

    if only in ("all", "table1"):
        from benchmarks import table1
        table1.run(emit)
    if only in ("all", "ablation"):
        from benchmarks import ablation
        ablation.run_objectives(emit)
        ablation.run_stage_balance(emit)
    if only in ("all", "roofline"):
        from benchmarks import roofline
        roofline.run(emit, "pod")
        roofline.run(emit, "multipod")
    if only in ("all", "kernels"):
        from benchmarks import kernel_bench
        kernel_bench.run(emit)

    print("\n== CSV ==")
    print("name,us_per_call,derived")
    for line in _CSV:
        print(line)


if __name__ == "__main__":
    main()
