"""Chaos benchmark: adversarial traffic + fault injection, gated on
liveness.

Every other serving bench measures the healthy path. This one measures
the contract that makes those numbers trustworthy — *every submitted
request resolves, never hangs* — while the deployment is actively being
hurt. Two parts per model, over one compiled
:class:`~repro.core.program.EngineProgram`:

* **Adversarial-arrival knees** — the same bracketing absolute-QPS
  sweep as ``serve_knee_bench``, but driven by the hostile arrival
  processes in :data:`repro.serving.SCENARIOS` (on/off flash crowds,
  lognormal and Pareto heavy-tail gaps, diurnal ramps) beside the
  uniform baseline, so the capacity cost of burstiness is a recorded
  number (``knee_of_steady`` per scenario) rather than folklore.

* **Fault replays** — a two-replica routed :class:`ReplicaPool` whose
  first replica is wrapped in a :class:`~repro.serving.ChaosExecutor`,
  calibrated healthy, then armed with one :class:`FaultPlan` per
  scenario: ``kill_replica`` (dies mid-batch, recovers later — probes
  re-admit it), ``straggler`` (every delivery dragged ``slowdown_s``
  late, the router must steer by price), ``fail_at_t`` (drops off the
  bus at time T, permanently). Each replay records the liveness
  headline (``hung``, ``resolved_frac``), the chaos-tier armed miss
  rate (failed counts against the SLO), the achieved pacing, and the
  :func:`~repro.serving.recovery_report` time-to-recover.

FPGA correspondence (DESIGN.md §9): a replica kill is a PE/stage hard
fault — the paper's fabric has no ECC, the batch in the array is lost;
a flash crowd is an input-buffer overrun at the host interface; a
straggler is a clock-degraded or thermally-throttled region; and
``fail_at_t`` is a board dropping off the host bus mid-run.

Results land in ``BENCH_serve_chaos.json`` — schema-validated, gated
against ``benchmarks/baselines/serve_chaos.json`` (hung == 0 and
resolved_frac == 1.0 are *hard* gates; recovery time and scenario knees
are warn-only bands) and uploaded by the CI bench-smoke job.

  PYTHONPATH=src:. python benchmarks/serve_chaos_bench.py --quick   # CI
  PYTHONPATH=src:. python benchmarks/serve_chaos_bench.py           # full
"""

from __future__ import annotations

import argparse
import json
import platform
import time

import jax
import numpy as np

from repro.core import workload as W
from repro.launch.serve_cnn import compile_for_serving, serve_knee
from repro.serving import (ChaosExecutor, FaultPlan, PipelineExecutor,
                           ReplicaPool, armed_class_names, default_mix,
                           make_scenario_schedule, pacing_report,
                           pipeline_throughput, recovery_report, replay,
                           synthetic_stream, warmed_frontend)

SCHEMA_VERSION = 1
DEFAULT_OUT = "BENCH_serve_chaos.json"
# Chaos verdicts use a looser band than the healthy knee's 1%: burst
# scenarios are *supposed* to miss during the burst — the question is
# whether the deployment recovers, not whether it is unconditionally
# clean.
DEFAULT_MISS_TARGET = 0.05
DEFAULT_LOAD_FACTOR = 0.5
FAULTS = ("kill_replica", "straggler", "fail_at_t")
ADVERSARIAL_SCENARIOS = ("onoff", "lognormal", "pareto", "diurnal")
QUICK_SCENARIOS = ("onoff", "pareto")


def _fault_plan(fault: str, *, batch: int, steady: float, n: int,
                rate: float) -> FaultPlan:
    """One replica's fault program, scaled to the replay: offsets are in
    the *victim's* dispatched batches (it sees roughly half the
    ``n / batch`` total), so the fault lands early enough that the
    post-fault window dominates the artifact."""
    window = batch / max(steady, 1e-9)
    victim_batches = max(4, n // (2 * batch))
    if fault == "kill_replica":
        # Dead for ~a third of its share, then answers probes again —
        # quarantine, steering, and re-admission all get exercised.
        return FaultPlan(kill_at_batch=3,
                         recover_at_batch=3 + max(3, victim_batches // 3))
    if fault == "straggler":
        # Every delivery dragged ~3 batch windows late: far past the
        # router's 3x-median straggler band, without ever failing.
        return FaultPlan(straggle_at_batch=3,
                         slowdown_s=round(3 * window, 6))
    if fault == "fail_at_t":
        # Board drops off the bus a quarter into the replay, for good.
        return FaultPlan(fail_after_s=round(0.25 * n / max(rate, 1e-9), 6))
    raise ValueError(f"unknown fault {fault!r} (expected one of {FAULTS})")


def bench_fault(model: str, prog, fault: str, *, batch: int, stages: int,
                frames: int, seed: int, slo_ms: float,
                miss_target: float, load_factor: float,
                flush_guard_ms: float | None, admission_control: bool,
                verbose: bool = True) -> dict:
    """One fault replay: build a 2-replica pool with the victim behind a
    benign ChaosExecutor, calibrate healthy through the pool, arm the
    plan, replay a seeded uniform stream at ``load_factor * fleet
    steady`` open-loop, and report liveness + recovery."""
    reps = [PipelineExecutor(prog, stages=stages, batch_size=batch,
                             output="top1") for _ in range(2)]
    victim = ChaosExecutor(reps[0], FaultPlan(), name=f"{model}-victim")
    pool = ReplicaPool(prog, executors=[victim, reps[1]],
                       router_seed=seed, probe_every=4)
    pool.start()
    stream = synthetic_stream(model, frames, seed)
    try:
        warmup_s, lat1_s, calib = pipeline_throughput(pool, stream, batch)
        steady = calib.steady_fps
        rate = load_factor * steady
        plan = _fault_plan(fault, batch=batch, steady=steady, n=frames,
                           rate=rate)
        mix = default_mix(slo_ms)
        armed = armed_class_names(mix)
        schedule, _ = make_scenario_schedule("uniform", frames, rate, mix,
                                             seed=seed)
        pool.reset_stats()
        fe = warmed_frontend(pool, steady, rate, batch, max_wait_ms=None,
                             admission_control=admission_control,
                             flush_guard_ms=flush_guard_ms, lat1_s=lat1_s,
                             max_queue=max(256, 2 * frames))
        victim.arm(plan)
        reqs = replay(fe, stream, schedule, raise_failed=False)
        pacing = pacing_report(schedule, reqs)
        fe.close()
        st = fe.stats
    finally:
        pool.close()

    # Chaos-tier armed miss: dropped, refused, late — or *failed*. The
    # healthy knee excludes failures (there, a failure is a bench bug);
    # a fault window must count them against the SLO.
    armed_reqs = [r for r in reqs if r.deadline_s is not None]
    armed_missed = sum(1 for r in armed_reqs
                       if r.missed_deadline()
                       or r.outcome in ("failed", "rejected"))
    cls = [st.klass(c) for c in armed if c in st.classes]
    total_s = [s for c in cls for s in c.total_s]
    p99_ms = (round(float(np.percentile(np.asarray(total_s), 99)) * 1e3, 3)
              if total_s else None)
    # ~4 full-batch assembly windows per bucket: enough armed arrivals
    # (25% of the mix) that one straggling request cannot flip a
    # window's verdict.
    window_s = 4 * batch / max(rate, 1e-9)
    recovery = recovery_report(reqs, fault_t0=victim.t_first_fault,
                               window_s=window_s, miss_target=miss_target)
    row = {
        "fault": fault,
        "plan": plan.to_json(),
        "replicas": pool.n_replicas,
        "frames": frames,
        "batch": batch,
        "slo_ms": slo_ms,
        "miss_target": miss_target,
        "load_factor": load_factor,
        "fleet_steady_fps": round(steady, 3),
        "unloaded_lat1_ms": round(lat1_s * 1e3, 3),
        "compile_plus_warmup_s": round(warmup_s, 3),
        "arrival_fps": round(rate, 3),
        "submitted": st.submitted,
        "completed": st.completed,
        "failed": st.failed,
        "expired": st.expired,
        "rejected": st.rejected,
        "rejected_wait": st.rejected_wait,
        "resolved": st.resolved,
        "hung": st.hung,
        "resolved_frac": (round(st.resolved / st.submitted, 6)
                          if st.submitted else None),
        "armed_submitted": len(armed_reqs),
        "armed_missed": armed_missed,
        "armed_miss_rate": (round(armed_missed / len(armed_reqs), 4)
                            if armed_reqs else None),
        "armed_p99_ms": p99_ms,
        "injected_failures": victim.injected_failures,
        "injected_slowdowns": victim.injected_slowdowns,
        "pacing": pacing,
        "recovery": recovery,
        "router": pool.router.snapshot(),
        "replica_rows": pool.replica_rows(),
    }
    if verbose:
        rec = recovery["recovered_s"]
        print(f"[serve_chaos] {model} fault={fault}: "
              f"{st.resolved}/{st.submitted} resolved, hung {st.hung}, "
              f"failed {st.failed}, injected "
              f"{victim.injected_failures}+{victim.injected_slowdowns}slow"
              f" | recovered "
              + (f"{rec:.3f}s" if rec is not None else "n/a"))
    return row


def run(emit, *, quick: bool = False, batch: int | None = None,
        frames: int | None = None, out: str = DEFAULT_OUT,
        models: list[str] | None = None, stages: int = 2,
        seed: int = 0, slo_ms: float | None = None,
        miss_target: float = DEFAULT_MISS_TARGET,
        refine_iters: int | None = None, max_factor: float = 8.0,
        load_factor: float = DEFAULT_LOAD_FACTOR,
        flush_guard_ms: float | None = None,
        admission_control: bool = True,
        scenarios: list[str] | None = None,
        faults: list[str] | None = None) -> dict:
    if models is None:
        models = ["alexnet"] if quick else list(W.CNN_MODELS)
    if batch is None:
        batch = 8 if quick else 32
    if refine_iters is None:
        refine_iters = 1 if quick else 3
    if scenarios is None:
        scenarios = list(QUICK_SCENARIOS if quick
                         else ADVERSARIAL_SCENARIOS)
    bad = [s for s in scenarios if s not in ADVERSARIAL_SCENARIOS]
    if bad:
        raise ValueError(f"unknown scenario(s) {bad} "
                         f"(expected from {ADVERSARIAL_SCENARIOS})")
    if faults is None:
        faults = list(FAULTS)
    bad = [f for f in faults if f not in FAULTS]
    if bad:
        raise ValueError(f"unknown fault(s) {bad} (expected from {FAULTS})")
    if not 0.0 < load_factor < 1.0:
        raise ValueError(f"load_factor={load_factor} not in (0, 1): the "
                         f"fault replays must leave headroom for the "
                         f"survivor to absorb the victim's share")
    knee_frames = frames if frames is not None else (6 + 2 * stages) * batch
    chaos_frames = frames if frames is not None \
        else (12 + 2 * stages) * batch
    data: dict = {
        "schema_version": SCHEMA_VERSION,
        "bench": "serve_chaos",
        "quick": quick,
        "batch": batch,
        "frames": frames,          # null = per-part default
        "stages": stages,
        "seed": seed,              # replays params, calibration, frames,
        "slo_ms": slo_ms,          # schedules and every fault program
        "miss_target": miss_target,
        "max_factor": max_factor,
        "refine_iters": refine_iters,
        "load_factor": load_factor,
        "scenarios": list(scenarios),
        "faults": list(faults),
        "admission_control": admission_control,
        "flush_guard_ms": flush_guard_ms,
        "device_count": jax.device_count(),
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "jax_version": jax.__version__,
        "backend": jax.devices()[0].platform,
        "host": platform.machine(),
        "models": {},
    }
    knee_common = dict(frames=knee_frames, batch=batch, stages=stages,
                       seed=seed, miss_target=miss_target,
                       refine_iters=refine_iters, max_factor=max_factor,
                       flush_guard_ms=flush_guard_ms,
                       admission_control=admission_control, verbose=True)
    for model in models:
        prog = compile_for_serving(model, bits=8, seed=seed)
        # Uniform baseline knee first: it resolves the SLO every other
        # row pins (re-deriving per scenario would measure a different
        # contract per row and make the knee ratios meaningless).
        base = serve_knee(model, slo_ms=slo_ms, scenario=None,
                          program=prog, **knee_common)
        pinned_slo = base["slo_ms"]
        srows = {"uniform": base}
        for s in scenarios:
            srows[s] = serve_knee(model, slo_ms=pinned_slo, scenario=s,
                                  program=prog, **knee_common)
        emit(f"serve_chaos/{model}/scenario_knees", 0.0,
             "|".join(f"{s}={r['knee_qps']}qps"
                      + (f"(x{r['knee_of_steady']})"
                         if r["knee_of_steady"] is not None else "")
                      for s, r in srows.items()))
        frows = {}
        for fault in faults:
            frows[fault] = bench_fault(
                model, prog, fault, batch=batch, stages=stages,
                frames=chaos_frames, seed=seed, slo_ms=pinned_slo,
                miss_target=miss_target, load_factor=load_factor,
                flush_guard_ms=flush_guard_ms,
                admission_control=admission_control)
            r = frows[fault]
            emit(f"serve_chaos/{model}/{fault}", 0.0,
                 f"hung={r['hung']}|resolved={r['resolved']}"
                 f"/{r['submitted']}|failed={r['failed']}|"
                 f"recovered_s={r['recovery']['recovered_s']}")
        data["models"][model] = {
            "slo_ms": pinned_slo,
            "uniform_knee_qps": base["knee_qps"],
            "scenarios": srows,
            "faults": frows,
        }
    with open(out, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
    print(f"\n[serve_chaos_bench] wrote {out} ({len(data['models'])} "
          f"model(s), {1 + len(scenarios)} arrival scenario(s), "
          f"{len(faults)} fault(s), batch {batch})")
    return data


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="AlexNet only, small batch, fewer scenarios "
                         "(CI bench-smoke)")
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--frames", type=int, default=None,
                    help="stream length for both parts (default: "
                         "per-part multiple of batch)")
    ap.add_argument("--stages", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0,
                    help="params/calibration/stream/schedule/fault seed")
    ap.add_argument("--slo-ms", type=float, default=None,
                    help="interactive-class deadline (default: derived "
                         "by the uniform baseline knee)")
    ap.add_argument("--miss-target", type=float,
                    default=DEFAULT_MISS_TARGET,
                    help="armed-class miss rate defining 'sustained' "
                         "and 'recovered' (default 0.05)")
    ap.add_argument("--max-factor", type=float, default=8.0,
                    help="knee sweep cap as a multiple of steady fps")
    ap.add_argument("--refine-iters", type=int, default=None,
                    help="knee bisection refinements (default 3, "
                         "1 with --quick)")
    ap.add_argument("--load-factor", type=float,
                    default=DEFAULT_LOAD_FACTOR,
                    help="fault-replay arrival rate as a fraction of "
                         "fleet steady fps (default 0.5)")
    ap.add_argument("--flush-guard-ms", type=float, default=None,
                    help="fixed flush guard (default: adaptive)")
    ap.add_argument("--no-admission", action="store_true",
                    help="disable estimated-wait admission control")
    ap.add_argument("--scenario", action="append", default=None,
                    dest="scenarios", choices=ADVERSARIAL_SCENARIOS,
                    help="adversarial arrival scenario(s) to knee-sweep "
                         "(default: all; uniform baseline always runs)")
    ap.add_argument("--fault", action="append", default=None,
                    dest="faults", choices=FAULTS,
                    help="fault replay(s) to run (default: all)")
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--model", action="append", default=None,
                    choices=sorted(W.CNN_MODELS), dest="models")
    args = ap.parse_args(argv)
    from benchmarks.run import print_csv
    csv: list[str] = []

    def emit(name, us, derived=""):
        csv.append(f"{name},{us:.1f},{derived}")

    run(emit, quick=args.quick, batch=args.batch, frames=args.frames,
        out=args.out, models=args.models, stages=args.stages,
        seed=args.seed, slo_ms=args.slo_ms,
        miss_target=args.miss_target, refine_iters=args.refine_iters,
        max_factor=args.max_factor, load_factor=args.load_factor,
        flush_guard_ms=args.flush_guard_ms,
        admission_control=not args.no_admission,
        scenarios=args.scenarios, faults=args.faults)
    print_csv(csv)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
