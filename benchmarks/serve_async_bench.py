"""Async serving benchmark: single-jit vs stage-pipelined serving.

For each model, compiles one :class:`EngineProgram` and serves the same
seeded synthetic stream through the K-stage software pipeline
(``repro.serving``) for K in ``--stages`` (default 1, 2, 4): closed-loop
steady-state throughput, then open-loop request latency (p50/p95/p99)
through the async frontend at a sustainable arrival rate. K=1 is the
single-jit baseline (one stage == ``compile_runner``'s whole chain), so
``throughput_vs_single_jit`` reads the cost/benefit of pipelining
directly. Results land in one JSON artifact (``BENCH_serve_async.json``,
built, validated and uploaded by the CI bench-smoke job).

The open-loop stream comes from the one seeded synthetic-traffic
generator (``repro.serving.traffic.make_schedule`` via ``serve_async``)
that ``serve_qos_bench.py`` also replays; the recorded ``seed`` field
reproduces the exact arrival schedule and frames.

  PYTHONPATH=src:. python benchmarks/serve_async_bench.py --quick  # CI
  PYTHONPATH=src:. python benchmarks/serve_async_bench.py          # full
"""

from __future__ import annotations

import argparse
import json
import platform
import time

import jax

from repro.core import workload as W
from repro.launch.serve_cnn import compile_for_serving, serve_async

SCHEMA_VERSION = 1
DEFAULT_OUT = "BENCH_serve_async.json"
DEFAULT_STAGES = (1, 2, 4)


def bench_model(model: str, *, batch: int, frames: int | None,
                stage_counts: tuple[int, ...], seed: int,
                max_wait_ms: float | None) -> dict:
    """One model: sweep stage counts over one compiled program. Without
    an explicit ``frames``, each K measures ``(4 + 2K)`` micro-batches —
    a deeper pipeline needs a longer stream for its fill/drain ramps to
    amortize out of the steady-state window."""
    prog = compile_for_serving(model, bits=8, seed=seed)
    row: dict = {
        "modeled_fps_alg1": round(prog.fps(), 3),
        "stages": {},
    }
    for k in stage_counts:
        n = frames if frames is not None else (4 + 2 * k) * batch
        r = serve_async(model, frames=n, batch=batch, stages=k,
                        seed=seed, max_wait_ms=max_wait_ms, program=prog,
                        verbose=True)
        row["stages"][str(k)] = r
    # Normalize against the true single-jit baseline (K=1), not whatever
    # ran first; the field is omitted when a custom --stages sweep has
    # no K=1 run to compare against.
    base = row["stages"].get("1")
    if base is not None:
        base_fps = max(base["measured_steady_fps"], 1e-9)
        for r in row["stages"].values():
            r["throughput_vs_single_jit"] = round(
                r["measured_steady_fps"] / base_fps, 4)
    return row


def run(emit, *, quick: bool = False, batch: int | None = None,
        frames: int | None = None, out: str = DEFAULT_OUT,
        models: list[str] | None = None,
        stage_counts: tuple[int, ...] = DEFAULT_STAGES,
        seed: int = 0, max_wait_ms: float | None = None) -> dict:
    if models is None:
        models = ["alexnet"] if quick else list(W.CNN_MODELS)
    if batch is None:
        batch = 8 if quick else 32
    data: dict = {
        "schema_version": SCHEMA_VERSION,
        "bench": "serve_async",
        "quick": quick,
        "batch": batch,
        "frames": frames,          # null = per-K default (4 + 2K batches)
        "seed": seed,
        "stage_counts": list(stage_counts),
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "jax_version": jax.__version__,
        "backend": jax.devices()[0].platform,
        "host": platform.machine(),
        "models": {},
    }
    for model in models:
        row = bench_model(model, batch=batch, frames=frames,
                          stage_counts=stage_counts, seed=seed,
                          max_wait_ms=max_wait_ms)
        data["models"][model] = row
        for k, r in row["stages"].items():
            vs_k1 = r.get("throughput_vs_single_jit")
            emit(f"serve_async/{model}/K{k}/steady_fps", 0.0,
                 f"{r['measured_steady_fps']}fps"
                 + (f"|x{vs_k1}_vs_K1" if vs_k1 is not None else ""))
            emit(f"serve_async/{model}/K{k}/latency_p99", 0.0,
                 f"{r['latency_ms_p99']}ms|p50={r['latency_ms_p50']}ms|"
                 f"arrival={r['arrival_fps']}fps")
    with open(out, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
    print(f"\n[serve_async_bench] wrote {out} ({len(data['models'])} "
          f"model(s), batch {batch}, K in {list(stage_counts)})")
    return data


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="AlexNet only, small batch (CI bench-smoke)")
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--frames", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0,
                    help="params/calibration/stream RNG seed")
    ap.add_argument("--stages", type=int, action="append", default=None,
                    dest="stage_counts",
                    help="stage count to sweep (repeatable; default 1 2 4)")
    ap.add_argument("--max-wait-ms", type=float, default=None,
                    help="batcher flush timeout (default: one full-batch "
                         "window at the arrival rate)")
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--model", action="append", default=None,
                    choices=sorted(W.CNN_MODELS), dest="models")
    args = ap.parse_args(argv)
    from benchmarks.run import print_csv
    csv: list[str] = []

    def emit(name, us, derived=""):
        csv.append(f"{name},{us:.1f},{derived}")

    run(emit, quick=args.quick, batch=args.batch, frames=args.frames,
        out=args.out, models=args.models, seed=args.seed,
        stage_counts=tuple(args.stage_counts or DEFAULT_STAGES),
        max_wait_ms=args.max_wait_ms)
    print_csv(csv)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
