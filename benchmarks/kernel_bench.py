"""Kernel microbenches: correctness-at-size plus CPU wall time of the
reference paths (the Pallas kernels themselves target TPU; interpret mode
is correctness-only, so wall time here tracks the jnp oracle).

Headline: the fused int8 engine epilogue (bias+ReLU+shift inside the GEMM,
int8 in / int8 out) vs the seed's dequantize-requantize path (int32 out ->
float32 scale -> float bias/ReLU -> per-forward ``quantize_po2`` back to
int8) on the VGG16 conv3 workload, with the layer shape taken from the
compiled EngineProgram so the benchmarked arithmetic is the planned one.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp


def _time(f, *args, n=10):
    jax.block_until_ready(f(*args))   # compile + warm caches
    best = float("inf")
    for _ in range(n):
        t0 = time.time()
        jax.block_until_ready(f(*args))
        best = min(best, time.time() - t0)
    return best * 1e6                 # min-of-n: robust to CPU noise


def run(emit):
    from repro.core import quant
    from repro.core.program import compile_model
    from repro.core.workload import vgg16
    from repro.kernels.conv2d_int8 import ref as cref
    from repro.kernels.flash_attention import ref as aref
    from repro.kernels.rglru_scan import ref as sref

    print("\n== Kernel oracle microbenches (CPU) ==")
    key = jax.random.PRNGKey(0)

    # ---- fused engine epilogue vs the seed dequantize-requantize path,
    # on the conv3_1 workload of the compiled VGG16 plan. The int32 GEMM
    # is byte-identical in both pipelines, so the comparison starts from
    # the shared accumulators: what the fused epilogue replaces is the
    # seed's float32 dequant -> float bias/ReLU -> per-forward
    # quantize_po2 -> align-to-tensor-format between every pair of layers.
    prog = compile_model(vgg16(), theta=900, bits=8)
    wl = next(a.layer for a in prog.allocs if a.layer.name == "conv3_1")
    N, M = wl.H * wl.W, wl.M
    acc = jax.random.randint(key, (N, M), -(2 ** 20), 2 ** 20, jnp.int32)
    shift = jnp.full((M,), 7, jnp.int32)
    bias = jax.random.randint(jax.random.fold_in(key, 1), (M,), -512, 512,
                              jnp.int32)

    fused = jax.jit(lambda a, s, bq: cref.requantize_ref(
        a, s, bq, relu=True))

    def seed_path(a, s, bq):
        y = a.astype(jnp.float32) * jnp.exp2(-7.0) + bq.astype(jnp.float32)
        y = jax.nn.relu(y)
        q, e = quant.quantize_po2(y, axis=-1, bits=8)
        # the seed aligned per-channel formats onto the tensor max before
        # the next layer's MAC array
        return quant.requantize_output(q.astype(jnp.int32), e,
                                       jnp.max(e), bits=8)

    seed = jax.jit(seed_path)
    us_fused = _time(fused, acc, shift, bias)
    us_seed = _time(seed, acc, shift, bias)
    speedup = us_seed / us_fused
    emit(f"kernels/conv3_fused_epilogue_{wl.H}x{wl.W}x{M}", us_fused,
         f"seed_dequant_requant={us_seed:.0f}us|speedup={speedup:.2f}x")
    print(f"conv3 epilogue {wl.H}x{wl.W}x{M}: fused int8 {us_fused:.0f} us "
          f"vs seed dequantize-requantize {us_seed:.0f} us "
          f"({speedup:.2f}x)")

    x = jax.random.randint(key, (1, 56, 56, 64), -128, 127, jnp.int8)
    w = jax.random.randint(key, (3, 3, 64, 128), -30, 30, jnp.int8)
    shift = jnp.full((128,), 7, jnp.int32)
    f = jax.jit(lambda a, b, s: cref.conv2d_int8_ref(a, b, s))
    us = _time(f, x, w, shift)
    emit("kernels/conv2d_int8_ref_56x56x64x128", us, "int8_conv")
    print(f"conv2d_int8 ref 56x56x64->128: {us:.0f} us")

    q = jax.random.normal(key, (1, 1024, 8, 64), jnp.float32)
    f = jax.jit(lambda q: aref.attention_ref(q, q, q))
    us = _time(f, q)
    emit("kernels/flash_attention_ref_1k_8h", us, "causal")
    print(f"attention ref 1k x 8h x 64: {us:.0f} us")

    a = jax.random.uniform(key, (4, 2048, 256), jnp.float32, 0.9, 0.999)
    b = jax.random.normal(key, (4, 2048, 256), jnp.float32)
    f = jax.jit(lambda a, b: sref.linear_scan_ref(a, b))
    us = _time(f, a, b)
    emit("kernels/linear_scan_ref_4x2048x256", us, "rglru")
    print(f"linear scan ref 4x2048x256: {us:.0f} us")
