"""Kernel microbenches: correctness-at-size plus CPU wall time of the
reference paths (the Pallas kernels themselves target TPU; interpret mode
is correctness-only, so wall time here tracks the jnp oracle)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp


def _time(f, *args, n=3):
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else \
        jax.block_until_ready(f(*args))
    t0 = time.time()
    for _ in range(n):
        jax.block_until_ready(f(*args))
    return (time.time() - t0) / n * 1e6


def run(emit):
    from repro.kernels.conv2d_int8 import ref as cref
    from repro.kernels.flash_attention import ref as aref
    from repro.kernels.rglru_scan import ref as sref

    print("\n== Kernel oracle microbenches (CPU) ==")
    key = jax.random.PRNGKey(0)

    x = jax.random.randint(key, (1, 56, 56, 64), -128, 127, jnp.int8)
    w = jax.random.randint(key, (3, 3, 64, 128), -30, 30, jnp.int8)
    shift = jnp.full((128,), 7, jnp.int32)
    f = jax.jit(lambda a, b, s: cref.conv2d_int8_ref(a, b, s))
    us = _time(f, x, w, shift)
    emit("kernels/conv2d_int8_ref_56x56x64x128", us, "int8_conv")
    print(f"conv2d_int8 ref 56x56x64->128: {us:.0f} us")

    q = jax.random.normal(key, (1, 1024, 8, 64), jnp.float32)
    f = jax.jit(lambda q: aref.attention_ref(q, q, q))
    us = _time(f, q)
    emit("kernels/flash_attention_ref_1k_8h", us, "causal")
    print(f"attention ref 1k x 8h x 64: {us:.0f} us")

    a = jax.random.uniform(key, (4, 2048, 256), jnp.float32, 0.9, 0.999)
    b = jax.random.normal(key, (4, 2048, 256), jnp.float32)
    f = jax.jit(lambda a, b: sref.linear_scan_ref(a, b))
    us = _time(f, a, b)
    emit("kernels/linear_scan_ref_4x2048x256", us, "rglru")
    print(f"linear scan ref 4x2048x256: {us:.0f} us")
