"""Table I reproduction: utilization & performance for VGG16 / AlexNet /
ZF / YOLO on a ZC706-class budget (900 DSPs @ 200 MHz), vs the paper's
reported numbers and our models of baselines [1] and [3].

Every row is derived from a compiled :class:`EngineProgram` — the same
object the executor runs — so the reported cycles and the executed
arithmetic come from one plan."""

from __future__ import annotations

import functools
import time

from benchmarks.baselines import (dnnbuilder_allocate, recurrent_efficiency,
                                  winograd_fused_model)
from repro.core import throughput as T
from repro.core import workload as W
from repro.core.program import compile_model
from repro.core.simulator import simulate

PAPER = {  # model: (DSP, eff, fps16, gops16, fps8, gops8)
    "vgg16": (900, 0.980, 11.3, 353, 22.6, 706),
    "alexnet": (864, 0.904, 230, 312, 459, 624),
    "zf": (892, 0.908, 138.4, 324, 276.8, 648),
    "yolo": (892, 0.984, 8.8, 351, 17.5, 702),
}
PAPER_GOP = {  # model complexity the paper quotes (GOP, 2 ops/MAC)
    "vgg16": 30.94, "alexnet": 1.45, "zf": 2.34, "yolo": 40.14,
}
PAPER_BASELINES_VGG = {  # reference: (DSP, eff, gops16)
    "[1] recurrent": (780, 0.585, 137),
    "[2] fused": (824, 0.696, 230),
    "[3] DNNBuilder": (680, 0.962, 262),
}

FREQ = 200e6
THETA = 900


@functools.lru_cache(maxsize=None)
def modeled_row(model: str) -> dict:
    """The analytic Table-I columns for one model, from plan-only compiles
    of the same :class:`EngineProgram` the executor runs — the "modeled"
    side that ``benchmarks/serve_bench.py`` records next to measured FPS.
    Cached: ``run.py all`` consumes it from both table1 and serve_bench."""
    m = W.CNN_MODELS[model]()
    # ---- 16-bit: 1 multiplier per DSP (plan-only compile: Alg. 1 + 2)
    t0 = time.time()
    p16 = compile_model(m, theta=THETA, bits=16, bram_total=545,
                        bandwidth_bytes=4.2e9, freq_hz=FREQ)
    alloc_us = (time.time() - t0) * 1e6
    a16 = p16.allocs
    # ---- 8-bit: 2 multipliers per DSP (paper's efficiency regime);
    # compute allocation only, as in Table I's efficiency columns.
    p8 = compile_model(m, theta=2 * THETA - len(m.layers), bits=8,
                       bram_total=None, freq_hz=FREQ)
    a8 = p8.allocs
    # ---- simulator cross-check on the same program object
    sim = simulate(p16, n_frames=3)
    return {
        "gop": m.gop,
        "alloc_us": alloc_us,
        "dsp16": T.dsps_used(a16),
        "eff16": T.dsp_efficiency(a16),
        "fps16": p16.fps(),
        "gops16": T.gops(a16, freq_hz=FREQ),
        "dsp8": T.dsps_used(a8, macs_per_dsp=2),
        "eff8": T.dsp_efficiency(a8, macs_per_dsp=2),
        "fps8": p8.fps(),
        "gops8": T.gops(a8, freq_hz=FREQ),
        "sim_eff": sim.dsp_efficiency,
    }


def run(emit, models: list[str] | None = None, quick: bool = False):
    """Print the Table-I reproduction. ``quick`` restricts to AlexNet and
    skips the VGG16 baseline / BRAM sections (the CI smoke setting)."""
    if models is None:
        models = ["alexnet"] if quick else list(W.CNN_MODELS)
    rows = []
    for model in models:
        r = modeled_row(model)
        p = PAPER[model]
        gop_ok = abs(r["gop"] - PAPER_GOP[model]) / PAPER_GOP[model] < 0.02
        emit(f"table1/{model}/alloc", r["alloc_us"],
             f"gop={r['gop']:.2f}|paper_gop_ok={gop_ok}")
        rows.append((model, r["dsp16"], r["eff16"], r["fps16"], r["gops16"],
                     r["dsp8"], r["eff8"], r["fps8"], r["gops8"],
                     r["sim_eff"], p))
    print("\n== Table I reproduction (This Work columns) ==")
    print(f"{'model':9s} {'DSP':>4s} {'eff16':>6s} {'fps16':>7s} "
          f"{'gops16':>7s} {'eff8':>6s} {'fps8':>7s} {'gops8':>7s} "
          f"{'sim_eff':>7s} | paper: DSP eff fps16 gops16 fps8 gops8")
    for (model, dsp16, eff16, fps16, gops16, dsp8, eff8, fps8, gops8,
         sim_eff, p) in rows:
        print(f"{model:9s} {dsp16:4d} {eff16:6.3f} {fps16:7.1f} "
              f"{gops16:7.0f} {eff8:6.3f} {fps8:7.1f} {gops8:7.0f} "
              f"{sim_eff:7.3f} | {p[0]:4d} {p[1]:.3f} {p[2]:6.1f} "
              f"{p[3]:4d} {p[4]:6.1f} {p[5]:4d}")
    if quick:
        return rows

    # ---- baselines on VGG16 (the paper's headline comparison)
    l16 = W.vgg16().layer_workloads(weight_bits=16)
    eff_r, cyc_r = recurrent_efficiency(l16)
    gops_r = 2 * sum(l.macs for l in l16) * (150e6 / cyc_r) / 1e9
    th_d, bound_d = dnnbuilder_allocate(l16, THETA)
    frame_d = max(bound_d, 0.0)
    gops_d = 2 * sum(l.macs for l in l16) * (FREQ / frame_d) / 1e9
    eff_d = 2 * sum(l.macs for l in l16) / (2 * th_d * frame_d)
    ours = T.gops(compile_model(W.vgg16(), theta=THETA, bits=16).allocs,
                  freq_hz=FREQ)
    print("\n== VGG16 vs baselines (modeled / paper-reported) ==")
    print(f"[1] recurrent  : eff={eff_r:.3f} gops16={gops_r:5.0f}"
          f"  (paper-reported: eff=0.585 gops=137 @150MHz)")
    print(f"[3] DNNBuilder : theta={th_d} eff={eff_d:.3f} "
          f"gops16={gops_d:5.0f}  (paper-reported: 680 DSP, eff=0.962, "
          f"gops=262)")
    gops_w, _ = winograd_fused_model(l16)
    print(f"[2] Winograd   : gops16(eff)={gops_w:5.0f}  (paper-reported: "
          f"230 @100MHz, 824 DSP, eff=0.696)")
    print(f"This work      : gops16={ours:5.0f}  -> speedup vs [1] "
          f"{ours/gops_r:.2f}x (paper claims 2.58x), vs [2] "
          f"{ours/gops_w:.2f}x (paper claims 1.53x), vs [3] "
          f"{ours/gops_d:.2f}x (paper claims 1.35x)")
    emit("table1/vgg16/speedup_vs_recurrent", 0.0,
         f"{ours/gops_r:.2f}x_vs_paper_2.58x")
    emit("table1/vgg16/speedup_vs_dnnbuilder", 0.0,
         f"{ours/gops_d:.2f}x_vs_paper_1.35x")
    emit("table1/vgg16/speedup_vs_winograd", 0.0,
         f"{ours/gops_w:.2f}x_vs_paper_1.53x")

    # ---- Algorithm 2: BRAM / bandwidth row (Table I "BRAM")
    from repro.core.allocator import total_bram, weight_traffic_per_frame
    paper_bram = {"vgg16": 0.74, "alexnet": 0.84, "zf": 0.58, "yolo": 0.76}
    print("\n== Algorithm 2: BRAM/bandwidth (1090 BRAM18, 4.2 GB/s DDR) ==")
    for model, fn in W.CNN_MODELS.items():
        allocs = compile_model(fn(), theta=THETA, bits=16, bram_total=1090,
                               bandwidth_bytes=4.2e9, freq_hz=FREQ,
                               bram_weights=True).allocs
        act18 = total_bram(allocs, act_bytes=2)
        bram18 = total_bram(allocs, act_bytes=2, weights=True)
        n_res = sum(a.weights_resident for a in allocs)
        traffic = sum(weight_traffic_per_frame(a) for a in allocs
                      if a.layer.kind == "conv")
        bw = T.pipeline_fps(allocs, freq_hz=FREQ) * traffic / 1e9
        print(f"  {model:8s} BRAM {bram18/1090:4.0%} (act {act18}, weight "
              f"{bram18 - act18}, {n_res} resident weight set(s); paper "
              f"total {paper_bram[model]:.0%}), DDR {bw:.1f} GB/s")
        emit(f"table1/{model}/bram", 0.0,
             f"{bram18}of1090|paper={paper_bram[model]}")
    return rows


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser(description="Table I reproduction")
    ap.add_argument("--quick", action="store_true",
                    help="AlexNet only, no baseline/BRAM sections (CI)")
    ap.add_argument("--model", action="append", default=None,
                    choices=sorted(W.CNN_MODELS), dest="models")
    args = ap.parse_args(argv)
    from benchmarks.run import print_csv
    csv: list[str] = []

    def emit(name, us, derived=""):
        csv.append(f"{name},{us:.1f},{derived}")

    run(emit, models=args.models, quick=args.quick)
    print_csv(csv)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
