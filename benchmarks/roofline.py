"""Roofline extraction for every (arch x shape x mesh) cell.

Sources: the dry-run artifacts (experiments/dryrun/*.json) provide the
compile proof, per-device memory, and the collective-op inventory; XLA's
cost analysis counts while-loop (scan) bodies ONCE, so the three roofline
terms are derived from the workload model (repro.core.workload — the same
numbers Algorithm 1 allocates against, validated against the HLO counts at
segment granularity) plus a transparent collective model of the sharding
strategy (Megatron-style TP all-reduces, ZeRO grad reduction, FSDP
all-gathers, pod-level hierarchical reduction).

Hardware (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import json
import os

from repro.configs import ARCHS
from repro.core.workload import lm_layer_workloads, total_params
from repro.launch.shapes import SHAPES, cell_is_runnable

PEAK = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    mesh: str
    chips: int
    t_compute: float
    t_memory: float
    t_collective: float
    model_flops: float
    hlo_flops_raw: float | None
    mem_per_dev: float | None
    coll_inventory: dict | None
    status: str = "ok"

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        # no-overlap baseline: terms serialize
        return self.t_compute + self.t_memory + self.t_collective

    @property
    def roofline_fraction(self) -> float:
        """Achieved fraction of the compute roofline at the modeled step
        time (= MFU when compute-dominated)."""
        return self.t_compute / max(self.step_time, 1e-30)


def analyze_cell(arch: str, shape: str, mesh: str,
                 dryrun_dir: str = "experiments/dryrun",
                 overlap: bool = False) -> Cell | None:
    cfg = ARCHS[arch]
    case = SHAPES[shape]
    ok, _ = cell_is_runnable(cfg, shape)
    if not ok:
        return None
    chips = 512 if mesh == "multipod" else 256
    n_pod = 2 if mesh == "multipod" else 1
    data_ax, model_ax = 16 * n_pod, 16

    mode = case.mode
    layers = lm_layer_workloads(cfg, seq_len=case.seq_len,
                                batch=case.global_batch, mode=mode)
    train = mode == "train"
    flops = 2.0 * sum(l.macs for l in layers) * (3.0 if train else 1.0)
    pbytes = sum(l.weight_bytes for l in layers)
    tokens = case.global_batch * (1 if mode == "decode" else case.seq_len)
    d = cfg.d_model

    # ---- memory term (per-chip bytes / HBM bw)
    if train:
        # params: fwd read + bwd read + optimizer read/write (bf16 + moments)
        param_io = 4.0 * pbytes / chips
        # activations: each layer writes+reads its output fwd, grad bwd,
        # plus ~1 recompute read under remat
        act_io = tokens * d * 2 * len(layers) * 5.0 / chips
    elif mode == "prefill":
        param_io = pbytes / chips
        act_io = tokens * d * 2 * len(layers) * 2.0 / chips
    else:  # decode: weights re-read per token + KV cache read
        param_io = pbytes / chips
        kv = _cache_bytes(cfg, case)
        act_io = kv / chips
    t_memory = (param_io + act_io) / HBM_BW

    # ---- compute term
    t_compute = flops / (chips * PEAK)

    # ---- collective term (per-chip bytes / ICI bw)
    act_bytes_shard = tokens * d * 2 / data_ax / n_pod
    n_layers = cfg.n_layers + (cfg.n_enc_layers or 0)
    coll = 0.0
    ar = lambda size, n: 2.0 * size * (n - 1) / n     # ring all-reduce
    if train:
        coll += n_layers * 2 * (2 if train else 1) * ar(act_bytes_shard,
                                                        model_ax)
        fsdp = total_params(layers) * 2 > 16e9 * 2
        if fsdp:
            coll += 3.0 * pbytes / model_ax / data_ax * (data_ax - 1) \
                / data_ax * 2  # per-layer param all-gathers fwd+bwd
        # gradient reduce-scatter + all-gather over data (ZeRO-1)
        coll += ar(pbytes / model_ax, data_ax)
        if n_pod > 1:  # hierarchical cross-pod all-reduce
            coll += ar(pbytes / (model_ax * 16), n_pod)
    else:
        coll += n_layers * 2 * ar(act_bytes_shard, model_ax)
    t_collective = coll / ICI_BW

    # ---- attach dry-run artifacts
    tag = f"{arch}_{shape}_{mesh}_pjit.json"
    path = os.path.join(dryrun_dir, tag)
    hlo_flops = mem = inv = None
    status = "no-dryrun"
    if os.path.exists(path):
        with open(path) as f:
            dr = json.load(f)
        status = dr.get("status", "?")
        hlo_flops = (dr.get("cost") or {}).get("flops")
        mem_d = dr.get("memory") or {}
        mem = (mem_d.get("argument_size_in_bytes", 0)
               + mem_d.get("temp_size_in_bytes", 0))
        inv = (dr.get("collectives") or {}).get("count_per_kind")
    return Cell(arch, shape, mesh, chips, t_compute, t_memory, t_collective,
                flops, hlo_flops, mem, inv, status)


def _cache_bytes(cfg, case) -> float:
    B, S = case.global_batch, case.seq_len
    if cfg.attn_impl == "mla":
        per_tok = cfg.kv_lora_rank + cfg.rope_head_dim
    else:
        per_tok = 2 * cfg.n_kv_heads * cfg.head_dim
    n_full = sum(1 for k in cfg.layer_kinds()
                 if k in ("attn", "moe", "mla", "mla_moe"))
    n_win = sum(1 for k in cfg.layer_kinds() if k == "attn_local")
    eff_S = S
    return (n_full * B * eff_S * per_tok * 2
            + n_win * B * min(cfg.window or S, S) * 2
            * 2 * cfg.n_kv_heads * cfg.head_dim)


def run(emit, mesh: str = "pod"):
    print(f"\n== Roofline ({mesh}: {512 if mesh=='multipod' else 256} chips,"
          " v5e constants) ==")
    print(f"{'arch':22s}{'shape':13s}{'comp(ms)':>9s}{'mem(ms)':>9s}"
          f"{'coll(ms)':>9s}{'dom':>6s}{'frac':>6s}{'MF/HLO':>7s}"
          f"{'mem/dev(GB)':>12s}")
    cells = []
    for arch in ARCHS:
        for shape in SHAPES:
            c = analyze_cell(arch, shape, mesh)
            if c is None:
                continue
            cells.append(c)
            ratio = (c.model_flops / (c.hlo_flops_raw * c.chips)
                     if c.hlo_flops_raw else float("nan"))
            memgb = (c.mem_per_dev or 0) / 1e9
            print(f"{c.arch:22s}{c.shape:13s}{c.t_compute*1e3:9.2f}"
                  f"{c.t_memory*1e3:9.2f}{c.t_collective*1e3:9.2f}"
                  f"{c.dominant[:5]:>6s}{c.roofline_fraction:6.2f}"
                  f"{ratio:7.1f}{memgb:12.2f}")
            emit(f"roofline/{mesh}/{arch}/{shape}", 0.0,
                 f"dom={c.dominant}|frac={c.roofline_fraction:.3f}"
                 f"|comp_ms={c.t_compute*1e3:.2f}")
    return cells
