"""Serving benchmark: measured steady-state FPS of the jitted batched
executor vs (a) the eager per-sample loop and (b) the Algorithm-1 modeled
pipeline FPS — all from the same compiled :class:`EngineProgram` — written
to one JSON artifact (``BENCH_serve.json``, uploaded by the CI bench-smoke
job).

  PYTHONPATH=src:. python benchmarks/serve_bench.py --quick   # CI setting
  PYTHONPATH=src:. python benchmarks/serve_bench.py           # full sweep
"""

from __future__ import annotations

import argparse
import json
import platform
import time

import jax

from benchmarks.table1 import modeled_row
from repro.core import workload as W
from repro.launch.serve_cnn import serve

SCHEMA_VERSION = 1
DEFAULT_OUT = "BENCH_serve.json"


def bench_model(model: str, *, batch: int, frames: int,
                eager_frames: int, seed: int = 0) -> dict:
    """One model: serve a synthetic stream through the jitted executor,
    time the eager reference loop, and attach the analytic Table-I row.
    ``seed`` pins the params/calibration/stream RNGs explicitly so the
    measured-vs-modeled rows are reproducible run to run."""
    measured = serve(model, frames=frames, batch=batch, seed=seed,
                     eager_frames=eager_frames, verbose=True)
    measured["modeled"] = {
        k: (round(v, 4) if isinstance(v, float) else v)
        for k, v in modeled_row(model).items()}
    return measured


def run(emit, *, quick: bool = False, batch: int | None = None,
        out: str = DEFAULT_OUT, models: list[str] | None = None,
        seed: int = 0) -> dict:
    if models is None:
        models = ["alexnet"] if quick else list(W.CNN_MODELS)
    if batch is None:
        batch = 8 if quick else 32
    frames = 3 * batch
    eager_frames = 2 if quick else 4
    data: dict = {
        "schema_version": SCHEMA_VERSION,
        "bench": "serve",
        "quick": quick,
        "batch": batch,
        "seed": seed,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "jax_version": jax.__version__,
        "backend": jax.devices()[0].platform,
        "host": platform.machine(),
        "models": {},
    }
    for model in models:
        r = bench_model(model, batch=batch, frames=frames,
                        eager_frames=eager_frames, seed=seed)
        data["models"][model] = r
        emit(f"serve/{model}/batched_fps", 0.0,
             f"{r['measured_steady_fps']}fps|batch={batch}")
        emit(f"serve/{model}/eager_fps", 0.0, f"{r['eager_fps']}fps")
        emit(f"serve/{model}/speedup_vs_eager", 0.0,
             f"{r['speedup_vs_eager']}x")
        emit(f"serve/{model}/modeled_fps_alg1", 0.0,
             f"{r['modeled_fps_alg1']}fps")
    with open(out, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
    print(f"\n[serve_bench] wrote {out} "
          f"({len(data['models'])} model(s), batch {batch})")
    return data


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="AlexNet only, small batch (CI bench-smoke)")
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0,
                    help="explicit params/calibration/stream RNG seed "
                         "(recorded in the artifact)")
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--model", action="append", default=None,
                    choices=sorted(W.CNN_MODELS), dest="models")
    args = ap.parse_args(argv)
    from benchmarks.run import print_csv
    csv: list[str] = []

    def emit(name, us, derived=""):
        csv.append(f"{name},{us:.1f},{derived}")

    run(emit, quick=args.quick, batch=args.batch, out=args.out,
        models=args.models, seed=args.seed)
    print_csv(csv)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
