"""Validate BENCH_*.json artifacts (CI bench-smoke gate).

Exits non-zero when a file is missing, is not valid JSON, records no
models, or any row lacks the numbers its schema requires — so a benchmark
run that silently produced garbage cannot upload a green artifact.

Schemas are selected by the artifact's ``bench`` field:

* ``serve`` — measured-vs-modeled FPS per model
  (``benchmarks/serve_bench.py``);
* ``serve_async`` — per stage count K: steady throughput, p50/p95/p99
  request latency, and throughput relative to the K=1 single-jit baseline
  (``benchmarks/serve_async_bench.py``);
* ``serve_qos`` — per arrival rate and per traffic class (at least two):
  queueing/assembly/compute phase-split percentiles, SLO miss rate, and
  drop rate, plus the recorded seed that replays the schedule
  (``benchmarks/serve_qos_bench.py``);
* ``serve_knee`` — the bracketing absolute-QPS sweep: every probe with
  its armed-class miss rate, plus the knee (max sustained QPS) as the
  headline capacity number (``benchmarks/serve_knee_bench.py``). An
  optional ``knee_scaling`` block (``--replicas-sweep``) holds one full
  knee row per replica count R plus the ``knee_vs_r1`` ratios — each R
  row is validated recursively and the ratios must reproduce from the
  rows' ``knee_qps``, so the CI gate on ``knee_vs_r1/2`` cannot drift
  from the data behind it;
* ``serve_chaos`` — fault injection + adversarial traffic
  (``benchmarks/serve_chaos_bench.py``): per model, adversarial-arrival
  knee rows (each a full knee result, validated recursively beside the
  uniform baseline) and one row per fault replay whose liveness
  identities (``resolved``, ``hung``, ``resolved_frac``) must reproduce
  from the outcome counts — the CI gates on hung == 0 and
  resolved_frac == 1.0 cannot drift from the data behind them;
* ``serve_multi`` — the multi-tenant model zoo
  (``benchmarks/serve_multi_bench.py``): per-tenant calibration rows,
  the aggregate-knee sweep (every probe carries per-tenant armed miss
  rates, and ``sustained`` must reproduce from the worst of them), and
  the gated ``isolation`` block — the worst victim armed miss rate
  under a one-tenant flood, which must reconcile with the per-victim
  rows it summarizes.

  python benchmarks/validate_bench.py BENCH_serve.json \
      BENCH_serve_async.json BENCH_serve_qos.json BENCH_serve_knee.json \
      BENCH_serve_multi.json

With ``--baseline DIR`` each artifact is additionally compared against
the committed reference bands in ``DIR`` (``benchmarks/baselines/``):
each baseline file names its ``bench`` kind and two band maps over
"/"-separated paths into the artifact — ``gates`` (regression fails the
run; machine-speed-*relative* metrics like ``throughput_vs_single_jit``
or miss rates) and ``warn`` (prints a warning only; machine-speed-
*absolute* metrics like fps, which legitimately differ across runners).
A gated path missing from a fresh artifact is a failure too — renaming
a field cannot silently disarm its gate.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

REQUIRED_MODEL_KEYS = ("measured_steady_fps", "eager_fps",
                       "speedup_vs_eager", "modeled_fps_alg1", "batch",
                       "frames", "route")

REQUIRED_STAGE_KEYS = ("measured_steady_fps", "modeled_fps_alg1",
                       "arrival_fps",
                       "latency_ms_p50", "latency_ms_p95",
                       "latency_ms_p99", "stages", "boundaries",
                       "stage_balance", "batch", "frames", "route")
POSITIVE_STAGE_KEYS = ("measured_steady_fps", "arrival_fps",
                       "latency_ms_p50", "latency_ms_p95",
                       "latency_ms_p99", "throughput_vs_single_jit")


REQUIRED_QOS_MODEL_KEYS = ("measured_steady_fps", "modeled_fps_alg1",
                           "batch", "stages", "seed", "slo_ms",
                           "traffic_mix", "rates", "route")
REQUIRED_QOS_RATE_KEYS = ("arrival_fps", "load_factor", "submitted",
                          "completed", "expired", "classes")
REQUIRED_QOS_CLASS_KEYS = ("submitted", "completed", "expired",
                           "rejected", "rejected_wait", "slo_miss_rate",
                           "drop_rate", "phase_ms")
QOS_PHASES = ("queueing", "assembly", "compute")
QOS_PCTS = ("p50", "p95", "p99")

REQUIRED_KNEE_MODEL_KEYS = ("measured_steady_fps", "modeled_fps_alg1",
                            "batch", "stages", "seed", "slo_ms",
                            "miss_target", "traffic_mix", "probes",
                            "knee_qps", "knee_of_steady",
                            "admission_control", "replicas", "route")
REQUIRED_KNEE_SCALING_KEYS = ("device_count", "mode", "rows",
                              "knee_vs_r1")
REQUIRED_KNEE_PROBE_KEYS = ("arrival_fps", "sustained",
                            "armed_miss_rate", "armed_submitted",
                            "submitted", "completed", "expired",
                            "rejected", "rejected_wait", "pacing")
REQUIRED_KNEE_RESCALE_KEYS = ("batch", "stages", "seed", "slo_ms",
                              "miss_target", "traffic_mix", "policy",
                              "anchor_qps", "measured_steady_fps_r1",
                              "segments", "rescale_events", "n_rescales",
                              "forced", "replicas_before",
                              "replicas_after", "armed_miss_at_trigger",
                              "armed_miss_after_rescale",
                              "miss_recovered", "hung", "knee")
REQUIRED_RESCALE_EVENT_KEYS = ("model", "before", "after", "compile_s",
                               "swap_s", "action", "reason")
REQUIRED_RESCALE_SEGMENT_KEYS = ("label", "arrival_fps",
                                 "armed_submitted", "armed_missed",
                                 "armed_miss_rate", "replicas")

REQUIRED_CHAOS_MODEL_KEYS = ("slo_ms", "uniform_knee_qps", "scenarios",
                             "faults")
REQUIRED_CHAOS_FAULT_KEYS = ("fault", "plan", "replicas", "arrival_fps",
                             "fleet_steady_fps", "submitted", "completed",
                             "failed", "expired", "rejected",
                             "rejected_wait", "resolved", "hung",
                             "resolved_frac", "armed_submitted",
                             "armed_missed", "armed_miss_rate",
                             "armed_p99_ms", "injected_failures",
                             "injected_slowdowns", "pacing", "recovery",
                             "router", "replica_rows")
REQUIRED_CHAOS_RECOVERY_KEYS = ("window_s", "miss_target", "armed_total",
                                "pre_fault_armed", "windows",
                                "recovered_s")

REQUIRED_MULTI_MODEL_KEYS = ("steady_fps", "modeled_fps_alg1", "share",
                             "slo_ms", "knee")
REQUIRED_MULTI_PROBE_KEYS = ("arrival_fps", "sustained",
                             "worst_armed_miss_rate", "submitted",
                             "completed", "per_tenant")
REQUIRED_MULTI_AGG_KEYS = ("agg_steady_fps", "knee_qps",
                           "knee_of_agg_steady", "probes")
REQUIRED_MULTI_ISO_KEYS = ("flood_tenant", "flood_factor",
                           "victim_armed_miss_rate", "victims")


def _positive(row: dict, key: str) -> bool:
    v = row.get(key)
    return isinstance(v, (int, float)) and v > 0


def _validate_serve_model(name: str, row: dict, errors: list[str]) -> None:
    for key in REQUIRED_MODEL_KEYS:
        if key not in row:
            errors.append(f"models.{name}: missing {key}")
    for key in ("measured_steady_fps", "eager_fps", "modeled_fps_alg1"):
        if not _positive(row, key):
            errors.append(f"models.{name}.{key}={row.get(key)!r} not > 0")


def _validate_async_model(name: str, row: dict, errors: list[str]) -> None:
    stages = row.get("stages")
    if not isinstance(stages, dict) or not stages:
        errors.append(f"models.{name}: empty or missing 'stages'")
        return
    # The K=1 baseline ratio exists iff a K=1 run is in the sweep.
    has_baseline = isinstance(stages.get("1"), dict)
    for k, srow in stages.items():
        where = f"models.{name}.stages.{k}"
        if not isinstance(srow, dict):
            errors.append(f"{where}: row is {type(srow).__name__}, "
                          f"not object")
            continue
        required = REQUIRED_STAGE_KEYS + (
            ("throughput_vs_single_jit",) if has_baseline else ())
        for key in required:
            if key not in srow:
                errors.append(f"{where}: missing {key}")
        for key in POSITIVE_STAGE_KEYS:
            if key in srow and not _positive(srow, key):
                errors.append(f"{where}.{key}={srow.get(key)!r} not > 0")
        if str(k).isdigit() and srow.get("stages") != int(k):
            errors.append(f"{where}: stage count {srow.get('stages')!r} "
                          f"does not match key {k!r}")
        if srow.get("latency_ms_p50") and srow.get("latency_ms_p99") and \
                srow["latency_ms_p99"] < srow["latency_ms_p50"]:
            errors.append(f"{where}: p99 < p50 "
                          f"({srow['latency_ms_p99']} < "
                          f"{srow['latency_ms_p50']})")


def _validate_qos_class(where: str, crow: dict, errors: list[str]) -> None:
    for key in REQUIRED_QOS_CLASS_KEYS:
        if key not in crow:
            errors.append(f"{where}: missing {key}")
    for key in ("slo_miss_rate", "drop_rate"):
        v = crow.get(key)
        if key in crow and not (isinstance(v, (int, float))
                                and 0 <= v <= 1):
            errors.append(f"{where}.{key}={v!r} not in [0, 1]")
    phases = crow.get("phase_ms")
    if not isinstance(phases, dict):
        errors.append(f"{where}: missing phase_ms")
        return
    for phase in QOS_PHASES:
        prow = phases.get(phase)
        if not isinstance(prow, dict):
            errors.append(f"{where}.phase_ms: missing {phase}")
            continue
        for p in QOS_PCTS:
            if not isinstance(prow.get(p), (int, float)):
                errors.append(f"{where}.phase_ms.{phase}: missing {p}")
    # Completed-request percentiles must be ordered (NaN — an empty
    # class — compares False and is allowed: a quick run may complete
    # nothing for a class under heavy overload).
    comp = phases.get("compute")
    if isinstance(comp, dict) and \
            isinstance(comp.get("p50"), float) and \
            isinstance(comp.get("p99"), float) and \
            comp["p99"] < comp["p50"]:
        errors.append(f"{where}: compute p99 < p50 "
                      f"({comp['p99']} < {comp['p50']})")


def _validate_qos_model(name: str, row: dict, errors: list[str]) -> None:
    for key in REQUIRED_QOS_MODEL_KEYS:
        if key not in row:
            errors.append(f"models.{name}: missing {key}")
    if not _positive(row, "measured_steady_fps"):
        errors.append(f"models.{name}.measured_steady_fps="
                      f"{row.get('measured_steady_fps')!r} not > 0")
    mix = row.get("traffic_mix")
    if not isinstance(mix, list) or len(mix) < 2:
        errors.append(f"models.{name}: traffic_mix needs >= 2 classes, "
                      f"got {mix!r}")
    rates = row.get("rates")
    if not isinstance(rates, dict) or len(rates) < 2:
        errors.append(f"models.{name}: needs >= 2 arrival rates, got "
                      f"{sorted(rates) if isinstance(rates, dict) else rates!r}")
        return
    for rate_key, rrow in rates.items():
        where = f"models.{name}.rates.{rate_key}"
        if not isinstance(rrow, dict):
            errors.append(f"{where}: row is {type(rrow).__name__}, "
                          f"not object")
            continue
        for key in REQUIRED_QOS_RATE_KEYS:
            if key not in rrow:
                errors.append(f"{where}: missing {key}")
        if not _positive(rrow, "arrival_fps"):
            errors.append(f"{where}.arrival_fps="
                          f"{rrow.get('arrival_fps')!r} not > 0")
        classes = rrow.get("classes")
        if not isinstance(classes, dict) or len(classes) < 2:
            errors.append(f"{where}: needs >= 2 traffic classes, got "
                          f"{sorted(classes) if isinstance(classes, dict) else classes!r}")
            continue
        n = sum(c.get("submitted", 0) for c in classes.values()
                if isinstance(c, dict))
        if rrow.get("submitted") != n:
            errors.append(f"{where}: class submitted counts {n} do not "
                          f"reconcile with total {rrow.get('submitted')!r}")
        for cname, crow in classes.items():
            if not isinstance(crow, dict):
                errors.append(f"{where}.classes.{cname}: row is "
                              f"{type(crow).__name__}, not object")
                continue
            _validate_qos_class(f"{where}.classes.{cname}", crow, errors)


def _validate_knee_scaling(name: str, block, errors: list[str]) -> None:
    """The knee-vs-R sweep block: every R row is itself a full knee
    result (validated recursively), row R must have run with R replicas,
    and the recorded ``knee_vs_r1`` ratios must reproduce from the rows'
    knee_qps values — a gate on ``knee_vs_r1/2`` is only meaningful if
    the ratio cannot drift from the data it summarizes."""
    where = f"models.{name}.knee_scaling"
    if not isinstance(block, dict):
        errors.append(f"{where}: block is {type(block).__name__}, "
                      f"not object")
        return
    for key in REQUIRED_KNEE_SCALING_KEYS:
        if key not in block:
            errors.append(f"{where}: missing {key}")
    rows = block.get("rows")
    if not isinstance(rows, dict) or "1" not in rows:
        errors.append(f"{where}: rows must include the R=1 baseline, "
                      f"got {sorted(rows) if isinstance(rows, dict) else rows!r}")
        return
    for rk, rrow in rows.items():
        if not isinstance(rrow, dict):
            errors.append(f"{where}.rows.{rk}: row is "
                          f"{type(rrow).__name__}, not object")
            continue
        _validate_knee_model(f"{name}.knee_scaling.rows.{rk}", rrow,
                             errors)
        if str(rk).isdigit() and rrow.get("replicas") != int(rk):
            errors.append(f"{where}.rows.{rk}: replicas="
                          f"{rrow.get('replicas')!r} does not match "
                          f"key {rk!r}")
    knee_r1 = rows["1"].get("knee_qps") if isinstance(rows["1"], dict) \
        else None
    ratios = block.get("knee_vs_r1")
    if not isinstance(ratios, dict) or not ratios:
        errors.append(f"{where}: empty or missing knee_vs_r1")
        return
    for rk, ratio in ratios.items():
        rwhere = f"{where}.knee_vs_r1.{rk}"
        if rk not in rows:
            errors.append(f"{rwhere}: no matching rows entry")
            continue
        knee_r = rows[rk].get("knee_qps") \
            if isinstance(rows[rk], dict) else None
        if ratio is None:
            # Legitimate only when the sweep itself found no knee for
            # one side of the ratio; a gate on this path still fails
            # (None is not comparable), which is the intended signal.
            if knee_r is not None and knee_r1 is not None:
                errors.append(f"{rwhere} is null but both knees exist "
                              f"({knee_r} / {knee_r1})")
            continue
        if not isinstance(ratio, (int, float)) or ratio <= 0:
            errors.append(f"{rwhere}={ratio!r} not > 0")
            continue
        if isinstance(knee_r1, (int, float)) and knee_r1 > 0 and \
                isinstance(knee_r, (int, float)) and \
                abs(ratio - knee_r / knee_r1) > 0.01:
            errors.append(f"{rwhere}={ratio} does not reproduce from "
                          f"rows ({knee_r} / {knee_r1})")


def _validate_knee_after_rescale(name: str, block,
                                 errors: list[str]) -> None:
    """The elastic-runtime ramp block: a live drain-swap-resume rescale
    happened (``n_rescales >= 1``) with no request dropped or left
    unresolved (``hung`` — the CI baseline pins it to 0), the recorded
    replica topology must reproduce from the rescale events it
    summarizes, and the nested post-rescale ``knee`` row is itself a
    full knee result (validated recursively) measured at the rescaled
    replica count."""
    where = f"models.{name}.knee_after_rescale"
    if not isinstance(block, dict):
        errors.append(f"{where}: block is {type(block).__name__}, "
                      f"not object")
        return
    for key in REQUIRED_KNEE_RESCALE_KEYS:
        if key not in block:
            errors.append(f"{where}: missing {key}")
    events = block.get("rescale_events")
    if not isinstance(events, list) or not events:
        errors.append(f"{where}: empty or missing rescale_events — the "
                      f"ramp must trigger (or force) a live rescale")
        return
    if block.get("n_rescales") != len(events):
        errors.append(f"{where}: n_rescales={block.get('n_rescales')!r} "
                      f"does not match {len(events)} recorded events")
    for i, ev in enumerate(events):
        ewhere = f"{where}.rescale_events[{i}]"
        if not isinstance(ev, dict):
            errors.append(f"{ewhere}: row is {type(ev).__name__}, "
                          f"not object")
            continue
        for key in REQUIRED_RESCALE_EVENT_KEYS:
            if key not in ev:
                errors.append(f"{ewhere}: missing {key}")
    first, last = events[0], events[-1]
    if isinstance(first, dict) and isinstance(first.get("before"), dict) \
            and first["before"].get("replicas") != \
            block.get("replicas_before"):
        errors.append(f"{where}: replicas_before="
                      f"{block.get('replicas_before')!r} does not "
                      f"reproduce from the first event "
                      f"({first['before'].get('replicas')!r})")
    if isinstance(last, dict) and isinstance(last.get("after"), dict) \
            and last["after"].get("replicas") != \
            block.get("replicas_after"):
        errors.append(f"{where}: replicas_after="
                      f"{block.get('replicas_after')!r} does not "
                      f"reproduce from the last event "
                      f"({last['after'].get('replicas')!r})")
    hung = block.get("hung")
    if not isinstance(hung, int) or hung < 0:
        errors.append(f"{where}.hung={hung!r} not an int >= 0")
    segments = block.get("segments")
    if not isinstance(segments, list) or len(segments) < 2:
        errors.append(f"{where}: needs >= 2 segments (ramp + recovery), "
                      f"got {len(segments) if isinstance(segments, list) else segments!r}")
    else:
        for i, seg in enumerate(segments):
            swhere = f"{where}.segments[{i}]"
            if not isinstance(seg, dict):
                errors.append(f"{swhere}: row is {type(seg).__name__}, "
                              f"not object")
                continue
            for key in REQUIRED_RESCALE_SEGMENT_KEYS:
                if key not in seg:
                    errors.append(f"{swhere}: missing {key}")
            miss = seg.get("armed_miss_rate")
            if not (isinstance(miss, (int, float)) and 0 <= miss <= 1):
                errors.append(f"{swhere}.armed_miss_rate={miss!r} "
                              f"not in [0, 1]")
    at, after = (block.get("armed_miss_at_trigger"),
                 block.get("armed_miss_after_rescale"))
    for key, v in (("armed_miss_at_trigger", at),
                   ("armed_miss_after_rescale", after)):
        if not (isinstance(v, (int, float)) and 0 <= v <= 1):
            errors.append(f"{where}.{key}={v!r} not in [0, 1]")
    if isinstance(at, (int, float)) and isinstance(after, (int, float)) \
            and bool(block.get("miss_recovered")) != (after <= at):
        errors.append(f"{where}: miss_recovered="
                      f"{block.get('miss_recovered')!r} contradicts "
                      f"miss {at} -> {after}")
    knee = block.get("knee")
    if not isinstance(knee, dict):
        errors.append(f"{where}.knee is "
                      f"{type(knee).__name__}, not object")
        return
    _validate_knee_model(f"{name}.knee_after_rescale.knee", knee, errors)
    if knee.get("replicas") != block.get("replicas_after"):
        errors.append(f"{where}.knee.replicas={knee.get('replicas')!r} "
                      f"was not measured at replicas_after="
                      f"{block.get('replicas_after')!r}")


def _validate_knee_model(name: str, row: dict, errors: list[str]) -> None:
    for key in REQUIRED_KNEE_MODEL_KEYS:
        if key not in row:
            errors.append(f"models.{name}: missing {key}")
    if "knee_scaling" in row:
        _validate_knee_scaling(name, row["knee_scaling"], errors)
    if "knee_after_rescale" in row:
        _validate_knee_after_rescale(name, row["knee_after_rescale"],
                                     errors)
    if not _positive(row, "measured_steady_fps"):
        errors.append(f"models.{name}.measured_steady_fps="
                      f"{row.get('measured_steady_fps')!r} not > 0")
    target = row.get("miss_target")
    if not (isinstance(target, (int, float)) and 0 < target < 1):
        errors.append(f"models.{name}.miss_target={target!r} "
                      f"not in (0, 1)")
        target = None
    probes = row.get("probes")
    if not isinstance(probes, list) or len(probes) < 2:
        errors.append(f"models.{name}: needs >= 2 probes, got "
                      f"{len(probes) if isinstance(probes, list) else probes!r}")
        return
    sustained_rates = []
    for i, prow in enumerate(probes):
        where = f"models.{name}.probes[{i}]"
        if not isinstance(prow, dict):
            errors.append(f"{where}: row is {type(prow).__name__}, "
                          f"not object")
            continue
        for key in REQUIRED_KNEE_PROBE_KEYS:
            if key not in prow:
                errors.append(f"{where}: missing {key}")
        if not _positive(prow, "arrival_fps"):
            errors.append(f"{where}.arrival_fps="
                          f"{prow.get('arrival_fps')!r} not > 0")
        miss = prow.get("armed_miss_rate")
        if not (isinstance(miss, (int, float)) and 0 <= miss <= 1):
            errors.append(f"{where}.armed_miss_rate={miss!r} "
                          f"not in [0, 1]")
            continue
        if target is not None and \
                bool(prow.get("sustained")) != (miss < target):
            errors.append(f"{where}: sustained={prow.get('sustained')!r} "
                          f"contradicts miss {miss} vs target {target}")
        if prow.get("sustained"):
            sustained_rates.append(prow["arrival_fps"])
    knee = row.get("knee_qps")
    if knee is None:
        if sustained_rates:
            errors.append(f"models.{name}: knee_qps is null but "
                          f"{len(sustained_rates)} probes sustained")
        return
    if not isinstance(knee, (int, float)) or knee <= 0:
        errors.append(f"models.{name}.knee_qps={knee!r} not > 0")
        return
    # The headline must be a probe the sweep actually sustained.
    if sustained_rates and abs(knee - max(sustained_rates)) > 1e-6:
        errors.append(f"models.{name}: knee_qps={knee} is not the max "
                      f"sustained probe ({max(sustained_rates)})")


def _validate_chaos_fault(where: str, frow: dict,
                          errors: list[str]) -> None:
    """One fault replay row. The liveness identities must *reproduce*
    from the outcome counts — the CI gates sit on ``hung`` and
    ``resolved_frac``, and a gate is only meaningful if the gated number
    cannot drift from the counts behind it."""
    for key in REQUIRED_CHAOS_FAULT_KEYS:
        if key not in frow:
            errors.append(f"{where}: missing {key}")
    for key in ("arrival_fps", "fleet_steady_fps"):
        if not _positive(frow, key):
            errors.append(f"{where}.{key}={frow.get(key)!r} not > 0")
    counts = {k: frow.get(k) for k in
              ("submitted", "completed", "failed", "expired", "rejected",
               "rejected_wait", "resolved", "hung")}
    if all(isinstance(v, int) for v in counts.values()):
        outcomes = (counts["completed"] + counts["failed"]
                    + counts["expired"] + counts["rejected"]
                    + counts["rejected_wait"])
        if counts["resolved"] != outcomes:
            errors.append(f"{where}: resolved={counts['resolved']} does "
                          f"not reproduce from outcome counts "
                          f"({outcomes})")
        if counts["hung"] != counts["submitted"] - counts["resolved"]:
            errors.append(f"{where}: hung={counts['hung']} does not "
                          f"reproduce from submitted - resolved "
                          f"({counts['submitted']} - "
                          f"{counts['resolved']})")
        frac = frow.get("resolved_frac")
        if counts["submitted"] > 0 and (
                not isinstance(frac, (int, float))
                or abs(frac - counts["resolved"] / counts["submitted"])
                > 1e-5):
            errors.append(f"{where}: resolved_frac={frac!r} does not "
                          f"reproduce from {counts['resolved']} / "
                          f"{counts['submitted']}")
    miss = frow.get("armed_miss_rate")
    if miss is not None and not (isinstance(miss, (int, float))
                                 and 0 <= miss <= 1):
        errors.append(f"{where}.armed_miss_rate={miss!r} not in [0, 1]")
    fault = frow.get("fault")
    if fault in ("kill_replica", "fail_at_t"):
        # A fault replay where the fault never fired measures nothing.
        for key in ("injected_failures", "failed"):
            if not _positive(frow, key):
                errors.append(f"{where}.{key}={frow.get(key)!r} not > 0 "
                              f"— the {fault} fault never bit")
    elif fault == "straggler":
        if not _positive(frow, "injected_slowdowns"):
            errors.append(f"{where}.injected_slowdowns="
                          f"{frow.get('injected_slowdowns')!r} not > 0 "
                          f"— the straggler fault never bit")
    plan = frow.get("plan")
    if not isinstance(plan, dict) or "kill_mode" not in plan:
        errors.append(f"{where}: plan is not a recorded FaultPlan")
    rec = frow.get("recovery")
    if not isinstance(rec, dict):
        errors.append(f"{where}: missing recovery report")
        return
    for key in REQUIRED_CHAOS_RECOVERY_KEYS:
        if key not in rec:
            errors.append(f"{where}.recovery: missing {key}")
    rs = rec.get("recovered_s")
    if rs is not None and (not isinstance(rs, (int, float)) or rs < 0):
        errors.append(f"{where}.recovery.recovered_s={rs!r} not >= 0")
    if not isinstance(rec.get("windows"), list):
        errors.append(f"{where}.recovery.windows is not a list")


def _validate_chaos_model(name: str, row: dict,
                          errors: list[str]) -> None:
    """One model's chaos row: scenario knees (each a full knee result,
    validated recursively) plus one row per fault replay."""
    for key in REQUIRED_CHAOS_MODEL_KEYS:
        if key not in row:
            errors.append(f"models.{name}: missing {key}")
    scen = row.get("scenarios")
    if not isinstance(scen, dict) or "uniform" not in scen:
        errors.append(f"models.{name}: scenarios must include the "
                      f"uniform baseline, got "
                      f"{sorted(scen) if isinstance(scen, dict) else scen!r}")
    else:
        if len(scen) < 2:
            errors.append(f"models.{name}: needs >= 1 adversarial "
                          f"scenario beside uniform, got {sorted(scen)}")
        for s, srow in scen.items():
            where = f"models.{name}.scenarios.{s}"
            if not isinstance(srow, dict):
                errors.append(f"{where}: row is {type(srow).__name__}, "
                              f"not object")
                continue
            _validate_knee_model(f"{name}.scenarios.{s}", srow, errors)
            if srow.get("scenario") != s:
                errors.append(f"{where}: scenario="
                              f"{srow.get('scenario')!r} does not match "
                              f"key {s!r}")
        base = scen.get("uniform")
        if isinstance(base, dict) and \
                row.get("uniform_knee_qps") != base.get("knee_qps"):
            errors.append(f"models.{name}: uniform_knee_qps="
                          f"{row.get('uniform_knee_qps')!r} does not "
                          f"match scenarios.uniform.knee_qps="
                          f"{base.get('knee_qps')!r}")
    faults = row.get("faults")
    if not isinstance(faults, dict) or not faults:
        errors.append(f"models.{name}: empty or missing faults")
        return
    for fname, frow in faults.items():
        where = f"models.{name}.faults.{fname}"
        if not isinstance(frow, dict):
            errors.append(f"{where}: row is {type(frow).__name__}, "
                          f"not object")
            continue
        if frow.get("fault") != fname:
            errors.append(f"{where}: fault={frow.get('fault')!r} does "
                          f"not match key {fname!r}")
        _validate_chaos_fault(where, frow, errors)


def _validate_multi(data: dict, errors: list[str]) -> None:
    """The multi-tenant artifact: per-tenant rows, the aggregate-knee
    sweep (each probe's ``sustained`` and ``worst_armed_miss_rate`` must
    reproduce from its per-tenant rows), and the isolation block whose
    gated headline must reconcile with the per-victim rows."""
    target = data.get("miss_target")
    if not (isinstance(target, (int, float)) and 0 < target < 1):
        errors.append(f"miss_target={target!r} not in (0, 1)")
        target = None
    models = data.get("models", {})
    if isinstance(models, dict) and len(models) < 2:
        errors.append(f"serve_multi needs >= 2 tenants, got "
                      f"{sorted(models)}")
    for name, row in models.items():
        if not isinstance(row, dict):
            continue                    # typed by the caller already
        for key in REQUIRED_MULTI_MODEL_KEYS:
            if key not in row:
                errors.append(f"models.{name}: missing {key}")
        for key in ("steady_fps", "modeled_fps_alg1", "slo_ms"):
            if key in row and not _positive(row, key):
                errors.append(f"models.{name}.{key}={row.get(key)!r} "
                              f"not > 0")
    agg = data.get("aggregate")
    if not isinstance(agg, dict):
        errors.append("empty or missing 'aggregate'")
        return
    for key in REQUIRED_MULTI_AGG_KEYS:
        if key not in agg:
            errors.append(f"aggregate: missing {key}")
    probes = agg.get("probes")
    if not isinstance(probes, list) or len(probes) < 2:
        errors.append(f"aggregate: needs >= 2 probes, got "
                      f"{len(probes) if isinstance(probes, list) else probes!r}")
        return
    sustained_rates = []
    for i, prow in enumerate(probes):
        where = f"aggregate.probes[{i}]"
        if not isinstance(prow, dict):
            errors.append(f"{where}: row is {type(prow).__name__}, "
                          f"not object")
            continue
        for key in REQUIRED_MULTI_PROBE_KEYS:
            if key not in prow:
                errors.append(f"{where}: missing {key}")
        if not _positive(prow, "arrival_fps"):
            errors.append(f"{where}.arrival_fps="
                          f"{prow.get('arrival_fps')!r} not > 0")
        per_tenant = prow.get("per_tenant")
        worst = prow.get("worst_armed_miss_rate")
        if isinstance(per_tenant, dict) and per_tenant:
            rates = [t.get("armed_miss_rate") for t in per_tenant.values()
                     if isinstance(t, dict)]
            if all(isinstance(r, (int, float)) for r in rates) and \
                    isinstance(worst, (int, float)) and rates and \
                    abs(worst - max(rates)) > 1e-9:
                errors.append(f"{where}: worst_armed_miss_rate={worst} "
                              f"does not reproduce from per_tenant "
                              f"(max {max(rates)})")
        if isinstance(worst, (int, float)) and target is not None and \
                bool(prow.get("sustained")) != (worst < target):
            errors.append(f"{where}: sustained={prow.get('sustained')!r} "
                          f"contradicts worst miss {worst} vs target "
                          f"{target}")
        if prow.get("sustained") and _positive(prow, "arrival_fps"):
            sustained_rates.append(prow["arrival_fps"])
    knee = agg.get("knee_qps")
    if knee is None:
        if sustained_rates:
            errors.append(f"aggregate: knee_qps is null but "
                          f"{len(sustained_rates)} probes sustained")
    elif not isinstance(knee, (int, float)) or knee <= 0:
        errors.append(f"aggregate.knee_qps={knee!r} not > 0")
    elif sustained_rates and abs(knee - max(sustained_rates)) > 1e-6:
        errors.append(f"aggregate: knee_qps={knee} is not the max "
                      f"sustained probe ({max(sustained_rates)})")
    iso = data.get("isolation")
    if not isinstance(iso, dict):
        errors.append("empty or missing 'isolation'")
        return
    for key in REQUIRED_MULTI_ISO_KEYS:
        if key not in iso:
            errors.append(f"isolation: missing {key}")
    flood = iso.get("flood_tenant")
    if isinstance(models, dict) and flood not in models:
        errors.append(f"isolation.flood_tenant={flood!r} is not a "
                      f"recorded tenant")
    victims = iso.get("victims")
    if not isinstance(victims, dict) or not victims:
        errors.append("isolation: empty or missing victims")
        return
    if isinstance(models, dict) and flood in victims:
        errors.append("isolation: the flood tenant cannot be its own "
                      "victim")
    vrates = [v.get("armed_miss_rate") for v in victims.values()
              if isinstance(v, dict)]
    headline = iso.get("victim_armed_miss_rate")
    if not (isinstance(headline, (int, float)) and 0 <= headline <= 1):
        errors.append(f"isolation.victim_armed_miss_rate={headline!r} "
                      f"not in [0, 1]")
    elif vrates and all(isinstance(r, (int, float)) for r in vrates) and \
            abs(headline - max(vrates)) > 1e-9:
        errors.append(f"isolation: victim_armed_miss_rate={headline} "
                      f"does not reproduce from victims "
                      f"(max {max(vrates)})")


def validate(path: str) -> list[str]:
    errors: list[str] = []
    try:
        with open(path) as f:
            data = json.load(f)
    except FileNotFoundError:
        return [f"{path}: file not found"]
    except json.JSONDecodeError as e:
        return [f"{path}: malformed JSON: {e}"]
    if not isinstance(data, dict):
        return [f"{path}: top level is {type(data).__name__}, not object"]
    if data.get("schema_version") != 1:
        errors.append(f"schema_version={data.get('schema_version')!r} != 1")
    bench = data.get("bench", "serve")
    if bench not in ("serve", "serve_async", "serve_qos", "serve_knee",
                     "serve_multi", "serve_chaos"):
        errors.append(f"unknown bench kind {bench!r}")
        return errors
    if bench in ("serve_qos", "serve_knee", "serve_multi",
                 "serve_chaos") and \
            not isinstance(data.get("seed"), int):
        errors.append(f"{bench} artifact must record its schedule seed")
    models = data.get("models")
    if not isinstance(models, dict) or not models:
        errors.append("empty or missing 'models'")
        return errors
    for name, row in models.items():
        if not isinstance(row, dict):
            errors.append(f"models.{name}: row is "
                          f"{type(row).__name__}, not object")
            continue
        if bench == "serve":
            _validate_serve_model(name, row, errors)
        elif bench == "serve_qos":
            _validate_qos_model(name, row, errors)
        elif bench == "serve_knee":
            _validate_knee_model(name, row, errors)
        elif bench == "serve_chaos":
            _validate_chaos_model(name, row, errors)
        elif bench == "serve_async":
            _validate_async_model(name, row, errors)
    if bench == "serve_multi":
        _validate_multi(data, errors)
    return errors


# ---------------------------------------------------------------------------
# Baseline regression gate (--baseline)
# ---------------------------------------------------------------------------


def _lookup(data, path: str):
    """Walk a "/"-separated path through nested dicts/lists ("/" rather
    than "." because rate keys like "0.6x" contain dots). Returns
    (found, value)."""
    cur = data
    for part in path.split("/"):
        if isinstance(cur, dict):
            if part not in cur:
                return False, None
            cur = cur[part]
        elif isinstance(cur, list):
            try:
                cur = cur[int(part)]
            except (ValueError, IndexError):
                return False, None
        else:
            return False, None
    return True, cur


def load_baselines(dirname: str) -> tuple[list[dict], list[str]]:
    """Load every ``*.json`` baseline in ``dirname``. A malformed
    baseline is an error — a gate that cannot load must not silently
    pass."""
    baselines, errors = [], []
    if not os.path.isdir(dirname):
        return [], [f"baseline dir {dirname!r} not found"]
    for fname in sorted(os.listdir(dirname)):
        if not fname.endswith(".json"):
            continue
        fpath = os.path.join(dirname, fname)
        try:
            with open(fpath) as f:
                b = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            errors.append(f"baseline {fpath}: unreadable: {e}")
            continue
        if not isinstance(b, dict) or "bench" not in b:
            errors.append(f"baseline {fpath}: missing 'bench' field")
            continue
        b["_file"] = fpath
        baselines.append(b)
    return baselines, errors


def _check_band(where: str, value, band: dict) -> str | None:
    if not isinstance(value, (int, float)) or isinstance(value, bool) \
            or value != value:            # NaN
        return f"{where}={value!r} is not a comparable number"
    lo, hi = band.get("min"), band.get("max")
    if lo is not None and value < lo:
        return f"{where}={value} below baseline min {lo}"
    if hi is not None and value > hi:
        return f"{where}={value} above baseline max {hi}"
    return None


def check_baseline(data: dict, baseline: dict) -> tuple[list[str],
                                                        list[str]]:
    """Compare one artifact against one baseline's bands. Returns
    (gate_errors, warnings). Gated paths must exist; warn-only paths
    that are missing only warn."""
    gate_errors, warnings = [], []
    src = baseline.get("_file", "<baseline>")
    for path, band in sorted(baseline.get("gates", {}).items()):
        found, value = _lookup(data, path)
        if not found:
            gate_errors.append(f"{src}: gated path {path!r} missing "
                               f"from artifact")
            continue
        msg = _check_band(path, value, band)
        if msg is not None:
            gate_errors.append(f"{src}: {msg}")
    for path, band in sorted(baseline.get("warn", {}).items()):
        found, value = _lookup(data, path)
        if not found:
            warnings.append(f"{src}: warn path {path!r} missing "
                            f"from artifact")
            continue
        msg = _check_band(path, value, band)
        if msg is not None:
            warnings.append(f"{src}: {msg}")
    return gate_errors, warnings


def check_against_baselines(path: str, data: dict,
                            baselines: list[dict]) -> tuple[list[str],
                                                            list[str]]:
    """Run every baseline matching this artifact's bench kind (and
    quick-mode flag, when the baseline pins one — quick reference
    numbers say nothing about a full run). Matching zero baselines is
    never silent: if this bench kind has committed baselines but none
    fit the artifact's quick flag, that is a gate failure (a regression
    in the quick wiring would otherwise disarm every band); a bench
    kind with no baselines at all only warns."""
    gate_errors, warnings = [], []
    kind = [b for b in baselines if b.get("bench") == data.get("bench")]
    matched = [b for b in kind
               if "quick" not in b
               or bool(b["quick"]) == bool(data.get("quick"))]
    if not kind:
        warnings.append(f"{path}: no committed baseline for bench kind "
                        f"{data.get('bench')!r}")
    elif not matched:
        gate_errors.append(
            f"{path}: bench kind {data.get('bench')!r} has "
            f"{len(kind)} baseline(s) but none match "
            f"quick={bool(data.get('quick'))!r} — the gate would be "
            f"silently disarmed")
    for b in matched:
        ge, wa = check_baseline(data, b)
        gate_errors.extend(ge)
        warnings.extend(wa)
    if matched:
        print(f"[validate_bench] {path}: checked against {len(matched)} "
              f"baseline(s)")
    return gate_errors, warnings


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("paths", nargs="*", default=["BENCH_serve.json"],
                    help="BENCH_*.json artifacts to validate")
    ap.add_argument("--baseline", default=None, metavar="DIR",
                    help="also gate artifacts against the committed "
                         "reference bands in DIR "
                         "(benchmarks/baselines/)")
    args = ap.parse_args(argv)
    paths = args.paths or ["BENCH_serve.json"]
    baselines: list[dict] = []
    bad = False
    if args.baseline is not None:
        baselines, berrs = load_baselines(args.baseline)
        for e in berrs:
            bad = True
            print(f"[validate_bench] FAIL: {e}", file=sys.stderr)
    for path in paths:
        errors = validate(path)
        if errors:
            bad = True
            for e in errors:
                print(f"[validate_bench] FAIL: {e}", file=sys.stderr)
            continue
        with open(path) as f:
            data = json.load(f)
        if baselines:
            gate_errors, warnings = check_against_baselines(
                path, data, baselines)
            for w in warnings:
                print(f"[validate_bench] WARN: {w}")
            if gate_errors:
                bad = True
                for e in gate_errors:
                    print(f"[validate_bench] FAIL: {e}", file=sys.stderr)
                continue
        print(f"[validate_bench] OK: {path} ({len(data['models'])} "
              f"model(s))")
    return 1 if bad else 0


if __name__ == "__main__":
    raise SystemExit(main())
