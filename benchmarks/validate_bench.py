"""Validate a BENCH_serve.json artifact (CI bench-smoke gate).

Exits non-zero when the file is missing, is not valid JSON, records no
models, or any model row lacks a positive measured/modeled FPS — so a
benchmark run that silently produced garbage cannot upload a green
artifact.

  python benchmarks/validate_bench.py BENCH_serve.json
"""

from __future__ import annotations

import json
import sys

REQUIRED_MODEL_KEYS = ("measured_steady_fps", "eager_fps",
                       "speedup_vs_eager", "modeled_fps_alg1", "batch",
                       "frames", "route")


def validate(path: str) -> list[str]:
    errors: list[str] = []
    try:
        with open(path) as f:
            data = json.load(f)
    except FileNotFoundError:
        return [f"{path}: file not found"]
    except json.JSONDecodeError as e:
        return [f"{path}: malformed JSON: {e}"]
    if not isinstance(data, dict):
        return [f"{path}: top level is {type(data).__name__}, not object"]
    if data.get("schema_version") != 1:
        errors.append(f"schema_version={data.get('schema_version')!r} != 1")
    models = data.get("models")
    if not isinstance(models, dict) or not models:
        errors.append("empty or missing 'models'")
        return errors
    for name, row in models.items():
        if not isinstance(row, dict):
            errors.append(f"models.{name}: row is "
                          f"{type(row).__name__}, not object")
            continue
        for key in REQUIRED_MODEL_KEYS:
            if key not in row:
                errors.append(f"models.{name}: missing {key}")
        for key in ("measured_steady_fps", "eager_fps", "modeled_fps_alg1"):
            v = row.get(key)
            if not isinstance(v, (int, float)) or not v > 0:
                errors.append(f"models.{name}.{key}={v!r} not > 0")
    return errors


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    path = argv[0] if argv else "BENCH_serve.json"
    errors = validate(path)
    if errors:
        for e in errors:
            print(f"[validate_bench] FAIL: {e}", file=sys.stderr)
        return 1
    with open(path) as f:
        n = len(json.load(f)["models"])
    print(f"[validate_bench] OK: {path} ({n} model(s))")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
