"""Validate BENCH_*.json artifacts (CI bench-smoke gate).

Exits non-zero when a file is missing, is not valid JSON, records no
models, or any row lacks the numbers its schema requires — so a benchmark
run that silently produced garbage cannot upload a green artifact.

Schemas are selected by the artifact's ``bench`` field:

* ``serve`` — measured-vs-modeled FPS per model
  (``benchmarks/serve_bench.py``);
* ``serve_async`` — per stage count K: steady throughput, p50/p95/p99
  request latency, and throughput relative to the K=1 single-jit baseline
  (``benchmarks/serve_async_bench.py``);
* ``serve_qos`` — per arrival rate and per traffic class (at least two):
  queueing/assembly/compute phase-split percentiles, SLO miss rate, and
  drop rate, plus the recorded seed that replays the schedule
  (``benchmarks/serve_qos_bench.py``).

  python benchmarks/validate_bench.py BENCH_serve.json \
      BENCH_serve_async.json BENCH_serve_qos.json
"""

from __future__ import annotations

import json
import sys

REQUIRED_MODEL_KEYS = ("measured_steady_fps", "eager_fps",
                       "speedup_vs_eager", "modeled_fps_alg1", "batch",
                       "frames", "route")

REQUIRED_STAGE_KEYS = ("measured_steady_fps", "modeled_fps_alg1",
                       "arrival_fps",
                       "latency_ms_p50", "latency_ms_p95",
                       "latency_ms_p99", "stages", "boundaries",
                       "stage_balance", "batch", "frames", "route")
POSITIVE_STAGE_KEYS = ("measured_steady_fps", "arrival_fps",
                       "latency_ms_p50", "latency_ms_p95",
                       "latency_ms_p99", "throughput_vs_single_jit")


REQUIRED_QOS_MODEL_KEYS = ("measured_steady_fps", "modeled_fps_alg1",
                           "batch", "stages", "seed", "slo_ms",
                           "traffic_mix", "rates", "route")
REQUIRED_QOS_RATE_KEYS = ("arrival_fps", "load_factor", "submitted",
                          "completed", "expired", "classes")
REQUIRED_QOS_CLASS_KEYS = ("submitted", "completed", "expired",
                           "rejected", "slo_miss_rate", "drop_rate",
                           "phase_ms")
QOS_PHASES = ("queueing", "assembly", "compute")
QOS_PCTS = ("p50", "p95", "p99")


def _positive(row: dict, key: str) -> bool:
    v = row.get(key)
    return isinstance(v, (int, float)) and v > 0


def _validate_serve_model(name: str, row: dict, errors: list[str]) -> None:
    for key in REQUIRED_MODEL_KEYS:
        if key not in row:
            errors.append(f"models.{name}: missing {key}")
    for key in ("measured_steady_fps", "eager_fps", "modeled_fps_alg1"):
        if not _positive(row, key):
            errors.append(f"models.{name}.{key}={row.get(key)!r} not > 0")


def _validate_async_model(name: str, row: dict, errors: list[str]) -> None:
    stages = row.get("stages")
    if not isinstance(stages, dict) or not stages:
        errors.append(f"models.{name}: empty or missing 'stages'")
        return
    # The K=1 baseline ratio exists iff a K=1 run is in the sweep.
    has_baseline = isinstance(stages.get("1"), dict)
    for k, srow in stages.items():
        where = f"models.{name}.stages.{k}"
        if not isinstance(srow, dict):
            errors.append(f"{where}: row is {type(srow).__name__}, "
                          f"not object")
            continue
        required = REQUIRED_STAGE_KEYS + (
            ("throughput_vs_single_jit",) if has_baseline else ())
        for key in required:
            if key not in srow:
                errors.append(f"{where}: missing {key}")
        for key in POSITIVE_STAGE_KEYS:
            if key in srow and not _positive(srow, key):
                errors.append(f"{where}.{key}={srow.get(key)!r} not > 0")
        if str(k).isdigit() and srow.get("stages") != int(k):
            errors.append(f"{where}: stage count {srow.get('stages')!r} "
                          f"does not match key {k!r}")
        if srow.get("latency_ms_p50") and srow.get("latency_ms_p99") and \
                srow["latency_ms_p99"] < srow["latency_ms_p50"]:
            errors.append(f"{where}: p99 < p50 "
                          f"({srow['latency_ms_p99']} < "
                          f"{srow['latency_ms_p50']})")


def _validate_qos_class(where: str, crow: dict, errors: list[str]) -> None:
    for key in REQUIRED_QOS_CLASS_KEYS:
        if key not in crow:
            errors.append(f"{where}: missing {key}")
    for key in ("slo_miss_rate", "drop_rate"):
        v = crow.get(key)
        if key in crow and not (isinstance(v, (int, float))
                                and 0 <= v <= 1):
            errors.append(f"{where}.{key}={v!r} not in [0, 1]")
    phases = crow.get("phase_ms")
    if not isinstance(phases, dict):
        errors.append(f"{where}: missing phase_ms")
        return
    for phase in QOS_PHASES:
        prow = phases.get(phase)
        if not isinstance(prow, dict):
            errors.append(f"{where}.phase_ms: missing {phase}")
            continue
        for p in QOS_PCTS:
            if not isinstance(prow.get(p), (int, float)):
                errors.append(f"{where}.phase_ms.{phase}: missing {p}")
    # Completed-request percentiles must be ordered (NaN — an empty
    # class — compares False and is allowed: a quick run may complete
    # nothing for a class under heavy overload).
    comp = phases.get("compute")
    if isinstance(comp, dict) and \
            isinstance(comp.get("p50"), float) and \
            isinstance(comp.get("p99"), float) and \
            comp["p99"] < comp["p50"]:
        errors.append(f"{where}: compute p99 < p50 "
                      f"({comp['p99']} < {comp['p50']})")


def _validate_qos_model(name: str, row: dict, errors: list[str]) -> None:
    for key in REQUIRED_QOS_MODEL_KEYS:
        if key not in row:
            errors.append(f"models.{name}: missing {key}")
    if not _positive(row, "measured_steady_fps"):
        errors.append(f"models.{name}.measured_steady_fps="
                      f"{row.get('measured_steady_fps')!r} not > 0")
    mix = row.get("traffic_mix")
    if not isinstance(mix, list) or len(mix) < 2:
        errors.append(f"models.{name}: traffic_mix needs >= 2 classes, "
                      f"got {mix!r}")
    rates = row.get("rates")
    if not isinstance(rates, dict) or len(rates) < 2:
        errors.append(f"models.{name}: needs >= 2 arrival rates, got "
                      f"{sorted(rates) if isinstance(rates, dict) else rates!r}")
        return
    for rate_key, rrow in rates.items():
        where = f"models.{name}.rates.{rate_key}"
        if not isinstance(rrow, dict):
            errors.append(f"{where}: row is {type(rrow).__name__}, "
                          f"not object")
            continue
        for key in REQUIRED_QOS_RATE_KEYS:
            if key not in rrow:
                errors.append(f"{where}: missing {key}")
        if not _positive(rrow, "arrival_fps"):
            errors.append(f"{where}.arrival_fps="
                          f"{rrow.get('arrival_fps')!r} not > 0")
        classes = rrow.get("classes")
        if not isinstance(classes, dict) or len(classes) < 2:
            errors.append(f"{where}: needs >= 2 traffic classes, got "
                          f"{sorted(classes) if isinstance(classes, dict) else classes!r}")
            continue
        n = sum(c.get("submitted", 0) for c in classes.values()
                if isinstance(c, dict))
        if rrow.get("submitted") != n:
            errors.append(f"{where}: class submitted counts {n} do not "
                          f"reconcile with total {rrow.get('submitted')!r}")
        for cname, crow in classes.items():
            if not isinstance(crow, dict):
                errors.append(f"{where}.classes.{cname}: row is "
                              f"{type(crow).__name__}, not object")
                continue
            _validate_qos_class(f"{where}.classes.{cname}", crow, errors)


def validate(path: str) -> list[str]:
    errors: list[str] = []
    try:
        with open(path) as f:
            data = json.load(f)
    except FileNotFoundError:
        return [f"{path}: file not found"]
    except json.JSONDecodeError as e:
        return [f"{path}: malformed JSON: {e}"]
    if not isinstance(data, dict):
        return [f"{path}: top level is {type(data).__name__}, not object"]
    if data.get("schema_version") != 1:
        errors.append(f"schema_version={data.get('schema_version')!r} != 1")
    bench = data.get("bench", "serve")
    if bench not in ("serve", "serve_async", "serve_qos"):
        errors.append(f"unknown bench kind {bench!r}")
        return errors
    if bench == "serve_qos" and not isinstance(data.get("seed"), int):
        errors.append("serve_qos artifact must record its schedule seed")
    models = data.get("models")
    if not isinstance(models, dict) or not models:
        errors.append("empty or missing 'models'")
        return errors
    for name, row in models.items():
        if not isinstance(row, dict):
            errors.append(f"models.{name}: row is "
                          f"{type(row).__name__}, not object")
            continue
        if bench == "serve":
            _validate_serve_model(name, row, errors)
        elif bench == "serve_qos":
            _validate_qos_model(name, row, errors)
        else:
            _validate_async_model(name, row, errors)
    return errors


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    paths = argv if argv else ["BENCH_serve.json"]
    bad = False
    for path in paths:
        errors = validate(path)
        if errors:
            bad = True
            for e in errors:
                print(f"[validate_bench] FAIL: {e}", file=sys.stderr)
            continue
        with open(path) as f:
            n = len(json.load(f)["models"])
        print(f"[validate_bench] OK: {path} ({n} model(s))")
    return 1 if bad else 0


if __name__ == "__main__":
    raise SystemExit(main())
