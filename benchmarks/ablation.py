"""Allocator ablation (paper greedy vs exact local search vs waterfill) and
the stage-balance benchmark on the TPU mesh (the paper's flexibility claim
ported: uniform stage assignment vs Algorithm-1 boundaries)."""

from __future__ import annotations

import time

from repro.configs import ARCHS
from repro.core import throughput as T
from repro.core import workload as W
from repro.core.allocator import (allocate_compute, plan_pipeline,
                                  _partition_min_max)
from repro.core.workload import lm_layer_workloads


def run_objectives(emit):
    print("\n== Allocator objective ablation (900 DSPs, 16-bit) ==")
    print(f"{'model':9s} {'paper':>7s} {'exact':>7s} {'optimal':>8s}")
    for model, fn in W.CNN_MODELS.items():
        layers = fn().layer_workloads(weight_bits=16)
        effs = {}
        for obj in ("paper", "exact", "optimal"):
            t0 = time.time()
            allocs = allocate_compute(layers, 900, objective=obj)
            us = (time.time() - t0) * 1e6
            effs[obj] = T.dsp_efficiency(allocs)
            emit(f"ablation/{model}/{obj}", us, f"eff={effs[obj]:.4f}")
        print(f"{model:9s} {effs['paper']:7.3f} {effs['exact']:7.3f} "
              f"{effs['optimal']:8.3f}")


def run_stage_balance(emit):
    """Uniform vs Algorithm-1 stage boundaries for heterogeneous archs —
    the TPU port of the paper's 'flexible allocation beats constrained'."""
    print("\n== Pipeline stage balance (TPU mesh 16x16, train_4k) ==")
    print(f"{'arch':22s} {'S':>2s} {'T':>2s} {'mb':>3s} "
          f"{'util(alloc)':>11s} {'util(uniform)':>13s} {'bubble':>7s}")
    for arch in ARCHS:
        cfg = ARCHS[arch]
        layers = lm_layer_workloads(cfg, seq_len=4096, batch=256,
                                    mode="train")
        t0 = time.time()
        plan = plan_pipeline(layers, model_axis=16, data_axis=16,
                             global_batch=256, seq_len=4096, train=True,
                             d_model=cfg.d_model, allow_infeasible=True)
        us = (time.time() - t0) * 1e6
        # uniform boundaries at the same (S, T, mb):
        flops = [l.macs * 6.0 for l in layers]
        S = plan.n_stages
        n = len(flops)
        uni = [round(i * n / S) for i in range(S + 1)]
        uni_max = max(sum(flops[uni[i]:uni[i + 1]]) for i in range(S))
        _, opt_max = _partition_min_max(flops, S)
        util_uni = plan.utilization * (opt_max / uni_max)
        fits = plan.mem_per_chip <= 16e9
        print(f"{arch:22s} {plan.n_stages:2d} {plan.tensor_parallel:2d} "
              f"{plan.microbatches:3d} {plan.utilization:11.3f} "
              f"{util_uni:13.3f} {plan.bubble_fraction:7.3f}"
              f"{'' if fits else '  [exceeds HBM: needs pjit/FSDP path]'}")
        emit(f"stage_balance/{arch}", us,
             f"S={plan.n_stages}|T={plan.tensor_parallel}"
             f"|util={plan.utilization:.3f}|uniform={util_uni:.3f}")
