"""QoS serving benchmark: mixed traffic classes under two arrival rates.

For each model, compiles one :class:`EngineProgram`, measures the
pipeline's steady-state throughput, then replays the same seeded
mixed-class schedule (``repro.serving.traffic`` — the generator
``serve_async_bench`` shares) open-loop at two load factors, one below
saturation and one above. The artifact (``BENCH_serve_qos.json``, built,
validated and uploaded by the CI bench-smoke job) records, per class and
per rate: the queueing / assembly / compute latency split (p50/p95/p99),
the SLO miss rate, and the drop rate — the numbers that show priority
lanes protecting the interactive class while the best-effort class
absorbs the overload.

  PYTHONPATH=src:. python benchmarks/serve_qos_bench.py --quick  # CI
  PYTHONPATH=src:. python benchmarks/serve_qos_bench.py          # full
"""

from __future__ import annotations

import argparse
import json
import platform
import time

import jax

from repro.core import workload as W
from repro.launch.serve_cnn import compile_for_serving, serve_qos
from repro.serving import parse_traffic_mix

SCHEMA_VERSION = 1
DEFAULT_OUT = "BENCH_serve_qos.json"
DEFAULT_LOAD_FACTORS = (0.6, 1.2)


def bench_model(model: str, *, batch: int, frames: int | None,
                stages: int, seed: int, slo_ms: float | None,
                traffic_mix, load_factors: tuple[float, ...],
                place_stages: bool, poisson: bool,
                admission_control: bool,
                flush_guard_ms: float | None) -> dict:
    """One model: throughput phase + one open-loop mixed-traffic replay
    per load factor, over one compiled program."""
    prog = compile_for_serving(model, bits=8, seed=seed)
    n = frames if frames is not None else (6 + 2 * stages) * batch
    return serve_qos(model, frames=n, batch=batch, stages=stages,
                     seed=seed, slo_ms=slo_ms, traffic_mix=traffic_mix,
                     load_factors=load_factors, place_stages=place_stages,
                     poisson=poisson, admission_control=admission_control,
                     flush_guard_ms=flush_guard_ms,
                     program=prog, verbose=True)


def run(emit, *, quick: bool = False, batch: int | None = None,
        frames: int | None = None, out: str = DEFAULT_OUT,
        models: list[str] | None = None, stages: int = 2,
        seed: int = 0, slo_ms: float | None = None,
        traffic_mix_spec: str | None = None,
        load_factors: tuple[float, ...] = DEFAULT_LOAD_FACTORS,
        place_stages: bool = False, poisson: bool = False,
        admission_control: bool = True,
        flush_guard_ms: float | None = None) -> dict:
    if models is None:
        models = ["alexnet"] if quick else list(W.CNN_MODELS)
    if batch is None:
        batch = 8 if quick else 32
    # slo_ms may be None (serve_qos derives a feasible deadline from
    # measured service time); parse_traffic_mix then refuses the 'slo'
    # token rather than arming a 0 ms deadline.
    mix = (parse_traffic_mix(traffic_mix_spec, slo_ms)
           if traffic_mix_spec else None)
    data: dict = {
        "schema_version": SCHEMA_VERSION,
        "bench": "serve_qos",
        "quick": quick,
        "batch": batch,
        "frames": frames,          # null = per-model default
        "stages": stages,
        "seed": seed,              # one seed drives params, calibration,
        "slo_ms": slo_ms,          # frames AND the arrival schedule —
        "poisson": poisson,        # the artifact replays bit-for-bit
        "load_factors": list(load_factors),
        "place_stages": place_stages,
        # The control-plane config behind these numbers, recorded so the
        # knee and qos artifacts are comparable across PRs (per-rate
        # rows additionally carry the live estimator state as
        # "control").
        "admission_control": admission_control,
        "flush_guard_ms": flush_guard_ms,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "jax_version": jax.__version__,
        "backend": jax.devices()[0].platform,
        "host": platform.machine(),
        "models": {},
    }
    for model in models:
        row = bench_model(model, batch=batch, frames=frames, stages=stages,
                          seed=seed, slo_ms=slo_ms, traffic_mix=mix,
                          load_factors=load_factors,
                          place_stages=place_stages, poisson=poisson,
                          admission_control=admission_control,
                          flush_guard_ms=flush_guard_ms)
        data["models"][model] = row
        for rate_key, rrow in row["rates"].items():
            for name, crow in rrow["classes"].items():
                q = crow["phase_ms"]["queueing"]["p95"]
                a = crow["phase_ms"]["assembly"]["p95"]
                c = crow["phase_ms"]["compute"]["p95"]
                emit(f"serve_qos/{model}/{rate_key}/{name}", 0.0,
                     f"p95_q={q}ms|a={a}ms|c={c}ms|"
                     f"miss={crow['slo_miss_rate']}|"
                     f"drop={crow['drop_rate']}")
    with open(out, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
    print(f"\n[serve_qos_bench] wrote {out} ({len(data['models'])} "
          f"model(s), batch {batch}, loads {list(load_factors)})")
    return data


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="AlexNet only, small batch (CI bench-smoke)")
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--frames", type=int, default=None)
    ap.add_argument("--stages", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0,
                    help="params/calibration/stream/schedule RNG seed")
    ap.add_argument("--slo-ms", type=float, default=None,
                    help="interactive-class deadline (default: derived "
                         "from the measured service time)")
    ap.add_argument("--traffic-mix", default=None, dest="traffic_mix",
                    help="name:priority:share[:deadline_ms],... "
                         "(default: interactive 25%% + batch 75%%)")
    ap.add_argument("--load", type=float, action="append", default=None,
                    dest="load_factors",
                    help="arrival rate as a fraction of measured steady "
                         "throughput (repeatable; default 0.6 1.2)")
    ap.add_argument("--place-stages", action="store_true",
                    help="pin stage i to jax.devices()[i %% n]")
    ap.add_argument("--poisson", action="store_true",
                    help="exponential inter-arrival gaps (bursty)")
    ap.add_argument("--no-admission", action="store_true",
                    help="disable estimated-wait admission control "
                         "(PR-4 lane-bound-only admission)")
    ap.add_argument("--flush-guard-ms", type=float, default=None,
                    help="fixed expedited-flush guard (default: "
                         "adaptive, 25%% of the service estimate + 2ms)")
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--model", action="append", default=None,
                    choices=sorted(W.CNN_MODELS), dest="models")
    args = ap.parse_args(argv)
    from benchmarks.run import print_csv
    csv: list[str] = []

    def emit(name, us, derived=""):
        csv.append(f"{name},{us:.1f},{derived}")

    run(emit, quick=args.quick, batch=args.batch, frames=args.frames,
        out=args.out, models=args.models, stages=args.stages,
        seed=args.seed, slo_ms=args.slo_ms,
        traffic_mix_spec=args.traffic_mix,
        load_factors=tuple(args.load_factors or DEFAULT_LOAD_FACTORS),
        place_stages=args.place_stages, poisson=args.poisson,
        admission_control=not args.no_admission,
        flush_guard_ms=args.flush_guard_ms)
    print_csv(csv)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
