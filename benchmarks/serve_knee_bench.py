"""QPS-knee benchmark: the headline capacity number per model.

``serve_qos_bench`` reports QoS behaviour at load factors *relative to*
the measured steady throughput; this bench answers the absolute
question — how many requests per second can a deployment take while the
interactive class holds its SLO? For each model it compiles one
:class:`EngineProgram`, measures steady pipeline throughput, then runs
the bracketing absolute-QPS sweep (``repro.launch.serve_cnn.serve_knee``:
double the arrival rate while the deadline-armed classes miss less than
``--miss-target`` of the time, then bisect the sustained/unsustained
bracket). The knee — max sustained QPS — lands in
``BENCH_serve_knee.json`` with every probe recorded, the control-plane
config (admission, flush guard, estimator warm start), and the seed
that replays the exact schedule. Built, schema-validated, gated against
``benchmarks/baselines/`` and uploaded by the CI bench-smoke job.

Two extensions ride on the same sweep:

* ``--arrival poisson`` additionally benches the knee under Poisson
  (exponential inter-arrival) traffic and records it as a
  ``<model>:poisson`` row alongside the uniform knee — burstiness costs
  capacity, and the artifact shows how much;
* ``--replicas-sweep 1,2,4`` runs the knee-vs-R scaling sweep through a
  routed :class:`repro.serving.ReplicaPool` (R>1 brackets open at the
  R=1 knee, so "replication never loses to one replica" is probed
  directly) and records a ``knee_scaling`` block per model —
  schema-validated and gated (``knee_r2 / knee_r1 >= 1``) in CI under
  ``XLA_FLAGS=--xla_force_host_platform_device_count=4``;
* ``--rescale`` (default on; ``--no-rescale`` skips) drives a load ramp
  across the R=1 knee with an ``ElasticController`` watching the
  frontend: when the armed miss rate crosses the target, the controller
  live-rescales the fleet (drain -> swap -> resume, no request dropped:
  ``hung == 0`` is a hard CI gate) and the post-rescale knee is
  re-bracketed on the same server — recorded as a ``knee_after_rescale``
  block per model.

  PYTHONPATH=src:. python benchmarks/serve_knee_bench.py --quick \
      --arrival poisson --replicas-sweep 1,2,4                   # CI
  PYTHONPATH=src:. python benchmarks/serve_knee_bench.py          # full
"""

from __future__ import annotations

import argparse
import json
import platform
import time

import jax

from repro.core import workload as W
from repro.launch.serve_cnn import (compile_for_serving, serve_knee,
                                    serve_knee_rescale)
from repro.serving import parse_traffic_mix

SCHEMA_VERSION = 1
DEFAULT_OUT = "BENCH_serve_knee.json"
DEFAULT_MISS_TARGET = 0.01


def bench_model(model: str, *, batch: int, frames: int | None,
                stages: int, seed: int, slo_ms: float | None,
                traffic_mix, miss_target: float, refine_iters: int,
                max_factor: float, flush_guard_ms: float | None,
                admission_control: bool, place_stages: bool,
                poisson: bool, program=None, replicas: int = 1,
                replica_mode: str = "pipeline",
                start_qps: float | None = None) -> dict:
    """One model: throughput phase + the bracketing QPS sweep, over one
    compiled program (pass ``program`` to reuse it across the arrival
    and replica variants)."""
    if program is None:
        program = compile_for_serving(model, bits=8, seed=seed)
    n = frames if frames is not None else (6 + 2 * stages) * batch
    return serve_knee(model, frames=n, batch=batch, stages=stages,
                      seed=seed, slo_ms=slo_ms, traffic_mix=traffic_mix,
                      miss_target=miss_target, refine_iters=refine_iters,
                      max_factor=max_factor, start_qps=start_qps,
                      flush_guard_ms=flush_guard_ms,
                      admission_control=admission_control,
                      place_stages=place_stages, poisson=poisson,
                      replicas=replicas, replica_mode=replica_mode,
                      program=program, verbose=True)


def run(emit, *, quick: bool = False, batch: int | None = None,
        frames: int | None = None, out: str = DEFAULT_OUT,
        models: list[str] | None = None, stages: int = 2,
        seed: int = 0, slo_ms: float | None = None,
        traffic_mix_spec: str | None = None,
        miss_target: float = DEFAULT_MISS_TARGET,
        refine_iters: int | None = None, max_factor: float = 8.0,
        flush_guard_ms: float | None = None,
        admission_control: bool = True,
        place_stages: bool = False, poisson: bool = False,
        arrival: str = "uniform", replicas: int = 1,
        replica_mode: str = "pipeline",
        replicas_sweep: list[int] | None = None,
        rescale: bool = True) -> dict:
    if arrival not in ("uniform", "poisson"):
        raise ValueError(f"unknown arrival {arrival!r}")
    if models is None:
        models = ["alexnet"] if quick else list(W.CNN_MODELS)
    if batch is None:
        batch = 8 if quick else 32
    if refine_iters is None:
        refine_iters = 2 if quick else 3
    if replicas_sweep is not None:
        replicas_sweep = sorted({int(r) for r in replicas_sweep})
        if any(r < 1 for r in replicas_sweep):
            raise ValueError(f"replicas_sweep={replicas_sweep} has R < 1")
        if 1 not in replicas_sweep:
            raise ValueError("replicas_sweep needs the R=1 baseline "
                             "(knee_vs_r1 is a ratio against it)")
    mix = (parse_traffic_mix(traffic_mix_spec, slo_ms)
           if traffic_mix_spec else None)
    data: dict = {
        "schema_version": SCHEMA_VERSION,
        "bench": "serve_knee",
        "quick": quick,
        "batch": batch,
        "frames": frames,          # null = per-model default
        "stages": stages,
        "seed": seed,              # replays params, calibration, frames
        "slo_ms": slo_ms,          # and every probe's arrival schedule
        "poisson": poisson,
        "arrival": arrival,
        "replicas": replicas,
        "replica_mode": replica_mode,
        "replicas_sweep": replicas_sweep,
        "rescale": rescale,
        "device_count": jax.device_count(),
        "miss_target": miss_target,
        "max_factor": max_factor,
        "refine_iters": refine_iters,
        "admission_control": admission_control,
        "flush_guard_ms": flush_guard_ms,
        "place_stages": place_stages,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "jax_version": jax.__version__,
        "backend": jax.devices()[0].platform,
        "host": platform.machine(),
        "models": {},
    }
    common = dict(batch=batch, frames=frames, stages=stages, seed=seed,
                  slo_ms=slo_ms, traffic_mix=mix, miss_target=miss_target,
                  refine_iters=refine_iters, max_factor=max_factor,
                  flush_guard_ms=flush_guard_ms,
                  admission_control=admission_control,
                  place_stages=place_stages)
    base_poisson = poisson     # legacy flag: the base sweep is bursty
    for model in models:
        prog = compile_for_serving(model, bits=8, seed=seed)
        row = bench_model(model, poisson=base_poisson, program=prog,
                          replicas=replicas, replica_mode=replica_mode,
                          **common)
        data["models"][model] = row
        emit(f"serve_knee/{model}/knee_qps", 0.0,
             f"{row['knee_qps']}qps|x{row['knee_of_steady']}_of_steady|"
             f"miss={row['knee_miss_rate']}|"
             f"probes={len(row['probes'])}")
        # Variant rows (bursty arrival, R>1 replicas) hold the base
        # row's *resolved* SLO constant: re-deriving per variant would
        # tighten the budget as fleet steady grows with R (per-batch
        # traversal latency does not shrink), so each row would measure
        # a different contract and the knee ratios would be meaningless.
        pinned = dict(common)
        if pinned["slo_ms"] is None:
            pinned["slo_ms"] = row["slo_ms"]
        if arrival == "poisson" and not base_poisson:
            # Bursty variant of the same sweep: exponential inter-arrival
            # gaps from the same seed, recorded alongside the uniform
            # knee so the burstiness cost is visible in the artifact.
            prow = bench_model(model, poisson=True, program=prog,
                               replicas=replicas,
                               replica_mode=replica_mode, **pinned)
            data["models"][f"{model}:poisson"] = prow
            emit(f"serve_knee/{model}:poisson/knee_qps", 0.0,
                 f"{prow['knee_qps']}qps|x{prow['knee_of_steady']}"
                 f"_of_steady|probes={len(prow['probes'])}")
        if replicas_sweep:
            base = (row if replicas == 1
                    else bench_model(model, poisson=base_poisson,
                                     program=prog, replicas=1, **pinned))
            knee_r1 = base["knee_qps"]
            # copy: base may be the model row itself, which grows the
            # knee_scaling block below — a cycle json.dump would reject
            rows = {"1": dict(base)}
            for r in replicas_sweep:
                if r == 1:
                    continue
                # Open each R>1 bracket at the R=1 knee: if R replicas
                # sustain the rate one replica topped out at, the knee
                # ratio is >= 1 by construction of "max sustained".
                rows[str(r)] = bench_model(
                    model, poisson=base_poisson, program=prog,
                    replicas=r, replica_mode=replica_mode,
                    start_qps=knee_r1, **pinned)
            # A row with no sustained probe has knee_qps None — keep the
            # ratio None too (the CI gate then fails on the missing
            # number, which is the intended signal) instead of crashing.
            ratios = {str(r): (None if knee_r1 is None
                               or rows[str(r)]["knee_qps"] is None
                               else round(rows[str(r)]["knee_qps"]
                                          / knee_r1, 4))
                      for r in replicas_sweep if r != 1}
            data["models"][model]["knee_scaling"] = {
                "device_count": jax.device_count(),
                "mode": replica_mode,
                "rows": rows,
                "knee_vs_r1": ratios,
            }
            emit(f"serve_knee/{model}/knee_scaling", 0.0,
                 "|".join(f"r{r}={rows[str(r)]['knee_qps']}qps"
                          + ("" if r == 1
                             else f"(x{ratios[str(r)]})")
                          for r in replicas_sweep))
        if rescale:
            # Elastic-runtime row: ramp across the R=1 knee with the
            # controller live, measure the drain-swap-resume rescale
            # under load, then re-bracket the knee on the rescaled
            # server. The ramp opens at the measured R=1 knee so the
            # very first segment crosses it.
            n = frames if frames is not None else (6 + 2 * stages) * batch
            rrow = serve_knee_rescale(
                model, frames=n, batch=batch, stages=stages, seed=seed,
                slo_ms=pinned["slo_ms"], traffic_mix=mix,
                miss_target=miss_target, start_qps=row["knee_qps"],
                max_factor=max_factor, refine_iters=refine_iters,
                flush_guard_ms=flush_guard_ms,
                admission_control=admission_control,
                place_stages=place_stages,
                scenario="poisson" if base_poisson else None,
                replica_mode=replica_mode, program=prog, verbose=True)
            data["models"][model]["knee_after_rescale"] = rrow
            emit(f"serve_knee/{model}/knee_after_rescale", 0.0,
                 f"rescales={rrow['n_rescales']}"
                 + ("(forced)" if rrow["forced"] else "")
                 + f"|R{rrow['replicas_before']}->"
                 f"{rrow['replicas_after']}|hung={rrow['hung']}|"
                 f"miss {rrow['armed_miss_at_trigger']}->"
                 f"{rrow['armed_miss_after_rescale']}|"
                 f"knee={rrow['knee']['knee_qps']}qps")
    with open(out, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
    print(f"\n[serve_knee_bench] wrote {out} ({len(data['models'])} "
          f"model(s), batch {batch}, miss target {miss_target:.0%})")
    return data


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="AlexNet only, small batch (CI bench-smoke)")
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--frames", type=int, default=None)
    ap.add_argument("--stages", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0,
                    help="params/calibration/stream/schedule RNG seed")
    ap.add_argument("--slo-ms", type=float, default=None,
                    help="interactive-class deadline (default: derived "
                         "from the measured service time)")
    ap.add_argument("--traffic-mix", default=None, dest="traffic_mix",
                    help="name:priority:share[:deadline_ms],... "
                         "(default: interactive 25%% + batch 75%%)")
    ap.add_argument("--miss-target", type=float,
                    default=DEFAULT_MISS_TARGET,
                    help="armed-class miss rate defining 'sustained' "
                         "(default 0.01)")
    ap.add_argument("--max-factor", type=float, default=8.0,
                    help="sweep cap as a multiple of measured steady "
                         "fps (default 8)")
    ap.add_argument("--refine-iters", type=int, default=None,
                    help="bisection refinements of the bracket "
                         "(default 3, 2 with --quick)")
    ap.add_argument("--flush-guard-ms", type=float, default=None,
                    help="fixed flush guard (default: adaptive)")
    ap.add_argument("--no-admission", action="store_true",
                    help="disable estimated-wait admission control")
    ap.add_argument("--place-stages", action="store_true",
                    help="pin stage i to jax.devices()[i %% n]")
    ap.add_argument("--poisson", action="store_true",
                    help="exponential inter-arrival gaps (bursty); "
                         "same as --arrival poisson")
    ap.add_argument("--arrival", default="uniform",
                    choices=("uniform", "poisson"),
                    help="'poisson' additionally records a "
                         "<model>:poisson row beside the uniform knee")
    ap.add_argument("--replicas", type=int, default=1,
                    help="pipeline replicas behind the least-wait "
                         "router (default 1 = plain PipelineExecutor)")
    ap.add_argument("--replica-mode", default="pipeline",
                    choices=("pipeline", "stage-shard"),
                    dest="replica_mode",
                    help="replica placement: whole pipeline per device "
                         "or stages across a contiguous device slice")
    ap.add_argument("--replicas-sweep", default=None,
                    dest="replicas_sweep",
                    help="comma list, e.g. 1,2,4: knee-vs-R scaling "
                         "sweep (R>1 brackets open at the R=1 knee); "
                         "records a knee_scaling block per model")
    ap.add_argument("--rescale", dest="rescale", action="store_true",
                    default=True,
                    help="elastic-runtime ramp: live rescale across the "
                         "knee, records knee_after_rescale (default on)")
    ap.add_argument("--no-rescale", dest="rescale", action="store_false",
                    help="skip the elastic-runtime rescale ramp")
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--model", action="append", default=None,
                    choices=sorted(W.CNN_MODELS), dest="models")
    args = ap.parse_args(argv)
    from benchmarks.run import print_csv
    csv: list[str] = []

    def emit(name, us, derived=""):
        csv.append(f"{name},{us:.1f},{derived}")

    run(emit, quick=args.quick, batch=args.batch, frames=args.frames,
        out=args.out, models=args.models, stages=args.stages,
        seed=args.seed, slo_ms=args.slo_ms,
        traffic_mix_spec=args.traffic_mix,
        miss_target=args.miss_target, refine_iters=args.refine_iters,
        max_factor=args.max_factor, flush_guard_ms=args.flush_guard_ms,
        admission_control=not args.no_admission,
        place_stages=args.place_stages, poisson=args.poisson,
        arrival=args.arrival, replicas=args.replicas,
        replica_mode=args.replica_mode,
        replicas_sweep=([int(r) for r in args.replicas_sweep.split(",")]
                        if args.replicas_sweep else None),
        rescale=args.rescale)
    print_csv(csv)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
