"""QPS-knee benchmark: the headline capacity number per model.

``serve_qos_bench`` reports QoS behaviour at load factors *relative to*
the measured steady throughput; this bench answers the absolute
question — how many requests per second can a deployment take while the
interactive class holds its SLO? For each model it compiles one
:class:`EngineProgram`, measures steady pipeline throughput, then runs
the bracketing absolute-QPS sweep (``repro.launch.serve_cnn.serve_knee``:
double the arrival rate while the deadline-armed classes miss less than
``--miss-target`` of the time, then bisect the sustained/unsustained
bracket). The knee — max sustained QPS — lands in
``BENCH_serve_knee.json`` with every probe recorded, the control-plane
config (admission, flush guard, estimator warm start), and the seed
that replays the exact schedule. Built, schema-validated, gated against
``benchmarks/baselines/`` and uploaded by the CI bench-smoke job.

  PYTHONPATH=src:. python benchmarks/serve_knee_bench.py --quick  # CI
  PYTHONPATH=src:. python benchmarks/serve_knee_bench.py          # full
"""

from __future__ import annotations

import argparse
import json
import platform
import time

import jax

from repro.core import workload as W
from repro.launch.serve_cnn import compile_for_serving, serve_knee
from repro.serving import parse_traffic_mix

SCHEMA_VERSION = 1
DEFAULT_OUT = "BENCH_serve_knee.json"
DEFAULT_MISS_TARGET = 0.01


def bench_model(model: str, *, batch: int, frames: int | None,
                stages: int, seed: int, slo_ms: float | None,
                traffic_mix, miss_target: float, refine_iters: int,
                max_factor: float, flush_guard_ms: float | None,
                admission_control: bool, place_stages: bool,
                poisson: bool) -> dict:
    """One model: throughput phase + the bracketing QPS sweep, over one
    compiled program."""
    prog = compile_for_serving(model, bits=8, seed=seed)
    n = frames if frames is not None else (6 + 2 * stages) * batch
    return serve_knee(model, frames=n, batch=batch, stages=stages,
                      seed=seed, slo_ms=slo_ms, traffic_mix=traffic_mix,
                      miss_target=miss_target, refine_iters=refine_iters,
                      max_factor=max_factor,
                      flush_guard_ms=flush_guard_ms,
                      admission_control=admission_control,
                      place_stages=place_stages, poisson=poisson,
                      program=prog, verbose=True)


def run(emit, *, quick: bool = False, batch: int | None = None,
        frames: int | None = None, out: str = DEFAULT_OUT,
        models: list[str] | None = None, stages: int = 2,
        seed: int = 0, slo_ms: float | None = None,
        traffic_mix_spec: str | None = None,
        miss_target: float = DEFAULT_MISS_TARGET,
        refine_iters: int | None = None, max_factor: float = 8.0,
        flush_guard_ms: float | None = None,
        admission_control: bool = True,
        place_stages: bool = False, poisson: bool = False) -> dict:
    if models is None:
        models = ["alexnet"] if quick else list(W.CNN_MODELS)
    if batch is None:
        batch = 8 if quick else 32
    if refine_iters is None:
        refine_iters = 2 if quick else 3
    mix = (parse_traffic_mix(traffic_mix_spec, slo_ms)
           if traffic_mix_spec else None)
    data: dict = {
        "schema_version": SCHEMA_VERSION,
        "bench": "serve_knee",
        "quick": quick,
        "batch": batch,
        "frames": frames,          # null = per-model default
        "stages": stages,
        "seed": seed,              # replays params, calibration, frames
        "slo_ms": slo_ms,          # and every probe's arrival schedule
        "poisson": poisson,
        "miss_target": miss_target,
        "max_factor": max_factor,
        "refine_iters": refine_iters,
        "admission_control": admission_control,
        "flush_guard_ms": flush_guard_ms,
        "place_stages": place_stages,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "jax_version": jax.__version__,
        "backend": jax.devices()[0].platform,
        "host": platform.machine(),
        "models": {},
    }
    for model in models:
        row = bench_model(model, batch=batch, frames=frames, stages=stages,
                          seed=seed, slo_ms=slo_ms, traffic_mix=mix,
                          miss_target=miss_target,
                          refine_iters=refine_iters, max_factor=max_factor,
                          flush_guard_ms=flush_guard_ms,
                          admission_control=admission_control,
                          place_stages=place_stages, poisson=poisson)
        data["models"][model] = row
        emit(f"serve_knee/{model}/knee_qps", 0.0,
             f"{row['knee_qps']}qps|x{row['knee_of_steady']}_of_steady|"
             f"miss={row['knee_miss_rate']}|"
             f"probes={len(row['probes'])}")
    with open(out, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
    print(f"\n[serve_knee_bench] wrote {out} ({len(data['models'])} "
          f"model(s), batch {batch}, miss target {miss_target:.0%})")
    return data


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="AlexNet only, small batch (CI bench-smoke)")
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--frames", type=int, default=None)
    ap.add_argument("--stages", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0,
                    help="params/calibration/stream/schedule RNG seed")
    ap.add_argument("--slo-ms", type=float, default=None,
                    help="interactive-class deadline (default: derived "
                         "from the measured service time)")
    ap.add_argument("--traffic-mix", default=None, dest="traffic_mix",
                    help="name:priority:share[:deadline_ms],... "
                         "(default: interactive 25%% + batch 75%%)")
    ap.add_argument("--miss-target", type=float,
                    default=DEFAULT_MISS_TARGET,
                    help="armed-class miss rate defining 'sustained' "
                         "(default 0.01)")
    ap.add_argument("--max-factor", type=float, default=8.0,
                    help="sweep cap as a multiple of measured steady "
                         "fps (default 8)")
    ap.add_argument("--refine-iters", type=int, default=None,
                    help="bisection refinements of the bracket "
                         "(default 3, 2 with --quick)")
    ap.add_argument("--flush-guard-ms", type=float, default=None,
                    help="fixed flush guard (default: adaptive)")
    ap.add_argument("--no-admission", action="store_true",
                    help="disable estimated-wait admission control")
    ap.add_argument("--place-stages", action="store_true",
                    help="pin stage i to jax.devices()[i %% n]")
    ap.add_argument("--poisson", action="store_true",
                    help="exponential inter-arrival gaps (bursty)")
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--model", action="append", default=None,
                    choices=sorted(W.CNN_MODELS), dest="models")
    args = ap.parse_args(argv)
    from benchmarks.run import print_csv
    csv: list[str] = []

    def emit(name, us, derived=""):
        csv.append(f"{name},{us:.1f},{derived}")

    run(emit, quick=args.quick, batch=args.batch, frames=args.frames,
        out=args.out, models=args.models, stages=args.stages,
        seed=args.seed, slo_ms=args.slo_ms,
        traffic_mix_spec=args.traffic_mix,
        miss_target=args.miss_target, refine_iters=args.refine_iters,
        max_factor=args.max_factor, flush_guard_ms=args.flush_guard_ms,
        admission_control=not args.no_admission,
        place_stages=args.place_stages, poisson=args.poisson)
    print_csv(csv)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
