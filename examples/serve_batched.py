"""Batched serving example: prefill a batch of prompts, then decode with
the KV/state cache — including the int8 weight-only quantized path (the
paper's fixed-point pipeline applied to decode).

  PYTHONPATH=src python examples/serve_batched.py [--arch rwkv6-7b]
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get as get_arch
from repro.configs.base import reduced
from repro.launch import steps as STEPS
from repro.models import layers as L
from repro.models import transformer as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    cfg = reduced(get_arch(args.arch))
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    prefill = jax.jit(STEPS.make_prefill_step(cfg))
    decode = jax.jit(STEPS.make_serve_step(cfg))

    def serve(params, tag):
        cache = T.init_cache(cfg, args.batch, args.prompt_len + args.gen)
        toks = jax.random.randint(jax.random.PRNGKey(1),
                                  (args.batch, args.prompt_len), 0,
                                  cfg.vocab)
        logits, cache = prefill(params, cache, {"tokens": toks})
        tok = jnp.argmax(logits.astype(jnp.float32), -1)[:, None]
        t0 = time.time()
        out = [tok]
        for _ in range(args.gen - 1):
            nxt, cache = decode(params, cache, {"tokens": tok})
            tok = nxt[:, None]
            out.append(tok)
        jax.block_until_ready(tok)
        dt = time.time() - t0
        rate = args.gen * args.batch / dt
        print(f"[{tag:5s}] {rate:8.1f} tok/s   first ids: "
              f"{jnp.concatenate(out, 1)[0, :6].tolist()}")
        return jnp.concatenate(out, 1)

    a = serve(params, "bf16")
    qparams = L.quantize_params_int8(params)
    b = serve(qparams, "int8")
    agree = float((a == b).mean())
    print(f"int8 vs bf16 greedy-token agreement: {agree:.2%}")


if __name__ == "__main__":
    main()
