"""Elastic rescale demo: train on 8 CPU devices, checkpoint, lose half the
"pod", re-plan with Algorithm 1 for the surviving devices, restore the
checkpoint re-sharded onto the smaller mesh, and keep training — the
paper's "regenerate the accelerator for the new resource budget" at mesh
scale.

  python examples/elastic_rescale.py      (sets its own XLA device count)
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import tempfile

import jax

from repro import checkpointing as ckpt
from repro import optim
from repro.configs import ARCHS
from repro.configs.base import reduced
from repro.data.pipeline import DataConfig, TokenStream
from repro.launch import steps as STEPS
from repro.models import transformer as T
from repro.runtime import sharding as SH
from repro.runtime.fault_tolerance import elastic_replan


def mk_mesh(n_data, n_model):
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def place(tree, shardings):
    return jax.tree.map(jax.device_put, tree, shardings)


def main():
    cfg = reduced(ARCHS["yi-6b"]).scaled(vocab=64)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    dc = DataConfig(global_batch=8, seq_len=16, vocab=cfg.vocab)
    stream = TokenStream(dc)
    step = jax.jit(STEPS.make_train_step(cfg, lr=1e-3, remat=False))

    with tempfile.TemporaryDirectory() as ckdir:
        # --- phase 1: 8 devices (4 data x 2 model)
        mesh = mk_mesh(4, 2)
        psh = SH.param_shardings(cfg, mesh, params, fsdp=False)
        params8 = place(params, psh)
        opt = optim.adamw_init(params8)
        with jax.set_mesh(mesh):
            for i in range(6):
                params8, opt, m = step(params8, opt, next(stream))
        print(f"[8 devices] step 6 loss {float(m['loss']):.4f}")
        ckpt.save(ckdir, 6, params8)

        # --- failure: pod shrinks to 4 devices; re-plan + re-shard
        plan = elastic_replan(ARCHS["yi-6b"], 4, seq_len=4096,
                              global_batch=256)
        print(f"[re-plan] surviving 4 chips -> stages x tp = "
              f"{plan.n_stages} x {plan.tensor_parallel}, "
              f"util {plan.utilization:.2f}")
        mesh4 = jax.make_mesh((2, 2), ("data", "model"),
                              devices=jax.devices()[:4])
        psh4 = SH.param_shardings(cfg, mesh4, params, fsdp=False)
        params4 = ckpt.restore_resharded(ckdir, 6, params, psh4)
        opt4 = optim.adamw_init(params4)
        stream.seek(6)
        with jax.set_mesh(mesh4):
            for i in range(6):
                params4, opt4, m = step(params4, opt4, next(stream))
        print(f"[4 devices] step 12 loss {float(m['loss']):.4f} "
              f"(resumed from the re-sharded checkpoint)")


if __name__ == "__main__":
    main()
