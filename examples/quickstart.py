"""Quickstart: the paper's resource-allocation framework in 30 lines.

Runs Algorithm 1 + Algorithm 2 for VGG16 on a ZC706-class budget, prints
the per-layer allocation and the resulting throughput (paper Table I), then
plans the same technique for a TPU pod running qwen2-72b.

  PYTHONPATH=src python examples/quickstart.py
"""

from repro.configs import ARCHS
from repro.core import throughput as T
from repro.core.allocator import allocate_buffers, allocate_compute, \
    plan_pipeline
from repro.core.workload import lm_layer_workloads, vgg16

# --- FPGA mode: the faithful reproduction -----------------------------------
model = vgg16()
layers = model.layer_workloads(weight_bits=16)
allocs = allocate_compute(layers, theta_total=900)
allocate_buffers(allocs, bram_total=545, bandwidth_bytes=4.2e9,
                 freq_hz=200e6)

print(f"== {model.name} on 900 DSPs @ 200 MHz ==")
print(f"{'layer':10s} {'theta':>6s} {'C_p':>4s} {'M_p':>4s} {'K':>3s}")
for a in allocs:
    if a.layer.macs:
        print(f"{a.layer.name:10s} {a.theta:6d} {a.Cp:4d} {a.Mp:4d} {a.K:3d}")
print(f"DSPs used      : {T.dsps_used(allocs)}")
print(f"DSP efficiency : {T.dsp_efficiency(allocs):.3f}")
print(f"Throughput     : {T.pipeline_fps(allocs, freq_hz=200e6):.1f} fps "
      f"({T.gops(allocs, freq_hz=200e6):.0f} GOPS)")

# --- Mesh mode: the same objective on a TPU pod ------------------------------
cfg = ARCHS["qwen2-72b"]
lm = lm_layer_workloads(cfg, seq_len=4096, batch=256, mode="train")
plan = plan_pipeline(lm, model_axis=16, data_axis=16, global_batch=256,
                     seq_len=4096, train=True, d_model=cfg.d_model)
print(f"\n== {cfg.name} on a 16x16 v5e pod (train, 4k seq) ==")
print(f"stages x tensor  : {plan.n_stages} x {plan.tensor_parallel}")
print(f"microbatches (K) : {plan.microbatches}")
print(f"layers per stage : {plan.layers_per_stage}")
print(f"bubble fraction  : {plan.bubble_fraction:.3f}")
print(f"predicted util   : {plan.utilization:.3f}")
