"""Live rescale demo: serve a small CNN, then re-partition the fleet
under traffic — R 1 -> 2 via ``Server.rescale`` — without dropping a
request. The serving-plane sibling of ``elastic_rescale.py`` (which
shows the same regenerate-and-swap idea at training-mesh scale).

  python examples/serve_rescale.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import threading
import time

import jax
import numpy as np

from repro.core import workload as W
from repro.core.program import compile_model
from repro.models import cnn
from repro.serving import ProgramRegistry, ServerConfig, build_server


def main():
    # A tiny CNN so the demo compiles in seconds.
    m = W.CNNModel("tiny", 16, 4, (
        W.ConvLayer("c1", 4, 8, 3),
        W.ConvLayer("p1", 8, 8, 2, stride=2, kind="pool"),
        W.ConvLayer("fc", 8 * 8 * 8, 10, 1, kind="fc"),
    ))
    params = cnn.init_params(m, jax.random.PRNGKey(0))
    calib = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16, 4))
    prog = compile_model(m, params, bits=8, calib_batch=calib)

    reg = ProgramRegistry()
    reg.register("tiny", prog)
    srv = build_server(reg, ServerConfig(batch=4, stages=2, replicas=1),
                       verbose=True)
    fe = srv.open_frontend(200.0)

    # Keep traffic flowing on a producer thread for the whole demo.
    stop = threading.Event()
    results = []

    def producer():
        i = 0
        while not stop.is_set():
            frame = np.full((16, 16, 4), i % 7, np.float32)
            results.append(fe.submit(frame, timeout=30))
            i += 1
            time.sleep(0.002)

    t = threading.Thread(target=producer)
    t.start()
    time.sleep(0.3)

    # The live reconfiguration: compile + calibrate an R=2 fleet in the
    # background, then drain -> swap -> resume between micro-batches.
    event = srv.rescale("tiny", replicas=2)
    print(f"rescaled {event['before']} -> {event['after']} "
          f"(compile {event['compile_s']:.2f}s, "
          f"swap {event['swap_s'] * 1e3:.1f}ms)")

    time.sleep(0.3)
    stop.set()
    t.join()
    fe.close()

    st = fe.stats
    print(f"submitted {st.submitted}, resolved {st.resolved}, "
          f"hung {st.hung}  <- the zero-loss contract")
    assert st.hung == 0
    srv.close()


if __name__ == "__main__":
    main()
