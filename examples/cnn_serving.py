"""Serve a paper CNN from one compiled, jitted EngineProgram.

Compiles AlexNet once (Algorithms 1/2 + calibration + lowering), builds
the jitted batched runner, then streams frames through the micro-batching
executor — compare the steady-state FPS against the eager per-sample loop
and the paper's Algorithm-1 prediction for the same plan.

With ``--stages K`` the same program is served through the stage-pipelined
subsystem instead: Algorithm 1's balance objective splits the step chain
into K near-equal stages, one worker thread per stage with depth-2
queues (the activation double-buffer analogue), and the async frontend
batches an open-loop request stream into it, reporting p50/p95/p99
request latency. ``--place-stages`` pins stage i to its own device
(round-robin over ``jax.devices()``; transparent on one device).

With ``--qos`` the stream is a two-class mix (25% interactive with a
deadline, 75% best-effort batch) through the QoS frontend's priority
lanes, replayed below and above saturation — per-class latency split
into queueing / assembly / compute, with SLO miss and drop rates. The
control decisions are adaptive: an EWMA service-time estimate drives
the expedited flush, and estimated-wait admission refuses hopeless
requests at submit (``rejected_wait``) instead of letting them expire
in queue.

With ``--knee`` the example runs the bracketing absolute-QPS sweep
instead and reports the capacity knee: the maximum sustained rate at
which the interactive class misses its SLO less than 1% of the time —
same sweep as ``repro.launch.serve_cnn --knee`` and
``benchmarks/serve_knee_bench.py``.

  PYTHONPATH=src python examples/cnn_serving.py [--model alexnet]
  PYTHONPATH=src python examples/cnn_serving.py --stages 2
  PYTHONPATH=src python examples/cnn_serving.py --stages 2 --qos
  PYTHONPATH=src python examples/cnn_serving.py --stages 2 --knee
"""

import argparse

from repro.core import workload as W
from repro.launch.serve_cnn import serve, serve_async, serve_knee, serve_qos


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="alexnet",
                    choices=sorted(W.CNN_MODELS))
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--frames", type=int, default=24)
    ap.add_argument("--stages", type=int, default=0,
                    help="serve through the K-stage pipeline + async "
                         "frontend (0 = single-jit executor)")
    ap.add_argument("--place-stages", action="store_true",
                    help="pin stage i to jax.devices()[i %% n]")
    ap.add_argument("--qos", action="store_true",
                    help="mixed-traffic QoS demo (priority lanes, "
                         "deadlines, phase-split latency)")
    ap.add_argument("--knee", action="store_true",
                    help="bracketing absolute-QPS sweep: report the "
                         "capacity knee (max sustained rate with "
                         "interactive SLO miss < 1%%)")
    ap.add_argument("--slo-ms", type=float, default=None,
                    help="interactive-class deadline (default: derived "
                         "from the measured service time)")
    args = ap.parse_args()
    if args.slo_ms is not None:      # an SLO only means anything in QoS
        args.qos = True              # mode — match the launcher CLI
    if args.knee:
        r = serve_knee(args.model, frames=max(args.frames, 4 * args.batch),
                       batch=args.batch, stages=max(args.stages, 1),
                       slo_ms=args.slo_ms, place_stages=args.place_stages)
        knee = r["knee_qps"]
        print(f"\n{r['stages']}-stage capacity knee of {r['model']} "
              f"(slo {r['slo_ms']:.0f} ms, steady "
              f"{r['measured_steady_fps']:.1f} fps):")
        for p in r["probes"]:
            print(f"  {p['arrival_fps']:8.1f} qps: "
                  f"{'sustained' if p['sustained'] else 'MISS     '} "
                  f"miss {p['armed_miss_rate']:6.1%} | expired "
                  f"{p['expired']:3d} | rejected_wait "
                  f"{p['rejected_wait']:3d}")
        print("  knee: "
              + (f"{knee:.1f} qps ({r['knee_of_steady']:.2f}x steady)"
                 if knee is not None else "not found at any probed rate"))
    elif args.qos:
        r = serve_qos(args.model, frames=max(args.frames, 4 * args.batch),
                      batch=args.batch, stages=max(args.stages, 1),
                      slo_ms=args.slo_ms, place_stages=args.place_stages)
        print(f"\n{r['stages']}-stage QoS serving of {r['model']} "
              f"(slo {r['slo_ms']:.0f} ms, steady "
              f"{r['measured_steady_fps']:.1f} fps):")
        for rate_key, rrow in r["rates"].items():
            print(f"  load {rate_key} ({rrow['arrival_fps']:.1f} fps):")
            for name, crow in rrow["classes"].items():
                ph = crow["phase_ms"]
                print(f"    {name:12s} p95 queue {ph['queueing']['p95']:8.1f}"
                      f" ms | assemble {ph['assembly']['p95']:8.1f} ms | "
                      f"compute {ph['compute']['p95']:8.1f} ms | "
                      f"miss {crow['slo_miss_rate']:5.0%} | "
                      f"drop {crow['drop_rate']:5.0%}")
    elif args.stages > 0:
        r = serve_async(args.model, frames=args.frames, batch=args.batch,
                        stages=args.stages, place_stages=args.place_stages)
        print(f"\n{r['stages']}-stage pipeline (boundaries "
              f"{r['boundaries']}, balance {r['stage_balance']:.2f}): "
              f"steady {r['measured_steady_fps']:.1f} fps at batch "
              f"{r['batch']}; open-loop {r['arrival_fps']:.1f} fps -> "
              f"p50 {r['latency_ms_p50']:.1f} ms, p95 "
              f"{r['latency_ms_p95']:.1f} ms, p99 "
              f"{r['latency_ms_p99']:.1f} ms — modeled pipeline "
              f"{r['modeled_fps_alg1']:.0f} fps @200MHz")
    else:
        r = serve(args.model, frames=args.frames, batch=args.batch,
                  eager_frames=2)
        print(f"\nsteady-state {r['measured_steady_fps']:.1f} fps at batch "
              f"{r['batch']} vs {r['eager_fps']:.2f} fps eager "
              f"({r['speedup_vs_eager']:.0f}x) — modeled pipeline "
              f"{r['modeled_fps_alg1']:.0f} fps @200MHz")


if __name__ == "__main__":
    main()
