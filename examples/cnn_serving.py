"""Serve a paper CNN from one compiled, jitted EngineProgram.

Compiles AlexNet once (Algorithms 1/2 + calibration + lowering), builds
the jitted batched runner, then streams frames through the micro-batching
executor — compare the steady-state FPS against the eager per-sample loop
and the paper's Algorithm-1 prediction for the same plan.

  PYTHONPATH=src python examples/cnn_serving.py [--model alexnet]
"""

import argparse

from repro.core import workload as W
from repro.launch.serve_cnn import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="alexnet",
                    choices=sorted(W.CNN_MODELS))
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--frames", type=int, default=24)
    args = ap.parse_args()
    r = serve(args.model, frames=args.frames, batch=args.batch,
              eager_frames=2)
    print(f"\nsteady-state {r['measured_steady_fps']:.1f} fps at batch "
          f"{r['batch']} vs {r['eager_fps']:.2f} fps eager "
          f"({r['speedup_vs_eager']:.0f}x) — modeled pipeline "
          f"{r['modeled_fps_alg1']:.0f} fps @200MHz")


if __name__ == "__main__":
    main()
