"""End-to-end training driver: a ~100M-parameter dense LM for a few hundred
steps on whatever devices exist, with the full substrate (sharded data
pipeline, AdamW + WSD schedule, checkpoints, fault-tolerant loop) — and a
mid-run injected crash to demonstrate restart.

  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse
import tempfile

import jax

from repro import optim
from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig, make_stream
from repro.launch import steps as STEPS
from repro.models import transformer as T
from repro.runtime.fault_tolerance import run_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()

    # ~100M params: a yi-6b-family decoder scaled down.
    cfg = ModelConfig(
        name="yi-100m", family="dense", n_layers=8, d_model=768,
        n_heads=12, n_kv_heads=4, d_ff=2048, vocab=32000, head_dim=64)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    opt = optim.adamw_init(params)
    print(f"params: {T.param_count(cfg)/1e6:.1f}M")

    stream = make_stream(cfg, DataConfig(global_batch=args.batch,
                                         seq_len=args.seq, vocab=cfg.vocab,
                                         zipf_alpha=1.2))
    lr = optim.wsd_schedule(3e-4, warmup=30, total=args.steps)
    step = jax.jit(STEPS.make_train_step(cfg, lr=lr, remat=False))
    losses = []

    def step_fn(state, batch):
        p, o = state
        p, o, m = step(p, o, batch)
        losses.append(float(m["loss"]))
        if len(losses) % 25 == 0:
            print(f"step {len(losses):4d}  loss {losses[-1]:.4f}")
        return (p, o), m

    with tempfile.TemporaryDirectory() as ck:
        state, rs = run_loop(
            state=(params, opt), step_fn=step_fn, stream=stream,
            ckpt_dir=ck, total_steps=args.steps, ckpt_every=100,
            fail_at={args.steps // 2: "crash"})   # survive a mid-run crash
    k = max(5, len(losses) // 10)
    first, last = sum(losses[:k]) / k, sum(losses[-k:]) / k
    print(f"\nloss {first:.3f} -> {last:.3f} over {args.steps} steps "
          f"(restarts survived: {rs.restarts})")
    assert last < first, (first, last)


if __name__ == "__main__":
    main()
