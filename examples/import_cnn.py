"""Import a LeNet-style CNN and serve it beside a paper model.

The zoo is closed no more: ``examples/lenet.json`` is a model the paper
never shipped, written as the compiler front door's dependency-free
graph spec. This example walks the whole importer pipeline —

  graph IR -> lowering (ReLU/pool folding, padding legalization)
           -> PTQ calibration + int8 golden (generated on the exact-f32
              MAC route, verified bit-exactly on the int32 oracle route)
           -> ProgramRegistry, next to a paper model compiled the
              classic way
           -> build_server: one multi-tenant fleet, one frontend,
              interleaved submits to both models

— and prints the per-tenant stats rollup at the end. Runs on CPU with
no optional dependencies (the ONNX path is a separate, guarded reader).

  PYTHONPATH=src python examples/import_cnn.py
  PYTHONPATH=src python examples/import_cnn.py --paper-model zf --frames 8
"""

import argparse
import os

from repro.core import workload as W
from repro.serving.server import (ProgramRegistry, ServerConfig,
                                  build_server, compile_for_serving,
                                  synthetic_stream_like)

SPEC = os.path.join(os.path.dirname(__file__), "lenet.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--spec", default=SPEC,
                    help="graph spec to import (.json)")
    ap.add_argument("--paper-model", default="alexnet",
                    choices=sorted(W.CNN_MODELS),
                    help="paper model to serve beside the import")
    ap.add_argument("--frames", type=int, default=6,
                    help="frames to submit per model")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--stages", type=int, default=1)
    args = ap.parse_args()

    registry = ProgramRegistry()
    name, golden = registry.register_imported(args.spec)
    print(f"imported {name!r} from {args.spec}: golden "
          f"acc_crc={int(golden['acc_crc'])} verified f32 -> oracle")
    registry.register(args.paper_model,
                      compile_for_serving(args.paper_model))
    print(f"registered paper model {args.paper_model!r} beside it: "
          f"zoo = {list(registry.names())}")

    cfg = ServerConfig(batch=args.batch, stages=args.stages,
                       calib_frames=3 * args.batch)
    with build_server(registry, cfg, verbose=True) as srv:
        reqs = []
        for i in range(args.frames):
            for model_id in registry.names():   # interleave the tenants
                frame = synthetic_stream_like(
                    registry.get(model_id).model, 1, seed=i)[0]
                reqs.append((model_id, srv.submit(model_id, frame)))
        for model_id, r in reqs:
            r.result(timeout=120.0)
        stats = srv.stats()

    print("\nper-tenant rollup:")
    for model_id, row in stats["models"].items():
        print(f"  {model_id:12s} completed {row['completed']:3d} | "
              f"steady {row['steady_fps']:8.2f} fps | "
              f"p95 {row['latency_ms_p95']} ms")
    print(f"fleet totals: {stats['totals']}")


if __name__ == "__main__":
    main()
