"""The paper's own workload end-to-end: AlexNet inference in channel-wise
fixed point (int8 MACs, 32-bit partial sums, shift alignment) vs float,
plus the allocator's predicted accelerator throughput for the same model.

  PYTHONPATH=src python examples/cnn_fixed_point.py
"""

import jax
import jax.numpy as jnp

from repro.core import throughput as T
from repro.core.allocator import allocate_compute
from repro.core.workload import CNN_MODELS
from repro.models import cnn

m = CNN_MODELS["alexnet"]()
params = cnn.init_params(m, jax.random.PRNGKey(0))
x = jax.random.normal(jax.random.PRNGKey(1), (4, m.input_hw, m.input_hw, 3))

y_float = cnn.forward(params, m, x)
y_int8 = cnn.forward(params, m, x, quantized=True, bits=8)
y_int16 = cnn.forward(params, m, x, quantized=True, bits=16)

rel8 = float(jnp.linalg.norm(y_float - y_int8) / jnp.linalg.norm(y_float))
rel16 = float(jnp.linalg.norm(y_float - y_int16) / jnp.linalg.norm(y_float))
top1_agree = float((jnp.argmax(y_float, -1) == jnp.argmax(y_int8, -1)).mean())
print(f"{m.name}: GOP={m.gop:.2f}")
print(f"int8  vs float rel-err {rel8:.4f}  (top-1 agreement "
      f"{top1_agree:.0%})")
print(f"int16 vs float rel-err {rel16:.6f}")

allocs = allocate_compute(m.layer_workloads(weight_bits=8), 1800 - 11)
print(f"\naccelerator plan (8-bit, 900 DSPs double-pumped):")
print(f"  DSP efficiency {T.dsp_efficiency(allocs, macs_per_dsp=2):.3f}, "
      f"{T.pipeline_fps(allocs, freq_hz=200e6):.0f} fps, "
      f"{T.gops(allocs, freq_hz=200e6):.0f} GOPS")
