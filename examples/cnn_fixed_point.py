"""The paper's own workload end-to-end: AlexNet inference through a
compiled EngineProgram — Algorithms 1/2 run once, po2 scales frozen from a
calibration batch, int8 activations end-to-end with the fused
bias/ReLU/shift epilogue — vs the float reference, plus the *same* plan's
predicted accelerator throughput (one object drives both).

  PYTHONPATH=src python examples/cnn_fixed_point.py
"""

import jax
import jax.numpy as jnp

from repro.core import throughput as T
from repro.core.program import compile_model
from repro.core.simulator import simulate
from repro.core.workload import CNN_MODELS
from repro.models import cnn

m = CNN_MODELS["alexnet"]()
params = cnn.init_params(m, jax.random.PRNGKey(0))
x = jax.random.normal(jax.random.PRNGKey(1), (4, m.input_hw, m.input_hw, 3))

# One compile: allocation + calibration + lowering (8-bit, 900 DSPs
# double-pumped = 2 * 900 - n_layers multiplier budget).
prog = compile_model(m, params, theta=1800 - 11, bits=8, calib_batch=x)
# 16-bit: one multiplier per DSP, so the plain 900-DSP budget.
prog16 = compile_model(m, params, theta=900, bits=16, calib_batch=x)

y_float = cnn.forward(params, m, x)
y_int8 = prog.run(x)
y_int16 = prog16.run(x)

rel8 = float(jnp.linalg.norm(y_float - y_int8) / jnp.linalg.norm(y_float))
rel16 = float(jnp.linalg.norm(y_float - y_int16) / jnp.linalg.norm(y_float))
top1_agree = float((jnp.argmax(y_float, -1) == jnp.argmax(y_int8, -1)).mean())
print(f"{m.name}: GOP={prog.gop:.2f}")
print(f"int8  vs float rel-err {rel8:.4f}  (top-1 agreement "
      f"{top1_agree:.0%})")
print(f"int16 vs float rel-err {rel16:.6f}")

# The same program object answers the throughput questions (Table I).
sim = simulate(prog, n_frames=3)
print(f"\naccelerator plan (8-bit, 900 DSPs double-pumped):")
print(f"  DSP efficiency {T.dsp_efficiency(prog.allocs, macs_per_dsp=2):.3f}"
      f" (simulated {sim.dsp_efficiency:.3f}), "
      f"{T.pipeline_fps(prog.allocs, freq_hz=200e6):.0f} fps, "
      f"{T.gops(prog.allocs, freq_hz=200e6):.0f} GOPS")
