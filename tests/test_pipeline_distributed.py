"""Multi-device tests (8 host CPU devices in a subprocess): the flexible
pipeline's numerics vs the sequential reference, and the pjit sharding
rules. Run in a subprocess so the main pytest session keeps 1 device."""

import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, "src")
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.compat import set_mesh
    from repro.configs import ARCHS, reduced
    from repro.core import pipeline as PL
    from repro.models import transformer as TF
    from repro.models import layers as L

    def run(arch, S, T, K, tol=5e-3, boundaries=None):
        # MoE: no-drop capacity (capacity overflow legitimately differs
        # between microbatched and full-batch dispatch) + wider tolerance
        # (expert psums split across tp reorder bf16 reductions).
        cfg = reduced(ARCHS[arch]).scaled(n_layers=4, vocab=128,
                                          moe_capacity_factor=8.0)
        mesh = PL.make_pipeline_mesh(n_data=8 // (S * T), n_stage=S, n_tp=T)
        params, kind = PL.build_pipeline_params(cfg, S=S,
                                                boundaries=boundaries)
        mask = params.pop("unit_mask")
        units_shape = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
            params["units"])
        ctx = PL.PipelineContext(cfg=cfg, unit_kind=kind, S=S, T=T,
                                 n_micro=K)
        loss_fn = PL.pipeline_loss_fn(ctx, mesh, units_shape,
                                      unit_mask=mask)
        B, Sq = 8, 16
        key = jax.random.PRNGKey(0)
        batch = {"tokens": jax.random.randint(key, (B, Sq), 0, 128),
                 "labels": jax.random.randint(key, (B, Sq), 0, 128)}
        with set_mesh(mesh):
            loss = float(jax.jit(loss_fn)(params, batch))
            g = jax.jit(jax.grad(loss_fn))(params, batch)
            gn = float(sum(jnp.sum(jnp.abs(x.astype(jnp.float32)))
                           for x in jax.tree.leaves(g)))
        # sequential reference
        def ref_loss(params, batch):
            x = jnp.take(params["embed"], batch["tokens"], axis=0)
            Bb, Ss = batch["tokens"].shape
            pos = jnp.broadcast_to(jnp.arange(Ss)[None], (Bb, Ss))
            if cfg.mrope:
                pos = jnp.broadcast_to(pos[..., None], (Bb, Ss, 3))
            S_, Lmax = mask.shape
            for s_ in range(S_):
                for j in range(Lmax):
                    if not bool(mask[s_, j]):
                        continue
                    lp = jax.tree.map(lambda t: t[s_, j], params["units"])
                    x, _, _ = TF._layer_apply(kind, lp, cfg, x, pos, None)
            x = L.rms_norm(params["final_norm"], x)
            logits = (x @ params["lm_head"]["w"]).astype(jnp.float32)
            logp = jax.nn.log_softmax(logits, -1)
            nll = -jnp.take_along_axis(
                logp, batch["labels"][..., None], -1)[..., 0]
            return float(nll.mean())
        rl = ref_loss(params, batch)
        assert abs(rl - loss) < tol, (arch, rl, loss)
        assert gn > 0 and np.isfinite(gn), (arch, gn)
        print(f"OK {arch} S={S} T={T} K={K} loss={loss:.4f} ref={rl:.4f}")

    run("yi-6b", 2, 2, 2)       # GQA units, 2-stage x 2-tp
    run("yi-6b", 4, 1, 4)       # 4-stage pure pipeline
    run("qwen2-72b", 2, 2, 2)   # qkv-bias GQA
    run("rwkv6-7b", 2, 2, 2)    # attention-free units
    run("deepseek-v2-236b", 2, 2, 2, tol=2e-2)  # MLA + MoE units
    # Algorithm-1-style nonuniform stage boundaries (3+1 layers)
    run("yi-6b", 2, 2, 2, boundaries=(0, 3, 4))
""")


@pytest.mark.slow
def test_pipeline_matches_reference_multidevice():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", _SCRIPT], cwd=os.path.join(
        os.path.dirname(__file__), ".."), env=env, capture_output=True,
        text=True, timeout=1800)
    assert res.returncode == 0, res.stdout + "\n" + res.stderr
    assert res.stdout.count("OK ") == 6, res.stdout + res.stderr


_SHARD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, "src")
    import jax, jax.numpy as jnp
    from repro.compat import set_mesh
    from repro.configs import ARCHS, reduced
    from repro.models import transformer as TF
    from repro.runtime import sharding as SH
    from repro.launch import steps as STEPS

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    for arch in ("yi-6b", "deepseek-v2-236b", "rwkv6-7b"):
        cfg = reduced(ARCHS[arch])
        params_sds, opt_sds = STEPS.abstract_state(cfg)
        psh = SH.param_shardings(cfg, mesh, params_sds, fsdp=False)
        # every spec must be constructible for real arrays
        params = TF.init_params(cfg, jax.random.PRNGKey(0))
        placed = jax.tree.map(jax.device_put, params, psh)
        batch = {"tokens": jnp.zeros((8, 16), jnp.int32),
                 "labels": jnp.zeros((8, 16), jnp.int32)}
        bsh = SH.batch_shardings(mesh, jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch))
        with set_mesh(mesh):
            loss, _ = jax.jit(lambda p, b: TF.loss_fn(p, cfg, b))(
                placed, jax.tree.map(jax.device_put, batch, bsh))
        assert bool(jnp.isfinite(loss)), arch
        print("OK", arch, float(loss))
""")


@pytest.mark.slow
def test_pjit_sharding_rules_multidevice():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", _SHARD_SCRIPT],
                         cwd=os.path.join(os.path.dirname(__file__), ".."),
                         env=env, capture_output=True, text=True,
                         timeout=1800)
    assert res.returncode == 0, res.stdout + "\n" + res.stderr
    assert res.stdout.count("OK") == 3, res.stdout + res.stderr
