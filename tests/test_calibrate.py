"""The shared calibration conventions (``repro.serving.calibrate``):
the one-batch-window flush timeout, the warmup/unloaded-traversal/
steady-throughput measurement pass, and the warm-started frontend every
QoS rate and knee probe opens. These used to be private helpers inside
the launcher; now they are the contract both the single-model serve
paths and the multi-tenant server build on, so they get pinned here."""

import numpy as np
import pytest

from repro.core.executor import ServeStats
from repro.serving import (default_max_wait_ms, pipeline_throughput,
                           warmed_frontend, window_key)


class _Partition:
    def __init__(self, n_stages=2):
        self.n_stages = n_stages


class FakePipeline:
    """Protocol-conformant fake with the calibration surface on top:
    serve() counts frames into a real ServeStats, warmup() records that
    it ran, reset_stats() zeroes the window."""

    def __init__(self, batch_size=4, stages=2):
        self.batch_size = batch_size
        self.partition = _Partition(stages)
        self.program = None
        self.on_result = None
        self.on_error = None
        self.stats = ServeStats()
        self.warmups = 0
        self.serves = []

    def warmup(self, frames):
        self.warmups += 1

    def serve(self, frames):
        self.serves.append(len(frames))
        self.stats.frames += len(frames)
        self.stats.batches += -(-len(frames) // self.batch_size)
        self.stats.wall_s += 0.01
        return [np.zeros(1)] * len(frames)

    def submit_batch(self, frames, n_valid, tag=None):
        if self.on_result:
            self.on_result(tag, [f.copy() for f in frames[:n_valid]])

    def flush_inflight(self):
        pass

    def reset_stats(self):
        self.stats = ServeStats()

    def replica_counts(self):
        return None


def test_default_max_wait_is_one_batch_window():
    assert default_max_wait_ms(16, 100.0) == pytest.approx(160.0)
    assert default_max_wait_ms(4, 50.0) == pytest.approx(80.0)
    # Rate 0 (or negative) cannot define a window: fixed 50ms fallback.
    assert default_max_wait_ms(16, 0.0) == 50.0


def test_pipeline_throughput_measures_a_clean_window():
    """The phase-1 pass: warmup (via the executor's own warmup hook when
    it has one), one unloaded single-batch traversal, stats reset, then
    the saturating closed-loop pass — so the returned snapshot covers
    exactly the steady-state serve and nothing before it."""
    px = FakePipeline(batch_size=4)
    stream = np.zeros((12, 2, 2, 1), np.float32)
    warmup_s, lat1_s, ph1 = pipeline_throughput(px, stream, 4)
    assert px.warmups == 1                      # warmup hook preferred
    assert warmup_s >= 0 and lat1_s > 0
    # serve() ran twice: the unloaded traversal (one batch) and the
    # measured stream; the snapshot covers only the latter.
    assert px.serves == [4, 12]
    assert ph1.frames == 12 and ph1.batches == 3
    # Snapshot, not alias: later serving must not mutate the phase-1
    # numbers the artifact records.
    px.serve(list(stream))
    assert ph1.frames == 12


def test_pipeline_throughput_without_warmup_hook():
    class NoWarmup(FakePipeline):
        warmup = None
    px = NoWarmup(batch_size=4)
    stream = np.zeros((8, 2, 2, 1), np.float32)
    _, _, ph1 = pipeline_throughput(px, stream, 4)
    # The warmup fell back to a serve() pass: 3 serves total.
    assert px.serves == [4, 4, 8]
    assert ph1.frames == 8


def test_warmed_frontend_seeds_both_channels():
    """Estimator warm-start convention: window channel at the measured
    batch window, latency channel at the measured unloaded traversal
    when given (it outranks the stages x window formula)."""
    px = FakePipeline(batch_size=4, stages=3)
    fe = warmed_frontend(px, steady=100.0, rate=50.0, batch=4,
                         max_wait_ms=None, admission_control=True,
                         flush_guard_ms=None, lat1_s=0.5)
    try:
        est = fe.estimator
        assert est.estimate(window_key(4)) == pytest.approx(0.04)
        assert est.estimate(4) == pytest.approx(0.5)   # measured wins
        # max_wait defaults to one batch window at min(rate, steady).
        assert fe.max_wait_s == pytest.approx(4 / 50.0)
    finally:
        fe.close()


def test_warmed_frontend_formula_fallback_and_explicit_wait():
    """Without a measured traversal the latency channel falls back to
    stages x replicas x window; an explicit max_wait_ms is taken as
    given."""
    px = FakePipeline(batch_size=4, stages=3)
    fe = warmed_frontend(px, steady=100.0, rate=400.0, batch=4,
                         max_wait_ms=7.5, admission_control=False,
                         flush_guard_ms=None)
    try:
        est = fe.estimator
        assert est.estimate(4) == pytest.approx(3 * 0.04)
        assert fe.max_wait_s == pytest.approx(0.0075)
    finally:
        fe.close()
