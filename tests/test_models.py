"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, output shapes + finiteness; decode with cache; cache consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.configs import ARCHS, reduced
from repro.launch import steps as STEPS
from repro.models import transformer as T

B, S = 2, 16


def _batch(cfg, with_labels=True, seq=S):
    out = {}
    key = jax.random.PRNGKey(0)
    if cfg.family == "enc_dec":
        out["enc_embeds"] = jnp.ones((B, 8, cfg.d_model), jnp.bfloat16)
        out["tokens"] = jax.random.randint(key, (B, seq), 0, cfg.vocab)
    elif cfg.frontend_stub:
        out["embeds"] = jax.random.normal(key, (B, seq, cfg.d_model),
                                          jnp.bfloat16)
        out["positions"] = jnp.broadcast_to(
            jnp.arange(seq)[None, :, None], (B, seq, 3)).astype(jnp.int32)
    else:
        out["tokens"] = jax.random.randint(key, (B, seq), 0, cfg.vocab)
    if with_labels:
        out["labels"] = jax.random.randint(
            jax.random.fold_in(key, 1), (B, seq), 0, cfg.vocab)
    return out


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_forward_shapes_and_finite(arch):
    cfg = reduced(ARCHS[arch])
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    logits, _, aux = T.forward(params, cfg, _batch(cfg, False))
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_train_step_finite(arch):
    cfg = reduced(ARCHS[arch])
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    opt = optim.adamw_init(params, cfg.opt_moment_dtype)
    step = STEPS.make_train_step(cfg, remat=False)
    p2, o2, m = step(params, opt, _batch(cfg))
    assert bool(jnp.isfinite(m["loss"]))
    assert bool(jnp.isfinite(m["grad_norm"])) and float(m["grad_norm"]) > 0
    # params actually moved
    delta = sum(float(jnp.sum(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert delta > 0


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_decode_step(arch):
    cfg = reduced(ARCHS[arch])
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    cache = T.init_cache(cfg, B, 64)
    if cfg.frontend_stub and cfg.family != "enc_dec":
        batch = {"embeds": jnp.ones((B, 1, cfg.d_model), jnp.bfloat16),
                 "positions": jnp.zeros((B, 1, 3), jnp.int32)}
    else:
        batch = {"tokens": jnp.zeros((B, 1), jnp.int32)}
    logits, cache2, _ = T.forward(params, cfg, batch, cache=cache)
    assert logits.shape == (B, 1, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert int(cache2["_pos"]) == 1


@pytest.mark.parametrize("arch", ["yi-6b", "rwkv6-7b", "recurrentgemma-2b",
                                  "deepseek-v2-236b"])
def test_prefill_decode_matches_full_forward(arch):
    """Teacher-forced decode over a cache must reproduce the densely
    computed logits (the KV-cache correctness invariant). MoE capacity is
    raised so no tokens drop — capacity overflow legitimately differs
    between a full pass and token-by-token decode."""
    cfg = reduced(ARCHS[arch])
    if cfg.moe_n_experts:
        cfg = cfg.scaled(moe_capacity_factor=8.0)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(5), (B, 8), 0, cfg.vocab)
    full_logits, _, _ = T.forward(params, cfg, {"tokens": toks})
    cache = T.init_cache(cfg, B, 16)
    # prefill first 4, then decode 4 teacher-forced steps
    logits_p, cache, _ = T.forward(params, cfg, {"tokens": toks[:, :4]},
                                   cache=cache)
    outs = [logits_p[:, -1]]
    for t in range(4, 8):
        lg, cache, _ = T.forward(params, cfg, {"tokens": toks[:, t:t + 1]},
                                 cache=cache)
        outs.append(lg[:, 0])
    got = jnp.stack(outs, 1).astype(jnp.float32)       # positions 3..7
    want = full_logits[:, 3:8].astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=6e-2, atol=8e-2)


def test_param_counts_in_expected_range():
    """Full-size parameter counts must be near the nameplate sizes."""
    expect = {"qwen2-72b": (69e9, 82e9), "yi-6b": (5.5e9, 6.8e9),
              "granite-34b": (30e9, 38e9), "deepseek-v3-671b": (640e9, 700e9),
              "deepseek-v2-236b": (220e9, 250e9), "rwkv6-7b": (6e9, 8.5e9),
              "recurrentgemma-2b": (2e9, 3.3e9), "qwen3-1.7b": (1.4e9, 2.4e9),
              "qwen2-vl-2b": (1.2e9, 2.4e9),
              "seamless-m4t-medium": (0.7e9, 1.6e9)}
    for arch, (lo, hi) in expect.items():
        n = T.param_count(ARCHS[arch])
        assert lo <= n <= hi, (arch, n / 1e9)


def test_pallas_attention_impl_matches_jax():
    """Model forward with the Pallas flash-attention kernel (interpret
    mode) matches the jax attention core."""
    from repro.models import layers as L
    cfg = reduced(ARCHS["yi-6b"]).scaled(n_layers=2, vocab=64)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 128),
                                          0, 64)}
    logits_jax, _, _ = T.forward(params, cfg, batch)
    L.set_attention_impl("pallas")
    try:
        logits_pal, _, _ = T.forward(params, cfg, batch)
    finally:
        L.set_attention_impl("jax")
    # bf16 params + different accumulation order: tiny tail of elements
    # wiggle by ~0.06 in logit space
    np.testing.assert_allclose(
        np.asarray(logits_jax, np.float32), np.asarray(logits_pal,
                                                       np.float32),
        rtol=6e-2, atol=8e-2)
