"""QoS frontend: priority lanes, deadlines, drop-on-SLO-miss, the four
request timestamps, per-class phase-split stats, and the seeded traffic
generator. The acceptance pins: a low-priority flood cannot starve
high-priority requests past their deadline, an expired request
resolves with the ``expired`` outcome instead of hanging, and — with
estimated-wait admission on an exact estimator — no request both passes
admission and later expires in queue."""

import threading
import time

import numpy as np
import pytest

from repro.serving import (AsyncFrontend, DeadlineExpired, RequestRejected,
                           ServiceTimeEstimator, TrafficClass,
                           armed_class_names, default_mix, make_schedule,
                           parse_traffic_mix, replay)


class EchoExecutor:
    """Fake executor: optional fixed service time per batch, echoes each
    frame back as its result, records dispatch order. Deterministic —
    no device, no jit."""

    def __init__(self, batch_size=4, delay_s=0.0):
        self.batch_size = batch_size
        self.delay_s = delay_s
        self.program = None         # no compiled program: skip shape checks
        self.on_result = None
        self.on_error = None
        self.dispatched = []        # list of tag tuples, in arrival order

    def submit_batch(self, frames, n_valid, tag=None):
        self.dispatched.append(tag)
        if self.delay_s:
            time.sleep(self.delay_s)
        if self.on_result:
            self.on_result(tag, [f.copy() for f in frames[:n_valid]])

    def flush_inflight(self):
        pass                        # delivers synchronously from submit

    def reset_stats(self):
        pass

    def replica_counts(self):
        return None


class GateExecutor(EchoExecutor):
    """EchoExecutor that blocks each submit_batch until released —
    batches complete exactly when the test says so."""

    def __init__(self, batch_size=4):
        super().__init__(batch_size)
        self.gate = threading.Semaphore(0)

    def submit_batch(self, frames, n_valid, tag=None):
        assert self.gate.acquire(timeout=30)
        super().submit_batch(frames, n_valid, tag)


FRAME = np.zeros((2, 2, 1), np.float32)


def _frames(n, base=0):
    return [np.full((2, 2, 1), base + i, np.float32) for i in range(n)]


# ---------------------------------------------------------------------------
# Outcomes
# ---------------------------------------------------------------------------


def test_expired_request_resolves_with_expired_outcome():
    """A request whose deadline passes while queued is dropped: outcome
    'expired', result() raises DeadlineExpired, nothing hangs, and the
    stats reconcile exactly."""
    ex = GateExecutor(batch_size=1)
    fe = AsyncFrontend(ex, max_wait_ms=5.0)
    blocker = fe.submit(FRAME)                  # occupies the executor
    time.sleep(0.05)                            # batcher blocks on gate
    doomed = fe.submit(FRAME, deadline_ms=1.0)  # expires while queued
    time.sleep(0.05)
    ex.gate.release()
    blocker.result(timeout=10)
    with pytest.raises(DeadlineExpired):
        doomed.result(timeout=10)
    assert doomed.outcome == "expired"
    assert doomed.expired() and doomed.missed_deadline()
    assert doomed.t_dispatched is None          # never reached the engine
    fe.close()
    st = fe.stats
    assert st.expired == 1 and st.completed == 1
    assert st.resolved == st.submitted == 2
    assert st.klass("p0").expired == 1


def test_rejected_outcome_on_full_lane_nonblocking():
    """block=False on a full lane load-sheds: the request comes back
    already resolved 'rejected' and result() raises RequestRejected."""
    ex = GateExecutor(batch_size=2)
    fe = AsyncFrontend(ex, max_wait_ms=5.0, max_queue=2)
    reqs = [fe.submit(FRAME) for _ in range(2)]   # claimed by the batcher
    time.sleep(0.05)
    reqs += [fe.submit(FRAME) for _ in range(2)]  # fills the p0 lane
    shed = fe.submit(FRAME, block=False)
    assert shed.outcome == "rejected"
    with pytest.raises(RequestRejected):
        shed.result(timeout=1)
    for _ in range(3):
        ex.gate.release()
    for r in reqs:
        r.result(timeout=10)
    fe.close()
    assert fe.stats.rejected == 1
    assert fe.stats.resolved == fe.stats.submitted == 5


def test_full_lane_still_blocks_by_default():
    """The PR-3 backpressure contract is unchanged: a blocking submit on
    a full lane raises queue.Full when its timeout expires."""
    import queue as queue_mod
    ex = GateExecutor(batch_size=2)
    fe = AsyncFrontend(ex, max_wait_ms=5.0, max_queue=2)
    reqs = [fe.submit(FRAME) for _ in range(2)]
    time.sleep(0.05)
    reqs += [fe.submit(FRAME) for _ in range(2)]
    with pytest.raises(queue_mod.Full):
        fe.submit(FRAME, timeout=0.05)
    for _ in range(3):
        ex.gate.release()
    for r in reqs:
        r.result(timeout=10)
    fe.close()


# ---------------------------------------------------------------------------
# Priority lanes + starvation
# ---------------------------------------------------------------------------


def test_priority_lanes_dispatch_high_first():
    """With both lanes populated, the next assembled batch drains the
    high-priority lane before touching the low one."""
    ex = GateExecutor(batch_size=4)
    fe = AsyncFrontend(ex, max_wait_ms=20.0)
    lo_first = [fe.submit(f, priority=0) for f in _frames(4)]
    time.sleep(0.05)        # batcher claims the first lo batch, blocks
    lo_rest = [fe.submit(f, priority=0) for f in _frames(4, base=10)]
    hi = [fe.submit(f, priority=1) for f in _frames(4, base=100)]
    for _ in range(3):
        ex.gate.release()
    for r in lo_first + lo_rest + hi:
        r.result(timeout=10)
    fe.close()
    assert len(ex.dispatched) == 3
    assert [r.priority for r in ex.dispatched[1]] == [1, 1, 1, 1]
    assert [r.priority for r in ex.dispatched[2]] == [0, 0, 0, 0]


def test_low_priority_flood_cannot_starve_high_past_deadline():
    """The pinned QoS guarantee: under a saturating best-effort flood,
    deadline-armed high-priority requests still complete inside their
    deadline (priority lanes + expedited flush), while every flood
    request still resolves eventually."""
    ex = EchoExecutor(batch_size=4, delay_s=0.05)
    fe = AsyncFrontend(ex, max_wait_ms=10.0)
    # 40 best-effort frames = 10 batches = ~500ms of queued work, so
    # FIFO service would answer a later arrival well past the 450ms
    # deadline the high class carries; the priority lane must not.
    flood = [fe.submit(f, priority=0, klass="lo") for f in _frames(40)]
    time.sleep(0.02)        # flood is queued ahead
    hi = [fe.submit(f, priority=2, deadline_ms=450.0, klass="hi")
          for f in _frames(4, base=100)]
    for r in hi:
        out = r.result(timeout=10)   # completes — never expired
        assert r.outcome == "completed"
        assert not r.missed_deadline()
        np.testing.assert_array_equal(out, np.full((2, 2, 1),
                                                   100 + hi.index(r)))
    fe.close()
    st = fe.stats
    assert st.resolved == st.submitted == 44
    assert st.klass("hi").completed == 4
    assert st.klass("hi").late == 0 and st.klass("hi").expired == 0
    assert st.klass("lo").completed == 40    # flood still fully served


def test_backlogged_frontend_dispatches_full_batches():
    """Once lane wait exceeds max_wait_ms the flush timer is permanently
    expired; the batcher must still fill batches from the queued backlog
    instead of timeout-flushing padded singletons (which would collapse
    the service rate by batch_size x)."""
    ex = EchoExecutor(batch_size=4, delay_s=0.05)
    fe = AsyncFrontend(ex, max_wait_ms=10.0, max_queue=1024)
    reqs = [fe.submit(f) for f in _frames(40)]
    for r in reqs:
        r.result(timeout=30)
    fe.close()
    sizes = [len(t) for t in ex.dispatched]
    assert sizes.count(4) >= 9, f"dispatch sizes {sizes}"
    assert fe.stats.flushes_full >= 9


def test_rejected_best_effort_is_drop_not_slo_miss():
    """Admission rejection of a deadline-less class counts in drop_rate
    only — a class with no SLO cannot miss one."""
    ex = GateExecutor(batch_size=2)
    fe = AsyncFrontend(ex, max_wait_ms=5.0, max_queue=2)
    reqs = [fe.submit(FRAME) for _ in range(2)]
    time.sleep(0.05)
    reqs += [fe.submit(FRAME) for _ in range(2)]
    shed = fe.submit(FRAME, block=False)
    assert shed.outcome == "rejected"
    for _ in range(3):
        ex.gate.release()
    for r in reqs:
        r.result(timeout=10)
    fe.close()
    cs = fe.stats.klass("default")
    assert cs.rejected == 1 and not cs.armed
    assert cs.drop_rate > 0.0
    assert cs.slo_miss_rate == 0.0


def test_starved_lane_request_still_expires_at_deadline():
    """A deadline-armed request in a lane the batcher never drains
    (sustained higher-priority traffic) must still resolve ``expired``
    at its deadline — never block in result() until the flood abates."""
    ex = EchoExecutor(batch_size=4, delay_s=0.05)
    fe = AsyncFrontend(ex, max_wait_ms=10.0)
    # ~0.5s of high-priority work keeps lane 1 non-empty throughout.
    flood = [fe.submit(f, priority=1, klass="hi") for f in _frames(40)]
    starved = fe.submit(FRAME, priority=0, deadline_ms=100.0, klass="lo")
    with pytest.raises(DeadlineExpired):
        starved.result(timeout=10)
    # Expired at ~deadline, not after the flood drained (~0.5s).
    assert starved.latency_s < 0.4
    for r in flood:
        r.result(timeout=30)
    fe.close()
    assert fe.stats.klass("lo").expired == 1
    assert fe.stats.resolved == fe.stats.submitted == 41


def test_deadline_expedites_flush():
    """A lone deadline-armed request in a quiet frontend must be flushed
    at its deadline, not parked for the full max_wait window."""
    ex = EchoExecutor(batch_size=8)
    fe = AsyncFrontend(ex, max_wait_ms=10_000.0)
    t0 = time.perf_counter()
    req = fe.submit(FRAME, deadline_ms=100.0)
    req.result(timeout=10)
    elapsed = time.perf_counter() - t0
    fe.close()
    assert req.outcome == "completed"
    assert elapsed < 5.0                     # nowhere near max_wait
    assert fe.stats.flushes_deadline == 1
    assert fe.stats.flushes_timeout == 0


# ---------------------------------------------------------------------------
# Adaptive control: EWMA flush + estimated-wait admission
# ---------------------------------------------------------------------------


def test_admission_rejects_hopeless_request_at_submit():
    """With ~500ms of queued work ahead priced by an exact estimator, a
    100ms-deadline request is refused at submit (rejected_wait) instead
    of expiring in queue; an ample-budget request sails through."""
    ex = EchoExecutor(batch_size=4, delay_s=0.05)
    est = ServiceTimeEstimator()
    est.warm_start(4, 0.05)
    fe = AsyncFrontend(ex, max_wait_ms=5.0, estimator=est,
                       admission_control=True, flush_guard_ms=10.0)
    flood = [fe.submit(f) for f in _frames(40)]   # ~10 batches queued
    doomed = fe.submit(FRAME, deadline_ms=100.0, klass="doomed")
    assert doomed.outcome == "rejected_wait"
    assert doomed.done() and doomed.missed_deadline()
    assert doomed.t_batched is None               # never entered a lane
    with pytest.raises(RequestRejected):
        doomed.result(timeout=1)
    ok = fe.submit(FRAME, deadline_ms=10_000.0, klass="ok")
    for r in flood:
        r.result(timeout=30)
    assert np.asarray(ok.result(timeout=30)).shape == FRAME.shape
    fe.close()
    st = fe.stats
    assert st.resolved == st.submitted == 42
    assert st.rejected_wait == 1 and st.expired == 0
    cs = st.klass("doomed")
    assert cs.rejected_wait == 1 and cs.armed
    assert cs.slo_miss_rate == 1.0 and cs.drop_rate == 1.0
    assert st.klass("ok").completed == 1


def test_admission_prices_only_work_at_or_above_own_priority():
    """A best-effort flood in the low lane must not scare admission off
    a high-priority request — the priority lanes will serve it first, so
    only work at its own priority or higher (plus in-flight batches) is
    ahead of it."""
    ex = EchoExecutor(batch_size=4, delay_s=0.05)
    est = ServiceTimeEstimator()
    est.warm_start(4, 0.05)
    fe = AsyncFrontend(ex, max_wait_ms=10.0, estimator=est,
                       admission_control=True, flush_guard_ms=10.0)
    flood = [fe.submit(f, priority=0, klass="lo") for f in _frames(40)]
    time.sleep(0.02)
    hi = fe.submit(FRAME, priority=2, deadline_ms=450.0, klass="hi")
    assert hi.outcome != "rejected_wait"          # admitted
    out = hi.result(timeout=10)
    assert hi.outcome == "completed" and not hi.missed_deadline()
    np.testing.assert_array_equal(out, FRAME)
    for r in flood:
        r.result(timeout=30)
    fe.close()
    assert fe.stats.rejected_wait == 0
    assert fe.stats.resolved == fe.stats.submitted == 41


def test_admission_disabled_keeps_expiry_behaviour():
    """admission_control=False (the default) is the PR-4 contract: the
    same hopeless request is accepted and expires in queue."""
    ex = EchoExecutor(batch_size=4, delay_s=0.05)
    est = ServiceTimeEstimator()
    est.warm_start(4, 0.05)
    fe = AsyncFrontend(ex, max_wait_ms=5.0, estimator=est,
                       flush_guard_ms=10.0)
    flood = [fe.submit(f) for f in _frames(40)]
    doomed = fe.submit(FRAME, deadline_ms=100.0)
    with pytest.raises(DeadlineExpired):
        doomed.result(timeout=10)
    assert doomed.outcome == "expired"
    for r in flood:
        r.result(timeout=30)
    fe.close()
    assert fe.stats.rejected_wait == 0 and fe.stats.expired == 1


def test_ewma_flush_replaces_fixed_guard_when_estimator_is_warm():
    """A lone deadline-armed request in a quiet frontend is parked until
    est_service + guard before its deadline — substantially *later* than
    the fixed 80%-of-budget fallback — and still completes in time."""
    ex = EchoExecutor(batch_size=8)                 # instant service
    est = ServiceTimeEstimator()
    est.warm_start(8, 0.010)
    fe = AsyncFrontend(ex, max_wait_ms=10_000.0, estimator=est,
                       flush_guard_ms=300.0)
    t0 = time.perf_counter()
    req = fe.submit(FRAME, deadline_ms=3_000.0)
    req.result(timeout=10)
    elapsed = time.perf_counter() - t0
    fe.close()
    assert req.outcome == "completed"
    assert not req.missed_deadline()
    # Fixed-guard fallback would have flushed at 2400ms; the estimator
    # holds the batch open until ~2690ms (more assembly opportunity).
    # The ~310ms slack before the deadline absorbs scheduler stalls on
    # a starved shared runner — this runs in the blocking tier-1 lane.
    assert elapsed > 2.5
    assert fe.stats.flushes_deadline == 1


def test_saturating_flood_admitted_requests_never_expire_in_queue():
    """The admission property pinned by the acceptance criteria: under a
    saturating deadline-armed flood with an *exact* estimator (the fake
    executor's service time is deterministic and warm-started verbatim),
    every request either completes or is refused at submit — zero
    requests pass admission and then expire in queue."""
    ex = EchoExecutor(batch_size=4, delay_s=0.05)
    est = ServiceTimeEstimator()
    est.warm_start(4, 0.05)
    fe = AsyncFrontend(ex, max_wait_ms=5.0, max_queue=1024,
                       estimator=est, admission_control=True,
                       flush_guard_ms=25.0)
    # 60 frames = 15 batches = 750ms of work at a 400ms deadline: the
    # early fraction is servable, the tail is hopeless.
    reqs = [fe.submit(f, deadline_ms=400.0, klass="rt")
            for f in _frames(60)]
    for r in reqs:
        assert r._event.wait(timeout=30), "request hung"
    fe.close()
    st = fe.stats
    assert st.resolved == st.submitted == 60
    assert st.expired == 0, \
        f"{st.expired} admitted requests expired in queue"
    assert st.rejected_wait > 0            # the hopeless tail failed fast
    assert st.completed > 0                # the servable head completed
    assert st.completed + st.rejected_wait == 60
    for r in reqs:
        assert r.outcome in ("completed", "rejected_wait")


# ---------------------------------------------------------------------------
# Timestamps + per-class stats
# ---------------------------------------------------------------------------


def test_four_timestamps_monotone_and_phase_split():
    """t_submit <= t_batched <= t_dispatched <= t_done for a completed
    request, and the phase split reassembles to the total latency."""
    ex = EchoExecutor(batch_size=2, delay_s=0.01)
    fe = AsyncFrontend(ex, max_wait_ms=20.0)
    reqs = [fe.submit(f, priority=1, deadline_ms=5_000.0, klass="hi")
            for f in _frames(2)]
    for r in reqs:
        r.result(timeout=10)
    fe.close()
    for r in reqs:
        assert r.t_submit <= r.t_batched <= r.t_dispatched <= r.t_done
        ph = r.phase_s()
        assert all(v is not None and v >= 0 for v in ph.values())
        total = ph["queueing"] + ph["assembly"] + ph["compute"]
        assert total == pytest.approx(r.latency_s, abs=1e-6)


def test_per_class_stats_reconcile_and_percentiles():
    """Class rows partition the totals; phase percentiles come back per
    class with p50 <= p95 <= p99."""
    ex = EchoExecutor(batch_size=4)
    fe = AsyncFrontend(ex, max_wait_ms=10.0)
    for f in _frames(8):
        fe.submit(f, priority=0, klass="bulk")
    for f in _frames(4, base=50):
        fe.submit(f, priority=1, deadline_ms=5_000.0, klass="rt")
    while fe.stats.resolved < 12:
        time.sleep(0.005)
    fe.close()
    st = fe.stats
    assert set(st.classes) == {"bulk", "rt"}
    assert st.klass("bulk").submitted == 8
    assert st.klass("rt").submitted == 4
    assert sum(cs.submitted for cs in st.classes.values()) == st.submitted
    assert sum(cs.completed for cs in st.classes.values()) == st.completed
    pp = st.phase_percentiles()
    for name in ("bulk", "rt"):
        for phase in ("queueing", "assembly", "compute", "total"):
            row = pp[name][phase]
            assert row["p50"] <= row["p95"] <= row["p99"]
    assert st.klass("rt").slo_miss_rate == 0.0
    assert st.klass("bulk").drop_rate == 0.0


def test_legacy_submit_is_single_default_class():
    """Plain submit() (no priority, no deadline) keeps the PR-3
    behaviour: one best-effort class, nothing dropped, nothing late."""
    ex = EchoExecutor(batch_size=4)
    fe = AsyncFrontend(ex, max_wait_ms=10.0)
    reqs = [fe.submit(f) for f in _frames(6)]
    for r in reqs:
        r.result(timeout=10)
    fe.close()
    assert set(fe.stats.classes) == {"default"}
    assert fe.stats.expired == fe.stats.rejected == 0
    assert not np.isnan(fe.stats.latency_percentiles()["p99"])


# ---------------------------------------------------------------------------
# Traffic generator (the one seeded stream every bench shares)
# ---------------------------------------------------------------------------


def test_make_schedule_deterministic_and_mixed():
    mix = default_mix(slo_ms=100.0)
    a = make_schedule(64, 200.0, mix, seed=7)
    b = make_schedule(64, 200.0, mix, seed=7)
    assert [(x.t, x.frame_idx, x.klass.name) for x in a] == \
        [(x.t, x.frame_idx, x.klass.name) for x in b]
    assert {x.klass.name for x in a} == {"interactive", "batch"}
    # Uniform pacing at 200 fps: 5ms period, monotone offsets.
    assert a[0].t == 0.0
    assert all(y.t - x.t == pytest.approx(0.005)
               for x, y in zip(a, a[1:]))
    c = make_schedule(64, 200.0, mix, seed=8)
    assert [x.klass.name for x in a] != [x.klass.name for x in c]
    # Poisson arrivals: same seed reproduces, gaps vary.
    d = make_schedule(64, 200.0, mix, seed=7, poisson=True)
    e = make_schedule(64, 200.0, mix, seed=7, poisson=True)
    assert [x.t for x in d] == [x.t for x in e]
    gaps = {round(y.t - x.t, 6) for x, y in zip(d, d[1:])}
    assert len(gaps) > 1


def test_parse_traffic_mix():
    mix = parse_traffic_mix("interactive:1:1:50,batch:0:3")
    assert [c.name for c in mix] == ["interactive", "batch"]
    assert mix[0].priority == 1 and mix[0].deadline_ms == 50.0
    assert mix[1].deadline_ms is None
    assert mix[0].share == pytest.approx(0.25)   # normalized 1:3
    assert parse_traffic_mix("a:0:1:slo", slo_ms=77.0)[0].deadline_ms == 77.0
    with pytest.raises(ValueError):
        parse_traffic_mix("bad")
    with pytest.raises(ValueError):
        parse_traffic_mix("a:0:0,b:0:0")
    with pytest.raises(ValueError):
        parse_traffic_mix("a:0:1:slo")       # 'slo' needs an slo_ms
    with pytest.raises(ValueError):
        parse_traffic_mix("a:0:1:slo", slo_ms=0.0)


def test_armed_class_names():
    mix = default_mix(slo_ms=100.0)
    assert armed_class_names(mix) == ("interactive",)
    assert armed_class_names(parse_traffic_mix("a:0:1,b:1:1")) == ()


def test_replay_resolves_every_request():
    """replay() waits out expired/failed requests instead of raising —
    handles come back with their outcomes readable."""
    ex = EchoExecutor(batch_size=4, delay_s=0.01)
    fe = AsyncFrontend(ex, max_wait_ms=10.0)
    mix = (TrafficClass("rt", priority=1, deadline_ms=2_000.0, share=0.5),
           TrafficClass("bulk", priority=0, deadline_ms=None, share=0.5))
    frames = np.stack(_frames(16))
    schedule = make_schedule(16, 500.0, mix, seed=3)
    reqs = replay(fe, frames, schedule)
    fe.close()
    assert len(reqs) == 16
    assert all(r.done() for r in reqs)
    assert fe.stats.resolved == fe.stats.submitted == 16
    for a, r in zip(schedule, reqs):
        assert r.klass == a.klass.name
        if r.outcome == "completed":
            np.testing.assert_array_equal(r.result(), frames[a.frame_idx])
