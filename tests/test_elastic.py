"""Elastic runtime, tier 1: the pure hysteresis policy
(ElasticController.decide over hand-built signal windows), the
observe -> decide -> act step against fakes, and the
knee_after_rescale artifact schema. The real mid-stream rescale under
producer threads lives in the stress lane (test_serving_stress.py)."""

import dataclasses
import importlib.util
import json
import os
import threading
import time
import types

import pytest

from repro.serving.elastic import (ElasticController, ElasticPolicy,
                                   RescaleDecision)
from repro.serving.estimator import ServiceTimeEstimator

_ROOT = os.path.join(os.path.dirname(__file__), "..")


def _load_validate_bench():
    spec = importlib.util.spec_from_file_location(
        "validate_bench",
        os.path.join(_ROOT, "benchmarks", "validate_bench.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


# -- fakes: just enough server/frontend for the controller ----------------


@dataclasses.dataclass
class _Class:
    armed: bool = True
    submitted: int = 0
    expired: int = 0
    rejected: int = 0
    rejected_wait: int = 0
    late: int = 0


class _Stats:
    def __init__(self, **classes):
        self.classes = classes


class _FakeFrontend:
    batch_size = 8

    def __init__(self):
        self.estimator = ServiceTimeEstimator()
        self._closing = threading.Event()
        self.snap = _Stats(interactive=_Class())

    def stats_snapshot(self):
        # Deep-ish copy so later mutation doesn't alias the baseline.
        return _Stats(**{k: dataclasses.replace(v)
                         for k, v in self.snap.classes.items()})


class _FakeServer:
    """Enough of Server for the controller: one model, a router-less
    executor, and a rescale() that just records the ask."""

    model_names = ("tiny",)

    def __init__(self, replicas=1):
        self.replicas = replicas
        self.rescales = []

    def _tenant_of(self, model):
        from repro.serving.frontend import DEFAULT_TENANT
        return DEFAULT_TENANT

    def runtime(self, model):
        ex = types.SimpleNamespace(router=None, partition=None,
                                   n_replicas=self.replicas)
        return types.SimpleNamespace(executor=ex)

    def rescale(self, model, *, replicas=None, **kw):
        before = {"replicas": self.replicas}
        self.replicas = replicas
        self.rescales.append(replicas)
        return {"model": model, "before": before,
                "after": {"replicas": replicas},
                "replica_mode": "pipeline", "compile_s": 0.0,
                "swap_s": 0.0, "swapped_frontends": 1}


def _ctrl(policy, replicas=1):
    return ElasticController(_FakeServer(replicas), _FakeFrontend(),
                             policy=policy)


def _win(miss, n=20, *, replicas=1, drift=None, quarantines=0):
    return {"armed_miss_rate": miss, "armed_submitted": n,
            "drift": drift, "quarantine_events": quarantines,
            "replicas": replicas, "stages": 2}


# -- policy validation ----------------------------------------------------


def test_policy_rejects_inverted_bands():
    with pytest.raises(ValueError):
        ElasticPolicy(miss_high=0.01, miss_low=0.05)
    with pytest.raises(ValueError):
        ElasticPolicy(drift_high=1.2, drift_low=1.5)
    with pytest.raises(ValueError):
        ElasticPolicy(sustain=0)
    with pytest.raises(ValueError):
        ElasticPolicy(min_replicas=3, max_replicas=2)


def test_policy_json_roundtrip():
    p = ElasticPolicy(miss_high=0.02, max_replicas=3)
    j = p.to_json()
    assert j["miss_high"] == 0.02 and j["max_replicas"] == 3
    assert ElasticPolicy(**j) == p


# -- decide: pure hysteresis ----------------------------------------------


def test_scale_out_needs_sustained_miss():
    ctrl = _ctrl(ElasticPolicy(miss_high=0.05, sustain=2))
    assert ctrl.decide(_win(0.2)) is None          # one window: a blip
    d = ctrl.decide(_win(0.2))                     # two: a trend
    assert isinstance(d, RescaleDecision)
    assert d.action == "scale_out" and d.replicas == 2
    assert "2 windows" in d.reason


def test_dead_band_window_breaks_the_trend():
    ctrl = _ctrl(ElasticPolicy(miss_high=0.05, miss_low=0.005, sustain=2))
    assert ctrl.decide(_win(0.2)) is None
    assert ctrl.decide(_win(0.02)) is None         # between the edges
    assert ctrl.decide(_win(0.2)) is None          # trend restarted
    assert ctrl.decide(_win(0.2)).action == "scale_out"


def test_quiet_window_neither_builds_nor_decays():
    p = ElasticPolicy(miss_high=0.05, sustain=2, min_window_requests=8)
    ctrl = _ctrl(p)
    assert ctrl.decide(_win(0.2)) is None
    assert ctrl.decide(_win(1.0, n=3)) is None     # too quiet to call
    assert ctrl.decide(_win(0.2)).action == "scale_out"


def test_drift_alone_scales_out():
    ctrl = _ctrl(ElasticPolicy(drift_high=2.0, sustain=1))
    d = ctrl.decide(_win(0.0, drift=2.5))
    assert d is not None and d.action == "scale_out"
    assert "drift" in d.reason


def test_quarantine_triggers_on_first_event_and_respects_ceiling():
    p = ElasticPolicy(max_replicas=2)
    ctrl = _ctrl(p)
    d = ctrl.decide(_win(0.0, quarantines=1))
    assert d is not None and d.action == "scale_out"
    assert "quarantined" in d.reason
    # Already at the ceiling: nothing to scale to.
    ctrl2 = _ctrl(p, replicas=2)
    assert ctrl2.decide(_win(0.0, replicas=2, quarantines=1)) is None
    # Opted out entirely.
    ctrl3 = _ctrl(ElasticPolicy(quarantine_triggers=False, sustain=2))
    assert ctrl3.decide(_win(0.0, quarantines=1)) is None


def test_scale_in_needs_both_low_bands_and_a_floor():
    p = ElasticPolicy(miss_low=0.005, drift_low=1.3, sustain=2,
                      min_replicas=1)
    ctrl = _ctrl(p, replicas=2)
    assert ctrl.decide(_win(0.0, replicas=2)) is None
    d = ctrl.decide(_win(0.0, replicas=2))
    assert d is not None and d.action == "scale_in" and d.replicas == 1
    # Quiet-but-drifting fleet is never shrunk.
    ctrl2 = _ctrl(p, replicas=2)
    assert ctrl2.decide(_win(0.0, replicas=2, drift=1.8)) is None
    assert ctrl2.decide(_win(0.0, replicas=2, drift=1.8)) is None
    # At the floor there is nothing to shrink.
    ctrl3 = _ctrl(p, replicas=1)
    assert ctrl3.decide(_win(0.0)) is None
    assert ctrl3.decide(_win(0.0)) is None


def test_cooldown_suppresses_even_quarantine():
    ctrl = _ctrl(ElasticPolicy(cooldown_s=60.0))
    ctrl._last_rescale_t = time.perf_counter()
    assert ctrl.decide(_win(1.0, quarantines=3)) is None


# -- step: observe -> decide -> act against fakes -------------------------


def test_step_rescales_and_records_event():
    srv = _FakeServer(replicas=1)
    fe = _FakeFrontend()
    ctrl = ElasticController(srv, fe, policy=ElasticPolicy(
        miss_high=0.05, sustain=1, min_window_requests=8))
    # First window: 20 armed submissions, 10 missed -> 50% >= 5%.
    fe.snap.classes["interactive"] = _Class(submitted=20, expired=10)
    event = ctrl.step()
    assert event is not None and srv.rescales == [2]
    assert event["action"] == "scale_out"
    assert event["signals"]["armed_miss_rate"] == 0.5
    assert event["before"] == {"replicas": 1}
    assert event["after"] == {"replicas": 2}
    assert ctrl.history == [event]
    assert not ctrl.busy
    # Cooldown right after the act: an equally bad window is ignored.
    fe.snap.classes["interactive"] = _Class(submitted=40, expired=30)
    assert ctrl.step() is None


def test_step_is_noop_after_frontend_close():
    srv = _FakeServer()
    fe = _FakeFrontend()
    ctrl = ElasticController(srv, fe, policy=ElasticPolicy(sustain=1))
    fe.snap.classes["interactive"] = _Class(submitted=20, expired=20)
    fe._closing.set()
    assert ctrl.step() is None and srv.rescales == []


def test_multi_model_server_needs_explicit_model():
    srv = _FakeServer()
    srv.model_names = ("a", "b")
    with pytest.raises(ValueError, match="explicit model"):
        ElasticController(srv, _FakeFrontend())


# -- artifact schema: knee_after_rescale ----------------------------------

vb = _load_validate_bench()

_PACING = {"arrivals": 40, "target_fps": 12.0, "achieved_fps": 12.0,
           "rate_ratio": 1.0, "lag_ms_mean": 0.1, "lag_ms_max": 0.5}


def _knee_row(replicas, knee_qps):
    return {
        "measured_steady_fps": 10.0, "modeled_fps_alg1": 100.0,
        "batch": 8, "stages": 2, "seed": 0, "slo_ms": 500.0,
        "miss_target": 0.01, "traffic_mix": [], "route": "f32",
        "admission_control": True, "replicas": replicas,
        "knee_qps": knee_qps, "knee_of_steady": knee_qps / 10.0,
        "probes": [
            {"arrival_fps": knee_qps, "sustained": True,
             "armed_miss_rate": 0.0, "armed_submitted": 10,
             "submitted": 40, "completed": 40, "expired": 0,
             "rejected": 0, "rejected_wait": 0, "pacing": _PACING},
            {"arrival_fps": 2 * knee_qps, "sustained": False,
             "armed_miss_rate": 0.5, "armed_submitted": 10,
             "submitted": 40, "completed": 20, "expired": 0,
             "rejected": 0, "rejected_wait": 20, "pacing": _PACING},
        ],
    }


def _seg(label, rate, miss, replicas):
    return {"label": label, "arrival_fps": rate, "armed_submitted": 20,
            "armed_missed": int(20 * miss), "armed_miss_rate": miss,
            "replicas": replicas, "rescales_so_far": 0}


def _rescale_block():
    return {
        "batch": 8, "stages": 2, "seed": 0, "slo_ms": 500.0,
        "miss_target": 0.01, "traffic_mix": [],
        "policy": ElasticPolicy().to_json(),
        "anchor_qps": 12.0, "measured_steady_fps_r1": 10.0,
        "segments": [_seg("ramp0", 12.0, 0.4, 1),
                     _seg("recovery", 12.0, 0.0, 2)],
        "rescale_events": [{
            "model": "alexnet", "before": {"replicas": 1},
            "after": {"replicas": 2}, "compile_s": 1.0, "swap_s": 0.01,
            "action": "scale_out", "reason": "armed miss", "signals": {},
        }],
        "n_rescales": 1, "forced": False,
        "replicas_before": 1, "replicas_after": 2,
        "armed_miss_at_trigger": 0.4, "armed_miss_after_rescale": 0.0,
        "miss_recovered": True, "hung": 0,
        "knee": _knee_row(2, 18.0),
    }


def test_validate_knee_after_rescale_block(tmp_path):
    top = _knee_row(1, 12.0)
    top["knee_after_rescale"] = _rescale_block()
    data = {"schema_version": 1, "bench": "serve_knee", "seed": 0,
            "models": {"alexnet": top}}
    p = tmp_path / "BENCH_serve_knee.json"
    p.write_text(json.dumps(data))
    assert vb.validate(str(p)) == []

    def _mutated(fn):
        bad = json.loads(json.dumps(data))
        fn(bad["models"]["alexnet"]["knee_after_rescale"])
        p.write_text(json.dumps(bad))
        return vb.validate(str(p))

    # No rescale event recorded: the ramp proved nothing.
    errs = _mutated(lambda b: b.update(rescale_events=[]))
    assert any("must trigger" in e for e in errs)
    # Topology summary must reproduce from the events.
    errs = _mutated(lambda b: b.update(replicas_after=4))
    assert any("does not reproduce" in e for e in errs)
    # Event count drifting from the list it summarizes.
    errs = _mutated(lambda b: b.update(n_rescales=2))
    assert any("does not match" in e for e in errs)
    # miss_recovered contradicting the recorded rates.
    errs = _mutated(lambda b: b.update(armed_miss_after_rescale=0.9))
    assert any("contradicts miss" in e for e in errs)
    # The nested knee row must have been measured post-rescale.
    errs = _mutated(lambda b: b["knee"].update(replicas=1))
    assert any("was not measured at replicas_after" in e for e in errs)
    # A lost request is never schema-legal.
    errs = _mutated(lambda b: b.update(hung=-1))
    assert any("hung" in e for e in errs)
