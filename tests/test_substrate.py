"""Data pipeline, optimizer, quantization, checkpointing, fault tolerance."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypcompat import given, settings, st

from repro import checkpointing as ckpt
from repro import optim
from repro.core import quant
from repro.data.pipeline import DataConfig, TokenStream


# -- data -------------------------------------------------------------------

def test_stream_deterministic_and_seekable():
    dc = DataConfig(global_batch=4, seq_len=8, vocab=100)
    s1, s2 = TokenStream(dc), TokenStream(dc)
    a = [next(s1)["tokens"] for _ in range(3)]
    s2.seek(2)
    b = next(s2)["tokens"]
    np.testing.assert_array_equal(np.asarray(a[2]), np.asarray(b))


def test_stream_host_shards_disjoint():
    d0 = DataConfig(global_batch=8, seq_len=4, vocab=1000, n_hosts=2,
                    host_id=0)
    d1 = DataConfig(global_batch=8, seq_len=4, vocab=1000, n_hosts=2,
                    host_id=1)
    b0, b1 = next(TokenStream(d0)), next(TokenStream(d1))
    assert b0["tokens"].shape == (4, 4)
    assert not np.array_equal(np.asarray(b0["tokens"]),
                              np.asarray(b1["tokens"]))


# -- optimizer ---------------------------------------------------------------

def _toy_params(key):
    return {"a": jax.random.normal(key, (64, 32)),
            "b": jnp.zeros((32,))}


def test_adamw_descends_quadratic():
    key = jax.random.PRNGKey(0)
    params = _toy_params(key)
    target = jax.tree.map(lambda p: jnp.ones_like(p), params)
    st_ = optim.adamw_init(params)

    def loss(p):
        return sum(jnp.sum((x - t) ** 2)
                   for x, t in zip(jax.tree.leaves(p),
                                   jax.tree.leaves(target)))

    l0 = float(loss(params))
    for _ in range(50):
        g = jax.grad(loss)(params)
        params, st_ = optim.adamw_update(params, g, st_, lr=0.05,
                                         weight_decay=0.0)
    assert float(loss(params)) < l0 * 0.1


def test_adamw_int8_moments_still_descend():
    """int8 blockwise moments are an approximation (bnb-style); the
    contract is that optimization still descends, not bitwise parity."""
    key = jax.random.PRNGKey(1)
    p8 = _toy_params(key)
    s8 = optim.adamw_init(p8, "int8")

    def loss(p):
        return sum(jnp.sum(x ** 2) for x in jax.tree.leaves(p))

    l0 = float(loss(p8))
    for _ in range(30):
        g8 = jax.grad(loss)(p8)
        p8, s8 = optim.adamw_update(p8, g8, s8, lr=0.02, weight_decay=0.0,
                                    moment_dtype="int8")
    assert float(loss(p8)) < 0.5 * l0


def test_grad_compression_error_feedback():
    key = jax.random.PRNGKey(2)
    g = {"w": jax.random.normal(key, (1000,))}
    err = {"w": jnp.zeros((1000,))}
    comp, err = optim.compress_grads(g, err)
    deq = optim.decompress_grads(comp, g)
    rel = float(jnp.linalg.norm(deq["w"] - g["w"]) /
                jnp.linalg.norm(g["w"]))
    assert rel < 0.02  # blockwise int8
    # error feedback: residual carries the lost mass
    assert float(jnp.linalg.norm(err["w"])) > 0


def test_clip_by_global_norm():
    g = {"w": jnp.full((10,), 100.0)}
    clipped, gn = optim.clip_by_global_norm(g, 1.0)
    assert float(gn) > 1.0
    assert float(jnp.linalg.norm(clipped["w"])) <= 1.0 + 1e-5


# -- quantization -------------------------------------------------------------

@given(st.integers(0, 4), st.sampled_from([8, 16]))
@settings(max_examples=20, deadline=None)
def test_po2_quant_roundtrip(seed, bits):
    x = jax.random.normal(jax.random.PRNGKey(seed), (32, 16)) * 10
    q, e = quant.quantize_po2(x, axis=-1, bits=bits)
    deq = quant.dequantize_po2(q, e, axis=-1)
    rel = float(jnp.linalg.norm(deq - x) / jnp.linalg.norm(x))
    assert rel < (0.02 if bits == 8 else 1e-4)


@pytest.mark.parametrize("seed", [0, 3])
@pytest.mark.parametrize("bits", [8, 16])
def test_po2_quant_roundtrip_fixed(seed, bits):
    """Deterministic fallback for test_po2_quant_roundtrip."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (32, 16)) * 10
    q, e = quant.quantize_po2(x, axis=-1, bits=bits)
    deq = quant.dequantize_po2(q, e, axis=-1)
    rel = float(jnp.linalg.norm(deq - x) / jnp.linalg.norm(x))
    assert rel < (0.02 if bits == 8 else 1e-4)


def test_requantize_shift_exact():
    acc = jnp.array([[1024, -2048, 255]], jnp.int32)
    out = quant.requantize_output(acc, 0, 4, bits=8)
    np.testing.assert_array_equal(np.asarray(out)[0], [64, -128, 15])


# -- checkpointing -------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"w": jnp.arange(12.0).reshape(3, 4),
            "nested": {"b": jnp.ones((2,), jnp.bfloat16)}}
    ckpt.save(str(tmp_path), 10, tree)
    assert ckpt.latest_step(str(tmp_path)) == 10
    got = ckpt.restore(str(tmp_path), 10, tree)
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(tree["w"]))
    assert got["nested"]["b"].dtype == jnp.bfloat16


def test_checkpoint_gc_and_latest(tmp_path):
    tree = {"x": jnp.zeros((2,))}
    for s in (1, 2, 3, 4, 5):
        ckpt.save(str(tmp_path), s, tree, keep=2)
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    assert steps == [4, 5]


def test_checkpoint_incomplete_ignored(tmp_path):
    tree = {"x": jnp.zeros((2,))}
    ckpt.save(str(tmp_path), 1, tree)
    os.makedirs(tmp_path / "step_99.tmp", exist_ok=True)
    assert ckpt.latest_step(str(tmp_path)) == 1


# -- fault tolerance -----------------------------------------------------------

def test_run_loop_crash_restart(tmp_path):
    from repro.runtime.fault_tolerance import run_loop

    dc = DataConfig(global_batch=2, seq_len=4, vocab=10)
    stream = TokenStream(dc)
    state = {"w": jnp.zeros((2,)), "n": jnp.zeros(())}
    seen = []

    def step_fn(state, batch):
        seen.append(int(batch["tokens"][0, 0]))
        return {"w": state["w"] + 1, "n": state["n"] + 1}, {}

    state, rs = run_loop(state=state, step_fn=step_fn, stream=stream,
                         ckpt_dir=str(tmp_path), total_steps=10,
                         ckpt_every=2, fail_at={5: "crash"},
                         log=lambda s: None)
    assert rs.restarts == 1
    assert float(state["n"]) >= 10  # every step executed (some replayed)


def test_elastic_replan():
    from repro.configs import ARCHS
    from repro.runtime.fault_tolerance import elastic_replan
    plan_full = elastic_replan(ARCHS["yi-6b"], 256, seq_len=4096,
                               global_batch=256)
    plan_small = elastic_replan(ARCHS["yi-6b"], 128, seq_len=4096,
                                global_batch=256)
    assert plan_full.n_stages * plan_full.tensor_parallel == 16
    assert plan_small.n_stages * plan_small.tensor_parallel in (8, 16)
