"""Compiler front door (``repro.compiler``): JSON-spec ingestion, the
lowering contract (ReLU/pool folding, padding legalization, typed
rejection of engine-unrepresentable ops), cross-route int8 golden
parity for an imported non-paper CNN, and the registry-serve smoke that
pins the acceptance criterion — an imported model serves through
``build_server``/``Server.submit`` beside the paper models, with no
``onnx`` installed."""

import os

import numpy as np
import pytest

from repro import compiler
from repro.compiler import (GoldenMismatch, Graph, GraphError,
                            UnsupportedOpError, from_spec, import_source)
from repro.serving import (ProgramRegistry, ServerConfig, build_server,
                           synthetic_stream_like)

LENET_SPEC = os.path.join(os.path.dirname(__file__), os.pardir,
                          "examples", "lenet.json")


def tiny_spec(**over):
    spec = {
        "name": "tinynet",
        "input": {"hw": 8, "channels": 3},
        "nodes": [
            {"op": "conv", "name": "c1", "input": "input",
             "out_channels": 4, "kernel": 3, "padding": "same"},
            {"op": "relu", "name": "r1", "input": "c1"},
            {"op": "maxpool", "name": "p1", "input": "r1",
             "kernel": 2, "stride": 2},
            {"op": "flatten", "name": "fl", "input": "p1"},
            {"op": "fc", "name": "f1", "input": "fl",
             "out_features": 10},
        ],
    }
    spec.update(over)
    return spec


# ---------------------------------------------------------------------------
# Graph IR + spec ingestion
# ---------------------------------------------------------------------------


def test_spec_builds_validated_graph_with_shapes():
    g = from_spec(tiny_spec())
    assert isinstance(g, Graph)
    assert g.shapes["c1"] == (8, 8, 4)          # 'same' keeps hw
    assert g.shapes["p1"] == (4, 4, 4)          # k2 s2 halves
    assert g.shapes["fl"] == (64,)              # 4*4*4 flattened
    assert g.shapes["f1"] == (10,)
    assert g.output == "f1"


def test_unknown_op_is_typed_and_names_the_node():
    spec = tiny_spec()
    spec["nodes"][1] = {"op": "gelu", "name": "r1", "input": "c1"}
    with pytest.raises(UnsupportedOpError) as ei:
        import_source(spec)
    assert "r1" in str(ei.value) and "gelu" in str(ei.value)
    assert isinstance(ei.value, GraphError)     # one catchable base


def test_shape_mismatch_rejected_at_import_time():
    spec = tiny_spec()
    spec["nodes"][4]["in_features"] = 999       # producer has 64
    with pytest.raises(GraphError) as ei:
        import_source(spec)
    assert "999" in str(ei.value) and "64" in str(ei.value)


def test_structural_errors_rejected_at_import_time():
    spec = tiny_spec()
    spec["nodes"][0]["input"] = "ghost"         # undefined producer
    with pytest.raises(GraphError):
        import_source(spec)
    spec = tiny_spec()
    spec["nodes"][0]["kernell"] = 3             # typo'd attr, not default
    with pytest.raises(GraphError):
        import_source(spec)
    spec = tiny_spec()
    spec["nodes"].append({"op": "relu", "name": "dangling",
                          "input": "p1"})       # two unconsumed terminals
    with pytest.raises(GraphError):
        import_source(spec)


# ---------------------------------------------------------------------------
# Lowering: normalization onto the engine contract
# ---------------------------------------------------------------------------


def test_lowering_folds_relu_and_pool_into_engine_chain():
    model, params = import_source(tiny_spec())
    assert params is None                       # spec carries no weights
    assert [(l.name, l.kind) for l in model.layers] == \
        [("c1", "conv"), ("p1", "pool"), ("f1", "fc")]
    assert model.layers[2].in_ch == 64          # flatten folded into fc


def test_relu_folds_through_max_pool_exactly():
    """conv -> pool -> relu is legal: max and ReLU commute, so the fold
    into the conv's epilogue is semantics-preserving."""
    spec = tiny_spec()
    spec["nodes"] = [
        spec["nodes"][0],
        {"op": "maxpool", "name": "p1", "input": "c1",
         "kernel": 2, "stride": 2},
        {"op": "relu", "name": "r1", "input": "p1"},
        {"op": "flatten", "name": "fl", "input": "r1"},
        spec["nodes"][4],
    ]
    model, _ = import_source(spec)
    assert [l.name for l in model.layers] == ["c1", "p1", "f1"]


def test_engine_relu_contract_is_enforced():
    # Missing ReLU on a hidden layer: the engine cannot skip its fused
    # epilogue ReLU.
    spec = tiny_spec()
    del spec["nodes"][1]
    spec["nodes"][1]["input"] = "c1"
    with pytest.raises(UnsupportedOpError) as ei:
        import_source(spec)
    assert "c1" in str(ei.value)
    # Trailing ReLU on the final layer: the final engine emits raw
    # accumulators.
    spec = tiny_spec()
    spec["nodes"].append({"op": "relu", "name": "r9", "input": "f1"})
    with pytest.raises(UnsupportedOpError) as ei:
        import_source(spec)
    assert "f1" in str(ei.value)


def test_engine_unrepresentable_ops_rejected_with_reason():
    spec = tiny_spec()
    spec["nodes"][2] = {"op": "avgpool", "name": "p1", "input": "r1",
                        "kernel": 2, "stride": 2}
    with pytest.raises(UnsupportedOpError) as ei:
        import_source(spec)
    assert "max-only" in str(ei.value)

    # Fan-out (residual topology) cannot map onto the linear chain.
    spec = tiny_spec()
    spec["nodes"] = [
        spec["nodes"][0],
        {"op": "relu", "name": "r1", "input": "c1"},
        {"op": "add", "name": "res", "inputs": ["r1", "c1"]},
    ]
    with pytest.raises(UnsupportedOpError) as ei:
        import_source(spec)
    assert "c1" in str(ei.value)


def test_illegal_padding_rejected_not_shifted():
    """A declared pad the engine's output arithmetic cannot reproduce
    must be refused — silently shifting windows would compute a
    different model."""
    spec = tiny_spec()
    # k3 s2 p1 on 8: out = 4, but the engine derives need=1 -> (0, 1)
    # from that output, not the declared (1, 1).
    spec["nodes"][0]["stride"] = 2
    spec["nodes"][0]["padding"] = 1
    with pytest.raises(UnsupportedOpError) as ei:
        import_source(spec)
    assert "shift" in str(ei.value)


# ---------------------------------------------------------------------------
# The acceptance pin: import -> compile -> golden -> serve, no onnx
# ---------------------------------------------------------------------------


def test_lenet_round_trip_golden_bit_exact():
    """The examples/lenet.json spec (a non-paper CNN) compiles through
    compile_model and its int8 execution reproduces the generated
    golden bit-exactly across independent MAC routes (f32 generate,
    int32-oracle verify)."""
    model, params = import_source(LENET_SPEC)
    assert model.name == "lenet" and params is None
    prog = compiler.quantize(model, seed=0)
    golden = compiler.make_golden(prog, seed=0, route="f32")
    assert golden["acc_sample"].dtype == np.int32
    assert len(golden["acc_sample"]) == min(
        compiler.calibrate.N_ACC_SAMPLE, 10)   # 10 logits in frame 0
    # Bit-exact across routes — and deterministic from (spec, seed):
    # recompiling from scratch reproduces the identical artifact.
    compiler.check_golden(prog, golden, seed=0, route="oracle")
    prog2 = compiler.quantize(*import_source(LENET_SPEC), seed=0)
    golden2 = compiler.make_golden(prog2, seed=0, route="f32")
    assert int(golden["acc_crc"]) == int(golden2["acc_crc"])
    assert np.array_equal(golden["acc_sample"], golden2["acc_sample"])


def test_golden_mismatch_is_detected():
    model, _ = import_source(tiny_spec())
    prog = compiler.quantize(model, seed=0)
    golden = compiler.make_golden(prog, seed=0)
    bad = dict(golden)
    bad["acc_crc"] = int(golden["acc_crc"]) ^ 1
    with pytest.raises(GoldenMismatch) as ei:
        compiler.check_golden(prog, bad, seed=0)
    assert "acc_crc" in str(ei.value)


def test_golden_save_load_round_trip(tmp_path):
    model, _ = import_source(tiny_spec())
    prog = compiler.quantize(model, seed=0)
    golden = compiler.make_golden(prog, seed=0)
    path = tmp_path / "tiny_golden.npz"
    compiler.save_golden(path, golden)
    compiler.check_golden(prog, compiler.load_golden(path), seed=0)


def test_registry_serve_smoke_imported_model():
    """The end of the pipeline: register_imported puts the compiled +
    golden-checked program in the zoo, build_server serves it, and
    Server.submit resolves completed."""
    reg = ProgramRegistry()
    name, golden = reg.register_imported(tiny_spec(), seed=0)
    assert name == "tinynet" and name in reg
    assert int(golden["acc_crc"]) != 0
    with pytest.raises(ValueError):             # duplicate id refused
        reg.register_imported(tiny_spec(), seed=0)
    cfg = ServerConfig(batch=4, stages=1, calib_frames=12)
    srv = build_server(reg, cfg)                # no stream: derived from
    try:                                        # the imported model
        frames = synthetic_stream_like(reg.get(name).model, 3, seed=0)
        reqs = [srv.submit(name, f) for f in frames]
        for r in reqs:
            r.result(timeout=120)
        assert all(r.outcome == "completed" for r in reqs)
        st = srv.stats()
        assert st["models"][name]["completed"] == 3
    finally:
        srv.close()


def test_register_imported_golden_check_catches_broken_program(monkeypatch):
    """The cross-route check is live: if verification cannot reproduce
    the golden, the model never enters the zoo."""
    reg = ProgramRegistry()
    real = compiler.check_golden

    def sabotaged(prog, golden, **kw):
        bad = dict(golden)
        bad["acc_crc"] = int(golden["acc_crc"]) ^ 1
        real(prog, bad, **kw)

    monkeypatch.setattr("repro.compiler.check_golden", sabotaged)
    with pytest.raises(GoldenMismatch):
        reg.register_imported(tiny_spec(), seed=0)
    assert len(reg) == 0


# ---------------------------------------------------------------------------
# ONNX path (skips cleanly when onnx is absent)
# ---------------------------------------------------------------------------


def _make_lenet_onnx(path):
    import onnx
    from onnx import TensorProto, helper, numpy_helper

    rng = np.random.default_rng(0)

    def init(name, arr):
        return numpy_helper.from_array(arr.astype(np.float32), name)

    inits = [
        init("w1", rng.standard_normal((4, 1, 3, 3)) * 0.1),   # OIHW
        init("b1", rng.standard_normal((4,)) * 0.1),
        init("w2", rng.standard_normal((10, 64)) * 0.1),       # (out, in)
        init("b2", rng.standard_normal((10,)) * 0.1),
    ]
    nodes = [
        helper.make_node("Conv", ["x", "w1", "b1"], ["c1"], name="c1",
                         kernel_shape=[3, 3], pads=[1, 1, 1, 1]),
        helper.make_node("Relu", ["c1"], ["r1"], name="r1"),
        helper.make_node("MaxPool", ["r1"], ["p1"], name="p1",
                         kernel_shape=[2, 2], strides=[2, 2]),
        helper.make_node("Flatten", ["p1"], ["fl"], name="fl"),
        helper.make_node("Gemm", ["fl", "w2", "b2"], ["y"], name="fc",
                         transB=1),
    ]
    graph = helper.make_graph(
        nodes, "tiny_onnx",
        [helper.make_tensor_value_info("x", TensorProto.FLOAT,
                                       [1, 1, 8, 8])],
        [helper.make_tensor_value_info("y", TensorProto.FLOAT, [1, 10])],
        initializer=inits)
    model = helper.make_model(graph)
    onnx.save(model, str(path))


def test_onnx_import_matches_reference_float_forward(tmp_path):
    """ONNX round trip: NCHW/OIHW conventions translate so the lowered
    model + imported params reproduce a reference NHWC float forward
    (same conv/pool/fc arithmetic) to float tolerance."""
    onnx = pytest.importorskip("onnx")  # noqa: F841
    import jax.numpy as jnp

    from repro.core.program import float_forward

    path = tmp_path / "tiny.onnx"
    _make_lenet_onnx(path)
    model, params = import_source(str(path))
    assert params is not None                  # weights imported
    assert model.input_hw == 8 and model.input_ch == 1
    assert [l.kind for l in model.layers] == ["conv", "pool", "fc"]

    # Reference: the same arithmetic in NHWC numpy, weights straight
    # from the initializers the file was built with.
    rng = np.random.default_rng(0)
    w1 = (rng.standard_normal((4, 1, 3, 3)) * 0.1).astype(np.float32)
    b1 = (rng.standard_normal((4,)) * 0.1).astype(np.float32)
    w2 = (rng.standard_normal((10, 64)) * 0.1).astype(np.float32)
    b2 = (rng.standard_normal((10,)) * 0.1).astype(np.float32)
    x = rng.standard_normal((1, 8, 8, 1)).astype(np.float32)

    xp = np.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)))
    conv = np.zeros((1, 8, 8, 4), np.float32)
    for i in range(8):
        for j in range(8):
            patch = xp[0, i:i + 3, j:j + 3, 0]          # (3, 3)
            for o in range(4):
                conv[0, i, j, o] = float((patch * w1[o, 0]).sum()) + b1[o]
    act = np.maximum(conv, 0.0)
    pool = act.reshape(1, 4, 2, 4, 2, 4).max(axis=(2, 4))
    flat_nchw = pool[0].transpose(2, 0, 1).reshape(-1)  # ONNX flatten order
    ref = flat_nchw @ w2.T + b2

    got = np.asarray(float_forward(params, model, jnp.asarray(x)))
    np.testing.assert_allclose(got[0], ref, rtol=1e-4, atol=1e-4)


def test_onnx_import_serves_end_to_end(tmp_path):
    pytest.importorskip("onnx")
    path = tmp_path / "tiny.onnx"
    _make_lenet_onnx(path)
    reg = ProgramRegistry()
    name, golden = reg.register_imported(str(path))
    assert name == "tiny"
    cfg = ServerConfig(batch=4, stages=1, calib_frames=12)
    srv = build_server(reg, cfg)
    try:
        frame = synthetic_stream_like(reg.get(name).model, 1, seed=0)[0]
        assert srv.submit(name, frame).result(timeout=120) is not None
    finally:
        srv.close()


def test_onnx_absent_raises_plain_import_error(monkeypatch):
    """The guarded path: with onnx unavailable the JSON pipeline is
    untouched and load_onnx raises ImportError, not a crash."""
    from repro.compiler import onnx_import
    monkeypatch.setattr(onnx_import, "onnx_available", lambda: False)
    with pytest.raises(ImportError):
        onnx_import.load_onnx("whatever.onnx")
    # and the dependency-free path still works end to end
    model, _ = import_source(tiny_spec())
    assert model.name == "tinynet"
