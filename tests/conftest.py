import os
import sys

# Tests run on the single real CPU device (NOT the 512-device dry-run
# environment — dryrun.py sets its own XLA_FLAGS). Multi-device tests use
# their own subprocess or the flag below must already be set externally.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
