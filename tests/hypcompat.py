"""Optional-hypothesis shim.

The property-based tests use ``hypothesis`` when it is installed (see
requirements-dev.txt). When it is not, this module exposes stand-ins that
mark those tests as skipped at collection time while letting the rest of the
module import and run — the deterministic fallback cases alongside them keep
coverage of the same invariants.
"""

from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on minimal images
    HAVE_HYPOTHESIS = False

    _SKIP = pytest.mark.skip(reason="hypothesis not installed "
                                    "(pip install -r requirements-dev.txt)")

    def given(*_args, **_kwargs):
        def deco(fn):
            return _SKIP(fn)
        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    class _Strategy:
        """Opaque placeholder: strategy factories return inert objects;
        ``@st.composite`` functions stay callable (returning None)."""

        def __call__(self, *args, **kwargs):
            return _Strategy()

        def __getattr__(self, name):
            return _Strategy()

    st = _Strategy()
