"""Regenerate the golden int8-program outputs checked into tests/golden/.

  PYTHONPATH=src python tests/golden/generate.py [model ...]

One ``<model>.npz`` per model, produced by the jitted batched runner
(route="f32" — bit-identical to the int32 oracle and the Pallas kernel)
on deterministic params/frames (``cnn.init_params`` uses a crc32 layer
fold, so the draw reproduces exactly across runs and machines). Stored:

  acc_sample  first 32 raw int32 accumulators of frame 0
  acc_crc     crc32 of the full int32 accumulator buffer (both frames)
  top1        per-frame argmax class ids
  e_input     frozen input exponent
  e_out       per-compute-step frozen output exponents

``tests/test_executor.py::test_golden_int8_program`` replays the same
compile and compares bit-for-bit. Only regenerate when the quantization
semantics change *intentionally* — and say so in the commit.
"""

import os
import sys
import zlib

import jax
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

from repro.core import workload as W                     # noqa: E402
from repro.core.program import compile_model             # noqa: E402
from repro.models import cnn                             # noqa: E402

HERE = os.path.dirname(os.path.abspath(__file__))
N_FRAMES = 2
N_SAMPLE = 32


def golden_for(model_name: str) -> dict:
    m = W.CNN_MODELS[model_name]()
    params = cnn.init_params(m, jax.random.PRNGKey(0))
    calib = jax.random.normal(jax.random.PRNGKey(1),
                              (1, m.input_hw, m.input_hw, m.input_ch))
    prog = compile_model(m, params, bits=8, calib_batch=calib)
    frames = np.asarray(jax.random.normal(
        jax.random.PRNGKey(2), (N_FRAMES, m.input_hw, m.input_hw,
                                m.input_ch)), np.float32)
    runner = prog.compile_runner(route="f32")
    acc = np.asarray(runner(runner.quantize(frames)))
    assert acc.dtype == np.int32, acc.dtype
    logits = runner.dequantize(acc)
    return {
        "acc_sample": acc[0].reshape(-1)[:N_SAMPLE].astype(np.int32),
        "acc_crc": np.int64(zlib.crc32(np.ascontiguousarray(acc).tobytes())),
        "top1": np.argmax(logits.reshape(N_FRAMES, -1), -1).astype(np.int64),
        "e_input": np.int64(prog.e_input),
        "e_out": np.asarray([s.e_out for s in prog.steps
                             if s.kind != "pool"], np.int64),
    }


def main(argv=None) -> int:
    models = (argv or sys.argv[1:]) or ["zf", "yolo"]
    for name in models:
        data = golden_for(name)
        out = os.path.join(HERE, f"{name}.npz")
        np.savez(out, **data)
        print(f"wrote {out}: top1={data['top1'].tolist()} "
              f"crc={int(data['acc_crc'])} e_input={int(data['e_input'])}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
