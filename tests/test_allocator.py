"""Algorithm 1/2 invariants, optimality, and Table-I-level checks."""

import math

import pytest
from hypcompat import given, settings, st

from repro.core import throughput as T
from repro.core import workload as W
from repro.core.allocator import (_decompose_theta, _partition_min_max,
                                  allocate_buffers, allocate_compute,
                                  engine_cycles, plan_pipeline, total_bram)
from repro.core.workload import LayerWorkload

THETA = 900


def _layers(model):
    return W.CNN_MODELS[model]().layer_workloads(weight_bits=16)


@pytest.mark.parametrize("model", ["vgg16", "alexnet", "zf", "yolo"])
@pytest.mark.parametrize("objective", ["paper", "exact", "optimal"])
def test_alg1_invariants(model, objective):
    layers = _layers(model)
    allocs = allocate_compute(layers, THETA, objective=objective)
    total = 0
    for a in allocs:
        l = a.layer
        if l.macs == 0:
            assert a.theta == 0
            continue
        assert a.theta >= l.R * l.S
        assert a.theta % (l.R * l.S) == 0
        assert a.Cp <= l.C and a.Mp <= l.M
        assert a.Cp * a.Mp * l.R * l.S == a.theta
        total += a.theta
    assert total <= THETA


@pytest.mark.parametrize("model", ["vgg16", "alexnet", "zf", "yolo"])
def test_optimal_no_worse_than_paper(model):
    layers = _layers(model)
    a_paper = allocate_compute(layers, THETA, objective="paper")
    a_opt = allocate_compute(layers, THETA, objective="optimal")
    assert T.frame_cycles(a_opt) <= T.frame_cycles(a_paper) * (1 + 1e-9)


def test_table1_reproduction_band():
    """Our allocator must land in the paper's efficiency band (Table I).

    The paper's own numbers are derived from its 8-bit (2 MAC/DSP)
    configuration; see EXPERIMENTS.md §Paper for the full comparison."""
    paper_eff = {"vgg16": 0.980, "alexnet": 0.904, "zf": 0.908,
                 "yolo": 0.984}
    for model, fn in W.CNN_MODELS.items():
        layers = fn().layer_workloads(weight_bits=8)
        allocs = allocate_compute(layers, 2 * THETA - len(layers))
        eff = T.dsp_efficiency(allocs, macs_per_dsp=2)
        assert eff > 0.90, (model, eff)
        assert eff <= 1.0 + 1e-9
        # at worst a few points under the paper's figure (we beat it on
        # AlexNet/ZF thanks to the waterfill allocator; YOLO's quoted 98.4%
        # exceeds the theta-sum feasibility bound we derive in
        # EXPERIMENTS.md §Paper, so a ~5pt gap there is expected)
        assert eff >= paper_eff[model] - 0.05, (model, eff)


def test_model_complexity_matches_paper():
    paper_gop = {"vgg16": 30.94, "alexnet": 1.45, "zf": 2.34, "yolo": 40.14}
    for model, fn in W.CNN_MODELS.items():
        gop = fn().gop
        assert abs(gop - paper_gop[model]) / paper_gop[model] < 0.02, \
            (model, gop)


def test_alg2_bandwidth_monotone():
    layers = _layers("vgg16")
    allocs = allocate_compute(layers, THETA)
    base_traffic = sum(a.layer.weight_bytes * math.ceil(a.layer.H / a.K)
                       for a in allocs if a.layer.kind == "conv")
    allocate_buffers(allocs, bram_total=545 * 2, bandwidth_bytes=1e9,
                     freq_hz=200e6)
    after = sum(a.layer.weight_bytes * math.ceil(a.layer.H / a.K)
                for a in allocs if a.layer.kind == "conv")
    assert after <= base_traffic
    assert all(a.K >= 1 for a in allocs)
    assert total_bram(allocs) <= 545 * 2 + 64  # within budget (+1 layer pad)


@st.composite
def layer_lists(draw):
    n = draw(st.integers(2, 8))
    out = []
    for i in range(n):
        r = draw(st.sampled_from([1, 3, 5, 7]))
        c = draw(st.integers(1, 64))
        m = draw(st.integers(1, 64))
        h = draw(st.sampled_from([7, 14, 28, 56]))
        out.append(LayerWorkload(
            name=f"l{i}", macs=h * h * r * r * c * m,
            weight_bytes=r * r * c * m * 2, act_in_bytes=h * h * c,
            act_out_bytes=h * h * m, kind="conv", R=r, S=r, stride=1,
            C=c, M=m, H=h, W=h))
    return out


def _fixed_layer_lists():
    """Deterministic stand-ins for the hypothesis strategy: a few hand-picked
    CNNs hitting primes, kernel-size mixes, and tiny channel counts."""
    def mk(i, r, c, m, h):
        return LayerWorkload(
            name=f"l{i}", macs=h * h * r * r * c * m,
            weight_bytes=r * r * c * m * 2, act_in_bytes=h * h * c,
            act_out_bytes=h * h * m, kind="conv", R=r, S=r, stride=1,
            C=c, M=m, H=h, W=h)
    return [
        [mk(0, 3, 3, 64, 56), mk(1, 1, 64, 7, 56)],
        [mk(0, 5, 17, 23, 28), mk(1, 3, 23, 64, 28), mk(2, 7, 64, 1, 14)],
        [mk(0, 1, 1, 1, 7), mk(1, 3, 1, 2, 7), mk(2, 5, 2, 3, 7)],
        [mk(i, [1, 3, 5, 7][i % 4], 8 * (i + 1), 8 * (8 - i), 14)
         for i in range(8)],
    ]


@given(layer_lists(), st.integers(64, 2048))
@settings(max_examples=30, deadline=None)
def test_alg1_property(layers, theta):
    allocs = allocate_compute(layers, theta)
    used = sum(a.theta for a in allocs)
    assert used <= max(theta, sum(l.R * l.S for l in layers))
    for a in allocs:
        assert a.theta % (a.layer.R * a.layer.S) == 0
        assert 1 <= a.Cp <= a.layer.C
        assert 1 <= a.Mp <= a.layer.M


@pytest.mark.parametrize("theta", [64, 311, 900, 2048])
def test_alg1_fixed_cases(theta):
    """Deterministic fallback for test_alg1_property."""
    for layers in _fixed_layer_lists():
        allocs = allocate_compute(layers, theta)
        used = sum(a.theta for a in allocs)
        assert used <= max(theta, sum(l.R * l.S for l in layers))
        for a in allocs:
            assert a.theta % (a.layer.R * a.layer.S) == 0
            assert 1 <= a.Cp <= a.layer.C
            assert 1 <= a.Mp <= a.layer.M


@given(st.lists(st.floats(0.1, 100.0), min_size=1, max_size=10),
       st.integers(1, 5))
@settings(max_examples=50, deadline=None)
def test_partition_optimal(weights, k):
    k = min(k, len(weights))
    bounds, cost = _partition_min_max(weights, k)
    assert bounds[0] == 0 and bounds[-1] == len(weights)
    assert len(bounds) == k + 1
    # verify cost matches the returned boundaries
    got = max(sum(weights[bounds[i]:bounds[i + 1]]) for i in range(k))
    assert abs(got - cost) < 1e-6 * max(1.0, cost)
    # brute force on small instances
    if len(weights) <= 7:
        import itertools
        best = float("inf")
        n = len(weights)
        for cuts in itertools.combinations(range(1, n), k - 1):
            bs = [0, *cuts, n]
            best = min(best, max(sum(weights[bs[i]:bs[i + 1]])
                                 for i in range(k)))
        assert cost <= best + 1e-6


def _brute_min_max(weights, k):
    """Exhaustive minimum of the max stage sum over ALL contiguous
    k-compositions of ``weights`` (every part non-empty)."""
    import itertools
    n = len(weights)
    best = float("inf")
    for cuts in itertools.combinations(range(1, n), k - 1):
        bs = [0, *cuts, n]
        best = min(best, max(sum(weights[bs[i]:bs[i + 1]])
                             for i in range(k)))
    return best


def _check_partition_exact(weights, k):
    """The serving-partition contract on the DP: boundaries are a
    strictly increasing contiguous cover of [0, n], the returned cost is
    the max stage sum of those boundaries, and that cost is *optimal* —
    equal to the brute force over all compositions. This pins
    Algorithm 1's balance objective independently of the allocator and
    of ``repro.serving.partition`` (both consume this one DP)."""
    bounds, cost = _partition_min_max(weights, k)
    assert len(bounds) == k + 1
    assert bounds[0] == 0 and bounds[-1] == len(weights)
    assert all(b < e for b, e in zip(bounds, bounds[1:]))  # contiguous,
    # non-empty stages; together with the 0..n endpoints: exhaustive.
    got = max(sum(weights[bounds[i]:bounds[i + 1]]) for i in range(k))
    assert got == pytest.approx(cost, rel=1e-9, abs=1e-9)
    assert cost == pytest.approx(_brute_min_max(weights, k),
                                 rel=1e-9, abs=1e-9)


@given(st.lists(st.floats(0.0, 100.0), min_size=1, max_size=12),
       st.integers(1, 12))
@settings(max_examples=60, deadline=None)
def test_partition_min_max_property(weights, k):
    """Random weight vectors (n <= 12, zeros included — pool steps weigh
    nothing), K <= n: the DP's cost matches brute force exactly."""
    _check_partition_exact(weights, min(k, len(weights)))


def test_partition_min_max_fixed_cases():
    """Deterministic fallback for test_partition_min_max_property: a
    seeded sweep over sizes, stage counts, and zero-weight densities."""
    import numpy as np
    rng = np.random.default_rng(20260730)
    for n in (1, 2, 3, 5, 8, 12):
        for zero_frac in (0.0, 0.3):
            w = rng.uniform(0.1, 100.0, size=n)
            w[rng.uniform(size=n) < zero_frac] = 0.0
            for k in sorted(k for k in {1, 2, max(1, n // 2), n}
                            if k <= n):
                _check_partition_exact(list(w), k)
    # Adversarial hand cases: equal weights, one dominant, all zero.
    _check_partition_exact([5.0] * 6, 3)
    _check_partition_exact([1.0, 1.0, 100.0, 1.0, 1.0], 2)
    _check_partition_exact([0.0, 0.0, 0.0], 2)


def test_plan_pipeline_basic():
    from repro.configs import ARCHS
    from repro.core.workload import lm_layer_workloads
    cfg = ARCHS["qwen2-72b"]
    layers = lm_layer_workloads(cfg, seq_len=4096, batch=256, mode="train")
    plan = plan_pipeline(layers, model_axis=16, data_axis=16,
                         global_batch=256, seq_len=4096, train=True,
                         d_model=cfg.d_model)
    assert plan.n_stages * plan.tensor_parallel == 16
    assert plan.utilization > 0.2
    assert plan.mem_per_chip < 16e9
    assert sum(plan.layers_per_stage) == len(layers)


@pytest.mark.parametrize("cycle_model", ["packed", "ceil"])
def test_decompose_theta_in_bounds(cycle_model):
    """Regression: the clamp fallback must never exceed (C, M) or the PE
    budget, including non-divisor budgets and theta_pe > C*M."""
    for C in (1, 2, 3, 5, 8, 13, 64):
        for M in (1, 2, 3, 7, 16, 64):
            for t in (1, 2, 3, 5, 7, 11, 63, 64, 100, C * M, C * M + 17):
                cp, mp = _decompose_theta(t, C, M, cycle_model=cycle_model)
                assert 1 <= cp <= C, (C, M, t, cp, mp)
                assert 1 <= mp <= M, (C, M, t, cp, mp)
                assert cp * mp <= max(t, 1), (C, M, t, cp, mp)
                if t >= C * M:
                    # full parallelism must be reached exactly
                    assert (cp, mp) == (C, M)


def test_engine_cycles_monotone():
    l = LayerWorkload(name="x", macs=56 * 56 * 9 * 64 * 128,
                      weight_bytes=9 * 64 * 128 * 2, act_in_bytes=0,
                      act_out_bytes=0, kind="conv", R=3, S=3, C=64, M=128,
                      H=56, W=56)
    prev = None
    for theta in range(9, 9 * 40, 9):
        c = engine_cycles(l, theta)
        if prev is not None:
            assert c <= prev + 1e-9
        prev = c


def test_stage_stack_nonuniform_boundaries():
    """The pipeline's stage stacking honors Algorithm-1 boundaries."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core.pipeline import stage_stack

    units = {"w": jnp.arange(7.0)[:, None] * jnp.ones((7, 3))}
    stacked, mask = stage_stack(units, (0, 3, 4, 7))
    assert stacked["w"].shape == (3, 3, 3)
    assert np.asarray(mask).tolist() == [
        [True, True, True], [True, False, False], [True, True, True]]
    # stage 1 holds only unit 3
    np.testing.assert_array_equal(np.asarray(stacked["w"][1, 0, :]),
                                  np.full(3, 3.0))


def test_collective_bytes_parser():
    from repro.launch import hlo_stats as DR
    hlo = """
  %p0 = bf16[16,1024]{1,0} parameter(0)
  %ar = bf16[16,1024]{1,0} all-reduce(%p0), replica_groups={}
  %ag = bf16[32,1024]{1,0} all-gather(%p0), dimensions={0}
  %cp = bf16[16,1024]{1,0} collective-permute(%p0), source_target_pairs={{0,1}}
"""
    got = DR.collective_bytes(hlo)
    assert got["count_per_kind"] == {"all-reduce": 1, "all-gather": 1,
                                     "collective-permute": 1}
    assert got["bytes_per_kind"]["all-reduce"] == 16 * 1024 * 2
    assert got["bytes_per_kind"]["all-gather"] == 16 * 1024 * 2  # operand


def test_workload_model_matches_real_param_counts():
    """The allocator's per-layer weight model must track the executable
    models within 6% — drift here silently mis-balances the pipeline."""
    from repro.configs import ARCHS
    from repro.core.workload import lm_layer_workloads
    from repro.models.transformer import param_count
    for name, cfg in ARCHS.items():
        lw = lm_layer_workloads(cfg, seq_len=4096, batch=256, mode="train")
        wb = sum(l.weight_bytes for l in lw) / 2
        pc = param_count(cfg)
        assert abs(wb / pc - 1) < 0.06, (name, wb / pc)


@pytest.mark.parametrize("k", [2, 4])
def test_partition_fixed_cases(k):
    """Deterministic fallback for test_partition_optimal."""
    import itertools
    weights = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0]
    bounds, cost = _partition_min_max(weights, k)
    assert bounds[0] == 0 and bounds[-1] == len(weights)
    got = max(sum(weights[bounds[i]:bounds[i + 1]]) for i in range(k))
    assert abs(got - cost) < 1e-9
    n = len(weights)
    best = min(max(sum(weights[bs[i]:bs[i + 1]]) for i in range(k))
               for cuts in itertools.combinations(range(1, n), k - 1)
               for bs in [[0, *cuts, n]])
    assert cost <= best + 1e-9


@pytest.mark.parametrize("bram,bandwidth", [(300, 5e8), (1090, 4.2e9)])
def test_alg2_fixed_cases(bram, bandwidth):
    """Deterministic fallback for test_alg2_property."""
    for layers in _fixed_layer_lists():
        allocs = allocate_compute(layers, 512)
        base = sum(a.layer.weight_bytes * math.ceil(a.layer.H / a.K)
                   for a in allocs if a.layer.kind == "conv")
        allocate_buffers(allocs, bram_total=bram, bandwidth_bytes=bandwidth,
                         freq_hz=200e6)
        after = sum(a.layer.weight_bytes * math.ceil(a.layer.H / a.K)
                    for a in allocs if a.layer.kind == "conv")
        assert after <= base
        assert all(a.K >= 1 for a in allocs)


def test_alg2_weight_bram_matches_paper_utilization():
    """Weight-buffer BRAM + residency (the Table I BRAM-column model):
    with weight buffers charged against the ZC706 budget, every paper
    model is board-feasible and the modeled totals regress against the
    paper's reported utilization (our structural model lands within 25
    points of the synthesized design; exact totals pinned to catch
    drift)."""
    from repro.core.allocator import weight_bram_for_layer
    paper_frac = {"vgg16": 0.74, "alexnet": 0.84, "zf": 0.58, "yolo": 0.76}
    pinned = {"vgg16": 1013, "alexnet": 847, "zf": 787, "yolo": 1090}
    for model in W.CNN_MODELS:
        allocs = allocate_compute(_layers(model), THETA)
        allocate_buffers(allocs, bram_total=1090, bandwidth_bytes=4.2e9,
                         freq_hz=200e6, act_bytes=2, weights=True)
        total = total_bram(allocs, act_bytes=2, weights=True)
        act_only = total_bram(allocs, act_bytes=2)
        assert total <= 1090, (model, total)                 # alpha holds
        assert total == pinned[model], (model, total)        # drift guard
        assert abs(total / 1090 - paper_frac[model]) <= 0.25, (model, total)
        # the weight side exists and decomposes consistently
        wt = sum(weight_bram_for_layer(a, 2) for a in allocs)
        assert total == act_only + wt
        assert wt > 0
        # residency only ever pins conv engines, and pinning is what
        # collapses omega_i to a single per-frame load
        for a in allocs:
            if a.weights_resident:
                assert a.layer.kind == "conv"
                from repro.core.allocator import weight_traffic_per_frame
                assert weight_traffic_per_frame(a) == a.layer.weight_bytes


def test_alg2_strict_flags_infeasible_baseline():
    """A budget the mandatory K=1 buffers cannot fit is returned
    best-effort by default (the paper assumes alpha covers them) but
    raises under strict=True — no silently over-budget plan."""
    layers = _layers("vgg16")
    allocs = allocate_compute(layers, THETA)
    allocate_buffers(allocs, bram_total=300, bandwidth_bytes=4.2e9,
                     freq_hz=200e6, act_bytes=2, weights=True)
    assert total_bram(allocs, act_bytes=2, weights=True) > 300  # best effort
    allocs = allocate_compute(layers, THETA)
    with pytest.raises(ValueError):
        allocate_buffers(allocs, bram_total=300, bandwidth_bytes=4.2e9,
                         freq_hz=200e6, act_bytes=2, weights=True,
                         strict=True)


def test_alg2_weight_phase_never_raises_traffic():
    """The residency phase may only lower DDR demand, and disabling it
    (weights=False) reproduces the seed act-only behavior bit for bit."""
    from repro.core.allocator import weight_traffic_per_frame
    layers = _layers("alexnet")
    base = allocate_compute(layers, THETA)
    allocate_buffers(base, bram_total=1090, bandwidth_bytes=4.2e9,
                     freq_hz=200e6, act_bytes=2)
    with_w = allocate_compute(layers, THETA)
    allocate_buffers(with_w, bram_total=1090, bandwidth_bytes=4.2e9,
                     freq_hz=200e6, act_bytes=2, weights=True)
    t_base = sum(weight_traffic_per_frame(a) for a in base
                 if a.layer.kind == "conv")
    t_w = sum(weight_traffic_per_frame(a) for a in with_w
              if a.layer.kind == "conv")
    assert t_w <= t_base
    assert all(not a.weights_resident for a in base)


@given(layer_lists(), st.integers(200, 2000), st.floats(1e8, 1e10))
@settings(max_examples=15, deadline=None)
def test_alg2_property(layers, bram, bandwidth):
    """Algorithm 2 invariants on random CNNs: K>=1 everywhere, BRAM within
    budget (one quantum of slack), bandwidth demand never increased."""
    allocs = allocate_compute(layers, 512)
    base = sum(a.layer.weight_bytes * math.ceil(a.layer.H / a.K)
               for a in allocs if a.layer.kind == "conv")
    allocate_buffers(allocs, bram_total=bram, bandwidth_bytes=bandwidth,
                     freq_hz=200e6)
    after = sum(a.layer.weight_bytes * math.ceil(a.layer.H / a.K)
                for a in allocs if a.layer.kind == "conv")
    assert after <= base
    assert all(a.K >= 1 for a in allocs)


@given(st.lists(st.tuples(
    st.sampled_from(["all-reduce", "all-gather", "collective-permute",
                     "reduce-scatter", "all-to-all"]),
    st.sampled_from(["f32", "bf16", "s8"]),
    st.integers(1, 64), st.integers(1, 2048)), min_size=0, max_size=8))
@settings(max_examples=25, deadline=None)
def test_hlo_parser_fuzz(ops):
    """The collective parser totals synthetic HLO exactly."""
    from repro.launch import hlo_stats
    bytes_per = {"f32": 4, "bf16": 2, "s8": 1}
    lines, want = [], 0
    for i, (kind, dt, a, b) in enumerate(ops):
        lines.append(f"  %p{i} = {dt}[{a},{b}]{{1,0}} parameter({i})")
        lines.append(f"  %c{i} = {dt}[{a},{b}]{{1,0}} {kind}(%p{i}), "
                     f"replica_groups={{}}")
        want += a * b * bytes_per[dt]
    got = hlo_stats.collective_bytes("\n".join(lines))
    assert got["total_bytes"] == want
