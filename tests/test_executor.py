"""Jitted batched executor: bit-identity with the eager per-sample path
(all routes), streaming micro-batch semantics, recompile/donation guards,
and the YOLO/ZF golden int8 outputs."""

import importlib.util
import os
import zlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import workload as W
from repro.core.executor import EngineExecutor
from repro.core.program import compile_model
from repro.models import cnn

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")


def _tiny():
    """Small graph exercising every step kind: strided conv stem, pool,
    grouped conv, fc head."""
    m = W.CNNModel("tiny", 16, 4, (
        W.ConvLayer("c1", 4, 8, 3),
        W.ConvLayer("p1", 8, 8, 2, stride=2, kind="pool"),
        W.ConvLayer("c2", 8, 8, 3, groups=2),
        W.ConvLayer("fc", 8 * 8 * 8, 10, 1, kind="fc"),
    ))
    p = cnn.init_params(m, jax.random.PRNGKey(0))
    calib = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16, 4))
    prog = compile_model(m, p, bits=8, calib_batch=calib)
    frames = np.asarray(jax.random.normal(jax.random.PRNGKey(2),
                                          (11, 16, 16, 4)), np.float32)
    return prog, frames


def _eager(prog, frames, **kw):
    return np.concatenate([np.asarray(prog.run(frames[i:i + 1], **kw))
                           for i in range(len(frames))])


@pytest.mark.parametrize("route", ["f32", "oracle", "kernel"])
def test_runner_routes_bit_identical_to_eager(route):
    """One jitted chain == the eager per-step loop, for every MAC
    lowering (exact-f32 chunked conv, int32 oracle, Pallas kernel)."""
    prog, frames = _tiny()
    want = _eager(prog, frames)
    runner = prog.compile_runner(route=route)
    got = runner.logits(frames)
    np.testing.assert_array_equal(got, want)
    assert runner.cache_size() == 1


def test_executor_stream_matches_eager():
    """submit/drain over a non-multiple frame count: order preserved,
    padding dropped, outputs bit-identical, stats consistent."""
    prog, frames = _tiny()
    want = _eager(prog, frames)
    ex = EngineExecutor(prog, batch_size=4, output="logits")
    got = np.stack(ex.serve(list(frames)))
    np.testing.assert_array_equal(got, want)
    assert ex.stats.frames == 11
    assert ex.stats.batches == 3
    assert ex.stats.padded_frames == 1
    ids = EngineExecutor(prog, batch_size=4).serve(list(frames))
    np.testing.assert_array_equal(
        np.asarray(ids), np.argmax(want.reshape(len(frames), -1), -1))


def test_executor_never_recompiles():
    """Tail padding keeps the batch shape fixed: one XLA executable no
    matter how many (partial) micro-batches stream through."""
    prog, frames = _tiny()
    ex = EngineExecutor(prog, batch_size=4)
    ex.serve(list(frames))          # 2 full batches + padded tail
    ex.submit(frames[:3])           # reuse across drains, partial again
    ex.drain()
    assert ex.runner.cache_size() == 1


def test_donated_runner_still_correct():
    """Forcing donation must not change results (CPU ignores the donation
    with a warning; on TPU the int8 buffer is actually reused)."""
    prog, frames = _tiny()
    want = _eager(prog, frames[:4])
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")  # "donated buffers were not usable"
        runner = prog.compile_runner(route="f32", donate=True)
        got = runner.logits(frames[:4])
        got2 = runner.logits(frames[:4])
    np.testing.assert_array_equal(got, want)
    np.testing.assert_array_equal(got2, want)
    assert runner.cache_size() == 1


def test_kernel_route_checked_up_front():
    """A kernel request that cannot run raises at compile/jit time — no
    silent per-step fallback to the oracle."""
    m = W.CNNModel("tiny16", 8, 3, (W.ConvLayer("c1", 3, 4, 3),))
    p = cnn.init_params(m, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 8, 3))
    prog = compile_model(m, p, bits=16, calib_batch=x)
    with pytest.raises(NotImplementedError):
        prog.compile_runner(route="kernel")
    with pytest.raises(NotImplementedError):
        prog.run(x, use_kernel=True)
    with pytest.raises(NotImplementedError):
        cnn.forward(p, m, x, quantized=True, bits=16, use_kernel=True)
    with pytest.raises(NotImplementedError):
        prog.compile_runner(route="f32")   # exact-f32 needs int8 products
    assert prog.compile_runner().route == "oracle"


def test_f32_route_refuses_oversized_kernel():
    """The exact-f32 proof needs R*S <= 1024 per chunk; a >32x32 kernel
    must be refused at compile time, not silently lose bits."""
    m = W.CNNModel("bigk", 40, 1, (W.ConvLayer("c1", 1, 2, 33),))
    p = cnn.init_params(m, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 40, 40, 1))
    prog = compile_model(m, p, bits=8, calib_batch=x)
    with pytest.raises(NotImplementedError):
        prog.compile_runner(route="f32")
    got = prog.compile_runner(route="oracle").logits(np.asarray(x))
    np.testing.assert_array_equal(got, np.asarray(prog.run(x)))


def test_stats_exclude_idle_between_drains():
    """wall_s accumulates active serving windows only — host idle between
    a drain and the next submit must not dilute steady_fps."""
    import time
    prog, frames = _tiny()
    ex = EngineExecutor(prog, batch_size=4)
    ex.serve(list(frames[:4]))
    w1 = ex.stats.wall_s
    time.sleep(0.25)
    t0 = time.perf_counter()
    ex.serve(list(frames[4:8]))
    window = time.perf_counter() - t0
    assert ex.stats.frames == 8
    # The recorded wall time may grow by at most the measured active
    # serve window — never by the idle sleep before it. Bounding against
    # the measurement (not a fixed constant) keeps this stable on slow
    # CI runners.
    assert ex.stats.wall_s - w1 <= window + 0.05


def test_plan_only_program_cannot_build_runner():
    prog = compile_model(W.CNN_MODELS["alexnet"](), theta=900, bits=8)
    with pytest.raises(ValueError):
        prog.compile_runner()


@pytest.mark.slow
@pytest.mark.parametrize("model", ["alexnet", "vgg16"])
def test_batched_matches_eager_paper_models(model):
    """Batched jitted runner == eager per-sample loop on the real paper
    models (f32 route; AlexNet additionally pins the kernel route)."""
    m = W.CNN_MODELS[model]()
    p = cnn.init_params(m, jax.random.PRNGKey(0))
    calib = jax.random.normal(jax.random.PRNGKey(1),
                              (1, m.input_hw, m.input_hw, m.input_ch))
    prog = compile_model(m, p, bits=8, calib_batch=calib)
    frames = np.asarray(jax.random.normal(
        jax.random.PRNGKey(2), (2, m.input_hw, m.input_hw, m.input_ch)),
        np.float32)
    want = _eager(prog, frames)
    got = prog.compile_runner(route="f32").logits(frames)
    np.testing.assert_array_equal(got, want)
    if model == "alexnet":
        got_k = prog.compile_runner(route="kernel").logits(frames)
        np.testing.assert_array_equal(got_k, want)


@pytest.mark.slow
@pytest.mark.parametrize("model", ["zf", "yolo"])
def test_golden_int8_program(model):
    """YOLO and ZF bit-exact against checked-in goldens (ROADMAP item):
    raw int32 accumulators (sample + crc of the full buffer), top-1 ids,
    and the frozen exponent schedule; frame 0 cross-checked against the
    eager oracle."""
    spec = importlib.util.spec_from_file_location(
        "golden_generate", os.path.join(GOLDEN_DIR, "generate.py"))
    gen = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(gen)
    got = gen.golden_for(model)
    want = np.load(os.path.join(GOLDEN_DIR, f"{model}.npz"))
    np.testing.assert_array_equal(got["e_out"], want["e_out"])
    assert int(got["e_input"]) == int(want["e_input"])
    np.testing.assert_array_equal(got["acc_sample"], want["acc_sample"])
    np.testing.assert_array_equal(got["top1"], want["top1"])
    assert int(got["acc_crc"]) == int(want["acc_crc"])
    # and the jitted batched path == the eager oracle on the same program
    m = W.CNN_MODELS[model]()
    p = cnn.init_params(m, jax.random.PRNGKey(0))
    calib = jax.random.normal(jax.random.PRNGKey(1),
                              (1, m.input_hw, m.input_hw, m.input_ch))
    prog = compile_model(m, p, bits=8, calib_batch=calib)
    frame = np.asarray(jax.random.normal(
        jax.random.PRNGKey(2), (2, m.input_hw, m.input_hw, m.input_ch)),
        np.float32)[:1]
    y_eager = np.asarray(prog.run(frame))
    runner = prog.compile_runner(route="f32")
    acc0 = np.asarray(runner(runner.quantize(frame)))
    np.testing.assert_array_equal(runner.dequantize(acc0), y_eager)
    crc_full = zlib.crc32(np.ascontiguousarray(acc0).tobytes())
    assert acc0.dtype == np.int32 and crc_full != 0


def test_quantize_np_twin_bit_identical():
    """Host-side numpy quantize == the jnp compile-time quantize,
    including round-half-to-even ties and rail clipping."""
    from repro.core import quant
    rng = np.random.default_rng(0)
    x = rng.standard_normal((3, 7, 7, 5)).astype(np.float32) * 40
    x.reshape(-1)[:8] = [0.5, 1.5, 2.5, -0.5, -1.5, 300.0, -300.0, 0.0]
    for e in (-3, 0, 2):
        for bits in (8, 16):
            a = np.asarray(quant.quantize_to_exponent(jnp.asarray(x), e,
                                                      bits))
            b = quant.quantize_to_exponent_np(x, e, bits)
            np.testing.assert_array_equal(a, b)
            assert b.dtype == (np.int8 if bits == 8 else np.int16)
