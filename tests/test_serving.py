"""Stage-pipelined serving subsystem: partition invariants, K-stage
bit-identity with the single-jit ``compile_runner`` chain (the acceptance
bar — including a stage boundary landing mid-conv-block and the K=1
degenerate case), thread-safe multi-producer execution, and the async
frontend's edge cases (empty stream, single frame, flush-by-timeout,
backpressure)."""

import dataclasses
import threading
import time

import jax
import numpy as np
import pytest

from repro.core import workload as W
from repro.core.executor import EngineExecutor
from repro.core.program import compile_model
from repro.models import cnn
from repro.serving import (AsyncFrontend, PipelineExecutor,
                           partition_program, stage_devices, step_cycles)


def _tiny():
    """Small graph exercising every step kind: conv stem, pool, grouped
    conv, fc head (same shape as tests/test_executor.py's)."""
    m = W.CNNModel("tiny", 16, 4, (
        W.ConvLayer("c1", 4, 8, 3),
        W.ConvLayer("p1", 8, 8, 2, stride=2, kind="pool"),
        W.ConvLayer("c2", 8, 8, 3, groups=2),
        W.ConvLayer("fc", 8 * 8 * 8, 10, 1, kind="fc"),
    ))
    p = cnn.init_params(m, jax.random.PRNGKey(0))
    calib = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16, 4))
    prog = compile_model(m, p, bits=8, calib_batch=calib)
    frames = np.asarray(jax.random.normal(jax.random.PRNGKey(2),
                                          (11, 16, 16, 4)), np.float32)
    return prog, frames


def _two_block():
    """Two conv *blocks* (conv-conv-pool twice) so a cut can land
    mid-block, between two convs that share a block."""
    m = W.CNNModel("twoblock", 16, 3, (
        W.ConvLayer("c1_1", 3, 8, 3),
        W.ConvLayer("c1_2", 8, 8, 3),
        W.ConvLayer("p1", 8, 8, 2, stride=2, kind="pool"),
        W.ConvLayer("c2_1", 8, 16, 3),
        W.ConvLayer("c2_2", 16, 16, 3),
        W.ConvLayer("p2", 16, 16, 2, stride=2, kind="pool"),
        W.ConvLayer("fc", 16 * 4 * 4, 10, 1, kind="fc"),
    ))
    p = cnn.init_params(m, jax.random.PRNGKey(3))
    calib = jax.random.normal(jax.random.PRNGKey(4), (2, 16, 16, 3))
    prog = compile_model(m, p, bits=8, calib_batch=calib)
    frames = np.asarray(jax.random.normal(jax.random.PRNGKey(5),
                                          (7, 16, 16, 3)), np.float32)
    return prog, frames


# ---------------------------------------------------------------------------
# Partition
# ---------------------------------------------------------------------------


def test_partition_invariants():
    """Contiguous cover, modeled cycles conserved, balance in (0, 1],
    bottleneck monotone non-increasing in K (more stages never model
    slower), pools never lead a stage."""
    prog, _ = _two_block()
    total = sum(step_cycles(prog.allocs).values())
    prev_bottleneck = float("inf")
    for k in range(1, 6):
        part = partition_program(prog, k)
        assert part.boundaries[0] == 0
        assert part.boundaries[-1] == len(prog.steps)
        assert list(part.boundaries) == sorted(set(part.boundaries))
        assert part.n_stages == k
        assert sum(part.stage_cycles) == pytest.approx(total)
        assert 0 < part.balance <= 1 + 1e-12
        assert part.bottleneck <= prev_bottleneck + 1e-9
        prev_bottleneck = part.bottleneck
        for b, e in part.stage_ranges()[1:]:
            assert prog.steps[b].kind != "pool"


def test_partition_rejects_bad_stage_counts():
    prog, _ = _tiny()
    with pytest.raises(ValueError):
        partition_program(prog, 0)
    with pytest.raises(ValueError):
        partition_program(prog, 4)  # only 3 compute steps
    plan_only = compile_model(W.CNN_MODELS["alexnet"](), theta=900, bits=8)
    with pytest.raises(ValueError):
        partition_program(plan_only, 2)


# ---------------------------------------------------------------------------
# Stage runners + pipelined bit-identity
# ---------------------------------------------------------------------------


def test_stage_runner_chain_bit_identical_all_routes():
    """Chaining compile_stage_runner ranges reproduces compile_runner
    exactly for every MAC lowering — int8 activations are the stage
    boundary contract."""
    prog, frames = _tiny()
    for route in ("f32", "oracle", "kernel"):
        full = prog.compile_runner(route=route)
        want = full.logits(frames[:4])
        first = prog.compile_stage_runner(0, 2, route=route)
        second = prog.compile_stage_runner(2, 4, route=route)
        mid = first(first.quantize(frames[:4]))
        assert np.asarray(mid).dtype == np.int8   # int8 across the cut
        got = second.dequantize(second(mid))
        np.testing.assert_array_equal(got, want)


def test_stage_runner_end_guards():
    """Host-side quantize/dequantize exist only at the matching chain
    ends; out-of-range stages are refused."""
    prog, frames = _tiny()
    inner = prog.compile_stage_runner(1, 3)
    with pytest.raises(ValueError):
        inner.quantize(frames[:1])
    with pytest.raises(ValueError):
        inner.dequantize(np.zeros((1, 10)))
    with pytest.raises(ValueError):
        prog.compile_stage_runner(2, 2)
    with pytest.raises(ValueError):
        prog.compile_stage_runner(0, 99)


@pytest.mark.parametrize("stages", [1, 2, 3])
def test_pipelined_bit_identical(stages):
    """K-stage pipelined serving == the single-jit chain, bit for bit,
    including the K=1 degenerate case and a padded tail batch."""
    prog, frames = _tiny()
    want = prog.compile_runner().logits(frames)
    with PipelineExecutor(prog, stages=stages, batch_size=4,
                          output="logits") as px:
        got = np.stack(px.serve(list(frames)))
    np.testing.assert_array_equal(got, want)
    assert px.stats.frames == len(frames)
    assert px.stats.padded_frames == 1
    # top1 path too
    with PipelineExecutor(prog, stages=stages, batch_size=4) as px:
        ids = px.serve(list(frames))
    np.testing.assert_array_equal(
        np.asarray(ids), np.argmax(want.reshape(len(frames), -1), -1))


def test_pipelined_mid_block_boundary_bit_identical():
    """A stage cut landing *inside* a conv block (between two convs that
    share a block, and one where a pool leads the next stage) stays
    bit-identical — the boundary contract is any step edge."""
    prog, frames = _two_block()
    want = prog.compile_runner().logits(frames)
    n = len(prog.steps)
    for bounds in [(0, 2, n),      # cut after c1_2 (mid-structure)
                   (0, 1, n),      # cut between c1_1 and c1_2: mid-block
                   (0, 4, n),      # cut between c2_1 and c2_2: mid-block
                   (0, 1, 4, n)]:  # both mid-block cuts at once
        with PipelineExecutor(prog, stages=len(bounds) - 1, batch_size=4,
                              boundaries=bounds, output="logits") as px:
            got = np.stack(px.serve(list(frames)))
        np.testing.assert_array_equal(got, want, err_msg=str(bounds))


def test_stage_devices_round_robin():
    """Placement policy: stage i -> devices[i % n], default jax.devices(),
    bad inputs refused."""
    devs = jax.devices()
    assert stage_devices(3) == [devs[i % len(devs)] for i in range(3)]
    fake = ["d0", "d1"]
    assert stage_devices(5, fake) == ["d0", "d1", "d0", "d1", "d0"]
    with pytest.raises(ValueError):
        stage_devices(0)
    with pytest.raises(ValueError):
        stage_devices(2, [])


@pytest.mark.parametrize("route", ["f32", "oracle", "kernel"])
def test_placed_stage_runners_bit_identical_all_routes(route):
    """--place-stages determinism: with every stage pinned to a device
    (all the same one on single-device CPU), K in {1, 2, 4} placed
    pipelines stay bit-identical to the monolithic compile_runner on
    every MAC route — placement moves buffers, never arithmetic."""
    prog, frames = _two_block()
    want = prog.compile_runner(route=route).logits(frames)
    for k in (1, 2, 4):
        with PipelineExecutor(prog, stages=k, batch_size=4, route=route,
                              place_stages=True, output="logits") as px:
            got = np.stack(px.serve(list(frames)))
        np.testing.assert_array_equal(got, want, err_msg=f"K={k}")
        assert len(px.stage_devices) == k
        assert all(d is not None for d in px.stage_devices)


def test_placed_runner_device_pin_single_runner():
    """compile_stage_runner(device=...) routes execution through the
    pinned device and stays bit-identical to the unpinned runner."""
    prog, frames = _tiny()
    dev = jax.devices()[0]
    pinned = prog.compile_stage_runner(0, len(prog.steps), device=dev)
    plain = prog.compile_runner()
    np.testing.assert_array_equal(pinned.logits(frames), plain.logits(frames))
    out = pinned(pinned.quantize(frames[:4]))
    assert next(iter(out.devices())) == dev


def test_pipeline_reuse_across_drains():
    """Workers survive drain(); a second stream through the same
    pipeline stays correct and never recompiles (fixed batch shape)."""
    prog, frames = _tiny()
    want = prog.compile_runner().logits(frames)
    with PipelineExecutor(prog, stages=2, batch_size=4,
                          output="logits") as px:
        got1 = np.stack(px.serve(list(frames)))
        got2 = np.stack(px.serve(list(frames[:5])))
        assert all(r.cache_size() in (1, -1) for r in px.runners)
    np.testing.assert_array_equal(got1, want)
    np.testing.assert_array_equal(got2, want[:5])


def test_pipeline_rejects_bad_boundaries():
    prog, _ = _tiny()
    with pytest.raises(ValueError):
        PipelineExecutor(prog, stages=2, boundaries=(0, 4))       # wrong len
    with pytest.raises(ValueError):
        PipelineExecutor(prog, stages=2, boundaries=(1, 2, 4))    # no 0
    with pytest.raises(ValueError):
        PipelineExecutor(prog, stages=2, boundaries=(0, 2, 3))    # short


@pytest.mark.slow
@pytest.mark.parametrize("model,stages", [
    ("alexnet", 2), ("alexnet", 4), ("vgg16", 2), ("zf", 2), ("yolo", 2),
])
def test_pipelined_paper_models_bit_identical(model, stages):
    """The acceptance bar: K-stage pipelined output == compile_runner on
    all four paper CNNs (f32 route, int8 golden comparison on the raw
    logits)."""
    m = W.CNN_MODELS[model]()
    p = cnn.init_params(m, jax.random.PRNGKey(0))
    calib = jax.random.normal(jax.random.PRNGKey(1),
                              (1, m.input_hw, m.input_hw, m.input_ch))
    prog = compile_model(m, p, bits=8, calib_batch=calib)
    frames = np.asarray(jax.random.normal(
        jax.random.PRNGKey(2), (3, m.input_hw, m.input_hw, m.input_ch)),
        np.float32)
    want = prog.compile_runner(route="f32").logits(frames)
    with PipelineExecutor(prog, stages=stages, batch_size=2, route="f32",
                          output="logits") as px:
        got = np.stack(px.serve(list(frames)))
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# Thread safety (the frontend's contract with EngineExecutor)
# ---------------------------------------------------------------------------


def _match_rows(got: np.ndarray, want: np.ndarray) -> None:
    """Every produced row must be exactly one expected row, each expected
    row consumed once (submission order across threads is arbitrary)."""
    assert got.shape == want.shape
    used = np.zeros(len(want), bool)
    for row in got:
        hit = np.nonzero((want == row).all(axis=1) & ~used)[0]
        assert hit.size > 0, "result row matches no unconsumed expectation"
        used[hit[0]] = True
    assert used.all()


def test_engine_executor_multi_producer_submit():
    """Concurrent submit() from several threads: no frame lost or
    corrupted through the shared pending buffer and tail padding."""
    prog, frames = _tiny()
    want = prog.compile_runner().logits(frames)
    ex = EngineExecutor(prog, batch_size=4, output="logits")
    chunks = [frames[0:3], frames[3:7], frames[7:11]]
    threads = [threading.Thread(target=ex.submit, args=(c,))
               for c in chunks]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    got = np.stack(ex.drain())
    _match_rows(got, want)
    assert ex.stats.frames == len(frames)


def test_frontend_over_engine_executor_multi_producer():
    """Many client threads -> AsyncFrontend -> thread-safe EngineExecutor:
    every request resolves to its own frame's exact logits."""
    prog, frames = _tiny()
    want = prog.compile_runner().logits(frames)
    ex = EngineExecutor(prog, batch_size=4, output="logits")
    fe = AsyncFrontend(ex, max_wait_ms=30.0)
    results = [None] * len(frames)

    def client(i):
        results[i] = fe.submit(frames[i]).result(timeout=120)

    threads = [threading.Thread(target=client, args=(i,))
               for i in range(len(frames))]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    fe.close()
    for i, r in enumerate(results):
        np.testing.assert_array_equal(np.asarray(r), want[i])
    assert fe.stats.completed == len(frames)
    assert not np.isnan(fe.stats.latency_percentiles()["p99"])


# ---------------------------------------------------------------------------
# Frontend edge cases
# ---------------------------------------------------------------------------


def test_frontend_empty_stream():
    """Close with zero submissions: no hang, clean stats, submit-after-
    close refused."""
    prog, _ = _tiny()
    with PipelineExecutor(prog, stages=2, batch_size=4) as px:
        fe = AsyncFrontend(px)
        fe.close()
        assert fe.stats.submitted == 0
        assert fe.stats.completed == 0
        assert fe.stats.fps == 0.0
        assert np.isnan(fe.stats.latency_percentiles()["p50"])
        with pytest.raises(RuntimeError):
            fe.submit(np.zeros((16, 16, 4), np.float32))


def test_frontend_single_frame_flush_by_timeout():
    """One lone frame must be answered after ~max_wait_ms, not parked
    waiting for a full batch."""
    prog, frames = _tiny()
    want = prog.compile_runner().logits(frames[:1])
    with PipelineExecutor(prog, stages=2, batch_size=4,
                          output="logits") as px:
        px.serve(list(frames[:4]))          # warm the stage jits
        fe = AsyncFrontend(px, max_wait_ms=10.0)
        req = fe.submit(frames[0])
        out = req.result(timeout=60)
        fe.close()
    np.testing.assert_array_equal(out, want[0])
    assert fe.stats.flushes_timeout == 1
    assert fe.stats.flushes_full == 0
    assert req.latency_s is not None and req.latency_s >= 0.010 * 0.5


def test_frontend_backpressure_bounded_queue():
    """A full submission queue blocks, and queue.Full surfaces when the
    caller's timeout expires (stub executor that never completes until
    released, so the test is deterministic)."""
    import queue as queue_mod

    release = threading.Event()

    class StallExecutor:
        batch_size = 2
        program = None
        on_result = None
        on_error = None

        def submit_batch(self, frames, n_valid, tag=None):
            release.wait(timeout=30)
            if self.on_result:
                self.on_result(tag, np.zeros((n_valid, 1)))

        def flush_inflight(self):
            pass

        def reset_stats(self):
            pass

        def replica_counts(self):
            return None

    ex = StallExecutor()
    fe = AsyncFrontend(ex, max_wait_ms=5.0, max_queue=2)
    f = np.zeros((4, 4, 1), np.float32)
    reqs = [fe.submit(f) for f in [f] * 2]      # first batch stalls
    time.sleep(0.05)                             # batcher picks them up
    reqs += [fe.submit(f) for f in [f] * 2]      # fills the queue
    with pytest.raises(queue_mod.Full):
        fe.submit(f, timeout=0.05)
    release.set()
    for r in reqs:
        r.result(timeout=30)
    fe.close()
    assert fe.stats.completed == fe.stats.submitted == 4


def test_frontend_resolves_requests_on_executor_failure():
    """A dispatch failure must resolve that batch's requests with the
    error (not kill the batcher silently): result() raises, close()
    converges, later submits still get answers."""
    class BrokenExecutor:
        batch_size = 2
        program = None
        on_result = None
        on_error = None

        def submit_batch(self, frames, n_valid, tag=None):
            raise RuntimeError("stage worker died")

        def flush_inflight(self):
            pass

        def reset_stats(self):
            pass

        def replica_counts(self):
            return None

    fe = AsyncFrontend(BrokenExecutor(), max_wait_ms=5.0)
    f = np.zeros((4, 4, 1), np.float32)
    reqs = [fe.submit(f) for _ in range(3)]
    for r in reqs:
        with pytest.raises(RuntimeError):
            r.result(timeout=30)
    fe.close()
    assert fe.stats.failed == 3
    assert fe.stats.completed == 0


def test_frontend_rejects_malformed_frame_at_submit():
    """A wrong-shape frame is refused at the client, before it can
    poison a micro-batch inside the batcher thread."""
    prog, frames = _tiny()
    with PipelineExecutor(prog, stages=1, batch_size=4) as px:
        fe = AsyncFrontend(px, max_wait_ms=10.0)
        with pytest.raises(ValueError):
            fe.submit(np.zeros((8, 8, 4), np.float32))
        req = fe.submit(frames[0])
        req.result(timeout=60)
        fe.close()
    assert fe.stats.completed == 1


def test_frontend_stage_failure_resolves_requests():
    """A stage worker dying mid-batch must deliver the error to that
    batch's requests through on_error — futures never hang."""
    prog, frames = _tiny()
    px = PipelineExecutor(prog, stages=2, batch_size=4)

    def boom(xq):
        raise RuntimeError("stage exploded")

    px.runners[0] = dataclasses.replace(px.runners[0], fn=boom)
    with px:
        fe = AsyncFrontend(px, max_wait_ms=5.0)
        req = fe.submit(frames[0])
        with pytest.raises(RuntimeError):
            req.result(timeout=60)
        fe.close()                      # converges: the request resolved
    assert fe.stats.failed == 1
    assert fe.stats.completed == 0


def test_frontend_rejects_busy_executor_until_closed():
    """A second frontend on a busy executor is refused; after close()
    the executor is released and reusable."""
    prog, frames = _tiny()
    with PipelineExecutor(prog, stages=1, batch_size=4,
                          output="logits") as px:
        fe = AsyncFrontend(px)
        with pytest.raises(ValueError):
            AsyncFrontend(px)           # on_result already consumed
        fe.close()
        fe2 = AsyncFrontend(px)         # released on close
        want = prog.compile_runner().logits(frames[:1])
        got = fe2.submit(frames[0]).result(timeout=120)
        fe2.close()
    np.testing.assert_array_equal(got, want[0])
