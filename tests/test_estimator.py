"""ServiceTimeEstimator: warm start, EWMA convergence, per-shape
isolation, and thread-safety under concurrent observe/estimate — the
properties the frontend's adaptive flush and admission control lean
on."""

import threading

import pytest

from repro.serving import ServiceTimeEstimator, window_key


def test_warm_start_channels_seeds_both_admission_channels():
    """One K>1 calibration throughput measurement seeds both channels:
    the busy-completion-window at the fleet batch window and the latency
    at stages x replicas x window — and real measurements still outrank
    the seed, channel by channel."""
    est = ServiceTimeEstimator()
    est.warm_start_channels(32, 0.040, stages=3, replicas=2)
    assert est.estimate(window_key(32)) == pytest.approx(0.040)
    assert est.estimate(32) == pytest.approx(3 * 2 * 0.040)
    # A measured latency outranks a later warm start on that channel
    # only; the never-observed window channel still accepts the seed.
    est.observe(32, 0.100)
    lat_after_obs = est.estimate(32)
    est.warm_start_channels(32, 0.010, stages=3, replicas=2)
    assert est.estimate(window_key(32)) == pytest.approx(0.010)
    assert est.estimate(32) == pytest.approx(lat_after_obs)
    # Degenerate K=1, R=1: both channels seed at the same window.
    est2 = ServiceTimeEstimator()
    est2.warm_start_channels(8, 0.020)
    assert est2.estimate(8) == pytest.approx(0.020)
    assert est2.estimate(window_key(8)) == pytest.approx(0.020)
    with pytest.raises(ValueError):
        est.warm_start_channels(32, 0.010, stages=0)
    with pytest.raises(ValueError):
        est.warm_start_channels(32, 0.010, replicas=0)
    with pytest.raises(ValueError):
        est.warm_start_channels(32, -1.0)


def test_empty_estimator_knows_nothing():
    est = ServiceTimeEstimator()
    assert est.estimate(32) is None
    assert est.n_observed(32) == 0
    assert est.snapshot() == {}


def test_warm_start_seeds_and_measurements_outrank_it():
    est = ServiceTimeEstimator()
    est.warm_start(32, 0.050)
    assert est.estimate(32) == pytest.approx(0.050)
    assert est.n_observed(32) == 0           # calibration != observation
    # A second warm start before any observation re-seeds (recalibration)
    est.warm_start(32, 0.040)
    assert est.estimate(32) == pytest.approx(0.040)
    # ...but once a real batch has been observed, warm_start is a no-op:
    # measurements outrank calibration.
    est.observe(32, 0.060)
    before = est.estimate(32)
    est.warm_start(32, 0.001)
    assert est.estimate(32) == pytest.approx(before)
    assert est.n_observed(32) == 1


def test_rejects_bad_inputs():
    with pytest.raises(ValueError):
        ServiceTimeEstimator(alpha=0.0)
    with pytest.raises(ValueError):
        ServiceTimeEstimator(alpha=1.5)
    est = ServiceTimeEstimator()
    with pytest.raises(ValueError):
        est.warm_start(32, 0.0)
    # Non-positive observations (clock skew) are dropped, not folded in.
    est.observe(32, -1.0)
    assert est.estimate(32) is None


def test_ewma_converges_and_tracks_a_shift():
    est = ServiceTimeEstimator(alpha=0.3)
    for _ in range(30):
        est.observe(8, 0.020)
    assert est.estimate(8) == pytest.approx(0.020, rel=1e-6)
    # The backend slows down 2x; the EWMA tracks it within ~10 batches.
    for _ in range(10):
        est.observe(8, 0.040)
    assert est.estimate(8) == pytest.approx(0.040, rel=0.05)
    # First observation initializes directly (no bias toward zero).
    fresh = ServiceTimeEstimator()
    fresh.observe(4, 0.123)
    assert fresh.estimate(4) == pytest.approx(0.123)


def test_shapes_are_isolated():
    est = ServiceTimeEstimator()
    est.warm_start(8, 0.010)
    for _ in range(5):
        est.observe(32, 0.050)
    assert est.estimate(8) == pytest.approx(0.010)
    assert est.estimate(32) == pytest.approx(0.050)
    assert est.estimate(16) is None
    assert est.n_observed(8) == 0 and est.n_observed(32) == 5
    snap = est.snapshot()
    assert snap["8"]["warm_started"] and not snap["32"]["warm_started"]
    assert snap["32"]["n_observed"] == 5


def test_thread_safety_under_concurrent_observe_and_estimate():
    """8 writer threads x 500 observations per shape, concurrent readers:
    no exception, every observation counted, and the final estimate sits
    inside the observed range (a torn read/write would escape it)."""
    est = ServiceTimeEstimator(alpha=0.5)
    n_threads, n_obs = 8, 500
    lo, hi = 0.010, 0.030
    errors = []

    def writer(shape):
        try:
            for i in range(n_obs):
                est.observe(shape, lo + (hi - lo) * (i % 10) / 9)
        except BaseException as e:  # noqa: BLE001 - surfaced to the test
            errors.append(e)

    def reader():
        try:
            for _ in range(n_obs):
                for shape in (0, 1, 2, 3):
                    v = est.estimate(shape)
                    assert v is None or lo <= v <= hi
                est.snapshot()
        except BaseException as e:  # noqa: BLE001 - surfaced to the test
            errors.append(e)

    threads = [threading.Thread(target=writer, args=(p % 4,))
               for p in range(n_threads)]
    threads += [threading.Thread(target=reader) for _ in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
        assert not t.is_alive(), "estimator thread hung"
    assert not errors, f"concurrent access raised: {errors}"
    assert sum(est.n_observed(s) for s in (0, 1, 2, 3)) == \
        n_threads * n_obs
    for shape in (0, 1, 2, 3):
        assert lo <= est.estimate(shape) <= hi
