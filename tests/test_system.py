"""End-to-end behaviour: training reduces loss; serving decodes; the
fault-tolerant loop survives a crash mid-training with bit-identical
resume semantics on the data stream."""

import jax
import jax.numpy as jnp

from repro import optim
from repro.configs import ARCHS, reduced
from repro.data.pipeline import DataConfig, make_stream
from repro.launch import steps as STEPS
from repro.models import transformer as T
from repro.runtime.fault_tolerance import run_loop


def _train(arch, steps=30, fail_at=None, ckpt_dir=None, tmp_path=None):
    cfg = reduced(ARCHS[arch]).scaled(vocab=64)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    opt = optim.adamw_init(params, cfg.opt_moment_dtype)
    dc = DataConfig(global_batch=4, seq_len=16, vocab=cfg.vocab)
    stream = make_stream(cfg, dc)
    step = jax.jit(STEPS.make_train_step(cfg, lr=1e-3, remat=False))
    losses = []

    def step_fn(state, batch):
        p, o = state
        p, o, m = step(p, o, batch)
        losses.append(float(m["loss"]))
        return (p, o), m

    state, rs = run_loop(
        state=(params, opt), step_fn=step_fn, stream=stream,
        ckpt_dir=str(ckpt_dir or tmp_path), total_steps=steps,
        ckpt_every=10, fail_at=fail_at, log=lambda s: None)
    return losses, rs


def test_training_reduces_loss(tmp_path):
    losses, rs = _train("qwen3-1.7b", steps=40, tmp_path=tmp_path)
    first = sum(losses[:5]) / 5
    last = sum(losses[-5:]) / 5
    assert last < first, (first, last)
    assert rs.restarts == 0


def test_training_survives_crash(tmp_path):
    losses, rs = _train("yi-6b", steps=25, fail_at={15: "crash"},
                        tmp_path=tmp_path)
    assert rs.restarts == 1
    assert len(losses) >= 25  # replayed steps counted too


def test_serve_greedy_decode_deterministic():
    cfg = reduced(ARCHS["rwkv6-7b"]).scaled(vocab=64)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    decode = jax.jit(STEPS.make_serve_step(cfg))

    def gen():
        cache = T.init_cache(cfg, 2, 32)
        tok = jnp.zeros((2, 1), jnp.int32)
        outs = []
        for _ in range(8):
            nxt, cache = decode(params, cache, {"tokens": tok})
            tok = nxt[:, None]
            outs.append(tok)
        return jnp.concatenate(outs, 1)

    a, b = gen(), gen()
    assert bool(jnp.all(a == b))


def test_quantized_cnn_inference_topk_agrees():
    """int8 fixed-point VGG16-small agrees with float on top-1 most of the
    time (the paper's deployment regime)."""
    import numpy as np
    from repro.core import workload as W
    from repro.models import cnn
    m = W.CNN_MODELS["alexnet"]()
    p = cnn.init_params(m, jax.random.PRNGKey(4))
    x = jax.random.normal(jax.random.PRNGKey(5),
                          (4, m.input_hw, m.input_hw, 3))
    yf = cnn.forward(p, m, x)
    yq = cnn.forward(p, m, x, quantized=True, bits=8)
    top_f = np.asarray(jnp.argmax(yf, -1))
    top_q = np.asarray(jnp.argmax(yq, -1))
    assert (top_f == top_q).mean() >= 0.5
