"""Eq. (2)-(4) closed form vs the cycle-accurate simulator."""

import pytest

from repro.core import throughput as T
from repro.core import workload as W
from repro.core.allocator import allocate_compute
from repro.core.simulator import simulate


@pytest.mark.parametrize("model", ["vgg16", "alexnet", "zf", "yolo"])
def test_simulator_matches_analytic(model):
    layers = W.CNN_MODELS[model]().layer_workloads(weight_bits=16)
    allocs = allocate_compute(layers, 900)
    sim = simulate(allocs, n_frames=3)
    analytic = T.frame_cycles(allocs)
    # Steady-state per-frame cycles must match Eq. (4) within 10% (the
    # simulator adds dependency stalls the closed form ignores).
    assert sim.steady_cycles >= analytic * 0.95
    assert sim.steady_cycles <= analytic * 1.15, (
        model, sim.steady_cycles, analytic)


def test_simulator_efficiency_close_to_model():
    layers = W.CNN_MODELS["vgg16"]().layer_workloads(weight_bits=16)
    allocs = allocate_compute(layers, 900)
    sim = simulate(allocs, n_frames=4)
    eff_model = T.dsp_efficiency(allocs)
    # fill/drain makes the simulated efficiency slightly lower
    assert sim.dsp_efficiency <= eff_model * 1.02
    assert sim.dsp_efficiency >= eff_model * 0.7


def test_fps_definition():
    layers = W.CNN_MODELS["alexnet"]().layer_workloads(weight_bits=16)
    allocs = allocate_compute(layers, 900)
    fps = T.pipeline_fps(allocs, freq_hz=200e6)
    assert fps == pytest.approx(200e6 / T.frame_cycles(allocs))
    g = T.gops(allocs, freq_hz=200e6)
    assert g == pytest.approx(
        2 * sum(a.layer.macs for a in allocs) * fps / 1e9)
