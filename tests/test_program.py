"""EngineProgram: compile-once semantics, bit-identity between the Pallas
kernel path and the pure-jnp int oracle, and plan/execution unification."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import workload as W
from repro.core.program import compile_model, float_forward
from repro.core.simulator import simulate
from repro.models import cnn


def _compiled(name, batch=1, seed=0, bits=8):
    m = W.CNN_MODELS[name]()
    p = cnn.init_params(m, jax.random.PRNGKey(seed))
    x = jax.random.normal(jax.random.PRNGKey(seed + 1),
                          (batch, m.input_hw, m.input_hw, m.input_ch))
    return compile_model(m, p, bits=bits, calib_batch=x), p, x


@pytest.mark.parametrize("model", ["alexnet", "vgg16"])
def test_program_kernel_bit_identical_to_oracle(model):
    """The Pallas PE-array path (interpret mode) and the jnp int oracle
    execute the same frozen plan bit-for-bit — including AlexNet's
    stride-4 stem and grouped convs, and VGG16's fc layers on the same
    GEMM engine."""
    prog, _, x = _compiled(model)
    y_oracle = prog.run(x, use_kernel=False)
    y_kernel = prog.run(x, use_kernel=True)
    np.testing.assert_array_equal(np.asarray(y_oracle), np.asarray(y_kernel))


def test_program_scales_frozen_at_compile():
    """No per-forward quantize_po2: weights are int8 with a fixed shift
    schedule, every hidden step requantizes to int8, and two runs on
    different inputs reuse the identical frozen formats."""
    prog, _, x = _compiled("alexnet")
    compute = [s for s in prog.steps if s.kind != "pool"]
    for s in compute[:-1]:
        assert s.wq.dtype == jnp.int8
        assert s.bias_q.dtype == jnp.int32
        assert s.shift.dtype == jnp.int32
        assert s.requantize and s.relu
        # activations stay int8 end-to-end: formats chain exactly
    assert not compute[-1].requantize and not compute[-1].relu
    e = prog.e_input
    for s in prog.steps:
        if s.kind == "pool":
            continue
        assert s.e_in == e
        e = s.e_out
    y1 = prog.run(x)
    y2 = prog.run(x * 0.5)  # different data, same frozen formats
    assert y1.shape == y2.shape
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(prog.run(x)))


def test_program_close_to_float_and_wrapper_equivalent():
    """forward(quantized=True) is a thin wrapper over the program; the
    program output tracks the float reference."""
    prog, p, x = _compiled("alexnet", batch=2)
    y_prog = prog.run(x)
    y_fwd = cnn.forward(p, prog.model, x, quantized=True, bits=8)
    np.testing.assert_array_equal(np.asarray(y_prog), np.asarray(y_fwd))
    y_f = float_forward(p, prog.model, x)
    rel = float(jnp.linalg.norm(y_f - y_prog) / jnp.linalg.norm(y_f))
    assert rel < 0.15, rel


def test_plan_only_program_drives_simulator():
    """compile_model without params produces the shared plan: the
    simulator and throughput model consume it; run() refuses."""
    from repro.core import throughput as T
    prog = compile_model(W.CNN_MODELS["vgg16"](), theta=900, bits=16)
    assert sum(a.theta for a in prog.allocs) <= 900
    sim = simulate(prog, n_frames=3)
    assert 0.9 < sim.dsp_efficiency <= 1.0
    # analytic and simulated steady state agree on the same plan
    assert abs(sim.steady_cycles - T.frame_cycles(prog.allocs)) \
        / T.frame_cycles(prog.allocs) < 0.02
    with pytest.raises(ValueError):
        prog.run(jnp.zeros((1, 224, 224, 3)))


def test_simulator_partial_last_row_group():
    """H % K != 0: the last row-group must be charged only its actual
    rows — steady-state equals the throughput model's H * t_row / K."""
    from repro.core.allocator import LayerAlloc
    from repro.core.workload import LayerWorkload
    l = LayerWorkload(name="c", macs=13 * 13 * 9 * 8 * 8,
                      weight_bytes=9 * 8 * 8, act_in_bytes=0,
                      act_out_bytes=0, kind="conv", R=3, S=3, C=8, M=8,
                      H=13, W=13)
    a = LayerAlloc(l, 9 * 4, 2, 2, K=5)   # 13 rows in groups of 5: 5+5+3
    sim = simulate([a], n_frames=3)
    want = l.H * a.t_per_output_row
    assert abs(sim.steady_cycles - want) < 1e-6
    assert abs(sim.frame_cycles - want) < 1e-6


@pytest.mark.parametrize("bits", [8, 16])
def test_program_model_ending_in_pool(bits):
    """A graph whose final layer is a pool: the dequant scale must come
    from the last *compute* step (regression for steps[-1] assumption),
    and the pool must handle the float accumulators of the bits=16 path."""
    m = W.CNNModel("tiny", 8, 3, (
        W.ConvLayer("c1", 3, 4, 3),
        W.ConvLayer("p1", 4, 4, 2, stride=2, kind="pool"),
    ))
    p = cnn.init_params(m, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 8, 3))
    prog = compile_model(m, p, bits=bits, calib_batch=x)
    y = prog.run(x)
    assert y.shape == (2, 4, 4, 4)
    if bits == 8:
        np.testing.assert_array_equal(
            np.asarray(y), np.asarray(prog.run(x, use_kernel=True)))


def test_dead_weight_channel_keeps_its_bias():
    """A channel with near-zero weights but a significant bias must not
    lose the bias to accumulator-format saturation (the weight format is
    floored so the bias stays representable)."""
    m = W.CNNModel("tiny", 8, 3, (
        W.ConvLayer("c1", 3, 4, 3),
        W.ConvLayer("c2", 4, 4, 3),
    ))
    p = cnn.init_params(m, jax.random.PRNGKey(0))
    p["c1"]["w"] = p["c1"]["w"].at[..., 0].set(1e-9)   # dead channel 0
    p["c1"]["b"] = p["c1"]["b"].at[0].set(8.0)         # ...with real bias
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 8, 3))
    prog = compile_model(m, p, bits=8, calib_batch=x)
    y = prog.run(x)
    y_f = float_forward(p, m, x)
    rel = float(jnp.linalg.norm(y_f - y) / jnp.linalg.norm(y_f))
    assert rel < 0.15, rel
    np.testing.assert_array_equal(np.asarray(y),
                                  np.asarray(prog.run(x, use_kernel=True)))


def test_program_int16_oracle_path():
    prog, p, x = _compiled("zf", bits=16)
    y = prog.run(x)
    y_f = float_forward(p, prog.model, x)
    rel = float(jnp.linalg.norm(y_f - y) / jnp.linalg.norm(y_f))
    assert rel < 1e-3, rel
    with pytest.raises(NotImplementedError):
        prog.run(x, use_kernel=True)
