"""Multi-producer stress lane for the QoS frontend (``stress`` marker;
``make test-stress``, warn-only CI step).

8 submitter threads x 64 frames each against a deliberately slow fake
executor: no request may ever hang, each producer's results must come
back in its own submission order (per-producer FIFO), every request must
resolve to its *own* frame, and the FrontendStats outcome counts must
reconcile exactly with the submissions — completed + failed + expired
(+ rejected) == submitted, totals and per-class alike."""

import threading
import time

import numpy as np
import pytest

from repro.serving import (AsyncFrontend, PipelineExecutor, ReplicaPool,
                           ServiceTimeEstimator, TenantMux,
                           install_stage_fault)

N_PRODUCERS = 8
N_FRAMES = 64

pytestmark = pytest.mark.stress


class SlowEchoExecutor:
    """Deterministic fake: fixed service time per micro-batch, echoes
    each frame back as its result (so a request's payload identifies the
    frame it was answered with)."""

    def __init__(self, batch_size=16, delay_s=0.002):
        self.batch_size = batch_size
        self.delay_s = delay_s
        self.program = None
        self.on_result = None
        self.on_error = None
        self.batches = 0

    def submit_batch(self, frames, n_valid, tag=None):
        self.batches += 1
        time.sleep(self.delay_s)
        if self.on_result:
            self.on_result(tag, [f.copy() for f in frames[:n_valid]])

    def flush_inflight(self):
        pass

    def reset_stats(self):
        pass

    def replica_counts(self):
        return None


def _frame(producer: int, i: int) -> np.ndarray:
    """A frame whose payload encodes (producer, sequence)."""
    return np.full((2, 2, 1), producer * 1000 + i, np.float32)


def _run_producers(fe, submit_one):
    """Spawn N_PRODUCERS threads, each submitting N_FRAMES requests via
    ``submit_one(producer, i)``; returns per-producer request lists."""
    reqs = [[None] * N_FRAMES for _ in range(N_PRODUCERS)]
    errors = []

    def producer(p):
        try:
            for i in range(N_FRAMES):
                reqs[p][i] = submit_one(p, i)
        except BaseException as e:  # noqa: BLE001 - surfaced to the test
            errors.append((p, e))

    threads = [threading.Thread(target=producer, args=(p,))
               for p in range(N_PRODUCERS)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
        assert not t.is_alive(), "producer thread hung"
    assert not errors, f"producer raised: {errors}"
    return reqs


def test_multi_producer_no_hang_fifo_and_reconciled_stats():
    ex = SlowEchoExecutor(batch_size=16, delay_s=0.002)
    fe = AsyncFrontend(ex, max_wait_ms=20.0, max_queue=1024)
    reqs = _run_producers(
        fe, lambda p, i: fe.submit(_frame(p, i), timeout=30))

    # No request hangs: every one resolves inside a bounded wait.
    for p in range(N_PRODUCERS):
        for r in reqs[p]:
            assert r._event.wait(timeout=60), "request hung"
    fe.close()

    total = N_PRODUCERS * N_FRAMES
    st = fe.stats
    # Exact reconciliation: all outcomes, no deadline traffic here.
    assert st.submitted == total
    assert st.completed == total
    assert st.failed == st.expired == st.rejected == 0
    assert st.resolved == total
    assert sum(cs.submitted for cs in st.classes.values()) == total
    assert sum(cs.completed for cs in st.classes.values()) == total

    for p in range(N_PRODUCERS):
        for i, r in enumerate(reqs[p]):
            # Every request got its own frame's answer...
            np.testing.assert_array_equal(
                np.asarray(r.result(timeout=1)),
                _frame(p, i))
            # ...with monotone timestamps through the frontend.
            assert r.t_submit <= r.t_batched <= r.t_dispatched <= r.t_done
        # Per-producer FIFO: a producer's requests are batched and
        # resolved in its own submission order (lanes are FIFO, batches
        # dispatch in pop order, the executor is FIFO).
        for a, b in zip(reqs[p], reqs[p][1:]):
            assert a.t_batched <= b.t_batched
            assert a.t_done <= b.t_done


def test_multi_producer_admission_control_reconciles():
    """8 producers flooding tight deadlines through estimated-wait
    admission: every request resolves to exactly one of
    completed | expired | rejected_wait (no hangs), the outcome counts
    reconcile exactly, and the hopeless tail is refused at submit (the
    flood queues far more work than a 150ms budget can absorb, so
    admission must fire)."""
    ex = SlowEchoExecutor(batch_size=16, delay_s=0.01)
    est = ServiceTimeEstimator()
    est.warm_start(16, ex.delay_s)
    fe = AsyncFrontend(ex, max_wait_ms=20.0, max_queue=1024,
                       estimator=est, admission_control=True,
                       flush_guard_ms=5.0)

    reqs = _run_producers(
        fe, lambda p, i: fe.submit(_frame(p, i), deadline_ms=150.0,
                                   timeout=30, klass=f"rt{p}"))
    for p in range(N_PRODUCERS):
        for r in reqs[p]:
            assert r._event.wait(timeout=60), "request hung"
    fe.close()

    total = N_PRODUCERS * N_FRAMES
    st = fe.stats
    assert st.submitted == total
    assert st.failed == st.rejected == 0
    assert st.completed + st.expired + st.rejected_wait == total
    assert st.resolved == total
    # 512 frames = 32 batches x 10ms ~= 320ms of queued work against
    # 150ms budgets: the estimator must refuse part of the flood.
    assert st.rejected_wait > 0, \
        "admission never fired under a saturating flood"
    assert st.completed > 0
    # Per-class reconciliation and per-request terminal outcomes.
    assert sum(cs.submitted for cs in st.classes.values()) == total
    assert sum(cs.resolved for cs in st.classes.values()) == total
    for p in range(N_PRODUCERS):
        for i, r in enumerate(reqs[p]):
            assert r.outcome in ("completed", "expired", "rejected_wait")
            if r.outcome == "completed":
                np.testing.assert_array_equal(
                    np.asarray(r.result(timeout=1)), _frame(p, i))
            else:
                assert r.missed_deadline()


def test_multi_producer_mixed_deadlines_reconcile():
    """Same flood, but half the producers arm tight deadlines: expired
    requests must resolve (never hang) and the outcome counts still
    reconcile exactly — completed + expired == submitted."""
    ex = SlowEchoExecutor(batch_size=16, delay_s=0.005)
    fe = AsyncFrontend(ex, max_wait_ms=20.0, max_queue=1024)

    def submit_one(p, i):
        if p % 2 == 0:
            return fe.submit(_frame(p, i), timeout=30, klass="bulk")
        return fe.submit(_frame(p, i), priority=1, deadline_ms=150.0,
                         timeout=30, klass="rt")

    reqs = _run_producers(fe, submit_one)
    for p in range(N_PRODUCERS):
        for r in reqs[p]:
            assert r._event.wait(timeout=60), "request hung"
    fe.close()

    total = N_PRODUCERS * N_FRAMES
    st = fe.stats
    assert st.submitted == total
    assert st.failed == st.rejected == 0
    assert st.completed + st.expired == total
    assert st.resolved == total
    bulk, rt = st.klass("bulk"), st.klass("rt")
    assert bulk.submitted == rt.submitted == total // 2
    assert bulk.expired == 0 and bulk.completed == bulk.submitted
    assert rt.completed + rt.expired == rt.submitted
    # Every rt request resolved one way or the other, with a value only
    # when completed.
    for p in range(1, N_PRODUCERS, 2):
        for i, r in enumerate(reqs[p]):
            assert r.outcome in ("completed", "expired")
            if r.outcome == "completed":
                np.testing.assert_array_equal(
                    np.asarray(r.result(timeout=1)), _frame(p, i))


def test_multi_producer_replica_pool_reconciles_exactly():
    """8 producers through the frontend over a routed 3-replica pool,
    with a concurrent ``stats_snapshot()`` reader hammering the stats
    lock the whole time: no request hangs, every request resolves to its
    own frame, no snapshot is ever torn (resolved > submitted), and the
    fleet totals reconcile *exactly* with the per-replica outcome rows —
    both the pool's lifetime counters and the frontend's close() delta."""
    exs = [SlowEchoExecutor(batch_size=16, delay_s=0.002)
           for _ in range(3)]
    pool = ReplicaPool(executors=exs, router_seed=11)
    fe = AsyncFrontend(pool, max_wait_ms=20.0, max_queue=1024)

    stop = threading.Event()
    torn: list[str] = []

    def snapshot_reader():
        while not stop.is_set():
            st = fe.stats_snapshot()
            resolved = (st.completed + st.failed + st.expired
                        + st.rejected + st.rejected_wait)
            if resolved > st.submitted:
                torn.append(f"resolved {resolved} > "
                            f"submitted {st.submitted}")
            time.sleep(0.0005)

    reader = threading.Thread(target=snapshot_reader)
    reader.start()
    try:
        reqs = _run_producers(
            fe, lambda p, i: fe.submit(_frame(p, i), timeout=30))
        for p in range(N_PRODUCERS):
            for r in reqs[p]:
                assert r._event.wait(timeout=60), "request hung"
        fe.close()
    finally:
        stop.set()
        reader.join(timeout=10)
    assert not reader.is_alive()
    assert torn == [], f"torn snapshots: {torn[:3]}"

    total = N_PRODUCERS * N_FRAMES
    st = fe.stats
    assert st.submitted == total
    assert st.completed == total
    assert st.failed == st.expired == st.rejected == 0
    assert st.resolved == total
    for p in range(N_PRODUCERS):
        for i, r in enumerate(reqs[p]):
            np.testing.assert_array_equal(
                np.asarray(r.result(timeout=1)), _frame(p, i))

    # Exact fleet-vs-replica reconciliation, three ways: the pool's
    # lifetime rows, the frontend's close() delta, and the fakes' own
    # batch counters all agree.
    counts = pool.replica_counts()
    assert sum(r["completed_frames"] for r in counts) == total
    assert sum(r["dispatched_frames"] for r in counts) == total
    assert sum(r["failed_batches"] for r in counts) == 0
    assert sum(r["completed_batches"] for r in counts) == \
        sum(ex.batches for ex in exs)
    assert st.replicas, "frontend recorded no per-replica outcomes"
    assert sorted(st.replicas) == ["0", "1", "2"]
    for r, row in enumerate(st.replicas.values()):
        assert row == counts[r]
    # Routing spread the load: every replica served something.
    assert all(r["completed_batches"] > 0 for r in counts)
    pool.close()


def test_multi_producer_mixed_tenants_reconcile_per_tenant():
    """The 8-producer lane, multi-tenant: producers split across two
    tenants behind a :class:`TenantMux` of per-tenant fakes. No request
    hangs, every request resolves to its own frame through its own
    tenant's executor (batches are single-tenant by construction), and
    the per-tenant rollups reconcile exactly with the per-producer
    submissions — no cross-tenant leakage in either direction."""
    exs = {"a": SlowEchoExecutor(batch_size=16, delay_s=0.002),
           "b": SlowEchoExecutor(batch_size=16, delay_s=0.004)}
    mux = TenantMux(exs, batch_size=16)
    fe = AsyncFrontend(mux, max_wait_ms=20.0, max_queue=1024)

    def submit_one(p, i):
        return fe.submit(_frame(p, i), tenant="a" if p % 2 == 0 else "b",
                         timeout=30)

    reqs = _run_producers(fe, submit_one)
    for p in range(N_PRODUCERS):
        for r in reqs[p]:
            assert r._event.wait(timeout=60), "request hung"
    fe.close()
    mux.close()

    total = N_PRODUCERS * N_FRAMES
    st = fe.stats
    assert st.submitted == total
    assert st.completed == total
    assert st.failed == st.expired == st.rejected == 0
    # Per-tenant reconciliation: each tenant's rollup counts exactly its
    # producers' submissions, and together they cover everything.
    ta, tb = st.tenant_row("a"), st.tenant_row("b")
    assert ta.submitted == tb.submitted == total // 2
    assert ta.completed == tb.completed == total // 2
    assert ta.failed == tb.failed == 0
    # Batches never mixed tenants: each fake served exactly its own
    # tenant's frames (payloads encode the producer, producers encode
    # the tenant).
    for p in range(N_PRODUCERS):
        for i, r in enumerate(reqs[p]):
            np.testing.assert_array_equal(
                np.asarray(r.result(timeout=1)), _frame(p, i))
    assert exs["a"].batches > 0 and exs["b"].batches > 0


def test_stage_death_mid_batch_resolves_every_request():
    """Chaos x stress: a *real* two-stage PipelineExecutor whose stage-1
    worker dies mid-batch (injected via install_stage_fault) under the
    full 8-producer flood. The liveness contract must hold through the
    death: every request resolves to completed | failed (no deadlines
    armed, so nothing may expire), the outcome counts reconcile exactly,
    the batches that cleared stage 1 before the fault completed with
    real answers, everything after resolves failed — and no producer or
    request ever hangs."""
    import jax

    from repro.core import workload as W
    from repro.core.program import compile_model
    from repro.models import cnn

    m = W.CNNModel("tiny", 16, 4, (
        W.ConvLayer("c1", 4, 8, 3),
        W.ConvLayer("p1", 8, 8, 2, stride=2, kind="pool"),
        W.ConvLayer("c2", 8, 8, 3, groups=2),
        W.ConvLayer("fc", 8 * 8 * 8, 10, 1, kind="fc"),
    ))
    p = cnn.init_params(m, jax.random.PRNGKey(0))
    calib = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16, 4))
    prog = compile_model(m, p, bits=8, calib_batch=calib)

    px = PipelineExecutor(prog, stages=2, batch_size=4)
    # Stage 1 dies from its 6th micro-batch on: exactly 5 batches make
    # it through the whole pipeline, everything else must fail cleanly
    # (in-flight batches through on_error, later submits synchronously).
    wrapper = install_stage_fault(px, stage=1, at_call=6)
    px.start()
    fe = AsyncFrontend(px, max_wait_ms=10.0, max_queue=4096)

    def frame16(producer, i):
        return np.full((16, 16, 4), (producer * 64 + i) % 7, np.float32)

    reqs = _run_producers(
        fe, lambda p_, i: fe.submit(frame16(p_, i), timeout=60))
    for prod in range(N_PRODUCERS):
        for r in reqs[prod]:
            assert r._event.wait(timeout=60), "request hung"
    fe.close()
    px.close()

    total = N_PRODUCERS * N_FRAMES
    st = fe.stats
    assert st.submitted == total
    assert st.hung == 0
    assert st.resolved == total
    # Exact reconciliation under the fault: completed + failed covers
    # everything (no deadlines => no expiry, queue ample => no rejects).
    assert st.completed + st.failed == total
    assert st.expired == st.rejected == st.rejected_wait == 0
    # The fault actually fired, after exactly 5 clean stage-1 batches.
    assert wrapper.calls >= 6
    assert 0 < st.completed <= 5 * px.batch_size
    assert st.failed == total - st.completed
    for prod in range(N_PRODUCERS):
        for r in reqs[prod]:
            assert r.outcome in ("completed", "failed")
            if r.outcome == "completed":
                # A real traversal: top-1 class id out of the tiny CNN.
                assert int(np.asarray(r.result(timeout=1))) in range(10)


def test_mid_stream_rescale_resolves_every_request():
    """Elastic x stress: a *real* one-model server (tiny CNN, 2-stage
    pipeline) under the full 8-producer flood while ``Server.rescale``
    performs a live drain -> swap -> resume to 2 replicas mid-stream.
    The zero-loss contract must hold across the swap: no producer or
    request hangs, nothing is rejected because of the rescale, every
    request resolves, outcome counts reconcile exactly, each producer's
    requests are batched in its own submission order — and a
    deadline-armed probe phase after the swap completes cleanly (armed
    miss recovered on the rescaled fleet)."""
    import jax

    from repro.core import workload as W
    from repro.core.program import compile_model
    from repro.models import cnn
    from repro.serving import ProgramRegistry, ServerConfig, build_server

    m = W.CNNModel("tiny", 16, 4, (
        W.ConvLayer("c1", 4, 8, 3),
        W.ConvLayer("p1", 8, 8, 2, stride=2, kind="pool"),
        W.ConvLayer("c2", 8, 8, 3, groups=2),
        W.ConvLayer("fc", 8 * 8 * 8, 10, 1, kind="fc"),
    ))
    p = cnn.init_params(m, jax.random.PRNGKey(0))
    calib = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16, 4))
    prog = compile_model(m, p, bits=8, calib_batch=calib)

    reg = ProgramRegistry()
    reg.register("tiny", prog)
    srv = build_server(reg, ServerConfig(batch=4, stages=2, replicas=1))
    fe = srv.open_frontend(400.0)
    event = {}
    rescale_errs: list[BaseException] = []

    def rescaler():
        # Let the flood establish itself, then swap under it. The
        # compile + calibration happens while the old executor serves;
        # only the drain/swap window pauses dispatch.
        time.sleep(0.2)
        try:
            event.update(srv.rescale("tiny", replicas=2))
        except BaseException as e:  # surfaced after join
            rescale_errs.append(e)

    def frame16(producer, i):
        return np.full((16, 16, 4), (producer * 64 + i) % 7, np.float32)

    t = threading.Thread(target=rescaler, name="rescaler")
    t.start()
    try:
        reqs = _run_producers(
            fe, lambda p_, i: fe.submit(frame16(p_, i), timeout=120))
        for prod in range(N_PRODUCERS):
            for r in reqs[prod]:
                assert r._event.wait(timeout=120), "request hung"
    finally:
        t.join(timeout=120)
    assert not t.is_alive(), "rescale hung"
    assert not rescale_errs, f"rescale raised: {rescale_errs}"

    # The swap happened mid-stream and is fully recorded.
    assert event["before"]["replicas"] == 1
    assert event["after"]["replicas"] == 2
    assert event["swapped_frontends"] >= 1
    assert getattr(srv.runtime("tiny").executor, "n_replicas", 1) == 2

    # Armed probe on the rescaled fleet: a full batch of requests with
    # an ample deadline must all complete — the estimator was rewarmed
    # from the *new* plan's calibration, so admission must not refuse
    # them and nothing may expire or arrive late.
    probes = [fe.submit(frame16(0, i), deadline_ms=10_000.0,
                        klass="post-swap", timeout=120)
              for i in range(8)]
    for r in probes:
        assert r._event.wait(timeout=120), "post-swap probe hung"
    fe.close()

    total = N_PRODUCERS * N_FRAMES + len(probes)
    st = fe.stats
    assert st.submitted == total
    assert st.hung == 0
    assert st.resolved == total
    # A rescale never rejects or fails a request: everything completed.
    assert st.completed == total
    assert st.failed == st.expired == st.rejected == st.rejected_wait == 0
    post = st.klass("post-swap")
    assert post.submitted == len(probes)
    assert post.completed == len(probes)
    assert post.late == 0, "armed miss did not recover post-swap"
    for prod in range(N_PRODUCERS):
        for r in reqs[prod]:
            # Real traversals on both executors: top-1 out of the CNN.
            assert int(np.asarray(r.result(timeout=1))) in range(10)
        # Per-producer FIFO held across the swap: lanes stay FIFO and
        # the parked batch re-dispatches before anything newer. (Done
        # order is not asserted — post-swap batches route across 2
        # replicas and may legally interleave.)
        for a, b in zip(reqs[prod], reqs[prod][1:]):
            assert a.t_batched <= b.t_batched
    srv.close()
