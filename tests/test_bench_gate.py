"""The CI bench-regression gate (benchmarks/validate_bench.py
--baseline): band checks over "/"-separated artifact paths, gated vs
warn-only severity, and the committed baselines themselves — a seeded
regression must fail, the real committed bands must be loadable and
self-consistent."""

import importlib.util
import json
import os

import pytest

_ROOT = os.path.join(os.path.dirname(__file__), "..")


def _load_validate_bench():
    spec = importlib.util.spec_from_file_location(
        "validate_bench",
        os.path.join(_ROOT, "benchmarks", "validate_bench.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


vb = _load_validate_bench()


# Every knee probe records its achieved-vs-target pacing
# (traffic.pacing_report) — the validator requires the key.
_PACING = {"arrivals": 40, "target_fps": 12.0, "achieved_fps": 12.0,
           "rate_ratio": 1.0, "lag_ms_mean": 0.1, "lag_ms_max": 0.5}

ARTIFACT = {
    "bench": "serve_async",
    "quick": True,
    "models": {"alexnet": {"stages": {
        "1": {"measured_steady_fps": 50.0},
        "4": {"measured_steady_fps": 45.0,
              "throughput_vs_single_jit": 0.9},
    }}},
}


def _baseline(gates=None, warn=None, **extra):
    b = {"bench": "serve_async", "quick": True, "_file": "<test>"}
    if gates:
        b["gates"] = gates
    if warn:
        b["warn"] = warn
    b.update(extra)
    return b


def test_lookup_walks_slash_paths_with_dotted_keys():
    data = {"rates": {"0.6x": {"classes": {"interactive":
                                           {"slo_miss_rate": 0.0}}}},
            "probes": [{"arrival_fps": 10.0}]}
    assert vb._lookup(
        data, "rates/0.6x/classes/interactive/slo_miss_rate") == (True, 0.0)
    assert vb._lookup(data, "probes/0/arrival_fps") == (True, 10.0)
    assert vb._lookup(data, "rates/0.7x/anything")[0] is False
    assert vb._lookup(data, "probes/5/arrival_fps")[0] is False


def test_gate_passes_inside_band_and_fails_outside():
    inside = _baseline(gates={
        "models/alexnet/stages/4/throughput_vs_single_jit":
            {"min": 0.5, "max": 2.0}})
    ge, wa = vb.check_baseline(ARTIFACT, inside)
    assert ge == [] and wa == []
    # A seeded regression: the relative-throughput band is violated.
    regressed = json.loads(json.dumps(ARTIFACT))
    regressed["models"]["alexnet"]["stages"]["4"][
        "throughput_vs_single_jit"] = 0.2
    ge, _ = vb.check_baseline(regressed, inside)
    assert len(ge) == 1 and "below baseline min" in ge[0]


def test_warn_band_never_gates():
    b = _baseline(warn={
        "models/alexnet/stages/1/measured_steady_fps": {"min": 1e9}})
    ge, wa = vb.check_baseline(ARTIFACT, b)
    assert ge == []
    assert len(wa) == 1 and "below baseline min" in wa[0]


def test_missing_gated_path_fails_but_missing_warn_path_warns():
    """Renaming an artifact field cannot silently disarm its gate."""
    b = _baseline(gates={"models/alexnet/stages/4/renamed": {"min": 0}},
                  warn={"models/alexnet/stages/1/renamed": {"min": 0}})
    ge, wa = vb.check_baseline(ARTIFACT, b)
    assert len(ge) == 1 and "missing" in ge[0]
    assert len(wa) == 1 and "missing" in wa[0]


def test_non_numeric_gated_value_fails():
    b = _baseline(gates={"models/alexnet/stages": {"min": 0}})
    ge, _ = vb.check_baseline(ARTIFACT, b)
    assert len(ge) == 1 and "not a comparable number" in ge[0]


def test_baselines_match_on_bench_kind_and_quick_flag():
    matching = _baseline(gates={
        "models/alexnet/stages/4/throughput_vs_single_jit": {"min": 0.5}})
    ge, wa = vb.check_against_baselines("x.json", ARTIFACT, [matching])
    assert ge == [] and wa == []
    # A different bench kind's baseline never applies; with no baseline
    # at all for this kind the gate warns (not silent, not fatal).
    other_bench = _baseline(gates={"nope": {"min": 0}})
    other_bench["bench"] = "serve_qos"
    ge, wa = vb.check_against_baselines("x.json", ARTIFACT, [other_bench])
    assert ge == []
    assert len(wa) == 1 and "no committed baseline" in wa[0]
    # Baselines for this kind exist but none match the quick flag: that
    # is a gate failure — a quick-wiring regression must not silently
    # disarm every band.
    full_run = _baseline(gates={"nope": {"min": 0}})
    full_run["quick"] = False
    ge, wa = vb.check_against_baselines("x.json", ARTIFACT, [full_run])
    assert len(ge) == 1 and "silently disarmed" in ge[0]


def test_committed_baselines_load_and_name_their_bench():
    """The real benchmarks/baselines/ directory: every file loads, names
    a known bench kind, and only uses min/max bands — the gate CI runs
    is the gate these tests exercised."""
    baselines, errors = vb.load_baselines(
        os.path.join(_ROOT, "benchmarks", "baselines"))
    assert errors == []
    assert len(baselines) >= 4, "expected a baseline per artifact kind"
    kinds = {b["bench"] for b in baselines}
    assert {"serve", "serve_async", "serve_qos",
            "serve_knee"} <= kinds
    for b in baselines:
        for band_kind in ("gates", "warn"):
            for path, band in b.get(band_kind, {}).items():
                assert isinstance(path, str) and "/" in path, \
                    f"{b['_file']}: {path!r} is not a /-separated path"
                assert isinstance(band, dict) and band, \
                    f"{b['_file']}: {path} band is empty"
                assert set(band) <= {"min", "max"}, \
                    f"{b['_file']}: {path} has unknown band keys"


def test_validate_rejects_seeded_knee_regression(tmp_path):
    """End to end through validate(): a knee artifact whose headline
    contradicts its probes is rejected by schema validation alone."""
    good = {
        "schema_version": 1, "bench": "serve_knee", "seed": 0,
        "models": {"alexnet": {
            "measured_steady_fps": 10.0, "modeled_fps_alg1": 100.0,
            "batch": 8, "stages": 2, "seed": 0, "slo_ms": 500.0,
            "miss_target": 0.01, "traffic_mix": [], "route": "f32",
            "admission_control": True, "replicas": 1,
            "knee_qps": 12.0, "knee_of_steady": 1.2,
            "probes": [
                {"arrival_fps": 12.0, "sustained": True,
                 "armed_miss_rate": 0.0, "armed_submitted": 10,
                 "submitted": 40, "completed": 40, "expired": 0,
                 "rejected": 0, "rejected_wait": 0,
                 "pacing": _PACING},
                {"arrival_fps": 24.0, "sustained": False,
                 "armed_miss_rate": 0.5, "armed_submitted": 10,
                 "submitted": 40, "completed": 20, "expired": 0,
                 "rejected": 0, "rejected_wait": 20,
                 "pacing": _PACING},
            ],
        }},
    }
    p = tmp_path / "BENCH_serve_knee.json"
    p.write_text(json.dumps(good))
    assert vb.validate(str(p)) == []
    # Headline not backed by a sustained probe -> schema failure.
    bad = json.loads(json.dumps(good))
    bad["models"]["alexnet"]["knee_qps"] = 24.0
    p.write_text(json.dumps(bad))
    errs = vb.validate(str(p))
    assert any("not the max sustained probe" in e for e in errs)
    # sustained flag contradicting the miss rate -> schema failure.
    bad = json.loads(json.dumps(good))
    bad["models"]["alexnet"]["probes"][1]["sustained"] = True
    p.write_text(json.dumps(bad))
    errs = vb.validate(str(p))
    assert any("contradicts miss" in e for e in errs)


def _knee_row(replicas, knee_qps):
    return {
        "measured_steady_fps": 10.0, "modeled_fps_alg1": 100.0,
        "batch": 8, "stages": 2, "seed": 0, "slo_ms": 500.0,
        "miss_target": 0.01, "traffic_mix": [], "route": "f32",
        "admission_control": True, "replicas": replicas,
        "knee_qps": knee_qps, "knee_of_steady": knee_qps / 10.0,
        "probes": [
            {"arrival_fps": knee_qps, "sustained": True,
             "armed_miss_rate": 0.0, "armed_submitted": 10,
             "submitted": 40, "completed": 40, "expired": 0,
             "rejected": 0, "rejected_wait": 0,
             "pacing": _PACING},
            {"arrival_fps": 2 * knee_qps, "sustained": False,
             "armed_miss_rate": 0.5, "armed_submitted": 10,
             "submitted": 40, "completed": 20, "expired": 0,
             "rejected": 0, "rejected_wait": 20,
             "pacing": _PACING},
        ],
    }


def test_validate_knee_scaling_block(tmp_path):
    """The knee-vs-R sweep block: rows validate recursively, row R must
    have run with R replicas, and the gated knee_vs_r1 ratios must
    reproduce from the rows' knee_qps."""
    top = _knee_row(1, 12.0)
    top["knee_scaling"] = {
        "device_count": 4, "mode": "pipeline",
        "rows": {"1": _knee_row(1, 12.0), "2": _knee_row(2, 18.0)},
        "knee_vs_r1": {"2": 1.5},
    }
    data = {"schema_version": 1, "bench": "serve_knee", "seed": 0,
            "models": {"alexnet": top}}
    p = tmp_path / "BENCH_serve_knee.json"
    p.write_text(json.dumps(data))
    assert vb.validate(str(p)) == []
    # Ratio drifting from the rows it summarizes -> schema failure
    # (the CI gate on knee_vs_r1/2 reads the ratio, so it must be
    # derivable from the data).
    bad = json.loads(json.dumps(data))
    bad["models"]["alexnet"]["knee_scaling"]["knee_vs_r1"]["2"] = 2.5
    p.write_text(json.dumps(bad))
    assert any("does not reproduce" in e for e in vb.validate(str(p)))
    # Row keyed "2" that actually ran one replica -> schema failure.
    bad = json.loads(json.dumps(data))
    bad["models"]["alexnet"]["knee_scaling"]["rows"]["2"]["replicas"] = 1
    p.write_text(json.dumps(bad))
    assert any("does not match key" in e for e in vb.validate(str(p)))
    # Sweep without its R=1 baseline -> schema failure.
    bad = json.loads(json.dumps(data))
    del bad["models"]["alexnet"]["knee_scaling"]["rows"]["1"]
    p.write_text(json.dumps(bad))
    assert any("R=1 baseline" in e for e in vb.validate(str(p)))
    # A row whose sweep found no knee carries a null ratio: legal for
    # the schema (the CI gate on that path still fails, by design)...
    nul = json.loads(json.dumps(data))
    ks = nul["models"]["alexnet"]["knee_scaling"]
    ks["rows"]["2"]["knee_qps"] = None
    ks["rows"]["2"]["knee_of_steady"] = None
    for probe in ks["rows"]["2"]["probes"]:
        probe["sustained"] = False
        probe["armed_miss_rate"] = 0.5
    ks["knee_vs_r1"]["2"] = None
    p.write_text(json.dumps(nul))
    assert vb.validate(str(p)) == []
    # ...but a null ratio with both knees present is a schema failure.
    bad = json.loads(json.dumps(data))
    bad["models"]["alexnet"]["knee_scaling"]["knee_vs_r1"]["2"] = None
    p.write_text(json.dumps(bad))
    assert any("null but both knees exist" in e
               for e in vb.validate(str(p)))


@pytest.mark.parametrize("band,value,ok", [
    ({"min": 1.0}, 1.0, True),
    ({"min": 1.0}, 0.99, False),
    ({"max": 2.0}, 2.0, True),
    ({"max": 2.0}, 2.01, False),
    ({"min": 0.0, "max": 1.0}, 0.5, True),
    ({"min": 0.0, "max": 1.0}, float("nan"), False),
    ({"min": 0.0}, True, False),          # bools are not measurements
    ({"min": 0.0}, "fast", False),
])
def test_band_edges(band, value, ok):
    msg = vb._check_band("x", value, band)
    assert (msg is None) == ok
