"""Chaos serving: fault injection, adversarial traffic, and the
liveness contract under both (ROADMAP item 5).

The load-bearing test is the pinned acceptance scenario at the bottom:
kill one replica mid-stream at ~0.6x the fleet's sustainable load and
require that *zero* requests hang, every affected request resolves
``failed``, the survivor absorbs the stream, and the armed miss rate
recovers below the target within a measured window. Everything above it
is the unit layer that makes that scenario diagnosable when it breaks:
FaultPlan semantics, ChaosExecutor protocol conformance, the scenario
schedule suite, trace round-trips, and the pacing/recovery reports."""

import json
import time

import numpy as np
import pytest

from repro.serving import (Arrival, AsyncFrontend, ChaosExecutor,
                           Executor, FaultPlan, ReplicaKilled,
                           ReplicaPool, SCENARIOS, TrafficClass,
                           install_stage_fault,
                           make_schedule, make_scenario_schedule,
                           pacing_report, record_trace, recovery_report,
                           replay, trace_schedule)


class EchoExec:
    """Minimal Executor-conforming fake: optional fixed service time,
    echoes valid frames back synchronously from the submit thread."""

    def __init__(self, batch_size=4, delay_s=0.0):
        self.batch_size = batch_size
        self.delay_s = delay_s
        self.program = None
        self.on_result = None
        self.on_error = None
        self.batches = 0

    def submit_batch(self, frames, n_valid, tag=None):
        self.batches += 1
        if self.delay_s:
            time.sleep(self.delay_s)
        if self.on_result is not None:
            self.on_result(tag, np.asarray(frames)[:n_valid].copy())

    def flush_inflight(self):
        pass

    def reset_stats(self):
        pass

    def replica_counts(self):
        return None


def _collectors(chaos):
    """Claim the wrapper's callback slots into (results, errors) lists."""
    results, errors = [], []
    chaos.on_result = lambda tag, out: results.append((tag, out))
    chaos.on_error = lambda tag, exc: errors.append((tag, exc))
    return results, errors


_FRAMES = np.zeros((4, 2, 2, 1), np.float32)


# ---------------------------------------------------------------------------
# FaultPlan
# ---------------------------------------------------------------------------


def test_fault_plan_validates():
    with pytest.raises(ValueError):
        FaultPlan(kill_mode="nope")
    with pytest.raises(ValueError):
        FaultPlan(kill_at_batch=0)
    with pytest.raises(ValueError):
        FaultPlan(recover_at_batch=0)
    with pytest.raises(ValueError):
        FaultPlan(fail_after_s=-1.0)
    with pytest.raises(ValueError):
        FaultPlan(straggle_at_batch=3)          # needs slowdown_s > 0
    plan = FaultPlan(kill_at_batch=5, recover_at_batch=9)
    rec = plan.to_json()
    assert rec["kill_at_batch"] == 5 and rec["recover_at_batch"] == 9
    json.dumps(rec)                             # artifact-serializable


def test_install_stage_fault_validates():
    with pytest.raises(ValueError):
        install_stage_fault(object(), stage=0, at_call=0)


# ---------------------------------------------------------------------------
# ChaosExecutor
# ---------------------------------------------------------------------------


def test_chaos_executor_conforms_and_passes_through():
    inner = EchoExec()
    chaos = ChaosExecutor(inner, FaultPlan())
    assert isinstance(chaos, Executor)
    assert chaos.batch_size == inner.batch_size
    assert chaos.batches == 0                   # __getattr__ passthrough
    # The wrapper claimed the inner slots and exposes fresh ones.
    assert inner.on_result is not None and chaos.on_result is None
    results, errors = _collectors(chaos)
    chaos.submit_batch(_FRAMES, 4, tag="a")
    assert inner.batches == 1
    assert [t for t, _ in results] == ["a"] and not errors


def test_chaos_kill_mid_batch_flows_through_on_error():
    """mid-batch mode: the dispatch is *accepted* and dies in the array —
    the error arrives asynchronously-shaped through on_error with the
    submit tag, which is exactly the path that resolves frontend
    requests ``failed`` instead of hanging them."""
    chaos = ChaosExecutor(EchoExec(), FaultPlan(kill_at_batch=2))
    results, errors = _collectors(chaos)
    chaos.submit_batch(_FRAMES, 4, tag="a")     # batch 1: alive
    chaos.submit_batch(_FRAMES, 4, tag="b")     # batch 2+: dead
    chaos.submit_batch(_FRAMES, 4, tag="c")
    assert [t for t, _ in results] == ["a"]
    assert [t for t, _ in errors] == ["b", "c"]
    assert all(isinstance(e, ReplicaKilled) for _, e in errors)
    assert chaos.inner.batches == 1             # never reached the inner
    assert chaos.injected_failures == 2
    assert chaos.t_first_fault is not None


def test_chaos_kill_reject_mode_raises_from_submit():
    chaos = ChaosExecutor(EchoExec(),
                          FaultPlan(kill_at_batch=1, kill_mode="reject"))
    _collectors(chaos)
    with pytest.raises(ReplicaKilled):
        chaos.submit_batch(_FRAMES, 4, tag="a")


def test_chaos_recovers_at_batch():
    chaos = ChaosExecutor(EchoExec(),
                          FaultPlan(kill_at_batch=2, recover_at_batch=4))
    results, errors = _collectors(chaos)
    for tag in "abcd":
        chaos.submit_batch(_FRAMES, 4, tag=tag)
    assert [t for t, _ in results] == ["a", "d"]
    assert [t for t, _ in errors] == ["b", "c"]


def test_chaos_fail_after_s_and_clock_reset():
    """fail_after_s counts from the fault clock (first dispatch, or the
    explicit reset a bench performs after calibration) — so calibration
    batches must not burn the fault window."""
    chaos = ChaosExecutor(EchoExec(), FaultPlan(fail_after_s=0.0))
    results, errors = _collectors(chaos)
    chaos.submit_batch(_FRAMES, 4, tag="a")     # t0 set, 0s elapsed: dead
    assert not results and [t for t, _ in errors] == ["a"]

    chaos = ChaosExecutor(EchoExec(), FaultPlan(kill_at_batch=3))
    results, errors = _collectors(chaos)
    chaos.submit_batch(_FRAMES, 4, tag="warm1")
    chaos.submit_batch(_FRAMES, 4, tag="warm2")
    chaos.reset_fault_clock()                   # calibration over
    chaos.submit_batch(_FRAMES, 4, tag="a")     # batches 1, 2 post-reset
    chaos.submit_batch(_FRAMES, 4, tag="b")
    chaos.submit_batch(_FRAMES, 4, tag="c")     # batch 3: dead
    assert [t for t, _ in results] == ["warm1", "warm2", "a", "b"]
    assert [t for t, _ in errors] == ["c"]


def test_chaos_straggle_delays_delivery_without_killing():
    chaos = ChaosExecutor(
        EchoExec(), FaultPlan(straggle_at_batch=2, slowdown_s=0.05))
    results, errors = _collectors(chaos)
    chaos.submit_batch(_FRAMES, 4, tag="a")
    t0 = time.perf_counter()
    chaos.submit_batch(_FRAMES, 4, tag="b")
    slow_s = time.perf_counter() - t0
    assert [t for t, _ in results] == ["a", "b"] and not errors
    assert slow_s >= 0.05
    assert chaos.injected_slowdowns == 1
    assert chaos.injected_failures == 0
    # A slowdown is a fault too: the straggler replay's recovery clock
    # starts at the first dragged delivery.
    assert chaos.t_first_fault is not None


def test_chaos_arm_swaps_plan_and_restarts_clock():
    """The bench calibrates through a benign wrapper, then arms the real
    plan — the armed offsets must count from zero, not from the
    calibration batches."""
    chaos = ChaosExecutor(EchoExec(), FaultPlan())
    results, errors = _collectors(chaos)
    for tag in ("c1", "c2", "c3"):              # calibration: no faults
        chaos.submit_batch(_FRAMES, 4, tag=tag)
    chaos.arm(FaultPlan(kill_at_batch=2))
    chaos.submit_batch(_FRAMES, 4, tag="a")     # batch 1 post-arm: fine
    chaos.submit_batch(_FRAMES, 4, tag="b")     # batch 2: dead
    assert [t for t, _ in results] == ["c1", "c2", "c3", "a"]
    assert [t for t, _ in errors] == ["b"]
    assert chaos.plan.kill_at_batch == 2
    assert chaos.injected_failures == 1


# ---------------------------------------------------------------------------
# Scenario schedules
# ---------------------------------------------------------------------------


def test_scenarios_deterministic_monotone_and_recorded():
    for scenario in SCENARIOS:
        sched, rec = make_scenario_schedule(scenario, 400, 200.0, seed=7)
        again, rec2 = make_scenario_schedule(scenario, 400, 200.0, seed=7)
        assert sched == again and rec == rec2
        assert len(sched) == 400
        times = [a.t for a in sched]
        assert times[0] == 0.0
        assert all(b >= a for a, b in zip(times, times[1:]))
        assert rec["scenario"] == scenario
        assert rec["seed"] == 7 and rec["n"] == 400
        assert rec["rate_fps"] == 200.0
        json.dumps(rec)


def test_scenarios_hold_the_long_run_rate():
    """Every envelope bends the arrival *process*, not the long-run mean
    rate the artifact claims (pareto's infinite variance earns it the
    loosest band)."""
    for scenario, lo, hi in [("uniform", 0.99, 1.01),
                             ("poisson", 0.8, 1.25),
                             ("onoff", 0.8, 1.25),
                             ("lognormal", 0.7, 1.4),
                             ("pareto", 0.5, 2.0),
                             ("diurnal", 0.8, 1.25)]:
        sched, _ = make_scenario_schedule(scenario, 2000, 500.0, seed=11)
        span = sched[-1].t - sched[0].t
        achieved = (len(sched) - 1) / span
        assert lo <= achieved / 500.0 <= hi, \
            f"{scenario}: achieved {achieved:.1f} fps vs target 500"


def test_uniform_and_poisson_reproduce_make_schedule():
    """The legacy paths ride the same front door bit-for-bit: existing
    knee artifacts stay comparable across the scenario refactor."""
    mix = (TrafficClass("rt", priority=1, deadline_ms=50.0, share=0.5),
           TrafficClass("bulk", share=0.5))
    for scenario, poisson in [("uniform", False), ("poisson", True)]:
        legacy = make_schedule(300, 150.0, mix, seed=3, poisson=poisson)
        sched, _ = make_scenario_schedule(scenario, 300, 150.0, mix, seed=3)
        assert sched == legacy


def test_onoff_has_two_gap_regimes():
    sched, rec = make_scenario_schedule("onoff", 800, 400.0, seed=1,
                                        burst_factor=4.0, duty=0.25)
    gaps = np.diff([a.t for a in sched])
    # burst gap = 1/(4 x base rate), idle gap = 1/base: 4x apart.
    assert gaps.max() > 2.5 * gaps.min()
    assert rec["burst_factor"] == 4.0 and rec["n_bursts"] == 4


def test_diurnal_ramps_from_trough_to_peak():
    sched, _ = make_scenario_schedule("diurnal", 1000, 500.0, seed=1,
                                      amp=0.8, cycles=1)
    gaps = np.diff([a.t for a in sched])
    # Starts at the trough (sparse) and peaks mid-stream (dense).
    assert gaps[:20].mean() > 2.0 * gaps[len(gaps) // 2 - 10:
                                        len(gaps) // 2 + 10].mean()


def test_scenario_rejects_unknown_and_bad_knobs():
    with pytest.raises(ValueError):
        make_scenario_schedule("flashmob", 10, 100.0)
    with pytest.raises(ValueError):
        make_scenario_schedule("onoff", 10, 100.0, bogus=1)
    with pytest.raises(ValueError):
        make_scenario_schedule("onoff", 10, 100.0, burst_factor=1.0)
    with pytest.raises(ValueError):
        make_scenario_schedule("onoff", 10, 100.0, duty=0.0)
    with pytest.raises(ValueError):
        make_scenario_schedule("lognormal", 10, 100.0, sigma=0.0)
    with pytest.raises(ValueError):
        make_scenario_schedule("pareto", 10, 100.0, alpha=1.0)
    with pytest.raises(ValueError):
        make_scenario_schedule("diurnal", 10, 100.0, amp=1.0)


def test_trace_round_trip_is_exact():
    sched, _ = make_scenario_schedule("pareto", 60, 120.0, seed=2)
    trace = record_trace(sched)
    json.dumps(trace)                           # artifact-serializable
    assert trace_schedule(trace) == sched
    # Two different class defs under one name cannot be recorded.
    clash = [Arrival(t=0.0, frame_idx=0, klass=TrafficClass("rt")),
             Arrival(t=1.0, frame_idx=1,
                     klass=TrafficClass("rt", deadline_ms=5.0))]
    with pytest.raises(ValueError):
        record_trace(clash)


# ---------------------------------------------------------------------------
# Pacing / recovery reports
# ---------------------------------------------------------------------------


class _Handle:
    def __init__(self, t_submit):
        self.t_submit = t_submit


def test_pacing_report_measures_rate_and_lag():
    mix = (TrafficClass("rt"),)
    sched, _ = make_scenario_schedule("uniform", 11, 100.0, mix, seed=0)
    on_time = [_Handle(5.0 + a.t) for a in sched]       # offset cancels
    pr = pacing_report(sched, on_time)
    assert pr["rate_ratio"] == pytest.approx(1.0)
    assert pr["lag_ms_max"] == pytest.approx(0.0)
    slow = [_Handle(5.0 + 1.25 * a.t) for a in sched]   # 25% too slow
    pr = pacing_report(sched, slow)
    assert pr["rate_ratio"] == pytest.approx(0.8)
    assert pr["target_fps"] == pytest.approx(100.0)
    assert pr["achieved_fps"] == pytest.approx(80.0)
    assert pr["lag_ms_max"] == pytest.approx(25.0)
    with pytest.raises(ValueError):
        pacing_report(sched, on_time[:-1])
    short = pacing_report(sched[:1], on_time[:1])
    assert short["rate_ratio"] is None


class _Req:
    def __init__(self, t_submit, outcome, *, armed=True, late=False):
        self.t_submit = t_submit
        self.outcome = outcome
        self.deadline_s = (t_submit + 1.0) if armed else None
        self._late = late

    def missed_deadline(self):
        return (self.outcome in ("expired", "rejected_wait")
                or self._late)


def test_recovery_report_windows_and_recovery_point():
    reqs = [
        _Req(9.5, "completed"),
        _Req(9.7, "completed", armed=False),     # unarmed: ignored
        _Req(10.2, "failed"), _Req(10.4, "failed"),  # fault window
        _Req(10.6, "completed"),
        _Req(11.1, "completed"), _Req(11.5, "completed"),
        _Req(11.9, "expired"),
    ]
    rec = recovery_report(reqs, fault_t0=10.0, window_s=1.0,
                          miss_target=0.5)
    assert rec["armed_total"] == 7
    assert rec["pre_fault_armed"] == {"submitted": 1, "missed": 0}
    w0, w1 = rec["windows"]
    assert (w0["submitted"], w0["missed"]) == (3, 2)    # failed counts
    assert (w1["submitted"], w1["missed"]) == (3, 1)    # expired counts
    assert w0["miss_rate"] > 0.5 > w1["miss_rate"]
    assert rec["recovered_s"] == 2.0
    json.dumps(rec)
    # No fault ever fired: nothing to window.
    empty = recovery_report(reqs, fault_t0=None, window_s=1.0,
                            miss_target=0.5)
    assert empty["recovered_s"] is None and empty["windows"] == []


# ---------------------------------------------------------------------------
# The pinned acceptance scenario (ISSUE 9): kill one replica mid-stream
# ---------------------------------------------------------------------------


def test_kill_one_replica_mid_stream_recovers_without_hangs():
    """Kill replica 0 mid-stream at ~0.6x the sustainable per-replica
    load: zero requests hang, every affected request resolves
    ``failed``, the survivor absorbs the rest of the stream, the victim
    is quarantined after exactly ``quarantine_after`` sacrificed batches
    and later re-admitted by a probe, and the armed miss rate is back
    under the target within a measured recovery window."""
    delay_s, batch = 0.004, 8
    plan = FaultPlan(kill_at_batch=4, recover_at_batch=10)
    victim = ChaosExecutor(EchoExec(batch_size=batch, delay_s=delay_s),
                           plan)
    survivor = EchoExec(batch_size=batch, delay_s=delay_s)
    pool = ReplicaPool(executors=[victim, survivor], router_seed=0,
                       quarantine_after=3, probe_every=4)
    # Warm symmetric estimators: ties break to replica 0, so the victim
    # carries the stream until its plan kills it.
    pool.router.warm_start(delay_s, 2.0 * delay_s)
    fe = AsyncFrontend(pool, max_wait_ms=8.0, max_queue=1024)

    # One armed class, paced at 1200 fps against a ~2000 fps single-
    # replica service rate (batch/delay): ~0.6x the knee.
    mix = (TrafficClass("rt", priority=1, deadline_ms=1000.0),)
    n = 320
    sched, _ = make_scenario_schedule("uniform", n, 1200.0, mix, seed=5)
    frames = [np.full((2, 2, 1), i, np.float32) for i in range(n)]
    reqs = replay(fe, frames, sched, raise_failed=False)
    pacing = pacing_report(sched, reqs)
    fe.close()
    pool.close()

    st = fe.stats
    # Liveness headline: nothing hangs, everything resolves terminally.
    assert st.submitted == n
    assert st.hung == 0
    assert st.resolved == n
    assert st.completed + st.failed == n and st.expired == 0
    assert {r.outcome for r in reqs} == {"completed", "failed"}

    # Exactly quarantine_after live batches were sacrificed discovering
    # the death; the survivor never failed and absorbed the stream.
    counts = pool.replica_counts()
    assert counts[0]["failed_batches"] == 3
    assert counts[1]["failed_batches"] == 0
    assert st.failed == counts[0]["failed_frames"] > 0
    assert counts[1]["completed_batches"] >= 10
    router = pool.router
    assert router.quarantine_events == 1
    # The victim came back at wrapper batch 10: probes (not live
    # requests) discovered it and re-admitted it.
    assert router.readmissions == 1
    assert not router.is_quarantined(0)
    assert counts[0]["probe_batches"] >= 1
    assert victim.injected_failures >= 3        # 3 live + failed probes

    # Time-to-recover: the armed miss rate re-enters the target band
    # within the windowed report, and its miss counts reconcile exactly
    # with the frontend's failure count.
    rec = recovery_report(reqs, fault_t0=victim.t_first_fault,
                          window_s=0.05, miss_target=0.1)
    assert rec["recovered_s"] is not None
    assert rec["recovered_s"] <= 0.25
    missed = rec["pre_fault_armed"]["missed"] + \
        sum(w["missed"] for w in rec["windows"])
    assert missed == st.failed

    # The open loop actually drove the claimed rate.
    assert pacing["rate_ratio"] is not None
    assert 0.5 <= pacing["rate_ratio"] <= 1.5
