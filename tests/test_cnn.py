"""CNN substrate: fixed-point vs float forward, graph integrity."""

import jax
import jax.numpy as jnp
import pytest

from repro.core import workload as W
from repro.models import cnn


@pytest.mark.parametrize("model", ["alexnet", "zf"])
def test_fixed_point_close_to_float(model):
    m = W.CNN_MODELS[model]()
    p = cnn.init_params(m, jax.random.PRNGKey(1))
    x = jax.random.normal(jax.random.PRNGKey(2),
                          (1, m.input_hw, m.input_hw, m.input_ch))
    yf = cnn.forward(p, m, x)
    y8 = cnn.forward(p, m, x, quantized=True, bits=8)
    y16 = cnn.forward(p, m, x, quantized=True, bits=16)
    rel8 = float(jnp.linalg.norm(yf - y8) / jnp.linalg.norm(yf))
    rel16 = float(jnp.linalg.norm(yf - y16) / jnp.linalg.norm(yf))
    assert rel8 < 0.15, rel8
    assert rel16 < 1e-3, rel16


def test_vgg_graph_shapes():
    m = W.vgg16()
    p = cnn.init_params(m, jax.random.PRNGKey(0))
    x = jnp.zeros((1, 224, 224, 3))
    y = cnn.forward(p, m, x)
    assert y.shape == (1, 1000)


def test_yolo_graph_shapes():
    m = W.yolo()
    p = cnn.init_params(m, jax.random.PRNGKey(0))
    x = jnp.zeros((1, 448, 448, 3))
    y = cnn.forward(p, m, x)
    assert y.shape == (1, 7 * 7 * 30)


def test_workload_matches_model_layers():
    """The allocator's workload graph and the executable model agree."""
    for name, fn in W.CNN_MODELS.items():
        m = fn()
        layers = m.layer_workloads()
        convs = [l for l in layers if l.kind == "conv"]
        assert all(l.macs > 0 for l in convs)
        assert all(l.weight_bytes > 0 for l in convs)
