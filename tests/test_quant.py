"""Quant round-trips: the signed-shift requantizer, adder-tree alignment
exactness, and the fused engine epilogue vs the float-epilogue reference."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quant
from repro.kernels.conv2d_int8 import ref as cref
from repro.kernels.conv2d_int8.kernel import gemm_int8


def test_requantize_negative_shift_left_shifts():
    """e_out < e_acc: the requantizer must take the left-shift branch
    (output format finer than the accumulator's)."""
    acc = jnp.array([[3, -5, 30]], jnp.int32)
    out = quant.requantize_output(acc, 0, -2, bits=8)
    np.testing.assert_array_equal(np.asarray(out)[0], [12, -20, 120])
    # and saturate on overflow rather than wrap
    out = quant.requantize_output(jnp.array([[100, -100]], jnp.int32),
                                  0, -2, bits=8)
    np.testing.assert_array_equal(np.asarray(out)[0], [127, -128])


def test_left_shift_saturates_instead_of_wrapping():
    """Large accumulators under a negative shift must saturate to the int8
    rails, not wrap int32 (regression: 1<<24 << 8 wrapped to 0)."""
    acc = jnp.array([[1 << 24, -(1 << 24), 1 << 30, -(1 << 30)]], jnp.int32)
    sh = jnp.full((4,), -8, jnp.int32)
    out = cref.requantize_ref(acc, sh)
    np.testing.assert_array_equal(np.asarray(out)[0], [127, -128, 127, -128])
    out = quant.requantize_output(acc, 0, -8, bits=8)
    np.testing.assert_array_equal(np.asarray(out)[0], [127, -128, 127, -128])
    # boundary: a full-width left shift must saturate positives to +127,
    # not collapse them to 0 (regression: int32_max >> 31 == 0 preimage)
    out = quant.requantize_output(jnp.array([[1, 5, -5, 0]], jnp.int32),
                                  0, -31, bits=8)
    np.testing.assert_array_equal(np.asarray(out)[0], [127, 127, -128, 0])
    # the Pallas kernel epilogue saturates identically
    x = jnp.full((8, 32), 127, jnp.int8)
    w = jnp.full((32, 8), 127, jnp.int8)     # acc = 32*127*127 ~ 2^19
    got = gemm_int8(x, w, jnp.full((8,), -13, jnp.int32), interpret=True)
    want = cref.gemm_int8_ref(x, w, jnp.full((8,), -13, jnp.int32))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert int(np.asarray(got)[0, 0]) == 127


def test_requantize_roundtrip_identity():
    """shift down then up by the same amount is lossless for in-range
    multiples (the formats are po2, so this is pure bit movement)."""
    q = jnp.arange(-32, 32, dtype=jnp.int32) * 4
    down = quant.requantize_output(q, 0, 2, bits=8)
    up = quant.requantize_output(down.astype(jnp.int32), 2, 0, bits=16)
    np.testing.assert_array_equal(np.asarray(up), np.asarray(q))


def test_align_partial_sums_exact_vs_float_oracle():
    """Aligning per-channel psums onto the common (finest) exponent is
    exact: q * 2^e_in == aligned * 2^e_common, verified against a float64
    oracle."""
    rng = np.random.default_rng(0)
    psum = jnp.asarray(rng.integers(-2 ** 20, 2 ** 20, (16, 8)), jnp.int32)
    e_in = jnp.asarray(rng.integers(-3, 6, (8,)), jnp.int32)
    e_common = jnp.full((), int(jnp.min(e_in)), jnp.int32)
    aligned = quant.align_partial_sums(psum, e_in, e_common, axis=-1)
    want = np.asarray(psum, np.float64) * np.exp2(np.asarray(e_in))[None, :]
    got = np.asarray(aligned, np.float64) * np.exp2(float(e_common))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("relu", [False, True])
def test_fused_epilogue_bit_exact_vs_float_epilogue(relu):
    """The fused int epilogue (bias+ReLU+shift inside the kernel) must be
    bit-exact against the float-epilogue path the seed model used — acc ->
    float32 dequant -> float bias/ReLU -> truncate onto the output format —
    when the float path applies the same floor semantics."""
    key = jax.random.PRNGKey(5)
    kx, kw, kb = jax.random.split(key, 3)
    N, K, M = 96, 64, 40
    x = jax.random.randint(kx, (N, K), -128, 127, jnp.int8)
    w = jax.random.randint(kw, (K, M), -30, 30, jnp.int8)
    bias = jax.random.randint(kb, (M,), -4096, 4096, jnp.int32)
    shift = jnp.asarray(np.tile([7, 5, 0, -1, 3], M // 5), jnp.int32)

    got = gemm_int8(x, w, shift, bias, relu=relu, interpret=True)

    # float64-epilogue oracle: exact for these magnitudes (< 2^53)
    acc = np.asarray(x, np.int64) @ np.asarray(w, np.int64) \
        + np.asarray(bias, np.int64)[None, :]
    y = np.maximum(acc, 0) if relu else acc
    y = np.floor(y.astype(np.float64) * np.exp2(-np.asarray(shift))[None, :])
    want = np.clip(y, -128, 127).astype(np.int8)
    np.testing.assert_array_equal(np.asarray(got), want)
    # and the ref oracle is the same function
    ref = cref.gemm_int8_ref(x, w, shift, bias, relu)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_fused_epilogue_within_one_lsb_of_round_to_nearest():
    """vs the seed's round-to-nearest float requantize, truncation differs
    by at most one LSB of the output format (the paper's stated cost of
    'right shifted and truncated')."""
    key = jax.random.PRNGKey(9)
    kx, kw = jax.random.split(key)
    N, K, M = 64, 32, 16
    x = jax.random.randint(kx, (N, K), -128, 127, jnp.int8)
    w = jax.random.randint(kw, (K, M), -30, 30, jnp.int8)
    shift = jnp.full((M,), 6, jnp.int32)
    got = np.asarray(gemm_int8(x, w, shift, relu=False, interpret=True),
                     np.int32)
    acc = np.asarray(x, np.int64) @ np.asarray(w, np.int64)
    seed_style = np.clip(np.round(acc.astype(np.float64) / 2.0 ** 6),
                         -128, 127).astype(np.int32)
    assert np.max(np.abs(got - seed_style)) <= 1
