"""Replicated serving: the least-estimated-wait router (warm pricing,
seeded cold power-of-two-choices, straggler avoidance) and the
:class:`ReplicaPool` behind it — routed replicated output must stay
bit-identical to the single-replica pipeline in both replica modes, and
per-replica outcome counts must reconcile exactly with fleet totals."""

import time

import jax
import numpy as np
import pytest

from repro.core import workload as W
from repro.core.program import compile_model
from repro.launch.mesh import device_slices
from repro.models import cnn
from repro.serving import LeastWaitRouter, ReplicaPool


def _tiny():
    """Small graph exercising every step kind (same shape as
    tests/test_serving.py's)."""
    m = W.CNNModel("tiny", 16, 4, (
        W.ConvLayer("c1", 4, 8, 3),
        W.ConvLayer("p1", 8, 8, 2, stride=2, kind="pool"),
        W.ConvLayer("c2", 8, 8, 3, groups=2),
        W.ConvLayer("fc", 8 * 8 * 8, 10, 1, kind="fc"),
    ))
    p = cnn.init_params(m, jax.random.PRNGKey(0))
    calib = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16, 4))
    prog = compile_model(m, p, bits=8, calib_batch=calib)
    frames = np.asarray(jax.random.normal(jax.random.PRNGKey(2),
                                          (11, 16, 16, 4)), np.float32)
    return prog, frames


class EchoExecutor:
    """Synchronous fake replica: optional fixed service delay, echoes
    the valid frames back as the batch output."""

    def __init__(self, batch_size=4, delay_s=0.0):
        self.batch_size = batch_size
        self.delay_s = delay_s
        self.on_result = None
        self.on_error = None
        self.batches = 0

    def submit_batch(self, frames, n_valid, tag=None):
        self.batches += 1
        if self.delay_s:
            time.sleep(self.delay_s)
        if self.on_result is not None:
            self.on_result(tag, np.asarray(frames)[:n_valid].copy())


# ---------------------------------------------------------------------------
# Router
# ---------------------------------------------------------------------------


def test_router_rejects_bad_inputs():
    with pytest.raises(ValueError):
        LeastWaitRouter(0, 4)
    with pytest.raises(ValueError):
        LeastWaitRouter(2, 4, straggler_factor=1.0)
    with pytest.raises(ValueError):
        LeastWaitRouter(2, 4, quarantine_after=0)
    with pytest.raises(ValueError):
        LeastWaitRouter(2, 4, probe_every=0)


def test_warm_least_wait_picks_the_idle_replica():
    """Warm pricing: wait(r) = inflight*window + latency. A busy replica
    prices one queued batch higher than an idle one, so the idle replica
    wins; symmetric ties break to the lowest index."""
    router = LeastWaitRouter(2, 4, seed=0)
    router.warm_start(0.010, 0.020)
    assert router.estimated_wait_s(0) == pytest.approx(0.020)
    assert router.pick() == 0          # symmetric tie -> index 0
    # Replica 0 now holds one in-flight batch: 1*0.010 + 0.020 prices
    # above idle replica 1's bare latency.
    assert router.estimated_wait_s(0) == pytest.approx(0.030)
    assert router.pick() == 1
    assert router.inflight(0) == router.inflight(1) == 1
    # Drain replica 1, keep 0 busy: the idle replica wins again.
    router.on_complete(1, 0.020)
    assert router.pick() == 1
    assert router.cold_picks == 0


def test_warm_router_prices_out_a_drifting_replica():
    """A replica whose latency EWMA drifts up loses the argmin without
    any dedicated straggler machinery."""
    router = LeastWaitRouter(2, 4, seed=0)
    router.warm_start(0.010, 0.020)
    r = router.pick()
    assert r == 0
    router.on_complete(0, 0.500)       # 25x the calibrated latency
    for _ in range(5):
        r = router.pick()
        assert r == 1
        router.on_complete(1, 0.020)


def test_reset_pricing_relevels_a_starved_replica():
    """The starvation-hysteresis bug the chaos fault replays flushed
    out: a replica left with a stale high latency EWMA after a
    saturated calibration pass loses every warm argmin, gets no new
    observations, and — being neither quarantined nor (at R=2, where
    its own EWMA drags the fleet median) straggler-flagged — is starved
    forever. warm_start alone cannot fix it (measurements outrank
    seeds); reset_pricing + warm_start must re-level the fleet."""
    router = LeastWaitRouter(2, 4, seed=0)
    router.warm_start(0.010, 0.020)
    router.on_complete(0, 0.500)       # calibration left 0 mispriced
    router.on_complete(1, 0.020)
    assert not router.is_straggler(0)  # median includes the victim
    # warm_start defers to the stale measurement: still starved.
    router.warm_start(0.010, 0.020)
    picks = [router.pick() for _ in range(4)]
    assert 0 not in picks
    for r in picks:
        router.on_complete(r, 0.020)
    # The replay-boundary re-level restores the symmetric tie.
    router.reset_pricing()
    router.warm_start(0.010, 0.020)
    assert router.estimated_wait_s(0) == pytest.approx(0.020)
    assert router.pick() == 0
    assert router.pick() == 1


def test_reset_pricing_clears_quarantine_and_streaks():
    """reset_pricing is a replay boundary: health verdicts reset with
    the pricing (a fresh replay earns fresh verdicts), while in-flight
    accounting and cumulative telemetry survive."""
    router = LeastWaitRouter(2, 4, seed=0, quarantine_after=2)
    for _ in range(2):
        router.pick()
    router.on_failure(0)
    router.on_failure(0)
    # One batch still in flight on replica 1 across the boundary.
    assert router.is_quarantined(0)
    router.reset_pricing()
    assert not router.is_quarantined(0)
    assert router.snapshot()["replicas"][0]["consecutive_failures"] == 0
    assert router.inflight(1) == 1
    assert router.quarantine_events == 1


def test_cold_power_of_two_choices_is_seeded_deterministic():
    """No warm start -> every pick is a cold p2c draw from the seeded
    RNG: two routers with the same seed reproduce the exact sequence."""
    a = LeastWaitRouter(4, 4, seed=7)
    b = LeastWaitRouter(4, 4, seed=7)
    seq_a = [a.pick() for _ in range(10)]
    seq_b = [b.pick() for _ in range(10)]
    assert seq_a == seq_b
    assert a.cold_picks == 10
    assert sum(a.picks) == 10
    # p2c keeps depths near-balanced: no replica hoards the draw.
    assert max(a.picks) <= 2 * (10 // 4 + 1)


def test_straggler_flagged_and_excluded_from_cold_draws():
    """A replica whose latency EWMA exceeds straggler_factor x the fleet
    median is flagged and sits out cold draws while healthy replicas
    exist."""
    router = LeastWaitRouter(4, 4, seed=3)
    for r, lat in enumerate([0.010, 0.011, 0.012, 1.0]):
        router.estimators[r].observe(4, lat)
    assert not router.is_straggler(0)
    assert router.is_straggler(3)
    # Window channels were never seeded -> every pick is cold.
    picks = [router.pick() for _ in range(30)]
    assert 3 not in picks
    assert router.straggler_skips > 0
    snap = router.snapshot()
    assert snap["replicas"][3]["straggler"] is True
    assert snap["replicas"][3]["picks"] == 0


def test_single_replica_fast_path():
    router = LeastWaitRouter(1, 4, seed=0)
    assert [router.pick() for _ in range(5)] == [0] * 5
    assert router.inflight(0) == 5
    assert router.cold_picks == 0


# ---------------------------------------------------------------------------
# ReplicaPool over fake executors
# ---------------------------------------------------------------------------


def test_pool_rejects_bad_config():
    with pytest.raises(ValueError):
        ReplicaPool(executors=[])
    with pytest.raises(ValueError):
        ReplicaPool(None, replicas=2, mode="nope")
    with pytest.raises(ValueError):
        ReplicaPool(None, replicas=2)    # no program, no executors


def test_pool_routes_and_reconciles_over_fakes():
    """Submission order survives routing (drain reorders by sequence
    number) and the per-replica outcome rows reconcile exactly with the
    fleet totals."""
    exs = [EchoExecutor(batch_size=4), EchoExecutor(batch_size=4)]
    pool = ReplicaPool(executors=exs)
    frames = [np.full((2, 2, 1), i, np.float32) for i in range(10)]
    out = pool.serve(frames)
    pool.close()
    assert len(out) == 10
    for i, f in enumerate(out):
        np.testing.assert_array_equal(f, frames[i])
    counts = pool.replica_counts()
    assert sum(r["dispatched_batches"] for r in counts) == 3   # 4+4+2
    assert sum(r["completed_batches"] for r in counts) == 3
    assert sum(r["completed_frames"] for r in counts) == 10
    assert sum(r["failed_batches"] for r in counts) == 0
    assert sum(ex.batches for ex in exs) == 3
    assert pool.stats.frames == 10
    assert pool.stats.padded_frames == 2                       # tail 2/4
    rows = pool.replica_rows()
    assert [r["replica"] for r in rows] == [0, 1]
    for r in rows:
        assert r["picks"] == r["dispatched_batches"]
        assert r["inflight"] == 0


def test_slowed_straggler_replica_gets_measurably_fewer_batches():
    """A warm-started pool over one fast and one deliberately slow fake:
    the slow replica's latency EWMA rises on its first picks and the
    router routes the rest of the stream away from it."""
    slow = EchoExecutor(batch_size=4, delay_s=0.005)
    fast = EchoExecutor(batch_size=4, delay_s=0.0)
    pool = ReplicaPool(executors=[slow, fast], router_seed=0)
    pool.router.warm_start(0.001, 0.002)
    batch = np.zeros((4, 2, 2, 1), np.float32)
    n = 24
    for _ in range(n):
        pool.submit_batch(batch, 4)
    pool.drain()
    pool.close()
    counts = pool.replica_counts()
    assert counts[0]["completed_batches"] + \
        counts[1]["completed_batches"] == n
    # Measurably fewer: the slow replica serves at most a quarter of the
    # stream (deterministically it gets only the first tie-break pick).
    assert counts[0]["completed_batches"] < counts[1]["completed_batches"]
    assert counts[0]["completed_batches"] <= n // 4


def test_pool_failure_releases_router_slot_and_is_accounted():
    class FailingExecutor(EchoExecutor):
        def submit_batch(self, frames, n_valid, tag=None):
            raise RuntimeError("replica died")

    pool = ReplicaPool(executors=[FailingExecutor(batch_size=4)])
    with pytest.raises(RuntimeError):
        pool.submit_batch(np.zeros((4, 2, 2, 1), np.float32), 4)
    assert pool.router.inflight(0) == 0
    counts = pool.replica_counts()
    assert counts[0]["failed_batches"] == 1
    assert counts[0]["failed_frames"] == 4
    assert pool.drain() == []          # the failed batch cannot hang drain
    pool.close()


# ---------------------------------------------------------------------------
# Quarantine + probe re-admission (dead-replica bugfix)
# ---------------------------------------------------------------------------


def test_router_quarantines_after_repeated_hard_failures():
    """Repeated hard failures quarantine a replica out of *all* live
    picks (warm and cold) — the straggler flag covers slow, not dead —
    and a completed batch (probe success) re-admits it."""
    router = LeastWaitRouter(2, 4, seed=0, quarantine_after=3)
    router.warm_start(0.010, 0.020)
    assert not router.is_quarantined(0)
    for _ in range(3):
        router.on_failure(0)
    assert router.is_quarantined(0)
    assert router.quarantine_events == 1
    # Every live pick now lands on the survivor, warm pricing included
    # (the corpse's frozen estimator would otherwise keep it attractive).
    for _ in range(10):
        r = router.pick()
        assert r == 1
        router.on_complete(1, 0.020)
    snap = router.snapshot()
    assert snap["replicas"][0]["quarantined"] is True
    assert snap["replicas"][0]["consecutive_failures"] == 3
    # Probe success = proof of life: re-admitted, streak cleared.
    router.on_complete(0, 0.020)
    assert not router.is_quarantined(0)
    assert router.readmissions == 1
    assert router.snapshot()["replicas"][0]["consecutive_failures"] == 0


def test_router_all_quarantined_still_serves():
    """With every replica quarantined the router must keep picking
    (failing fast beats deadlocking the pool)."""
    router = LeastWaitRouter(2, 4, seed=0, quarantine_after=1)
    router.on_failure(0)
    router.on_failure(1)
    assert router.is_quarantined(0) and router.is_quarantined(1)
    assert router.pick() in (0, 1)


def test_probe_target_beats_and_feedback():
    """probe_target nominates a quarantined replica every probe_every-th
    call, only while idle; a failed probe keeps the quarantine, a
    successful one re-admits."""
    router = LeastWaitRouter(2, 4, seed=0, quarantine_after=2,
                             probe_every=3)
    assert router.probe_target() is None        # nothing injured: no tick
    router.on_failure(0)
    router.on_failure(0)
    assert router.is_quarantined(0)
    assert router.probe_target() is None        # tick 1
    assert router.probe_target() is None        # tick 2
    p = router.probe_target()                   # tick 3 -> probe due
    assert p == 0
    assert router.probe_picks == 1
    assert router.inflight(0) == 1              # probe holds a slot
    router.on_failure(0)                        # probe failed
    assert router.is_quarantined(0)
    for _ in range(2):
        assert router.probe_target() is None
    assert router.probe_target() == 0
    router.on_complete(0, 0.010)                # probe succeeded
    assert not router.is_quarantined(0)
    assert router.readmissions == 1


class FlakyExecutor(EchoExecutor):
    """Fake replica that hard-fails every dispatch in a batch-count
    window (its own 1-based counter), then recovers."""

    def __init__(self, dead_from=3, dead_to=8, **kw):
        super().__init__(**kw)
        self.dead_from, self.dead_to = dead_from, dead_to

    def submit_batch(self, frames, n_valid, tag=None):
        self.batches += 1
        if self.dead_from <= self.batches <= self.dead_to:
            raise RuntimeError("replica down")
        if self.on_result is not None:
            self.on_result(tag, np.asarray(frames)[:n_valid].copy())


def test_pool_kill_mid_stream_quarantines_steers_and_readmits():
    """The kill-mid-stream regression: a replica that dies mid-stream is
    quarantined after quarantine_after consecutive hard failures (before
    this fix the router kept picking the corpse forever), the survivor
    absorbs the stream, probe batches — not live requests — keep
    checking the victim, and the first probe success re-admits it."""
    victim = FlakyExecutor(batch_size=4, dead_from=3, dead_to=8)
    survivor = EchoExecutor(batch_size=4, delay_s=0.005)
    pool = ReplicaPool(executors=[victim, survivor], router_seed=0,
                       quarantine_after=3, probe_every=2)
    pool.router.warm_start(0.001, 0.002)
    batch = np.zeros((4, 2, 2, 1), np.float32)
    n, raised = 24, 0
    for _ in range(n):
        try:
            pool.submit_batch(batch, 4)
        except RuntimeError:
            raised += 1
    out = pool.drain()
    pool.close()
    router = pool.router
    counts = pool.replica_counts()
    # Exactly quarantine_after live batches were sacrificed to discover
    # the death; every later failure is a probe (invisible to callers).
    assert raised == 3
    assert counts[0]["failed_batches"] == 3
    assert counts[1]["failed_batches"] == 0
    assert router.quarantine_events == 1
    # The victim recovered (its fake comes back at batch 9): a probe
    # re-admitted it and live traffic returned to it.
    assert router.readmissions == 1
    assert not router.is_quarantined(0)
    assert counts[0]["probe_batches"] >= 2
    assert router.probe_picks == counts[0]["probe_batches"]
    assert counts[0]["completed_batches"] > 2   # pre-death + post-readmit
    # Liveness: every live batch resolved — completed or raised — and
    # probe outputs never leak into the drained results.
    assert sum(c["completed_batches"] for c in counts) + raised == n
    assert len(out) == (n - raised) * 4


# ---------------------------------------------------------------------------
# Straggler decay (degrade -> recover bugfix)
# ---------------------------------------------------------------------------


def test_straggler_flag_decays_when_ewma_reenters_band():
    """Degrade -> recover: a flagged straggler is excluded from cold
    draws, but probe completions keep feeding its EWMA, and once it
    re-enters band the (dynamic) flag clears and the replica rejoins the
    draw — before this fix an excluded replica got no observations and
    stayed excluded forever."""
    router = LeastWaitRouter(4, 4, seed=3, probe_every=4)
    for r, lat in enumerate([0.010, 0.011, 0.012, 1.0]):
        router.estimators[r].observe(4, lat)
    assert router.is_straggler(3)
    # Excluded from live cold draws...
    picks = [router.pick() for _ in range(12)]
    assert 3 not in picks
    # ...but probe_target still nominates it (the decay path): inflight
    # from the live picks above sits on 0..2, never 3.
    probed = [router.probe_target() for _ in range(4)]
    assert probed[:3] == [None, None, None] and probed[3] == 3
    router.on_complete(3, 0.011)
    # Recovery: fast probe completions walk the EWMA back into band.
    for _ in range(40):
        if not router.is_straggler(3):
            break
        p = None
        while p is None:
            p = router.probe_target()
        assert p == 3
        router.on_complete(3, 0.011)
    assert not router.is_straggler(3)
    # Back in the cold draw: the seeded p2c reaches it again.
    picks = [router.pick() for _ in range(40)]
    assert 3 in picks


def test_device_slices_contiguous_cover_and_wrap():
    devs = list("abcdefgh")
    sl = device_slices(3, devs)
    assert [len(s) for s in sl] == [3, 3, 2]
    assert [d for s in sl for d in s] == devs       # contiguous cover
    assert device_slices(4, ["x"]) == [["x"]] * 4   # wrap when R >= D
    with pytest.raises(ValueError):
        device_slices(0, devs)
    with pytest.raises(ValueError):
        device_slices(2, [])


# ---------------------------------------------------------------------------
# Bit-identity (the acceptance bar): routed replicas == single-jit chain
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["pipeline", "stage-shard"])
def test_replicated_pool_bit_identical_both_modes(mode):
    """Routing only chooses *where* a micro-batch runs: the routed
    2-replica pool's output equals the single-jit chain bit for bit in
    both replica modes, tail padding included."""
    prog, frames = _tiny()
    want = prog.compile_runner().logits(frames)
    with ReplicaPool(prog, replicas=2, mode=mode, stages=2, batch_size=4,
                     output="logits") as pool:
        got = np.stack(pool.serve(list(frames)))
    np.testing.assert_array_equal(got, want)
    assert pool.n_replicas == 2
    assert len(pool.replica_devices) == 2
    counts = pool.replica_counts()
    assert sum(r["completed_batches"] for r in counts) == 3    # 11/4
    assert sum(r["completed_frames"] for r in counts) == len(frames)
    assert pool.stats.padded_frames == 1
