"""Multi-tenant serving engine (``repro.serving.server``): the program
registry's typed errors, the build -> serve -> stats -> close lifecycle
over a real four-model registry with interleaved tagged traffic, tenant
fairness under a one-tenant flood (the isolation acceptance), and the
Executor protocol conformance of everything the frontend can drive."""

import threading
import time

import jax
import numpy as np
import pytest

from repro.core import workload as W
from repro.core.program import compile_model
from repro.models import cnn
from repro.serving import (AsyncFrontend, Executor, ProgramRegistry,
                           Server, ServerConfig, TenantMux,
                           UnknownModelError, build_server)


def _tiny_model(name: str, hw: int, ch: int, seed: int, bits: int = 8):
    """One small compiled program per 'model' — distinct input shapes so
    cross-tenant frame mixups cannot pass shape validation silently."""
    m = W.CNNModel(name, hw, ch, (
        W.ConvLayer("c1", ch, 8, 3),
        W.ConvLayer("p1", 8, 8, 2, stride=2, kind="pool"),
        W.ConvLayer("fc", 8 * (hw // 2) ** 2, 10, 1, kind="fc"),
    ))
    p = cnn.init_params(m, jax.random.PRNGKey(seed))
    calib = jax.random.normal(jax.random.PRNGKey(seed + 1),
                              (2, hw, hw, ch))
    return compile_model(m, p, bits=bits, calib_batch=calib)


ZOO = (("m-a", 8, 3), ("m-b", 8, 4), ("m-c", 12, 3), ("m-d", 12, 4))


def _zoo_registry():
    reg = ProgramRegistry()
    for i, (name, hw, ch) in enumerate(ZOO):
        reg.register(name, _tiny_model(name, hw, ch, seed=10 * i))
    return reg


def _streams(n=12, seed=7):
    rng = np.random.default_rng(seed)
    return {name: rng.standard_normal((n, hw, hw, ch)).astype(np.float32)
            for name, hw, ch in ZOO}


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------


def test_registry_typed_errors_and_order():
    reg = ProgramRegistry()
    reg.register("alex", object())
    reg.register("zf", object())
    assert reg.names() == ("alex", "zf")      # insertion order kept
    assert "alex" in reg and len(reg) == 2
    with pytest.raises(ValueError):
        reg.register("alex", object())        # duplicate id refused
    with pytest.raises(UnknownModelError) as ei:
        reg.get("vgg")
    # The error is typed (a KeyError subclass) and names the catalogue.
    assert isinstance(ei.value, KeyError)
    assert "vgg" in str(ei.value) and "alex" in str(ei.value)


def test_unknown_model_error_lists_ids_sorted():
    """Deterministic messages: the registered ids in the error read
    sorted regardless of registration order."""
    reg = ProgramRegistry()
    for name in ("zf", "alex", "mid"):
        reg.register(name, object())
    with pytest.raises(UnknownModelError) as ei:
        reg.get("ghost")
    msg = str(ei.value)
    assert "registered: alex, mid, zf" in msg


def test_register_refuses_same_shape_different_bits():
    """Frames are validated by shape at submit; two models with the
    same input shape but different bit widths would take each other's
    frames under different integer formats — refused at register."""
    reg = ProgramRegistry()
    reg.register("m8", _tiny_model("m8", 8, 3, seed=0))
    p16 = _tiny_model("m16", 8, 3, seed=1, bits=16)
    with pytest.raises(ValueError) as ei:
        reg.register("m16", p16)
    assert "dtype" in str(ei.value) and "m8" in str(ei.value)
    # Same bits, same shape: fine (tenant routing is by model id).
    reg.register("m8b", _tiny_model("m8b", 8, 3, seed=2))
    # Different shape, different bits: no ambiguity, fine.
    reg.register("m16w", _tiny_model("m16w", 12, 3, seed=3, bits=16))
    # Opaque stand-ins (no model/bits contract) skip the check.
    reg.register("fake", object())


def test_per_model_replicas_dict():
    """ServerConfig.replicas as {model: R}: the named tenant gets a
    routed pool of R replicas, unnamed tenants serve unreplicated, and
    a dict naming an unregistered model is refused before any executor
    starts."""
    cfg = ServerConfig(replicas={"hot": 3})
    assert cfg.replicas_for("hot") == 3
    assert cfg.replicas_for("cold") == 1
    assert ServerConfig(replicas=2).replicas_for("anything") == 2

    reg = ProgramRegistry()
    reg.register("hot", _tiny_model("hot", 8, 3, seed=0))
    reg.register("cold", _tiny_model("cold", 12, 3, seed=1))
    streams = {
        "hot": np.zeros((12, 8, 8, 3), np.float32),
        "cold": np.zeros((12, 12, 12, 3), np.float32),
    }
    with pytest.raises(ValueError) as ei:
        build_server(reg, ServerConfig(batch=4, stages=1,
                                       replicas={"ghost": 2}),
                     streams=streams)
    assert "ghost" in str(ei.value)

    srv = build_server(reg, ServerConfig(batch=4, stages=1,
                                         replicas={"hot": 2}),
                       streams=streams)
    try:
        assert getattr(srv.runtime("hot").executor, "n_replicas", 1) == 2
        assert getattr(srv.runtime("cold").executor, "n_replicas", 1) == 1
        st = srv.stats()
        assert st["models"]["hot"]["replicas"] == 2
        assert st["models"]["cold"]["replicas"] == 1
    finally:
        srv.close()


def test_build_server_refuses_empty_registry_and_short_streams():
    with pytest.raises(ValueError):
        build_server(ProgramRegistry(), ServerConfig())
    reg = ProgramRegistry()
    reg.register("m-a", _tiny_model("m-a", 8, 3, seed=0))
    short = {"m-a": np.zeros((4, 8, 8, 3), np.float32)}
    with pytest.raises(ValueError):
        build_server(reg, ServerConfig(batch=4, stages=1), streams=short)


# ---------------------------------------------------------------------------
# Four-model registry, interleaved tagged traffic
# ---------------------------------------------------------------------------


def test_four_model_interleaved_traffic_reconciles_per_tenant():
    """The tentpole acceptance: four compiled models behind one
    frontend, requests tagged with their model id and interleaved
    round-robin; every request resolves through its own model's
    executor, results are deterministic per (model, frame), unknown ids
    and wrong-shape frames are refused at submit, and the per-tenant
    stats rollups reconcile exactly with what each tenant submitted."""
    reg = _zoo_registry()
    streams = _streams()
    cfg = ServerConfig(batch=4, stages=1, calib_frames=12)
    srv = build_server(reg, cfg, streams=streams)
    n_each = 8
    try:
        reqs = {name: [] for name, _, _ in ZOO}
        for i in range(n_each):                 # interleaved by model
            for name, _, _ in ZOO:
                reqs[name].append(srv.submit(name, streams[name][i]))
        for name in reqs:
            for r in reqs[name]:
                r.result(timeout=120)

        # Determinism: resubmitting a frame gives the same class id.
        again = srv.submit("m-a", streams["m-a"][0]).result(timeout=120)
        assert int(again) == int(reqs["m-a"][0].result(timeout=1))

        with pytest.raises(UnknownModelError):
            srv.submit("nope", streams["m-a"][0])
        with pytest.raises(ValueError):         # m-b frames are 8x8x4
            srv.submit("m-a", streams["m-b"][0])

        st = srv.stats()
        assert set(st["models"]) == {name for name, _, _ in ZOO}
        for name, row in st["models"].items():
            want = n_each + (1 if name == "m-a" else 0)
            assert row["submitted"] == row["completed"] == want
            assert row["failed"] == row["expired"] == row["rejected"] == 0
            assert row["steady_fps"] > 0
            assert row["latency_ms_p50"] is not None
        assert st["totals"]["submitted"] == 4 * n_each + 1
        assert st["totals"]["completed"] == st["totals"]["submitted"]
    finally:
        srv.close()
    srv.close()                                 # idempotent
    with pytest.raises(RuntimeError):
        srv.submit("m-a", streams["m-a"][0])    # closed: typed, no hang


def test_unknown_model_rejected_fast_never_hangs():
    """An unregistered id must fail in microseconds at submit — before
    any queue — not time out somewhere in the batcher."""
    reg = ProgramRegistry()
    reg.register("only", _tiny_model("only", 8, 3, seed=0))
    streams = {"only": np.zeros((12, 8, 8, 3), np.float32)}
    srv = build_server(reg, ServerConfig(batch=4, stages=1),
                       streams=streams)
    try:
        t0 = time.perf_counter()
        with pytest.raises(UnknownModelError):
            srv.submit("ghost", streams["only"][0])
        assert time.perf_counter() - t0 < 1.0
    finally:
        srv.close()


# ---------------------------------------------------------------------------
# Fairness / isolation (deterministic fakes, no compile)
# ---------------------------------------------------------------------------


class EchoExecutor:
    """Protocol-conformant fake with a fixed per-batch service time;
    records the tenant of every batch it served."""

    def __init__(self, batch_size=4, delay_s=0.002):
        self.batch_size = batch_size
        self.delay_s = delay_s
        self.program = None
        self.on_result = None
        self.on_error = None
        self.served_tenants = []

    def submit_batch(self, frames, n_valid, tag=None):
        assert tag, "frontend batches are always tagged"
        tenants = {r.tenant for r in tag}
        assert len(tenants) == 1, f"mixed-tenant batch: {tenants}"
        self.served_tenants.append(next(iter(tenants)))
        time.sleep(self.delay_s)
        if self.on_result:
            self.on_result(tag, [f.copy() for f in frames[:n_valid]])

    def flush_inflight(self):
        pass

    def reset_stats(self):
        pass

    def replica_counts(self):
        return None


FRAME = np.zeros((2, 2, 1), np.float32)


def test_tenant_flood_does_not_starve_other_tenants_armed_traffic():
    """The isolation acceptance: tenant A floods its lane far beyond
    capacity while tenant B trickles deadline-armed requests. Weighted
    round-robin must keep serving B between A's batches, so B's armed
    traffic never expires — A's overload stays A's problem."""
    mux = TenantMux({"a": EchoExecutor(delay_s=0.005),
                     "b": EchoExecutor(delay_s=0.005)}, batch_size=4)
    fe = AsyncFrontend(mux, max_wait_ms=4.0, max_queue=4096)
    flood = [fe.submit(FRAME, tenant="a", klass="bulk", timeout=10)
             for _ in range(400)]
    b_reqs = []
    for _ in range(10):
        b_reqs.append(fe.submit(FRAME, tenant="b", klass="rt",
                                deadline_ms=400.0, timeout=10))
        time.sleep(0.01)
    for r in b_reqs:
        assert r._event.wait(timeout=30), "tenant B request hung"
    for r in flood:
        assert r._event.wait(timeout=60), "tenant A request hung"
    fe.close()
    mux.close()

    st = fe.stats
    tb = st.tenant_row("b")
    assert tb.submitted == 10
    assert tb.expired == 0, "tenant A's flood starved tenant B"
    assert tb.completed == 10
    ta = st.tenant_row("a")
    assert ta.submitted == 400
    assert ta.completed + ta.expired == 400     # no armed traffic in A
    # Interleave really happened: B's batches were served while A still
    # had a backlog (B appears before the last A batch).
    order = mux.children["b"].served_tenants
    assert order, "tenant B's executor never served a batch"


def test_tenant_shares_bias_the_sweep():
    """A 3:1 share split must show up in the *order* batches are opened
    while both lanes are saturated (totals are fixed by the
    submissions, so fairness is visible only in the sweep sequence)."""
    order: list[str] = []
    ex = {"big": EchoExecutor(delay_s=0.004),
          "small": EchoExecutor(delay_s=0.004)}
    for e in ex.values():
        e.served_tenants = order        # shared: global service order
    mux = TenantMux(ex, batch_size=4)
    fe = AsyncFrontend(mux, max_wait_ms=2.0, max_queue=4096,
                       tenant_shares={"big": 3.0, "small": 1.0})
    reqs = []
    for i in range(300):
        reqs.append(fe.submit(FRAME, tenant="big", timeout=10))
        reqs.append(fe.submit(FRAME, tenant="small", timeout=10))
    for r in reqs:
        assert r._event.wait(timeout=60)
    fe.close()
    mux.close()
    # While both lanes were saturated (big drains 3x faster, so its 75
    # batches are done well before small's): in the window where big
    # still had work, it was picked ~3x as often.
    last_big = max(i for i, t in enumerate(order) if t == "big")
    window = order[:last_big + 1]
    big = window.count("big")
    small = window.count("small")
    assert big == 75 and small > 0
    assert big >= 2 * small, \
        f"shares ignored in sweep order: big={big} small={small}"


# ---------------------------------------------------------------------------
# Protocol conformance
# ---------------------------------------------------------------------------


def test_executor_protocol_conformance():
    """Everything the frontend can drive satisfies the runtime-checkable
    protocol; a bare object is refused with a TypeError naming the
    missing members."""
    assert isinstance(EchoExecutor(), Executor)
    assert isinstance(TenantMux({"t": EchoExecutor()}, batch_size=4),
                      Executor)

    class NotAnExecutor:
        batch_size = 4

    with pytest.raises(TypeError) as ei:
        AsyncFrontend(NotAnExecutor(), max_wait_ms=5.0)
    assert "submit_batch" in str(ei.value)
    assert "replica_counts" in str(ei.value)


def test_server_over_fakes_is_cheap_to_reason_about():
    """Server plumbing without compiles: TenantMux refuses executors
    that already have a result consumer, and close() is idempotent on
    the mux too."""
    ex = EchoExecutor()
    ex.on_result = lambda tag, out: None
    with pytest.raises(ValueError):
        TenantMux({"t": ex}, batch_size=4)
    mux = TenantMux({"t": EchoExecutor()}, batch_size=4)
    mux.close()
    mux.close()
    assert Server is not None and ServerConfig is not None


# ---------------------------------------------------------------------------
# Live rescale (drain -> swap -> resume)
# ---------------------------------------------------------------------------


def test_rescale_live_one_model():
    """R 1 -> 2 on a serving one-model server: traffic before and after
    the swap completes, the event records the topology transition and
    both timing halves, the runtime's executor/calibration are
    replaced, and close() tears the rescaled fleet down cleanly."""
    reg = ProgramRegistry()
    name, hw, ch = ZOO[0]
    reg.register(name, _tiny_model(name, hw, ch, seed=0))
    srv = build_server(reg, ServerConfig(batch=4, stages=1, replicas=1))
    frame = np.zeros((hw, hw, ch), np.float32)
    assert srv.submit(name, frame).result(timeout=30) is not None

    ev = srv.rescale(name, replicas=2)
    assert ev["model"] == name
    assert ev["before"]["replicas"] == 1
    assert ev["after"]["replicas"] == 2
    assert ev["compile_s"] >= 0 and ev["swap_s"] >= 0
    assert ev["swapped_frontends"] >= 1
    rt = srv.runtime(name)
    assert getattr(rt.executor, "n_replicas", 1) == 2
    assert rt.steady_fps > 0          # recalibrated on the new fleet

    # The same frontend keeps serving on the rescaled executor.
    assert srv.submit(name, frame).result(timeout=30) is not None
    st = srv.stats()
    assert st["models"][name]["replicas"] == 2
    assert st["totals"]["submitted"] == 2
    srv.close()


def test_rescale_validation_errors():
    reg = ProgramRegistry()
    for name, hw, ch in ZOO[:2]:
        reg.register(name, _tiny_model(name, hw, ch, seed=1))
    srv = build_server(reg, ServerConfig(batch=4, stages=1))
    try:
        # Multi-model: the model must be named ...
        with pytest.raises(ValueError, match="explicit model_id"):
            srv.rescale(replicas=2)
        # ... the id must exist ...
        with pytest.raises(UnknownModelError):
            srv.rescale("ghost", replicas=2)
        # ... a no-op delta is a caller bug ...
        name = ZOO[0][0]
        with pytest.raises(ValueError, match="nothing to change"):
            srv.rescale(name)
        # ... and the micro-batch size is fleet-wide.
        with pytest.raises(ValueError, match="fleet-wide"):
            srv.rescale(name, batch=8)
    finally:
        srv.close()
    with pytest.raises(RuntimeError):
        srv.rescale(ZOO[0][0], replicas=2)   # closed server
