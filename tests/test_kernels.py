"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypcompat import given, settings, st

from repro.kernels.conv2d_int8 import ref as conv_ref
from repro.kernels.conv2d_int8.ops import conv2d_int8
from repro.kernels.conv2d_int8.kernel import gemm_int8
from repro.kernels.flash_attention import ref as attn_ref
from repro.kernels.flash_attention.ops import attention
from repro.kernels.rglru_scan import ref as scan_ref
from repro.kernels.rglru_scan.ops import rglru_scan


@pytest.mark.parametrize("n,k,m", [(17, 40, 33), (128, 128, 128),
                                   (300, 100, 260), (1, 9, 1)])
def test_gemm_int8_shapes(n, k, m):
    key = jax.random.PRNGKey(n * k + m)
    kx, kw = jax.random.split(key)
    x = jax.random.randint(kx, (n, k), -128, 127, jnp.int8)
    w = jax.random.randint(kw, (k, m), -50, 50, jnp.int8)
    shift = jax.random.randint(jax.random.fold_in(key, 2), (m,), 0, 12,
                               jnp.int32)
    got = gemm_int8(x, w, shift, interpret=True)
    want = conv_ref.gemm_int8_ref(x, w, shift)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("shape", [(1, 12, 12, 16, 32, 3, 1),
                                   (2, 9, 9, 8, 24, 5, 2),
                                   (1, 7, 7, 3, 8, 1, 1),
                                   (1, 10, 10, 4, 8, 7, 2)])
def test_conv2d_int8_vs_ref(shape):
    B, H, W, C, M, R, stride = shape
    key = jax.random.PRNGKey(sum(shape))
    kx, kw = jax.random.split(key)
    x = jax.random.randint(kx, (B, H, W, C), -128, 127, jnp.int8)
    w = jax.random.randint(kw, (R, R, C, M), -30, 30, jnp.int8)
    shift = jnp.full((M,), 7, jnp.int32)
    got = conv2d_int8(x, w, shift, stride=stride, interpret=True)
    want = conv_ref.conv2d_int8_ref(x, w, shift, stride=stride)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("B,S,D,chunk", [(1, 64, 8, 16), (2, 128, 32, 64),
                                         (3, 96, 16, 32), (1, 256, 128, 256)])
def test_linear_scan_vs_ref(B, S, D, chunk):
    key = jax.random.PRNGKey(B * S * D)
    ka, kb = jax.random.split(key)
    a = jax.random.uniform(ka, (B, S, D), jnp.float32, 0.7, 0.999)
    b = jax.random.normal(kb, (B, S, D), jnp.float32)
    got = rglru_scan(a, b, chunk=chunk, interpret=True)
    want = scan_ref.linear_scan_ref(a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@given(st.integers(1, 3), st.sampled_from([64, 128]),
       st.sampled_from([1, 2]), st.sampled_from([32, 64]),
       st.booleans())
@settings(max_examples=8, deadline=None)
def test_flash_attention_property(B, S, H, d, causal):
    key = jax.random.PRNGKey(B * S + H * d)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, S, H, d), jnp.float32)
    k = jax.random.normal(kk, (B, S, H, d), jnp.float32)
    v = jax.random.normal(kv, (B, S, H, d), jnp.float32)
    got = attention(q, k, v, causal=causal, interpret=True)
    want = attn_ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("B,S,H,d,causal", [(1, 64, 1, 32, False),
                                            (2, 128, 2, 64, True)])
def test_flash_attention_fixed_cases(B, S, H, d, causal):
    """Deterministic fallback for test_flash_attention_property."""
    key = jax.random.PRNGKey(B * S + H * d)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, S, H, d), jnp.float32)
    k = jax.random.normal(kk, (B, S, H, d), jnp.float32)
    v = jax.random.normal(kv, (B, S, H, d), jnp.float32)
    got = attention(q, k, v, causal=causal, interpret=True)
    want = attn_ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes_window(dtype):
    key = jax.random.PRNGKey(7)
    kq, kk, kv = jax.random.split(key, 3)
    B, S, H, d = 1, 256, 2, 64
    q = jax.random.normal(kq, (B, S, H, d), dtype)
    k = jax.random.normal(kk, (B, S, H, d), dtype)
    v = jax.random.normal(kv, (B, S, H, d), dtype)
    got = attention(q, k, v, causal=True, window=64, interpret=True)
    want = attn_ref.attention_ref(q.astype(jnp.float32),
                                  k.astype(jnp.float32),
                                  v.astype(jnp.float32), causal=True,
                                  window=64)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want), rtol=tol, atol=tol)


def test_model_block_uses_scan_kernel_equivalence():
    """RG-LRU model path (associative scan) == chunked kernel semantics."""
    key = jax.random.PRNGKey(3)
    B, S, D = 2, 64, 16
    a = jax.random.uniform(key, (B, S, D), jnp.float32, 0.8, 0.99)
    b = jax.random.normal(jax.random.fold_in(key, 1), (B, S, D), jnp.float32)
    want = scan_ref.linear_scan_ref(a, b)
    got = rglru_scan(a, b, chunk=16, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5,
                               atol=2e-5)


def test_gemm_int8_emit_int32():
    key = jax.random.PRNGKey(11)
    kx, kw = jax.random.split(key)
    x = jax.random.randint(kx, (64, 72), -128, 127, jnp.int8)
    w = jax.random.randint(kw, (72, 40), -50, 50, jnp.int8)
    got = gemm_int8(x, w, jnp.zeros((40,), jnp.int32), interpret=True,
                    emit_int32=True)
    want = jnp.matmul(x.astype(jnp.int32), w.astype(jnp.int32))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_cnn_kernel_path_bit_exact():
    """The Pallas PE-array kernel inside the full AlexNet fixed-point
    forward matches the jnp path bit-for-bit."""
    from repro.core import workload as W
    from repro.models import cnn
    m = W.CNN_MODELS["alexnet"]()
    p = cnn.init_params(m, jax.random.PRNGKey(1))
    x = jax.random.normal(jax.random.PRNGKey(2),
                          (1, m.input_hw, m.input_hw, 3))
    y_jnp = cnn.forward(p, m, x, quantized=True, bits=8)
    y_ker = cnn.forward(p, m, x, quantized=True, bits=8, use_kernel=True)
    np.testing.assert_array_equal(np.asarray(y_jnp), np.asarray(y_ker))


def test_autotuner_picks_feasible_aligned_blocks():
    from repro.kernels.autotune import (VMEM_BUDGET, pick_attention_blocks,
                                        pick_gemm_blocks)
    c = pick_gemm_blocks(50176, 576, 128, in_bytes=1)
    assert c.vmem_bytes <= VMEM_BUDGET
    assert c.bn % 128 == 0 and c.bm % 128 == 0 and c.bk % 128 == 0
    a = pick_attention_blocks(32768, 128)
    assert a.vmem_bytes <= VMEM_BUDGET
    assert a.bq % 128 == 0 and a.bkv % 128 == 0
    # bigger q tiles amortize kv re-reads: the tuner must not pick the
    # smallest q tile when VMEM allows larger
    assert a.bq >= 256
