PYTHON ?= python

# Tier-1 verify (ROADMAP.md): the full suite on CPU.
.PHONY: test
test:
	PYTHONPATH=src $(PYTHON) -m pytest -x -q

.PHONY: test-fast
test-fast:
	PYTHONPATH=src $(PYTHON) -m pytest -x -q -m "not slow"

.PHONY: bench
bench:
	PYTHONPATH=src:. $(PYTHON) benchmarks/run.py all
