PYTHON ?= python

# Tier-1 verify (ROADMAP.md): the full suite on CPU.
.PHONY: test
test:
	PYTHONPATH=src $(PYTHON) -m pytest -x -q

.PHONY: test-fast
test-fast:
	PYTHONPATH=src $(PYTHON) -m pytest -x -q -m "not slow"

.PHONY: bench
bench:
	PYTHONPATH=src:. $(PYTHON) benchmarks/run.py all

# Exactly what the CI bench-smoke job runs (AlexNet-only, small batch).
.PHONY: bench-quick
bench-quick:
	PYTHONPATH=src:. $(PYTHON) benchmarks/serve_bench.py --quick --out BENCH_serve.json
	PYTHONPATH=src:. $(PYTHON) benchmarks/serve_async_bench.py --quick --out BENCH_serve_async.json
	PYTHONPATH=src:. $(PYTHON) benchmarks/table1.py --quick
	PYTHONPATH=src:. $(PYTHON) benchmarks/validate_bench.py BENCH_serve.json BENCH_serve_async.json

# Full async serving sweep (all four models, K in {1,2,4}, batch 32).
.PHONY: bench-async
bench-async:
	PYTHONPATH=src:. $(PYTHON) benchmarks/serve_async_bench.py --out BENCH_serve_async.json
	PYTHONPATH=src:. $(PYTHON) benchmarks/validate_bench.py BENCH_serve_async.json

.PHONY: lint
lint:
	ruff check src tests benchmarks examples
