PYTHON ?= python

# Tier-1 verify (ROADMAP.md): the full suite on CPU. Stress-marked
# tests (tests/test_serving_stress.py) run in their own lane below —
# deterministic, but thread-heavy enough to keep out of the -x gate.
.PHONY: test
test:
	PYTHONPATH=src $(PYTHON) -m pytest -x -q -m "not stress"

.PHONY: test-fast
test-fast:
	PYTHONPATH=src $(PYTHON) -m pytest -x -q -m "not slow and not stress"

# Multi-producer stress lane (8 submitter threads x 64 frames etc.).
# Blocking in CI: each test gets a hard timeout (when pytest-timeout is
# installed — requirements-dev.txt; probed so a bare container without
# it still runs the lane), and a failed run gets exactly one retry of
# the failed tests — shared two-core runners can starve 8 submitter
# threads once, but a real regression fails twice.
STRESS_TIMEOUT := $(shell $(PYTHON) -c "import pytest_timeout" 2>/dev/null \
	&& echo --timeout=120 --timeout-method=thread)
.PHONY: test-stress
test-stress:
	PYTHONPATH=src $(PYTHON) -m pytest -q -m stress $(STRESS_TIMEOUT) \
		|| PYTHONPATH=src $(PYTHON) -m pytest -q -m stress --last-failed \
			$(STRESS_TIMEOUT)

.PHONY: bench
bench:
	PYTHONPATH=src:. $(PYTHON) benchmarks/run.py all

# Compiler front door smoke: import the example LeNet spec, verify its
# int8 golden across MAC routes, and serve it through build_server.
# Dependency-free (JSON path) — the same command CI runs.
.PHONY: import-smoke
import-smoke:
	PYTHONPATH=src $(PYTHON) -m repro.launch.import_model examples/lenet.json --serve-frames 6 --batch 4 --stages 1

# Exactly what the CI bench-smoke job runs (AlexNet-only, small batch):
# build every artifact, schema-validate them, and gate against the
# committed reference bands in benchmarks/baselines/.
.PHONY: bench-quick
bench-quick:
	PYTHONPATH=src:. $(PYTHON) benchmarks/serve_bench.py --quick --out BENCH_serve.json
	PYTHONPATH=src:. $(PYTHON) benchmarks/serve_async_bench.py --quick --out BENCH_serve_async.json
	PYTHONPATH=src:. $(PYTHON) benchmarks/serve_qos_bench.py --quick --out BENCH_serve_qos.json
	XLA_FLAGS=--xla_force_host_platform_device_count=4 PYTHONPATH=src:. $(PYTHON) benchmarks/serve_knee_bench.py --quick --arrival poisson --replicas-sweep 1,2,4 --rescale --out BENCH_serve_knee.json
	PYTHONPATH=src:. $(PYTHON) benchmarks/serve_multi_bench.py --quick --out BENCH_serve_multi.json
	PYTHONPATH=src:. $(PYTHON) benchmarks/serve_chaos_bench.py --quick --out BENCH_serve_chaos.json
	PYTHONPATH=src:. $(PYTHON) benchmarks/table1.py --quick
	PYTHONPATH=src:. $(PYTHON) benchmarks/validate_bench.py --baseline benchmarks/baselines BENCH_serve.json BENCH_serve_async.json BENCH_serve_qos.json BENCH_serve_knee.json BENCH_serve_multi.json BENCH_serve_chaos.json

# Full async serving sweep (all four models, K in {1,2,4}, batch 32).
.PHONY: bench-async
bench-async:
	PYTHONPATH=src:. $(PYTHON) benchmarks/serve_async_bench.py --out BENCH_serve_async.json
	PYTHONPATH=src:. $(PYTHON) benchmarks/validate_bench.py BENCH_serve_async.json

# Full QoS sweep (mixed traffic classes at 0.6x / 1.2x load).
.PHONY: bench-qos
bench-qos:
	PYTHONPATH=src:. $(PYTHON) benchmarks/serve_qos_bench.py --out BENCH_serve_qos.json
	PYTHONPATH=src:. $(PYTHON) benchmarks/validate_bench.py BENCH_serve_qos.json

# Full QPS-knee sweep (all four models; the headline capacity number).
.PHONY: bench-knee
bench-knee:
	PYTHONPATH=src:. $(PYTHON) benchmarks/serve_knee_bench.py --out BENCH_serve_knee.json
	PYTHONPATH=src:. $(PYTHON) benchmarks/validate_bench.py BENCH_serve_knee.json

# Multi-tenant model zoo (all four paper CNNs behind one frontend):
# aggregate mixed-traffic knee + the tenant-isolation flood headline.
.PHONY: bench-multi
bench-multi:
	PYTHONPATH=src:. $(PYTHON) benchmarks/serve_multi_bench.py --out BENCH_serve_multi.json
	PYTHONPATH=src:. $(PYTHON) benchmarks/validate_bench.py BENCH_serve_multi.json

# Chaos serving (all four models): adversarial-arrival knee sweeps
# (on/off, lognormal, Pareto, diurnal beside the uniform baseline) plus
# the replica-kill / straggler / bus-drop fault replays, gated on
# liveness (hung == 0, resolved_frac == 1.0).
.PHONY: bench-chaos
bench-chaos:
	PYTHONPATH=src:. $(PYTHON) benchmarks/serve_chaos_bench.py --out BENCH_serve_chaos.json
	PYTHONPATH=src:. $(PYTHON) benchmarks/validate_bench.py BENCH_serve_chaos.json

# Knee-vs-R replication sweep (the PR headline): 4 forced host devices,
# R in {1,2,4} routed replicas, uniform + poisson arrivals. R>1 brackets
# open at the R=1 knee, so knee(R=2) >= knee(R=1) is probed directly.
.PHONY: bench-knee-scaling
bench-knee-scaling:
	XLA_FLAGS=--xla_force_host_platform_device_count=4 PYTHONPATH=src:. $(PYTHON) benchmarks/serve_knee_bench.py --arrival poisson --replicas-sweep 1,2,4 --out BENCH_serve_knee.json
	PYTHONPATH=src:. $(PYTHON) benchmarks/validate_bench.py BENCH_serve_knee.json

.PHONY: lint
lint:
	ruff check src tests benchmarks examples tools

# Docs drift gate: every src/repro path, module reference, make target,
# and CLI flag named in README.md / DESIGN.md / docs/OPERATIONS.md must
# resolve against the tree. Pure text scan — no jax import.
.PHONY: docs-check
docs-check:
	$(PYTHON) tools/docs_check.py
