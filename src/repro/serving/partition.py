"""Balanced stage partitioning for the software layer-wise pipeline.

The paper's Algorithm 1 balances hardware across layers so every engine
finishes a row group at the same rate; the serving pipeline needs the dual
decision — given the *fixed* per-engine allocation the program was
compiled with, split the step chain into K contiguous stages whose modeled
busy cycles are as equal as possible, so K worker threads each finish a
micro-batch at the same rate. The partition objective (minimize the
slowest stage) is exactly Algorithm 1's T_rowmax balance, solved with the
same contiguous min-max DP the mesh allocator uses
(:func:`repro.core.allocator._partition_min_max`).

Stage weights come from :class:`~repro.core.allocator.LayerAlloc` — the
single source of truth for modeled cycles — matched to steps by layer
name: conv engines cost ``H * t_row / K`` busy cycles per frame, FC
engines ``t_row``, pools zero (they ride with whichever compute stage the
cut assigns them to, as on the FPGA where pooling hides inside the
line-buffer read-out).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax

from repro.core.allocator import LayerAlloc, _partition_min_max
from repro.core.program import EngineProgram


def stage_devices(n_stages: int,
                  devices: Sequence | None = None) -> list:
    """Round-robin device assignment for K stages: stage i runs on
    ``devices[i % len(devices)]`` (default ``jax.devices()``) — each
    balanced stage gets its own accelerator when the backend has several,
    the software form of resource-partitioned multi-accelerator serving.
    On a single-device backend every stage maps to that device, so
    placement is transparent (same arithmetic, same buffers)."""
    if n_stages < 1:
        raise ValueError(f"n_stages={n_stages} < 1")
    devs = list(jax.devices() if devices is None else devices)
    if not devs:
        raise ValueError("no devices to place stages on")
    return [devs[i % len(devs)] for i in range(n_stages)]


def step_cycles(allocs: Sequence[LayerAlloc]) -> dict[str, float]:
    """Modeled per-frame busy cycles for each engine, keyed by layer name
    (pool layers map to 0.0 — they are plumbing, not compute)."""
    out: dict[str, float] = {}
    for a in allocs:
        if a.layer.macs == 0:
            out[a.layer.name] = 0.0
        elif a.layer.kind == "fc":
            out[a.layer.name] = a.t_row
        else:
            out[a.layer.name] = a.layer.H * a.t_per_output_row
    return out


@dataclasses.dataclass(frozen=True)
class StagePartition:
    """A K-way contiguous split of an ``EngineProgram``'s step chain.

    ``boundaries`` has K+1 step indices: stage i runs steps
    ``[boundaries[i], boundaries[i+1])``. ``stage_cycles`` are the modeled
    busy cycles per frame per stage; ``bottleneck`` is their max — the
    modeled steady-state cost of one pipeline beat (the T_rowmax analogue
    at micro-batch granularity)."""

    n_stages: int
    boundaries: tuple[int, ...]
    stage_cycles: tuple[float, ...]

    @property
    def bottleneck(self) -> float:
        return max(self.stage_cycles)

    @property
    def balance(self) -> float:
        """mean/max stage cycles in (0, 1]; 1.0 == perfectly balanced.
        The pipeline's modeled speedup over one monolithic stage is
        ``n_stages * balance``."""
        if self.bottleneck <= 0:
            return 1.0
        return (sum(self.stage_cycles) / self.n_stages) / self.bottleneck

    def stage_ranges(self) -> list[tuple[int, int]]:
        return [(self.boundaries[i], self.boundaries[i + 1])
                for i in range(self.n_stages)]


def partition_from_boundaries(program: EngineProgram,
                              boundaries: Sequence[int]) -> StagePartition:
    """Build a :class:`StagePartition` for caller-chosen ``boundaries``
    (K+1 step indices covering ``[0, len(steps))``), with the same cycle
    weighting :func:`partition_program` uses — one source of truth for
    stage_cycles/balance however the cuts were picked."""
    if program.steps is None:
        raise ValueError("plan-only program (no lowered steps) cannot be "
                         "partitioned for serving")
    bounds = tuple(boundaries)
    n_stages = len(bounds) - 1
    if (n_stages < 1 or bounds[0] != 0 or bounds[-1] != len(program.steps)
            or any(b >= e for b, e in zip(bounds, bounds[1:]))):
        raise ValueError(
            f"boundaries {bounds} is not a contiguous cover of "
            f"[0, {len(program.steps)})")
    cycles = step_cycles(program.allocs)
    weights = [cycles.get(s.name, 0.0) for s in program.steps]
    return StagePartition(
        n_stages=n_stages, boundaries=bounds,
        stage_cycles=tuple(sum(weights[b:e])
                           for b, e in zip(bounds, bounds[1:])))


def partition_program(program: EngineProgram,
                      n_stages: int) -> StagePartition:
    """Split ``program``'s step chain into ``n_stages`` contiguous stages
    with near-equal modeled cycles (Algorithm 1's balance objective via
    the exact contiguous min-max DP).

    Raises when the program is plan-only (no lowered steps) or when more
    stages than compute steps are requested — a stage of only pool steps
    would spin on zero modeled work.
    """
    if program.steps is None:
        raise ValueError("plan-only program (no lowered steps) cannot be "
                         "partitioned for serving")
    n_compute = sum(1 for s in program.steps if s.kind != "pool")
    if not 1 <= n_stages <= n_compute:
        raise ValueError(
            f"n_stages={n_stages} outside [1, {n_compute}] "
            f"(compute steps in the chain)")
    cycles = step_cycles(program.allocs)
    weights = [cycles.get(s.name, 0.0) for s in program.steps]
    bounds, _ = _partition_min_max(weights, n_stages)
    # The DP may cut between a compute step and a trailing zero-weight
    # pool; both cuts cost the same, but keeping a pool with its producer
    # mirrors the FPGA (pooling reads out of the producing engine's line
    # buffer). Pull each boundary forward past any leading pools.
    bounds = list(bounds)
    for i in range(1, n_stages):
        while (bounds[i] < len(weights) and bounds[i] < bounds[i + 1] - 1
               and program.steps[bounds[i]].kind == "pool"):
            bounds[i] += 1
    return partition_from_boundaries(program, bounds)
