"""Multi-tenant model zoo behind one serving frontend.

The paper compiles one accelerator per CNN, but the framework's point is
that the *same* fabric and allocation algorithm serve "various CNN
models" — production traffic is many models at once. This module is the
serving-side analogue of partitioning one fabric across concurrent
compiled workloads (Shen et al., "Maximizing CNN Accelerator Efficiency
Through Resource Partitioning"):

* :class:`ProgramRegistry` — an ordered catalogue of compiled
  :class:`~repro.core.program.EngineProgram`\\ s, one per model id;
* :class:`ServerConfig` + :func:`build_server` — the
  compile -> partition -> replicate -> warm -> frontend lifecycle, run
  once per registered model (each model gets its own
  :class:`~repro.serving.pipeline_executor.PipelineExecutor` or
  :class:`~repro.serving.replica_pool.ReplicaPool`, its own measured
  steady-state throughput, and its own estimator channels);
* :class:`TenantMux` — one :class:`~repro.serving.Executor` over the
  per-model executors, dispatching each single-tenant micro-batch by
  the tenant tag the frontend stamped on it;
* :class:`Server` — ``submit(model_id, frame, ...)`` with a typed
  :class:`UnknownModelError` for unregistered ids, ``stats()`` with
  per-tenant rollups, :meth:`Server.rescale` for live
  drain-swap-resume reconfiguration (new K, R, or batch compiled and
  calibrated in the background, swapped in between micro-batches —
  see ``repro.serving.elastic`` for the controller that automates
  it), idempotent ``close()``.

The single-model serve paths (:func:`serve`, :func:`serve_async`,
:func:`serve_qos`, :func:`serve_knee` — re-exported by
``repro.launch.serve_cnn``, whose CLI stays the entry point) are thin
wrappers building a one-model registry: a one-model server attaches the
frontend straight to the bare executor under the default tenant, so the
estimator channels, router warm-start, and every artifact schema are
bit-for-bit the pre-registry ones.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Iterator

import jax
import numpy as np

from repro.core import workload as W
from repro.core.executor import EngineExecutor
from repro.core.program import compile_model
from repro.models import cnn
from repro.serving.calibrate import (default_max_wait_ms,
                                     pipeline_throughput, warmed_frontend)
from repro.serving.estimator import ServiceTimeEstimator, window_key
from repro.serving.frontend import (DEFAULT_TENANT, AsyncFrontend,
                                    ServedRequest, tenant_key)
from repro.serving.pipeline_executor import PipelineExecutor
from repro.serving.replica_pool import ReplicaPool


class UnknownModelError(KeyError):
    """Submit (or lookup) named a model id the registry never saw."""

    def __init__(self, name: str, known=()):
        self.name = name
        known = sorted(known)
        msg = f"unknown model {name!r}"
        if known:
            msg += f" (registered: {', '.join(known)})"
        super().__init__(msg)

    def __str__(self) -> str:  # KeyError repr-quotes its arg; keep prose
        return self.args[0]


def compile_for_serving(model_name: str, *, bits: int = 8, seed: int = 0,
                        theta: int | None = None):
    """Compile ``model_name`` exactly as the serve paths consume it:
    seeded params, seeded calibration batch, Table I's budget convention
    for the bit width (the plan only affects modeled numbers — never the
    executed arithmetic)."""
    m = W.CNN_MODELS[model_name]()
    params = cnn.init_params(m, jax.random.PRNGKey(seed))
    calib = jax.random.normal(
        jax.random.PRNGKey(seed + 1), (1, m.input_hw, m.input_hw,
                                       m.input_ch))
    # 8-bit double-pumps the 900 DSPs, so modeled_fps_alg1 here equals
    # the fps8/fps16 column in benchmarks/table1.py.
    if theta is None:
        theta = 2 * 900 - len(m.layers) if bits == 8 else 900
    kwargs = {"theta": theta,
              "bram_total": None if bits == 8 else 545}
    return compile_model(m, params, bits=bits, calib_batch=calib, **kwargs)


def synthetic_stream_like(model, frames: int, seed: int = 0) -> np.ndarray:
    """The seeded synthetic frame stream for any :class:`CNNModel` —
    paper or imported (explicit RNG: identical frames run to run)."""
    rng = np.random.default_rng(seed + 2)
    return rng.standard_normal(
        (frames, model.input_hw, model.input_hw, model.input_ch),
        dtype=np.float32)


def synthetic_stream(model_name: str, frames: int,
                     seed: int = 0) -> np.ndarray:
    """:func:`synthetic_stream_like` over a named paper CNN."""
    return synthetic_stream_like(W.CNN_MODELS[model_name](), frames, seed)


class ProgramRegistry:
    """Ordered catalogue of compiled programs, one per model id. The
    registry is pure bookkeeping — no executors, no threads — so it can
    be built anywhere (tests hand it tiny compiled programs) and handed
    to :func:`build_server` to bring a serving fleet up around it."""

    def __init__(self):
        self._programs: dict[str, object] = {}

    @staticmethod
    def _io_contract(program):
        """The (input shape, bits) contract a compiled program imposes
        on submitted frames — None for opaque stand-ins (tests register
        fakes), which then skip collision checking."""
        model = getattr(program, "model", None)
        bits = getattr(program, "bits", None)
        if model is None or bits is None:
            return None
        return ((model.input_hw, model.input_hw, model.input_ch),
                int(bits))

    def register(self, name: str, program) -> None:
        if name in self._programs:
            raise ValueError(f"model {name!r} already registered")
        # Frames are validated by shape at Server.submit; two models
        # with identical input shapes but different bit widths would
        # accept each other's frames while quantizing them to different
        # integer formats — refuse the ambiguity at registration.
        new = self._io_contract(program)
        if new is not None:
            for other, prog in self._programs.items():
                old = self._io_contract(prog)
                if old is not None and old[0] == new[0] \
                        and old[1] != new[1]:
                    raise ValueError(
                        f"model {name!r} (input {new[0]}, "
                        f"{new[1]}-bit) collides with registered "
                        f"{other!r} (input {old[0]}, {old[1]}-bit): "
                        f"same frame shape under a different dtype "
                        f"contract")
        self._programs[str(name)] = program

    def register_imported(self, source, *, name: str | None = None,
                          bits: int = 8, seed: int = 0,
                          theta: int | None = None,
                          golden_check: bool = True):
        """The compiler front door: import ``source`` (a spec dict,
        ``.json``/``.onnx`` path, or in-memory compiler ``Graph``),
        lower it onto the engine contract, quantize it with the shared
        serving conventions, and register the compiled program.

        Returns ``(name, golden)`` — the id it registered under and the
        int8 golden parity record. With ``golden_check`` (default) the
        golden is generated on the exact-f32 MAC route and re-executed
        on the int32 oracle route before registration: an import that
        cannot reproduce its own golden across routes never enters the
        zoo (raises :class:`repro.compiler.GoldenMismatch`)."""
        from repro import compiler

        model, params = compiler.import_source(source)
        if name is None:
            name = model.name
        if name in self._programs:
            raise ValueError(f"model {name!r} already registered")
        prog = compiler.quantize(model, params, bits=bits, seed=seed,
                                 theta=theta)
        golden = compiler.make_golden(prog, seed=seed, route="f32")
        if golden_check:
            compiler.check_golden(prog, golden, seed=seed, route="oracle")
        self.register(name, prog)
        return name, golden

    def get(self, name: str):
        try:
            return self._programs[name]
        except KeyError:
            raise UnknownModelError(name, self._programs) from None

    def names(self) -> tuple[str, ...]:
        return tuple(self._programs)

    def items(self):
        return self._programs.items()

    def __contains__(self, name: str) -> bool:
        return name in self._programs

    def __len__(self) -> int:
        return len(self._programs)

    def __iter__(self) -> Iterator[str]:
        return iter(self._programs)

    @classmethod
    def compile(cls, names, *, bits: int = 8, seed: int = 0,
                theta: int | None = None) -> "ProgramRegistry":
        """Convenience: compile each named paper CNN with the shared
        serving conventions and register it."""
        reg = cls()
        for name in names:
            reg.register(name, compile_for_serving(name, bits=bits,
                                                   seed=seed, theta=theta))
        return reg


@dataclasses.dataclass(frozen=True)
class ServerConfig:
    """Everything :func:`build_server` needs beyond the programs. One
    config applies to every registered model (the compiled batch size
    must be fleet-wide: the frontend assembles fixed-size micro-batches
    per tenant); per-tenant asymmetry lives in ``tenant_shares``."""

    batch: int = 16
    stages: int = 2
    bits: int = 8                      # recorded; programs carry their own
    route: str | None = None
    output: str = "top1"
    seed: int = 0
    theta: int | None = None
    replicas: int | dict = 1           # fleet-wide, or {model: R} per tenant
    replica_mode: str = "pipeline"
    place_stages: bool = False
    max_wait_ms: float | None = None   # None: one batch window at the rate
    max_queue: int = 256               # per-(tenant, priority) lane bound
    admission_control: bool = True
    flush_guard_ms: float | None = None
    tenant_shares: dict | None = None  # WRR weights; None = equal
    calib_frames: int | None = None    # None: (6 + 2*stages) * batch
    # Elastic runtime: with auto_rescale, every frontend the server
    # mints gets an ElasticController watching it (observe -> decide ->
    # act on a background thread; see repro.serving.elastic).
    # rescale_policy overrides ElasticPolicy fields by name.
    auto_rescale: bool = False
    rescale_policy: dict | None = None
    rescale_interval_s: float = 0.25

    def replicas_for(self, name: str) -> int:
        """The replica count for one model: the fleet-wide int, or the
        model's entry in a per-model dict (absent models serve
        unreplicated — a hot tenant scales out without forcing R
        replicas of every cold one)."""
        if isinstance(self.replicas, dict):
            return int(self.replicas.get(name, 1))
        return int(self.replicas)


@dataclasses.dataclass
class TenantRuntime:
    """One model's serving state inside a server: its compiled program,
    its (started) executor, and the calibration measurements the
    frontend warm-starts from."""

    name: str
    program: object
    executor: object
    steady_fps: float = 0.0
    lat1_s: float | None = None        # unloaded single-batch traversal
    warmup_s: float = 0.0              # compile + first warm pass
    calib: object = None               # ServeStats over the measured window


def make_executor(prog, *, stages: int, batch: int, route, output,
                  place_stages: bool = False, replicas: int = 1,
                  replica_mode: str = "pipeline", seed: int = 0):
    """One executor for every serve path: the single
    :class:`PipelineExecutor` when ``replicas <= 1`` (exact PR-5
    behaviour), otherwise a :class:`ReplicaPool` of R routed replicas
    over the device mesh (``pipeline``: whole pipeline per device;
    ``stage-shard``: each replica stage-pipelines across its contiguous
    device slice). The router RNG is seeded alongside everything else,
    so cold-start placement replays."""
    if replicas <= 1:
        return PipelineExecutor(prog, stages=stages, batch_size=batch,
                                route=route, output=output,
                                place_stages=place_stages)
    return ReplicaPool(prog, replicas=replicas, mode=replica_mode,
                       stages=stages, batch_size=batch, route=route,
                       output=output, router_seed=seed)


class TenantMux:
    """One :class:`~repro.serving.Executor` over N per-tenant executors.

    The frontend's batches are single-tenant by construction (models
    take different frame shapes), so the mux only has to read the
    tenant tag the frontend stamped on each request and forward the
    batch to that tenant's executor; results and errors flow back
    through one shared pair of callback slots. ``program`` is None —
    there is no single compiled program behind the mux, and the
    :class:`Server` validates frames against the tenant's own program
    before they reach the frontend."""

    def __init__(self, executors: dict[str, object], *, batch_size: int):
        if not executors:
            raise ValueError("TenantMux needs at least one executor")
        self.children = dict(executors)
        self.batch_size = int(batch_size)
        self.program = None
        self.on_result: Callable | None = None
        self.on_error: Callable | None = None
        for name, ex in self.children.items():
            if ex.on_result is not None:
                raise ValueError(f"executor for {name!r} already has an "
                                 f"on_result consumer")
            # Late-bound forwarders: the frontend claims the mux's slots
            # after construction, and close() releases them; children
            # read whatever is current at delivery time.
            ex.on_result = self._forward_result
            ex.on_error = self._forward_error

    def _forward_result(self, tag, outputs) -> None:
        cb = self.on_result
        if cb is not None:
            cb(tag, outputs)

    def _forward_error(self, tag, exc) -> None:
        cb = self.on_error
        if cb is not None:
            cb(tag, exc)

    def submit_batch(self, frames: np.ndarray, n_valid: int,
                     tag=None) -> None:
        """Dispatch one single-tenant micro-batch to its tenant's
        executor (blocking on that executor's own backpressure). The
        tag must be the frontend's request tuple — the tenant routing
        key lives on the requests."""
        if not tag:
            raise ValueError("TenantMux.submit_batch needs a request tag "
                             "to route by tenant")
        tenant = tag[0].tenant
        child = self.children.get(tenant)
        if child is None:
            raise UnknownModelError(tenant, self.children)
        child.submit_batch(frames, n_valid, tag=tag)

    def swap_child(self, tenant: str, new_executor) -> object:
        """Replace one tenant's executor behind the mux (the multi-
        tenant half of a live rescale): release the old child's
        forwarder slots, claim the new one's, swap the table entry.
        The caller must have drained dispatch first
        (:meth:`AsyncFrontend.pause_dispatch` + quiescence) — the mux
        itself holds no queue, so a swap between micro-batches is
        atomic by construction. Returns the old executor (drained;
        caller closes it)."""
        old = self.children.get(tenant)
        if old is None:
            raise UnknownModelError(tenant, self.children)
        if new_executor.on_result is not None:
            raise ValueError(f"executor for {tenant!r} already has an "
                             f"on_result consumer")
        old.on_result = None
        old.on_error = None
        new_executor.on_result = self._forward_result
        new_executor.on_error = self._forward_error
        self.children[tenant] = new_executor
        return old

    def flush_inflight(self) -> None:
        for ex in self.children.values():
            ex.flush_inflight()

    def reset_stats(self) -> None:
        for ex in self.children.values():
            ex.reset_stats()

    def replica_counts(self) -> list | None:
        """No fleet-wide replica rows: per-tenant replica accounting is
        read per child (``Server.stats`` does)."""
        return None

    def close(self) -> None:
        for ex in self.children.values():
            # close() is an executor-lifecycle concern, not part of the
            # frontend protocol (the single-jit EngineExecutor has
            # none); fakes without one are already "closed".
            close = getattr(ex, "close", None)
            if close is not None:
                close()


_OUTCOME_KEYS = ("submitted", "completed", "failed", "expired",
                 "rejected", "rejected_wait", "late")


class Server:
    """A started multi-tenant serving fleet: one (possibly muxed)
    executor, per-tenant calibration, and frontend lifecycle. Built by
    :func:`build_server`; use as a context manager or call
    :meth:`close` (idempotent)."""

    def __init__(self, registry: ProgramRegistry, config: ServerConfig,
                 runtimes: dict[str, TenantRuntime]):
        self.registry = registry
        self.config = config
        self._runtimes = runtimes
        self._lock = threading.Lock()
        self._rescale_lock = threading.Lock()
        self._closed = False
        self._frontends: list[AsyncFrontend] = []
        self._default_frontend: AsyncFrontend | None = None
        self._controller = None            # auto-rescale ElasticController
        # One model serves under the default tenant on its bare
        # executor: the frontend's estimator keys, router warm-start,
        # and lane layout are then exactly the single-model ones — the
        # registry is invisible until a second model registers.
        self.multi = len(runtimes) > 1
        if self.multi:
            self._mux = TenantMux(
                {name: rt.executor for name, rt in runtimes.items()},
                batch_size=config.batch)
        else:
            self._mux = None

    # -- topology ------------------------------------------------------------

    @property
    def executor(self):
        """What a frontend attaches to: the tenant mux, or the single
        model's bare executor."""
        if self._mux is not None:
            return self._mux
        (rt,) = self._runtimes.values()
        return rt.executor

    @property
    def model_names(self) -> tuple[str, ...]:
        return tuple(self._runtimes)

    def runtime(self, name: str) -> TenantRuntime:
        rt = self._runtimes.get(name)
        if rt is None:
            raise UnknownModelError(name, self._runtimes)
        return rt

    def _tenant_of(self, name: str) -> str:
        return name if self.multi else DEFAULT_TENANT

    def _model_of_tenant(self, tenant: str) -> str | None:
        if self.multi:
            return tenant if tenant in self._runtimes else None
        (name,) = self._runtimes
        return name if tenant in (DEFAULT_TENANT, name) else None

    # -- frontend lifecycle --------------------------------------------------

    def open_frontend(self, rate=None, *,
                      admission_control: bool | None = None) -> AsyncFrontend:
        """A fresh frontend over this server's executor, warm-started
        from the per-tenant calibration. ``rate`` sizes the batcher's
        flush timeout (one full-batch window at the expected arrival
        rate): a float for a one-model server, a ``{model: fps}``
        mapping (or None — the calibrated steady rates) for a
        multi-model one. The server closes any still-open frontend it
        minted at :meth:`close`; callers that finish earlier close it
        themselves (the executor is reusable across frontends). With
        ``ServerConfig(auto_rescale=True)`` an
        :class:`~repro.serving.elastic.ElasticController` is attached
        to the new frontend (observe cadence
        ``config.rescale_interval_s``, policy overrides from
        ``config.rescale_policy``)."""
        if self._closed:
            raise RuntimeError("server is closed")
        cfg = self.config
        admission = (cfg.admission_control if admission_control is None
                     else admission_control)
        if not self.multi:
            (rt,) = self._runtimes.values()
            r = float(rate) if rate is not None else rt.steady_fps
            fe = warmed_frontend(rt.executor, rt.steady_fps, r, cfg.batch,
                                 max_wait_ms=cfg.max_wait_ms,
                                 admission_control=admission,
                                 flush_guard_ms=cfg.flush_guard_ms,
                                 lat1_s=rt.lat1_s,
                                 max_queue=cfg.max_queue)
        else:
            rates = dict(rate) if isinstance(rate, dict) else {}
            est = ServiceTimeEstimator()
            waits = []
            for name, rt in self._runtimes.items():
                tenant = self._tenant_of(name)
                steady = max(rt.steady_fps, 1e-9)
                win = cfg.batch / steady
                n_rep = getattr(rt.executor, "n_replicas", 1)
                stages = rt.executor.partition.n_stages
                # Same two-channel convention as the single-model
                # warmed_frontend, on the tenant-scoped keys: window at
                # the tenant's fleet batch beat, latency at the measured
                # unloaded traversal (formula fallback K x R x window).
                est.warm_start(window_key(tenant_key(tenant, cfg.batch)),
                               win)
                lat_seed = (rt.lat1_s if rt.lat1_s is not None
                            and rt.lat1_s > 0 else stages * n_rep * win)
                est.warm_start(tenant_key(tenant, cfg.batch), lat_seed)
                router = getattr(rt.executor, "router", None)
                if router is not None:
                    router.warm_start(n_rep * win, stages * n_rep * win)
                r_t = rates.get(name, rt.steady_fps)
                waits.append(default_max_wait_ms(
                    cfg.batch, min(r_t, rt.steady_fps)))
            # One global flush timeout must let the *slowest* tenant
            # fill a batch; faster tenants fill (or expedite) sooner.
            wait_ms = (cfg.max_wait_ms if cfg.max_wait_ms is not None
                       else max(waits))
            fe = AsyncFrontend(self._mux, max_wait_ms=wait_ms,
                               estimator=est,
                               admission_control=admission,
                               flush_guard_ms=cfg.flush_guard_ms,
                               max_queue=cfg.max_queue,
                               tenant_shares=cfg.tenant_shares)
        with self._lock:
            self._frontends.append(fe)
        if cfg.auto_rescale:
            self._attach_controller(fe)
        return fe

    def _attach_controller(self, fe: AsyncFrontend) -> None:
        """Start an :class:`~repro.serving.elastic.ElasticController`
        watching ``fe`` (``ServerConfig.auto_rescale``). One controller
        per server: a newer frontend takes over the watch."""
        from repro.serving.elastic import ElasticController, ElasticPolicy
        if self.multi:
            raise ValueError("auto_rescale currently watches one model; "
                             "drive rescale() directly on a multi-model "
                             "server")
        cfg = self.config
        policy = ElasticPolicy(**(cfg.rescale_policy or {}))
        with self._lock:
            prev = self._controller
        if prev is not None:
            prev.stop()
        ctrl = ElasticController(self, fe, policy=policy)
        ctrl.start(interval_s=cfg.rescale_interval_s)
        with self._lock:
            self._controller = ctrl

    def _ensure_frontend(self) -> AsyncFrontend:
        with self._lock:
            fe = self._default_frontend
            if fe is not None and not fe._closing.is_set():
                return fe
        fe = self.open_frontend()
        with self._lock:
            self._default_frontend = fe
        return fe

    # -- client side ---------------------------------------------------------

    def submit(self, model_id: str, frame: np.ndarray, *,
               priority: int = 0, deadline_ms: float | None = None,
               klass: str | None = None, timeout: float | None = None,
               block: bool = True) -> ServedRequest:
        """Enqueue one frame for ``model_id`` through the shared
        frontend (created lazily on first submit). Raises
        :class:`UnknownModelError` immediately for an unregistered id —
        typed, at submit, never a hang — and ``ValueError`` for a frame
        the model's compiled program cannot take."""
        if self._closed:
            raise RuntimeError("server is closed")
        rt = self.runtime(model_id)          # raises UnknownModelError
        arr = np.asarray(frame)
        hw = rt.program.model.input_hw
        want = (hw, hw, rt.program.model.input_ch)
        if arr.shape != want:
            raise ValueError(f"frame shape {arr.shape} does not match "
                             f"model {model_id!r} {want}")
        fe = self._ensure_frontend()
        return fe.submit(arr, priority=priority, deadline_ms=deadline_ms,
                         klass=klass, tenant=self._tenant_of(model_id),
                         timeout=timeout, block=block)

    def stats(self) -> dict:
        """Per-tenant rollups across every frontend this server minted:
        calibration numbers per model plus outcome counters and
        end-to-end latency percentiles, and fleet totals."""
        models: dict[str, dict] = {}
        samples: dict[str, list] = {}
        for name, rt in self._runtimes.items():
            models[name] = {
                "steady_fps": round(rt.steady_fps, 3),
                "modeled_fps_alg1": round(rt.program.fps(), 3),
                "warmup_s": round(rt.warmup_s, 3),
                "lat1_ms": (None if rt.lat1_s is None
                            else round(rt.lat1_s * 1e3, 3)),
                "replicas": getattr(rt.executor, "n_replicas", 1),
                "stages": rt.executor.partition.n_stages,
                **{k: 0 for k in _OUTCOME_KEYS},
                "latency_ms_p50": None,
                "latency_ms_p95": None,
            }
            samples[name] = []
        totals = {k: 0 for k in _OUTCOME_KEYS}
        with self._lock:
            frontends = list(self._frontends)
        for fe in frontends:
            st = fe.stats_snapshot()
            for tname, ts in st.tenants.items():
                model = self._model_of_tenant(tname)
                if model is None:
                    continue
                row = models[model]
                for k in _OUTCOME_KEYS:
                    v = getattr(ts, k)
                    row[k] += v
                    totals[k] += v
                samples[model].extend(ts.total_s)
        for name, row in models.items():
            if samples[name]:
                arr = np.asarray(samples[name])
                p50, p95 = np.percentile(arr, [50, 95])
                row["latency_ms_p50"] = round(float(p50) * 1e3, 3)
                row["latency_ms_p95"] = round(float(p95) * 1e3, 3)
        return {"models": models, "totals": totals}

    # -- elastic rescale -----------------------------------------------------

    def _live_frontends(self) -> list[AsyncFrontend]:
        with self._lock:
            return [fe for fe in self._frontends
                    if not fe._closing.is_set()]

    def rescale(self, model_id: str | None = None, *,
                replicas: int | None = None, stages: int | None = None,
                batch: int | None = None, replica_mode: str | None = None,
                calib_frames: int | None = None,
                drain_timeout_s: float = 60.0) -> dict:
        """Live re-partition one model without dropping a request.

        The act half of the elastic runtime (DESIGN.md section 10):
        build the candidate executor — a new K partition via the
        Algorithm-1 DP, a changed micro-batch size, or R+-1 replicas —
        **in the background** while the old one keeps serving, warm and
        calibrate it (every stage jit compiles, steady fps and unloaded
        traversal are measured fresh), then drain -> swap -> resume:
        every live frontend pauses dispatch at a micro-batch boundary
        (submits keep queueing — nothing is rejected), in-flight batches
        resolve on the old executor, the new executor takes the callback
        slots, and dispatch resumes. Int8 stage boundaries carry no
        cross-batch state, so the handoff is stateless. The frontend's
        estimator channels are forcibly re-warmed
        (:meth:`~repro.serving.estimator.ServiceTimeEstimator
        .rewarm_channels`) from the new calibration — the old plan's
        measured EWMA priced a pipeline that no longer exists.

        ``model_id`` defaults to the sole model of a one-model server;
        unset topology arguments keep their current values. Changing
        ``batch`` is refused on a multi-tenant server (the frontend's
        micro-batch size is fleet-wide). Returns a JSON-ready rescale
        event (before/after topology, compile and swap timings).
        Serialized: concurrent calls queue on an internal lock."""
        if self._closed:
            raise RuntimeError("server is closed")
        if model_id is None:
            if len(self._runtimes) != 1:
                raise ValueError(
                    "a multi-model server needs an explicit model_id "
                    f"(registered: {', '.join(self._runtimes)})")
            (model_id,) = self._runtimes
        rt = self.runtime(model_id)          # raises UnknownModelError
        with self._rescale_lock:
            cfg = self.config
            old_ex = rt.executor
            old = {
                "replicas": getattr(old_ex, "n_replicas", 1),
                "stages": old_ex.partition.n_stages,
                "batch": int(old_ex.batch_size),
                "steady_fps": round(rt.steady_fps, 3),
            }
            new_r = old["replicas"] if replicas is None else int(replicas)
            new_k = old["stages"] if stages is None else int(stages)
            new_b = old["batch"] if batch is None else int(batch)
            mode = (replica_mode if replica_mode is not None
                    else cfg.replica_mode)
            if new_r < 1 or new_k < 1 or new_b < 1:
                raise ValueError(f"replicas={new_r}, stages={new_k}, "
                                 f"batch={new_b} must all be >= 1")
            if self.multi and new_b != old["batch"]:
                raise ValueError(
                    "cannot change batch on a multi-tenant server: the "
                    "frontend's micro-batch size is fleet-wide")
            if (new_r, new_k, new_b) == (old["replicas"], old["stages"],
                                         old["batch"]):
                raise ValueError("rescale with nothing to change "
                                 f"(replicas={new_r}, stages={new_k}, "
                                 f"batch={new_b} already serving)")

            # 1. Background build + calibration: the old executor keeps
            # serving while every new stage jit compiles and the new
            # plan's steady fps / unloaded traversal are measured.
            t0 = time.perf_counter()
            ex = make_executor(rt.program, stages=new_k, batch=new_b,
                               route=cfg.route, output=cfg.output,
                               place_stages=cfg.place_stages,
                               replicas=new_r, replica_mode=mode,
                               seed=cfg.seed)
            ex.start()
            try:
                n_calib = (calib_frames if calib_frames is not None
                           else (6 + 2 * new_k) * new_b)
                stream = synthetic_stream_like(rt.program.model, n_calib,
                                               cfg.seed)
                warmup_s, lat1_s, ph1 = pipeline_throughput(ex, stream,
                                                            new_b)
                compile_s = time.perf_counter() - t0

                # 2. Drain -> swap -> resume on every live frontend.
                t1 = time.perf_counter()
                lives = self._live_frontends()
                if self._mux is None:
                    for fe in lives:
                        if fe.executor is old_ex:
                            fe.swap_executor(
                                ex, drain_timeout_s=drain_timeout_s)
                else:
                    tenant = self._tenant_of(model_id)
                    paused = []
                    try:
                        for fe in lives:
                            fe.pause_dispatch()
                            paused.append(fe)
                        deadline = time.perf_counter() + drain_timeout_s
                        for fe in paused:
                            while not fe._quiescent():
                                if time.perf_counter() > deadline:
                                    raise TimeoutError(
                                        "frontend did not drain within "
                                        f"{drain_timeout_s:.1f}s; rescale "
                                        "aborted")
                                fe.executor.flush_inflight()
                                time.sleep(0.001)
                        self._mux.swap_child(tenant, ex)
                    finally:
                        for fe in paused:
                            fe.resume_dispatch()
                swap_s = time.perf_counter() - t1
            except BaseException:
                ex.close()
                raise

            # 3. Bookkeeping: runtime, config, estimator re-warm.
            rt.executor = ex
            rt.steady_fps = ph1.steady_fps
            rt.lat1_s = lat1_s
            rt.warmup_s = warmup_s
            rt.calib = ph1
            if isinstance(cfg.replicas, dict):
                new_map = dict(cfg.replicas)
                new_map[model_id] = new_r
            elif self.multi:
                new_map = {name: cfg.replicas_for(name)
                           for name in self._runtimes}
                new_map[model_id] = new_r
            else:
                new_map = new_r
            self.config = dataclasses.replace(
                cfg, replicas=new_map,
                stages=new_k if not self.multi else cfg.stages,
                batch=new_b if not self.multi else cfg.batch)
            self._rewarm_frontends(model_id, rt)

            # 4. The old executor is drained (the swap waited); close it.
            wait = getattr(old_ex, "wait_idle", None)
            if wait is not None:
                wait(timeout=drain_timeout_s)
            old_ex.close()

            actual_k = ex.partition.n_stages
            event = {
                "model": model_id,
                "before": old,
                "after": {
                    "replicas": getattr(ex, "n_replicas", 1),
                    "stages": actual_k,
                    "batch": new_b,
                    "steady_fps": round(rt.steady_fps, 3),
                },
                "replica_mode": mode if new_r > 1 else None,
                "compile_s": round(compile_s, 3),
                "swap_s": round(swap_s, 3),
                "swapped_frontends": len(lives),
            }
            return event

    def _rewarm_frontends(self, model_id: str, rt: TenantRuntime) -> None:
        """Force-reseed every live frontend's estimator channels (and
        the new router) for ``model_id`` from the rescaled plan's fresh
        calibration — the exact :func:`~repro.serving.calibrate
        .warmed_frontend` convention, applied with :meth:`rewarm` so the
        old plan's measurements cannot outrank it."""
        ex = rt.executor
        batch = int(ex.batch_size)
        n_rep = getattr(ex, "n_replicas", 1)
        stages = ex.partition.n_stages
        win = batch / max(rt.steady_fps, 1e-9)
        tenant = self._tenant_of(model_id)
        key = tenant_key(tenant, batch)
        router = getattr(ex, "router", None)
        if router is not None:
            router.reset_pricing()
            router.warm_start(n_rep * win, stages * n_rep * win)
        for fe in self._live_frontends():
            fe.estimator.rewarm_channels(key, win, stages=stages,
                                         replicas=n_rep)
            if rt.lat1_s is not None and rt.lat1_s > 0:
                fe.estimator.rewarm(key, rt.lat1_s)

    # -- lifecycle -----------------------------------------------------------

    def close(self) -> None:
        """Close every frontend this server minted, then every
        executor. Idempotent; safe after partial failure."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            frontends = list(self._frontends)
            ctrl = self._controller
            self._controller = None
        if ctrl is not None:                 # stop rescales before drain
            ctrl.stop()
        for fe in frontends:
            fe.close()                       # idempotent per frontend
        if self._mux is not None:
            self._mux.close()
        else:
            for rt in self._runtimes.values():
                rt.executor.close()

    def __enter__(self) -> "Server":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def build_server(registry: ProgramRegistry, config: ServerConfig, *,
                 streams: dict[str, np.ndarray] | None = None,
                 verbose: bool = False) -> Server:
    """Bring a serving fleet up around ``registry``: per model, build
    its executor (pipeline or replica pool), start it, and run the
    shared calibration pass (:func:`~repro.serving.calibrate
    .pipeline_throughput` — compile-warm every stage jit, measure the
    unloaded traversal, measure closed-loop steady fps). ``streams``
    overrides the seeded synthetic calibration stream per model (the
    single-model serve paths pass their exact bench stream, keeping
    their measured numbers identical to the pre-registry code). On any
    failure mid-build, executors already started are closed before the
    error propagates."""
    if len(registry) == 0:
        raise ValueError("registry has no models to serve")
    if isinstance(config.replicas, dict):
        unknown = set(config.replicas) - set(registry.names())
        if unknown:
            raise ValueError(
                f"ServerConfig.replicas names unregistered models "
                f"{sorted(unknown)} (registered: "
                f"{', '.join(sorted(registry.names()))})")
    calib_frames = (config.calib_frames if config.calib_frames is not None
                    else (6 + 2 * config.stages) * config.batch)
    runtimes: dict[str, TenantRuntime] = {}
    try:
        for name, prog in registry.items():
            stream = (streams or {}).get(name)
            if stream is None:
                # Keyed off the compiled program's own model, so
                # imported (non-paper) models calibrate the same way.
                stream = synthetic_stream_like(prog.model, calib_frames,
                                               config.seed)
            if len(stream) <= config.batch:
                raise ValueError(
                    f"calibration stream for {name!r} has {len(stream)} "
                    f"frames <= batch={config.batch}: no steady-state "
                    f"window (use >= 2*batch)")
            ex = make_executor(prog, stages=config.stages,
                               batch=config.batch, route=config.route,
                               output=config.output,
                               place_stages=config.place_stages,
                               replicas=config.replicas_for(name),
                               replica_mode=config.replica_mode,
                               seed=config.seed)
            ex.start()
            runtimes[name] = rt = TenantRuntime(name=name, program=prog,
                                                executor=ex)
            t0 = time.perf_counter()
            warmup_s, lat1_s, ph1 = pipeline_throughput(ex, stream,
                                                        config.batch)
            rt.warmup_s = warmup_s
            rt.lat1_s = lat1_s
            rt.steady_fps = ph1.steady_fps
            rt.calib = ph1
            if verbose:
                print(f"[server] {name}: K={ex.partition.n_stages} "
                      f"batch={config.batch} steady "
                      f"{rt.steady_fps:.2f} fps, unloaded traversal "
                      f"{lat1_s * 1e3:.1f}ms, warm "
                      f"{time.perf_counter() - t0:.1f}s")
    except BaseException:
        for rt in runtimes.values():
            rt.executor.close()
        raise
    return Server(registry, config, runtimes)


# ---------------------------------------------------------------------------
# Single-model serve paths (the serve_cnn launch surface, unchanged
# flags and artifact schemas — each builds a one-model registry).
# ---------------------------------------------------------------------------


def serve(model_name: str, *, frames: int = 64, batch: int = 16,
          bits: int = 8, route: str | None = None, seed: int = 0,
          theta: int | None = None, eager_frames: int = 0,
          output: str = "top1", verbose: bool = True) -> dict:
    """Compile ``model_name``, serve ``frames`` synthetic frames through
    the single-jit :class:`EngineExecutor`, return a result dict
    (measured/modeled FPS). ``eager_frames > 0`` also times the eager
    per-sample reference loop for comparison. (No pipeline, no
    frontend — the measurement includes the first cold batch, so this
    path deliberately bypasses :func:`build_server`'s warm
    calibration.)"""
    if frames <= batch:
        raise ValueError(
            f"frames={frames} <= batch={batch}: the whole stream fits in "
            f"the first micro-batch, which is charged to compile/warmup, "
            f"leaving no steady-state window to measure (steady_fps would "
            f"be 0). Use frames >= 2*batch.")
    registry = ProgramRegistry()
    registry.register(model_name, compile_for_serving(
        model_name, bits=bits, seed=seed, theta=theta))
    prog = registry.get(model_name)
    stream = synthetic_stream(model_name, frames, seed)

    ex = EngineExecutor(prog, batch_size=batch, route=route, output=output)
    outs = ex.serve(stream)
    st = ex.stats

    # cache_size() counts XLA executables (1 = compiled once, never
    # recompiled); -1 means the running jax doesn't expose the counter.
    n_exec = ex.runner.cache_size()
    result = {
        "model": model_name,
        "bits": bits,
        "route": ex.runner.route,
        "batch": batch,
        "frames": st.frames,
        "batches": st.batches,
        "padded_frames": st.padded_frames,
        "compile_plus_first_batch_s": round(st.first_batch_s, 3),
        "measured_steady_fps": round(st.steady_fps, 3),
        "modeled_fps_alg1": round(prog.fps(), 3),
        "executables": n_exec,
        "recompiles": (n_exec - 1) if n_exec >= 0 else None,
        "sample_top1": [int(np.asarray(o).reshape(-1).argmax())
                        if output == "logits" else int(o)
                        for o in outs[:4]],
    }
    if eager_frames > 0:
        y = prog.run(stream[:1])           # warm the eager op caches
        jax.block_until_ready(y)
        t0 = time.perf_counter()
        for i in range(eager_frames):
            jax.block_until_ready(prog.run(stream[i:i + 1]))
        dt = time.perf_counter() - t0
        result["eager_fps"] = round(eager_frames / dt, 3)
        result["speedup_vs_eager"] = round(
            result["measured_steady_fps"] / max(result["eager_fps"], 1e-9), 2)
    if verbose:
        hw_fps = result["modeled_fps_alg1"]
        print(f"[serve_cnn] {model_name} bits={bits} route={result['route']}"
              f" batch={batch}: measured {result['measured_steady_fps']:.2f}"
              f" fps (steady), modeled {hw_fps:.1f} fps (Alg. 1 @200MHz)"
              f" | first batch {st.first_batch_s:.1f}s"
              f" | recompiles="
              f"{'?' if result['recompiles'] is None else result['recompiles']}")
        if "eager_fps" in result:
            print(f"[serve_cnn]   eager per-sample {result['eager_fps']:.2f}"
                  f" fps -> {result['speedup_vs_eager']:.1f}x batched")
    return result


def _one_model_server(model_name: str, *, frames: int, batch: int,
                      stages: int, bits: int, route, output,
                      place_stages: bool, replicas: int,
                      replica_mode: str, seed: int, theta,
                      max_wait_ms, admission_control: bool = True,
                      flush_guard_ms=None, program=None):
    """The shared head of the pipelined serve paths: one-model registry,
    server built over the caller's exact frame stream (so phase-1
    calibration measures the same window the pre-registry code did).
    Returns ``(server, runtime, stream)``."""
    if frames <= batch:
        raise ValueError(f"frames={frames} <= batch={batch}: no "
                         f"steady-state window (use frames >= 2*batch)")
    registry = ProgramRegistry()
    registry.register(model_name,
                      program if program is not None
                      else compile_for_serving(model_name, bits=bits,
                                               seed=seed, theta=theta))
    stream = synthetic_stream(model_name, frames, seed)
    cfg = ServerConfig(batch=batch, stages=stages, bits=bits, route=route,
                       output=output, seed=seed, theta=theta,
                       replicas=replicas, replica_mode=replica_mode,
                       place_stages=place_stages, max_wait_ms=max_wait_ms,
                       admission_control=admission_control,
                       flush_guard_ms=flush_guard_ms)
    srv = build_server(registry, cfg, streams={model_name: stream})
    return srv, srv.runtime(model_name), stream


def serve_async(model_name: str, *, frames: int = 64, batch: int = 16,
                stages: int = 2, bits: int = 8, route: str | None = None,
                seed: int = 0, theta: int | None = None,
                max_wait_ms: float | None = None,
                arrival_fps: float | None = None,
                place_stages: bool = False,
                replicas: int = 1, replica_mode: str = "pipeline",
                output: str = "top1", program=None,
                verbose: bool = True) -> dict:
    """Serve ``frames`` synthetic frames through the K-stage pipelined
    subsystem (``repro.serving``) behind the async request frontend.

    Two measurement phases over one compiled pipeline:

    1. **throughput** — closed-loop stream straight into the
       :class:`PipelineExecutor` (saturating, no frontend) after a
       warmup pass, measuring the steady-state FPS the single-jit path's
       ``measured_steady_fps`` is compared against;
    2. **latency** — the :class:`AsyncFrontend` replays the stream as an
       open-loop arrival process at ``arrival_fps`` (default: 70% of the
       measured throughput, scheduled by the shared seeded generator
       :func:`repro.serving.traffic.make_schedule`) and records
       per-request p50/p95/p99. ``max_wait_ms`` defaults to one
       full-batch assembly window at the arrival rate.

    ``place_stages`` pins stage i to ``jax.devices()[i % n]``
    (transparent on a single device); ``replicas > 1`` serves through a
    routed :class:`ReplicaPool` instead. Pass ``program`` to reuse an
    already-compiled program (the bench sweeps stage counts over one
    compile).
    """
    from repro.serving.traffic import TrafficClass, make_schedule, replay

    srv, rt, stream = _one_model_server(
        model_name, frames=frames, batch=batch, stages=stages, bits=bits,
        route=route, output=output, place_stages=place_stages,
        replicas=replicas, replica_mode=replica_mode, seed=seed,
        theta=theta, max_wait_ms=max_wait_ms, program=program)
    px, ph1 = rt.executor, rt.calib
    part = px.partition
    steady = rt.steady_fps
    try:
        # Phase 2: open-loop latency at a sustainable arrival rate, one
        # best-effort class (the QoS path is serve_qos).
        rate = arrival_fps if arrival_fps is not None else 0.7 * steady
        if max_wait_ms is None:
            max_wait_ms = default_max_wait_ms(batch, rate)
        fe = AsyncFrontend(px, max_wait_ms=max_wait_ms)
        schedule = make_schedule(len(stream), rate,
                                 [TrafficClass("default")], seed=seed)
        replay(fe, stream, schedule)
        fe.close()
    finally:
        srv.close()

    lat = fe.stats.latency_percentiles()
    result = {
        "model": model_name,
        "bits": bits,
        "route": px.route,
        "batch": batch,
        "stages": part.n_stages,
        "boundaries": list(part.boundaries),
        "stage_cycles": [round(c, 1) for c in part.stage_cycles],
        "stage_balance": round(part.balance, 4),
        "placed": place_stages,
        "replicas": getattr(px, "n_replicas", 1),
        "replica_mode": replica_mode if replicas > 1 else None,
        "replica_devices": getattr(px, "replica_devices", None),
        "replica_rows": (px.replica_rows()
                         if hasattr(px, "replica_rows") else None),
        "frames": ph1.frames,
        "batches": ph1.batches,
        "padded_frames": ph1.padded_frames,
        "compile_plus_warmup_s": round(rt.warmup_s, 3),
        "measured_steady_fps": round(steady, 3),
        "modeled_fps_alg1": round(rt.program.fps(), 3),
        "arrival_fps": round(rate, 3),
        "client_fps": round(fe.stats.fps, 3),
        "max_wait_ms": round(max_wait_ms, 3),
        "flushes_full": fe.stats.flushes_full,
        "flushes_timeout": fe.stats.flushes_timeout,
        "latency_ms_p50": round(lat["p50"] * 1e3, 3),
        "latency_ms_p95": round(lat["p95"] * 1e3, 3),
        "latency_ms_p99": round(lat["p99"] * 1e3, 3),
        "latency_ms_mean": round(lat["mean"] * 1e3, 3),
    }
    if verbose:
        print(f"[serve_async] {model_name} K={part.n_stages} "
              f"batch={batch}: steady {steady:.2f} fps (balance "
              f"{part.balance:.2f}), arrival {rate:.1f} fps -> p50 "
              f"{result['latency_ms_p50']:.1f}ms p95 "
              f"{result['latency_ms_p95']:.1f}ms p99 "
              f"{result['latency_ms_p99']:.1f}ms | modeled "
              f"{result['modeled_fps_alg1']:.1f} fps")
    return result


def _class_row(cs) -> dict:
    """One traffic class's QoS row: outcome counts, SLO rates, and the
    phase-split latency percentiles (ms)."""
    pp = cs.phase_percentiles()
    return {
        "submitted": cs.submitted,
        "completed": cs.completed,
        "expired": cs.expired,
        "rejected": cs.rejected,
        "rejected_wait": cs.rejected_wait,
        "failed": cs.failed,
        "late": cs.late,
        "drop_rate": round(cs.drop_rate, 4),
        "slo_miss_rate": round(cs.slo_miss_rate, 4),
        "phase_ms": {
            phase: {p: round(v * 1e3, 3) for p, v in pcts.items()}
            for phase, pcts in pp.items()},
    }


def _derived_slo_ms(part, px, batch: int, steady: float) -> float:
    """The feasible-deadline convention shared by serve_qos and
    serve_knee: a request's best case traverses assembly (~1 window)
    plus the K-stage pipeline with its depth-2 queues; ~stages + 3
    windows is comfortably feasible below saturation. With R routed
    replicas the *fleet* window is ~R x shorter than one replica's
    per-batch beat, but a batch still traverses a single replica — so
    the traversal term scales by R."""
    return round(
        (part.n_stages * getattr(px, "n_replicas", 1) + 3)
        * 1e3 * batch / max(steady, 1e-9), 1)


def serve_qos(model_name: str, *, frames: int = 96, batch: int = 16,
              stages: int = 2, bits: int = 8, route: str | None = None,
              seed: int = 0, theta: int | None = None,
              slo_ms: float | None = None,
              traffic_mix=None,
              load_factors: tuple[float, ...] = (0.6, 1.2),
              arrival_fps: float | None = None,
              max_wait_ms: float | None = None,
              place_stages: bool = False,
              replicas: int = 1, replica_mode: str = "pipeline",
              poisson: bool = False,
              admission_control: bool = True,
              flush_guard_ms: float | None = None,
              output: str = "top1", program=None,
              verbose: bool = True) -> dict:
    """Serve a mixed-traffic stream through the QoS frontend and report
    per-class phase-split latency, SLO miss rate, and drop rate.

    After the closed-loop throughput phase (shared with
    :func:`serve_async`), each entry of ``load_factors`` replays the
    same seeded mixed-class schedule
    (:func:`repro.serving.traffic.make_schedule`) open-loop at
    ``factor * measured_steady_fps`` — one rate below saturation and one
    above shows the QoS machinery working: under overload the priority
    lanes keep the interactive class inside its deadline while the
    best-effort class absorbs the queueing, and deadline-armed requests
    that cannot make it are dropped (``expired``), not served late.
    ``arrival_fps`` overrides the factor-derived rates with absolute
    rates ``factor * arrival_fps`` instead.

    ``traffic_mix`` is a sequence of :class:`TrafficClass` (default:
    25% interactive priority-1 with deadline ``slo_ms``, 75%
    best-effort batch). A ``slo_ms`` of None is derived from the
    measured service time — ``(stages + 3)`` batch windows at the
    steady rate — so the deadline is feasible below saturation on any
    backend but binds under overload (a fixed wall-clock default would
    be always-missed for a slow model on CPU and never-missed for a
    fast one, telling us nothing).

    The frontend's control decisions are adaptive: each rate's replay
    gets a :class:`~repro.serving.ServiceTimeEstimator` warm-started
    from the measured calibration pass (one batch window at the steady
    rate) and kept current by every completed batch, driving the
    expedited flush; ``admission_control`` (default on) additionally
    refuses deadline-armed requests whose estimated wait already
    exceeds their budget (``rejected_wait`` — they fail fast instead of
    expiring in queue). Set ``admission_control=False`` for the
    estimator-less PR-4 admission behaviour.
    """
    from repro.serving.traffic import default_mix, make_schedule, replay

    srv, rt, stream = _one_model_server(
        model_name, frames=frames, batch=batch, stages=stages, bits=bits,
        route=route, output=output, place_stages=place_stages,
        replicas=replicas, replica_mode=replica_mode, seed=seed,
        theta=theta, max_wait_ms=max_wait_ms,
        admission_control=admission_control,
        flush_guard_ms=flush_guard_ms, program=program)
    px = rt.executor
    part = px.partition
    steady = rt.steady_fps
    rates: dict[str, dict] = {}
    try:
        base = arrival_fps if arrival_fps is not None else steady
        if slo_ms is None:
            slo_ms = _derived_slo_ms(part, px, batch, steady)
        mix = tuple(traffic_mix) if traffic_mix is not None \
            else default_mix(slo_ms)

        warm_start_s = batch / max(steady, 1e-9)
        for factor in load_factors:
            rate = factor * base
            fe = srv.open_frontend(rate)
            schedule = make_schedule(len(stream), rate, mix, seed=seed,
                                     poisson=poisson)
            replay(fe, stream, schedule)
            fe.close()
            st = fe.stats
            rates[f"{factor:g}x"] = {
                "load_factor": factor,
                "arrival_fps": round(rate, 3),
                "client_fps": round(st.fps, 3),
                "max_wait_ms": round(fe.max_wait_s * 1e3, 3),
                "submitted": st.submitted,
                "completed": st.completed,
                "expired": st.expired,
                "rejected": st.rejected,
                "rejected_wait": st.rejected_wait,
                "failed": st.failed,
                "batches": st.batches,
                "flushes_full": st.flushes_full,
                "flushes_timeout": st.flushes_timeout,
                "flushes_deadline": st.flushes_deadline,
                "control": fe.control_config(),
                "classes": {name: _class_row(cs)
                            for name, cs in sorted(st.classes.items())},
                "replica_outcomes": st.replicas or None,
            }
            if verbose:
                parts = []
                for name, cs in sorted(st.classes.items()):
                    pq = cs.phase_percentiles()
                    parts.append(
                        f"{name}: p95 q/a/c "
                        f"{pq['queueing']['p95'] * 1e3:.1f}/"
                        f"{pq['assembly']['p95'] * 1e3:.1f}/"
                        f"{pq['compute']['p95'] * 1e3:.1f}ms "
                        f"miss {cs.slo_miss_rate:.0%} "
                        f"drop {cs.drop_rate:.0%}")
                print(f"[serve_qos] {model_name} K={part.n_stages} "
                      f"load {factor:g}x ({rate:.1f} fps): "
                      + " | ".join(parts))
    finally:
        srv.close()

    return {
        "model": model_name,
        "bits": bits,
        "route": px.route,
        "batch": batch,
        "stages": part.n_stages,
        "boundaries": list(part.boundaries),
        "stage_balance": round(part.balance, 4),
        "placed": place_stages,
        "stage_devices": ([str(d) for d in px.stage_devices]
                          if place_stages and hasattr(px, "stage_devices")
                          else None),
        "replicas": getattr(px, "n_replicas", 1),
        "replica_mode": replica_mode if replicas > 1 else None,
        "replica_devices": getattr(px, "replica_devices", None),
        "replica_rows": (px.replica_rows()
                         if hasattr(px, "replica_rows") else None),
        "seed": seed,
        "slo_ms": slo_ms,
        "poisson": poisson,
        "admission_control": admission_control,
        "flush_guard_ms": flush_guard_ms,
        "estimator_warm_start_ms": round(1e3 * warm_start_s, 3),
        "traffic_mix": [c.to_json() for c in mix],
        "frames": frames,
        "compile_plus_warmup_s": round(rt.warmup_s, 3),
        "measured_steady_fps": round(steady, 3),
        "modeled_fps_alg1": round(rt.program.fps(), 3),
        "rates": rates,
    }


def serve_knee(model_name: str, *, frames: int = 96, batch: int = 16,
               stages: int = 2, bits: int = 8, route: str | None = None,
               seed: int = 0, theta: int | None = None,
               slo_ms: float | None = None,
               traffic_mix=None,
               miss_target: float = 0.01,
               start_factor: float = 0.5,
               start_qps: float | None = None,
               max_factor: float = 4.0,
               refine_iters: int = 3,
               max_wait_ms: float | None = None,
               flush_guard_ms: float | None = None,
               admission_control: bool = True,
               place_stages: bool = False,
               replicas: int = 1, replica_mode: str = "pipeline",
               poisson: bool = False,
               scenario: str | None = None,
               scenario_params: dict | None = None,
               output: str = "top1", program=None,
               server: "Server | None" = None,
               verbose: bool = True) -> dict:
    """Bracketing absolute-QPS sweep: find the knee — the maximum
    sustained arrival rate at which the deadline-armed (interactive)
    classes keep ``slo_miss_rate < miss_target`` — and record it as the
    headline capacity number.

    ``serve_qos`` reports behaviour at load factors *relative to* the
    measured steady fps; the knee is the *absolute* QPS answer to "how
    much traffic can this deployment take": replay the seeded mix
    open-loop at ``start_factor * steady`` QPS, double while the armed
    classes stay under ``miss_target`` (capped at ``max_factor *
    steady``), halve downward if even the first probe misses, then
    bisect the sustained/unsustained bracket ``refine_iters`` times.
    Every probe reuses the same compiled pipeline, the same seeded
    schedule generator, and a fresh estimator warm-started from the
    calibration pass, so the sweep is reproducible from the recorded
    ``(seed, mix, rates)`` alone. A miss at any probe counts every
    armed-class request that did not complete inside its deadline —
    expired + refused at admission (``rejected_wait``, or ``rejected``
    on a full lane) + served late — so failing fast cannot launder the
    miss rate.

    ``replicas > 1`` sweeps the same knee over a routed
    :class:`ReplicaPool`; ``start_qps`` opens the bracket at an absolute
    rate instead of ``start_factor * steady`` — the knee-vs-R scaling
    sweep starts each R>1 bracket at the R=1 knee, so "replication never
    loses to one replica" is probed directly.

    ``scenario`` selects any arrival process from
    :data:`repro.serving.traffic.SCENARIOS` (``onoff``, ``pareto``, ...)
    with knobs in ``scenario_params`` — the adversarial knees the chaos
    bench sweeps; it supersedes the legacy ``poisson`` flag (which maps
    to ``scenario="poisson"``). Every probe row records a
    :func:`~repro.serving.traffic.pacing_report`, so the artifact shows
    the rate the open loop *achieved*, not just the one it targeted.

    ``server`` reuses an already-built one-model :class:`Server` (e.g.
    after a live :meth:`Server.rescale` — the post-rescale knee must be
    measured on the *rescaled* executor, not a fresh build) instead of
    compiling a new fleet; the caller keeps ownership and closes it.
    """
    from repro.serving.traffic import (armed_class_names, default_mix,
                                       make_scenario_schedule,
                                       pacing_report, replay,
                                       resolve_scenario_params)

    if not 0.0 < miss_target < 1.0:
        raise ValueError(f"miss_target={miss_target} not in (0, 1)")
    if scenario is None:
        scenario = "poisson" if poisson else "uniform"
        if scenario_params:
            raise ValueError("scenario_params without a scenario")
    elif poisson and scenario != "poisson":
        raise ValueError(f"both poisson=True and scenario={scenario!r}")
    # Validate the knobs once up front (fail before compiling anything);
    # the per-probe call re-resolves with the probe's rate.
    resolve_scenario_params(scenario, 0.0, **(scenario_params or {}))
    own_server = server is None
    if own_server:
        srv, rt, stream = _one_model_server(
            model_name, frames=frames, batch=batch, stages=stages,
            bits=bits, route=route, output=output,
            place_stages=place_stages, replicas=replicas,
            replica_mode=replica_mode, seed=seed,
            theta=theta, max_wait_ms=max_wait_ms,
            admission_control=admission_control,
            flush_guard_ms=flush_guard_ms, program=program)
    else:
        srv = server
        if srv.multi:
            raise ValueError("serve_knee reuses one-model servers only")
        rt = srv.runtime(model_name)         # raises UnknownModelError
        stream = synthetic_stream_like(rt.program.model, frames, seed)
        batch = int(rt.executor.batch_size)
        replica_mode = srv.config.replica_mode
    px = rt.executor
    part = px.partition
    steady = rt.steady_fps
    probes: list[dict] = []
    try:
        if slo_ms is None:
            slo_ms = _derived_slo_ms(part, px, batch, steady)
        mix = tuple(traffic_mix) if traffic_mix is not None \
            else default_mix(slo_ms)
        armed = armed_class_names(mix)
        if not armed:
            raise ValueError("traffic mix has no deadline-armed class — "
                             "nothing can define 'sustained'")
        warm_start_s = batch / max(steady, 1e-9)

        def _probe(rate: float) -> dict:
            fe = srv.open_frontend(rate)
            schedule, _ = make_scenario_schedule(
                scenario, len(stream), rate, mix, seed=seed,
                **(scenario_params or {}))
            reqs = replay(fe, stream, schedule)
            pacing = pacing_report(schedule, reqs)
            fe.close()
            st = fe.stats
            cls = [st.klass(n) for n in armed if n in st.classes]
            n_armed = sum(c.submitted for c in cls)
            n_miss = sum(c.expired + c.rejected + c.rejected_wait + c.late
                         for c in cls)
            # The verdict is computed on the rounded rate the artifact
            # stores, so `sustained` and `armed_miss_rate` can never
            # contradict each other under the validator's cross-check.
            miss = round(n_miss / n_armed if n_armed else 0.0, 4)
            total_s = [s for c in cls for s in c.total_s]
            # None, not NaN, when no armed request completed — NaN is
            # not valid JSON and would poison the uploaded artifact.
            p95_ms = (round(float(np.percentile(np.asarray(total_s), 95))
                            * 1e3, 3) if total_s else None)
            row = {
                "arrival_fps": round(rate, 3),
                "sustained": bool(miss < miss_target),
                "armed_miss_rate": miss,
                "armed_submitted": n_armed,
                "armed_missed": n_miss,
                "armed_p95_ms": p95_ms,
                "client_fps": round(st.fps, 3),
                "max_wait_ms": round(fe.max_wait_s * 1e3, 3),
                "submitted": st.submitted,
                "completed": st.completed,
                "expired": st.expired,
                "rejected": st.rejected,
                "rejected_wait": st.rejected_wait,
                "failed": st.failed,
                "pacing": pacing,
            }
            if verbose:
                print(f"[serve_knee] {model_name} probe {rate:8.2f} qps: "
                      f"armed miss {miss:6.2%} "
                      f"({'sustained' if row['sustained'] else 'MISS'}) | "
                      f"expired {st.expired} rejected_wait "
                      f"{st.rejected_wait} p95 "
                      + (f"{p95_ms:.1f}ms" if p95_ms is not None else "n/a"))
            return row

        # Bracket: escalate from start_factor * steady (or the absolute
        # start_qps) by doubling until the armed miss rate crosses the
        # target (or the cap), then bisect [highest sustained, lowest
        # unsustained].
        cap = max(max_factor * steady,
                  start_qps if start_qps is not None else 0.0)
        lo_rate, lo_row, hi_rate = None, None, None
        rate = start_qps if start_qps is not None else start_factor * steady
        while hi_rate is None:
            row = _probe(rate)
            probes.append(row)
            if row["sustained"]:
                lo_rate, lo_row = rate, row
                if rate >= cap:
                    break
                rate = min(2 * rate, cap)
            else:
                hi_rate = rate
        if lo_rate is None:
            # Even the opening probe missed: descend until sustained or
            # the sweep floor — a knee of None means this deployment
            # cannot hold the SLO at any probed rate.
            floor = 0.05 * steady
            while lo_rate is None and rate / 2 >= floor:
                rate = rate / 2
                row = _probe(rate)
                probes.append(row)
                if row["sustained"]:
                    lo_rate, lo_row = rate, row
                else:
                    hi_rate = rate
        for _ in range(max(0, int(refine_iters))):
            if lo_rate is None or hi_rate is None:
                break
            if hi_rate / lo_rate < 1.05:
                break
            mid = (lo_rate + hi_rate) / 2
            row = _probe(mid)
            probes.append(row)
            if row["sustained"]:
                lo_rate, lo_row = mid, row
            else:
                hi_rate = mid
    finally:
        if own_server:
            srv.close()

    result = {
        "model": model_name,
        "bits": bits,
        "route": px.route,
        "batch": batch,
        "stages": part.n_stages,
        "boundaries": list(part.boundaries),
        "stage_balance": round(part.balance, 4),
        "placed": place_stages,
        "replicas": getattr(px, "n_replicas", 1),
        "replica_mode": (replica_mode
                         if getattr(px, "n_replicas", 1) > 1 else None),
        "replica_devices": getattr(px, "replica_devices", None),
        "replica_rows": (px.replica_rows()
                         if hasattr(px, "replica_rows") else None),
        "start_qps": None if start_qps is None else round(start_qps, 3),
        "seed": seed,
        "slo_ms": slo_ms,
        "poisson": scenario == "poisson",
        "scenario": scenario,
        # The resolved knobs minus rate_fps (each probe row carries its
        # own rate): enough to regenerate any probe's schedule.
        "scenario_params": {
            k: v for k, v in resolve_scenario_params(
                scenario, 0.0, **(scenario_params or {})).items()
            if k != "rate_fps"},
        "miss_target": miss_target,
        "admission_control": admission_control,
        "flush_guard_ms": flush_guard_ms,
        "estimator_warm_start_ms": round(1e3 * warm_start_s, 3),
        "traffic_mix": [c.to_json() for c in mix],
        "frames": frames,
        "compile_plus_warmup_s": round(rt.warmup_s, 3),
        "measured_steady_fps": round(steady, 3),
        "modeled_fps_alg1": round(rt.program.fps(), 3),
        "knee_qps": None if lo_rate is None else round(lo_rate, 3),
        "knee_of_steady": (None if lo_rate is None
                           else round(lo_rate / max(steady, 1e-9), 4)),
        "knee_miss_rate": (None if lo_row is None
                           else lo_row["armed_miss_rate"]),
        "knee_armed_p95_ms": (None if lo_row is None
                              else lo_row["armed_p95_ms"]),
        "bracket_unsustained_qps": (None if hi_rate is None
                                    else round(hi_rate, 3)),
        "probes": probes,
    }
    if verbose:
        knee = result["knee_qps"]
        print(f"[serve_knee] {model_name} K={part.n_stages} batch={batch}: "
              f"knee "
              + (f"{knee:.1f} qps ({result['knee_of_steady']:.2f}x steady)"
                 if knee is not None else "not found")
              + f" at armed miss < {miss_target:.0%} | steady "
              f"{steady:.1f} fps | slo {slo_ms:.0f}ms | "
              f"{len(probes)} probes")
    return result


def serve_knee_rescale(model_name: str = "alexnet", *, frames: int = 96,
                       batch: int = 16, stages: int = 2, bits: int = 8,
                       route: str | None = None, seed: int = 0,
                       theta: int | None = None,
                       slo_ms: float | None = None,
                       traffic_mix=None, miss_target: float = 0.01,
                       start_qps: float | None = None,
                       ramp_growth: float = 1.3, max_segments: int = 6,
                       max_factor: float = 4.0, refine_iters: int = 2,
                       max_wait_ms: float | None = None,
                       flush_guard_ms: float | None = None,
                       admission_control: bool = True,
                       place_stages: bool = False,
                       scenario: str | None = None,
                       scenario_params: dict | None = None,
                       max_replicas: int = 2,
                       replica_mode: str = "pipeline",
                       output: str = "top1", program=None,
                       verbose: bool = True) -> dict:
    """Drive a load ramp across the R=1 knee and measure the elastic
    runtime closing the loop live: an :class:`~repro.serving.elastic
    .ElasticController` watches the frontend while open-loop segments
    escalate (``ramp_growth`` per segment, capped at ``max_factor *
    steady``); when the armed miss rate crosses ``miss_target`` the
    controller compiles an R+1 plan in the background and performs the
    drain -> swap -> resume between micro-batches — traffic keeps
    flowing the whole time, and ``hung == 0`` certifies no request was
    dropped or left unresolved across the swap.

    After the swap a recovery segment replays the anchor rate — the
    rated pre-ramp load — against the rescaled fleet
    (``armed_miss_after_rescale`` vs ``armed_miss_at_trigger``), and
    :func:`serve_knee` re-brackets the
    knee **on the same server** (``server=`` reuse) so the artifact's
    nested ``knee`` row is the post-rescale capacity, directly
    comparable to the base row's pre-rescale knee.

    Quick CI runs can be too short for the policy's sustained-miss
    window to fire; if the ramp exhausts without a controller event,
    the rescale is *forced* concurrently with live recovery traffic
    (``forced: true`` in the artifact) — the drain-swap-resume
    mechanism is still exercised under load, only the trigger differs.
    """
    from repro.serving.elastic import ElasticController, ElasticPolicy
    from repro.serving.traffic import (armed_class_names, default_mix,
                                       make_scenario_schedule, replay,
                                       resolve_scenario_params)

    if not 0.0 < miss_target < 1.0:
        raise ValueError(f"miss_target={miss_target} not in (0, 1)")
    if max_replicas < 2:
        raise ValueError(f"max_replicas={max_replicas} leaves no room "
                         "to scale out")
    if scenario is None:
        scenario = "uniform"
    resolve_scenario_params(scenario, 0.0, **(scenario_params or {}))
    srv, rt, stream = _one_model_server(
        model_name, frames=frames, batch=batch, stages=stages, bits=bits,
        route=route, output=output, place_stages=place_stages,
        replicas=1, replica_mode=replica_mode, seed=seed, theta=theta,
        max_wait_ms=max_wait_ms, admission_control=admission_control,
        flush_guard_ms=flush_guard_ms, program=program)
    px = rt.executor
    part = px.partition
    steady = rt.steady_fps
    try:
        if slo_ms is None:
            slo_ms = _derived_slo_ms(part, px, batch, steady)
        mix = tuple(traffic_mix) if traffic_mix is not None \
            else default_mix(slo_ms)
        armed = armed_class_names(mix)
        if not armed:
            raise ValueError("traffic mix has no deadline-armed class — "
                             "nothing can trigger a rescale")
        anchor = start_qps if start_qps is not None else steady
        policy = ElasticPolicy(miss_high=miss_target,
                               miss_low=miss_target / 4,
                               sustain=1, cooldown_s=1.0,
                               max_replicas=max_replicas,
                               min_window_requests=4)
        fe = srv.open_frontend(anchor)
        ctrl = ElasticController(srv, fe, policy=policy)
        ctrl.start(interval_s=0.15)
        segments: list[dict] = []

        def _armed_counts(st) -> tuple[int, int]:
            cls = [st.klass(n) for n in armed if n in st.classes]
            return (sum(c.submitted for c in cls),
                    sum(c.expired + c.rejected + c.rejected_wait + c.late
                        for c in cls))

        def _segment(rate: float, label: str, seg_seed: int) -> dict:
            sub0, miss0 = _armed_counts(fe.stats_snapshot())
            schedule, _ = make_scenario_schedule(
                scenario, len(stream), rate, mix, seed=seg_seed,
                **(scenario_params or {}))
            replay(fe, stream, schedule)
            sub1, miss1 = _armed_counts(fe.stats_snapshot())
            dsub, dmiss = sub1 - sub0, miss1 - miss0
            row = {
                "label": label,
                "arrival_fps": round(rate, 3),
                "armed_submitted": dsub,
                "armed_missed": dmiss,
                "armed_miss_rate": round(dmiss / dsub if dsub else 0.0, 4),
                "replicas": getattr(rt.executor, "n_replicas", 1),
                "rescales_so_far": len(ctrl.history),
            }
            segments.append(row)
            if verbose:
                print(f"[serve_knee_rescale] {model_name} {label:>9} "
                      f"{rate:8.2f} qps: armed miss "
                      f"{row['armed_miss_rate']:6.2%} | R="
                      f"{row['replicas']} | rescales "
                      f"{row['rescales_so_far']}")
            return row

        # Ramp: escalate past the R=1 knee until the controller fires.
        # Its history gains an event only once the swap *completed*, so
        # after the ramp, hold segments keep traffic in flight while
        # ctrl.busy — the background compile easily outlasts a short
        # open-loop segment, and the whole point is a swap with
        # requests in the air.
        cap = max(max_factor * steady, anchor)
        rate, trigger_row = anchor, None
        for i in range(max(1, int(max_segments))):
            rate = min(rate * ramp_growth, cap)
            row = _segment(rate, f"ramp{i}", seed + i)
            if ctrl.history:
                trigger_row = row
                break
        k = 0
        while not ctrl.history and (ctrl.busy or k < 2) and k < 60:
            _segment(rate, f"hold{k}", seed + 100 + k)
            k += 1
        ctrl.stop()                    # joins any in-flight rescale
        events = [dict(ev) for ev in ctrl.history]
        forced = not events
        if events and trigger_row is None:
            # The act completed during a hold segment (or the stop
            # join); the last segment carried the traffic across it.
            trigger_row = segments[-1]
        if forced:
            # Policy never fired within the ramp; force the mechanism
            # under live traffic so the artifact still certifies the
            # drain-swap-resume path end to end.
            trigger_row = segments[-1]
            errs: list[BaseException] = []

            def _force() -> None:
                try:
                    ev = srv.rescale(model_name, replicas=max_replicas)
                    ev.update({"action": "scale_out", "reason": "forced",
                               "signals": None,
                               "total_s": round(ev["compile_s"]
                                                + ev["swap_s"], 3)})
                    events.append(ev)
                except BaseException as e:  # surfaced after join
                    errs.append(e)

            t = threading.Thread(target=_force, daemon=True,
                                 name="forced-rescale")
            t.start()
            k = 0
            while t.is_alive():        # keep requests in flight
                _segment(trigger_row["arrival_fps"], f"forcehold{k}",
                         seed + 200 + k)
                k += 1
            t.join()
            if errs:
                raise errs[0]
        # Recovery is measured at the anchor (the rated pre-ramp load),
        # not the escalated trigger rate: the question the artifact
        # answers is whether the rescaled fleet serves the load the old
        # topology was rated for, not whether it absorbs an arbitrary
        # overload the ramp happened to end on.
        recovery = _segment(anchor, "recovery", seed + 500)
        fe.close()
        hung = fe.stats.hung
        replicas_after = getattr(rt.executor, "n_replicas", 1)

        # Re-bracket the knee on the rescaled server: the nested row is
        # the post-rescale capacity under the same seed/mix/SLO.
        knee_row = serve_knee(
            model_name, frames=frames, batch=batch, bits=bits, seed=seed,
            slo_ms=slo_ms, traffic_mix=mix, miss_target=miss_target,
            start_qps=anchor, max_factor=max_factor,
            refine_iters=refine_iters, max_wait_ms=max_wait_ms,
            flush_guard_ms=flush_guard_ms,
            admission_control=admission_control, scenario=scenario,
            scenario_params=scenario_params, output=output,
            server=srv, verbose=verbose)
    finally:
        srv.close()

    result = {
        "model": model_name,
        "bits": bits,
        "batch": batch,
        "stages": part.n_stages,
        "seed": seed,
        "slo_ms": slo_ms,
        "miss_target": miss_target,
        "scenario": scenario,
        "traffic_mix": [c.to_json() for c in mix],
        "measured_steady_fps_r1": round(steady, 3),
        "anchor_qps": round(anchor, 3),
        "policy": policy.to_json(),
        "segments": segments,
        "rescale_events": events,
        "n_rescales": len(events),
        "forced": forced,
        "replicas_before": 1,
        "replicas_after": replicas_after,
        "armed_miss_at_trigger": trigger_row["armed_miss_rate"],
        "armed_miss_after_rescale": recovery["armed_miss_rate"],
        "miss_recovered": bool(recovery["armed_miss_rate"]
                               <= trigger_row["armed_miss_rate"]),
        "hung": hung,
        "knee": knee_row,
    }
    if verbose:
        print(f"[serve_knee_rescale] {model_name}: "
              f"{len(events)} rescale(s)"
              + (" (forced)" if forced else "")
              + f" R 1 -> {replicas_after} | miss at trigger "
              f"{result['armed_miss_at_trigger']:.2%} -> after "
              f"{result['armed_miss_after_rescale']:.2%} | hung {hung} | "
              f"post-rescale knee "
              + (f"{knee_row['knee_qps']:.1f} qps"
                 if knee_row["knee_qps"] is not None else "not found"))
    return result
