"""Stage-pipelined executor: one worker thread per stage, bounded queues.

The paper's engines run concurrently, exchanging row groups through
double-buffered activation memories: engine i computes row group n while
engine i+1 consumes row group n-1 (Fig. 2). :class:`PipelineExecutor` is
the same structure at micro-batch granularity:

* the step chain is split into K contiguous stages with near-equal
  modeled cycles (:func:`repro.serving.partition.partition_program` —
  Algorithm 1's balance objective);
* each stage is one jitted device program
  (:meth:`EngineProgram.compile_stage_runner`) driven by its own worker
  thread;
* stages are connected by depth-2 :class:`queue.Queue`\\ s — the two
  halves of the activation double buffer. A full queue stalls the
  producer stage exactly like a full activation buffer stalls the
  upstream engine (backpressure), so at most ``queue_depth`` micro-batches
  sit between any two stages.

Activations cross stage boundaries as the same int8 tensors the
monolithic jit passes between steps, so the K-stage pipeline is
bit-identical to :meth:`EngineProgram.compile_runner` for every route
(pinned by ``tests/test_serving.py``); K=1 degenerates to the single-jit
serve path with one worker.
"""

from __future__ import annotations

import queue
import threading
import time
from typing import Callable, Iterable, Sequence

import numpy as np

from repro.core.executor import (ServeStats, normalize_frames,
                                 pad_micro_batch)
from repro.core.program import CompiledRunner, EngineProgram
from repro.serving.partition import (partition_from_boundaries,
                                     partition_program, stage_devices)

# Inter-stage queue depth: two mirrors the paper's double-buffered
# activation memory (one micro-batch in flight, one staged).
DEFAULT_QUEUE_DEPTH = 2

_SENTINEL = ("stop", 0, None, None, 0)


class PipelineExecutor:
    """Serve a frame stream through a K-stage software pipeline.

    >>> px = PipelineExecutor(program, stages=2, batch_size=32)
    >>> for frame in frames:
    ...     px.submit(frame)            # [H, W, C] float
    >>> ids = px.drain()                # per-frame top-1 class ids
    >>> px.close()

    ``on_result`` (for the async frontend) is called from the collector
    thread with ``(tag, outputs)`` for every micro-batch submitted with a
    non-None tag; ``on_error`` with ``(tag, exception)`` when such a
    batch fails in a stage. Untagged batches accumulate for
    :meth:`drain`.
    """

    def __init__(self, program: EngineProgram, *, stages: int = 2,
                 batch_size: int = 32, boundaries: Sequence[int] | None = None,
                 route: str | None = None, interpret: bool | None = None,
                 donate: bool | None = None, output: str = "top1",
                 queue_depth: int = DEFAULT_QUEUE_DEPTH,
                 place_stages: bool = False,
                 devices: Sequence | None = None,
                 on_result: Callable[[object, np.ndarray], None] | None = None,
                 on_error: Callable[[object, BaseException], None] | None = None):
        if output not in ("top1", "logits"):
            raise ValueError(f"unknown output {output!r}")
        self.program = program
        self.batch_size = int(batch_size)
        self.output = output
        self.on_result = on_result
        self.on_error = on_error
        if boundaries is not None:
            if len(tuple(boundaries)) != stages + 1:
                raise ValueError(
                    f"boundaries {tuple(boundaries)} is not a {stages}-"
                    f"stage contiguous cover of [0, {len(program.steps)})")
            self.partition = partition_from_boundaries(program, boundaries)
        else:
            self.partition = partition_program(program, stages)
        # place_stages pins stage i to jax.devices()[i % n] so K-stage
        # pipelining buys real concurrency on a multi-device backend
        # (stages stop competing for one chip); transparent on a
        # single-device backend, where every stage lands on the same
        # device and the arithmetic is unchanged. An explicit ``devices``
        # list round-robins over that list instead — the replica pool
        # uses it to pin a whole replica to one device (pipeline mode)
        # or its stages across a mesh slice (stage-shard mode).
        if devices is not None:
            self.stage_devices = stage_devices(self.partition.n_stages,
                                               list(devices))
        elif place_stages:
            self.stage_devices = stage_devices(self.partition.n_stages)
        else:
            self.stage_devices = [None] * self.partition.n_stages
        self.runners: list[CompiledRunner] = [
            program.compile_stage_runner(b, e, route=route,
                                         interpret=interpret, donate=donate,
                                         device=dev)
            for (b, e), dev in zip(self.partition.stage_ranges(),
                                   self.stage_devices)]
        self.route = self.runners[0].route
        self.stats = ServeStats()
        self.stats._first_n = self.batch_size
        self.stage_busy_s = [0.0] * self.partition.n_stages

        depth = max(1, int(queue_depth))
        # queues[i] feeds stage i; queues[K] feeds the collector.
        self._queues = [queue.Queue(maxsize=depth)
                        for _ in range(self.partition.n_stages + 1)]
        self._threads: list[threading.Thread] = []
        self._lock = threading.RLock()
        # Serializes batch assembly + seq assignment + stage-0 enqueue as
        # one step so concurrent producers cannot interleave out of
        # order, and so close() cannot slip its stop sentinel past a
        # producer blocked on a full queue. Separate from _lock: the
        # holder may block on a full queue, and the collector needs
        # _lock to drain it. Re-entrant: submit() holds it across the
        # pending-buffer flush while submit_batch re-acquires.
        self._order_lock = threading.RLock()
        self._done = threading.Condition(self._lock)
        self._pending: list[np.ndarray] = []
        self._results: list[np.ndarray] = []
        self._submitted = 0
        self._collected = 0
        self._error: BaseException | None = None
        self._closed = False
        self._t0: float | None = None
        self._first_t0: float | None = None

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> None:
        """Spawn the K stage workers and the collector (idempotent;
        :meth:`submit` calls this lazily on first use)."""
        if self._threads:
            return
        if self._closed:
            raise RuntimeError("PipelineExecutor is closed")
        for i in range(self.partition.n_stages):
            t = threading.Thread(target=self._stage_worker, args=(i,),
                                 name=f"pipeline-stage-{i}", daemon=True)
            t.start()
            self._threads.append(t)
        t = threading.Thread(target=self._collector,
                             name="pipeline-collector", daemon=True)
        t.start()
        self._threads.append(t)

    def close(self) -> None:
        """Stop all workers (waits for in-flight batches to finish).
        Taking the order lock first means no producer is mid-enqueue, so
        the stop sentinel can never overtake a submitted batch into a
        dead queue."""
        if self._closed:
            return
        with self._order_lock:
            self._closed = True
            if self._threads:
                self._queues[0].put(_SENTINEL)
        for t in self._threads:
            t.join()
        self._threads = []

    def __enter__(self) -> "PipelineExecutor":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- intake --------------------------------------------------------------

    def submit(self, frame: np.ndarray) -> None:
        """Queue one float frame ``[H, W, C]`` (or a pre-batched
        ``[N, H, W, C]`` chunk); dispatches whenever ``batch_size`` frames
        are buffered. Thread-safe."""
        frames = normalize_frames(self.program, frame)
        # Buffer-flush and dispatch happen under one order-lock hold, or
        # a second producer could assemble and enqueue a later batch
        # between this one's assembly and its enqueue.
        with self._order_lock:
            full: list[np.ndarray] = []
            with self._lock:
                for f in frames:
                    self._pending.append(f)
                    if len(self._pending) >= self.batch_size:
                        full.append(np.stack(self._pending[:self.batch_size]))
                        self._pending = self._pending[self.batch_size:]
            for batch in full:
                self.submit_batch(batch, len(batch))

    def submit_batch(self, frames: np.ndarray, n_valid: int,
                     tag: object = None) -> None:
        """Dispatch one float micro-batch ``[B, H, W, C]`` (padded with
        zero frames to the compiled batch size if short). Quantizes on the
        calling thread — the host half of the stage-0 double buffer — and
        blocks when the stage-0 queue is full (backpressure)."""
        self._check_error()
        self.start()
        frames = pad_micro_batch(self.program, frames, self.batch_size)
        xq = self.runners[0].quantize(frames)
        # seq assignment and the stage-0 enqueue must be one atomic step,
        # or two producers could enter the FIFO out of submission order
        # (and a close() racing a blocked producer could slot its stop
        # sentinel ahead of this batch).
        with self._order_lock:
            if self._closed:
                raise RuntimeError("PipelineExecutor is closed")
            with self._lock:
                if self._t0 is None:
                    self._t0 = time.perf_counter()
                if self._first_t0 is None:
                    self._first_t0 = time.perf_counter()
                seq = self._submitted
                self._submitted += 1
                self.stats.batches += 1
                self.stats.frames += n_valid
                self.stats.padded_frames += len(frames) - n_valid
            self._put(self._queues[0], ("batch", seq, tag, xq, n_valid))

    def serve(self, frames: Iterable[np.ndarray]) -> list[np.ndarray]:
        """Convenience: submit a finite stream and drain."""
        for f in frames:
            self.submit(f)
        return self.drain()

    def reset_stats(self) -> None:
        """Zero the serve statistics (after a warmup pass, so a measured
        window starts with hot jits and counts every frame: fresh stats
        have ``_first_n = 0`` — no first-batch exclusion needed once
        nothing compiles). Call between drains, not mid-stream."""
        with self._lock:
            if self._collected < self._submitted or self._pending:
                raise RuntimeError("reset_stats with work in flight")
            self.stats = ServeStats()
            self.stage_busy_s = [0.0] * self.partition.n_stages
            self._t0 = None

    def flush_inflight(self) -> None:
        """Protocol no-op: the collector thread delivers results
        continuously, so there is never anything to flush on demand."""

    def wait_idle(self, timeout: float | None = None) -> bool:
        """Block until every submitted micro-batch has cleared all K
        stages (tagged and untagged alike) — the executor-side half of a
        drain->swap->resume handoff. Unlike :meth:`drain` this neither
        flushes the partial tail nor consumes results; it only waits.
        Returns ``True`` when idle, ``False`` on timeout. Raises if a
        stage worker has failed (a dead stage will never go idle)."""
        deadline = (None if timeout is None
                    else time.perf_counter() + float(timeout))
        with self._done:
            while self._collected < self._submitted and self._error is None:
                remaining = 0.1
                if deadline is not None:
                    remaining = min(remaining,
                                    deadline - time.perf_counter())
                    if remaining <= 0:
                        return False
                self._done.wait(timeout=remaining)
        self._check_error()
        return True

    def replica_counts(self) -> list | None:
        """Protocol conformance: a single pipeline is not a replica
        fleet."""
        return None

    # -- drain ---------------------------------------------------------------

    def drain(self) -> list[np.ndarray]:
        """Flush the partial tail, wait for every in-flight micro-batch to
        clear all K stages, and return per-frame outputs of untagged
        batches in submission order. Workers stay alive for reuse."""
        with self._lock:
            tail = self._pending
            self._pending = []
        if tail:
            self.submit_batch(np.stack(tail), len(tail))
        with self._done:
            while self._collected < self._submitted and self._error is None:
                self._done.wait(timeout=0.1)
        self._check_error()
        with self._lock:
            if self._t0 is not None:
                # Active serving window only (idle between drains excluded).
                self.stats.wall_s += time.perf_counter() - self._t0
                self._t0 = None
            results = self._results
            self._results = []
        if not results:
            return []
        flat = np.concatenate(results, axis=0)
        return list(flat)

    # -- workers -------------------------------------------------------------

    def _put(self, q: queue.Queue, item) -> None:
        while True:
            self._check_error()
            try:
                q.put(item, timeout=0.1)
                return
            except queue.Full:
                continue

    def _check_error(self) -> None:
        if self._error is not None:
            raise RuntimeError(
                "pipeline worker failed; no further batches can be "
                "served") from self._error

    def _fail(self, exc: BaseException) -> None:
        with self._done:
            if self._error is None:
                self._error = exc
            self._done.notify_all()

    def _stage_worker(self, i: int) -> None:
        """Run stage i: pull a micro-batch, execute the stage's jitted
        range, hand the int8 boundary activations (or final accumulators)
        to the next queue. FIFO queues + one thread per stage preserve
        submission order end to end."""
        runner = self.runners[i]
        q_in, q_out = self._queues[i], self._queues[i + 1]
        while True:
            item = q_in.get()
            if item[0] == "stop":
                q_out.put(item)
                return
            kind, seq, tag, payload, n_valid = item
            if kind == "batch":
                try:
                    t0 = time.perf_counter()
                    out = runner(payload)
                    out.block_until_ready()
                    self.stage_busy_s[i] += time.perf_counter() - t0
                    item = ("batch", seq, tag, out, n_valid)
                except BaseException as e:  # noqa: BLE001 - forwarded
                    self._fail(e)
                    item = ("err", seq, tag, e, n_valid)
            q_out.put(item)

    def _collector(self) -> None:
        """Final stage: dequantize/argmax on the host (overlapping the
        device stages), deliver results, account completion."""
        runner = self.runners[-1]
        q = self._queues[-1]
        while True:
            item = q.get()
            if item[0] == "stop":
                return
            kind, seq, tag, payload, n_valid = item
            out = None
            if kind == "batch":
                try:
                    out = runner.dequantize(payload)[:n_valid]
                    if self.output == "top1":
                        # reshape(0, -1) is ill-posed for an all-padding
                        # batch; its top-1 is just empty.
                        out = (np.argmax(out.reshape(n_valid, -1), axis=-1)
                               if n_valid else
                               np.zeros((0,), dtype=np.int64))
                except BaseException as e:  # noqa: BLE001 - recorded
                    self._fail(e)
                    kind, payload = "err", e
            with self._done:
                if self._collected == 0 and self._first_t0 is not None:
                    # First micro-batch traverses K cold jits serially —
                    # pipeline fill + compile, charged apart from steady
                    # state exactly like EngineExecutor's first batch.
                    self.stats.first_batch_s = (time.perf_counter()
                                                - self._first_t0)
                self._collected += 1
                if kind == "batch":
                    if tag is None:
                        self._results.append(out)
                self._done.notify_all()
            if tag is not None:
                try:
                    if kind == "batch" and self.on_result:
                        self.on_result(tag, out)
                    elif kind == "err" and self.on_error:
                        # A failed tagged batch must still answer its
                        # requests — deliver the stage error instead of
                        # leaving the futures hanging.
                        self.on_error(tag, payload)
                except BaseException as e:  # noqa: BLE001 - recorded
                    self._fail(e)
