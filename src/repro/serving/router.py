"""Least-estimated-wait routing across pipeline replicas.

Shen et al. (PAPERS.md) raise aggregate accelerator efficiency by
splitting one monolithic design into multiple specialized processors;
the serving-plane analogue is R replicas of the compiled pipeline behind
a router. The router's job is the same pricing problem admission control
already solves for one replica (PR 5), applied per replica:

    wait(r) = inflight_batches(r) * est_window(r) + est_latency(r)

where ``est_window(r)`` is replica r's busy inter-completion window (its
throughput beat — what one more queued batch costs) and ``est_latency(r)``
its dispatch->done traversal, both per-replica
:class:`~repro.serving.estimator.ServiceTimeEstimator` channels under the
same key convention as the frontend (:func:`window_key`).

Placement policy, in order:

* **warm** (every replica has both channels): pick ``argmin wait(r)`` —
  straggler avoidance falls out for free, because a replica whose EWMA
  drifts up prices itself out of the draw;
* **cold** (any estimator empty): power-of-two-choices on queue depth —
  draw two distinct replicas from a seeded RNG, take the one with fewer
  batches in flight (deterministic under the seed for a single
  submitting thread). Replicas already *flagged* as stragglers (latency
  EWMA beyond ``straggler_factor`` x the fleet median) are excluded from
  the cold draw while a healthy replica exists, so a replica that went
  bad after warmup cannot win a coin toss it should lose.

Two health states sit above pricing:

* **quarantine** (dead, not slow): ``quarantine_after`` *consecutive*
  hard failures (dispatch raised, or the batch came back as an error)
  take the replica out of both the warm argmin and the cold draw — the
  straggler flag cannot cover this case because a corpse produces no
  latency observations to drift. Any completed batch clears the state.
* **probes** (the recovery path for both states): an excluded replica
  receives no traffic, so its estimator freezes and — without help — a
  quarantined corpse that came back, or a straggler whose EWMA once
  spiked, stays excluded forever. :meth:`probe_target` fixes that:
  every ``probe_every``-th call (the pool invokes it once per real
  dispatch) it nominates one idle injured replica for a *probe batch* —
  traffic the pool synthesizes and never counts against live requests.
  A probe completion re-admits a quarantined replica and feeds the
  straggler EWMA until it re-enters band; a probe failure keeps the
  quarantine (and costs no live request).

The router never touches frames — :class:`~repro.serving.replica_pool.
ReplicaPool` calls :meth:`pick` before each dispatch and
:meth:`on_complete`/:meth:`on_failure` from the replicas' collector
threads, so every method is thread-safe.
"""

from __future__ import annotations

import threading
import time

import numpy as np

from repro.serving.estimator import ServiceTimeEstimator, window_key

# A replica whose latency EWMA exceeds this multiple of the fleet median
# is flagged a straggler: excluded from cold-start draws, and picked
# warm only when its priced wait still wins (it rarely does).
DEFAULT_STRAGGLER_FACTOR = 3.0

# Consecutive hard failures before a replica is quarantined (excluded
# from all live-traffic picks until a probe batch completes).
DEFAULT_QUARANTINE_AFTER = 3

# One probe batch per this many live dispatches while any replica is
# excluded (quarantined or flagged): the re-admission / EWMA-decay beat.
DEFAULT_PROBE_EVERY = 8


class LeastWaitRouter:
    """Place each micro-batch on the replica with the least estimated
    wait; fall back to seeded power-of-two-choices while cold.

    >>> router = LeastWaitRouter(n_replicas=2, batch_key=32)
    >>> r = router.pick()                   # registers one in-flight batch
    >>> router.on_complete(r, service_s)    # observe + release
    """

    def __init__(self, n_replicas: int, batch_key, *, seed: int = 0,
                 straggler_factor: float = DEFAULT_STRAGGLER_FACTOR,
                 alpha: float | None = None,
                 quarantine_after: int = DEFAULT_QUARANTINE_AFTER,
                 probe_every: int = DEFAULT_PROBE_EVERY):
        if n_replicas < 1:
            raise ValueError(f"n_replicas={n_replicas} < 1")
        if straggler_factor <= 1.0:
            raise ValueError(
                f"straggler_factor={straggler_factor} must be > 1")
        if quarantine_after < 1:
            raise ValueError(
                f"quarantine_after={quarantine_after} must be >= 1")
        if probe_every < 1:
            raise ValueError(f"probe_every={probe_every} must be >= 1")
        self.n_replicas = int(n_replicas)
        self.batch_key = batch_key
        self.straggler_factor = float(straggler_factor)
        self.quarantine_after = int(quarantine_after)
        self.probe_every = int(probe_every)
        self._est_kw = {} if alpha is None else {"alpha": alpha}
        self.estimators = [ServiceTimeEstimator(**self._est_kw)
                           for _ in range(self.n_replicas)]
        self._rng = np.random.default_rng(seed)
        self._lock = threading.Lock()
        self._inflight = [0] * self.n_replicas
        # Per-replica anchor for the busy inter-completion window: the
        # previous completion's timestamp, valid only while the replica
        # stayed busy across the gap (same discipline as the frontend).
        self._last_done: list[float | None] = [None] * self.n_replicas
        self._consec_fails = [0] * self.n_replicas
        self._quarantined = [False] * self.n_replicas
        self._probe_tick = 0
        self._probe_rr = 0
        self.picks = [0] * self.n_replicas
        self.cold_picks = 0
        self.straggler_skips = 0
        self.probe_picks = 0
        self.quarantine_events = 0
        self.readmissions = 0

    # -- pricing -------------------------------------------------------------

    def estimated_wait_s(self, replica: int) -> float | None:
        """Priced wait for one more batch on ``replica``:
        ``inflight * window + latency``. ``None`` while either channel is
        cold (callers fall back to power-of-two-choices)."""
        est = self.estimators[replica]
        lat = est.estimate(self.batch_key)
        win = est.estimate(window_key(self.batch_key))
        if lat is None or win is None:
            return None
        with self._lock:
            inflight = self._inflight[replica]
        return inflight * win + lat

    def is_straggler(self, replica: int) -> bool:
        """True when ``replica``'s latency EWMA has drifted beyond
        ``straggler_factor`` x the fleet median (needs >= 2 replicas with
        latency estimates to define a fleet)."""
        lats = [e.estimate(self.batch_key) for e in self.estimators]
        known = sorted(v for v in lats if v is not None)
        mine = lats[replica]
        if mine is None or len(known) < 2:
            return False
        return mine > self.straggler_factor * float(np.median(known))

    def is_quarantined(self, replica: int) -> bool:
        """True while ``replica`` is excluded for repeated hard failures
        (``quarantine_after`` consecutive). Cleared by any completion —
        in practice a probe batch, since live traffic stops arriving."""
        with self._lock:
            return self._quarantined[replica]

    # -- placement -----------------------------------------------------------

    def pick(self) -> int:
        """Choose a replica for the next micro-batch and register the
        dispatch (one more in flight). Release with :meth:`on_complete`
        or :meth:`on_failure`."""
        if self.n_replicas == 1:
            with self._lock:
                self._inflight[0] += 1
                self.picks[0] += 1
            return 0
        waits = [self.estimated_wait_s(r) for r in range(self.n_replicas)]
        with self._lock:
            # Quarantined replicas sit out both paths (dead beats slow:
            # their frozen estimator would otherwise keep pricing them
            # attractively). If *everything* is quarantined, serve
            # anyway — failing fast beats deadlocking the pool.
            alive = [r for r in range(self.n_replicas)
                     if not self._quarantined[r]]
            if not alive:
                alive = list(range(self.n_replicas))
            if any(waits[i] is None for i in alive):
                r = self._cold_pick_locked(alive)
                self.cold_picks += 1
            else:
                # Ties (fresh symmetric fleet) break toward the shorter
                # queue, then the lowest index — deterministic.
                r = min(alive,
                        key=lambda i: (waits[i], self._inflight[i], i))
            self._inflight[r] += 1
            self.picks[r] += 1
        return r

    def _cold_pick_locked(self, alive: list[int]) -> int:
        """Power-of-two-choices on queue depth, from the seeded RNG.
        Flagged stragglers sit out the draw while a healthy replica
        exists."""
        pool = [r for r in alive if not self.is_straggler(r)]
        if len(pool) < len(alive):
            self.straggler_skips += len(alive) - len(pool)
        if not pool:
            pool = list(alive)
        if len(pool) == 1:
            return pool[0]
        a, b = self._rng.choice(len(pool), size=2, replace=False)
        a, b = pool[int(a)], pool[int(b)]
        if self._inflight[b] < self._inflight[a]:
            return b
        return a

    def probe_target(self) -> int | None:
        """Nominate one excluded replica for a probe batch, or ``None``.

        The pool calls this once per live dispatch; every
        ``probe_every``-th call while any replica is excluded
        (quarantined, or flagged straggler) returns one such replica —
        round-robin across the injured set — and registers the dispatch.
        Only *idle* replicas are nominated: probing a replica with work
        still in flight could block the submitting thread on its full
        stage queue. The probe's :meth:`on_complete` is what re-admits a
        quarantined replica and decays a straggler's frozen EWMA back
        into band; its :meth:`on_failure` keeps the quarantine."""
        if self.n_replicas == 1:
            return None
        flagged = [r for r in range(self.n_replicas) if self.is_straggler(r)]
        with self._lock:
            injured = [r for r in range(self.n_replicas)
                       if (self._quarantined[r] or r in flagged)
                       and self._inflight[r] == 0]
            if not injured or injured == list(range(self.n_replicas)):
                return None
            self._probe_tick += 1
            if self._probe_tick % self.probe_every:
                return None
            r = injured[self._probe_rr % len(injured)]
            self._probe_rr += 1
            self._inflight[r] += 1
            self.probe_picks += 1
        return r

    # -- feedback ------------------------------------------------------------

    def on_complete(self, replica: int, service_s: float,
                    now: float | None = None) -> None:
        """One batch finished on ``replica`` after ``service_s`` seconds:
        fold the traversal latency, fold the busy inter-completion window
        when the replica stayed busy across the gap, release the
        in-flight slot."""
        if now is None:
            now = time.perf_counter()
        est = self.estimators[replica]
        est.observe(self.batch_key, service_s)
        with self._lock:
            last = self._last_done[replica]
            busy = self._inflight[replica] >= 1
            if last is not None and busy:
                window = now - last
                if window > 0:
                    est.observe(window_key(self.batch_key), window)
            self._inflight[replica] = max(0, self._inflight[replica] - 1)
            # The window anchor survives only while more work is queued
            # behind this completion; an idle gap is not a service time.
            self._last_done[replica] = (
                now if self._inflight[replica] > 0 else None)
            # A completed batch is proof of life: clear the failure
            # streak, and re-admit a quarantined replica (probe success).
            self._consec_fails[replica] = 0
            if self._quarantined[replica]:
                self._quarantined[replica] = False
                self.readmissions += 1

    def on_failure(self, replica: int) -> None:
        """A dispatched batch failed (or never reached the replica):
        release the slot, drop the window anchor — the failure gap is
        not a throughput beat — and quarantine the replica once the
        consecutive-failure streak reaches ``quarantine_after``."""
        with self._lock:
            self._inflight[replica] = max(0, self._inflight[replica] - 1)
            self._last_done[replica] = None
            self._consec_fails[replica] += 1
            if (not self._quarantined[replica]
                    and self._consec_fails[replica] >= self.quarantine_after):
                self._quarantined[replica] = True
                self.quarantine_events += 1

    # -- calibration / reporting ---------------------------------------------

    def warm_start(self, window_s: float, latency_s: float) -> None:
        """Seed every replica's two channels from the calibration pass
        (per-replica window = R x the fleet window under round-robin;
        the caller does that arithmetic). Measurements outrank this."""
        for est in self.estimators:
            est.warm_start(window_key(self.batch_key), window_s)
            est.warm_start(self.batch_key, latency_s)

    def reset_pricing(self) -> None:
        """Forget every replica's *measured* verdicts — estimator
        channels, window anchors, failure streaks, quarantine flags —
        so the next :meth:`warm_start` re-seeds the fleet level.

        This is the replay-boundary counterpart of the frontend's
        fresh-estimator-per-replay rule, and it exists because
        :meth:`warm_start` alone cannot undo a starvation spiral: a
        replica starved during a saturated calibration window keeps a
        stale high latency EWMA, the warm argmin then routes nothing to
        it, and — since a merely-mispriced replica is neither
        quarantined nor (with R=2, where its own EWMA drags the fleet
        median) straggler-flagged — no probe ever re-prices it. The
        cumulative telemetry counters (picks, quarantine_events, ...)
        and in-flight accounting survive; only pricing state resets."""
        with self._lock:
            self.estimators = [ServiceTimeEstimator(**self._est_kw)
                               for _ in range(self.n_replicas)]
            self._last_done = [None] * self.n_replicas
            self._consec_fails = [0] * self.n_replicas
            self._quarantined = [False] * self.n_replicas

    def inflight(self, replica: int) -> int:
        with self._lock:
            return self._inflight[replica]

    def snapshot(self) -> dict:
        """JSON-ready router state: per-replica picks, in-flight depth,
        estimator channels, straggler/quarantine flags, and the
        cold-start/skip/probe counters."""
        with self._lock:
            inflight = list(self._inflight)
            picks = list(self.picks)
            cold, skips = self.cold_picks, self.straggler_skips
            probes = self.probe_picks
            quarantines, readmits = self.quarantine_events, self.readmissions
            quarantined = list(self._quarantined)
            fails = list(self._consec_fails)
        return {
            "n_replicas": self.n_replicas,
            "cold_picks": cold,
            "straggler_skips": skips,
            "probe_picks": probes,
            "quarantine_events": quarantines,
            "readmissions": readmits,
            "replicas": [
                {"replica": r, "picks": picks[r], "inflight": inflight[r],
                 "straggler": self.is_straggler(r),
                 "quarantined": quarantined[r],
                 "consecutive_failures": fails[r],
                 "estimator": self.estimators[r].snapshot()}
                for r in range(self.n_replicas)],
        }
