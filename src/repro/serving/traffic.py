"""Seeded synthetic traffic: mixed-class arrival schedules + replay.

Every serving benchmark needs the same thing — an open-loop request
stream at a target rate, with each request assigned a traffic class —
and before this module each bench rolled its own pacing loop. Here it is
once, seeded and recorded, so ``BENCH_serve_async.json`` and
``BENCH_serve_qos.json`` are reproducible from the artifact alone:

* :class:`TrafficClass` names one class of requests: a priority lane, an
  optional per-request deadline, and its share of the arrival mix;
* :func:`make_schedule` draws a deterministic arrival schedule — paced
  inter-arrival times (optionally exponential, i.e. Poisson arrivals)
  and a class per request — from one ``numpy`` RNG seed;
* :func:`make_scenario_schedule` is the adversarial superset — one
  front door over :data:`SCENARIOS`: ``uniform`` / ``poisson`` (the
  PR-5/6 paths, bit-identical under the same seed), ``onoff``
  flash-crowd bursts, heavy-tailed ``lognormal`` / ``pareto``
  inter-arrival, and ``diurnal`` rate ramps — returning the schedule
  plus a JSON-ready record of every resolved parameter, so a chaos
  artifact replays from its own metadata;
* :func:`record_trace` / :func:`trace_schedule` round-trip a schedule
  through a JSON-serializable trace (the recorded-trace replay path:
  measured or captured arrivals re-driven exactly);
* :func:`replay` submits a frame stream through an
  :class:`~repro.serving.frontend.AsyncFrontend` following a schedule
  against *absolute* deadlines (sleep until ``t0 + schedule[i].t``, so
  sleep overshoot never accumulates drift), and waits for every request
  to resolve (completed, failed, or expired — expired requests raise
  out of ``result()`` and are counted, never re-raised here);
* :func:`pacing_report` measures achieved-vs-target submit rate and
  per-arrival lag from the replayed handles, so pacing drift is visible
  in every artifact instead of silently biasing the knee optimistic.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Sequence

import numpy as np

from repro.serving.frontend import (DEFAULT_TENANT, AsyncFrontend,
                                    ServedRequest)

# The canonical two-class mix the QoS bench and launcher default to:
# a latency-sensitive interactive slice over a best-effort bulk floor.
DEFAULT_SLO_MS = 250.0


@dataclasses.dataclass(frozen=True)
class TrafficClass:
    """One traffic class: lane priority, per-request deadline (None =
    best-effort, never dropped), and share of the arrival mix."""

    name: str
    priority: int = 0
    deadline_ms: float | None = None
    share: float = 1.0

    def to_json(self) -> dict:
        return {"name": self.name, "priority": self.priority,
                "deadline_ms": self.deadline_ms, "share": self.share}


def default_mix(slo_ms: float = DEFAULT_SLO_MS) -> tuple[TrafficClass, ...]:
    """interactive (priority 1, deadline ``slo_ms``, 25% of arrivals)
    over batch (priority 0, best-effort, 75%)."""
    return (TrafficClass("interactive", priority=1, deadline_ms=slo_ms,
                         share=0.25),
            TrafficClass("batch", priority=0, deadline_ms=None, share=0.75))


def armed_class_names(mix: Sequence[TrafficClass]) -> tuple[str, ...]:
    """Names of the deadline-armed classes in a mix — the latency-
    sensitive slice whose SLO miss rate defines ``sustained`` for the
    QPS-knee sweep (best-effort classes have no SLO to miss)."""
    return tuple(c.name for c in mix if c.deadline_ms is not None)


def parse_traffic_mix(spec: str,
                      slo_ms: float | None = None) -> tuple[TrafficClass, ...]:
    """Parse ``name:priority:share[:deadline_ms]`` comma-separated, e.g.
    ``interactive:1:0.25:50,batch:0:0.75`` (omitted/'-' deadline =
    best-effort; 'slo' = use ``slo_ms``, which must then be given — a
    silent 0 ms fallback would expire the whole class at submit).
    Shares are normalized."""
    classes = []
    for part in spec.split(","):
        fields = part.strip().split(":")
        if not 3 <= len(fields) <= 4:
            raise ValueError(
                f"traffic-mix entry {part!r} is not "
                f"name:priority:share[:deadline_ms]")
        name, prio, share = fields[0], int(fields[1]), float(fields[2])
        deadline: float | None = None
        if len(fields) == 4 and fields[3] not in ("", "-", "none"):
            if fields[3] == "slo":
                if slo_ms is None or slo_ms <= 0:
                    raise ValueError(
                        f"traffic-mix entry {part!r} uses the 'slo' "
                        f"deadline token but no --slo-ms was given")
                deadline = slo_ms
            else:
                deadline = float(fields[3])
        classes.append(TrafficClass(name, priority=prio,
                                    deadline_ms=deadline, share=share))
    total = sum(c.share for c in classes)
    if total <= 0:
        raise ValueError(f"traffic mix {spec!r} has no positive share")
    return tuple(dataclasses.replace(c, share=c.share / total)
                 for c in classes)


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One scheduled request: submit at ``t`` seconds after stream
    start, frame ``frame_idx`` of the (tenant's) stream, as class
    ``klass``, addressed to ``tenant`` (the default tenant for the
    single-model schedules :func:`make_schedule` draws; a multi-tenant
    bench tags per-tenant schedules with :func:`tag_tenant` and merges
    them by time)."""

    t: float
    frame_idx: int
    klass: TrafficClass
    tenant: str = DEFAULT_TENANT


def tag_tenant(schedule: Sequence[Arrival], tenant: str) -> list[Arrival]:
    """The same schedule addressed to ``tenant`` — the building block
    for multi-tenant replays: draw one seeded schedule per tenant (its
    own rate, mix, and frame indices), tag each, then merge-sort by
    ``t`` into the single interleaved arrival stream one frontend
    replays."""
    return [dataclasses.replace(a, tenant=tenant) for a in schedule]


def merge_schedules(*schedules: Sequence[Arrival]) -> list[Arrival]:
    """Interleave per-tenant schedules into one stream ordered by
    arrival time (stable: equal offsets keep argument order, so the
    merge is deterministic)."""
    merged = [a for s in schedules for a in s]
    merged.sort(key=lambda a: a.t)
    return merged


def make_schedule(n: int, rate_fps: float,
                  classes: Sequence[TrafficClass] | None = None, *,
                  seed: int = 0, poisson: bool = False) -> list[Arrival]:
    """Deterministic arrival schedule for ``n`` requests at ``rate_fps``.

    Class assignment is drawn per request from the mix shares; arrivals
    are uniformly paced at ``1/rate`` (or exponential inter-arrival gaps
    of the same mean with ``poisson=True`` — the bursty open-loop case).
    Everything comes from one ``np.random.default_rng(seed)``, so a
    recorded ``(n, rate, mix, seed, poisson)`` tuple replays the exact
    same stream.
    """
    if n < 0:
        raise ValueError(f"n={n} < 0")
    if classes is None:
        classes = default_mix()
    rng = np.random.default_rng(seed)
    shares = np.asarray([c.share for c in classes], dtype=np.float64)
    shares = shares / shares.sum()
    which = rng.choice(len(classes), size=n, p=shares)
    period = 1.0 / rate_fps if rate_fps > 0 else 0.0
    if poisson and period > 0:
        gaps = rng.exponential(scale=period, size=n)
        times = np.cumsum(gaps) - gaps[0] if n else np.zeros(0)
    else:
        times = np.arange(n) * period
    return [Arrival(t=float(times[i]), frame_idx=i,
                    klass=classes[int(which[i])]) for i in range(n)]


# The adversarial scenario suite (ROADMAP item 5). ``uniform`` and
# ``poisson`` reproduce make_schedule exactly (same RNG draw order), so
# existing artifacts stay comparable; the rest bend the arrival process
# while keeping the same long-run mean rate:
#
#   onoff     - flash crowd: square-wave between a burst rate and a base
#               rate (duty-cycle fraction of each period at burst_factor
#               x base), the input-buffer-overrun case;
#   lognormal - heavy-tailed gaps, lognormal(sigma) with mean 1/rate;
#   pareto    - heavier still: Pareto(alpha) gaps with mean 1/rate
#               (alpha must be > 1 for the mean to exist);
#   diurnal   - slow sinusoidal rate ramp, ``cycles`` periods across the
#               stream, swinging +-amp around the mean rate.
SCENARIOS = ("uniform", "poisson", "onoff", "lognormal", "pareto",
             "diurnal")


def resolve_scenario_params(scenario: str, rate_fps: float,
                            **params) -> dict:
    """Validate + default the knobs of one scenario into the JSON-ready
    record :func:`make_scenario_schedule` stores in artifacts. Unknown
    knobs are an error — a typo must not silently run the default."""
    if scenario not in SCENARIOS:
        raise ValueError(f"unknown scenario {scenario!r} "
                         f"(expected one of {SCENARIOS})")
    out: dict = {"scenario": scenario, "rate_fps": float(rate_fps)}
    if scenario == "onoff":
        bf = float(params.pop("burst_factor", 4.0))
        duty = float(params.pop("duty", 0.25))
        nb = int(params.pop("n_bursts", 4))
        if bf <= 1.0:
            raise ValueError(f"burst_factor={bf} must be > 1")
        if not 0.0 < duty < 1.0:
            raise ValueError(f"duty={duty} must be in (0, 1)")
        if nb < 1:
            raise ValueError(f"n_bursts={nb} must be >= 1")
        out.update(burst_factor=bf, duty=duty, n_bursts=nb)
    elif scenario == "lognormal":
        sigma = float(params.pop("sigma", 1.0))
        if sigma <= 0:
            raise ValueError(f"sigma={sigma} must be > 0")
        out["sigma"] = sigma
    elif scenario == "pareto":
        alpha = float(params.pop("alpha", 1.5))
        if alpha <= 1.0:
            raise ValueError(f"alpha={alpha} must be > 1 for a finite "
                             f"mean inter-arrival gap")
        out["alpha"] = alpha
    elif scenario == "diurnal":
        amp = float(params.pop("amp", 0.8))
        cycles = int(params.pop("cycles", 1))
        if not 0.0 <= amp < 1.0:
            raise ValueError(f"amp={amp} must be in [0, 1)")
        if cycles < 1:
            raise ValueError(f"cycles={cycles} must be >= 1")
        out.update(amp=amp, cycles=cycles)
    if params:
        raise ValueError(f"unknown {scenario!r} scenario params: "
                         f"{sorted(params)}")
    return out


def _scenario_times(n: int, rate_fps: float, rng: np.random.Generator,
                    p: dict) -> np.ndarray:
    period = 1.0 / rate_fps if rate_fps > 0 else 0.0
    scenario = p["scenario"]
    if n == 0 or period == 0.0:
        return np.zeros(n)
    if scenario == "uniform":
        return np.arange(n) * period
    if scenario == "poisson":
        gaps = rng.exponential(scale=period, size=n)
        return np.cumsum(gaps) - gaps[0]
    if scenario == "lognormal":
        # mean of lognormal(mu, sigma) is exp(mu + sigma^2/2): pin the
        # mean gap at 1/rate so the long-run rate matches the target.
        sigma = p["sigma"]
        mu = np.log(period) - sigma * sigma / 2.0
        gaps = rng.lognormal(mean=mu, sigma=sigma, size=n)
        return np.cumsum(gaps) - gaps[0]
    if scenario == "pareto":
        # numpy's pareto is the Lomax form; (x+1)*m is Pareto(alpha)
        # with minimum m and mean m*alpha/(alpha-1): scale for mean gap.
        alpha = p["alpha"]
        m = period * (alpha - 1.0) / alpha
        gaps = (rng.pareto(alpha, size=n) + 1.0) * m
        return np.cumsum(gaps) - gaps[0]
    if scenario == "onoff":
        # Square-wave envelope: duty-cycle fraction of each period runs
        # at burst_factor x the base rate; the base is chosen so the
        # duty-weighted mean equals rate_fps.
        bf, duty, nb = p["burst_factor"], p["duty"], p["n_bursts"]
        duration = n * period
        cycle = duration / nb
        rate_base = rate_fps / (duty * bf + (1.0 - duty))
        rate_on = bf * rate_base
        times = np.empty(n)
        t = 0.0
        for i in range(n):
            times[i] = t
            in_burst = (t % cycle) < duty * cycle
            t += 1.0 / (rate_on if in_burst else rate_base)
        return times
    if scenario == "diurnal":
        # rate(t) swings +-amp around the mean, starting at the trough
        # (1-amp) so the ramp-up through the mean is part of the window.
        amp, cycles = p["amp"], p["cycles"]
        duration = n * period
        times = np.empty(n)
        t = 0.0
        for i in range(n):
            times[i] = t
            r = rate_fps * (1.0 - amp * np.cos(2.0 * np.pi * cycles
                                               * t / duration))
            t += 1.0 / max(r, 1e-9)
        return times
    raise AssertionError(f"unhandled scenario {scenario!r}")


def make_scenario_schedule(scenario: str, n: int, rate_fps: float,
                           classes: Sequence[TrafficClass] | None = None,
                           *, seed: int = 0,
                           **params) -> tuple[list[Arrival], dict]:
    """Deterministic arrival schedule under one adversarial scenario.

    Same contract as :func:`make_schedule` (one RNG, class draw first —
    ``uniform``/``poisson`` reproduce it bit-for-bit under the same
    seed), plus the scenario envelope on the inter-arrival process.
    Returns ``(schedule, record)`` where ``record`` is the JSON-ready
    resolved-parameter dict (scenario, rate, seed, n, every knob) that
    makes the stream reproducible from the artifact alone."""
    if n < 0:
        raise ValueError(f"n={n} < 0")
    if classes is None:
        classes = default_mix()
    p = resolve_scenario_params(scenario, rate_fps, **params)
    rng = np.random.default_rng(seed)
    shares = np.asarray([c.share for c in classes], dtype=np.float64)
    shares = shares / shares.sum()
    which = rng.choice(len(classes), size=n, p=shares)
    times = _scenario_times(n, rate_fps, rng, p)
    schedule = [Arrival(t=float(times[i]), frame_idx=i,
                        klass=classes[int(which[i])]) for i in range(n)]
    record = dict(p, seed=int(seed), n=int(n))
    return schedule, record


def record_trace(schedule: Sequence[Arrival]) -> dict:
    """A JSON-serializable trace of a schedule — class table + per-
    arrival ``[t, frame_idx, class, tenant]`` rows. With
    :func:`trace_schedule` this is the recorded-trace replay path: any
    arrival stream (synthetic or captured) can be stored in an artifact
    and re-driven exactly, independent of the RNG that produced it."""
    classes: dict[str, TrafficClass] = {}
    for a in schedule:
        prev = classes.setdefault(a.klass.name, a.klass)
        if prev != a.klass:
            raise ValueError(
                f"schedule has two different classes named {a.klass.name!r}")
    return {"version": 1,
            "classes": [c.to_json() for c in classes.values()],
            "arrivals": [[float(a.t), int(a.frame_idx), a.klass.name,
                          a.tenant] for a in schedule]}


def trace_schedule(trace: dict) -> list[Arrival]:
    """Rebuild the exact schedule a :func:`record_trace` dict captured."""
    classes = {c["name"]: TrafficClass(
        c["name"], priority=int(c["priority"]),
        deadline_ms=(None if c["deadline_ms"] is None
                     else float(c["deadline_ms"])),
        share=float(c["share"])) for c in trace["classes"]}
    return [Arrival(t=float(t), frame_idx=int(idx), klass=classes[name],
                    tenant=tenant)
            for t, idx, name, tenant in trace["arrivals"]]


def pacing_report(schedule: Sequence[Arrival],
                  reqs: Sequence[ServedRequest]) -> dict:
    """Achieved-vs-target pacing of one replay, from the request
    handles' ``t_submit`` stamps: the achieved submit rate over the
    stream span, the ratio against the scheduled rate, and the
    per-arrival lag behind the absolute schedule (mean / max). A ratio
    near 1 certifies the open loop actually drove the rate the artifact
    claims; a large max lag flags a submit path that fell behind."""
    if len(schedule) != len(reqs):
        raise ValueError(f"schedule has {len(schedule)} arrivals but "
                         f"{len(reqs)} request handles were returned")
    n = len(reqs)
    if n < 2:
        return {"arrivals": n, "target_fps": None, "achieved_fps": None,
                "rate_ratio": None, "lag_ms_mean": None, "lag_ms_max": None}
    t0_sched, t0_real = schedule[0].t, reqs[0].t_submit
    lags = [(reqs[i].t_submit - t0_real) - (schedule[i].t - t0_sched)
            for i in range(n)]
    span_sched = schedule[-1].t - t0_sched
    span_real = reqs[-1].t_submit - t0_real
    target = (n - 1) / span_sched if span_sched > 0 else None
    achieved = (n - 1) / span_real if span_real > 0 else None
    ratio = (achieved / target if achieved is not None
             and target is not None and target > 0 else None)
    return {"arrivals": n,
            "target_fps": None if target is None else round(target, 3),
            "achieved_fps": None if achieved is None else round(achieved, 3),
            "rate_ratio": None if ratio is None else round(ratio, 4),
            "lag_ms_mean": round(1e3 * float(np.mean(lags)), 3),
            "lag_ms_max": round(1e3 * float(np.max(lags)), 3)}


def replay(frontend: AsyncFrontend, frames,
           schedule: Sequence[Arrival], *,
           result_timeout: float = 600.0,
           raise_failed: bool = True) -> list[ServedRequest]:
    """Submit ``frames`` through ``frontend`` following ``schedule``
    (open loop: each request goes in at its scheduled offset, late or
    not), then wait for every request to resolve. ``frames`` is one
    stream array for a single-tenant schedule, or a ``{tenant: stream}``
    mapping for a merged multi-tenant one (each arrival's ``frame_idx``
    indexes its own tenant's stream). Returns the request handles in
    schedule order. Pacing is against *absolute* deadlines — each sleep
    targets ``t0 + a.t``, never a relative gap, so per-sleep overshoot
    cannot accumulate into rate drift at high QPS (pass the handles to
    :func:`pacing_report` to verify). An ``expired`` request is a
    resolved handle (drop-on-SLO-miss is expected QoS behaviour — read
    ``req.outcome``), but a ``failed`` one re-raises its serving error:
    a broken pipeline must fail the bench, not quietly thin out the
    percentile samples. Chaos scenarios that *inject* failures pass
    ``raise_failed=False`` and assert on the outcomes instead."""
    t0 = time.perf_counter()
    reqs: list[ServedRequest] = []
    for a in schedule:
        delay = (t0 + a.t) - time.perf_counter()
        if delay > 0:
            time.sleep(delay)
        stream = frames[a.tenant] if isinstance(frames, dict) else frames
        reqs.append(frontend.submit(
            stream[a.frame_idx], priority=a.klass.priority,
            deadline_ms=a.klass.deadline_ms, klass=a.klass.name,
            tenant=a.tenant))
    deadline = time.perf_counter() + result_timeout
    for r in reqs:
        if not r._event.wait(timeout=max(0.0, deadline - time.perf_counter())):
            raise TimeoutError("replayed request did not resolve")
    if raise_failed:
        for r in reqs:
            if r.outcome == "failed":
                r.result(timeout=0)     # re-raises the serving error
    return reqs
