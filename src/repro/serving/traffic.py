"""Seeded synthetic traffic: mixed-class arrival schedules + replay.

Every serving benchmark needs the same thing — an open-loop request
stream at a target rate, with each request assigned a traffic class —
and before this module each bench rolled its own pacing loop. Here it is
once, seeded and recorded, so ``BENCH_serve_async.json`` and
``BENCH_serve_qos.json`` are reproducible from the artifact alone:

* :class:`TrafficClass` names one class of requests: a priority lane, an
  optional per-request deadline, and its share of the arrival mix;
* :func:`make_schedule` draws a deterministic arrival schedule — paced
  inter-arrival times (optionally exponential, i.e. Poisson arrivals)
  and a class per request — from one ``numpy`` RNG seed;
* :func:`replay` submits a frame stream through an
  :class:`~repro.serving.frontend.AsyncFrontend` following a schedule,
  sleeping out each inter-arrival gap, and waits for every request to
  resolve (completed, failed, or expired — expired requests raise out
  of ``result()`` and are counted, never re-raised here).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Sequence

import numpy as np

from repro.serving.frontend import (DEFAULT_TENANT, AsyncFrontend,
                                    ServedRequest)

# The canonical two-class mix the QoS bench and launcher default to:
# a latency-sensitive interactive slice over a best-effort bulk floor.
DEFAULT_SLO_MS = 250.0


@dataclasses.dataclass(frozen=True)
class TrafficClass:
    """One traffic class: lane priority, per-request deadline (None =
    best-effort, never dropped), and share of the arrival mix."""

    name: str
    priority: int = 0
    deadline_ms: float | None = None
    share: float = 1.0

    def to_json(self) -> dict:
        return {"name": self.name, "priority": self.priority,
                "deadline_ms": self.deadline_ms, "share": self.share}


def default_mix(slo_ms: float = DEFAULT_SLO_MS) -> tuple[TrafficClass, ...]:
    """interactive (priority 1, deadline ``slo_ms``, 25% of arrivals)
    over batch (priority 0, best-effort, 75%)."""
    return (TrafficClass("interactive", priority=1, deadline_ms=slo_ms,
                         share=0.25),
            TrafficClass("batch", priority=0, deadline_ms=None, share=0.75))


def armed_class_names(mix: Sequence[TrafficClass]) -> tuple[str, ...]:
    """Names of the deadline-armed classes in a mix — the latency-
    sensitive slice whose SLO miss rate defines ``sustained`` for the
    QPS-knee sweep (best-effort classes have no SLO to miss)."""
    return tuple(c.name for c in mix if c.deadline_ms is not None)


def parse_traffic_mix(spec: str,
                      slo_ms: float | None = None) -> tuple[TrafficClass, ...]:
    """Parse ``name:priority:share[:deadline_ms]`` comma-separated, e.g.
    ``interactive:1:0.25:50,batch:0:0.75`` (omitted/'-' deadline =
    best-effort; 'slo' = use ``slo_ms``, which must then be given — a
    silent 0 ms fallback would expire the whole class at submit).
    Shares are normalized."""
    classes = []
    for part in spec.split(","):
        fields = part.strip().split(":")
        if not 3 <= len(fields) <= 4:
            raise ValueError(
                f"traffic-mix entry {part!r} is not "
                f"name:priority:share[:deadline_ms]")
        name, prio, share = fields[0], int(fields[1]), float(fields[2])
        deadline: float | None = None
        if len(fields) == 4 and fields[3] not in ("", "-", "none"):
            if fields[3] == "slo":
                if slo_ms is None or slo_ms <= 0:
                    raise ValueError(
                        f"traffic-mix entry {part!r} uses the 'slo' "
                        f"deadline token but no --slo-ms was given")
                deadline = slo_ms
            else:
                deadline = float(fields[3])
        classes.append(TrafficClass(name, priority=prio,
                                    deadline_ms=deadline, share=share))
    total = sum(c.share for c in classes)
    if total <= 0:
        raise ValueError(f"traffic mix {spec!r} has no positive share")
    return tuple(dataclasses.replace(c, share=c.share / total)
                 for c in classes)


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One scheduled request: submit at ``t`` seconds after stream
    start, frame ``frame_idx`` of the (tenant's) stream, as class
    ``klass``, addressed to ``tenant`` (the default tenant for the
    single-model schedules :func:`make_schedule` draws; a multi-tenant
    bench tags per-tenant schedules with :func:`tag_tenant` and merges
    them by time)."""

    t: float
    frame_idx: int
    klass: TrafficClass
    tenant: str = DEFAULT_TENANT


def tag_tenant(schedule: Sequence[Arrival], tenant: str) -> list[Arrival]:
    """The same schedule addressed to ``tenant`` — the building block
    for multi-tenant replays: draw one seeded schedule per tenant (its
    own rate, mix, and frame indices), tag each, then merge-sort by
    ``t`` into the single interleaved arrival stream one frontend
    replays."""
    return [dataclasses.replace(a, tenant=tenant) for a in schedule]


def merge_schedules(*schedules: Sequence[Arrival]) -> list[Arrival]:
    """Interleave per-tenant schedules into one stream ordered by
    arrival time (stable: equal offsets keep argument order, so the
    merge is deterministic)."""
    merged = [a for s in schedules for a in s]
    merged.sort(key=lambda a: a.t)
    return merged


def make_schedule(n: int, rate_fps: float,
                  classes: Sequence[TrafficClass] | None = None, *,
                  seed: int = 0, poisson: bool = False) -> list[Arrival]:
    """Deterministic arrival schedule for ``n`` requests at ``rate_fps``.

    Class assignment is drawn per request from the mix shares; arrivals
    are uniformly paced at ``1/rate`` (or exponential inter-arrival gaps
    of the same mean with ``poisson=True`` — the bursty open-loop case).
    Everything comes from one ``np.random.default_rng(seed)``, so a
    recorded ``(n, rate, mix, seed, poisson)`` tuple replays the exact
    same stream.
    """
    if n < 0:
        raise ValueError(f"n={n} < 0")
    if classes is None:
        classes = default_mix()
    rng = np.random.default_rng(seed)
    shares = np.asarray([c.share for c in classes], dtype=np.float64)
    shares = shares / shares.sum()
    which = rng.choice(len(classes), size=n, p=shares)
    period = 1.0 / rate_fps if rate_fps > 0 else 0.0
    if poisson and period > 0:
        gaps = rng.exponential(scale=period, size=n)
        times = np.cumsum(gaps) - gaps[0] if n else np.zeros(0)
    else:
        times = np.arange(n) * period
    return [Arrival(t=float(times[i]), frame_idx=i,
                    klass=classes[int(which[i])]) for i in range(n)]


def replay(frontend: AsyncFrontend, frames,
           schedule: Sequence[Arrival], *,
           result_timeout: float = 600.0) -> list[ServedRequest]:
    """Submit ``frames`` through ``frontend`` following ``schedule``
    (open loop: each request goes in at its scheduled offset, late or
    not), then wait for every request to resolve. ``frames`` is one
    stream array for a single-tenant schedule, or a ``{tenant: stream}``
    mapping for a merged multi-tenant one (each arrival's ``frame_idx``
    indexes its own tenant's stream). Returns the request handles in
    schedule order. An ``expired`` request is a resolved handle
    (drop-on-SLO-miss is expected QoS behaviour — read
    ``req.outcome``), but a ``failed`` one re-raises its serving error:
    a broken pipeline must fail the bench, not quietly thin out the
    percentile samples."""
    t0 = time.perf_counter()
    reqs: list[ServedRequest] = []
    for a in schedule:
        delay = (t0 + a.t) - time.perf_counter()
        if delay > 0:
            time.sleep(delay)
        stream = frames[a.tenant] if isinstance(frames, dict) else frames
        reqs.append(frontend.submit(
            stream[a.frame_idx], priority=a.klass.priority,
            deadline_ms=a.klass.deadline_ms, klass=a.klass.name,
            tenant=a.tenant))
    deadline = time.perf_counter() + result_timeout
    for r in reqs:
        if not r._event.wait(timeout=max(0.0, deadline - time.perf_counter())):
            raise TimeoutError("replayed request did not resolve")
    for r in reqs:
        if r.outcome == "failed":
            r.result(timeout=0)         # re-raises the serving error
    return reqs
