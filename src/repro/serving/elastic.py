"""Elastic runtime: the measure -> decide -> act loop over a live server.

The paper's "flexible pipelining" balances the engine chain *once*,
offline (Algorithm 1); everything PRs 5-9 added — the per-shape EWMA
estimator, the knee sweep, SLO miss accounting, router quarantine —
only *measures* how well that one-shot balance is holding up under the
traffic actually arriving. :class:`ElasticController` closes the loop:
it watches the signals the stack already produces, and when they cross
hysteresis thresholds it compiles a candidate plan in the background
and swaps it in atomically between micro-batches.

The FPGA correspondence (DESIGN.md section 10): a live rescale is the
serving-plane form of partial reconfiguration — regenerate the
"bitstream" (compile the new stage jits / replica fleet) for the new
resource budget while the old configuration keeps serving, then flip at
a frame boundary. Int8 stage boundaries make the handoff stateless: a
drained pipeline holds nothing but weights, so nothing needs migrating.

Signals (all already produced by the stack, read as deltas per
observation window):

* **armed-miss rate** — expired + refused-at-admission + served-late
  over deadline-armed submissions, from :class:`~repro.serving.frontend
  .FrontendStats` (the same accounting the knee sweep calls a miss);
* **estimator drift** — the live latency EWMA against the value the
  channel was (re)warmed with: sustained drift means the plan the
  admission prices were calibrated for no longer describes the
  executor;
* **router quarantine events** — the cumulative
  ``LeastWaitRouter.quarantine_events`` counter: a replica died
  (a ``ChaosExecutor``-style kill), so the fleet the estimator was
  warmed for is smaller than the fleet admission thinks it has.

Decision rules (:meth:`ElasticController.decide` is pure — given an
observed window it returns the same verdict every time, so the policy
is unit-testable without a server):

* scale **out** (R+1) when the armed-miss rate has exceeded
  ``miss_high`` for ``sustain`` consecutive windows, or the latency
  EWMA has drifted past ``drift_high`` x its warm seed for ``sustain``
  windows, or any quarantine event arrived (a kill triggers rescale
  immediately — the top PR-9 follow-up);
* scale **in** (R-1) when the miss rate has stayed under ``miss_low``
  *and* drift under ``drift_low`` for ``sustain`` windows (both bands,
  so a quiet-but-drifting fleet is never shrunk);
* do nothing inside ``cooldown_s`` of the last rescale, outside the
  ``[min_replicas, max_replicas]`` bounds, or on windows with fewer
  than ``min_window_requests`` armed submissions (a 3-request window
  is noise, not a signal).

The act step delegates to :meth:`repro.serving.server.Server.rescale`,
which builds and warms the new executor while the old one keeps
serving, then performs the drain -> swap -> resume through
:meth:`~repro.serving.frontend.AsyncFrontend.swap_executor` — no
in-flight request is dropped or reordered, and submits are never
rejected during the swap (lanes keep accepting; backpressure only).
"""

from __future__ import annotations

import dataclasses
import threading
import time

from repro.serving.frontend import tenant_key

# One controller default set, shared by ServerConfig.auto_rescale and
# the knee bench's rescale ramp (overridable per field).
DEFAULT_MISS_HIGH = 0.05
DEFAULT_MISS_LOW = 0.005
DEFAULT_DRIFT_HIGH = 2.0
DEFAULT_DRIFT_LOW = 1.3
DEFAULT_SUSTAIN = 2
DEFAULT_COOLDOWN_S = 2.0


@dataclasses.dataclass(frozen=True)
class ElasticPolicy:
    """Hysteresis thresholds for the measure -> decide -> act loop.

    ``miss_high``/``miss_low`` bound the armed-miss-rate band,
    ``drift_high``/``drift_low`` the latency-EWMA-over-warm-seed band;
    crossing the high edge for ``sustain`` consecutive windows scales
    out, staying under *both* low edges for ``sustain`` windows scales
    in — the gap between the edges is the hysteresis that keeps the
    controller from oscillating on a load sitting near one threshold.
    ``cooldown_s`` rate-limits rescales (a swap invalidates the very
    signals the next decision would read, so the controller must wait
    for post-swap windows); ``min_window_requests`` ignores windows
    with too few armed submissions to call a rate."""

    miss_high: float = DEFAULT_MISS_HIGH
    miss_low: float = DEFAULT_MISS_LOW
    drift_high: float = DEFAULT_DRIFT_HIGH
    drift_low: float = DEFAULT_DRIFT_LOW
    sustain: int = DEFAULT_SUSTAIN
    cooldown_s: float = DEFAULT_COOLDOWN_S
    min_replicas: int = 1
    max_replicas: int = 4
    min_window_requests: int = 8
    quarantine_triggers: bool = True

    def __post_init__(self):
        if not 0.0 <= self.miss_low <= self.miss_high <= 1.0:
            raise ValueError(
                f"need 0 <= miss_low ({self.miss_low}) <= miss_high "
                f"({self.miss_high}) <= 1")
        if not 1.0 <= self.drift_low <= self.drift_high:
            raise ValueError(
                f"need 1 <= drift_low ({self.drift_low}) <= drift_high "
                f"({self.drift_high})")
        if self.sustain < 1:
            raise ValueError(f"sustain={self.sustain} must be >= 1")
        if not 1 <= self.min_replicas <= self.max_replicas:
            raise ValueError(
                f"need 1 <= min_replicas ({self.min_replicas}) <= "
                f"max_replicas ({self.max_replicas})")

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class RescaleDecision:
    """One verdict of :meth:`ElasticController.decide`: the action
    (``scale_out`` / ``scale_in``), the target replica count, and the
    signal values that justified it (recorded into the rescale event so
    artifacts explain every reconfiguration)."""

    action: str
    replicas: int
    reason: str
    signals: dict


class ElasticController:
    """Watch one frontend's signals; rescale its server under drift.

    >>> ctrl = ElasticController(server, frontend)
    >>> ctrl.start(interval_s=0.25)     # background observe/decide/act
    >>> ...                             # traffic crosses the knee
    >>> ctrl.stop()
    >>> ctrl.history                    # JSON-ready rescale events

    ``step()`` runs one synchronous observe -> decide -> act round for
    callers that drive the cadence themselves (the stress tests do).
    The controller only ever *adds* work on its own thread — the swap
    itself happens between micro-batches via
    :meth:`AsyncFrontend.swap_executor`, so serving never stops.
    """

    def __init__(self, server, frontend, *, model: str | None = None,
                 policy: ElasticPolicy | None = None):
        self.server = server
        self.frontend = frontend
        self.policy = policy if policy is not None else ElasticPolicy()
        if model is None:
            names = server.model_names
            if len(names) != 1:
                raise ValueError(
                    "a multi-model server needs an explicit model= "
                    f"(registered: {', '.join(names)})")
            model = names[0]
        self.model = model
        self.history: list[dict] = []
        self._lock = threading.Lock()
        self._last_stats = frontend.stats_snapshot()
        self._last_quarantines = self._quarantine_events()
        self._ref_latency: float | None = None
        self._capture_reference()
        self._over = 0          # consecutive windows over a high edge
        self._under = 0         # consecutive windows under both low edges
        self._last_rescale_t: float | None = None
        self._busy = False      # an act (background compile + swap) is
        self._thread: threading.Thread | None = None   # in flight
        self._stop = threading.Event()

    @property
    def busy(self) -> bool:
        """True while an act is in flight — the candidate plan is
        compiling in the background or the swap is mid-drain. Load
        drivers (the knee bench's rescale ramp) poll this to keep
        traffic flowing until the event lands in :attr:`history`."""
        return self._busy

    # -- signal plumbing -----------------------------------------------------

    def _tenant(self) -> str:
        return self.server._tenant_of(self.model)

    def _quarantine_events(self) -> int:
        router = getattr(self.server.runtime(self.model).executor,
                         "router", None)
        if router is None:
            return 0
        return int(router.snapshot()["quarantine_events"])

    def _lat_key(self):
        return tenant_key(self._tenant(), self.frontend.batch_size)

    def _capture_reference(self) -> None:
        """Pin the current latency estimate as the drift reference —
        at construction and after every swap (``rewarm_channels`` has
        just re-seeded the channel from the new plan's calibration), so
        drift always measures the live EWMA against the value the
        *current* plan was priced from."""
        self._ref_latency = self.frontend.estimator.estimate(self._lat_key())

    def _drift(self) -> float | None:
        """Live latency EWMA over the pinned reference for the watched
        tenant's batch-shape channel; None until the channel has both a
        reference and a real observation."""
        est = self.frontend.estimator
        key = self._lat_key()
        cur = est.estimate(key)
        if (cur is None or self._ref_latency is None
                or self._ref_latency <= 0 or est.n_observed(key) == 0):
            return None
        return cur / self._ref_latency

    def observe(self) -> dict:
        """One observation window: deltas of the frontend's armed
        outcome counters since the previous call, the current estimator
        drift ratio, and new router quarantine events. JSON-ready."""
        snap = self.frontend.stats_snapshot()
        prev = self._last_stats
        self._last_stats = snap

        def _armed(st):
            sub = miss = 0
            for cs in st.classes.values():
                if not cs.armed:
                    continue
                sub += cs.submitted
                miss += (cs.expired + cs.rejected + cs.rejected_wait
                         + cs.late)
            return sub, miss

        sub1, miss1 = _armed(snap)
        sub0, miss0 = _armed(prev)
        d_sub, d_miss = sub1 - sub0, miss1 - miss0
        quarantines = self._quarantine_events()
        d_quar = quarantines - self._last_quarantines
        self._last_quarantines = quarantines
        ex = self.server.runtime(self.model).executor
        return {
            "armed_submitted": d_sub,
            "armed_missed": d_miss,
            "armed_miss_rate": (round(d_miss / d_sub, 4) if d_sub else None),
            "drift": (None if (d := self._drift()) is None
                      else round(d, 3)),
            "quarantine_events": d_quar,
            "replicas": getattr(ex, "n_replicas", 1),
            "stages": (ex.partition.n_stages
                       if ex.partition is not None else 1),
        }

    # -- decision (pure) -----------------------------------------------------

    def decide(self, signals: dict) -> RescaleDecision | None:
        """Apply the hysteresis rules to one observed window. Mutates
        only the sustain counters; performs no I/O, touches no executor
        — the policy logic is testable with hand-built signal dicts."""
        p = self.policy
        replicas = int(signals.get("replicas", 1))
        now = time.perf_counter()
        if (self._last_rescale_t is not None
                and now - self._last_rescale_t < p.cooldown_s):
            return None
        # A replica death is not a trend — act on the first event.
        if p.quarantine_triggers and signals.get("quarantine_events", 0) > 0:
            self._over = self._under = 0
            if replicas < p.max_replicas:
                return RescaleDecision(
                    action="scale_out", replicas=replicas + 1,
                    reason="replica quarantined", signals=dict(signals))
            return None
        miss = signals.get("armed_miss_rate")
        drift = signals.get("drift")
        n = signals.get("armed_submitted", 0)
        if miss is None or n < p.min_window_requests:
            # Too quiet to call a rate; trends neither build nor decay.
            return None
        over = miss >= p.miss_high or (drift is not None
                                       and drift >= p.drift_high)
        under = miss <= p.miss_low and (drift is None
                                        or drift <= p.drift_low)
        self._over = self._over + 1 if over else 0
        self._under = self._under + 1 if under else 0
        if self._over >= p.sustain and replicas < p.max_replicas:
            self._over = self._under = 0
            why = (f"armed miss {miss:.2%} >= {p.miss_high:.2%}"
                   if miss >= p.miss_high else
                   f"latency drift {drift:.2f}x >= {p.drift_high:.2f}x")
            return RescaleDecision(
                action="scale_out", replicas=replicas + 1,
                reason=f"{why} for {p.sustain} windows",
                signals=dict(signals))
        if self._under >= p.sustain and replicas > p.min_replicas:
            self._over = self._under = 0
            return RescaleDecision(
                action="scale_in", replicas=replicas - 1,
                reason=(f"armed miss {miss:.2%} <= {p.miss_low:.2%} and "
                        f"no drift for {p.sustain} windows"),
                signals=dict(signals))
        return None

    # -- act -----------------------------------------------------------------

    def step(self) -> dict | None:
        """One synchronous observe -> decide -> act round. Returns the
        JSON-ready rescale event when a reconfiguration happened, else
        None. Thread-safe (the background loop and a caller-driven
        step never interleave mid-round)."""
        with self._lock:
            if self.frontend._closing.is_set():
                return None
            signals = self.observe()
            decision = self.decide(signals)
            if decision is None:
                return None
            t0 = time.perf_counter()
            self._busy = True
            try:
                event = self.server.rescale(self.model,
                                            replicas=decision.replicas)
            finally:
                self._busy = False
            self._last_rescale_t = time.perf_counter()
            event.update({
                "action": decision.action,
                "reason": decision.reason,
                "signals": decision.signals,
                "total_s": round(self._last_rescale_t - t0, 3),
            })
            # The swap re-baselined the estimator and replica counters;
            # stale sustain counts would double-trigger on old evidence.
            self._over = self._under = 0
            self._last_stats = self.frontend.stats_snapshot()
            self._last_quarantines = self._quarantine_events()
            self._capture_reference()
            self.history.append(event)
            return event

    # -- background loop -----------------------------------------------------

    def start(self, interval_s: float = 0.25) -> None:
        """Run :meth:`step` every ``interval_s`` on a daemon thread
        until :meth:`stop` (idempotent while running)."""
        if self._thread is not None and self._thread.is_alive():
            return
        self._stop.clear()

        def _loop():
            while not self._stop.wait(timeout=interval_s):
                try:
                    self.step()
                except Exception:  # noqa: BLE001 - the loop must survive
                    # A failed rescale (e.g. drain timeout) leaves the
                    # old executor serving; the next window retries.
                    continue

        self._thread = threading.Thread(target=_loop,
                                        name="elastic-controller",
                                        daemon=True)
        self._thread.start()

    def stop(self) -> None:
        """Stop the background loop (joins the thread; idempotent)."""
        self._stop.set()
        t = self._thread
        if t is not None:
            t.join()
            self._thread = None

    def __enter__(self) -> "ElasticController":
        return self

    def __exit__(self, *exc) -> None:
        self.stop()
