"""Async request frontend: submission queue, dynamic batcher, latency SLOs.

Non-synthetic traffic arrives one frame at a time, at arbitrary rates;
the engines underneath want fixed-shape micro-batches. The frontend
bridges the two (the ROADMAP's "real async frontend (queue + worker
thread)"):

* :meth:`AsyncFrontend.submit` enqueues a request into a *bounded*
  submission queue and returns a :class:`ServedRequest` handle
  immediately. A full queue blocks the caller (backpressure — the same
  stall a full activation buffer exerts on the paper's producer engine)
  or raises :class:`queue.Full` when ``timeout`` expires.
* a batcher thread assembles micro-batches dynamically: a batch is
  flushed when it reaches ``batch_size`` frames **or** the oldest queued
  request has waited ``max_wait_ms`` — so a lone frame never waits for a
  full batch, and a saturating stream never pays the timeout.
* completed micro-batches come back through the executor's ``on_result``
  hook; per-request latency (submit -> result) is recorded for the
  p50/p95/p99 figures :class:`FrontendStats` reports.

The executor can be a :class:`~repro.serving.pipeline_executor
.PipelineExecutor` (K-stage pipeline) or a thread-safe
:class:`~repro.core.executor.EngineExecutor` (single jit) — anything with
``batch_size``, ``submit_batch(frames, n_valid, tag)`` and an
``on_result`` callback slot.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time

import numpy as np


class ServedRequest:
    """Handle for one in-flight frame: ``result()`` blocks until the
    pipeline answers (re-raising the serving error if its batch failed);
    ``latency_s`` is submit -> result wall time."""

    __slots__ = ("t_submit", "t_done", "_value", "_error", "_event")

    def __init__(self):
        self.t_submit = time.perf_counter()
        self.t_done: float | None = None
        self._value: np.ndarray | None = None
        self._error: BaseException | None = None
        self._event = threading.Event()

    def _resolve(self, value) -> None:
        self._value = value
        self.t_done = time.perf_counter()
        self._event.set()

    def _fail(self, exc: BaseException) -> None:
        self._error = exc
        self.t_done = time.perf_counter()
        self._event.set()

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: float | None = None):
        if not self._event.wait(timeout):
            raise TimeoutError("request not served within timeout")
        if self._error is not None:
            raise RuntimeError("request failed in the serving "
                               "pipeline") from self._error
        return self._value

    @property
    def latency_s(self) -> float | None:
        return None if self.t_done is None else self.t_done - self.t_submit


@dataclasses.dataclass
class FrontendStats:
    """Per-request accounting over one frontend lifetime."""

    submitted: int = 0
    completed: int = 0
    failed: int = 0              # requests resolved with an error
    batches: int = 0
    flushes_full: int = 0        # batches flushed at batch_size
    flushes_timeout: int = 0     # batches flushed by max_wait_ms
    latencies_s: list = dataclasses.field(default_factory=list)
    _t_first: float | None = None
    _t_last: float | None = None

    def latency_percentiles(self) -> dict[str, float]:
        """{'p50','p95','p99','mean'} request latency in seconds (NaN
        when nothing completed yet)."""
        if not self.latencies_s:
            nan = float("nan")
            return {"p50": nan, "p95": nan, "p99": nan, "mean": nan}
        lat = np.asarray(self.latencies_s)
        p50, p95, p99 = np.percentile(lat, [50, 95, 99])
        return {"p50": float(p50), "p95": float(p95), "p99": float(p99),
                "mean": float(lat.mean())}

    @property
    def fps(self) -> float:
        """Completed requests per second over the first-submit ->
        last-result window (includes compile/fill — the client-observed
        rate, unlike the executor's steady_fps)."""
        if self._t_first is None or self._t_last is None:
            return 0.0
        dt = self._t_last - self._t_first
        return self.completed / dt if dt > 0 else 0.0


class AsyncFrontend:
    """Dynamic-batching request frontend over a serving executor.

    >>> with PipelineExecutor(prog, stages=2, batch_size=8) as px:
    ...     fe = AsyncFrontend(px, max_wait_ms=5.0)
    ...     reqs = [fe.submit(f) for f in frames]
    ...     ids = [r.result() for r in reqs]
    ...     fe.close()
    """

    def __init__(self, executor, *, max_wait_ms: float = 5.0,
                 max_queue: int = 256):
        if getattr(executor, "on_result", None) is not None:
            raise ValueError("executor already has an on_result consumer")
        self.executor = executor
        self.batch_size = int(executor.batch_size)
        self.max_wait_s = float(max_wait_ms) / 1e3
        self.stats = FrontendStats()
        self._q: queue.Queue = queue.Queue(maxsize=max(1, int(max_queue)))
        self._closing = threading.Event()
        self._lock = threading.Lock()
        # Makes the closing-check + enqueue in submit() atomic against
        # close(), so no request can slip into the queue after close()'s
        # straggler drain. Separate from _lock: the holder may block on
        # a full submission queue while the batcher (which only needs
        # _lock for stats) drains it.
        self._submit_lock = threading.Lock()
        executor.on_result = self._on_result
        if hasattr(executor, "on_error"):
            # Pipelined executors report stage failures asynchronously;
            # the single-jit executor raises from submit_batch instead
            # (handled in _dispatch).
            executor.on_error = self._on_error
        self._batcher = threading.Thread(target=self._run,
                                         name="frontend-batcher", daemon=True)
        self._batcher.start()

    # -- client side ---------------------------------------------------------

    def submit(self, frame: np.ndarray,
               timeout: float | None = None) -> ServedRequest:
        """Enqueue one float frame ``[H, W, C]``. Blocks while the
        submission queue is full (backpressure); raises ``queue.Full``
        when ``timeout`` (seconds) expires first, ``ValueError`` on a
        frame the compiled program cannot take, and ``RuntimeError``
        after :meth:`close`."""
        if self._closing.is_set():
            raise RuntimeError("frontend is closed")
        req_frame = np.asarray(frame)
        # Reject malformed frames at the client, not inside the batcher
        # thread where one bad frame would poison a whole micro-batch.
        prog = getattr(self.executor, "program", None)
        if prog is not None:
            hw = prog.model.input_hw
            want = (hw, hw, prog.model.input_ch)
            if req_frame.shape != want:
                raise ValueError(f"frame shape {req_frame.shape} does not "
                                 f"match the compiled program {want}")
        req = ServedRequest()
        with self._submit_lock:
            if self._closing.is_set():
                raise RuntimeError("frontend is closed")
            self._q.put((req, req_frame), timeout=timeout)
            with self._lock:
                self.stats.submitted += 1
                if self.stats._t_first is None:
                    self.stats._t_first = req.t_submit
        return req

    def close(self) -> None:
        """Stop accepting requests, flush everything queued, and wait for
        every in-flight request to complete."""
        with self._submit_lock:
            if self._closing.is_set():
                return
            self._closing.set()
        self._batcher.join()
        # A submit() racing close() may have enqueued after the batcher's
        # final empty poll — flush any stragglers here so no request is
        # ever silently dropped.
        leftover = []
        while True:
            try:
                leftover.append(self._q.get_nowait())
            except queue.Empty:
                break
        for i in range(0, len(leftover), self.batch_size):
            self._dispatch(leftover[i:i + self.batch_size], False)
        # Everything is dispatched; make sure trailing micro-batches are
        # collected (PipelineExecutor's collector runs continuously, the
        # single-jit EngineExecutor collects on flush).
        flush = getattr(self.executor, "flush_inflight", None)
        if flush is not None:
            flush()
        deadline = time.perf_counter() + 60.0
        while True:
            with self._lock:
                done = self.stats.completed + self.stats.failed
                if done >= self.stats.submitted:
                    break
            if time.perf_counter() > deadline:
                raise TimeoutError("in-flight requests did not complete")
            time.sleep(0.001)
        # Release the executor for a future frontend (it is documented
        # as reusable across drains) and drop the cross-reference.
        self.executor.on_result = None
        if hasattr(self.executor, "on_error"):
            self.executor.on_error = None

    def __enter__(self) -> "AsyncFrontend":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- batcher -------------------------------------------------------------

    def _run(self) -> None:
        while True:
            try:
                first = self._q.get(timeout=0.01)
            except queue.Empty:
                if self._closing.is_set():
                    return
                # Idle: collect finished micro-batches the single-jit
                # executor is holding (no-op for the pipeline, whose
                # collector thread is always live).
                flush = getattr(self.executor, "flush_inflight", None)
                if flush is not None:
                    flush()
                continue
            batch = [first]
            deadline = first[0].t_submit + self.max_wait_s
            timed_out = False
            while len(batch) < self.batch_size:
                if self._closing.is_set():
                    break
                now = time.perf_counter()
                if now >= deadline:
                    timed_out = True
                    break
                try:
                    batch.append(self._q.get(
                        timeout=min(deadline - now, 0.05)))
                except queue.Empty:
                    continue
            self._dispatch(batch, timed_out)

    def _dispatch(self, batch, timed_out: bool) -> None:
        """Hand one assembled micro-batch to the executor. A dispatch
        failure (e.g. the pipeline died) resolves this batch's requests
        with the error instead of killing the batcher thread — later
        requests still get answers (more errors, most likely), and
        close() still converges."""
        reqs = tuple(r for r, _ in batch)
        with self._lock:
            self.stats.batches += 1
            if len(batch) >= self.batch_size:
                self.stats.flushes_full += 1
            elif timed_out:
                self.stats.flushes_timeout += 1
        try:
            frames = np.stack([f for _, f in batch])
            self.executor.submit_batch(frames, len(frames), tag=reqs)
        except BaseException as e:  # noqa: BLE001 - resolved per request
            with self._lock:
                self.stats.failed += len(reqs)
            for r in reqs:
                r._fail(e)

    # -- completion (runs on the executor's collector thread) ----------------

    def _on_result(self, tag, outputs) -> None:
        now = time.perf_counter()
        with self._lock:
            for i, req in enumerate(tag):
                req._resolve(outputs[i])
                self.stats.completed += 1
                self.stats.latencies_s.append(now - req.t_submit)
            self.stats._t_last = now

    def _on_error(self, tag, exc: BaseException) -> None:
        with self._lock:
            self.stats.failed += len(tag)
        for req in tag:
            req._fail(exc)
