"""Async request frontend: tenant+priority lanes, deadlines, batcher.

Non-synthetic traffic arrives one frame at a time, at arbitrary rates,
and not all of it is equal: an interactive frame wants an answer inside
its deadline, a bulk re-index frame only wants an answer eventually —
and in a multi-model deployment the frames belong to different
*tenants* (compiled models) that must not starve each other. The
engines underneath want fixed-shape micro-batches. The frontend bridges
the two (the QoS analogue of the FPGA's stream arbitration in front of
the engine pipeline):

* :meth:`AsyncFrontend.submit` enqueues a request into a *bounded
  per-``(tenant, priority)`` lane* and returns a :class:`ServedRequest`
  handle immediately. Requests carry ``(tenant, priority,
  deadline_ms)``; a full lane blocks the caller (backpressure — the
  same stall a full activation buffer exerts on the paper's producer
  engine) or raises :class:`queue.Full` when ``timeout`` expires.
  Per-lane bounds mean a flood in one class — or one tenant — cannot
  exhaust another's admission capacity.
* a batcher thread assembles micro-batches dynamically. Across tenants
  it sweeps *weighted round-robin* (``tenant_shares``, default equal):
  each time a new batch opens, every tenant with queued work earns
  credit proportional to its share and the highest-credit tenant wins —
  so a flooding tenant gets its share of batch slots, never all of
  them. Within the winning tenant, lanes drain highest-priority first,
  exactly the single-tenant PR-4 discipline. A batch is *single-tenant*
  (different models take different frame shapes): it is flushed when it
  reaches ``batch_size`` frames, when the oldest member has waited
  ``max_wait_ms``, **or** when holding it any longer would push a
  member past its deadline (the expedited flush). The expedited flush
  fires ``est_service + guard`` before the tightest member deadline,
  where ``est_service`` is an online per-tenant EWMA of measured
  compute phases (:class:`~repro.serving.estimator
  .ServiceTimeEstimator`, fed from each batch's
  ``t_dispatched -> t_done``); with no estimate yet it falls back to
  the static 20%-of-budget guard (``DEADLINE_GUARD_FRAC``), so the
  frontend is transparent to PR-4 behaviour until it has measurements.
* a request whose deadline passes while it is still queued or assembling
  is *dropped*, resolving with an ``expired`` outcome (``result()``
  raises :class:`DeadlineExpired`) instead of wasting a batch slot —
  the software form of a frame-rate bound: a frame that missed its
  display slot is not worth computing.
* with ``admission_control=True``, a deadline-armed request whose
  deadline budget is already smaller than the estimated wait for the
  queued work ahead of it (frames in *its own tenant's* lanes at its
  priority or higher plus its tenant's in-flight micro-batches, priced
  by that tenant's estimator channels) is refused at submit with the
  ``rejected_wait`` outcome — hopeless requests fail fast instead of
  expiring in queue. Pricing only own-tenant work is the admission half
  of isolation: another tenant's flood never inflates this tenant's
  estimated wait.
* every request records four timestamps — ``t_submit`` (enters its
  lane), ``t_batched`` (popped into an assembling batch),
  ``t_dispatched`` (micro-batch handed to the executor), ``t_done``
  (resolved) — so :class:`FrontendStats` can split latency into
  queueing / assembly / compute percentiles *per traffic class* (and
  roll outcomes up *per tenant*), not just end to end.

The executor must conform to the :class:`repro.serving.Executor`
protocol — :class:`~repro.serving.pipeline_executor.PipelineExecutor`
(K-stage pipeline), :class:`~repro.serving.replica_pool.ReplicaPool`
(R routed replicas), the thread-safe single-jit
:class:`~repro.core.executor.EngineExecutor`, or the per-tenant
:class:`~repro.serving.server.TenantMux`; non-conforming objects are
refused with a TypeError naming the missing members.
"""

from __future__ import annotations

import collections
import copy
import dataclasses
import math
import queue
import threading
import time

import numpy as np

from repro.serving.estimator import ServiceTimeEstimator, window_key

DEFAULT_CLASS = "default"
DEFAULT_TENANT = "default"

# Outcomes a ServedRequest can resolve with.
PENDING = "pending"
COMPLETED = "completed"
FAILED = "failed"
EXPIRED = "expired"      # deadline passed while queued/assembling; dropped
REJECTED = "rejected"    # refused at admission (full lane, block=False)
REJECTED_WAIT = "rejected_wait"  # refused: estimated wait exceeds deadline


# Fallback expedited-flush rule, used only until the service-time
# estimator has a measurement: fire when this fraction of a request's
# deadline budget is still left — flushing *at* the deadline would
# dispatch a batch whose deadline-armed members are already dead on
# arrival.
DEADLINE_GUARD_FRAC = 0.2


def tenant_key(tenant: str, shape):
    """The estimator key for ``shape`` scoped to ``tenant``. The default
    tenant keeps the bare shape key, so a single-tenant frontend's
    estimator channels (and everything warm-starting them) are bit-for-
    bit the pre-multi-tenant ones."""
    return shape if tenant == DEFAULT_TENANT else (tenant, shape)


class DeadlineExpired(RuntimeError):
    """The request's deadline passed before it reached the executor."""


class RequestRejected(RuntimeError):
    """The request was refused at admission — lane full (non-blocking
    submit) or estimated wait already past its deadline budget."""


class ServedRequest:
    """Handle for one in-flight frame.

    ``result()`` blocks until the pipeline answers, re-raising the
    serving error if its batch failed, :class:`DeadlineExpired` if the
    request was dropped on an SLO miss, or :class:`RequestRejected` if
    it was refused at admission. The four timestamps
    ``t_submit -> t_batched -> t_dispatched -> t_done`` chart its path
    through lane, batcher, and executor; ``phase_s()`` returns the
    split."""

    __slots__ = ("priority", "deadline_s", "klass", "tenant",
                 "t_submit", "t_batched", "t_dispatched", "t_done",
                 "_value", "_error", "_outcome", "_event")

    def __init__(self, priority: int = 0, deadline_ms: float | None = None,
                 klass: str | None = None, tenant: str = DEFAULT_TENANT):
        self.priority = int(priority)
        self.tenant = str(tenant)
        self.klass = klass if klass is not None else (
            DEFAULT_CLASS if priority == 0 and deadline_ms is None
            else f"p{priority}")
        self.t_submit = time.perf_counter()
        # Absolute wall deadline; None = best-effort (never expires).
        self.deadline_s = (None if deadline_ms is None
                           else self.t_submit + float(deadline_ms) / 1e3)
        self.t_batched: float | None = None
        self.t_dispatched: float | None = None
        self.t_done: float | None = None
        self._value: np.ndarray | None = None
        self._error: BaseException | None = None
        self._outcome = PENDING
        self._event = threading.Event()

    # -- resolution (frontend-internal) --------------------------------------

    def _resolve(self, value) -> None:
        self._value = value
        self._outcome = COMPLETED
        self.t_done = time.perf_counter()
        self._event.set()

    def _fail(self, exc: BaseException) -> None:
        self._error = exc
        self._outcome = FAILED
        self.t_done = time.perf_counter()
        self._event.set()

    def _expire(self) -> None:
        self._outcome = EXPIRED
        self.t_done = time.perf_counter()
        self._event.set()

    def _reject(self, outcome: str = REJECTED) -> None:
        self._outcome = outcome
        self.t_done = time.perf_counter()
        self._event.set()

    # -- client side ---------------------------------------------------------

    @property
    def outcome(self) -> str:
        """'pending' | 'completed' | 'failed' | 'expired' | 'rejected'
        | 'rejected_wait'."""
        return self._outcome

    def done(self) -> bool:
        return self._event.is_set()

    def expired(self) -> bool:
        return self._outcome == EXPIRED

    def result(self, timeout: float | None = None):
        if not self._event.wait(timeout):
            raise TimeoutError("request not served within timeout")
        if self._outcome == EXPIRED:
            raise DeadlineExpired(
                f"request dropped: deadline passed after "
                f"{(self.t_done - self.t_submit) * 1e3:.1f}ms in queue")
        if self._outcome == REJECTED_WAIT:
            raise RequestRejected(
                "request refused at admission: estimated wait for the "
                "queued work ahead already exceeds the deadline budget")
        if self._outcome == REJECTED:
            raise RequestRejected("request refused at admission "
                                  "(lane full)")
        if self._error is not None:
            raise RuntimeError("request failed in the serving "
                               "pipeline") from self._error
        return self._value

    @property
    def latency_s(self) -> float | None:
        return None if self.t_done is None else self.t_done - self.t_submit

    def missed_deadline(self) -> bool:
        """True when the request did not complete inside its deadline —
        dropped (expired), refused for a hopeless wait, or completed
        late."""
        if self.deadline_s is None or self.t_done is None:
            return False
        return (self._outcome in (EXPIRED, REJECTED_WAIT)
                or self.t_done > self.deadline_s)

    def phase_s(self) -> dict[str, float | None]:
        """The latency split the four timestamps define: ``queueing``
        (lane wait), ``assembly`` (in a forming batch), ``compute``
        (executor dispatch -> result). Phases a dropped request never
        reached are None."""
        q = (None if self.t_batched is None
             else self.t_batched - self.t_submit)
        a = (None if self.t_dispatched is None or self.t_batched is None
             else self.t_dispatched - self.t_batched)
        c = (None if self.t_done is None or self.t_dispatched is None
             else self.t_done - self.t_dispatched)
        return {"queueing": q, "assembly": a, "compute": c}


def _percentiles(samples: list) -> dict[str, float]:
    if not samples:
        nan = float("nan")
        return {"p50": nan, "p95": nan, "p99": nan, "mean": nan}
    arr = np.asarray(samples)
    p50, p95, p99 = np.percentile(arr, [50, 95, 99])
    return {"p50": float(p50), "p95": float(p95), "p99": float(p99),
            "mean": float(arr.mean())}


@dataclasses.dataclass
class ClassStats:
    """Per-traffic-class accounting: outcome counts and the phase-split
    latency samples of completed requests. Reused per *tenant* for the
    ``FrontendStats.tenants`` rollup (a tenant is just a coarser
    grouping over the same outcomes)."""

    submitted: int = 0
    completed: int = 0
    failed: int = 0
    expired: int = 0        # dropped on deadline while queued/assembling
    rejected: int = 0       # refused at admission (full lane)
    rejected_wait: int = 0  # refused: estimated wait > deadline budget
    late: int = 0           # completed, but after the deadline
    armed: bool = False     # any submission of this class carried a deadline
    queueing_s: list = dataclasses.field(default_factory=list)
    assembly_s: list = dataclasses.field(default_factory=list)
    compute_s: list = dataclasses.field(default_factory=list)
    total_s: list = dataclasses.field(default_factory=list)

    @property
    def resolved(self) -> int:
        return (self.completed + self.failed + self.expired
                + self.rejected + self.rejected_wait)

    @property
    def drop_rate(self) -> float:
        """Fraction of submissions dropped/refused without compute."""
        if self.submitted == 0:
            return 0.0
        return (self.expired + self.rejected
                + self.rejected_wait) / self.submitted

    @property
    def slo_miss_rate(self) -> float:
        """Fraction of submissions that missed their deadline — dropped,
        refused at admission, or completed late. 0.0 for a class that
        never armed a deadline (best-effort requests have no SLO to
        miss; their admission rejections count only in drop_rate)."""
        if self.submitted == 0 or not self.armed:
            return 0.0
        return (self.expired + self.rejected + self.rejected_wait
                + self.late) / self.submitted

    def phase_percentiles(self) -> dict[str, dict[str, float]]:
        """{'queueing'|'assembly'|'compute'|'total': {p50,p95,p99,mean}}
        in seconds, over *completed* requests (a dropped request never
        reached the later phases, so it would skew them)."""
        return {"queueing": _percentiles(self.queueing_s),
                "assembly": _percentiles(self.assembly_s),
                "compute": _percentiles(self.compute_s),
                "total": _percentiles(self.total_s)}


@dataclasses.dataclass
class FrontendStats:
    """Per-request accounting over one frontend lifetime: totals, a
    per-traffic-class breakdown (``classes``), a per-tenant rollup
    (``tenants`` — same :class:`ClassStats` shape, keyed by tenant, so a
    multi-model server reads each model's outcomes without re-deriving
    them from class names), and — when the executor is a
    :class:`~repro.serving.replica_pool.ReplicaPool` — a per-replica
    outcome breakdown (``replicas``, filled at :meth:`AsyncFrontend
    .close` as the delta of the pool's lifetime counters over this
    frontend's window, so fleet totals reconcile exactly with the sum of
    the per-replica rows)."""

    submitted: int = 0
    completed: int = 0
    failed: int = 0              # requests resolved with an error
    expired: int = 0             # dropped on deadline (SLO miss)
    rejected: int = 0            # refused at admission (full lane)
    rejected_wait: int = 0       # refused: estimated wait > deadline budget
    batches: int = 0
    flushes_full: int = 0        # batches flushed at batch_size
    flushes_timeout: int = 0     # batches flushed by max_wait_ms
    flushes_deadline: int = 0    # batches expedited by a member deadline
    latencies_s: list = dataclasses.field(default_factory=list)
    classes: dict = dataclasses.field(default_factory=dict)
    tenants: dict = dataclasses.field(default_factory=dict)
    replicas: dict = dataclasses.field(default_factory=dict)
    _t_first: float | None = None
    _t_last: float | None = None

    @property
    def resolved(self) -> int:
        """Requests that reached *any* terminal outcome; close() waits
        for this to reconcile exactly with ``submitted``."""
        return (self.completed + self.failed + self.expired
                + self.rejected + self.rejected_wait)

    @property
    def hung(self) -> int:
        """Submitted requests with no terminal outcome yet — the
        liveness headline the chaos artifacts gate at zero (after
        close(), every fault path must have resolved its requests)."""
        return self.submitted - self.resolved

    def klass(self, name: str) -> ClassStats:
        cs = self.classes.get(name)
        if cs is None:
            cs = self.classes[name] = ClassStats()
        return cs

    def tenant_row(self, name: str) -> ClassStats:
        ts = self.tenants.get(name)
        if ts is None:
            ts = self.tenants[name] = ClassStats()
        return ts

    def latency_percentiles(self) -> dict[str, float]:
        """{'p50','p95','p99','mean'} end-to-end request latency in
        seconds over all classes (NaN when nothing completed yet)."""
        return _percentiles(self.latencies_s)

    def phase_percentiles(self) -> dict[str, dict[str, dict[str, float]]]:
        """Per-class phase split: {class: {queueing|assembly|compute|
        total: {p50,p95,p99,mean}}} in seconds."""
        return {name: cs.phase_percentiles()
                for name, cs in sorted(self.classes.items())}

    @property
    def fps(self) -> float:
        """Completed requests per second over the first-submit ->
        last-result window (includes compile/fill — the client-observed
        rate, unlike the executor's steady_fps)."""
        if self._t_first is None or self._t_last is None:
            return 0.0
        dt = self._t_last - self._t_first
        return self.completed / dt if dt > 0 else 0.0


def _require_executor(executor) -> None:
    """Protocol gate: refuse any executor that does not offer the whole
    :class:`repro.serving.Executor` surface, naming what is missing.
    (Imported lazily — the package __init__ imports this module.)"""
    from repro.serving import EXECUTOR_MEMBERS, Executor
    if isinstance(executor, Executor):
        return
    missing = sorted(m for m in EXECUTOR_MEMBERS if not hasattr(executor, m))
    raise TypeError(
        f"{type(executor).__name__} does not conform to the "
        f"repro.serving.Executor protocol (missing: {', '.join(missing)})")


class AsyncFrontend:
    """Dynamic-batching QoS frontend over a serving executor.

    >>> with PipelineExecutor(prog, stages=2, batch_size=8) as px:
    ...     fe = AsyncFrontend(px, max_wait_ms=5.0)
    ...     hi = fe.submit(frame, priority=1, deadline_ms=50.0)
    ...     lo = fe.submit(frame)                   # best-effort
    ...     out = hi.result()
    ...     fe.close()

    ``priority`` orders lanes within a tenant (higher drains first);
    ``deadline_ms`` arms drop-on-SLO-miss and the expedited flush;
    ``tenant`` names the model a request belongs to in a multi-model
    deployment. All default to a single best-effort FIFO class of one
    tenant.

    ``estimator`` is the shared :class:`ServiceTimeEstimator` driving
    the expedited flush (and admission), with channels keyed per tenant
    (:func:`tenant_key` — the default tenant keeps the bare keys); one
    is created per frontend if not given, self-warming from observed
    batches. The serve paths warm it from the calibration pass
    (``batch / measured_steady_fps``). ``admission_control=True``
    enables estimated-wait admission: a deadline-armed request is
    refused (``rejected_wait``) when the estimator prices the queued
    work ahead of it — own-tenant work only — past its deadline budget.
    ``flush_guard_ms`` is the safety margin the expedited flush (and
    admission) keeps against the estimate; ``None`` adapts it to 25% of
    the estimate + 2 ms. ``tenant_shares`` weights the round-robin
    batcher sweep across tenants (default: equal shares; tenants absent
    from the mapping get 1.0). Deadline-less requests are untouched by
    the estimator knobs — the plain best-effort path is unchanged.

    :meth:`swap_executor` repoints a live frontend onto a freshly
    calibrated executor between micro-batches — the elastic runtime's
    drain-swap-resume (see :mod:`repro.serving.elastic`): dispatch
    pauses, submits keep landing in lanes, in-flight batches deliver
    on the old executor, then dispatch resumes on the new one. No
    request is rejected, dropped, or reordered by a swap.
    """

    def __init__(self, executor, *, max_wait_ms: float = 5.0,
                 max_queue: int = 256,
                 estimator: ServiceTimeEstimator | None = None,
                 admission_control: bool = False,
                 flush_guard_ms: float | None = None,
                 tenant_shares: dict[str, float] | None = None):
        _require_executor(executor)
        if executor.on_result is not None:
            raise ValueError("executor already has an on_result consumer")
        self.executor = executor
        self.batch_size = int(executor.batch_size)
        self.max_wait_s = float(max_wait_ms) / 1e3
        self.max_queue = max(1, int(max_queue))
        self.estimator = (estimator if estimator is not None
                          else ServiceTimeEstimator())
        self.admission_control = bool(admission_control)
        self.flush_guard_s = (None if flush_guard_ms is None
                              else float(flush_guard_ms) / 1e3)
        # Weighted round-robin state for the cross-tenant batcher sweep
        # (guarded by _lane_cv, like the lanes it arbitrates).
        self.tenant_shares = dict(tenant_shares or {})
        self._credit: dict[str, float] = {}
        # Micro-batches dispatched but not yet resolved, and frames the
        # batcher has popped into its currently-assembling batch (both
        # guarded by _lock); work in either place is ahead of a new
        # request but visible in neither the lanes nor the executor, so
        # admission must price it explicitly. Tracked per tenant: a
        # request only waits behind its own tenant's work (cross-tenant
        # capacity is governed by the round-robin shares, not priced
        # into admission).
        self._inflight_batches = 0
        self._inflight: dict[str, int] = {}
        self._assembling = 0
        self._assembling_tenant: str | None = None
        # Second estimator channel per tenant: the *completion window*
        # (gap between consecutive batch completions while another of
        # the tenant's batches was still in flight) — the executor's
        # throughput beat, which is what a backlog drains at. Distinct
        # from the latency key because a K-stage pipeline's traversal
        # latency is ~K windows.
        self._window_key = window_key(self.batch_size)
        self._last_done: dict[str, float | None] = {}
        self.stats = FrontendStats()
        self._closing = threading.Event()
        self._lock = threading.Lock()
        # Drain->swap->resume support: the batcher parks assembled
        # batches at this gate while cleared (pause_dispatch), so a live
        # executor swap happens strictly *between* micro-batches.
        # _dispatching marks the window between passing the gate and
        # the in-flight increment (both flipped under _lock), so the
        # swap's quiescence check can never race a batch into the old
        # executor.
        self._dispatch_gate = threading.Event()
        self._dispatch_gate.set()
        self._dispatching = False
        # Lane state: (tenant, priority) -> FIFO deque of (req, frame).
        # _lane_cv guards lanes + per-lane counts; submit() waits on it
        # when its lane is full (backpressure), the batcher waits on it
        # for work. Separate from _lock (stats): a producer blocked on a
        # full lane must not stop the collector thread from recording
        # completions.
        self._lane_cv = threading.Condition()
        self._lanes: dict[tuple[str, int], collections.deque] = {}
        # Replica-pool executors expose exact per-replica outcome
        # counters; baseline them here so close() can report the delta
        # scoped to this frontend's lifetime (the pool's counters span
        # warmup and earlier frontends).
        self._replica_base = executor.replica_counts()
        executor.on_result = self._on_result
        # Pipelined executors report stage failures asynchronously; the
        # single-jit executor raises from submit_batch instead (handled
        # in _dispatch) and simply never calls the slot.
        executor.on_error = self._on_error
        self._batcher = threading.Thread(target=self._run,
                                         name="frontend-batcher", daemon=True)
        self._batcher.start()

    def _lat_key(self, tenant: str):
        return tenant_key(tenant, self.batch_size)

    def _win_key(self, tenant: str):
        return window_key(tenant_key(tenant, self.batch_size))

    # -- client side ---------------------------------------------------------

    def submit(self, frame: np.ndarray, *, priority: int = 0,
               deadline_ms: float | None = None, klass: str | None = None,
               tenant: str = DEFAULT_TENANT,
               timeout: float | None = None,
               block: bool = True) -> ServedRequest:
        """Enqueue one float frame ``[H, W, C]`` into the ``(tenant,
        priority)`` lane. ``deadline_ms`` (from now) arms
        drop-on-SLO-miss; ``klass`` labels the request's traffic class
        for the stats breakdown (default: 'default' for plain requests,
        'p<priority>' otherwise); ``tenant`` routes it to the named
        model behind a multi-tenant executor.

        Blocks while the lane is full (backpressure); raises
        ``queue.Full`` when ``timeout`` (seconds) expires first. With
        ``block=False`` a full lane instead returns a request already
        resolved with the ``rejected`` outcome — load-shedding without
        stalling the caller. Raises ``ValueError`` on a frame the
        compiled program cannot take and ``RuntimeError`` after
        :meth:`close`."""
        if self._closing.is_set():
            raise RuntimeError("frontend is closed")
        req_frame = np.asarray(frame)
        # Reject malformed frames at the client, not inside the batcher
        # thread where one bad frame would poison a whole micro-batch.
        # (program is None behind a multi-tenant mux — the Server
        # validates against the tenant's own program before submitting.)
        prog = self.executor.program
        if prog is not None:
            hw = prog.model.input_hw
            want = (hw, hw, prog.model.input_ch)
            if req_frame.shape != want:
                raise ValueError(f"frame shape {req_frame.shape} does not "
                                 f"match the compiled program {want}")
        req = ServedRequest(priority=priority, deadline_ms=deadline_ms,
                            klass=klass, tenant=tenant)
        deadline = (None if timeout is None
                    else time.perf_counter() + timeout)
        with self._lane_cv:
            if self._closing.is_set():
                raise RuntimeError("frontend is closed")
            # Estimated-wait admission: a deadline-armed request whose
            # budget the queued work ahead already exhausts fails fast
            # (rejected_wait) instead of expiring in queue. Checked
            # before the capacity wait — blocking on a full lane only
            # to expire afterwards would be the worst of both.
            if self._hopeless(req):
                self._reject_wait(req)
                return req
            key = (req.tenant, req.priority)
            lane = self._lanes.get(key)
            if lane is None:
                lane = self._lanes[key] = collections.deque()
            wait_blocked = False
            while len(lane) >= self.max_queue:
                if not block:
                    self._admit(req)
                    req._reject()
                    with self._lock:
                        self.stats.rejected += 1
                        self.stats.klass(req.klass).rejected += 1
                        self.stats.tenant_row(req.tenant).rejected += 1
                    return req
                remaining = (None if deadline is None
                             else deadline - time.perf_counter())
                if remaining is not None and remaining <= 0:
                    raise queue.Full
                wait_blocked = True
                if not self._lane_cv.wait(timeout=remaining):
                    raise queue.Full
                if self._closing.is_set():
                    raise RuntimeError("frontend is closed")
            # Re-price after any backpressure wait: the verdict from
            # before the block is stale — the deadline budget shrank
            # and other producers refilled the queues — and enqueueing
            # on it would let an admitted request expire in queue.
            if wait_blocked and self._hopeless(req):
                self._reject_wait(req)
                return req
            self._admit(req)
            lane.append((req, req_frame))
            self._lane_cv.notify_all()
        return req

    def _admit(self, req: ServedRequest) -> None:
        with self._lock:
            self.stats.submitted += 1
            cs = self.stats.klass(req.klass)
            cs.submitted += 1
            ts = self.stats.tenant_row(req.tenant)
            ts.submitted += 1
            if req.deadline_s is not None:
                cs.armed = True
                ts.armed = True
            if self.stats._t_first is None:
                self.stats._t_first = req.t_submit

    # -- adaptive control (estimator-driven) ---------------------------------

    def _guard_s(self, est: float) -> float:
        """Safety margin kept against the service-time estimate: covers
        batcher poll cadence, host stacking/quantize, and estimator
        noise. Fixed when the caller pinned ``flush_guard_ms``, else
        25% of the estimate + 2 ms."""
        if self.flush_guard_s is not None:
            return self.flush_guard_s
        return 0.25 * est + 0.002

    def _urgent_at(self, req: ServedRequest) -> float:
        """The instant the batcher must flush a batch holding ``req``
        (inf for best-effort requests): ``est_service + guard`` before
        the deadline once the estimator has a measurement for the
        request's tenant, else the static fallback of 80% of the
        deadline budget spent."""
        if req.deadline_s is None:
            return float("inf")
        est = self.estimator.estimate(self._lat_key(req.tenant))
        if est is None:
            return req.deadline_s - DEADLINE_GUARD_FRAC * (req.deadline_s
                                                           - req.t_submit)
        return req.deadline_s - (est + self._guard_s(est))

    def estimated_wait_s(self, priority: int,
                         tenant: str = DEFAULT_TENANT) -> float | None:
        """Estimated completion time (seconds from now) of a request
        entering the ``(tenant, priority)`` lane now:
        ``(backlog_batches - 1) * est_window + est_latency`` over the
        tenant's *own* work — frames in its lanes at this priority or
        higher, its assembling batch, its in-flight micro-batches. The
        backlog drains one batch per *completion window* (EWMA of busy
        inter-completion gaps; a pipelined executor overlaps in-flight
        batches, so pricing them serially at full latency would refuse
        servable requests), then the request's own batch traverses the
        pipeline in ``est_latency`` (EWMA of measured dispatch->done
        phases). For a serial executor window == latency and this
        reduces to pricing every batch at full service time; until a
        window gap has been observed the latency estimate stands in for
        the window. Other tenants' backlogs are deliberately not priced:
        the round-robin sweep guarantees this tenant its share of batch
        slots regardless of their floods (any cross-tenant slowdown
        shows up in this tenant's own observed window instead). ``None``
        until the estimator knows nothing for the tenant. Caller holds
        ``_lane_cv`` (or accepts a racy read)."""
        lat = self.estimator.estimate(self._lat_key(tenant))
        if lat is None:
            return None
        win = self.estimator.estimate(self._win_key(tenant))
        if win is None:
            win = lat
        ahead = sum(len(lane) for (t, prio), lane in self._lanes.items()
                    if t == tenant and prio >= priority)
        with self._lock:
            inflight = self._inflight.get(tenant, 0)
            # The tenant's currently-assembling batch dispatches ahead
            # of any of its lane content regardless of priority.
            if self._assembling_tenant == tenant:
                ahead += self._assembling
        batches = inflight + math.ceil((ahead + 1) / self.batch_size)
        return (batches - 1) * win + lat

    def _hopeless(self, req: ServedRequest) -> bool:
        """True when admission control applies to ``req`` and the
        estimated wait for the work ahead of it already exceeds its
        deadline budget (caller holds _lane_cv)."""
        if not self.admission_control or req.deadline_s is None:
            return False
        wait = self.estimated_wait_s(req.priority, req.tenant)
        if wait is None:
            return False
        est = self.estimator.estimate(self._lat_key(req.tenant))
        budget = req.deadline_s - time.perf_counter()
        return wait + self._guard_s(est) > budget

    def _reject_wait(self, req: ServedRequest) -> None:
        """Resolve ``req`` refused-for-hopeless-wait, with stats."""
        self._admit(req)
        req._reject(REJECTED_WAIT)
        with self._lock:
            self.stats.rejected_wait += 1
            self.stats.klass(req.klass).rejected_wait += 1
            self.stats.tenant_row(req.tenant).rejected_wait += 1

    def control_config(self) -> dict:
        """The adaptive-control knobs as a JSON-ready dict — benches
        record it so knee and QoS artifacts are comparable across PRs.
        The headline estimates are the default tenant's channels (the
        single-model case); the full per-tenant channel map is in
        ``estimator``."""
        est = self.estimator.estimate(self.batch_size)
        win = self.estimator.estimate(self._window_key)
        return {
            "admission_control": self.admission_control,
            "flush_guard_ms": (None if self.flush_guard_s is None
                               else round(self.flush_guard_s * 1e3, 3)),
            "deadline_guard_frac_fallback": DEADLINE_GUARD_FRAC,
            "est_service_ms": (None if est is None
                               else round(est * 1e3, 3)),
            "est_window_ms": (None if win is None
                              else round(win * 1e3, 3)),
            "tenant_shares": dict(self.tenant_shares) or None,
            "estimator": self.estimator.snapshot(),
        }

    def stats_snapshot(self) -> FrontendStats:
        """A consistent deep copy of :attr:`stats`, taken atomically
        under the stats lock. With a replica pool underneath, N
        collector threads mutate the live ``stats`` concurrently
        (counters, latency lists, class dicts); reading it field by
        field mid-flight can tear — e.g. ``resolved > submitted`` or a
        latency list longer than ``completed``. Monitoring loops and the
        stress lane read through this instead."""
        with self._lock:
            return copy.deepcopy(self.stats)

    # -- drain -> swap -> resume (elastic rescale) ---------------------------

    def pause_dispatch(self) -> None:
        """Hold every assembled micro-batch at the dispatch boundary.

        Submits keep landing in the lanes (backpressure only when a lane
        fills — nothing is rejected), the batcher keeps assembling, but
        no new micro-batch enters the executor until
        :meth:`resume_dispatch`. A closing frontend overrides the gate
        so :meth:`close` always converges."""
        self._dispatch_gate.clear()

    def resume_dispatch(self) -> None:
        """Reopen the dispatch gate after :meth:`pause_dispatch`."""
        self._dispatch_gate.set()

    def _quiescent(self) -> bool:
        """True when no micro-batch is in flight *and* the batcher is
        not mid-dispatch (between passing the gate and the in-flight
        increment). Only meaningful while dispatch is paused."""
        with self._lock:
            return self._inflight_batches == 0 and not self._dispatching

    def _merge_replica_delta(self) -> None:
        """Fold the current executor's per-replica outcome delta since
        the last baseline into ``stats.replicas`` (no-op for executors
        without replica counters). Rows merge by replica index across
        executor generations, so the sum over rows keeps reconciling
        with fleet totals after a swap. Caller ensures the executor is
        quiescent for this frontend's traffic."""
        if self._replica_base is None:
            return
        rows = self.executor.replica_counts()
        with self._lock:
            for r, base in enumerate(self._replica_base):
                delta = {k: rows[r][k] - base[k] for k in base}
                cur = self.stats.replicas.get(str(r))
                if cur is None:
                    self.stats.replicas[str(r)] = delta
                else:
                    for k, v in delta.items():
                        cur[k] = cur.get(k, 0) + v

    def swap_executor(self, new_executor, *,
                      drain_timeout_s: float = 60.0):
        """Atomically replace the executor underneath this frontend.

        The drain->swap->resume sequence behind a live rescale
        (``Server.rescale`` / the elastic controller): pause dispatch at
        the micro-batch boundary, wait until every dispatched batch has
        resolved on the old executor (int8 stage boundaries carry no
        cross-batch state, so a drained executor holds nothing), move
        the ``on_result``/``on_error`` slots and the replica-counter
        baseline over, then reopen the gate. Submits are never rejected
        — requests arriving during the drain queue in their lanes and
        dispatch to the new executor in submission order, so no request
        is dropped or reordered. Returns the old executor (drained;
        caller closes it). Raises ``TimeoutError`` if the old executor
        does not drain within ``drain_timeout_s`` (the gate reopens and
        the frontend continues on the old executor)."""
        _require_executor(new_executor)
        if new_executor is self.executor:
            raise ValueError("swap_executor with the executor already "
                             "installed")
        if new_executor.on_result is not None:
            raise ValueError("executor already has an on_result consumer")
        if self._closing.is_set():
            raise RuntimeError("frontend is closed")
        self.pause_dispatch()
        try:
            deadline = time.perf_counter() + float(drain_timeout_s)
            while not self._quiescent():
                if time.perf_counter() > deadline:
                    raise TimeoutError(
                        "executor did not drain within "
                        f"{drain_timeout_s:.1f}s; swap aborted")
                # Single-jit executors deliver on flush, not from a
                # collector thread — keep flushing while we wait.
                self.executor.flush_inflight()
                time.sleep(0.001)
            old = self.executor
            self._merge_replica_delta()
            old.on_result = None
            old.on_error = None
            new_executor.on_result = self._on_result
            new_executor.on_error = self._on_error
            self._replica_base = new_executor.replica_counts()
            with self._lock:
                self.executor = new_executor
                self.batch_size = int(new_executor.batch_size)
                self._window_key = window_key(self.batch_size)
                # The inter-completion beat spans two topologies at the
                # swap point; never observe a window across it.
                self._last_done.clear()
            return old
        finally:
            self.resume_dispatch()

    def close(self) -> None:
        """Stop accepting requests, flush everything queued, and wait for
        every in-flight request to resolve (completed, failed, expired,
        or rejected — nothing may hang)."""
        with self._lane_cv:
            if self._closing.is_set():
                return
            self._closing.set()
            self._lane_cv.notify_all()   # wake producers blocked on a lane
        self._batcher.join()
        # The batcher exits only after its final drain saw every lane
        # empty under _lane_cv, and submit() refuses new requests once
        # _closing is set — so nothing can be left queued here. Collect
        # trailing micro-batches (PipelineExecutor's collector runs
        # continuously, the single-jit EngineExecutor collects on
        # flush — both sides of the protocol's flush_inflight contract).
        self.executor.flush_inflight()
        deadline = time.perf_counter() + 60.0
        while True:
            with self._lock:
                if self.stats.resolved >= self.stats.submitted:
                    break
            if time.perf_counter() > deadline:
                raise TimeoutError("in-flight requests did not complete")
            time.sleep(0.001)
        # Every request has resolved, so the pool's counters are
        # quiescent for this frontend's traffic: fold in the per-replica
        # outcome delta over our lifetime (exact fleet reconciliation —
        # added to any deltas already merged at executor swaps).
        self._merge_replica_delta()
        # Release the executor for a future frontend (it is documented
        # as reusable across drains) and drop the cross-reference.
        self.executor.on_result = None
        self.executor.on_error = None

    def __enter__(self) -> "AsyncFrontend":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- batcher -------------------------------------------------------------

    def _purge_expired(self, now: float) -> None:
        """Drop expired requests from *every* lane (caller holds
        _lane_cv). Expiry cannot wait for a pop: under sustained
        higher-priority traffic a lower lane might never be popped, and
        its deadline-armed requests must still resolve ``expired`` at
        their deadline instead of blocking in result()."""
        for lane in self._lanes.values():
            if not any(r.deadline_s is not None and now > r.deadline_s
                       for r, _ in lane):
                continue
            live = []
            while lane:
                r, f = lane.popleft()
                if r.deadline_s is not None and now > r.deadline_s:
                    self._drop_expired(r)
                else:
                    live.append((r, f))
            lane.extend(live)
            self._lane_cv.notify_all()   # lane freed admission slots

    def _pick_tenant(self) -> str | None:
        """Weighted round-robin choice among tenants with queued work
        (caller holds _lane_cv): every waiting tenant earns credit in
        proportion to its share of the waiting total, the highest
        credit wins one batch slot (ties break by name for
        determinism), and the winner pays one slot back. Over any
        contended interval each tenant's slot count converges to its
        share; a lone tenant nets zero credit, so a returning tenant
        faces no accumulated debt. Credits of idle tenants are dropped —
        fairness is about the present backlog, not hoarded history."""
        waiting: set[str] = {t for (t, _p), lane in self._lanes.items()
                             if lane}
        if not waiting:
            return None
        shares = {t: self.tenant_shares.get(t, 1.0) for t in waiting}
        total = sum(shares.values())
        self._credit = {t: c for t, c in self._credit.items()
                        if t in waiting}
        for t in waiting:
            self._credit[t] = self._credit.get(t, 0.0) + shares[t] / total
        chosen = max(sorted(waiting), key=lambda t: self._credit[t])
        self._credit[chosen] -= 1.0
        return chosen

    def _pop_tenant(self, tenant: str, now: float) -> tuple | None:
        """Pop the oldest live request from ``tenant``'s highest-
        priority non-empty lane (caller holds _lane_cv); None when the
        tenant has nothing live."""
        for key in sorted((k for k in self._lanes if k[0] == tenant),
                          key=lambda k: k[1], reverse=True):
            lane = self._lanes[key]
            while lane:
                req, frame = lane.popleft()
                self._lane_cv.notify_all()  # lane freed a slot
                if (req.deadline_s is not None
                        and now > req.deadline_s):
                    self._drop_expired(req)
                    continue
                return req, frame
        return None

    def _pop_next(self, timeout: float,
                  tenant: str | None = None) -> tuple | None:
        """Pop the next request for the batcher (None on timeout).
        Expired requests anywhere are dropped first — the
        queueing-phase SLO miss — without consuming a batch slot; the
        batcher's poll cadence (<= 50 ms between calls) bounds how
        stale an expiry can go undetected. With ``tenant=None`` (a new
        batch opening) the weighted round-robin sweep picks the tenant;
        a pinned ``tenant`` (filling a single-tenant batch) pops only
        that tenant's lanes, highest priority first."""
        deadline = time.perf_counter() + timeout
        with self._lane_cv:
            while True:
                now = time.perf_counter()
                self._purge_expired(now)
                pick = tenant if tenant is not None else self._pick_tenant()
                if pick is not None:
                    got = self._pop_tenant(pick, now)
                    if got is not None:
                        return got
                    if tenant is None:
                        # The picked tenant held only now-expired work;
                        # re-sweep before consuming any of the timeout.
                        continue
                remaining = deadline - now
                if remaining <= 0 or self._closing.is_set():
                    return None
                self._lane_cv.wait(timeout=remaining)

    def _drop_expired(self, req: ServedRequest) -> None:
        req._expire()
        with self._lock:
            self.stats.expired += 1
            self.stats.klass(req.klass).expired += 1
            self.stats.tenant_row(req.tenant).expired += 1
            self.stats._t_last = req.t_done

    def _run(self) -> None:
        while True:
            nxt = self._pop_next(timeout=0.01)
            if nxt is None:
                if self._closing.is_set():
                    # Final drain: anything a racing submit() slipped in
                    # before _closing was set is still in the lanes.
                    while (nxt := self._pop_next(timeout=0.0)) is not None:
                        self._assemble(nxt)
                    return
                # Idle: collect finished micro-batches the single-jit
                # executor is holding (no-op for the pipeline, whose
                # collector thread is always live).
                self.executor.flush_inflight()
                continue
            self._assemble(nxt)

    def _assemble(self, first: tuple) -> None:
        """Grow a single-tenant micro-batch from ``first`` until
        batch_size, the max_wait timeout, or — the expedited flush —
        the tightest member deadline, then dispatch it. Fill pops are
        pinned to the first request's tenant: models take different
        frame shapes, so a batch can never mix tenants."""
        tenant = first[0].tenant
        batch = [first]
        first[0].t_batched = time.perf_counter()
        with self._lock:
            self._assembling = 1
            self._assembling_tenant = tenant
        flush_at = first[0].t_submit + self.max_wait_s
        # Holding the batch into a member's deadline would turn a
        # servable request into a drop; flush with guard margin instead.
        urgent_at = self._urgent_at(first[0])
        reason = "full"

        def take(nxt) -> None:
            nonlocal urgent_at
            nxt[0].t_batched = time.perf_counter()
            batch.append(nxt)
            with self._lock:
                self._assembling = len(batch)
            urgent_at = min(urgent_at, self._urgent_at(nxt[0]))

        while len(batch) < self.batch_size:
            # Fill from the queued backlog before honoring any flush
            # timer: once lane wait exceeds max_wait the timer is
            # permanently expired, and flushing ahead of a non-empty
            # lane would collapse a backlogged frontend into padded
            # 1-frame batches (service rate / batch_size).
            nxt = self._pop_next(timeout=0.0, tenant=tenant)
            if nxt is not None:
                take(nxt)
                continue
            if self._closing.is_set():
                reason = "timeout"
                break
            now = time.perf_counter()
            if now >= urgent_at:
                reason = "deadline"
                break
            if now >= flush_at:
                reason = "timeout"
                break
            nxt = self._pop_next(
                timeout=min(flush_at - now, urgent_at - now, 0.05),
                tenant=tenant)
            if nxt is not None:
                take(nxt)
        self._dispatch(batch, reason)

    def _dispatch(self, batch, reason: str) -> None:
        """Hand one assembled micro-batch to the executor. Members whose
        deadline passed during assembly are dropped here (the
        assembly-phase SLO miss). A dispatch failure (e.g. the pipeline
        died) resolves this batch's requests with the error instead of
        killing the batcher thread — later requests still get answers
        (more errors, most likely), and close() still converges."""
        # The swap boundary: while pause_dispatch holds the gate, this
        # assembled batch parks here — still counted as assembling, so
        # admission keeps pricing it — and a concurrent swap_executor
        # can drain the old executor knowing no batch is mid-entry
        # (_dispatching flips under the same lock as the in-flight
        # increment). A closing frontend overrides the gate so every
        # parked request still resolves.
        while True:
            with self._lock:
                if self._dispatch_gate.is_set() or self._closing.is_set():
                    self._dispatching = True
                    break
            self._dispatch_gate.wait(timeout=0.05)
        try:
            now = time.perf_counter()
            live = []
            for r, f in batch:
                if r.deadline_s is not None and now > r.deadline_s:
                    self._drop_expired(r)
                else:
                    live.append((r, f))
            if not live:
                with self._lock:
                    self._assembling = 0
                    self._assembling_tenant = None
                return
            # A swap may have shrunk batch_size while this batch was
            # parked; split so no chunk exceeds the compiled shape.
            bs = self.batch_size
            chunks = [live[i:i + bs] for i in range(0, len(live), bs)]
            for chunk in chunks:
                self._dispatch_chunk(chunk, reason, len(batch))
        finally:
            with self._lock:
                self._dispatching = False

    def _dispatch_chunk(self, live, reason: str, assembled_n: int) -> None:
        reqs = tuple(r for r, _ in live)
        tenant = reqs[0].tenant
        t_disp = time.perf_counter()
        for r in reqs:
            r.t_dispatched = t_disp
        with self._lock:
            # One atomic flip from assembling to in-flight: a concurrent
            # admission check must never see this batch in neither
            # counter (it would under-price the work ahead by a batch).
            self._assembling = 0
            self._assembling_tenant = None
            self.stats.batches += 1
            self._inflight_batches += 1
            self._inflight[tenant] = self._inflight.get(tenant, 0) + 1
            if assembled_n >= self.batch_size:
                self.stats.flushes_full += 1
            elif reason == "deadline":
                self.stats.flushes_deadline += 1
            else:
                self.stats.flushes_timeout += 1
        try:
            frames = np.stack([f for _, f in live])
            self.executor.submit_batch(frames, len(frames), tag=reqs)
        except BaseException as e:  # noqa: BLE001 - resolved per request
            for r in reqs:
                r._fail(e)
            with self._lock:
                self._inflight_batches -= 1
                self._inflight[tenant] -= 1
                self._last_done[tenant] = None
                self.stats.failed += len(reqs)
                ts = self.stats.tenant_row(tenant)
                ts.failed += len(reqs)
                for r in reqs:
                    self.stats.klass(r.klass).failed += 1
                    self.stats._t_last = r.t_done

    # -- completion (runs on the executor's collector thread) ----------------

    def _on_result(self, tag, outputs) -> None:
        now = time.perf_counter()
        tenant = tag[0].tenant
        # One observation per micro-batch: the measured compute phase
        # (dispatch -> done) feeds the tenant's EWMA driving the next
        # flush and admission decisions. All of a batch's requests share
        # t_dispatched (and, single-tenant batches, one tenant).
        self.estimator.observe(self._lat_key(tenant),
                               now - tag[0].t_dispatched)
        with self._lock:
            self._inflight_batches -= 1
            n_left = self._inflight.get(tenant, 1) - 1
            self._inflight[tenant] = n_left
            # A completion with another of the tenant's batches still in
            # flight measures its throughput beat (busy inter-completion
            # gap); idle gaps say nothing about drain rate and are
            # skipped — _last_done is cleared whenever the tenant
            # drains, or the first busy completion after an idle spell
            # would observe a "window" spanning the whole idle time.
            last = self._last_done.get(tenant)
            if last is not None and n_left >= 1:
                self.estimator.observe(self._win_key(tenant), now - last)
            self._last_done[tenant] = now if n_left >= 1 else None
            ts = self.stats.tenant_row(tenant)
            for i, req in enumerate(tag):
                req._resolve(outputs[i])
                cs = self.stats.klass(req.klass)
                self.stats.completed += 1
                cs.completed += 1
                ts.completed += 1
                if req.deadline_s is not None and now > req.deadline_s:
                    cs.late += 1
                    ts.late += 1
                self.stats.latencies_s.append(now - req.t_submit)
                ph = req.phase_s()
                cs.queueing_s.append(ph["queueing"])
                cs.assembly_s.append(ph["assembly"])
                cs.compute_s.append(ph["compute"])
                cs.total_s.append(now - req.t_submit)
                ts.total_s.append(now - req.t_submit)
            self.stats._t_last = now

    def _on_error(self, tag, exc: BaseException) -> None:
        for req in tag:
            req._fail(exc)
        tenant = tag[0].tenant
        with self._lock:
            self._inflight_batches -= 1
            self._inflight[tenant] = self._inflight.get(tenant, 1) - 1
            # A failed batch is not a completion: the next success must
            # not measure a "window" spanning this batch's interval.
            self._last_done[tenant] = None
            self.stats.failed += len(tag)
            self.stats.tenant_row(tenant).failed += len(tag)
            for req in tag:
                self.stats.klass(req.klass).failed += 1
            self.stats._t_last = time.perf_counter()
