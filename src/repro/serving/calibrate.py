"""One implementation of warmup / throughput calibration for every
serve path.

Three routines shared by the single-model serve paths, the
multi-tenant server's per-tenant warm-start, and live rescale
recalibration:

- :func:`pipeline_throughput` — compile-warm a pipeline (or replica
  pool), measure the unloaded single-batch traversal, then measure
  closed-loop steady-state throughput over a clean stats window;
- :func:`default_max_wait_ms` — the one-full-batch-window flush-timeout
  convention;
- :func:`warmed_frontend` — a fresh :class:`AsyncFrontend` whose
  estimator (and router, for a pool) is warm-started from that
  calibration, the shared convention behind every QoS rate and knee
  probe.

:func:`repro.serving.server.build_server` runs the same
:func:`pipeline_throughput` per tenant, and :meth:`Server.rescale
<repro.serving.server.Server.rescale>` runs it on every candidate
executor before swapping it live, so warm-start numbers everywhere are
measured by exactly the code the single-model benches use.
"""

from __future__ import annotations

import dataclasses
import time


def pipeline_throughput(px, stream, batch: int):
    """Warmup + closed-loop steady-state throughput of one pipeline:
    one micro-batch through all K stages compiles every stage jit (stats
    reset afterwards so the measured window is pure steady state —
    without this, batches queued during the cold compiles flood out the
    moment the pipeline opens and a short stream reads an absurd fps),
    then a saturating closed-loop pass. Returns ``(warmup_s, lat1_s,
    phase-1 stats snapshot)`` — snapshotting keeps the counts describing
    exactly the window steady_fps was measured over (later frontend
    phases keep accumulating into ``px.stats``). A replica pool warms
    every replica (all R x K stage jits), so no probe ever pays a cold
    compile mid-measurement."""
    t0 = time.perf_counter()
    warm = getattr(px, "warmup", None)
    if warm is not None:
        warm(list(stream[:batch]))
    else:
        px.serve(list(stream[:batch]))
    warmup_s = time.perf_counter() - t0
    # One more single-batch pass through the now-compiled, *empty*
    # pipeline: the unloaded K-stage traversal. This is the honest seed
    # for the admission latency channel — the closed-loop pass below
    # runs saturated, so its per-batch dispatch->done times include
    # stage-queue waits that an admitted open-loop request never sees.
    t0 = time.perf_counter()
    px.serve(list(stream[:batch]))
    lat1_s = time.perf_counter() - t0
    px.reset_stats()
    px.serve(list(stream))
    return warmup_s, lat1_s, dataclasses.replace(px.stats)


def default_max_wait_ms(batch: int, rate: float) -> float:
    """One full batch assembles in batch/rate seconds; waiting any less
    flushes padded partial batches faster than the pipeline drains them
    (service rate collapses), any more only parks the first frame of a
    quiet period."""
    return 1e3 * batch / rate if rate > 0 else 50.0


def warmed_frontend(px, steady: float, rate: float, batch: int, *,
                    max_wait_ms: float | None,
                    admission_control: bool,
                    flush_guard_ms: float | None,
                    lat1_s: float | None = None,
                    max_queue: int = 256):
    """One convention for the per-replay control plane — shared by the
    QoS rates and the knee probes so their artifacts stay comparable: a
    fresh estimator per replay (an overload replay's noisy tail must
    not skew the next replay's admission), warm-started from the
    measured calibration throughput (:meth:`ServiceTimeEstimator
    .warm_start_channels`) — the window channel at the fleet batch
    window (``batch / steady``), the latency channel at
    ``stages x replicas x window`` (a K-stage traversal is ~K windows,
    and R-way routing multiplies each replica's per-batch beat by R) —
    behind a frontend whose ``max_wait`` defaults to one full-batch
    window at the arrival rate. When the calibration pass measured the
    *unloaded* single-batch traversal (``lat1_s``), that measurement
    replaces the formula on the latency channel: the ``K x R x window``
    bound assumes fleet throughput scales linearly with R, which
    overprices admission whenever replicas share silicon (the backlog
    ahead of a request is priced separately, via the window channel, so
    the latency channel must NOT bake queueing in). With a replica pool
    underneath, the router's per-replica estimators get the matching
    per-replica formula seed — router pricing is relative across
    replicas, so a shared bias cancels — and admission itself stays on
    the fleet numbers: the frontend's shared estimator observes the
    interleaved completion beat of all R replicas. The router's
    fresh-start is *forced* (:meth:`LeastWaitRouter.reset_pricing`
    before the warm seed): ``warm_start`` alone defers to existing
    measurements, so a replica starved during the saturated calibration
    pass would keep its stale high EWMA and be priced out of every
    subsequent pick — the starvation-hysteresis liveness bug the chaos
    fault replays flushed out."""
    from repro.serving.estimator import ServiceTimeEstimator
    from repro.serving.frontend import AsyncFrontend
    n_replicas = getattr(px, "n_replicas", 1)
    warm = batch / max(steady, 1e-9)
    est = ServiceTimeEstimator()
    est.warm_start_channels(batch, warm, stages=px.partition.n_stages,
                            replicas=n_replicas)
    if lat1_s is not None and lat1_s > 0:
        est.warm_start(batch, lat1_s)
    router = getattr(px, "router", None)
    if router is not None:
        router.reset_pricing()
        router.warm_start(n_replicas * warm,
                          px.partition.n_stages * n_replicas * warm)
    wait_ms = (max_wait_ms if max_wait_ms is not None
               else default_max_wait_ms(batch, min(rate, steady)))
    return AsyncFrontend(px, max_wait_ms=wait_ms, estimator=est,
                         admission_control=admission_control,
                         flush_guard_ms=flush_guard_ms,
                         max_queue=max_queue)
