"""R replicas of the compiled pipeline behind a least-estimated-wait router.

The paper's Algorithm 1 balances engine resources across the stages of
*one* pipeline; Shen et al. (PAPERS.md) show the next efficiency tier
comes from splitting the fabric into multiple specialized processors.
:class:`ReplicaPool` is that move for the serving plane: it instantiates
R independent :class:`~repro.serving.pipeline_executor.PipelineExecutor`
replicas of one compiled :class:`~repro.core.program.EngineProgram` and
routes each ready micro-batch to the replica with the least estimated
wait (:class:`~repro.serving.router.LeastWaitRouter`).

Two replica modes co-partition the device mesh:

* ``pipeline`` — whole-pipeline data parallelism: replica r's K stages
  all pin to ``devices[r % D]``, so each device runs one complete
  pipeline (the Shen "one specialized processor per partition" shape);
* ``stage-shard`` — the D devices split into R contiguous near-equal
  slices (:func:`repro.launch.mesh.device_slices`) and each replica
  stage-pipelines *across its slice*: the Algorithm-1 DP balances the
  step chain into ``len(slice)`` stages and stage i pins to slice[i]
  (replication x flexible pipelining composed).

The pool satisfies the executor duck type the
:class:`~repro.serving.frontend.AsyncFrontend` expects (``batch_size``,
``submit_batch(frames, n_valid, tag)``, ``on_result``/``on_error``
slots, ``program``), so the frontend — lanes, deadlines, admission —
is structurally unchanged: admission keeps pricing the *fleet* backlog
because its shared estimator observes the interleaved completion beat
of all R replicas. Every replica dispatch is wrapped in a pool tag, so
per-replica outcomes (dispatched/completed/failed) are counted exactly
and :meth:`replica_counts` reconciles against fleet totals.

Bit-identity: routing only chooses *where* a micro-batch runs; every
replica executes the same compiled step chain with the same int8 stage
boundaries, so pooled output equals the single-replica pipeline frame
for frame in both modes (pinned by ``tests/test_router.py``).
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Iterable, Sequence

import numpy as np

from repro.core.executor import ServeStats, normalize_frames
from repro.core.program import EngineProgram
from repro.serving.pipeline_executor import (DEFAULT_QUEUE_DEPTH,
                                             PipelineExecutor)
from repro.serving.router import (DEFAULT_PROBE_EVERY,
                                  DEFAULT_QUARANTINE_AFTER,
                                  DEFAULT_STRAGGLER_FACTOR, LeastWaitRouter)

REPLICA_MODES = ("pipeline", "stage-shard")


@dataclasses.dataclass(frozen=True)
class _Dispatch:
    """Pool-level tag wrapped around every replica submission: which
    replica got batch ``seq``, when, how many frames were real, and the
    caller's own tag (None for the drain path). ``probe`` marks router
    health probes — synthetic all-padding batches that feed the router
    (re-admission / straggler decay) but never touch live accounting."""

    seq: int
    replica: int
    n_valid: int
    t_disp: float
    tag: object
    probe: bool = False


def _fresh_row() -> dict:
    return {"dispatched_batches": 0, "dispatched_frames": 0,
            "completed_batches": 0, "completed_frames": 0,
            "failed_batches": 0, "failed_frames": 0,
            "probe_batches": 0}


class ReplicaPool:
    """Serve one frame stream through R routed pipeline replicas.

    >>> pool = ReplicaPool(program, replicas=2, stages=2, batch_size=32)
    >>> for frame in frames:
    ...     pool.submit(frame)
    >>> ids = pool.drain()          # per-frame outputs, submission order
    >>> pool.close()

    ``executors`` swaps in pre-built replica executors (tests use fakes
    with a ``submit_batch``/``on_result`` surface); otherwise R
    :class:`PipelineExecutor` replicas are compiled from ``program``
    according to ``mode``.
    """

    def __init__(self, program: EngineProgram | None = None, *,
                 executors: Sequence[object] | None = None,
                 replicas: int = 2, mode: str = "pipeline",
                 stages: int = 2, batch_size: int = 32,
                 route: str | None = None, interpret: bool | None = None,
                 donate: bool | None = None, output: str = "top1",
                 queue_depth: int = DEFAULT_QUEUE_DEPTH,
                 devices: Sequence[object] | None = None,
                 router_seed: int = 0,
                 straggler_factor: float = DEFAULT_STRAGGLER_FACTOR,
                 quarantine_after: int = DEFAULT_QUARANTINE_AFTER,
                 probe_every: int = DEFAULT_PROBE_EVERY,
                 on_result: Callable[[object, np.ndarray], None] | None = None,
                 on_error: Callable[[object, BaseException], None] | None = None):
        if mode not in REPLICA_MODES:
            raise ValueError(f"unknown replica mode {mode!r} "
                             f"(expected one of {REPLICA_MODES})")
        self.program = program
        self.mode = mode
        self.output = output
        self.on_result = on_result
        self.on_error = on_error

        if executors is not None:
            self.replicas = list(executors)
            if not self.replicas:
                raise ValueError("executors is empty")
            self.batch_size = int(getattr(self.replicas[0], "batch_size",
                                          batch_size))
            self.replica_devices: list[list[str] | None] = \
                [None] * len(self.replicas)
        else:
            if program is None:
                raise ValueError("need a program or pre-built executors")
            if replicas < 1:
                raise ValueError(f"replicas={replicas} < 1")
            self.batch_size = int(batch_size)
            self.replicas, self.replica_devices = self._build_replicas(
                program, replicas, mode, stages=stages, batch_size=batch_size,
                route=route, interpret=interpret, donate=donate,
                output=output, queue_depth=queue_depth, devices=devices)
        self.n_replicas = len(self.replicas)
        self.partition = getattr(self.replicas[0], "partition", None)
        self.route = getattr(self.replicas[0], "route", route)
        self.router = LeastWaitRouter(self.n_replicas, self.batch_size,
                                      seed=router_seed,
                                      straggler_factor=straggler_factor,
                                      quarantine_after=quarantine_after,
                                      probe_every=probe_every)

        self.stats = ServeStats()
        self.stats._first_n = self.batch_size
        # RLock: completion callbacks from N replica collector threads
        # mutate fleet stats + per-replica rows concurrently with
        # submitters and snapshot readers.
        self._lock = threading.RLock()
        self._done = threading.Condition(self._lock)
        # Serializes batch assembly + routing + replica enqueue for
        # multi-producer submit(), mirroring PipelineExecutor's order
        # lock (the holder may block on a full replica queue while the
        # completion path takes _lock).
        self._order_lock = threading.RLock()
        self._pending: list[np.ndarray] = []
        self._results: dict[int, np.ndarray] = {}
        self._rows = [_fresh_row() for _ in range(self.n_replicas)]
        self._submitted = 0
        self._collected = 0
        self._error: BaseException | None = None
        self._closed = False
        self._t0: float | None = None
        self._first_t0: float | None = None

        for i, rep in enumerate(self.replicas):
            rep.on_result = self._replica_done
            if hasattr(rep, "on_error"):
                rep.on_error = self._replica_error

    @staticmethod
    def _build_replicas(program, replicas, mode, *, stages, batch_size,
                        route, interpret, donate, output, queue_depth,
                        devices):
        import jax  # deferred: fake-executor pools never touch devices

        from repro.launch.mesh import device_slices
        devs = list(jax.devices() if devices is None else devices)
        if mode == "pipeline":
            # Whole pipeline per device: replica r's stages all share
            # devices[r % D].
            slices = [[devs[r % len(devs)]] for r in range(replicas)]
        else:
            slices = device_slices(replicas, devs)
        built, built_devs = [], []
        for r in range(replicas):
            sl = slices[r]
            # stage-shard co-partition: as many stages as the replica
            # has devices (the DP balances the step chain over them);
            # pipeline mode keeps the requested stage count.
            n_stages = stages if mode == "pipeline" else max(1, len(sl))
            built.append(PipelineExecutor(
                program, stages=n_stages, batch_size=batch_size,
                route=route, interpret=interpret, donate=donate,
                output=output, queue_depth=queue_depth, devices=sl))
            built_devs.append([str(d) for d in sl])
        return built, built_devs

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> None:
        for rep in self.replicas:
            s = getattr(rep, "start", None)
            if s is not None:
                s()

    def close(self) -> None:
        """Close every replica (each waits for its in-flight batches, so
        all pool callbacks have fired when this returns)."""
        if self._closed:
            return
        self._closed = True
        for rep in self.replicas:
            c = getattr(rep, "close", None)
            if c is not None:
                c()

    def __enter__(self) -> "ReplicaPool":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- intake --------------------------------------------------------------

    def submit(self, frame: np.ndarray) -> None:
        """Queue one float frame (or pre-batched chunk); routes a
        micro-batch whenever ``batch_size`` frames are buffered.
        Thread-safe."""
        if self.program is not None:
            frames = normalize_frames(self.program, frame)
        else:
            frames = [np.asarray(frame)]
        with self._order_lock:
            full: list[np.ndarray] = []
            with self._lock:
                for f in frames:
                    self._pending.append(f)
                    if len(self._pending) >= self.batch_size:
                        full.append(np.stack(self._pending[:self.batch_size]))
                        self._pending = self._pending[self.batch_size:]
            for batch in full:
                self.submit_batch(batch, len(batch))

    def submit_batch(self, frames: np.ndarray, n_valid: int,
                     tag: object = None) -> None:
        """Route one float micro-batch to the least-wait replica and
        dispatch it there. Blocks when that replica's stage-0 queue is
        full (per-replica backpressure). Thread-safe; results may
        complete out of submission order across replicas (drain reorders
        by sequence number)."""
        self._check_error()
        n_valid = int(n_valid)
        with self._order_lock:
            if self._closed:
                raise RuntimeError("ReplicaPool is closed")
            r = self.router.pick()
            now = time.perf_counter()
            with self._lock:
                if self._t0 is None:
                    self._t0 = now
                if self._first_t0 is None:
                    self._first_t0 = now
                seq = self._submitted
                self._submitted += 1
                self.stats.batches += 1
                self.stats.frames += n_valid
                self.stats.padded_frames += max(0, self.batch_size - n_valid)
                row = self._rows[r]
                row["dispatched_batches"] += 1
                row["dispatched_frames"] += n_valid
            disp = _Dispatch(seq=seq, replica=r, n_valid=n_valid,
                             t_disp=time.perf_counter(), tag=tag)
            try:
                self.replicas[r].submit_batch(frames, n_valid, tag=disp)
            except BaseException:
                # The batch never entered the replica: release the
                # router slot and account the failure so drain/close
                # cannot wait on a batch that will never complete.
                self.router.on_failure(r)
                with self._done:
                    self._collected += 1
                    row = self._rows[r]
                    row["failed_batches"] += 1
                    row["failed_frames"] += n_valid
                    self._done.notify_all()
                raise
            self._maybe_probe(frames)

    def _maybe_probe(self, frames: np.ndarray) -> None:
        """Dispatch one all-padding probe batch when the router asks for
        one (an excluded replica is due its health check). Probes ride
        the live submit beat but live outside it: they never count in
        ``_submitted``/``_collected`` or the outcome rows beyond their
        own ``probe_batches`` counter, so no live request is ever
        sacrificed to discover that a quarantined replica came back (or
        that a flagged straggler's EWMA re-entered band)."""
        p = self.router.probe_target()
        if p is None:
            return
        disp = _Dispatch(seq=-1, replica=p, n_valid=1,
                         t_disp=time.perf_counter(), tag=None, probe=True)
        with self._lock:
            self._rows[p]["probe_batches"] += 1
        try:
            # Fresh copy: the live replica may donate/consume its input
            # buffer, and the probe replica must see intact frames. One
            # valid frame, so the probe observes a real traversal.
            self.replicas[p].submit_batch(np.array(frames, copy=True), 1,
                                          tag=disp)
        except BaseException:
            # A dead replica refuses the probe synchronously: feed the
            # router (quarantine persists) and move on — probes are
            # best-effort by construction.
            self.router.on_failure(p)

    def serve(self, frames: Iterable[np.ndarray]) -> list[np.ndarray]:
        """Convenience: submit a finite stream and drain."""
        for f in frames:
            self.submit(f)
        return self.drain()

    def warmup(self, frames: Iterable[np.ndarray]) -> None:
        """Run one drained pass through *every* replica directly (all
        R x K stage jits compile), bypassing the router so no replica is
        left cold. Follow with :meth:`reset_stats` for a hot measured
        window."""
        frames = list(frames)
        for rep in self.replicas:
            rep.serve(frames)

    def flush_inflight(self) -> None:
        """Protocol no-op: every replica's collector thread delivers
        results continuously."""

    def wait_idle(self, timeout: float | None = None) -> bool:
        """Block until every dispatched micro-batch has cleared its
        replica — the fleet-side half of a drain->swap->resume handoff.
        Does not flush the partial tail or consume drain results; only
        waits. Returns ``True`` when idle, ``False`` on timeout. Raises
        if a replica failed on the untagged drain path."""
        deadline = (None if timeout is None
                    else time.perf_counter() + float(timeout))
        with self._done:
            while self._collected < self._submitted and self._error is None:
                remaining = 0.1
                if deadline is not None:
                    remaining = min(remaining,
                                    deadline - time.perf_counter())
                    if remaining <= 0:
                        return False
                self._done.wait(timeout=remaining)
        self._check_error()
        return True

    def reset_stats(self) -> None:
        """Zero the fleet serve statistics and each replica's (between
        drains, not mid-stream). Per-replica dispatch rows and router
        counters are pool-lifetime and survive — scoped accounting
        deltas :meth:`replica_counts` (the frontend does)."""
        with self._lock:
            if self._collected < self._submitted or self._pending:
                raise RuntimeError("reset_stats with work in flight")
            self.stats = ServeStats()
            self._t0 = None
        for rep in self.replicas:
            rs = getattr(rep, "reset_stats", None)
            if rs is not None:
                rs()

    # -- drain ---------------------------------------------------------------

    def drain(self) -> list[np.ndarray]:
        """Flush the partial tail, wait for every in-flight micro-batch
        to clear its replica, and return per-frame outputs of untagged
        batches in submission order (results are re-ordered by sequence
        number — replicas finish out of order by design)."""
        with self._lock:
            tail = self._pending
            self._pending = []
        if tail:
            self.submit_batch(np.stack(tail), len(tail))
        with self._done:
            while self._collected < self._submitted and self._error is None:
                self._done.wait(timeout=0.1)
        self._check_error()
        with self._lock:
            if self._t0 is not None:
                self.stats.wall_s += time.perf_counter() - self._t0
                self._t0 = None
            results = self._results
            self._results = {}
        if not results:
            return []
        flat = np.concatenate([results[s] for s in sorted(results)], axis=0)
        return list(flat)

    # -- completion (replica collector threads) ------------------------------

    def _replica_done(self, disp: _Dispatch, outputs) -> None:
        now = time.perf_counter()
        self.router.on_complete(disp.replica, now - disp.t_disp, now=now)
        if disp.probe:
            # Probe success = proof of life; on_complete above already
            # re-admitted the replica / fed its EWMA. Nothing to count.
            return
        with self._done:
            if self._collected == 0 and self._first_t0 is not None:
                self.stats.first_batch_s = now - self._first_t0
            self._collected += 1
            row = self._rows[disp.replica]
            row["completed_batches"] += 1
            row["completed_frames"] += disp.n_valid
            if disp.tag is None:
                self._results[disp.seq] = outputs
            self._done.notify_all()
            cb = self.on_result
        if disp.tag is not None and cb is not None:
            cb(disp.tag, outputs)

    def _replica_error(self, disp: _Dispatch, exc: BaseException) -> None:
        self.router.on_failure(disp.replica)
        if disp.probe:
            # Failed probe: quarantine persists, no live batch was lost.
            return
        with self._done:
            self._collected += 1
            row = self._rows[disp.replica]
            row["failed_batches"] += 1
            row["failed_frames"] += disp.n_valid
            if disp.tag is None and self._error is None:
                self._error = exc
            self._done.notify_all()
            cb = self.on_error
        if disp.tag is not None and cb is not None:
            cb(disp.tag, exc)

    def _check_error(self) -> None:
        if self._error is not None:
            raise RuntimeError(
                "replica pipeline failed; no further batches can be "
                "served") from self._error

    # -- reporting -----------------------------------------------------------

    def replica_counts(self) -> list[dict]:
        """Exact per-replica outcome counters (pool lifetime):
        dispatched/completed/failed batches and frames. Snapshot is
        atomic — taken under the fleet lock — so
        ``sum(completed_frames) == fleet completed frames`` holds at any
        quiescent point."""
        with self._lock:
            return [dict(row) for row in self._rows]

    def replica_rows(self) -> list[dict]:
        """JSON-ready per-replica rows: outcome counters + device
        placement + router view (picks, in-flight, straggler/quarantine
        flags, estimator channels)."""
        counts = self.replica_counts()
        snap = self.router.snapshot()["replicas"]
        rows = []
        for r in range(self.n_replicas):
            rows.append({"replica": r, "devices": self.replica_devices[r],
                         **counts[r],
                         "picks": snap[r]["picks"],
                         "inflight": snap[r]["inflight"],
                         "straggler": snap[r]["straggler"],
                         "quarantined": snap[r]["quarantined"],
                         "estimator": snap[r]["estimator"]})
        return rows
