"""FaultPlan-driven fault injection for the serving plane.

The paper's pipeline-balance story (and every artifact this repo
publishes) is only credible if the serving contract — every submitted
request resolves, never hangs — survives the faults a real accelerator
deployment sees: a PE/stage dying mid-batch, a replica degrading into a
straggler, an executor that starts failing at time T. The seed's
``runtime/fault_tolerance.py`` sketched the *detection* side (EWMA
straggler detector, a ``fail_at`` step->fault injection dict for
training loops); this module is the serving-side injection half, so
recovery is measured rather than assumed:

* :class:`FaultPlan` declares one replica's faults (when to die, how —
  mid-batch or refusing dispatch —, when to start dragging, when to
  come back), JSON-recordable so a chaos artifact replays its exact
  fault program;
* :class:`ChaosExecutor` wraps any :class:`~repro.serving.Executor`
  (a real :class:`~repro.serving.pipeline_executor.PipelineExecutor`
  replica or a test fake) and conforms to the same protocol, injecting
  the plan at the dispatch/result boundary — errors flow through the
  pool/frontend ``on_error`` paths that already resolve requests
  ``failed``, which is exactly the property under test;
* :func:`install_stage_fault` reaches *inside* a real PipelineExecutor
  and arms one stage's runner to raise mid-batch — the PE-death case a
  wrapper at the executor boundary cannot express;
* :func:`recovery_report` turns replayed request handles into the
  time-to-recover measurement: windowed armed-miss rates after the
  first injected fault, and the time until the miss rate re-enters the
  target band.

FPGA correspondence: a ``kill`` is a PE/stage hard fault (the paper's
fabric has no ECC story — the batch in the array is lost), a
``straggle`` is a clock-degraded or thermally-throttled region, and
``fail_after_s`` is a board dropping off the host bus mid-run.
"""

from __future__ import annotations

import dataclasses
import threading
import time


class ReplicaKilled(RuntimeError):
    """Injected hard fault: the wrapped replica 'died' on this batch."""


class StageKilled(RuntimeError):
    """Injected stage fault: a pipeline stage runner 'died' mid-batch."""


KILL_MODES = ("mid-batch", "reject")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """One replica's fault program, in wrapper-batch counts and seconds.

    ``kill_at_batch``   — from this (1-based) dispatched batch on, the
                          replica is dead: ``mid-batch`` mode accepts
                          the batch and fails it asynchronously through
                          ``on_error`` (the batch was in the array when
                          the PE died); ``reject`` mode raises from
                          ``submit_batch`` (the dispatch itself bounces,
                          like a poisoned pipeline).
    ``fail_after_s``    — the replica starts failing this many seconds
                          after its fault clock starts (first dispatch,
                          or :meth:`ChaosExecutor.reset_fault_clock`).
    ``straggle_at_batch`` / ``slowdown_s`` — from this batch on, every
                          result is delivered ``slowdown_s`` late (on
                          the victim's own delivery thread), degrading
                          it into a straggler without killing it.
    ``recover_at_batch`` — kill/fail faults stop from this batch on:
                          the replica answers probes again, which is
                          how re-admission is exercised.
    """

    kill_at_batch: int | None = None
    kill_mode: str = "mid-batch"
    fail_after_s: float | None = None
    straggle_at_batch: int | None = None
    slowdown_s: float = 0.0
    recover_at_batch: int | None = None

    def __post_init__(self):
        if self.kill_mode not in KILL_MODES:
            raise ValueError(f"kill_mode={self.kill_mode!r} not in "
                             f"{KILL_MODES}")
        for fld in ("kill_at_batch", "straggle_at_batch",
                    "recover_at_batch"):
            v = getattr(self, fld)
            if v is not None and v < 1:
                raise ValueError(f"{fld}={v} must be >= 1 (1-based)")
        if self.fail_after_s is not None and self.fail_after_s < 0:
            raise ValueError(f"fail_after_s={self.fail_after_s} < 0")
        if self.straggle_at_batch is not None and self.slowdown_s <= 0:
            raise ValueError("straggle_at_batch needs slowdown_s > 0")

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


class ChaosExecutor:
    """Protocol-conforming injection wrapper around one executor.

    Sits between a :class:`~repro.serving.replica_pool.ReplicaPool` (or
    an :class:`~repro.serving.frontend.AsyncFrontend` directly) and the
    wrapped executor: claims the inner ``on_result``/``on_error`` slots
    and exposes its own, so injected faults and real results travel the
    same delivery path the healthy stack uses. Attributes the protocol
    does not name (``partition``, ``route``, ``stats``, ``serve`` for
    warmup, ...) pass through to the inner executor untouched.
    """

    def __init__(self, inner, plan: FaultPlan, *, name: str = "victim"):
        self.inner = inner
        self.plan = plan
        self.name = name
        self.batch_size = inner.batch_size
        self.program = inner.program
        self.on_result = None
        self.on_error = None
        self._lock = threading.Lock()
        self._batches = 0          # wrapper dispatches since fault clock
        self._t0: float | None = None
        self.injected_failures = 0
        self.injected_slowdowns = 0
        self.t_first_fault: float | None = None
        inner.on_result = self._forward_result
        if hasattr(inner, "on_error"):
            inner.on_error = self._forward_error

    def __getattr__(self, attr):
        # Only consulted for attributes not set on the wrapper itself.
        return getattr(self.inner, attr)

    def reset_fault_clock(self) -> None:
        """Re-zero the batch counter and the ``fail_after_s`` clock —
        called after warmup/calibration so plan offsets count from the
        measured chaos window, not from the first calibration batch."""
        with self._lock:
            self._batches = 0
            self._t0 = None

    def arm(self, plan: FaultPlan) -> None:
        """Swap in a new fault program and restart the fault clock.

        The chaos bench constructs the wrapper with a benign
        ``FaultPlan()`` so throughput calibration can run through the
        pool (calibration dispatches tick the wrapper's batch counter —
        an armed ``kill_at_batch`` would fire mid-calibration), then
        arms the real plan so its offsets count from the measured
        window."""
        with self._lock:
            self.plan = plan
            self._batches = 0
            self._t0 = None

    # -- fault decisions ------------------------------------------------------

    def _dead(self, n: int, now: float) -> bool:
        p = self.plan
        if p.recover_at_batch is not None and n >= p.recover_at_batch:
            return False
        if p.kill_at_batch is not None and n >= p.kill_at_batch:
            return True
        if (p.fail_after_s is not None and self._t0 is not None
                and now - self._t0 >= p.fail_after_s):
            return True
        return False

    def _straggling(self, n: int) -> bool:
        p = self.plan
        return (p.straggle_at_batch is not None
                and n >= p.straggle_at_batch)

    # -- Executor protocol ----------------------------------------------------

    def submit_batch(self, frames, n_valid: int, tag: object = None) -> None:
        now = time.perf_counter()
        with self._lock:
            if self._t0 is None:
                self._t0 = now
            self._batches += 1
            n = self._batches
            dead = self._dead(n, now)
            if dead:
                self.injected_failures += 1
                if self.t_first_fault is None:
                    self.t_first_fault = now
        if dead:
            exc = ReplicaKilled(
                f"injected fault: replica {self.name!r} is down "
                f"(batch {n} of plan {self.plan.to_json()})")
            if self.plan.kill_mode == "mid-batch" and self.on_error is not None:
                # The batch was accepted and died in the array: resolve
                # it through the same async error path a real stage
                # death uses.
                self.on_error(tag, exc)
                return
            raise exc
        self.inner.submit_batch(frames, n_valid, tag=tag)

    def flush_inflight(self) -> None:
        self.inner.flush_inflight()

    def reset_stats(self) -> None:
        self.inner.reset_stats()

    def replica_counts(self):
        return self.inner.replica_counts()

    # -- delivery (inner executor's threads) ----------------------------------

    def _forward_result(self, tag, outputs) -> None:
        with self._lock:
            slow = self._straggling(self._batches)
            if slow and self.t_first_fault is None:
                # A slowdown is a fault too: the straggler row's
                # recovery clock starts at the first dragged delivery.
                self.t_first_fault = time.perf_counter()
        if slow:
            # Dragging the delivery inflates the observed dispatch->done
            # service time (what the router prices) and runs on the
            # victim's own delivery thread, so only the victim stalls.
            self.injected_slowdowns += 1
            time.sleep(self.plan.slowdown_s)
        if self.on_result is not None:
            self.on_result(tag, outputs)

    def _forward_error(self, tag, exc) -> None:
        if self.on_error is not None:
            self.on_error(tag, exc)


def install_stage_fault(px, stage: int, at_call: int):
    """Arm stage ``stage`` of a real PipelineExecutor to raise
    :class:`StageKilled` from its ``at_call``-th batch (1-based) on —
    the PE-dies-mid-batch case: the stage worker catches the raise,
    poisons the executor, and forwards the error downstream so in-flight
    tagged batches resolve through ``on_error`` while later submits
    bounce synchronously. Returns the wrapper (its ``calls`` counter is
    the assertion hook). Must be installed before the stage runs."""
    if at_call < 1:
        raise ValueError(f"at_call={at_call} must be >= 1")

    class _DyingRunner:
        def __init__(self, runner):
            self._runner = runner
            self.calls = 0
            self._lock = threading.Lock()

        def __call__(self, payload):
            with self._lock:
                self.calls += 1
                n = self.calls
            if n >= at_call:
                raise StageKilled(
                    f"injected fault: stage {stage} died on its "
                    f"batch {n}")
            return self._runner(payload)

        def __getattr__(self, attr):
            # quantize/dequantize and anything else the pipeline needs.
            return getattr(self._runner, attr)

    wrapper = _DyingRunner(px.runners[stage])
    px.runners[stage] = wrapper
    return wrapper


def _armed_miss(req) -> bool:
    """Chaos-tier miss for one deadline-armed request: dropped, refused,
    completed late — or *failed*, which the knee's miss definition
    excludes (a healthy sweep treats failures as bench bugs) but a fault
    window must count against the SLO."""
    return (req.missed_deadline()
            or req.outcome in ("failed", "rejected"))


def recovery_report(reqs, *, fault_t0: float | None, window_s: float,
                    miss_target: float) -> dict:
    """Time-to-recover from replayed request handles.

    Buckets the deadline-armed requests submitted after ``fault_t0``
    into ``window_s``-wide windows and reports each window's miss rate
    (chaos definition: expired/refused/late *or failed*). Recovery is
    the end of the first non-empty window whose miss rate is back under
    ``miss_target`` — i.e. the router has steered the stream around the
    injured replica — reported as seconds after ``fault_t0``
    (``recovered_s = None`` when no window recovers, or no fault ever
    fired)."""
    armed = [r for r in reqs if r.deadline_s is not None]
    out: dict = {"window_s": round(window_s, 6),
                 "miss_target": miss_target,
                 "armed_total": len(armed),
                 "pre_fault_armed": None, "windows": [],
                 "recovered_s": None}
    if fault_t0 is None or not armed:
        return out
    pre = [r for r in armed if r.t_submit < fault_t0]
    post = [r for r in armed if r.t_submit >= fault_t0]
    out["pre_fault_armed"] = {
        "submitted": len(pre),
        "missed": sum(1 for r in pre if _armed_miss(r)),
    }
    if not post:
        return out
    end = max(r.t_submit for r in post)
    n_windows = int((end - fault_t0) // window_s) + 1
    windows = []
    for w in range(n_windows):
        lo = fault_t0 + w * window_s
        hi = lo + window_s
        inside = [r for r in post if lo <= r.t_submit < hi]
        missed = sum(1 for r in inside if _armed_miss(r))
        rate = missed / len(inside) if inside else None
        windows.append({"t_s": round(w * window_s, 6),
                        "submitted": len(inside), "missed": missed,
                        "miss_rate": None if rate is None
                        else round(rate, 4)})
        if (out["recovered_s"] is None and inside
                and rate is not None and rate < miss_target):
            out["recovered_s"] = round((w + 1) * window_s, 6)
    out["windows"] = windows
    return out
