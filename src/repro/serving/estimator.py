"""Online per-batch-shape EWMA service-time estimator.

The paper's Algorithm 1 balances engine resources against the *measured*
cost of each layer; the serving control plane needs the same discipline
at micro-batch granularity. Both adaptive decisions the frontend makes —
when to expedite a flush and whether to admit a deadline-armed request —
are only as good as their estimate of how long the executor takes to
serve one micro-batch. A fixed guess (PR 4's 20% deadline-budget guard)
is wrong in both directions: too early on a fast backend (padded partial
batches), too late on a slow one (dead-on-arrival dispatches).

:class:`ServiceTimeEstimator` keeps one exponentially-weighted moving
average per *batch shape* (the compiled micro-batch size — different
frontends over differently-shaped executors do not pollute each other's
estimate), fed with each batch's measured compute phase
(``t_dispatched -> t_done``). It is:

* **thread-safe** — ``observe`` runs on the executor's collector thread
  while ``estimate`` runs on every submitting thread and the batcher;
* **warm-startable** — the serve paths seed it with the calibration
  pass's measured batch window (``batch / steady_fps``) so the very
  first open-loop request is priced from a measurement, not a guess;
* **honest about ignorance** — ``estimate`` returns ``None`` until it
  has either a warm start or an observation, and callers fall back to
  the static PR-4 guard, so an estimator-less frontend behaves exactly
  as before.
"""

from __future__ import annotations

import dataclasses
import threading

# Fast enough to track a backend warming up (jit caches, CPU frequency)
# within ~10 batches, slow enough that one scheduler hiccup does not
# whipsaw the flush guard.
DEFAULT_ALPHA = 0.3


def window_key(shape) -> tuple:
    """The estimator key for ``shape``'s *completion window* channel —
    the busy inter-completion gap (throughput beat), as opposed to the
    bare ``shape`` key holding the dispatch->done traversal latency.
    One convention, shared by the frontend (which observes both) and
    the serve paths (which warm-start both from the calibration pass:
    latency at ``stages x window``, window at ``batch/steady_fps``)."""
    return (shape, "window")


@dataclasses.dataclass
class _ShapeEstimate:
    value: float            # current EWMA, seconds per micro-batch
    n_observed: int = 0     # real observations (warm start not counted)
    warm: bool = False      # seeded from a calibration measurement


class ServiceTimeEstimator:
    """EWMA of per-micro-batch service time, keyed by batch shape.

    >>> est = ServiceTimeEstimator()
    >>> est.warm_start(32, 0.045)        # calibration: batch/steady_fps
    >>> est.estimate(32)
    0.045
    >>> est.observe(32, 0.052)           # each completed batch updates
    >>> est.estimate(16) is None         # shapes are isolated
    True

    ``shape`` is any hashable key; the frontend uses its compiled
    micro-batch size. All methods are safe to call concurrently.
    """

    def __init__(self, alpha: float = DEFAULT_ALPHA):
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha={alpha} not in (0, 1]")
        self.alpha = float(alpha)
        self._lock = threading.Lock()
        self._shapes: dict[object, _ShapeEstimate] = {}

    def warm_start(self, shape, seconds: float) -> None:
        """Seed ``shape``'s estimate with a measured calibration value
        (e.g. one batch window of the throughput phase). A later warm
        start overwrites only while no real batch has been observed —
        measurements outrank calibration."""
        if seconds <= 0:
            raise ValueError(f"warm_start seconds={seconds} not > 0")
        with self._lock:
            cur = self._shapes.get(shape)
            if cur is None or cur.n_observed == 0:
                self._shapes[shape] = _ShapeEstimate(float(seconds),
                                                     warm=True)

    def warm_start_channels(self, shape, window_s: float, *,
                            stages: int = 1, replicas: int = 1) -> None:
        """Seed *both* admission channels for ``shape`` from one K>1
        calibration throughput measurement: the busy-completion-window
        channel at the measured fleet batch window
        (``batch / steady_fps``) and the latency channel at
        ``stages * replicas * window`` — one micro-batch's traversal of
        a K-stage pipeline is K windows at steady state, and routing
        over R replicas multiplies the per-batch beat each replica
        sustains by R. Admission can price a deadline before any two
        completions have ever overlapped. Measurements outrank this
        (same rule as :meth:`warm_start`)."""
        if stages < 1 or replicas < 1:
            raise ValueError(
                f"stages={stages}, replicas={replicas} must be >= 1")
        self.warm_start(window_key(shape), window_s)
        self.warm_start(shape, stages * replicas * window_s)

    def rewarm(self, shape, seconds: float) -> None:
        """Forcibly re-seed ``shape``'s estimate after a topology change.

        Unlike :meth:`warm_start`, this *overwrites* a channel that has
        real observations: when the executor underneath a frontend is
        swapped (``Server.rescale``), the old plan's measured EWMA
        describes a pipeline that no longer exists, and "measurements
        outrank calibration" would pin admission to stale prices. The
        observation count resets to zero so the swapped-in plan's own
        batches take over at full EWMA weight."""
        if seconds <= 0:
            raise ValueError(f"rewarm seconds={seconds} not > 0")
        with self._lock:
            self._shapes[shape] = _ShapeEstimate(float(seconds), warm=True)

    def rewarm_channels(self, shape, window_s: float, *,
                        stages: int = 1, replicas: int = 1) -> None:
        """Forced counterpart of :meth:`warm_start_channels` for a live
        rescale: re-seed both admission channels for ``shape`` from the
        *old* plan's measured window scaled to the new topology (the
        caller computes ``window_s``; the latency channel gets the same
        ``stages * replicas * window`` traversal formula). Existing
        observations are discarded — they priced the old partition."""
        if stages < 1 or replicas < 1:
            raise ValueError(
                f"stages={stages}, replicas={replicas} must be >= 1")
        self.rewarm(window_key(shape), window_s)
        self.rewarm(shape, stages * replicas * window_s)

    def observe(self, shape, seconds: float) -> None:
        """Fold one measured batch service time into ``shape``'s EWMA.
        Non-positive samples (clock skew) are dropped rather than
        poisoning the average."""
        if seconds <= 0:
            return
        with self._lock:
            cur = self._shapes.get(shape)
            if cur is None:
                self._shapes[shape] = _ShapeEstimate(float(seconds),
                                                     n_observed=1)
            else:
                cur.value += self.alpha * (float(seconds) - cur.value)
                cur.n_observed += 1

    def estimate(self, shape) -> float | None:
        """Current estimate (seconds per micro-batch) for ``shape``, or
        ``None`` when nothing — warm start or observation — is known."""
        with self._lock:
            cur = self._shapes.get(shape)
            return None if cur is None else cur.value

    def n_observed(self, shape) -> int:
        """Real observations folded into ``shape`` (excludes the warm
        start)."""
        with self._lock:
            cur = self._shapes.get(shape)
            return 0 if cur is None else cur.n_observed

    def snapshot(self) -> dict:
        """JSON-ready state per shape — the benches record it so an
        artifact documents the estimate its control decisions used."""
        with self._lock:
            return {str(shape): {"est_ms": round(cur.value * 1e3, 3),
                                 "n_observed": cur.n_observed,
                                 "warm_started": cur.warm}
                    for shape, cur in self._shapes.items()}
