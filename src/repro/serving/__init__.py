"""Stage-pipelined async serving subsystem.

The software embodiment of the paper's layer-wise pipeline: Algorithm 1's
balance objective splits a compiled :class:`~repro.core.program
.EngineProgram` into K stages of near-equal modeled cycles
(:mod:`~repro.serving.partition`), one worker thread per stage executes
its jitted step range with depth-2 bounded queues between stages — the
activation double-buffer analogue (:mod:`~repro.serving
.pipeline_executor`), optionally with each stage placed on its own
device — and a QoS-aware request frontend batches live traffic into the
pipeline through per-``(tenant, priority)`` lanes with per-request
deadlines, backpressure, weighted round-robin tenant fairness, and
per-class phase-split latency accounting
(:mod:`~repro.serving.frontend`). The frontend's control decisions —
expedited flush and estimated-wait admission — are driven by an online
per-batch-shape EWMA service-time estimator
(:mod:`~repro.serving.estimator`). :mod:`~repro.serving.traffic` is the
one seeded synthetic-traffic generator every serving bench replays, and
:mod:`~repro.serving.server` hosts a multi-tenant model zoo — a
:class:`ProgramRegistry` of compiled programs behind one frontend.

Every executor the frontend can drive conforms to the :class:`Executor`
protocol below — :class:`PipelineExecutor`, :class:`ReplicaPool`, the
single-jit :class:`~repro.core.executor.EngineExecutor`, and the
per-tenant :class:`~repro.serving.server.TenantMux` all by construction.
"""

from typing import Protocol, runtime_checkable

import numpy as np

# The frontend<->executor contract, spelled out. ``AsyncFrontend``
# refuses (TypeError) any executor that does not conform, replacing the
# per-call ``hasattr`` probes of earlier revisions: an executor either
# offers the whole surface or none of it.
EXECUTOR_MEMBERS = ("batch_size", "program", "on_result", "on_error",
                    "submit_batch", "flush_inflight", "reset_stats",
                    "replica_counts")


@runtime_checkable
class Executor(Protocol):
    """What the :class:`AsyncFrontend` requires of a serving executor.

    ================== =====================================================
    member             contract
    ================== =====================================================
    ``batch_size``     compiled micro-batch size (frames per dispatch)
    ``program``        the compiled :class:`EngineProgram` behind the
                       executor, or ``None`` when there is no single one
                       (fakes, the per-tenant mux) — the frontend uses it
                       to reject malformed frames at submit
    ``on_result``      callback slot ``(tag, outputs)``; the frontend
                       claims it (must be ``None`` at attach) and releases
                       it at :meth:`AsyncFrontend.close`
    ``on_error``       callback slot ``(tag, exc)`` for async batch
                       failures (``None`` acceptable for executors that
                       raise synchronously from ``submit_batch``)
    ``submit_batch``   ``(frames, n_valid, tag=None)``: dispatch one
                       micro-batch; blocks on internal backpressure
    ``flush_inflight`` collect finished batches now (no-op for executors
                       whose collector thread runs continuously)
    ``reset_stats``    zero the executor's serve statistics (between
                       drains, not mid-stream)
    ``replica_counts`` exact per-replica outcome counters
                       (``list[dict]``), or ``None`` for executors that
                       are not replica pools
    ================== =====================================================
    """

    batch_size: int
    program: object
    on_result: object
    on_error: object

    def submit_batch(self, frames: np.ndarray, n_valid: int,
                     tag: object = None) -> None: ...

    def flush_inflight(self) -> None: ...

    def reset_stats(self) -> None: ...

    def replica_counts(self) -> list | None: ...


from repro.serving.estimator import (ServiceTimeEstimator,  # noqa: E402
                                     window_key)
from repro.serving.frontend import (DEFAULT_TENANT,  # noqa: E402
                                    AsyncFrontend, ClassStats,
                                    DeadlineExpired, FrontendStats,
                                    RequestRejected, ServedRequest,
                                    tenant_key)
from repro.serving.partition import (StagePartition,  # noqa: E402
                                     partition_program, stage_devices,
                                     step_cycles)
from repro.serving.pipeline_executor import PipelineExecutor  # noqa: E402
from repro.serving.replica_pool import ReplicaPool  # noqa: E402
from repro.serving.router import LeastWaitRouter  # noqa: E402
from repro.serving.traffic import (SCENARIOS, Arrival,  # noqa: E402
                                   TrafficClass, armed_class_names,
                                   default_mix, make_scenario_schedule,
                                   make_schedule, merge_schedules,
                                   pacing_report, parse_traffic_mix,
                                   record_trace, replay, tag_tenant,
                                   trace_schedule)
from repro.serving.chaos import (ChaosExecutor, FaultPlan,  # noqa: E402
                                 ReplicaKilled, StageKilled,
                                 install_stage_fault, recovery_report)
from repro.serving.elastic import (ElasticController,  # noqa: E402
                                   ElasticPolicy, RescaleDecision)
from repro.serving.calibrate import (default_max_wait_ms,  # noqa: E402
                                     pipeline_throughput,
                                     warmed_frontend)
from repro.serving.server import (ProgramRegistry, Server,  # noqa: E402
                                  ServerConfig, TenantMux,
                                  UnknownModelError, build_server,
                                  synthetic_stream, synthetic_stream_like)

__all__ = [
    "Arrival",
    "AsyncFrontend",
    "ChaosExecutor",
    "ClassStats",
    "DEFAULT_TENANT",
    "DeadlineExpired",
    "EXECUTOR_MEMBERS",
    "ElasticController",
    "ElasticPolicy",
    "Executor",
    "FaultPlan",
    "FrontendStats",
    "LeastWaitRouter",
    "PipelineExecutor",
    "ProgramRegistry",
    "ReplicaKilled",
    "ReplicaPool",
    "RequestRejected",
    "RescaleDecision",
    "SCENARIOS",
    "ServedRequest",
    "Server",
    "ServerConfig",
    "ServiceTimeEstimator",
    "StageKilled",
    "StagePartition",
    "TenantMux",
    "TrafficClass",
    "UnknownModelError",
    "armed_class_names",
    "build_server",
    "default_max_wait_ms",
    "default_mix",
    "install_stage_fault",
    "make_scenario_schedule",
    "make_schedule",
    "merge_schedules",
    "pacing_report",
    "parse_traffic_mix",
    "partition_program",
    "pipeline_throughput",
    "record_trace",
    "recovery_report",
    "replay",
    "stage_devices",
    "step_cycles",
    "synthetic_stream",
    "synthetic_stream_like",
    "tag_tenant",
    "tenant_key",
    "trace_schedule",
    "warmed_frontend",
    "window_key",
]
