"""Stage-pipelined async serving subsystem.

The software embodiment of the paper's layer-wise pipeline: Algorithm 1's
balance objective splits a compiled :class:`~repro.core.program
.EngineProgram` into K stages of near-equal modeled cycles
(:mod:`~repro.serving.partition`), one worker thread per stage executes
its jitted step range with depth-2 bounded queues between stages — the
activation double-buffer analogue (:mod:`~repro.serving
.pipeline_executor`) — and an async request frontend batches live traffic
into the pipeline with backpressure and per-request latency accounting
(:mod:`~repro.serving.frontend`).
"""

from repro.serving.frontend import (AsyncFrontend, FrontendStats,
                                    ServedRequest)
from repro.serving.partition import (StagePartition, partition_program,
                                     step_cycles)
from repro.serving.pipeline_executor import PipelineExecutor

__all__ = [
    "AsyncFrontend",
    "FrontendStats",
    "PipelineExecutor",
    "ServedRequest",
    "StagePartition",
    "partition_program",
    "step_cycles",
]
