"""Stage-pipelined async serving subsystem.

The software embodiment of the paper's layer-wise pipeline: Algorithm 1's
balance objective splits a compiled :class:`~repro.core.program
.EngineProgram` into K stages of near-equal modeled cycles
(:mod:`~repro.serving.partition`), one worker thread per stage executes
its jitted step range with depth-2 bounded queues between stages — the
activation double-buffer analogue (:mod:`~repro.serving
.pipeline_executor`), optionally with each stage placed on its own
device — and a QoS-aware request frontend batches live traffic into the
pipeline through priority lanes with per-request deadlines,
backpressure, and per-class phase-split latency accounting
(:mod:`~repro.serving.frontend`). The frontend's control decisions —
expedited flush and estimated-wait admission — are driven by an online
per-batch-shape EWMA service-time estimator
(:mod:`~repro.serving.estimator`). :mod:`~repro.serving.traffic` is the
one seeded synthetic-traffic generator every serving bench replays.
"""

from repro.serving.estimator import ServiceTimeEstimator, window_key
from repro.serving.frontend import (AsyncFrontend, ClassStats,
                                    DeadlineExpired, FrontendStats,
                                    RequestRejected, ServedRequest)
from repro.serving.partition import (StagePartition, partition_program,
                                     stage_devices, step_cycles)
from repro.serving.pipeline_executor import PipelineExecutor
from repro.serving.replica_pool import ReplicaPool
from repro.serving.router import LeastWaitRouter
from repro.serving.traffic import (Arrival, TrafficClass,
                                   armed_class_names, default_mix,
                                   make_schedule, parse_traffic_mix,
                                   replay)

__all__ = [
    "Arrival",
    "AsyncFrontend",
    "ClassStats",
    "DeadlineExpired",
    "FrontendStats",
    "LeastWaitRouter",
    "PipelineExecutor",
    "ReplicaPool",
    "RequestRejected",
    "ServedRequest",
    "ServiceTimeEstimator",
    "StagePartition",
    "TrafficClass",
    "armed_class_names",
    "default_mix",
    "make_schedule",
    "parse_traffic_mix",
    "partition_program",
    "replay",
    "stage_devices",
    "step_cycles",
    "window_key",
]
