"""DeepSeek-V2 236B [arXiv:2405.04434]: MLA kv_lora=512, 2 shared + 160
routed top-6 MoE. First layer dense (d_ff 12288)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b", family="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
    d_ff=12288, vocab=102400, head_dim=128,
    attn_impl="mla", q_lora_rank=1536, kv_lora_rank=512,
    rope_head_dim=64, v_head_dim=128,
    moe_n_experts=160, moe_top_k=6, moe_n_shared=2, moe_d_ff=1536,
    moe_layer_start=1,
    opt_moment_dtype="int8",
)
