"""RWKV6-7B "Finch" [arXiv:2404.05892]: attention-free, data-dependent
decay linear recurrence. 32L d_model=4096, head_dim 64."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b", family="ssm",
    n_layers=32, d_model=4096, n_heads=64, n_kv_heads=64,
    d_ff=14336, vocab=65536, head_dim=64,
    block_pattern=("rwkv",), mlp_kind="rwkv",
)
