"""DeepSeek-V3 671B [arXiv:2412.19437]: MLA + 1 shared / 256 routed top-8 MoE.

Assignment: 61L d_model=7168 128H d_ff(expert)=2048 vocab=129280.
First 3 layers are dense (d_ff 18432); MoE from layer 3 on. MLA with
kv_lora=512, q_lora=1536, rope_head=64. (MTP head omitted: the assigned
shape set exercises the backbone.)
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
    d_ff=18432, vocab=129280, head_dim=128,
    attn_impl="mla", q_lora_rank=1536, kv_lora_rank=512,
    rope_head_dim=64, v_head_dim=128,
    moe_n_experts=256, moe_top_k=8, moe_n_shared=1, moe_d_ff=2048,
    moe_layer_start=3,
    opt_moment_dtype="int8",  # fits 512x16GB HBM (see DESIGN.md)
)
