"""RecurrentGemma-2B [arXiv:2402.19427] (Griffin): RG-LRU + local attention,
2 recurrent blocks : 1 local-attention block, window 2048, MQA (kv=1)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1,
    d_ff=7680, vocab=256000, head_dim=256,
    block_pattern=("rglru", "rglru", "attn_local"), window=2048,
    lru_width=2560, conv1d_width=4, mlp_kind="geglu",
    tie_embeddings=True,
)
