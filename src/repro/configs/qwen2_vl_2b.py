"""Qwen2-VL-2B [arXiv:2409.12191]: GQA decoder backbone with M-RoPE.

Dynamic-resolution vision tower is stubbed per the assignment:
input_specs() feeds precomputed patch embeddings + 3D positions.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b", family="vlm",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2,
    d_ff=8960, vocab=151936, head_dim=128,
    qkv_bias=True, mrope=True, mrope_sections=(16, 24, 24),
    rope_theta=1e6, frontend_stub=True, tie_embeddings=True,
)
