"""Granite-34B-Code [arXiv:2405.04324]: deep MQA (kv=1) decoder."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b", family="dense",
    n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1,
    d_ff=24576, vocab=49152, head_dim=128,
    mlp_kind="gelu",  # gpt_bigcode-style 2-matrix MLP (param count matches 34B)
)
