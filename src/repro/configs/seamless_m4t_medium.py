"""SeamlessM4T-medium [arXiv:2308.11596]: encoder-decoder backbone.

The speech/text frontends are stubbed per the assignment: input_specs()
feeds precomputed frame embeddings to the encoder.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="enc_dec",
    n_layers=12, n_enc_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=256206, head_dim=64,
    mlp_kind="gelu", frontend_stub=True,
)
