"""Model configuration schema shared by every architecture config.

One frozen dataclass describes any member of the supported families:
dense decoder LMs (GQA/MQA, optional bias + qk_norm), MLA + MoE
(DeepSeek-V2/V3), encoder-decoder (Seamless-M4T backbone), hybrid
RG-LRU/local-attention (RecurrentGemma), M-RoPE VLM backbones (Qwen2-VL),
and attention-free RWKV6 — plus the paper's own CNNs (see
``repro.core.workload.CNN_MODELS``, which have their own schema).
"""

from __future__ import annotations

import dataclasses
from typing import Literal


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Literal["dense", "moe", "enc_dec", "hybrid", "vlm", "ssm"]
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    head_dim: int = 0                   # 0 -> d_model // n_heads
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1e4
    mrope: bool = False                 # M-RoPE (Qwen2-VL): 3-section rotary
    mrope_sections: tuple[int, ...] = (16, 24, 24)
    window: int = 0                     # >0: sliding-window (local) attention
    mlp_kind: Literal["swiglu", "gelu", "geglu", "rwkv"] = "swiglu"

    # Layer pattern: tuple cycled over the depth, e.g. Griffin's
    # ("rglru", "rglru", "attn_local"). Default: all attention.
    block_pattern: tuple[str, ...] = ("attn",)

    # MoE (DeepSeek-style shared + routed, top-k)
    moe_n_experts: int = 0
    moe_top_k: int = 0
    moe_n_shared: int = 0
    moe_d_ff: int = 0
    moe_layer_start: int = 0            # leading dense layers
    moe_capacity_factor: float = 1.25

    # MLA (DeepSeek)
    attn_impl: Literal["gqa", "mla"] = "gqa"
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    rope_head_dim: int = 0
    v_head_dim: int = 0

    # Encoder-decoder
    n_enc_layers: int = 0

    # Recurrent
    lru_width: int = 0                  # RG-LRU recurrence width
    conv1d_width: int = 4

    # Modality frontend stub: inputs are precomputed frame/patch embeddings
    # of this dimension instead of token ids (seamless / qwen2-vl).
    frontend_stub: bool = False

    tie_embeddings: bool = False

    # Numerics / optimizer defaults (overridable per launch)
    dtype: str = "bfloat16"
    opt_moment_dtype: str = "float32"   # deepseek-v3 uses int8 (see optim/)

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.attn_impl == "mla" and self.v_head_dim == 0:
            object.__setattr__(self, "v_head_dim", self.head_dim)

    # -- layer-pattern helpers -------------------------------------------
    def block_kind(self, i: int) -> str:
        """Kind of decoder layer i: attn | attn_local | rglru | rwkv,
        suffixed with 'moe'/'mla' flavors where applicable."""
        base = self.block_pattern[i % len(self.block_pattern)]
        if self.moe_n_experts and i >= self.moe_layer_start:
            base = {"attn": "moe", "mla": "mla_moe"}.get(base, base + "_moe")
        if self.attn_impl == "mla":
            base = base.replace("attn", "mla").replace("moe", "mla_moe") \
                if base in ("attn", "moe") else base
        return base

    def layer_kinds(self) -> list[str]:
        return [self.block_kind(i) for i in range(self.n_layers)]

    @property
    def sub_quadratic(self) -> bool:
        """True if no full-attention layer (long_500k is runnable)."""
        kinds = set(self.layer_kinds())
        return not any(k in ("attn", "moe", "mla", "mla_moe") for k in kinds)

    def scaled(self, **kw) -> "ModelConfig":
        """Reduced copy for smoke tests."""
        return dataclasses.replace(self, **kw)


def reduced(cfg: ModelConfig) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    n_layers = max(2, min(4, len(cfg.block_pattern) * 2))
    kv = max(1, min(cfg.n_kv_heads, 2))
    heads = max(kv, 4)
    kw = dict(
        n_layers=n_layers, d_model=64, n_heads=heads, n_kv_heads=kv,
        d_ff=128, vocab=128, head_dim=16,
    )
    if cfg.moe_n_experts:
        kw.update(moe_n_experts=4, moe_top_k=2,
                  moe_n_shared=min(cfg.moe_n_shared, 1), moe_d_ff=32,
                  moe_layer_start=min(cfg.moe_layer_start, 1))
    if cfg.attn_impl == "mla":
        kw.update(q_lora_rank=32 if cfg.q_lora_rank else 0, kv_lora_rank=32,
                  rope_head_dim=8, v_head_dim=16)
    if cfg.n_enc_layers:
        kw.update(n_enc_layers=2)
    if cfg.lru_width:
        kw.update(lru_width=64)
    if cfg.window:
        kw.update(window=32)
    if cfg.mrope:
        kw.update(mrope_sections=(2, 3, 3))  # sums to head_dim/2 = 8
    return cfg.scaled(**kw)
