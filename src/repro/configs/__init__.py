"""Architecture registry: the 10 assigned LM-family configs plus the
paper's own four CNNs (VGG16 / AlexNet / ZF / YOLO).

Each LM config is importable as ``repro.configs.get(name)``; CNNs live in
``repro.core.workload.CNN_MODELS`` and are selected through the same
``--arch`` flag by the launchers.
"""

from repro.configs.base import ModelConfig, reduced
from repro.configs.qwen2_72b import CONFIG as qwen2_72b
from repro.configs.yi_6b import CONFIG as yi_6b
from repro.configs.qwen3_1p7b import CONFIG as qwen3_1p7b
from repro.configs.granite_34b import CONFIG as granite_34b
from repro.configs.deepseek_v3_671b import CONFIG as deepseek_v3_671b
from repro.configs.deepseek_v2_236b import CONFIG as deepseek_v2_236b
from repro.configs.seamless_m4t_medium import CONFIG as seamless_m4t_medium
from repro.configs.recurrentgemma_2b import CONFIG as recurrentgemma_2b
from repro.configs.qwen2_vl_2b import CONFIG as qwen2_vl_2b
from repro.configs.rwkv6_7b import CONFIG as rwkv6_7b

ARCHS: dict[str, ModelConfig] = {
    c.name: c for c in (
        qwen2_72b, yi_6b, qwen3_1p7b, granite_34b, deepseek_v3_671b,
        deepseek_v2_236b, seamless_m4t_medium, recurrentgemma_2b,
        qwen2_vl_2b, rwkv6_7b,
    )
}

CNN_ARCHS = ("vgg16", "alexnet", "zf", "yolo")


def get(name: str) -> ModelConfig:
    try:
        return ARCHS[name]
    except KeyError:
        raise KeyError(
            f"unknown arch {name!r}; LM archs: {sorted(ARCHS)}; "
            f"CNNs (paper substrate): {CNN_ARCHS}") from None


__all__ = ["ModelConfig", "reduced", "ARCHS", "CNN_ARCHS", "get"]
