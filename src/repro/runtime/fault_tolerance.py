"""Fault tolerance: restartable step loop, straggler mitigation, elastic
rescale.

Design for 1000+ nodes (DESIGN.md §5):
* every N steps a sharded checkpoint is written atomically (manifest last);
  a restart resumes from the last complete step and the deterministic,
  seekable data pipeline replays from there — no data loss/dup;
* per-step wall-times feed an EWMA straggler detector; a straggler (or a
  dead host, which surfaces as a collective timeout -> process restart)
  triggers `elastic_replan`: Algorithm 1 re-runs for the surviving chip
  count, the checkpoint is restored re-sharded onto the new mesh, and
  training continues — the paper's "framework regenerates the accelerator
  for the new resource budget", at mesh scale;
* simulated failure injection hooks let the tests exercise all paths on
  CPU.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import numpy as np

from repro import checkpointing as ckpt


@dataclasses.dataclass
class StragglerDetector:
    """EWMA step-time outlier detection (threshold x median of peers)."""
    alpha: float = 0.2
    threshold: float = 2.0
    _ewma: dict[int, float] = dataclasses.field(default_factory=dict)

    def observe(self, host_times: dict[int, float]) -> list[int]:
        for h, t in host_times.items():
            prev = self._ewma.get(h, t)
            self._ewma[h] = (1 - self.alpha) * prev + self.alpha * t
        med = float(np.median(list(self._ewma.values())))
        return [h for h, t in self._ewma.items()
                if t > self.threshold * med]


@dataclasses.dataclass
class RunState:
    step: int = 0
    restarts: int = 0
    rescales: int = 0


def run_loop(
    *,
    state: Any,
    step_fn: Callable[[Any, dict], tuple[Any, dict]],
    stream,
    ckpt_dir: str,
    total_steps: int,
    ckpt_every: int = 50,
    fail_at: dict[int, str] | None = None,
    on_rescale: Callable[[Any], Any] | None = None,
    log: Callable[[str], None] = print,
) -> tuple[Any, RunState]:
    """Restartable training loop.

    ``fail_at``: {step: "crash"|"straggler"|"shrink"} — simulated faults
    for tests. "crash" raises once then the loop restarts from the last
    checkpoint; "shrink" invokes on_rescale (elastic re-plan).
    """
    rs = RunState()
    detector = StragglerDetector()
    fail_at = dict(fail_at or {})
    crashed_once: set[int] = set()

    last = ckpt.latest_step(ckpt_dir)
    if last is not None:
        state = ckpt.restore(ckpt_dir, last, state)
        stream.seek(last)
        rs.step = last
        log(f"[ft] resumed from step {last}")

    while rs.step < total_steps:
        step = rs.step
        try:
            if fail_at.get(step) == "crash" and step not in crashed_once:
                crashed_once.add(step)
                raise RuntimeError(f"injected crash at step {step}")
            t0 = time.time()
            batch = next(stream)
            state, metrics = step_fn(state, batch)
            dt = time.time() - t0
            if fail_at.get(step) == "straggler":
                detector.observe({0: dt, 1: dt * 5.0})
            slow = detector.observe({0: dt})
            if slow:
                log(f"[ft] stragglers detected: {slow} (would swap spares)")
            if fail_at.get(step) == "shrink" and on_rescale is not None:
                state = on_rescale(state)
                rs.rescales += 1
                log(f"[ft] elastic rescale at step {step}")
                fail_at.pop(step)
            rs.step += 1
            if rs.step % ckpt_every == 0 or rs.step == total_steps:
                ckpt.save(ckpt_dir, rs.step, state)
        except RuntimeError as e:
            log(f"[ft] failure: {e}; restarting from checkpoint")
            rs.restarts += 1
            last = ckpt.latest_step(ckpt_dir)
            if last is None:
                rs.step = 0
                stream.seek(0)
            else:
                state = ckpt.restore(ckpt_dir, last, state)
                stream.seek(last)
                rs.step = last
    return state, rs


def elastic_replan(cfg, n_chips: int, *, seq_len: int, global_batch: int,
                   train: bool = True):
    """Re-run the mesh allocator for a shrunken/grown chip pool. Returns the
    new StagePlan; callers re-shard the restored checkpoint accordingly."""
    from repro.core.allocator import plan_pipeline
    from repro.core.workload import lm_layer_workloads

    layers = lm_layer_workloads(cfg, seq_len=seq_len, batch=global_batch,
                                mode="train" if train else "prefill")
    # Factor chips into data x model, preferring model=16.
    model_axis = min(16, n_chips)
    while n_chips % model_axis:
        model_axis //= 2
    data_axis = n_chips // model_axis
    return plan_pipeline(layers, model_axis=model_axis, data_axis=data_axis,
                         global_batch=global_batch, seq_len=seq_len,
                         train=train, allow_infeasible=True)
