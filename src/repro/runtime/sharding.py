"""NamedSharding rules for the pjit (TP+DP[+pod]) execution path.

Rules are keyed by parameter path suffix; they compose Megatron-style tensor
parallelism over the ``model`` axis with FSDP-style parameter sharding over
``data`` for the very large archs, ZeRO-1 optimizer-state sharding, and pod
data parallelism. The shard_map pipeline path (core/pipeline.py) does its
own manual sharding and does not use these rules.
"""

from __future__ import annotations

import re
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig

# (regex over "/"-joined path, spec builder). Leading layer-stack dims are
# handled generically: specs below describe the *trailing* dims and are
# left-padded with None.
_RULES: list[tuple[str, tuple]] = [
    (r"embed$", ("model", "fsdp")),             # [V, D] vocab-parallel
    (r"lm_head/w$", ("fsdp", "model")),         # [D, V]
    (r"(wq|wk|wv|wi|wg)/w$", ("fsdp", "model")),
    (r"(wo|cm_wv)/w$", ("model", "fsdp")),
    (r"(wq_b|wkv_b|cm_wk)/w$", ("fsdp", "model")),
    (r"(wq_a|wkv_a)/w$", ("fsdp", None)),
    (r"router/w$", (None, None)),
    (r"mlp/(wi|wg)$", ("model", "fsdp", None)),     # MoE expert stacks [E,D,F]
    (r"mlp/wo$", ("model", None, "fsdp")),          # [E,F,D]
    (r"(w_input_gate|w_rec_gate)/w$", ("fsdp", "model")),
    (r"(wx|wy)/w$", ("fsdp", "model")),
    (r"(ddl_w1|dec_w1)$", ("fsdp", None)),
    (r"(ddl_w2)$", (None, None, "fsdp")),
    (r"(dec_w2)$", (None, "fsdp")),
    (r"shared/(wi|wg)/w$", ("fsdp", "model")),
    (r"shared/wo/w$", ("model", "fsdp")),
]


def _spec_for(path: str, ndim: int, fsdp: bool) -> P:
    for pat, dims in _RULES:
        if re.search(pat, path):
            trail = [("data" if d == "fsdp" and fsdp else
                      (None if d == "fsdp" else d)) for d in dims]
            pad = [None] * (ndim - len(trail))
            return P(*(pad + trail))
    return P()  # replicated (norms, biases, small vectors)


def param_shardings(cfg: ModelConfig, mesh: Mesh, params_shape: Any,
                    fsdp: bool | None = None) -> Any:
    """NamedSharding tree matching a params pytree (of ShapeDtypeStructs or
    arrays). fsdp defaults to on for models too big for TP-only sharding."""
    if fsdp is None:
        from repro.models.transformer import param_count
        # >16B params: shard over the data axis as well (memory roof).
        fsdp = param_count(cfg) > 16e9

    flat = jax.tree_util.tree_flatten_with_path(params_shape)[0]

    def shard_one(path, leaf):
        pstr = "/".join(str(getattr(k, "key", k)) for k in path)
        spec = _spec_for(pstr, leaf.ndim, fsdp)
        # Drop axes that do not divide the mesh axis size.
        fixed = []
        for dim, ax in zip(leaf.shape, spec + (None,) * (leaf.ndim - len(spec))):
            if ax is None:
                fixed.append(None)
            else:
                size = mesh.shape[ax]
                fixed.append(ax if dim % size == 0 and dim >= size else None)
        return NamedSharding(mesh, P(*fixed))

    specs = [shard_one(p, l) for p, l in flat]
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(params_shape), specs)


def batch_shardings(mesh: Mesh, batch_shape: Any,
                    seq_shard: bool = False) -> Any:
    """Batch dims over (pod, data); optionally shard seq instead when the
    per-shape batch is too small (32k prefill with batch < data axis)."""
    axes = [a for a in ("pod", "data") if a in mesh.shape]
    batch_axes = tuple(axes)

    def one(leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        bsz = leaf.shape[0]
        n = 1
        for a in batch_axes:
            n *= mesh.shape[a]
        if bsz % n == 0 and bsz >= n:
            return NamedSharding(mesh, P(batch_axes))
        if seq_shard and leaf.ndim >= 2 and leaf.shape[1] % n == 0:
            return NamedSharding(mesh, P(None, batch_axes))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map(one, batch_shape)


def cache_shardings(mesh: Mesh, cache_shape: Any) -> Any:
    """KV caches: leaves are layer-stacked [L, B, S, ...]; shard the batch
    dim (axis 1) over (pod, data) where divisible. kv-heads / latent dims
    stay replicated (attention math is head-sharded via params)."""
    axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    nm = mesh.shape.get("model", 1)

    def one(leaf):
        import jax.numpy as jnp
        if jnp.issubdtype(leaf.dtype, jnp.integer):
            return NamedSharding(mesh, P())   # idx / slot_pos bookkeeping
        spec = [None] * leaf.ndim
        if leaf.ndim >= 2 and leaf.shape[1] % n == 0 and leaf.shape[1] >= n:
            spec[1] = axes
        # Long-context KV: also shard the sequence/head dim over `model`
        # so a 32k cache fits per-device HBM.
        if leaf.ndim >= 3 and leaf.shape[2] % nm == 0 and leaf.shape[2] >= nm:
            spec[2] = "model"
        if not any(spec):
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map(one, cache_shape)


def opt_state_shardings(param_sh: Any) -> Any:
    """ZeRO-1: moments inherit parameter shardings (they are also further
    split over 'data' when fsdp already shards params there)."""
    return jax.tree_util.tree_map(lambda s: s, param_sh)
