"""Recurrent blocks: RG-LRU (Griffin / RecurrentGemma) and RWKV6 (Finch).

Both expose a sequence path (training / prefill; parallel where the math
permits — RG-LRU's diagonal recurrence uses an associative scan, RWKV6's
rank-1 state update uses a time scan whose chunked Pallas form lives in
``repro.kernels.rglru_scan``) and a single-step path for decode. Decode
state is O(1) in sequence length — these are the two assigned architectures
that run the `long_500k` shape.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import Params, apply_dense, dense

# ---------------------------------------------------------------------------
# RG-LRU (Griffin, arXiv:2402.19427, Section 2.4)
# ---------------------------------------------------------------------------

_RGLRU_C = 8.0


def rglru_block_init(key, cfg, dtype) -> Params:
    d, dr = cfg.d_model, cfg.lru_width or cfg.d_model
    ks = jax.random.split(key, 8)
    # Lambda init so that a = sigmoid(lam)^c spreads over (0.9, 0.999).
    u = jax.random.uniform(ks[6], (dr,), jnp.float32,
                           0.9 ** (1 / _RGLRU_C), 0.999 ** (1 / _RGLRU_C))
    lam = jnp.log(u / (1 - u))
    return {
        "wx": dense(ks[0], d, dr, dtype),          # rnn branch in
        "wy": dense(ks[1], d, dr, dtype),          # gate branch in
        "conv_w": (jax.random.normal(ks[2], (cfg.conv1d_width, dr),
                                     jnp.float32) / math.sqrt(
                                         cfg.conv1d_width)).astype(dtype),
        "conv_b": jnp.zeros((dr,), dtype),
        "w_input_gate": dense(ks[3], dr, dr, dtype),
        "w_rec_gate": dense(ks[4], dr, dr, dtype),
        "lam": lam.astype(jnp.float32),
        "wo": dense(ks[5], dr, d, dtype),
    }


def _rglru_coeffs(p: Params, xr: jnp.ndarray):
    """Gate computations shared by scan and step paths. xr [.., dr]."""
    i_gate = jax.nn.sigmoid(apply_dense(p["w_input_gate"], xr)
                            .astype(jnp.float32))
    r_gate = jax.nn.sigmoid(apply_dense(p["w_rec_gate"], xr)
                            .astype(jnp.float32))
    log_a = -_RGLRU_C * r_gate * jax.nn.softplus(p["lam"])
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = mult * i_gate * xr.astype(jnp.float32)
    return a, b


def rglru_scan(p: Params, xr: jnp.ndarray, h0: jnp.ndarray | None = None):
    """Diagonal linear recurrence h_t = a_t h_{t-1} + b_t via associative
    scan over time. xr [B,S,dr] (post-conv). Returns (y [B,S,dr], h_last)."""
    a, b = _rglru_coeffs(p, xr)

    if h0 is not None:
        # Fold the carry state in as a virtual step 0.
        a = jnp.concatenate([jnp.ones_like(a[:, :1]), a], axis=1)
        b = jnp.concatenate([h0[:, None, :], b], axis=1)

    def op(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(op, (a, b), axis=1)
    if h0 is not None:
        h = h[:, 1:]
    return h.astype(xr.dtype), h[:, -1].astype(jnp.float32)


def rglru_step(p: Params, xr: jnp.ndarray, h: jnp.ndarray):
    """One decode step. xr [B,dr], h [B,dr] fp32."""
    a, b = _rglru_coeffs(p, xr)
    h_new = a * h + b
    return h_new.astype(xr.dtype), h_new


def _causal_conv1d(w, b, x, state=None):
    """Short causal conv (Griffin's width-4 temporal conv). x [B,S,dr];
    state [B,W-1,dr] carries the tail for decode."""
    W = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(W)) + b
    new_state = xp[:, -(W - 1):] if W > 1 else pad[:, :0]
    return out, new_state


def rglru_block_apply(p: Params, cfg, x, *, state: Params | None = None):
    """Full Griffin recurrent block: (gate branch GeLU) * (conv1d -> RG-LRU),
    then output projection. state = {"h": [B,dr], "conv": [B,W-1,dr]}.
    Returns (y [B,S,D], new_state)."""
    B, S, D = x.shape
    gate = jax.nn.gelu(apply_dense(p["wy"], x))
    xr = apply_dense(p["wx"], x)
    conv_state = state["conv"] if state is not None else None
    xr, conv_state = _causal_conv1d(p["conv_w"], p["conv_b"], xr, conv_state)
    if state is not None and S == 1:
        y, h = rglru_step(p, xr[:, 0], state["h"])
        y = y[:, None, :]
    else:
        h0 = state["h"] if state is not None else None
        y, h = rglru_scan(p, xr, h0)
    new_state = {"h": h, "conv": conv_state.astype(x.dtype)}
    return apply_dense(p["wo"], y * gate), new_state


def rglru_state_init(cfg, batch: int, dtype=jnp.float32) -> Params:
    dr = cfg.lru_width or cfg.d_model
    return {"h": jnp.zeros((batch, dr), jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv1d_width - 1, dr), dtype)}


# ---------------------------------------------------------------------------
# RWKV6 "Finch" time-mix + channel-mix (arXiv:2404.05892)
# ---------------------------------------------------------------------------

_DDLERP_RANK = 32
_DECAY_RANK = 64


def rwkv6_block_init(key, cfg, dtype) -> Params:
    d = cfg.d_model
    hd = cfg.head_dim
    nh = d // hd
    ks = jax.random.split(key, 16)
    mix = lambda k: (jax.random.uniform(k, (d,), jnp.float32)).astype(dtype)
    p = {
        # token-shift data-dependent lerp (ddlerp): base mus + low-rank delta
        "mu_base": jnp.stack([mix(ks[0]) for _ in range(5)]),   # r,k,v,w,g
        "ddl_w1": (jax.random.normal(ks[1], (d, 5 * _DDLERP_RANK),
                                     jnp.float32) * 0.01).astype(dtype),
        "ddl_w2": (jax.random.normal(ks[2], (5, _DDLERP_RANK, d),
                                     jnp.float32) * 0.01).astype(dtype),
        "wr": dense(ks[3], d, d, dtype),
        "wk": dense(ks[4], d, d, dtype),
        "wv": dense(ks[5], d, d, dtype),
        "wg": dense(ks[6], d, d, dtype),
        "wo": dense(ks[7], d, d, dtype),
        # data-dependent decay lora
        "w0": (jax.random.uniform(ks[8], (d,), jnp.float32, -8.0, -5.0)),
        "dec_w1": (jax.random.normal(ks[9], (d, _DECAY_RANK), jnp.float32)
                   * 0.01).astype(dtype),
        "dec_w2": (jax.random.normal(ks[10], (_DECAY_RANK, d), jnp.float32)
                   * 0.01).astype(dtype),
        "u": (jax.random.normal(ks[11], (nh, hd), jnp.float32) * 0.5),
        "ln_x_scale": jnp.ones((d,), jnp.float32),
        "ln_x_bias": jnp.zeros((d,), jnp.float32),
        # channel mix
        "mu_cm": jnp.stack([mix(ks[12]) for _ in range(2)]),    # r,k
        "cm_wr": dense(ks[13], d, d, dtype),
        "cm_wk": dense(ks[14], d, cfg.d_ff, dtype),
        "cm_wv": dense(ks[15], cfg.d_ff, d, dtype),
    }
    return p


def _token_shift(x, prev):
    """x [B,S,D] -> x shifted right by one; prev [B,D] fills slot 0."""
    return jnp.concatenate([prev[:, None, :], x[:, :-1]], axis=1)


def _ddlerp(p, x, xs):
    """Data-dependent lerp producing the 5 mixed inputs (r,k,v,w,g)."""
    dx = xs - x
    base = x[:, :, None, :] + dx[:, :, None, :] * p["mu_base"]  # [B,S,5,D]
    lo = jnp.tanh((x + dx * 0.5) @ p["ddl_w1"])                  # [B,S,5R]
    lo = lo.reshape(*lo.shape[:-1], 5, _DDLERP_RANK)
    delta = jnp.einsum("bsfr,frd->bsfd", lo, p["ddl_w2"])
    return base + delta * dx[:, :, None, :]


def rwkv6_wkv_scan(p, r, k, v, w, state0):
    """The WKV6 recurrence. r,k,v [B,S,nh,hd]; w [B,S,nh,hd] in (0,1).

    S_t = diag(w_t) S_{t-1} + k_t^T v_t ;  o_t = r_t (S_{t-1} + u k_t^T v_t)
    state [B,nh,hd,hd] fp32. Sequential lax.scan here; the chunked TPU
    kernel (repro.kernels.rglru_scan) computes the same in block-parallel
    form.
    """
    def step(S, inp):
        r_t, k_t, v_t, w_t = inp
        kv = jnp.einsum("bhk,bhv->bhkv", k_t, v_t)
        o = jnp.einsum("bhk,bhkv->bhv", r_t,
                       S + p["u"][None, :, :, None] * kv)
        S_new = w_t[..., None] * S + kv
        return S_new, o

    rs, ks_, vs, ws = (t.swapaxes(0, 1).astype(jnp.float32)
                       for t in (r, k, v, w))
    state, outs = jax.lax.scan(step, state0, (rs, ks_, vs, ws))
    return outs.swapaxes(0, 1), state                       # [B,S,nh,hd]


def rwkv6_block_apply(p: Params, cfg, x, *, state: Params | None = None):
    """Time-mix + channel-mix. state = {"shift_tm","shift_cm" [B,D],
    "wkv" [B,nh,hd,hd]}. Returns (y, new_state)."""
    B, S, D = x.shape
    hd = cfg.head_dim
    # Head count follows the projection width, which may be tensor-
    # parallel-sliced (pipeline executor slices wr/wk/wv/wg by heads).
    d_loc = p["wr"]["w"].shape[-1]
    nh = d_loc // hd
    st = state or {
        "shift_tm": jnp.zeros((B, D), x.dtype),
        "shift_cm": jnp.zeros((B, D), x.dtype),
        "wkv": jnp.zeros((B, nh, hd, hd), jnp.float32),
    }
    # ---- time mix
    xs = _token_shift(x, st["shift_tm"])
    mixed = _ddlerp(p, x, xs)                                # [B,S,5,D]
    xr, xk, xv, xw, xg = (mixed[:, :, i] for i in range(5))
    r = apply_dense(p["wr"], xr).reshape(B, S, nh, hd)
    k = apply_dense(p["wk"], xk).reshape(B, S, nh, hd)
    v = apply_dense(p["wv"], xv).reshape(B, S, nh, hd)
    g = apply_dense(p["wg"], xg)
    dec = p["w0"] + jnp.tanh(xw @ p["dec_w1"]) @ p["dec_w2"]
    w = jnp.exp(-jnp.exp(dec.astype(jnp.float32))).reshape(B, S, nh, hd)
    o, wkv = rwkv6_wkv_scan(p, r, k, v, w, st["wkv"])
    o = o.reshape(B, S, nh * hd)
    # per-head group norm
    og = o.reshape(B, S, nh, hd).astype(jnp.float32)
    og = (og - og.mean(-1, keepdims=True)) * jax.lax.rsqrt(
        og.var(-1, keepdims=True) + 64e-5)
    o = (og.reshape(B, S, nh * hd) * p["ln_x_scale"]
         + p["ln_x_bias"]).astype(x.dtype)
    y_tm = apply_dense(p["wo"], o * jax.nn.silu(g))
    new_state = {"shift_tm": x[:, -1], "wkv": wkv}
    return y_tm, new_state


def rwkv6_channel_mix(p: Params, x, shift_prev):
    """RWKV channel-mix (the FFN analogue). Returns (y, new_shift)."""
    xs = _token_shift(x, shift_prev)
    xr = x + (xs - x) * p["mu_cm"][0]
    xk = x + (xs - x) * p["mu_cm"][1]
    rgate = jax.nn.sigmoid(apply_dense(p["cm_wr"], xr))
    kk = jnp.square(jax.nn.relu(apply_dense(p["cm_wk"], xk)))
    return rgate * apply_dense(p["cm_wv"], kk), x[:, -1]
