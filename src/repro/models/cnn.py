"""CNN substrate in JAX: the paper's four benchmark models (VGG16, AlexNet,
ZF, YOLO) as runnable networks, in float and in the paper's channel-wise
fixed-point arithmetic (int8/int16 MACs, 32-bit accumulation, shift-aligned
per-channel formats).

The layer graph comes from ``repro.core.workload`` and execution is owned by
``repro.core.program`` (single source of truth for the allocator, the
simulator, and the runnable model): ``forward(quantized=True)`` is a thin
wrapper that compiles an :class:`~repro.core.program.EngineProgram` —
freezing po2 scales on the given batch — and runs it, so the fixed-point
pipeline here is byte-for-byte the one the benchmarks cycle-count. NHWC
layout.
"""

from __future__ import annotations

import math
import zlib
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.program import compile_model, float_forward
from repro.core.workload import CNNModel

Params = dict[str, Any]


def init_params(model: CNNModel, key=None, dtype=jnp.float32) -> Params:
    key = key if key is not None else jax.random.PRNGKey(0)
    p: Params = {}
    hw = model.input_hw
    for lyr in model.layers:
        if lyr.kind == "pool":
            hw = lyr.out_hw(hw)
            continue
        # stable per-layer fold (builtin str hash is salted per process,
        # which made init non-reproducible across runs)
        k = jax.random.fold_in(key, zlib.crc32(lyr.name.encode()) % (2 ** 31))
        if lyr.kind == "fc":
            fan_in = lyr.in_ch
            w = jax.random.normal(k, (lyr.in_ch, lyr.out_ch), jnp.float32)
        else:
            fan_in = lyr.kernel * lyr.kernel * lyr.in_ch // lyr.groups
            w = jax.random.normal(
                k, (lyr.kernel, lyr.kernel, lyr.in_ch // lyr.groups,
                    lyr.out_ch), jnp.float32)
        p[lyr.name] = {"w": (w / math.sqrt(fan_in)).astype(dtype),
                       "b": jnp.zeros((lyr.out_ch,), dtype)}
        hw = lyr.out_hw(hw)
    return p


def forward(params: Params, model: CNNModel, x: jnp.ndarray,
            quantized: bool = False, bits: int = 8,
            use_kernel: bool = False) -> jnp.ndarray:
    """x [B,H,W,C] float. quantized=True compiles an EngineProgram with
    scales calibrated on ``x`` and runs the paper's fixed-point pipeline
    (per-channel po2 weight formats, int32 accumulation, fused
    bias/ReLU/shift epilogue, int8 activations end-to-end).
    use_kernel=True routes the MACs through the Pallas PE-array kernel
    (interpret mode on CPU; the real thing on TPU).

    Note: this wrapper recompiles (and recalibrates on ``x``) every call —
    the seed's dynamic-scale semantics. For repeated inference, compile
    once with ``repro.core.program.compile_model`` and reuse the program."""
    if not quantized:
        return float_forward(params, model, x)
    prog = compile_model(model, params, bits=bits, calib_batch=x)
    # No silent fallback: run() raises up front if the kernel route is
    # requested but unavailable (bits=16 / Pallas missing).
    return prog.run(x, use_kernel=use_kernel)
