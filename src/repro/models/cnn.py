"""CNN substrate in JAX: the paper's four benchmark models (VGG16, AlexNet,
ZF, YOLO) as runnable networks, in float and in the paper's channel-wise
fixed-point arithmetic (int8/int16 MACs, 32-bit accumulation, shift-aligned
per-channel formats).

The layer graph comes from ``repro.core.workload`` (single source of truth
for both the allocator and the executable model). NHWC layout.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.core.workload import CNNModel, ConvLayer

Params = dict[str, Any]


def _pad_for(lyr: ConvLayer, in_hw: int, out_hw: int) -> tuple[int, int]:
    """Explicit symmetric-ish padding reproducing each model's published
    output sizes (SAME for stride-1, VALID-like for the stride-k stems)."""
    need = (out_hw - 1) * lyr.stride + lyr.kernel - in_hw
    need = max(need, 0)
    lo = need // 2
    return lo, need - lo


def init_params(model: CNNModel, key=None, dtype=jnp.float32) -> Params:
    key = key if key is not None else jax.random.PRNGKey(0)
    p: Params = {}
    hw = model.input_hw
    for lyr in model.layers:
        if lyr.kind == "pool":
            hw = lyr.out_hw(hw)
            continue
        k = jax.random.fold_in(key, hash(lyr.name) % (2 ** 31))
        if lyr.kind == "fc":
            fan_in = lyr.in_ch
            w = jax.random.normal(k, (lyr.in_ch, lyr.out_ch), jnp.float32)
        else:
            fan_in = lyr.kernel * lyr.kernel * lyr.in_ch // lyr.groups
            w = jax.random.normal(
                k, (lyr.kernel, lyr.kernel, lyr.in_ch // lyr.groups,
                    lyr.out_ch), jnp.float32)
        p[lyr.name] = {"w": (w / math.sqrt(fan_in)).astype(dtype),
                       "b": jnp.zeros((lyr.out_ch,), dtype)}
        hw = lyr.out_hw(hw)
    return p


def forward(params: Params, model: CNNModel, x: jnp.ndarray,
            quantized: bool = False, bits: int = 8,
            use_kernel: bool = False) -> jnp.ndarray:
    """x [B,H,W,C] float. quantized=True runs the paper's fixed-point path
    (per-channel po2 scales, int32 accumulation) via the same graph.
    use_kernel=True routes the int8 conv MACs through the Pallas PE-array
    kernel (interpret mode on CPU; the real thing on TPU)."""
    hw = x.shape[1]
    last = [l for l in model.layers if l.kind != "pool"][-1]
    for lyr in model.layers:
        out_hw = lyr.out_hw(hw)
        if lyr.kind == "pool":
            lo, hi = _pad_for(lyr, hw, out_hw)
            x = -jax.lax.reduce_window(
                -x, jnp.inf, jax.lax.min,
                (1, lyr.kernel, lyr.kernel, 1),
                (1, lyr.stride, lyr.stride, 1),
                ((0, 0), (lo, hi), (lo, hi), (0, 0)))
        elif lyr.kind == "fc":
            x = x.reshape(x.shape[0], -1)
            w, b = params[lyr.name]["w"], params[lyr.name]["b"]
            x = (_fc_quantized(x, w, bits) if quantized else x @ w) + b
            if lyr is not last:
                x = jax.nn.relu(x)
        else:
            w, b = params[lyr.name]["w"], params[lyr.name]["b"]
            lo, hi = _pad_for(lyr, hw, out_hw)
            if quantized:
                x = _conv_quantized(x, w, lyr, (lo, hi), bits,
                                    use_kernel=use_kernel)
            else:
                x = jax.lax.conv_general_dilated(
                    x, w, (lyr.stride, lyr.stride),
                    ((lo, hi), (lo, hi)),
                    dimension_numbers=("NHWC", "HWIO", "NHWC"),
                    feature_group_count=lyr.groups)
            x = jax.nn.relu(x + b)
        hw = out_hw
    return x


def _conv_quantized(x, w, lyr: ConvLayer, pad, bits, use_kernel=False):
    """Paper-style fixed point: quantize activations (per-tensor) and
    weights (per-output-channel) to po2 scales, int MACs, 32-bit accumulate,
    dequantize for the (float) bias+relu epilogue."""
    xq, ex = quant.quantize_po2(x, axis=-1, bits=bits)
    # Align per-channel formats onto the per-tensor (max) exponent before
    # the MAC array — the left/right shifter stage of Fig. 3(c).
    ex_t = jnp.max(ex)
    xq = quant.requantize_output(xq.astype(jnp.int32), ex, ex_t, bits)
    wq, ew = quant.quantize_po2(w, axis=-1, bits=bits)      # per out-channel
    # 8-bit: exact int32 accumulation (the paper's 32-bit partial sums).
    # 16-bit: the DSP48 accumulates in 48 bits; we simulate in fp32 (exact
    # to ~2^-24, far below the quantization step).
    if use_kernel and bits <= 8 and lyr.groups == 1 \
            and pad[0] == pad[1] == lyr.kernel // 2:
        # Pallas PE-array path: int8 implicit GEMM with shift epilogue is
        # the engine; the epilogue shift is folded into the fp scale here
        # (shift=0 keeps full int32 precision in this validation mode).
        from repro.kernels.conv2d_int8.ops import conv2d_int8
        import jax as _jax
        interp = _jax.devices()[0].platform != "tpu"
        acc = conv2d_int8(xq.astype(jnp.int8), wq.astype(jnp.int8),
                          jnp.zeros((w.shape[-1],), jnp.int32),
                          stride=lyr.stride, interpret=interp,
                          emit_int32=True)
        return acc.astype(jnp.float32) * jnp.exp2(
            (ew + ex_t).astype(jnp.float32))
    acc_dt = jnp.int32 if bits <= 8 else jnp.float32
    acc = jax.lax.conv_general_dilated(
        xq.astype(acc_dt), wq.astype(acc_dt),
        (lyr.stride, lyr.stride), (pad, pad),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=lyr.groups,
        preferred_element_type=acc_dt)
    return acc.astype(jnp.float32) * jnp.exp2(
        (ew + ex_t).astype(jnp.float32))


def _fc_quantized(x, w, bits):
    xq, ex = quant.quantize_po2(x, axis=0, bits=bits)   # per-row (batch)
    wq, ew = quant.quantize_po2(w, axis=-1, bits=bits)
    acc_dt = jnp.int32 if bits <= 8 else jnp.float32
    acc = jnp.einsum("bi,io->bo", xq.astype(acc_dt), wq.astype(acc_dt))
    return acc.astype(jnp.float32) * jnp.exp2(
        (ex[:, None] + ew[None, :]).astype(jnp.float32))
