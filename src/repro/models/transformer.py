"""Model assembly: decoder-only / encoder-decoder transformers over the
block kinds (attn, attn_local, mla, moe variants, rglru, rwkv).

Layers are grouped into maximal runs of identical kind ("segments"); each
segment's parameters are stacked on a leading axis and executed with
``jax.lax.scan`` so that an 88-layer model lowers to one compiled block per
segment (compile time and HLO size stay bounded for the 512-device
dry-run). Heterogeneous archs (RecurrentGemma's 2:1 pattern) simply produce
short segments which are unrolled.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import recurrent as R

Params = dict[str, Any]

ATTN_KINDS = ("attn", "attn_local", "mla", "moe", "mla_moe")


# ---------------------------------------------------------------------------
# Segments
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Segment:
    kind: str
    start: int
    count: int

    @property
    def scanned(self) -> bool:
        return self.count >= 3


def segments(cfg: ModelConfig) -> list[Segment]:
    kinds = cfg.layer_kinds()
    segs: list[Segment] = []
    i = 0
    while i < len(kinds):
        j = i
        while j < len(kinds) and kinds[j] == kinds[i]:
            j += 1
        segs.append(Segment(kinds[i], i, j - i))
        i = j
    return segs


# ---------------------------------------------------------------------------
# Per-layer init / apply dispatch
# ---------------------------------------------------------------------------


def _layer_init(kind: str, cfg: ModelConfig, key, dtype) -> Params:
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    p: Params = {"ln1": L.rms_norm_init(d, dtype),
                 "ln2": L.rms_norm_init(d, dtype)}
    if kind in ("attn", "attn_local"):
        p["attn"] = L.gqa_init(ks[0], cfg, dtype)
    elif kind in ("mla", "mla_moe"):
        p["attn"] = L.mla_init(ks[0], cfg, dtype)
    elif kind == "moe":
        p["attn"] = L.gqa_init(ks[0], cfg, dtype)
    elif kind == "rglru":
        p["rec"] = R.rglru_block_init(ks[0], cfg, dtype)
    elif kind == "rwkv":
        p["rwkv"] = R.rwkv6_block_init(ks[0], cfg, dtype)
    else:
        raise ValueError(kind)
    if kind.endswith("moe"):
        p["mlp"] = L.moe_init(ks[1], cfg, dtype)
    elif kind != "rwkv":
        p["mlp"] = L.mlp_init(ks[1], d, cfg.d_ff, cfg.mlp_kind, dtype)
    return p


def _layer_cache(kind: str, cfg: ModelConfig, batch: int, max_len: int,
                 dtype) -> Params | None:
    KV, hd = cfg.n_kv_heads, cfg.head_dim
    if kind in ("attn", "moe"):
        return {"k": jnp.zeros((batch, max_len, KV, hd), dtype),
                "v": jnp.zeros((batch, max_len, KV, hd), dtype),
                "idx": jnp.zeros((), jnp.int32)}
    if kind == "attn_local":
        size = min(cfg.window or max_len, max_len)
        return {"k": jnp.zeros((batch, size, KV, hd), dtype),
                "v": jnp.zeros((batch, size, KV, hd), dtype),
                "slot_pos": jnp.full((size,), -(10 ** 9), jnp.int32),
                "idx": jnp.zeros((), jnp.int32)}
    if kind in ("mla", "mla_moe"):
        return {"ckv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
                "krope": jnp.zeros((batch, max_len, cfg.rope_head_dim), dtype),
                "idx": jnp.zeros((), jnp.int32)}
    if kind == "rglru":
        st = R.rglru_state_init(cfg, batch, dtype)
        return st
    if kind == "rwkv":
        nh = cfg.d_model // cfg.head_dim
        return {"shift_tm": jnp.zeros((batch, cfg.d_model), dtype),
                "shift_cm": jnp.zeros((batch, cfg.d_model), dtype),
                "wkv": jnp.zeros((batch, nh, cfg.head_dim, cfg.head_dim),
                                 jnp.float32)}
    raise ValueError(kind)


def _layer_apply(kind: str, p: Params, cfg: ModelConfig, x, positions,
                 cache: Params | None):
    """Pre-norm residual block. Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if kind == "rwkv":
        h, tm_state = R.rwkv6_block_apply(
            p["rwkv"], cfg, L.rms_norm(p["ln1"], x),
            state=None if cache is None else
            {"shift_tm": cache["shift_tm"], "wkv": cache["wkv"]})
        x = x + h
        cm_prev = (cache["shift_cm"] if cache is not None
                   else jnp.zeros_like(x[:, 0]))
        h2, cm_new = R.rwkv6_channel_mix(p["rwkv"], L.rms_norm(p["ln2"], x),
                                         cm_prev)
        x = x + h2
        new_cache = None if cache is None else {
            "shift_tm": tm_state["shift_tm"], "shift_cm": cm_new,
            "wkv": tm_state["wkv"]}
        return x, new_cache, aux
    if kind == "rglru":
        h, st = R.rglru_block_apply(p["rec"], cfg, L.rms_norm(p["ln1"], x),
                                    state=cache)
        x = x + h
        new_cache = st if cache is not None else None
    elif kind in ("mla", "mla_moe"):
        h, new_cache = L.mla_apply(p["attn"], cfg, L.rms_norm(p["ln1"], x),
                                   positions, cache=cache)
        x = x + h
    else:
        h, new_cache = L.gqa_apply(
            p["attn"], cfg, L.rms_norm(p["ln1"], x), positions, cache=cache,
            window=cfg.window if kind == "attn_local" else 0)
        x = x + h
    if kind.endswith("moe"):
        h, aux = L.moe_apply(p["mlp"], cfg, L.rms_norm(p["ln2"], x))
    else:
        h = L.mlp_apply(p["mlp"], L.rms_norm(p["ln2"], x), cfg.mlp_kind)
    return x + h, new_cache, aux


# ---------------------------------------------------------------------------
# Encoder layers (Seamless backbone) — bidirectional attn + cross-attn in dec
# ---------------------------------------------------------------------------


def _enc_layer_init(cfg, key, dtype) -> Params:
    ks = jax.random.split(key, 2)
    return {"ln1": L.rms_norm_init(cfg.d_model, dtype),
            "ln2": L.rms_norm_init(cfg.d_model, dtype),
            "attn": L.gqa_init(ks[0], cfg, dtype),
            "mlp": L.mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp_kind,
                              dtype)}


def _dec_xattn_init(cfg, key, dtype) -> Params:
    ks = jax.random.split(key, 2)
    return {"ln3": L.rms_norm_init(cfg.d_model, dtype),
            "xattn": L.gqa_init(ks[0], cfg, dtype)}


# ---------------------------------------------------------------------------
# Model init / cache init / forward
# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, key=None, dtype=None) -> Params:
    dtype = dtype or jnp.dtype(cfg.dtype)
    key = key if key is not None else jax.random.PRNGKey(0)
    ks = jax.random.split(key, 8)
    p: Params = {
        "embed": (jax.random.normal(ks[0], (cfg.vocab, cfg.d_model),
                                    jnp.float32) * 0.02).astype(dtype),
        "final_norm": L.rms_norm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = L.dense(ks[1], cfg.d_model, cfg.vocab, dtype)
    for si, seg in enumerate(segments(cfg)):
        keys = jax.random.split(ks[2 + si % 6], seg.count)
        stacked = [
            _layer_init(seg.kind, cfg, keys[i], dtype)
            for i in range(seg.count)]
        if cfg.n_enc_layers and seg.kind in ATTN_KINDS:
            for i, lp in enumerate(stacked):
                lp.update(_dec_xattn_init(
                    cfg, jax.random.fold_in(keys[i], 7), dtype))
        p[f"seg{si}"] = jax.tree.map(lambda *xs: jnp.stack(xs), *stacked)
    if cfg.n_enc_layers:
        ekeys = jax.random.split(ks[7], cfg.n_enc_layers)
        enc = [_enc_layer_init(cfg, k, dtype) for k in ekeys]
        p["encoder"] = jax.tree.map(lambda *xs: jnp.stack(xs), *enc)
        p["enc_norm"] = L.rms_norm_init(cfg.d_model, dtype)
    return p


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=None) -> Params:
    dtype = dtype or jnp.dtype(cfg.dtype)
    c: Params = {"_pos": jnp.zeros((), jnp.int32)}
    for si, seg in enumerate(segments(cfg)):
        per = [_layer_cache(seg.kind, cfg, batch, max_len, dtype)
               for _ in range(seg.count)]
        c[f"seg{si}"] = jax.tree.map(lambda *xs: jnp.stack(xs), *per)
    return c


def _positions(cfg: ModelConfig, B: int, S: int, offset) -> jnp.ndarray:
    pos = offset + jnp.arange(S)[None, :]
    pos = jnp.broadcast_to(pos, (B, S))
    if cfg.mrope:
        # Text tokens: all three M-RoPE components equal the text position.
        return jnp.broadcast_to(pos[..., None], (B, S, 3))
    return pos


def encode(params: Params, cfg: ModelConfig, enc_embeds: jnp.ndarray):
    """Bidirectional encoder over precomputed frame embeddings."""
    B, S, D = enc_embeds.shape
    x = enc_embeds
    positions = _positions(cfg, B, S, 0)

    def body(x, lp):
        h, _ = L.gqa_apply(lp["attn"], cfg, L.rms_norm(lp["ln1"], x),
                           positions, causal=False)
        x = x + h
        x = x + L.mlp_apply(lp["mlp"], L.rms_norm(lp["ln2"], x),
                            cfg.mlp_kind)
        return x, None

    x, _ = jax.lax.scan(body, x, params["encoder"])
    return L.rms_norm(params["enc_norm"], x)


def forward(params: Params, cfg: ModelConfig, batch: dict,
            cache: Params | None = None, remat: bool = False):
    """Returns (logits [B,S,V], new_cache, aux_loss).

    batch: {"tokens" [B,S]} or {"embeds" [B,S,D] (+"positions")} and
    optionally {"enc_embeds"} for enc-dec.
    """
    if "tokens" in batch:
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = jnp.take(params["embed"], tokens, axis=0)
    else:
        x = batch["embeds"]
        B, S, _ = x.shape
    offset = 0 if cache is None else cache["_pos"]
    if "positions" in batch:
        positions = batch["positions"]
    else:
        positions = _positions(cfg, B, S, offset)

    enc_out = None
    if cfg.n_enc_layers and "enc_embeds" in batch:
        enc_out = encode(params, cfg, batch["enc_embeds"])

    aux_total = jnp.zeros((), jnp.float32)
    new_cache: Params = {}
    for si, seg in enumerate(segments(cfg)):
        sp = params[f"seg{si}"]
        sc = cache[f"seg{si}"] if cache is not None else None

        def one_layer(x, lp, lc):
            x, nc, aux = _layer_apply(seg.kind, lp, cfg, x, positions, lc)
            if enc_out is not None and seg.kind in ATTN_KINDS:
                Bx, Sx, Dx = enc_out.shape
                kv_k = L.apply_dense(lp["xattn"]["wk"], enc_out)
                kv_v = L.apply_dense(lp["xattn"]["wv"], enc_out)
                KV = cfg.n_kv_heads
                hd = cfg.head_dim
                h, _ = L.gqa_apply(
                    lp["xattn"], cfg, L.rms_norm(lp["ln3"], x), positions,
                    cross_kv=(kv_k.reshape(Bx, Sx, KV, hd),
                              kv_v.reshape(Bx, Sx, KV, hd)))
                x = x + h
            return x, nc, aux

        if seg.scanned:
            def body(carry, xs):
                x = carry
                lp, lc = xs
                x, nc, aux = one_layer(x, lp, lc)
                return x, (nc, aux)

            if remat and cache is None:
                body = jax.checkpoint(body)
            x, (ncs, auxs) = jax.lax.scan(
                body, x, (sp, sc))
            aux_total = aux_total + auxs.sum()
            if cache is not None:
                new_cache[f"seg{si}"] = ncs
        else:
            ncs_list = []
            for i in range(seg.count):
                lp = jax.tree.map(lambda t: t[i], sp)
                lc = (jax.tree.map(lambda t: t[i], sc)
                      if sc is not None else None)
                x, nc, aux = one_layer(x, lp, lc)
                aux_total = aux_total + aux
                ncs_list.append(nc)
            if cache is not None:
                new_cache[f"seg{si}"] = jax.tree.map(
                    lambda *xs: jnp.stack(xs), *ncs_list)

    if cache is not None:
        new_cache["_pos"] = offset + S
    x = L.rms_norm(params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = x @ params["embed"].T
    else:
        logits = L.apply_dense(params["lm_head"], x)
    return logits, (new_cache if cache is not None else None), aux_total


def loss_fn(params: Params, cfg: ModelConfig, batch: dict,
            remat: bool = False):
    logits, _, aux = forward(params, cfg, batch, remat=remat)
    labels = batch["labels"]
    logits = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    loss = (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return loss + 0.01 * aux, {"loss": loss, "aux": aux}


def param_count(cfg: ModelConfig) -> int:
    shapes = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    return sum(int(math.prod(x.shape)) for x in jax.tree.leaves(shapes))
