"""Transformer building blocks: norms, rotary (+M-RoPE), GQA/MLA attention
(with KV cache, sliding window, chunked-softmax long-context path), MLPs and
DeepSeek-style shared+routed MoE.

Everything is a pure function over explicit parameter dicts so the same code
lowers under pjit (NamedSharding inputs) and under shard_map (pipeline
stages), and so `jax.eval_shape` can build abstract parameter trees for the
multi-pod dry-run without allocating 671B parameters on a laptop.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Params = dict[str, Any]

# ---------------------------------------------------------------------------
# Init helpers
# ---------------------------------------------------------------------------


def _dense_init(key, shape, dtype, scale: float | None = None):
    fan_in = shape[0] if len(shape) > 1 else shape[-1]
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def dense(key, d_in: int, d_out: int, dtype, bias: bool = False) -> Params:
    p = {"w": _dense_init(key, (d_in, d_out), dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def apply_dense(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    w = p["w"]
    if w.dtype == jnp.int8:
        # Weight-only int8 (the paper's fixed-point insight applied to
        # decode): HBM reads are int8; dequant fuses into the matmul.
        w = w.astype(x.dtype) * p["w_scale"].astype(x.dtype)
    y = x @ w
    if "b" in p:
        y = y + p["b"]
    return y


def quantize_params_int8(params: Params) -> Params:
    """Per-output-channel symmetric int8 for every dense weight (and the
    embedding). Halves (vs bf16) the per-token weight traffic that bounds
    decode throughput."""
    def q2d(w):
        w = w.astype(jnp.float32)
        s = jnp.maximum(jnp.abs(w).max(axis=-2, keepdims=True),
                        1e-8) / 127.0
        q = jnp.clip(jnp.round(w / s), -127, 127).astype(jnp.int8)
        return q, jnp.squeeze(s, -2).astype(jnp.float32)

    def visit(node):
        # Dense weights, possibly layer-stacked: [d_in, d_out] or
        # [L, d_in, d_out]. Scales are per-out-channel (and per-layer).
        if isinstance(node, dict) and "w" in node and hasattr(node["w"], "ndim") \
                and node["w"].ndim in (2, 3) and node["w"].dtype != jnp.int8:
            q, s = q2d(node["w"])
            return {**node, "w": q, "w_scale": s}
        if isinstance(node, dict):
            out = {}
            for k, v in node.items():
                # MoE expert stacks: [E, D, F] (or layer-stacked
                # [L, E, D, F]) arrays — per-(expert, out-channel) scales.
                if k in ("wi", "wg", "wo") and hasattr(v, "ndim") \
                        and getattr(v, "ndim", 0) in (3, 4) \
                        and v.dtype != jnp.int8:
                    q, sc = q2d(v)
                    out[k] = q
                    out[k + "_scale"] = sc
                else:
                    out[k] = visit(v)
            return out
        return node

    # The embedding stays bf16: a decode step gathers only B rows of it,
    # so it never bounds the weight-streaming term.
    return visit(params)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm_init(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rms_norm(p: Params, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dt) * p["scale"]


def layer_norm_init(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layer_norm(p: Params, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(dt) * p["scale"] + p["bias"]


# ---------------------------------------------------------------------------
# Rotary embeddings (RoPE and M-RoPE)
# ---------------------------------------------------------------------------


def _rope_angles(positions: jnp.ndarray, half: int, theta: float):
    """positions [..., S] -> cos/sin [..., S, half] (float32)."""
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
               mrope_sections: tuple[int, ...] | None = None) -> jnp.ndarray:
    """x [B,S,H,hd]; positions [B,S] or [B,S,3] for M-RoPE.

    M-RoPE (Qwen2-VL): the head-dim halves are split into sections, each
    rotated by a different position component (temporal/height/width).
    """
    half = x.shape[-1] // 2
    if mrope_sections is None or positions.ndim == 2:
        cos, sin = _rope_angles(positions, half, theta)       # [B,S,half]
    else:
        secs = list(mrope_sections)
        assert sum(secs) == half, (secs, half)
        coss, sins = [], []
        for j, sec in enumerate(secs):
            freqs = theta ** (-(jnp.arange(sum(secs[:j]), sum(secs[:j]) + sec,
                                           dtype=jnp.float32)) / half)
            ang = positions[..., j].astype(jnp.float32)[..., None] * freqs
            coss.append(jnp.cos(ang))
            sins.append(jnp.sin(ang))
        cos = jnp.concatenate(coss, -1)
        sin = jnp.concatenate(sins, -1)
    cos = cos[:, :, None, :]
    sin = sin[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Scaled-dot-product attention cores
# ---------------------------------------------------------------------------


def _sdpa_direct(q, k, v, *, causal: bool, window: int,
                 q_offset: jnp.ndarray | int, kv_len: jnp.ndarray | None,
                 kpos: jnp.ndarray | None = None):
    """q [B,Sq,KV,G,hd], k/v [B,Skv,KV,hd]. fp32 softmax.

    q_offset: absolute position of q[0] (for causal masking w/ cache).
    kv_len: number of valid cache entries (decode), else None.
    kpos: per-slot absolute key positions (ring caches), else arange.
    """
    B, Sq, KV, G, hd = q.shape
    Skv = k.shape[1]
    scale = 1.0 / math.sqrt(hd)
    logits = jnp.einsum("bqkgh,bskh->bkgqs", q, k).astype(jnp.float32) * scale
    qpos = jnp.arange(Sq)[:, None] + q_offset            # [Sq,1]
    kpos = (jnp.arange(Skv) if kpos is None else kpos)[None, :]
    mask = jnp.ones((Sq, Skv), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    if kv_len is not None:
        mask &= kpos < kv_len
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bkgqs,bskh->bqkgh", probs, v)


def _sdpa_chunked(q, k, v, *, causal: bool, window: int, q_offset,
                  chunk: int = 1024):
    """Flash-style online-softmax over KV chunks — O(Sq*chunk) memory.

    Used for the 32k prefill shapes where Sq x Skv logits would not fit.
    """
    B, Sq, KV, G, hd = q.shape
    dv = v.shape[-1]                      # may differ from hd (MLA)
    Skv = k.shape[1]
    n_chunks = max(1, Skv // chunk)
    assert Skv % n_chunks == 0, (Skv, chunk)
    chunk = Skv // n_chunks
    scale = 1.0 / math.sqrt(hd)
    kc = k.reshape(B, n_chunks, chunk, KV, hd)
    vc = v.reshape(B, n_chunks, chunk, KV, dv)
    qpos = jnp.arange(Sq)[:, None] + q_offset

    def step(carry, xs):
        m, l, acc = carry
        kj, vj, j = xs
        logits = (jnp.einsum("bqkgh,bskh->bkgqs", q, kj)
                  .astype(jnp.float32) * scale)
        kpos = j * chunk + jnp.arange(chunk)[None, :]
        mask = jnp.ones((Sq, chunk), dtype=bool)
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= kpos > qpos - window
        logits = jnp.where(mask, logits, -1e30)
        m_new = jnp.maximum(m, logits.max(-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgqs,bskh->bkgqh", p, vj.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KV, G, Sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, KV, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, KV, G, Sq, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0),
        (kc.swapaxes(0, 1), vc.swapaxes(0, 1), jnp.arange(n_chunks)))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.swapaxes(1, 3).swapaxes(2, 3).astype(q.dtype)  # -> b q k g h


# Attention implementation switch: "jax" (default; compiles anywhere,
# incl. the 512-device CPU dry-run) or "pallas" (the TPU flash kernel;
# interpret-mode on CPU). Applies to the cache-less full-attention path.
_ATTN_IMPL = "jax"


def set_attention_impl(impl: str) -> None:
    global _ATTN_IMPL
    assert impl in ("jax", "pallas"), impl
    _ATTN_IMPL = impl


def _sdpa_pallas(q, k, v, *, causal, window):
    """Route [B,S,KV,G,hd] GQA tensors through the flash kernel
    (kv heads repeated to full heads)."""
    from repro.kernels.flash_attention.kernel import flash_attention
    B, Sq, KV, G, hd = q.shape
    qf = q.reshape(B, Sq, KV * G, hd)
    kf = jnp.repeat(k, G, axis=2)
    vf = jnp.repeat(v, G, axis=2)
    interpret = jax.devices()[0].platform != "tpu"
    out = flash_attention(qf, kf, vf, causal=causal, window=window,
                          bq=min(128, Sq), bkv=min(128, Sq),
                          interpret=interpret)
    return out.reshape(B, Sq, KV, G, hd)


def sdpa(q, k, v, *, causal: bool = True, window: int = 0, q_offset=0,
         kv_len=None, kpos=None, chunked_threshold: int = 8192):
    """Dispatch between the direct, chunked, and Pallas attention cores."""
    Sq, Skv = q.shape[1], k.shape[1]
    if (_ATTN_IMPL == "pallas" and kv_len is None and kpos is None
            and Sq == Skv and Sq % min(128, Sq) == 0
            and q.shape[-1] == v.shape[-1]):
        return _sdpa_pallas(q, k, v, causal=causal, window=window)
    if (Sq > 1 and Sq * Skv > chunked_threshold ** 2 and kv_len is None
            and kpos is None):
        return _sdpa_chunked(q, k, v, causal=causal, window=window,
                             q_offset=q_offset)
    return _sdpa_direct(q, k, v, causal=causal, window=window,
                        q_offset=q_offset, kv_len=kv_len, kpos=kpos)


# ---------------------------------------------------------------------------
# GQA attention block
# ---------------------------------------------------------------------------


def gqa_init(key, cfg, dtype) -> Params:
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 6)
    p = {
        "wq": dense(ks[0], d, H * hd, dtype, cfg.qkv_bias),
        "wk": dense(ks[1], d, KV * hd, dtype, cfg.qkv_bias),
        "wv": dense(ks[2], d, KV * hd, dtype, cfg.qkv_bias),
        "wo": dense(ks[3], H * hd, d, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = rms_norm_init(hd, dtype)
        p["k_norm"] = rms_norm_init(hd, dtype)
    return p


def gqa_apply(p: Params, cfg, x, positions, *, cache: Params | None = None,
              window: int = 0, cross_kv: tuple | None = None,
              causal: bool = True):
    """Returns (out [B,S,D], new_cache). cache = {"k","v","idx"}.

    cross_kv: (k, v) already projected — encoder-decoder cross attention
    (positions are not rotated in that case, matching the Seamless backbone).
    """
    B, S, D = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    G = H // KV
    q = apply_dense(p["wq"], x).reshape(B, S, KV, G, hd)
    if cross_kv is None:
        k = apply_dense(p["wk"], x).reshape(B, S, KV, hd)
        v = apply_dense(p["wv"], x).reshape(B, S, KV, hd)
    else:
        k, v = cross_kv
    if cfg.qk_norm:
        q = rms_norm(p["q_norm"], q)
        k = rms_norm(p["k_norm"], k)
    causal = causal and cross_kv is None
    if cross_kv is None:
        q = apply_rope(q.reshape(B, S, KV * G, hd), positions, cfg.rope_theta,
                       cfg.mrope_sections if cfg.mrope else None
                       ).reshape(B, S, KV, G, hd)
        k = apply_rope(k, positions, cfg.rope_theta,
                       cfg.mrope_sections if cfg.mrope else None)

    new_cache = None
    kv_len = None
    q_offset = 0
    if cache is not None:
        idx = cache["idx"]
        size = cache["k"].shape[1]
        ring = window > 0 and size <= window
        if ring:
            # Ring buffer: a window-sized cache holds the last `size` keys;
            # RoPE is applied before caching so slot order is irrelevant.
            # Per-slot absolute positions keep causal/window masking exact
            # during multi-token prefill into the ring.
            if S > size:
                k, v = k[:, -size:], v[:, -size:]
            s_eff = min(S, size)
            start = idx + (S - s_eff)
            slots = jnp.mod(start + jnp.arange(s_eff), size)
            knew = cache["k"].at[:, slots].set(k.astype(cache["k"].dtype))
            vnew = cache["v"].at[:, slots].set(v.astype(cache["v"].dtype))
            slot_pos = cache.get(
                "slot_pos", jnp.full((size,), -(10 ** 9), jnp.int32))
            slot_pos = slot_pos.at[slots].set(start + jnp.arange(s_eff))
            new_cache = {"k": knew, "v": vnew, "idx": idx + S,
                         "slot_pos": slot_pos}
            out = sdpa(q, knew, vnew, causal=causal, window=window,
                       q_offset=idx, kpos=slot_pos)
            out = out.reshape(B, S, H * hd)
            return apply_dense(p["wo"], out), new_cache
        else:
            k = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), idx, axis=1)
            v = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), idx, axis=1)
            new_cache = {"k": k, "v": v, "idx": idx + S}
            kv_len = idx + S
            q_offset = idx
    out = sdpa(q, k, v, causal=causal, window=window, q_offset=q_offset,
               kv_len=kv_len)
    out = out.reshape(B, S, H * hd)
    return apply_dense(p["wo"], out), new_cache


# ---------------------------------------------------------------------------
# MLA attention (DeepSeek V2/V3)
# ---------------------------------------------------------------------------


def mla_init(key, cfg, dtype) -> Params:
    d, H = cfg.d_model, cfg.n_heads
    nope, rope, vh = cfg.head_dim, cfg.rope_head_dim, cfg.v_head_dim
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    ks = jax.random.split(key, 8)
    p: Params = {}
    if qr:
        p["wq_a"] = dense(ks[0], d, qr, dtype)
        p["q_a_norm"] = rms_norm_init(qr, dtype)
        p["wq_b"] = dense(ks[1], qr, H * (nope + rope), dtype)
    else:
        p["wq"] = dense(ks[0], d, H * (nope + rope), dtype)
    p["wkv_a"] = dense(ks[2], d, kvr + rope, dtype)
    p["kv_a_norm"] = rms_norm_init(kvr, dtype)
    p["wkv_b"] = dense(ks[3], kvr, H * (nope + vh), dtype)
    p["wo"] = dense(ks[4], H * vh, d, dtype)
    return p


def mla_apply(p: Params, cfg, x, positions, *, cache: Params | None = None):
    """MLA with low-rank latent KV. Prefill/train: decompressed path.
    Decode: matrix-absorbed path attending directly over the cached latent
    (the memory win that is MLA's point).

    cache = {"ckv" [B,Smax,kvr], "krope" [B,Smax,rope], "idx"}.
    """
    B, S, D = x.shape
    H = cfg.n_heads
    nope, rope, vh = cfg.head_dim, cfg.rope_head_dim, cfg.v_head_dim
    kvr = cfg.kv_lora_rank
    if cfg.q_lora_rank:
        q = apply_dense(p["wq_b"],
                        rms_norm(p["q_a_norm"], apply_dense(p["wq_a"], x)))
    else:
        q = apply_dense(p["wq"], x)
    q = q.reshape(B, S, H, nope + rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv_a = apply_dense(p["wkv_a"], x)
    ckv, k_rope = kv_a[..., :kvr], kv_a[..., kvr:]
    ckv = rms_norm(p["kv_a_norm"], ckv)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)
    k_rope = k_rope[:, :, 0, :]

    wkv_b = p["wkv_b"]["w"]
    if wkv_b.dtype == jnp.int8:
        wkv_b = wkv_b.astype(x.dtype) * p["wkv_b"]["w_scale"].astype(x.dtype)
    wkv_b = wkv_b.reshape(kvr, H, nope + vh)
    w_uk, w_uv = wkv_b[..., :nope], wkv_b[..., nope:]
    scale = 1.0 / math.sqrt(nope + rope)

    if cache is not None and S == 1:
        # Absorbed decode: q_nope' = q_nope @ W_uk -> latent space.
        idx = cache["idx"]
        ckv_all = jax.lax.dynamic_update_slice_in_dim(
            cache["ckv"], ckv.astype(cache["ckv"].dtype), idx, axis=1)
        kr_all = jax.lax.dynamic_update_slice_in_dim(
            cache["krope"], k_rope.astype(cache["krope"].dtype), idx, axis=1)
        new_cache = {"ckv": ckv_all, "krope": kr_all, "idx": idx + S}
        q_lat = jnp.einsum("bshn,rhn->bshr", q_nope, w_uk)   # [B,1,H,kvr]
        logits = (jnp.einsum("bshr,btr->bhst", q_lat, ckv_all)
                  + jnp.einsum("bshn,btn->bhst", q_rope, kr_all)
                  ).astype(jnp.float32) * scale
        kpos = jnp.arange(ckv_all.shape[1])[None, None, None, :]
        logits = jnp.where(kpos < idx + S, logits, -1e30)
        probs = jax.nn.softmax(logits, -1).astype(x.dtype)
        o_lat = jnp.einsum("bhst,btr->bshr", probs, ckv_all)  # latent out
        out = jnp.einsum("bshr,rhv->bshv", o_lat, w_uv)       # [B,1,H,vh]
        out = apply_dense(p["wo"], out.reshape(B, S, H * vh))
        return out, new_cache

    # Decompressed path (train / prefill).
    new_cache = None
    if cache is not None:
        idx = cache["idx"]
        ckv_all = jax.lax.dynamic_update_slice_in_dim(
            cache["ckv"], ckv.astype(cache["ckv"].dtype), idx, axis=1)
        kr_all = jax.lax.dynamic_update_slice_in_dim(
            cache["krope"], k_rope.astype(cache["krope"].dtype), idx, axis=1)
        new_cache = {"ckv": ckv_all, "krope": kr_all, "idx": idx + S}
    kv = jnp.einsum("btr,rhn->bthn", ckv, wkv_b)             # [B,S,H,n+v]
    k_nope, v = kv[..., :nope], kv[..., nope:]
    # Pack rope part: queries per head, key rope shared across heads.
    q_full = jnp.concatenate([q_nope, q_rope], -1)           # [B,S,H,n+r]
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (B, S, H, rope))], -1)
    # Treat H as KV groups of size 1 for the shared sdpa core.
    q5 = q_full[:, :, :, None, :]                            # [B,S,H,1,*]
    out = sdpa(q5, k_full, v, causal=True, q_offset=0)
    out = out[:, :, :, 0, :]
    out = apply_dense(p["wo"], out.reshape(B, S, H * vh))
    return out, new_cache


# ---------------------------------------------------------------------------
# MLPs and MoE
# ---------------------------------------------------------------------------


def mlp_init(key, d: int, f: int, kind: str, dtype) -> Params:
    ks = jax.random.split(key, 3)
    if kind in ("swiglu", "geglu"):
        return {"wi": dense(ks[0], d, f, dtype),
                "wg": dense(ks[1], d, f, dtype),
                "wo": dense(ks[2], f, d, dtype)}
    return {"wi": dense(ks[0], d, f, dtype), "wo": dense(ks[1], f, d, dtype)}


def mlp_apply(p: Params, x, kind: str):
    if kind == "swiglu":
        return apply_dense(
            p["wo"], jax.nn.silu(apply_dense(p["wg"], x))
            * apply_dense(p["wi"], x))
    if kind == "geglu":
        return apply_dense(
            p["wo"], jax.nn.gelu(apply_dense(p["wg"], x))
            * apply_dense(p["wi"], x))
    return apply_dense(p["wo"], jax.nn.gelu(apply_dense(p["wi"], x)))


def moe_init(key, cfg, dtype) -> Params:
    d, E, f = cfg.d_model, cfg.moe_n_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": dense(ks[0], d, E, jnp.float32),
        "wi": _dense_init(ks[1], (E, d, f), dtype),
        "wg": _dense_init(ks[2], (E, d, f), dtype),
        "wo": _dense_init(ks[3], (E, f, d), dtype),
    }
    if cfg.moe_n_shared:
        p["shared"] = mlp_init(ks[4], d, cfg.moe_d_ff * cfg.moe_n_shared,
                               "swiglu", dtype)
    return p


def moe_apply(p: Params, cfg, x):
    """Top-k MoE dispatcher. Under an active device mesh with a ``model``
    axis (the pjit path), the sort-based dispatch runs inside a local
    shard_map — tokens stay on their data shard, experts are
    expert-parallel over ``model``, and the combine is a psum (a dispatch
    tensor of global-token extent would not fit at 1M tokens x 256
    experts). Without a mesh (single-device smoke tests) the same math runs
    locally."""
    from repro.compat import ambient_mesh
    mesh = ambient_mesh()
    if mesh is not None and "model" in mesh.axis_names \
            and mesh.axis_sizes and math.prod(mesh.axis_sizes) > 1:
        return _moe_sharded(p, cfg, x, mesh)
    return _moe_local(p, cfg, x)


def _moe_sharded(p: Params, cfg, x, mesh):
    E = cfg.moe_n_experts
    T = mesh.shape["model"]
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    B = x.shape[0]
    n_b = math.prod(mesh.shape[a] for a in batch_axes) if batch_axes else 1
    bspec = P(batch_axes if B % n_b == 0 and B >= n_b else None, None, None)
    espec = {"router": jax.tree.map(lambda _: P(), p["router"]),
             "wi": P("model", None, None), "wg": P("model", None, None),
             "wo": P("model", None, None)}
    for k in ("wi_scale", "wg_scale", "wo_scale"):
        if k in p:
            espec[k] = P("model", None)
    if "shared" in p:
        espec["shared"] = jax.tree.map(lambda _: P(), p["shared"])

    from repro.compat import shard_map
    @partial(shard_map, mesh=mesh, in_specs=(espec, bspec),
             out_specs=(bspec, P()), check_vma=False)
    def run(p_loc, x_loc):
        y, aux = _moe_expert_parallel(p_loc, cfg, x_loc, axis="model",
                                      n_shards=T)
        for ax in batch_axes:
            aux = jax.lax.pmean(aux, ax)
        return y, aux

    return run(p, x)


def _expert_w(p: Params, name: str, dtype):
    w = p[name]
    if w.dtype == jnp.int8:
        return w.astype(dtype) * p[name + "_scale"][:, None, :].astype(dtype)
    return w


def _moe_expert_parallel(p: Params, cfg, x, *, axis: str, n_shards: int):
    """Sort-based dispatch over the local tokens, local experts only,
    psum-combine over the expert-parallel axis."""
    B, S, D = x.shape
    E, k = cfg.moe_n_experts, cfg.moe_top_k
    E_loc = E // n_shards
    Tk = B * S
    C = max(1, int(math.ceil(k * Tk / E * cfg.moe_capacity_factor)))
    xt = x.reshape(Tk, D)
    logits = apply_dense(p["router"], xt.astype(jnp.float32))
    gates = jax.nn.softmax(logits, -1)
    topv, topi = jax.lax.top_k(gates, k)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)
    off = jax.lax.axis_index(axis) * E_loc
    flat_e = topi.reshape(-1) - off
    flat_w = topv.reshape(-1).astype(xt.dtype)
    in_range = (flat_e >= 0) & (flat_e < E_loc)
    flat_e_c = jnp.where(in_range, flat_e, E_loc)
    order = jnp.argsort(flat_e_c)
    tok_of_slot = order // k
    counts = jax.ops.segment_sum(in_range.astype(jnp.int32), flat_e_c,
                                 num_segments=E_loc + 1)[:E_loc]
    offsets = jnp.cumsum(counts) - counts
    slot = offsets[:, None] + jnp.arange(C)[None, :]
    valid = (jnp.arange(C)[None, :] < counts[:, None]) & (slot < Tk * k)
    slot = jnp.clip(slot, 0, Tk * k - 1)
    tok_idx = tok_of_slot[slot]
    xe = jnp.take(xt, tok_idx.reshape(-1), axis=0).reshape(E_loc, C, D)
    xe = xe * valid[..., None].astype(xt.dtype)
    h = jnp.einsum("ecd,edf->ecf", xe, _expert_w(p, "wi", xe.dtype))
    g = jnp.einsum("ecd,edf->ecf", xe, _expert_w(p, "wg", xe.dtype))
    ye = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * h,
                    _expert_w(p, "wo", xe.dtype))
    w_slot = flat_w[order][slot] * valid.astype(xt.dtype)
    yt = jnp.zeros((Tk, D), xt.dtype).at[tok_idx.reshape(-1)].add(
        (ye * w_slot[..., None]).reshape(E_loc * C, D))
    y = jax.lax.psum(yt.reshape(B, S, D), axis)
    if "shared" in p:
        y = y + mlp_apply(p["shared"], x, "swiglu")
    density = jnp.mean(jax.nn.one_hot(topi, E, dtype=jnp.float32).sum(1), 0)
    router_prob = jnp.mean(gates, axis=0)
    aux = E * jnp.sum(density * router_prob)
    return y, aux.astype(jnp.float32)


def _moe_local(p: Params, cfg, x):
    """Single-shard fallback of the sort-based dispatch (smoke tests)."""
    B, S, D = x.shape
    E, k = cfg.moe_n_experts, cfg.moe_top_k
    T = B * S
    C = max(1, int(math.ceil(k * T / E * cfg.moe_capacity_factor)))
    xt = x.reshape(T, D)
    logits = apply_dense(p["router"], xt.astype(jnp.float32))
    gates = jax.nn.softmax(logits, -1)
    topv, topi = jax.lax.top_k(gates, k)                       # [T,k]
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    flat_e = topi.reshape(-1)                                  # [N], N=T*k
    flat_w = topv.reshape(-1).astype(xt.dtype)
    order = jnp.argsort(flat_e)                                # group by expert
    tok_of_slot = order // k                                   # token per slot
    counts = jax.ops.segment_sum(jnp.ones_like(flat_e), flat_e,
                                 num_segments=E)               # [E]
    offsets = jnp.cumsum(counts) - counts
    slot = offsets[:, None] + jnp.arange(C)[None, :]           # [E,C]
    valid = (jnp.arange(C)[None, :] < counts[:, None]) & (slot < T * k)
    slot = jnp.clip(slot, 0, T * k - 1)
    tok_idx = tok_of_slot[slot]                                # [E,C]
    xe = jnp.take(xt, tok_idx.reshape(-1), axis=0).reshape(E, C, D)
    xe = xe * valid[..., None].astype(xt.dtype)

    h = jnp.einsum("ecd,edf->ecf", xe, _expert_w(p, "wi", xe.dtype))
    g = jnp.einsum("ecd,edf->ecf", xe, _expert_w(p, "wg", xe.dtype))
    ye = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * h,
                    _expert_w(p, "wo", xe.dtype))

    w_slot = flat_w[order][slot] * valid.astype(xt.dtype)      # [E,C]
    yt = jnp.zeros((T, D), xt.dtype).at[tok_idx.reshape(-1)].add(
        (ye * w_slot[..., None]).reshape(E * C, D))
    y = yt.reshape(B, S, D)
    if "shared" in p:
        y = y + mlp_apply(p["shared"], x, "swiglu")
    # Load-balance auxiliary loss (Switch-style), returned for training.
    density = jnp.mean(jax.nn.one_hot(topi, E, dtype=jnp.float32).sum(1), 0)
    router_prob = jnp.mean(gates, axis=0)
    aux = E * jnp.sum(density * router_prob)
    return y, aux.astype(jnp.float32)
