from repro.models import layers, recurrent, transformer  # noqa: F401
