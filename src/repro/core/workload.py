"""Per-layer workload model.

The paper's allocator (Algorithms 1 and 2) operates on per-layer workload
numbers: MAC count ``pi_i = H*W*R*S*C*M``, weight volume, and activation row
sizes. This module provides those numbers for (a) CNN graphs exactly as the
paper defines them and (b) transformer-family graphs (the assigned
architectures), so the same allocator drives both the faithful FPGA
reproduction and the TPU-mesh port.

Conventions
-----------
* ``macs``: multiply-accumulates per *frame* (CNN) or per *token-batch unit*
  (LM; see :class:`LayerWorkload.unit`). GOP numbers in the paper count
  2 ops per MAC.
* ``weight_bytes``: bytes of parameters the layer must have resident to
  compute (at the workload's quantization width).
* All CNN spatial sizes follow the paper's Eq. (1): input is
  ``C x (H+R-1) x (W+S-1)`` (i.e. "same" padding), output ``M x H x W`` at
  stride 1; stride G divides the output size.
"""

from __future__ import annotations

import dataclasses
from typing import Literal, Sequence

# ---------------------------------------------------------------------------
# Generic layer workload record (what the allocator consumes)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LayerWorkload:
    """One pipeline-stage candidate, reduced to what Algorithms 1/2 need."""

    name: str
    macs: int                       # MACs per frame / per microbatch-token-group
    weight_bytes: int               # resident parameter bytes
    act_in_bytes: int               # activation bytes consumed per unit
    act_out_bytes: int              # activation bytes produced per unit
    kind: str = "generic"           # conv | pool | fc | attn | mlp | moe | ...
    # CNN-specific fields used by the faithful FPGA allocator. For
    # non-conv layers they keep neutral defaults (R=S=1, G=1).
    R: int = 1
    S: int = 1
    stride: int = 1
    C: int = 1                      # input channels (parallelism bound)
    M: int = 1                      # output channels (parallelism bound)
    H: int = 1                      # output rows
    W: int = 1                      # output cols

    @property
    def flops(self) -> int:
        return 2 * self.macs


# ---------------------------------------------------------------------------
# CNN graphs (paper substrate)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ConvLayer:
    name: str
    in_ch: int
    out_ch: int
    kernel: int                     # R == S (all four paper models are square)
    stride: int = 1
    kind: Literal["conv", "fc", "pool"] = "conv"
    groups: int = 1                 # AlexNet's two-tower grouped convs
    out_size: int | None = None     # explicit output H=W (valid-padding cases)

    def out_hw(self, in_hw: int) -> int:
        if self.kind == "fc":
            return 1
        if self.out_size is not None:
            return self.out_size
        return in_hw // self.stride

    def padding(self, in_hw: int) -> tuple[int, int]:
        """Explicit (lo, hi) spatial padding reproducing each model's
        published output sizes (SAME for stride-1, VALID-like for the
        stride-k stems; asymmetric when the arithmetic demands it)."""
        out_hw = self.out_hw(in_hw)
        need = max((out_hw - 1) * self.stride + self.kernel - in_hw, 0)
        lo = need // 2
        return lo, need - lo


@dataclasses.dataclass(frozen=True)
class CNNModel:
    name: str
    input_hw: int
    input_ch: int
    layers: tuple[ConvLayer, ...]

    def layer_workloads(self, weight_bits: int = 16) -> list[LayerWorkload]:
        """Expand the graph into per-layer workloads (paper's pi/omega)."""
        wb = weight_bits // 8
        out: list[LayerWorkload] = []
        hw = self.input_hw
        for lyr in self.layers:
            o_hw = lyr.out_hw(hw)
            if lyr.kind == "pool":
                # Pooling has no MACs/weights; it is a pipeline stage that
                # only shrinks H (paper folds it into the stride product G).
                out.append(
                    LayerWorkload(
                        name=lyr.name, macs=0, weight_bytes=0,
                        act_in_bytes=hw * hw * lyr.in_ch * wb,
                        act_out_bytes=o_hw * o_hw * lyr.out_ch * wb,
                        kind="pool", R=lyr.kernel, S=lyr.kernel,
                        stride=lyr.stride, C=lyr.in_ch, M=lyr.out_ch,
                        H=o_hw, W=o_hw,
                    )
                )
            else:
                if lyr.kind == "fc":
                    h = w = 1
                    r = s = 1
                    macs = lyr.in_ch * lyr.out_ch
                    wbytes = lyr.in_ch * lyr.out_ch * wb
                    cin = lyr.in_ch
                else:
                    h = w = o_hw
                    r = s = lyr.kernel
                    cin_g = lyr.in_ch // lyr.groups
                    macs = h * w * r * s * cin_g * lyr.out_ch
                    wbytes = r * s * cin_g * lyr.out_ch * wb
                    cin = lyr.in_ch
                out.append(
                    LayerWorkload(
                        name=lyr.name, macs=macs, weight_bytes=wbytes,
                        act_in_bytes=hw * hw * cin * wb,
                        act_out_bytes=h * w * lyr.out_ch * wb,
                        kind=lyr.kind, R=r, S=s, stride=lyr.stride,
                        C=cin if lyr.kind == "fc" else lyr.in_ch // lyr.groups,
                        M=lyr.out_ch, H=h, W=w,
                    )
                )
            hw = o_hw
        return out

    @property
    def gop(self) -> float:
        """Model complexity in GOP (2 ops / MAC), as quoted by the paper."""
        return 2 * sum(l.macs for l in self.layer_workloads()) / 1e9


def _vgg_block(idx: int, n: int, cin: int, cout: int) -> list[ConvLayer]:
    ls = [ConvLayer(f"conv{idx}_{i+1}", cin if i == 0 else cout, cout, 3)
          for i in range(n)]
    ls.append(ConvLayer(f"pool{idx}", cout, cout, 2, stride=2, kind="pool"))
    return ls


def vgg16() -> CNNModel:
    layers: list[ConvLayer] = []
    layers += _vgg_block(1, 2, 3, 64)
    layers += _vgg_block(2, 2, 64, 128)
    layers += _vgg_block(3, 3, 128, 256)
    layers += _vgg_block(4, 3, 256, 512)
    layers += _vgg_block(5, 3, 512, 512)
    layers += [
        ConvLayer("fc6", 512 * 7 * 7, 4096, 1, kind="fc"),
        ConvLayer("fc7", 4096, 4096, 1, kind="fc"),
        ConvLayer("fc8", 4096, 1000, 1, kind="fc"),
    ]
    return CNNModel("vgg16", 224, 3, tuple(layers))


def alexnet() -> CNNModel:
    # Canonical two-tower AlexNet (grouped conv2/4/5). 1.45 GOP — matches
    # the paper's quoted complexity.
    layers = (
        ConvLayer("conv1", 3, 96, 11, stride=4, out_size=55),
        ConvLayer("pool1", 96, 96, 3, stride=2, kind="pool", out_size=27),
        ConvLayer("conv2", 96, 256, 5, groups=2, out_size=27),
        ConvLayer("pool2", 256, 256, 3, stride=2, kind="pool", out_size=13),
        ConvLayer("conv3", 256, 384, 3, out_size=13),
        ConvLayer("conv4", 384, 384, 3, groups=2, out_size=13),
        ConvLayer("conv5", 384, 256, 3, groups=2, out_size=13),
        ConvLayer("pool5", 256, 256, 3, stride=2, kind="pool", out_size=6),
        ConvLayer("fc6", 256 * 6 * 6, 4096, 1, kind="fc"),
        ConvLayer("fc7", 4096, 4096, 1, kind="fc"),
        ConvLayer("fc8", 4096, 1000, 1, kind="fc"),
    )
    return CNNModel("alexnet", 227, 3, layers)


def zfnet() -> CNNModel:
    # ZF-Net (Zeiler & Fergus). 2.33 GOP — paper quotes 2.34.
    layers = (
        ConvLayer("conv1", 3, 96, 7, stride=2, out_size=110),
        ConvLayer("pool1", 96, 96, 3, stride=2, kind="pool", out_size=55),
        ConvLayer("conv2", 96, 256, 5, stride=2, out_size=26),
        ConvLayer("pool2", 256, 256, 3, stride=2, kind="pool", out_size=13),
        ConvLayer("conv3", 256, 384, 3, out_size=13),
        ConvLayer("conv4", 384, 384, 3, out_size=13),
        ConvLayer("conv5", 384, 256, 3, out_size=13),
        ConvLayer("pool5", 256, 256, 3, stride=2, kind="pool", out_size=6),
        ConvLayer("fc6", 256 * 6 * 6, 4096, 1, kind="fc"),
        ConvLayer("fc7", 4096, 4096, 1, kind="fc"),
        ConvLayer("fc8", 4096, 1000, 1, kind="fc"),
    )
    return CNNModel("zf", 224, 3, layers)


def yolo() -> CNNModel:
    # YOLOv1-style 24-conv detector (448x448). Paper quotes 40.14 GOP.
    L = ConvLayer
    layers = [
        L("conv1", 3, 64, 7, stride=2),
        L("pool1", 64, 64, 2, stride=2, kind="pool"),
        L("conv2", 64, 192, 3),
        L("pool2", 192, 192, 2, stride=2, kind="pool"),
        L("conv3", 192, 128, 1),
        L("conv4", 128, 256, 3),
        L("conv5", 256, 256, 1),
        L("conv6", 256, 512, 3),
        L("pool6", 512, 512, 2, stride=2, kind="pool"),
    ]
    for i in range(4):
        layers += [L(f"conv{7+2*i}", 512, 256, 1), L(f"conv{8+2*i}", 256, 512, 3)]
    layers += [
        L("conv15", 512, 512, 1),
        L("conv16", 512, 1024, 3),
        L("pool16", 1024, 1024, 2, stride=2, kind="pool"),
        L("conv17", 1024, 512, 1),
        L("conv18", 512, 1024, 3),
        L("conv19", 1024, 512, 1),
        L("conv20", 512, 1024, 3),
        L("conv21", 1024, 1024, 3),
        L("conv22", 1024, 1024, 3, stride=2),
        L("conv23", 1024, 1024, 3),
        L("conv24", 1024, 1024, 3),
        L("fc25", 1024 * 7 * 7, 4096, 1, kind="fc"),
        L("fc26", 4096, 7 * 7 * 30, 1, kind="fc"),
    ]
    return CNNModel("yolo", 448, 3, tuple(layers))


CNN_MODELS = {"vgg16": vgg16, "alexnet": alexnet, "zf": zfnet, "yolo": yolo}


# ---------------------------------------------------------------------------
# Transformer-family workloads (assigned architectures)
# ---------------------------------------------------------------------------


def lm_layer_workloads(
    cfg,
    *,
    seq_len: int,
    batch: int,
    mode: Literal["train", "prefill", "decode"] = "train",
    dtype_bytes: int = 2,
) -> list[LayerWorkload]:
    """Per-layer workload for a transformer config (see configs/base.py).

    ``macs`` counts the forward pass per global step (train multiplies by 3
    inside the allocator's time model, not here). ``decode`` counts one new
    token against a ``seq_len`` KV cache.
    """
    d = cfg.d_model
    toks = batch * (1 if mode == "decode" else seq_len)
    kv_len = seq_len
    n_ffn_mats = 3 if cfg.mlp_kind in ("swiglu", "geglu") else 2
    out: list[LayerWorkload] = []

    emb_bytes = cfg.vocab * d * dtype_bytes
    out.append(LayerWorkload(
        name="embed", macs=0, weight_bytes=emb_bytes,
        act_in_bytes=toks * 4, act_out_bytes=toks * d * dtype_bytes,
        kind="embed", C=d, M=d))

    # Encoder layers (enc-dec archs): bidirectional attn + mlp, processing
    # the encoder sequence (same length by our shape convention).
    for i in range(cfg.n_enc_layers or 0):
        dh = cfg.head_dim
        w_attn = (d * cfg.n_heads * dh + 2 * d * cfg.n_kv_heads * dh
                  + cfg.n_heads * dh * d)
        w_ffn = n_ffn_mats * d * cfg.d_ff
        enc_toks = batch * seq_len if mode != "decode" else batch
        macs = enc_toks * (w_attn + w_ffn) \
            + enc_toks * kv_len * cfg.n_heads * dh * 2
        out.append(LayerWorkload(
            name=f"enc{i}", macs=macs,
            weight_bytes=(w_attn + w_ffn) * dtype_bytes,
            act_in_bytes=enc_toks * d * dtype_bytes,
            act_out_bytes=enc_toks * d * dtype_bytes,
            kind="enc", C=d, M=d, H=seq_len, W=batch))

    for i in range(cfg.n_layers):
        blk = cfg.block_kind(i)  # "attn" | "rglru" | "rwkv" | "moe" | ...
        macs = 0
        wbytes = 0
        if blk in ("attn", "attn_local", "moe", "mla", "mla_moe"):
            if blk.startswith("mla"):
                # MLA: q/kv low-rank projections + score/av + out proj.
                q_rank = getattr(cfg, "q_lora_rank", 0) or d
                kv_rank = getattr(cfg, "kv_lora_rank", 512)
                dh = cfg.head_dim
                rope_dim = getattr(cfg, "rope_head_dim", 64)
                nh = cfg.n_heads
                w_attn = (d * q_rank + q_rank * nh * (dh + rope_dim)
                          + d * (kv_rank + rope_dim)
                          + kv_rank * nh * (dh + dh)
                          + nh * dh * d)
            else:
                dh = cfg.head_dim
                w_attn = (d * cfg.n_heads * dh
                          + 2 * d * cfg.n_kv_heads * dh
                          + cfg.n_heads * dh * d)
            ctx = min(kv_len, getattr(cfg, "window", None) or kv_len) \
                if blk == "attn_local" else kv_len
            score_macs = toks * ctx * cfg.n_heads * cfg.head_dim * 2
            if cfg.n_enc_layers:   # enc-dec decoder: + cross-attention
                w_attn *= 2
                score_macs *= 2
            macs += toks * w_attn + score_macs
            wbytes += w_attn * dtype_bytes
        if blk in ("rglru",):
            # Griffin block: wx, wy, wo (3 d x dr) + 2 recurrence gates
            # (2 dr^2); the recurrence itself is elementwise.
            dr = cfg.lru_width or d
            w_rec = 3 * d * dr + 2 * dr * dr
            macs += toks * w_rec
            wbytes += w_rec * dtype_bytes
        if blk in ("rwkv",):
            # RWKV6 time-mix: r,k,v,g,o projections (5 d^2) + decay lora.
            w_rec = 5 * d * d
            macs += toks * w_rec
            wbytes += w_rec * dtype_bytes
        # FFN part
        if blk.endswith("moe"):
            n_act = cfg.moe_top_k + cfg.moe_n_shared
            w_ffn_tot = (cfg.moe_n_experts + cfg.moe_n_shared) * 3 * d * cfg.moe_d_ff
            macs += toks * n_act * 3 * d * cfg.moe_d_ff
            wbytes += w_ffn_tot * dtype_bytes
        elif blk == "rwkv":
            # channel mix: cm_wr (d^2) + cm_wk (d x ff) + cm_wv (ff x d)
            w_ffn = d * d + 2 * d * cfg.d_ff
            macs += toks * w_ffn
            wbytes += w_ffn * dtype_bytes
        else:
            macs += toks * n_ffn_mats * d * cfg.d_ff
            wbytes += n_ffn_mats * d * cfg.d_ff * dtype_bytes
        out.append(LayerWorkload(
            name=f"layer{i}", macs=macs, weight_bytes=wbytes,
            act_in_bytes=toks * d * dtype_bytes,
            act_out_bytes=toks * d * dtype_bytes,
            kind=blk, C=d, M=d, H=seq_len, W=batch))

    out.append(LayerWorkload(
        name="lm_head", macs=toks * d * cfg.vocab,
        # tied embeddings: the head reuses the embedding bytes (already
        # counted), but its MACs still happen.
        weight_bytes=(0 if cfg.tie_embeddings
                      else cfg.vocab * d * dtype_bytes),
        act_in_bytes=toks * d * dtype_bytes,
        act_out_bytes=toks * cfg.vocab * dtype_bytes,
        kind="head", C=d, M=cfg.vocab))
    return out


def total_params(layers: Sequence[LayerWorkload], dtype_bytes: int = 2) -> int:
    return sum(l.weight_bytes for l in layers) // dtype_bytes


def model_flops(layers: Sequence[LayerWorkload], train: bool) -> int:
    """MODEL_FLOPS = 6*N*D-style useful flops (fwd 2x, train 6x per MAC)."""
    fwd = 2 * sum(l.macs for l in layers)
    return 3 * fwd if train else fwd
