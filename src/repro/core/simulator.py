"""Cycle-accurate pipeline simulator.

Validates the closed-form throughput model (Eqs. 2-4) by simulating the
layer-wise pipeline at row-group granularity: engine i may compute its r-th
output-row group only when (a) the producer has delivered the input rows its
receptive field needs and (b) its own previous group is done. The steady
state must match ``H_0 * T_rowmax``; the simulator additionally exposes the
fill/drain latency and per-engine idle cycles (the quantity the paper's
DSP-efficiency metric penalizes).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

from repro.core.allocator import LayerAlloc


@dataclasses.dataclass(frozen=True)
class SimResult:
    frame_cycles: float           # makespan for one frame (incl. fill)
    steady_cycles: float          # asymptotic per-frame cycles (pipelined)
    engine_busy: tuple[float, ...]
    engine_idle_frac: tuple[float, ...]
    dsp_efficiency: float         # busy MACs / (theta_total * makespan)


def simulate(allocs: Sequence[LayerAlloc], n_frames: int = 2) -> SimResult:
    """Event-driven simulation over ``n_frames`` consecutive frames.

    Accepts either a sequence of :class:`LayerAlloc` or any object exposing
    an ``allocs`` attribute (e.g. :class:`repro.core.program.EngineProgram`),
    so the simulator consumes the same compiled plan as the executor.

    Returns per-frame steady-state cycles measured between the completion of
    consecutive frames, which is what Eq. (4) predicts.
    """
    allocs = getattr(allocs, "allocs", allocs)
    engines = [a for a in allocs if a.layer.macs > 0]
    n = len(engines)

    # ready[i][g] = cycle when group g of engine i's output exists.
    finish: list[list[float]] = []
    frame_done: list[float] = []

    for i, a in enumerate(engines):
        l = a.layer
        groups = max(1, math.ceil(l.H / max(1, a.K))) if l.kind == "conv" else 1
        finish.append([0.0] * (groups * n_frames))
    busy_acc = [0.0] * n

    for f in range(n_frames):
        for i, a in enumerate(engines):
            l = a.layer
            if l.kind == "conv":
                groups = max(1, math.ceil(l.H / max(1, a.K)))
            else:
                groups = 1
            base = f * groups
            for g in range(groups):
                # Input dependency: which producer group covers the rows this
                # group's receptive field needs?
                if i == 0:
                    t_dep = 0.0  # frame f input fully available at cycle ~0
                else:
                    p = engines[i - 1]
                    pl = p.layer
                    pgroups = (max(1, math.ceil(pl.H / max(1, p.K)))
                               if pl.kind == "conv" else 1)
                    if l.kind == "fc":
                        need = pgroups - 1          # whole feature map
                    else:
                        # Output rows [g*K, (g+1)*K) need input rows up to
                        # (g+1)*K*G + R - 1 from the producer.
                        last_in_row = min(
                            pl.H - 1,
                            ((g + 1) * max(1, a.K)) * max(1, l.stride) + l.R - 2)
                        need = min(pgroups - 1,
                                   last_in_row // max(1, p.K))
                    t_dep = finish[i - 1][f * pgroups + need]
                t_self = finish[i][base + g - 1] if (g > 0 or f > 0) else 0.0
                if g == 0 and f > 0:
                    t_self = finish[i][base - 1]
                if l.kind == "conv":
                    # The last row-group of a frame may cover fewer than K
                    # output rows (H % K != 0); charge only its actual rows.
                    rows = min(max(1, a.K), l.H - g * max(1, a.K))
                    dur = rows * a.t_per_output_row
                else:
                    dur = a.t_row
                busy_acc[i] += dur
                finish[i][base + g] = max(t_dep, t_self) + dur
        frame_done.append(finish[-1][(f + 1) * len(finish[-1]) // n_frames - 1])

    makespan = frame_done[0]
    steady = (frame_done[-1] - frame_done[0]) / (n_frames - 1) \
        if n_frames > 1 else makespan

    total_span = frame_done[-1]
    busy = tuple(busy_acc)
    idle = tuple(1.0 - min(1.0, b / total_span) for b in busy)
    theta_total = sum(a.theta for a in engines)
    # steady-state efficiency (per-frame rate once the pipe is full);
    # the fill/drain latency is reported separately via frame_cycles.
    per_frame = steady if n_frames > 1 else makespan
    total_macs = sum(a.layer.macs for a in engines)
    eff = total_macs / (theta_total * per_frame) if theta_total else 0.0
    return SimResult(
        frame_cycles=makespan,
        steady_cycles=steady,
        engine_busy=busy,
        engine_idle_frac=idle,
        dsp_efficiency=min(1.0, eff),
    )
