"""Streaming executor over a compiled :class:`EngineProgram`.

The paper's engines overlap three things per pipeline stage: reading the
next activation rows into one half of the line buffer, computing on the
other half, and draining finished outputs (activation-buffer double
buffering, Fig. 2). :class:`EngineExecutor` is the software analogue on a
frame stream:

* ``submit(frame)`` micro-batches incoming frames to ``batch_size``;
* a full micro-batch is quantized to int8 on the *host* and dispatched to
  the jitted chain — JAX dispatch is async, so the device computes batch
  ``k`` while the host quantizes batch ``k+1`` and argmax-decodes batch
  ``k-1`` (the two "buffer halves" are the bounded in-flight queue);
* ``drain()`` flushes the partial tail batch (padded to the compiled
  shape so the runner never recompiles) and collects all results.

Results are per-frame class ids (``top1``) or float logits; padding
frames are dropped on the way out.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Callable, Iterable

import jax
import numpy as np

from repro.core.program import CompiledRunner, EngineProgram

# In-flight micro-batches. Two mirrors the paper's double-buffered
# activation memory: one batch computing on-device, one being staged
# host-side; a deeper queue only adds memory, not throughput.
DEFAULT_MAX_INFLIGHT = 2


def normalize_frames(program: EngineProgram,
                     frame: np.ndarray) -> np.ndarray:
    """Accept one ``[H, W, C]`` frame or a pre-batched ``[N, H, W, C]``
    chunk, validate it against ``program``'s input spec, and return the
    ``[N, H, W, C]`` form — the submit()-side twin of
    :func:`pad_micro_batch`, shared by both executors."""
    frame = np.asarray(frame)
    if frame.ndim == 3:
        frames = frame[None]
    elif frame.ndim == 4:
        frames = frame
    else:
        raise ValueError(f"expected [H,W,C] or [N,H,W,C], got "
                         f"{frame.shape}")
    hw = program.model.input_hw
    if frames.shape[1:] != (hw, hw, program.model.input_ch):
        raise ValueError(
            f"frame shape {frames.shape[1:]} does not match the "
            f"compiled program ({hw}, {hw}, {program.model.input_ch})")
    return frames


def pad_micro_batch(program: EngineProgram, frames: np.ndarray,
                    batch_size: int) -> np.ndarray:
    """Validate a ``[B, H, W, C]`` micro-batch against ``program``'s input
    spec and zero-pad it to ``batch_size`` (the fixed compiled shape) —
    the one batch-shaping rule both the single-jit and the pipelined
    executor share."""
    frames = np.asarray(frames)
    hw = program.model.input_hw
    if frames.ndim != 4 or frames.shape[1:] != (hw, hw,
                                                program.model.input_ch):
        raise ValueError(
            f"micro-batch shape {frames.shape} does not match the "
            f"compiled program [B, {hw}, {hw}, {program.model.input_ch}]")
    if len(frames) > batch_size:
        raise ValueError(f"micro-batch of {len(frames)} exceeds the "
                         f"compiled batch size {batch_size}")
    if len(frames) < batch_size:
        pad = np.zeros((batch_size - len(frames),) + frames.shape[1:],
                       frames.dtype)
        frames = np.concatenate([frames, pad], axis=0)
    return frames


@dataclasses.dataclass
class ServeStats:
    """Steady-state accounting for one serve run."""

    frames: int = 0
    batches: int = 0
    padded_frames: int = 0
    wall_s: float = 0.0          # active serving time (idle between
    first_batch_s: float = 0.0   # drains excluded); first dispatch is
    # charged to first_batch_s (jit compile) and excluded from fps.

    @property
    def steady_fps(self) -> float:
        """Frames/s excluding the first dispatch (compile + warmup) —
        the analogue of the pipeline's steady-state rate, which is what
        Algorithm 1's model predicts. Returns 0.0 when every frame landed
        in that first batch (stream <= one micro-batch): there is no
        steady-state window to measure, not a measured rate of zero."""
        steady_wall = self.wall_s - self.first_batch_s
        steady_frames = self.frames - min(self.frames, self._first_n)
        if steady_wall <= 0 or steady_frames <= 0:
            return 0.0
        return steady_frames / steady_wall

    _first_n: int = 0


class EngineExecutor:
    """Micro-batching serve loop over one jitted engine chain.

    >>> ex = EngineExecutor(program, batch_size=32)
    >>> for frame in frames:
    ...     ex.submit(frame)            # [H, W, C] float
    >>> ids = ex.drain()                # per-frame top-1 class ids
    >>> ex.stats.steady_fps
    """

    def __init__(self, program: EngineProgram, *, batch_size: int = 32,
                 route: str | None = None, interpret: bool | None = None,
                 donate: bool | None = None, output: str = "top1",
                 max_inflight: int = DEFAULT_MAX_INFLIGHT,
                 on_result: Callable[[object, np.ndarray], None] | None = None):
        if output not in ("top1", "logits"):
            raise ValueError(f"unknown output {output!r}")
        self.program = program
        self.batch_size = int(batch_size)
        self.output = output
        self.on_result = on_result
        # Protocol slot only: this executor raises synchronously from
        # submit_batch / flush_inflight, so the callback is never fired.
        self.on_error: Callable[[object, BaseException], None] | None = None
        self.runner: CompiledRunner = program.compile_runner(
            route=route, interpret=interpret, donate=donate)
        self.stats = ServeStats()
        self.stats._first_n = self.batch_size
        # One lock serializes the pending micro-batch, the in-flight
        # queue, and stats, so multiple producer threads (the async
        # frontend's batcher plus direct callers) can feed one executor
        # without corrupting the tail-padding path. Re-entrant because
        # _dispatch collects under the same lock when back-pressured.
        self._lock = threading.RLock()
        self._pending: list[np.ndarray] = []
        self._inflight: collections.deque = collections.deque()
        self._max_inflight = max(1, int(max_inflight))
        self._results: list[np.ndarray] = []
        self._t0: float | None = None

    # -- intake --------------------------------------------------------------

    def submit(self, frame: np.ndarray) -> None:
        """Queue one float frame ``[H, W, C]`` (or a pre-batched
        ``[N, H, W, C]`` chunk); dispatches whenever ``batch_size``
        frames are buffered."""
        frames = normalize_frames(self.program, frame)
        with self._lock:
            for f in frames:
                self._pending.append(f)
                if len(self._pending) >= self.batch_size:
                    self._dispatch(self._pending[:self.batch_size])
                    self._pending = self._pending[self.batch_size:]

    def submit_batch(self, frames: np.ndarray, n_valid: int,
                     tag: object = None) -> None:
        """Dispatch one pre-assembled micro-batch ``[B, H, W, C]``
        directly (padded with zero frames to the compiled batch size if
        short), bypassing the pending buffer — the entry point the async
        frontend's batcher uses. ``tag`` is handed to ``on_result``
        with this batch's outputs. Thread-safe; blocks when
        ``max_inflight`` batches are already on device."""
        batch = pad_micro_batch(self.program, frames, self.batch_size)
        with self._lock:
            self._dispatch(batch, n_valid=n_valid, tag=tag)

    def flush_inflight(self) -> None:
        """Collect every dispatched micro-batch (delivering their
        ``on_result`` callbacks) without flushing the pending tail."""
        with self._lock:
            while self._inflight:
                self._collect_one()

    def serve(self, frames: Iterable[np.ndarray]) -> list[np.ndarray]:
        """Convenience: submit a finite stream and drain."""
        for f in frames:
            self.submit(f)
        return self.drain()

    def reset_stats(self) -> None:
        """Zero the serve statistics (between drains, not mid-stream:
        with batches still in flight the window split would be
        meaningless)."""
        with self._lock:
            if self._inflight or self._pending:
                raise RuntimeError("reset_stats with work in flight")
            self.stats = ServeStats()
            self.stats._first_n = self.batch_size
            self._t0 = None

    def replica_counts(self) -> list | None:
        """Protocol conformance: a single jitted chain is not a replica
        fleet."""
        return None

    # -- the overlap core ----------------------------------------------------

    def _dispatch(self, frames, n_valid: int | None = None,
                  tag: object = None):
        """Host quantize-in + async device dispatch of one micro-batch
        (a list of frames from the pending buffer, or an already-stacked
        ``[B, H, W, C]`` array — no re-stacking copy on that path).
        Blocks only when ``max_inflight`` batches are already on device
        (the double-buffer back-pressure). Caller holds the lock."""
        if self._t0 is None:
            self._t0 = time.perf_counter()
        while len(self._inflight) >= self._max_inflight:
            self._collect_one()
        n = n_valid if n_valid is not None else len(frames)
        batch = (frames if isinstance(frames, np.ndarray)
                 else np.stack(frames))
        xq = self.runner.quantize(batch)
        t0 = time.perf_counter()
        acc = self.runner(xq)          # async: returns a device future
        if self.stats.batches == 0:
            # First dispatch traces + compiles the whole chain; charge it
            # separately so steady_fps reflects the pipeline, not the jit.
            jax.block_until_ready(acc)
            self.stats.first_batch_s = time.perf_counter() - t0
        self._inflight.append((acc, n, tag))
        self.stats.batches += 1
        self.stats.frames += n
        self.stats.padded_frames += len(frames) - n

    def _collect_one(self) -> None:
        """Fetch the oldest in-flight batch and argmax/dequant it on the
        host — this runs while newer batches compute on device. Tagged
        batches go to ``on_result``; untagged accumulate for drain()."""
        acc, n, tag = self._inflight.popleft()
        out = self.runner.dequantize(acc)[:n]
        if self.output == "top1":
            out = np.argmax(out.reshape(n, -1), axis=-1)
        if tag is not None and self.on_result is not None:
            self.on_result(tag, out)
        else:
            self._results.append(out)

    # -- drain ---------------------------------------------------------------

    def drain(self) -> list[np.ndarray]:
        """Flush the partial tail (padded to the compiled batch shape so
        the jitted chain never recompiles), collect everything, and
        return per-frame outputs in submission order. Thread-safe."""
        with self._lock:
            if self._pending:
                tail = self._pending
                self._pending = []
                n = len(tail)
                pad = [np.zeros_like(tail[0])] * (self.batch_size - n)
                self._dispatch(tail + pad, n_valid=n)
            while self._inflight:
                self._collect_one()
            if self._t0 is not None:
                # Accumulate only the active window; a later submit()
                # opens a fresh one, so host idle between drains never
                # counts.
                self.stats.wall_s += time.perf_counter() - self._t0
                self._t0 = None
            results = self._results
            self._results = []
        if not results:
            return []
        flat = np.concatenate(results, axis=0)
        return list(flat)
