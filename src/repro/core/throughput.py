"""Analytic throughput model — paper Eqs. (2), (3), (4) and DSP efficiency.

The pipeline advances in row-groups: engine i needs ``T_row_i`` cycles
(Eq. 2) per K_i of its output rows. One output row of layer i corresponds to
``prod(G_j, j <= i)`` input rows, so normalizing every engine's time to
*input rows* gives Eq. (3)'s ``T_rowmax``, and a frame of H_0 input rows
takes ``H_0 * T_rowmax`` cycles (Eq. 4).
"""

from __future__ import annotations

import math
from typing import Sequence

from repro.core.allocator import LayerAlloc


def cumulative_strides(allocs: Sequence[LayerAlloc]) -> list[int]:
    """prod(G_j, j <= i): how many input-image rows map to one output row of
    layer i. Pooling layers contribute their stride too (paper Eq. 3)."""
    out: list[int] = []
    g = 1
    for a in allocs:
        g *= max(1, a.layer.stride)
        out.append(g)
    return out


def t_rowmax(allocs: Sequence[LayerAlloc]) -> float:
    """Eq. (3): slowest engine's cycles per *input row* of the frame."""
    gs = cumulative_strides(allocs)
    worst = 0.0
    for a, g in zip(allocs, gs):
        if a.layer.macs == 0:
            continue
        if a.layer.kind == "fc":
            # FC layers run once per frame; amortize over all input rows.
            continue
        worst = max(worst, a.t_per_output_row / g)
    return worst


def frame_cycles(allocs: Sequence[LayerAlloc], h0: int | None = None) -> float:
    """Steady-state cycles per frame.

    Eq. (4) writes H_0 * T_rowmax with T_rowmax stride-normalized (Eq. 3);
    when valid-padding makes H_i < H_0/prod(G), the engine is only busy for
    its actual H_i output rows, so the exact steady-state bound is the
    slowest engine's *busy* cycles per frame, H_i * t_row/K. The two agree
    exactly for same-padded stride pyramids (e.g. VGG16).
    """
    del h0
    conv_cycles = max((a.layer.H * a.t_per_output_row for a in allocs
                       if a.layer.kind == "conv"), default=0.0)
    # Each FC engine is its own pipeline stage overlapping other frames; the
    # frame rate is bounded by the slowest single engine, not their sum.
    fc_cycles = max((a.t_row for a in allocs if a.layer.kind == "fc"),
                    default=0.0)
    return max(conv_cycles, fc_cycles)


def pipeline_fps(allocs: Sequence[LayerAlloc], *, freq_hz: float,
                 h0: int | None = None) -> float:
    """Eq. (4): throughput in frames/sec."""
    return freq_hz / frame_cycles(allocs, h0)


def gops(allocs: Sequence[LayerAlloc], *, freq_hz: float,
         h0: int | None = None) -> float:
    total_macs = sum(a.layer.macs for a in allocs)
    return 2 * total_macs * pipeline_fps(allocs, freq_hz=freq_hz, h0=h0) / 1e9


def dsp_efficiency(allocs: Sequence[LayerAlloc], *, macs_per_dsp: int = 1,
                   h0: int | None = None) -> float:
    """Busy-MAC fraction: useful MACs / (DSPs * frame cycles * macs_per_dsp).

    This is the paper's "DSP Efficiency" row in Table I; ``macs_per_dsp=2``
    models the 8-bit double-pumped DSP48E1.
    """
    dsps = dsps_used(allocs, macs_per_dsp=macs_per_dsp)
    if dsps == 0:
        return 0.0
    total_macs = sum(a.layer.macs for a in allocs)
    return total_macs / (dsps * macs_per_dsp * frame_cycles(allocs, h0))


def dsps_used(allocs: Sequence[LayerAlloc], *, macs_per_dsp: int = 1) -> int:
    return sum(math.ceil(a.theta / macs_per_dsp) for a in allocs)
