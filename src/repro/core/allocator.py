"""Resource-allocation framework (paper Section 4, Algorithms 1 and 2).

Two modes:

* **FPGA mode** (faithful reproduction): allocate Θ DSP multipliers across
  conv-layer engines (Algorithm 1) and BRAM/DDR bandwidth via row
  parallelism K (Algorithm 2), exactly as the paper's pseudo-code.
* **Mesh mode** (TPU port): the same objective — balance per-stage time to
  maximize utilization — applied to a pod's ``model`` mesh axis: factor it
  into ``stage x tensor``, assign layers to stages (contiguous partition that
  minimizes the slowest stage = the paper's T_rowmax), and choose the
  microbatch granularity (the K analogue) so weight streaming stays under
  the HBM-bandwidth roof subject to the HBM-capacity roof.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

from repro.core.workload import LayerWorkload

# ---------------------------------------------------------------------------
# Algorithm 1 — computation resources (faithful)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class LayerAlloc:
    layer: LayerWorkload
    theta: int          # multipliers assigned (= C' * M' * R * S)
    Cp: int             # input-channel parallelism C'
    Mp: int             # output-channel parallelism M'
    K: int = 1          # row parallelism (Algorithm 2)
    weights_resident: bool = False   # full weight set pinned in BRAM
    cycle_model: str = "packed"   # see engine_cycles()

    @property
    def t_row(self) -> float:
        """Eq. (2): cycles for this engine to produce K output rows."""
        l = self.layer
        if l.macs == 0:
            return 0.0
        if self.cycle_model == "packed":
            pe = max(1, self.Cp * self.Mp)
            if l.kind == "fc":
                return float(math.ceil(l.C * l.M / pe))
            return float(self.K * math.ceil(l.W * l.C * l.M / pe))
        return (self.K * l.W * math.ceil(l.C / self.Cp)
                * math.ceil(l.M / self.Mp))

    @property
    def t_per_output_row(self) -> float:
        """Cycles per single output row of this layer."""
        return self.t_row / max(self.K, 1)


def _decompose_theta(theta_pe: int, C: int, M: int,
                     cycle_model: str = "packed") -> tuple[int, int]:
    """Split ``theta_pe`` (= theta/(R*S)) into (C', M') — line 9 of Alg. 1.

    The paper's flexible activation buffer removes the power-of-two and
    producer/consumer-matching constraints, so any (C', M') with
    ``C'*M' <= theta_pe`` is legal — the pair need not factor theta_pe
    exactly (non-divisor budgets would otherwise clamp out of bounds; the
    old divisor-only fallback could do exactly that).

    Under the packed cycle model a row costs ``ceil(W*C*M / (C'*M'))``, so
    the best split maximizes the PE product; under the strict ceil model it
    minimizes ``ceil(C/C')*ceil(M/M')`` and, on ties, the PE count (fewer
    multipliers for the same cycles = strictly better DSP efficiency).
    Always returns ``1 <= C' <= C``, ``1 <= M' <= M``, ``C'*M' <= theta_pe``.
    """
    t = max(1, theta_pe)
    if t >= C * M:
        return C, M
    best: tuple[int, int] | None = None
    best_key: tuple | None = None
    for cp in range(1, min(C, t) + 1):
        mp = min(M, t // cp)
        if cycle_model == "packed":
            key = (-(cp * mp), abs(cp - mp))
        else:
            # Same ceil(M/mp) is reachable with the minimal mp in its
            # block — shrink so equal-cycle splits spend fewer PEs.
            mp = math.ceil(M / math.ceil(M / mp))
            key = (math.ceil(C / cp) * math.ceil(M / mp), cp * mp,
                   abs(cp - mp))
        if best_key is None or key < best_key:
            best, best_key = (cp, mp), key
    assert best is not None
    return best


def engine_cycles(l: LayerWorkload, theta: int,
                  cycle_model: str = "packed") -> float:
    """Engine-busy cycles per frame for a given multiplier budget.

    ``cycle_model="packed"`` (default, paper-faithful): the flexible
    activation buffer's address generator packs partial channel groups
    across the row, so a row of W output pixels costs
    ``ceil(W*C*M / PE)`` group-cycles — quantization loss is one cycle per
    row. This is the model under which the paper's reported 96-98% DSP
    efficiencies are achievable at all; strict per-group scheduling caps
    VGG16 below 93% for any allocation (we verified by exhaustive
    waterfilling), so the paper's numbers imply packing.

    ``cycle_model="ceil"``: strict per-group scheduling,
    ``W * ceil(C/C') * ceil(M/M')`` per row at the best decomposition —
    what an inflexible buffer (e.g. DNNBuilder's, with its pow2 and
    producer=consumer parallelism constraints) is limited to.
    """
    pe = max(1, theta // (l.R * l.S))
    if cycle_model == "packed":
        work = l.C * l.M  # group-cycles per output pixel * PE
        if l.kind == "fc":
            return float(math.ceil(work / pe))
        return float(l.H * math.ceil(l.W * work / pe))
    cp, mp = _decompose_theta(pe, l.C, l.M, cycle_model="ceil")
    cycles = math.ceil(l.C / cp) * math.ceil(l.M / mp)
    if l.kind == "fc":
        return float(cycles)
    return float(l.H * l.W * cycles)


def _ceil_blocks(n: int) -> list[int]:
    """Distinct values of ceil(n/k) for k in 1..n, in O(sqrt n)."""
    if n <= 1:
        return [max(1, n)]
    vals = set()
    m = n - 1
    i = 1
    while i <= m:
        q = m // i
        vals.add(q + 1)
        i = m // q + 1
    vals.add(1)
    return sorted(vals)


def _theta_min_for_bound(l: LayerWorkload, bound: float,
                         cycle_model: str = "packed") -> int | None:
    """Min theta such that engine_cycles(l, theta) <= bound, or None."""
    if cycle_model == "packed":
        if l.kind == "fc":
            rows, work = 1, l.C * l.M
        else:
            rows, work = l.H, l.W * l.C * l.M
        per_row = int(bound // rows)
        if per_row < 1:
            return None
        pe = min(l.C * l.M, math.ceil(work / per_row))
        if math.ceil(work / pe) > per_row:
            return None
        return pe * l.R * l.S
    per_px = bound if l.kind == "fc" else bound / (l.H * l.W)
    if per_px < 1.0:
        return None
    best: int | None = None
    for a in _ceil_blocks(l.C):            # a = ceil(C / C') candidate
        cp = math.ceil(l.C / a)
        a_eff = math.ceil(l.C / cp)
        b_max = int(per_px // a_eff)
        if b_max < 1:
            continue
        mp = min(l.M, math.ceil(l.M / b_max))
        pe = cp * mp
        if math.ceil(l.C / cp) * math.ceil(l.M / mp) <= per_px:
            if best is None or pe < best:
                best = pe
    if best is None:
        return None
    return best * l.R * l.S


def _waterfill(compute: list[LayerWorkload], theta_total: int,
               cycle_model: str = "packed") -> dict[str, int] | None:
    """Global optimum of max-engine-cycles via binary search on the bound.

    For a candidate bottleneck B, each engine independently needs
    theta_min(B) multipliers; the bound is feasible iff they sum within
    Theta. engine_cycles is monotone non-increasing in theta, so binary
    search over B converges to the optimum (up to float resolution).
    """
    lo = max(engine_cycles(l, l.C * l.M * l.R * l.S, cycle_model)
             for l in compute)
    hi = max(engine_cycles(l, l.R * l.S, cycle_model) for l in compute)

    def feasible(B: float) -> dict[str, int] | None:
        out: dict[str, int] = {}
        tot = 0
        for l in compute:
            t = _theta_min_for_bound(l, B, cycle_model)
            if t is None:
                return None
            out[l.name] = t
            tot += t
            if tot > theta_total:
                return None
        return out

    best = feasible(hi)
    if best is None:
        return None
    for _ in range(64):
        mid = math.sqrt(lo * hi) if lo > 0 else (lo + hi) / 2
        got = feasible(mid)
        if got is not None:
            best, hi = got, mid
        else:
            lo = mid
        if hi - lo < 0.5:
            break
    return best


def allocate_compute(
    layers: Sequence[LayerWorkload],
    theta_total: int,
    *,
    objective: str = "optimal",
    cycle_model: str = "packed",
) -> list[LayerAlloc]:
    """Algorithm 1 — allocate multipliers to each compute layer.

    1. pi_i = H*W*R*S*C*M (MACs)
    2. theta_hat_i = pi_i * Theta / sum(pi)
    3. theta_i = [theta_hat_i / (R_i*S_i)] * R_i*S_i   (>= R_i*S_i)
    4. while spare DSPs remain: give R_j*S_j more to the layer with the
       largest pi_j/theta_j (the slowest one).
    5. decompose theta_i into C'_i x M'_i.

    objective="paper" is the pseudo-code verbatim (slowness proxy
    pi_i/theta_i, add-only greedy). objective="exact" (beyond-paper — see
    EXPERIMENTS.md §Perf) optimizes the true per-frame engine cycles
    including ceil losses, and adds a multi-donor rebalance: the step-3
    quantization can strand the bottleneck engine one R*S quantum short,
    which an add-only greedy cannot repair once Theta is exhausted;
    stealing single quanta from several fast engines can.
    objective="optimal" (default) solves the min-max exactly by binary
    search on the bottleneck bound (waterfilling), then runs the exact
    local search on the result.
    """
    compute = [l for l in layers if l.macs > 0]
    if not compute:
        return [LayerAlloc(l, 0, 1, 1) for l in layers]
    total_pi = sum(l.macs for l in compute)
    theta: dict[str, int] = {}
    if objective == "optimal":
        wf = _waterfill(compute, theta_total, cycle_model)
        if wf is not None:
            theta.update(wf)
            _rebalance_exact(compute, theta, theta_total, cycle_model)
            return _finalize(layers, theta, cycle_model)
        objective = "exact"  # infeasible budget: fall back to greedy
    for l in compute:
        hat = l.macs * theta_total / total_pi
        rs = l.R * l.S
        theta[l.name] = max(rs, round(hat / rs) * rs)
    # Rounding may overshoot Theta; shave from the fastest until feasible.
    slowness = ((lambda l: engine_cycles(l, theta[l.name], cycle_model))
                if objective == "exact"
                else (lambda l: l.macs / theta[l.name]))
    while sum(theta.values()) > theta_total:
        order = sorted(compute, key=slowness)
        for j in order:
            rs = j.R * j.S
            if theta[j.name] > rs:
                theta[j.name] -= rs
                break
        else:
            break

    # Greedy refinement (lines 4-8): feed the slowest layer.
    while True:
        order = sorted(compute, key=slowness, reverse=True)
        placed = False
        for j in order:
            rs = j.R * j.S
            if theta[j.name] + rs > j.C * j.M * rs:
                continue  # already at full parallelism
            if sum(theta.values()) + rs <= theta_total:
                theta[j.name] += rs
                placed = True
                break
        if not placed:
            break

    if objective == "exact":
        _rebalance_exact(compute, theta, theta_total, cycle_model)

    return _finalize(layers, theta, cycle_model)


def _finalize(layers: Sequence[LayerWorkload], theta: dict[str, int],
              cycle_model: str = "packed") -> list[LayerAlloc]:
    allocs = []
    for l in layers:
        if l.macs == 0:
            allocs.append(LayerAlloc(l, 0, 1, 1, cycle_model=cycle_model))
            continue
        cp, mp = _decompose_theta(theta[l.name] // (l.R * l.S), l.C, l.M,
                                  cycle_model=cycle_model)
        allocs.append(LayerAlloc(l, cp * mp * l.R * l.S, cp, mp,
                                 cycle_model=cycle_model))
    return allocs


def _rebalance_exact(compute: list[LayerWorkload], theta: dict[str, int],
                     theta_total: int, cycle_model: str = "packed",
                     max_rounds: int = 400) -> None:
    """Multi-donor local search on the exact frame-cycle objective.

    Repeatedly: take the bottleneck engine b; to fund one extra R_b*S_b
    quantum, steal single quanta from the engines that stay fastest after
    donating; commit only if the global bottleneck strictly improves
    (ties broken by the number of engines sitting at the bottleneck).
    """
    def state() -> tuple[float, int]:
        times = [engine_cycles(l, theta[l.name], cycle_model) for l in compute]
        mx = max(times)
        return mx, sum(1 for t in times if t >= mx * (1 - 1e-12))

    for _ in range(max_rounds):
        cur_max, cur_ties = state()
        order = sorted(compute, key=lambda l: engine_cycles(l, theta[l.name], cycle_model),
                       reverse=True)
        improved = False
        for b in order:
            if engine_cycles(b, theta[b.name], cycle_model) < cur_max * (1 - 1e-12):
                break  # only engines at the bottleneck are worth funding
            rs_b = b.R * b.S
            if theta[b.name] + rs_b > b.C * b.M * rs_b:
                continue
            need = rs_b - (theta_total - sum(theta.values()))
            trial = dict(theta)
            trial[b.name] += rs_b
            ok = True
            while need > 0:
                donors = [d for d in compute
                          if d.name != b.name and trial[d.name] > d.R * d.S]
                donors = [d for d in donors
                          if engine_cycles(d, trial[d.name] - d.R * d.S,
                                           cycle_model)
                          < cur_max * (1 - 1e-12)]
                if not donors:
                    ok = False
                    break
                d = min(donors,
                        key=lambda d: engine_cycles(
                            d, trial[d.name] - d.R * d.S, cycle_model))
                trial[d.name] -= d.R * d.S
                need -= d.R * d.S
            if not ok:
                continue
            new_max = max(engine_cycles(l, trial[l.name], cycle_model) for l in compute)
            new_ties = sum(1 for l in compute
                           if engine_cycles(l, trial[l.name], cycle_model)
                           >= new_max * (1 - 1e-12))
            if (new_max, new_ties) < (cur_max, cur_ties):
                theta.clear()
                theta.update(trial)
                improved = True
                break
        if not improved:
            break


# ---------------------------------------------------------------------------
# Algorithm 2 — BRAM vs DDR bandwidth (faithful)
# ---------------------------------------------------------------------------

BRAM18_BYTES = 18 * 1024 // 8  # one BRAM18 block stores 18 Kbit


def bram_for_layer(alloc: LayerAlloc, prev_K: int, act_bytes: int = 1) -> int:
    """Activation-buffer BRAM18 blocks for one layer (Sections 3.3 / 4.2).

    Buffer rows: K_{i-1} (write side) + R_i + G_i*(K_i - 1) (read window).
    Each row holds W_i * C_i pixels split over the channelBuffers; BRAM
    blocks are allocated per channelBuffer (they cannot be subdivided).
    """
    l = alloc.layer
    rows = prev_K + l.R + l.stride * (alloc.K - 1)
    n_chan_buf = max(alloc.Cp, 1)
    row_px = l.W * math.ceil(l.C / n_chan_buf)
    per_buf = max(1, math.ceil(row_px * rows * act_bytes / BRAM18_BYTES))
    return per_buf * n_chan_buf


def weight_bram_for_layer(alloc: LayerAlloc, weight_bytes_el: int = 1) -> int:
    """Weight-buffer BRAM18 blocks for one compute engine.

    Non-resident engines stream their weights from DDR through a
    *double-buffered* ping-pong tile holding the PE grid's working set
    (C' x M' x R x S weights: one half feeds the multipliers while DDR
    fills the other — the weight-side twin of the activation double
    buffer). Engines Algorithm 2 marked ``weights_resident`` instead pin
    the full weight set on-chip (one copy, loaded once per frame), which
    collapses their reload traffic from ``omega_i = weight_bytes *
    ceil(H/K)`` to a single ``weight_bytes`` fetch.
    """
    l = alloc.layer
    if l.macs == 0:
        return 0
    if alloc.weights_resident:
        return max(1, math.ceil(l.weight_bytes / BRAM18_BYTES))
    tile = alloc.Cp * alloc.Mp * l.R * l.S * weight_bytes_el
    return 2 * max(1, math.ceil(tile / BRAM18_BYTES))


def total_bram(allocs: Sequence[LayerAlloc], act_bytes: int = 1, *,
               weights: bool = False,
               weight_bytes_el: int | None = None) -> int:
    """Total BRAM18 blocks: activation line buffers always; with
    ``weights=True`` also the weight buffers (streaming ping-pong tiles +
    any resident weight sets — the Table I "BRAM" column model)."""
    total, prev_K = 0, 1
    for a in allocs:
        if a.layer.kind in ("conv", "pool"):
            total += bram_for_layer(a, prev_K, act_bytes)
            prev_K = a.K
        if weights:
            total += weight_bram_for_layer(
                a, act_bytes if weight_bytes_el is None else weight_bytes_el)
    return total


def weight_traffic_per_frame(a: LayerAlloc) -> float:
    """Bytes of weights fetched from DDR per frame: a full reload once per
    K output rows (omega_i in Algorithm 2); a single load for engines
    whose weights are pinned on-chip."""
    if a.weights_resident:
        return float(a.layer.weight_bytes)
    reloads = max(1, math.ceil(a.layer.H / max(1, a.K)))
    return a.layer.weight_bytes * reloads


def allocate_buffers(
    allocs: list[LayerAlloc],
    *,
    bram_total: int,
    bandwidth_bytes: float,
    freq_hz: float,
    act_bytes: int = 1,
    weights: bool = False,
    strict: bool = False,
    max_iters: int = 100_000,
) -> list[LayerAlloc]:
    """Algorithm 2 — raise row parallelism K_i to fit the bandwidth roof.

    While the aggregate weight traffic B = FPS * sum(omega_i) exceeds the
    board bandwidth beta, bump K of the worst-traffic conv layer, paying
    activation-buffer BRAMs; stop when BRAM budget alpha would be exceeded.

    With ``weights=True`` the alpha test also charges weight buffers
    (:func:`weight_bram_for_layer`: double-buffered streaming tiles), and
    a second phase spends the surplus BRAM pinning whole conv weight sets
    on-chip — greedily by DDR traffic saved per BRAM block — which cuts
    reload traffic beyond what K alone can (the model behind the paper's
    reported BRAM utilization totals; see ``tests/test_allocator.py``'s
    regression against Table I).

    The phases only ever *add* BRAM to a K=1 baseline, so a budget the
    baseline itself does not fit is returned as-is (best effort, the
    paper assumes alpha covers the mandatory buffers); pass
    ``strict=True`` to get a ``ValueError`` instead of a silently
    over-budget plan (e.g. when sweeping small boards for feasibility).
    """
    from repro.core.throughput import pipeline_fps

    convs = [a for a in allocs if a.layer.macs > 0 and a.layer.kind == "conv"]

    def used() -> int:
        return total_bram(allocs, act_bytes, weights=weights)

    def demand() -> float:
        f = pipeline_fps(allocs, freq_hz=freq_hz)
        return f * sum(weight_traffic_per_frame(a) for a in convs)

    if strict and used() > bram_total:
        raise ValueError(
            f"BRAM budget alpha={bram_total} cannot hold the K=1 "
            f"baseline ({used()} blocks of mandatory activation"
            f"{'/weight' if weights else ''} buffers)")

    for _ in range(max_iters):
        if demand() <= bandwidth_bytes:
            break
        cand = max(convs, key=weight_traffic_per_frame)
        if cand.K >= cand.layer.H:
            break
        cand.K += 1
        if used() > bram_total:
            cand.K -= 1
            break
    if not weights:
        return allocs

    # Phase 2 — weight residency: surplus alpha buys the hottest weight
    # streams a permanent home. Order by traffic saved per BRAM block so
    # a huge layer cannot starve two cheaper, hotter ones.
    def saving(a: LayerAlloc) -> float:
        reloads = max(1, math.ceil(a.layer.H / max(1, a.K)))
        return a.layer.weight_bytes * (reloads - 1)

    def blocks(a: LayerAlloc) -> int:
        return max(1, math.ceil(a.layer.weight_bytes / BRAM18_BYTES))

    for a in sorted((a for a in convs if saving(a) > 0),
                    key=lambda a: saving(a) / blocks(a), reverse=True):
        a.weights_resident = True
        if used() > bram_total:
            a.weights_resident = False
    return allocs


# ---------------------------------------------------------------------------
# Mesh mode — the TPU-pod port of Algorithms 1 + 2
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MeshHw:
    """Per-chip hardware roofs (defaults: TPU v5e)."""

    peak_flops: float = 197e12     # bf16
    hbm_bytes: float = 16e9
    hbm_bw: float = 819e9
    ici_bw: float = 50e9           # per link


V5E = MeshHw()


@dataclasses.dataclass(frozen=True)
class StagePlan:
    """Output of the mesh allocator: the flexible pipeline layout."""

    n_stages: int                    # S
    tensor_parallel: int             # T; S*T == model axis size
    boundaries: tuple[int, ...]      # len S+1 layer indices (contiguous)
    microbatches: int                # GPipe microbatch count
    stage_flops: tuple[int, ...]     # flops per stage (global batch)
    t_stage_max: float               # sec/microbatch, the T_rowmax analogue
    bubble_fraction: float
    step_time: float                 # sec (predicted)
    utilization: float               # ideal/achieved = DSP-efficiency analogue
    mem_per_chip: float              # bytes (params+opt+activations)

    @property
    def layers_per_stage(self) -> tuple[int, ...]:
        return tuple(self.boundaries[i + 1] - self.boundaries[i]
                     for i in range(self.n_stages))


def _partition_min_max(weights: Sequence[float], k: int) -> tuple[list[int], float]:
    """Optimal contiguous partition of ``weights`` into k parts minimizing
    the max part-sum (DP). This is Algorithm 1's balance objective solved
    exactly for the mesh setting: "give more multipliers to the slowest
    layer" becomes "give fewer layers to the slowest stage".
    """
    n = len(weights)
    prefix = [0.0]
    for w in weights:
        prefix.append(prefix[-1] + w)
    INF = math.inf
    dp = [[INF] * (k + 1) for _ in range(n + 1)]
    cut = [[0] * (k + 1) for _ in range(n + 1)]
    dp[0][0] = 0.0
    for j in range(1, k + 1):
        for i in range(j, n + 1):
            for p in range(j - 1, i):
                cost = max(dp[p][j - 1], prefix[i] - prefix[p])
                if cost < dp[i][j]:
                    dp[i][j] = cost
                    cut[i][j] = p
    bounds = [n]
    i, j = n, k
    while j > 0:
        i = cut[i][j]
        bounds.append(i)
        j -= 1
    bounds.reverse()
    return bounds, dp[n][k]


def plan_pipeline(
    layers: Sequence[LayerWorkload],
    *,
    model_axis: int,
    data_axis: int,
    global_batch: int,
    seq_len: int,
    train: bool,
    hw: MeshHw = V5E,
    dtype_bytes: int = 2,
    d_model: int | None = None,
    stage_choices: Sequence[int] | None = None,
    max_microbatches: int = 128,
    overlap_comm: bool = False,
    zero1: bool = True,
    allow_infeasible: bool = False,
) -> StagePlan:
    """Mesh-mode Algorithms 1 + 2.

    For each stage count S dividing the model axis, partition layers to
    minimize the slowest stage (Alg. 1), then sweep the microbatch count —
    the FPGA row-parallelism K maps to tokens-per-weight-residency
    ``total_tokens / microbatches``; more microbatches shrink the pipeline
    bubble but re-stream stage weights from HBM more often (Alg. 2's
    bandwidth-vs-buffer trade, with alpha -> HBM capacity, beta -> HBM bw).
    """
    mult = 3.0 if train else 1.0
    flops = [l.macs * 2.0 * mult for l in layers]
    wbytes = [float(l.weight_bytes) for l in layers]
    total_flops = sum(flops)
    n_chips = model_axis * data_axis
    if d_model is None:
        d_model = max(l.C for l in layers)
    tokens_per_shard = max(1, global_batch // max(1, data_axis)) * seq_len

    if stage_choices is None:
        stage_choices = [s for s in (1, 2, 4, 8, 16) if model_axis % s == 0]

    best: StagePlan | None = None
    for S in stage_choices:
        if S > max(1, len(layers)):
            continue
        T = model_axis // S
        bounds, _ = _partition_min_max(flops, S)
        stage_fl = [sum(flops[bounds[i]:bounds[i + 1]]) for i in range(S)]
        stage_wb = [sum(wbytes[bounds[i]:bounds[i + 1]]) for i in range(S)]
        max_fl, max_wb = max(stage_fl), max(stage_wb)

        layers_max = max(bounds[i + 1] - bounds[i] for i in range(S))
        for mb in [2 ** p for p in range(0, 1 + int(math.log2(max_microbatches)))]:
            if S > 1 and mb < S:
                continue  # degenerate pipeline
            # Per-microbatch, per-chip times for the slowest stage.
            t_comp = max_fl / mb / (T * data_axis) / hw.peak_flops
            t_wt = (max_wb / T) / hw.hbm_bw           # weights re-read per mb
            mb_act = tokens_per_shard / mb * d_model * dtype_bytes
            # Megatron TP all-reduces: 2/layer fwd (+2 bwd) on the tp ring.
            n_ar = 2 * (2 if train else 1)
            t_tp = (layers_max * n_ar * 2.0 * (T - 1) / T * mb_act
                    / hw.ici_bw) if T > 1 else 0.0
            # Inter-stage transfer (the activation line buffer).
            t_xfer = (mb_act / hw.ici_bw) if S > 1 else 0.0
            if overlap_comm:
                t_mb = max(t_comp, t_wt, t_tp + t_xfer)
            else:
                t_mb = max(t_comp, t_wt) + t_tp + t_xfer
            step = t_mb * (mb + S - 1)

            # HBM capacity (the alpha test).
            param_chip = max_wb / T
            opt_chip = (param_chip * 6.0 / (data_axis if zero1 else 1)
                        if train else 0.0)
            inflight = min(mb, S) if train else 1
            act_chip = (tokens_per_shard / mb) * d_model * dtype_bytes \
                * inflight / T
            mem = param_chip + opt_chip + act_chip
            if mem > hw.hbm_bytes:
                continue

            ideal = total_flops / (n_chips * hw.peak_flops)
            util = min(1.0, ideal / step) if step > 0 else 0.0
            plan = StagePlan(
                n_stages=S, tensor_parallel=T, boundaries=tuple(bounds),
                microbatches=mb, stage_flops=tuple(int(f) for f in stage_fl),
                t_stage_max=max_fl / mb / (T * data_axis) / hw.peak_flops,
                bubble_fraction=(S - 1) / (mb + S - 1),
                step_time=step, utilization=util, mem_per_chip=mem,
            )
            if best is None or plan.utilization > best.utilization:
                best = plan
    if best is None:
        if allow_infeasible:
            # Best-effort plan ignoring the HBM cap (flagged by caller via
            # mem_per_chip > hbm_bytes): weight sharding over data (the
            # pjit FSDP path) is then required.
            return plan_pipeline(
                layers, model_axis=model_axis, data_axis=data_axis,
                global_batch=global_batch, seq_len=seq_len, train=train,
                hw=dataclasses.replace(hw, hbm_bytes=float("inf")),
                dtype_bytes=dtype_bytes, d_model=d_model,
                stage_choices=stage_choices,
                max_microbatches=max_microbatches,
                overlap_comm=overlap_comm, zero1=zero1,
                allow_infeasible=False)
        raise ValueError(
            "no feasible pipeline plan fits HBM; increase mesh or reduce model")
    return best
