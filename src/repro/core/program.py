"""Compiled engine programs: one plan drives execution, simulation and
benchmarks.

The paper's central object is a *balanced plan*: per-layer workloads
(Section 3), the multiplier/buffer allocation that balances them
(Algorithms 1/2), and the fixed-point formats the engines exchange
(Fig. 3(c)). :func:`compile_model` materializes that plan once as an
:class:`EngineProgram`:

1. **allocate** — Algorithms 1 and 2 run once over the model's
   :class:`~repro.core.workload.LayerWorkload` graph, producing the
   per-engine ``LayerAlloc``s every consumer shares (``program.allocs``
   feeds ``simulator.simulate`` and the throughput model directly).
2. **calibrate** — a float forward over ``calib_batch`` records per-layer
   activation ranges; per-tensor activation exponents and per-output-channel
   weight exponents are frozen, weights are quantized *once* (int8 + a shift
   schedule), and biases are pre-scaled onto each engine's 32-bit
   accumulator format.
3. **lower** — each layer becomes an :class:`EngineStep` whose bias-add,
   ReLU and requantize-to-int8 are fused into the GEMM epilogue
   (`kernels/conv2d_int8`), so activations stay int8 end-to-end: no
   per-forward ``quantize_po2``, no float32 bounce between layers.

``run(x)`` executes the program either through the Pallas PE-array kernels
(``use_kernel=True``; interpret mode on CPU) or through a pure-jnp integer
oracle — the two are bit-identical, which is what ``tests/test_program.py``
pins down.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant
from repro.core.allocator import (LayerAlloc, allocate_buffers,
                                  allocate_compute)
from repro.core.workload import CNNModel, ConvLayer

Params = dict[str, Any]

# ZC706-class board defaults (the paper's Table I setting).
DEFAULT_THETA = 900
DEFAULT_BRAM = 1090
DEFAULT_BW = 4.2e9
DEFAULT_FREQ = 200e6


# ---------------------------------------------------------------------------
# Shared float executor (the calibration reference and the fp32 model path)
# ---------------------------------------------------------------------------


def float_forward(params: Params, model: CNNModel, x: jnp.ndarray,
                  record: dict[str, float] | None = None) -> jnp.ndarray:
    """Reference float forward over the model graph (NHWC). With ``record``
    it doubles as the calibration pass: per-layer output amax (post-ReLU
    for hidden layers — what the next engine actually consumes) is stored
    under the layer name, the network input under ``"__input__"``."""
    if record is not None:
        record["__input__"] = float(jnp.max(jnp.abs(x)))
    hw = x.shape[1]
    last = [l for l in model.layers if l.kind != "pool"][-1]
    for lyr in model.layers:
        out_hw = lyr.out_hw(hw)
        if lyr.kind == "pool":
            lo, hi = lyr.padding(hw)
            x = -jax.lax.reduce_window(
                -x, jnp.inf, jax.lax.min,
                (1, lyr.kernel, lyr.kernel, 1),
                (1, lyr.stride, lyr.stride, 1),
                ((0, 0), (lo, hi), (lo, hi), (0, 0)))
        elif lyr.kind == "fc":
            x = x.reshape(x.shape[0], -1)
            w, b = params[lyr.name]["w"], params[lyr.name]["b"]
            x = x @ w + b
            if lyr is not last:
                x = jax.nn.relu(x)
            if record is not None:
                record[lyr.name] = float(jnp.max(jnp.abs(x)))
        else:
            w, b = params[lyr.name]["w"], params[lyr.name]["b"]
            lo, hi = lyr.padding(hw)
            x = jax.lax.conv_general_dilated(
                x, w, (lyr.stride, lyr.stride), ((lo, hi), (lo, hi)),
                dimension_numbers=("NHWC", "HWIO", "NHWC"),
                feature_group_count=lyr.groups)
            x = x + b
            if lyr is not last:
                x = jax.nn.relu(x)
            if record is not None:
                record[lyr.name] = float(jnp.max(jnp.abs(x)))
        hw = out_hw
    return x


# ---------------------------------------------------------------------------
# Lowered steps
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class EngineStep:
    """One pipeline engine, fully lowered: quantized weights, the frozen
    shift schedule, and the spatial plumbing the kernel needs."""

    name: str
    kind: str                      # "conv" | "fc" | "pool"
    layer: ConvLayer
    pad: tuple[int, int]           # (lo, hi), both spatial dims
    # compute-step payload (None for pool):
    wq: jnp.ndarray | None = None          # int8/int16 quantized weights
    bias_q: jnp.ndarray | None = None      # int32 bias on the acc format
    shift: jnp.ndarray | None = None       # int32 [M]: e_out - (e_in+e_w)
    e_in: int = 0                          # input activation exponent
    e_w: np.ndarray | None = None          # int [M] weight exponents
    e_out: int = 0                         # output activation exponent
    relu: bool = False
    requantize: bool = True        # False on the last engine (emit acc32)


@dataclasses.dataclass
class EngineProgram:
    """The compiled plan. ``allocs`` is the single source of truth for
    cycles (simulator / throughput model / Table I); ``steps`` is the
    executable lowering of the same layers."""

    model: CNNModel
    bits: int
    theta_total: int
    allocs: list[LayerAlloc]
    steps: list[EngineStep] | None = None
    e_input: int = 0
    freq_hz: float = DEFAULT_FREQ

    # -- analytics ----------------------------------------------------------

    @property
    def gop(self) -> float:
        return self.model.gop

    def frame_cycles(self) -> float:
        from repro.core import throughput as T
        return T.frame_cycles(self.allocs)

    def fps(self) -> float:
        from repro.core import throughput as T
        return T.pipeline_fps(self.allocs, freq_hz=self.freq_hz)

    # -- execution ----------------------------------------------------------

    def run(self, x: jnp.ndarray, *, use_kernel: bool = False,
            interpret: bool | None = None) -> jnp.ndarray:
        """Fixed-point forward. ``x`` is float NHWC; returns float logits
        (the final engine's 32-bit accumulators on their exact po2 scale).
        All intermediate activations are int8 (int16 for bits=16)."""
        if self.steps is None:
            raise ValueError(
                "plan-only program (compiled without params) cannot run")
        if interpret is None:
            interpret = jax.devices()[0].platform != "tpu"
        if use_kernel and self.bits > 8:
            raise NotImplementedError(
                "the Pallas PE-array kernel is int8; bits=16 runs the "
                "jnp oracle (48-bit DSP accumulation model)")
        xq = quant.quantize_to_exponent(x, self.e_input, self.bits)
        for step in self.steps:
            if step.kind == "pool":
                xq = _pool_int(xq, step)
            elif use_kernel:
                xq = _step_kernel(xq, step, interpret)
            else:
                xq = _step_oracle(xq, step, self.bits)
        last = [s for s in self.steps if s.kind != "pool"][-1]
        scale = jnp.exp2(jnp.asarray(last.e_in + last.e_w, jnp.float32))
        return xq.astype(jnp.float32) \
            * scale.reshape((1,) * (xq.ndim - 1) + (-1,))


# ---------------------------------------------------------------------------
# Step executors
# ---------------------------------------------------------------------------


def _pool_int(xq: jnp.ndarray, step: EngineStep) -> jnp.ndarray:
    """Max pool directly on the integer activations — max is monotone in
    the po2 format, so this is exact and the exponent passes through."""
    lyr = step.layer
    lo, hi = step.pad
    # bits=16 models accumulators in float32, so the last engine's output
    # (requantize=False) may reach a trailing pool as floats.
    init = jnp.array(-jnp.inf if jnp.issubdtype(xq.dtype, jnp.floating)
                     else jnp.iinfo(xq.dtype).min, xq.dtype)
    return jax.lax.reduce_window(
        xq, init, jax.lax.max,
        (1, lyr.kernel, lyr.kernel, 1), (1, lyr.stride, lyr.stride, 1),
        ((0, 0), (lo, hi), (lo, hi), (0, 0)))


def _step_kernel(xq: jnp.ndarray, step: EngineStep,
                 interpret: bool) -> jnp.ndarray:
    from repro.kernels.conv2d_int8.ops import conv2d_int8, fc_int8
    lyr = step.layer
    emit = not step.requantize
    if step.kind == "fc":
        return fc_int8(xq.reshape(xq.shape[0], -1), step.wq, step.shift,
                       step.bias_q, relu=step.relu, interpret=interpret,
                       emit_int32=emit)
    return conv2d_int8(xq, step.wq, step.shift, step.bias_q,
                       stride=lyr.stride, padding=(step.pad, step.pad),
                       groups=lyr.groups, relu=step.relu,
                       interpret=interpret, emit_int32=emit)


def _step_oracle(xq: jnp.ndarray, step: EngineStep, bits: int) -> jnp.ndarray:
    """Pure-jnp integer oracle with the identical fused epilogue. For
    bits<=8 the arithmetic is exact int32 (bit-identical to the Pallas
    kernel); bits=16 models the DSP48's 48-bit accumulate in float32."""
    lyr = step.layer
    exact = bits <= 8
    acc_dt = jnp.int32 if exact else jnp.float32
    if step.kind == "fc":
        acc = jnp.matmul(xq.reshape(xq.shape[0], -1).astype(acc_dt),
                         step.wq.astype(acc_dt),
                         preferred_element_type=acc_dt)
    else:
        lo, hi = step.pad
        acc = jax.lax.conv_general_dilated(
            xq.astype(acc_dt), step.wq.astype(acc_dt),
            (lyr.stride, lyr.stride), ((lo, hi), (lo, hi)),
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            feature_group_count=lyr.groups,
            preferred_element_type=acc_dt)
    if exact and step.requantize:
        # Same fused epilogue as the kernel, from the shared oracle.
        from repro.kernels.conv2d_int8.ref import requantize_ref
        flat = requantize_ref(acc.reshape(-1, acc.shape[-1]), step.shift,
                              step.bias_q, step.relu)
        return flat.reshape(acc.shape)
    bias = step.bias_q.astype(acc_dt)
    acc = acc + bias.reshape((1,) * (acc.ndim - 1) + (-1,))
    if step.relu:
        acc = jnp.maximum(acc, 0)
    if not step.requantize:
        return acc
    # bits=16: floor(acc / 2^sh) — the shifter's truncation in float.
    sh = step.shift.reshape((1,) * (acc.ndim - 1) + (-1,))
    y = jnp.floor(acc * jnp.exp2(-sh.astype(jnp.float32)))
    qmax = 2 ** (bits - 1) - 1
    return jnp.clip(y, -qmax - 1, qmax).astype(jnp.int16)


# ---------------------------------------------------------------------------
# Compilation
# ---------------------------------------------------------------------------


def compile_model(model: CNNModel, params: Params | None = None, *,
                  theta: int = DEFAULT_THETA, bits: int = 8,
                  calib_batch: jnp.ndarray | None = None,
                  bram_total: int | None = DEFAULT_BRAM,
                  bandwidth_bytes: float = DEFAULT_BW,
                  freq_hz: float = DEFAULT_FREQ,
                  objective: str = "optimal") -> EngineProgram:
    """Workload -> allocation -> execution, compiled once.

    Without ``params`` this produces a *plan-only* program (Algorithms 1/2
    only) for the simulator and benchmarks. With ``params`` (and a
    ``calib_batch`` for activation ranges) the program is fully lowered and
    runnable. ``bram_total=None`` skips Algorithm 2 (compute allocation
    only, all K=1).
    """
    workloads = model.layer_workloads(weight_bits=bits)
    allocs = allocate_compute(workloads, theta, objective=objective)
    if bram_total is not None:
        allocate_buffers(allocs, bram_total=bram_total,
                         bandwidth_bytes=bandwidth_bytes, freq_hz=freq_hz,
                         act_bytes=bits // 8)
    prog = EngineProgram(model=model, bits=bits, theta_total=theta,
                         allocs=allocs, freq_hz=freq_hz)
    if params is None:
        return prog

    if calib_batch is None:
        raise ValueError("compiling an executable program needs a "
                         "calib_batch to freeze activation formats")
    amax: dict[str, float] = {}
    float_forward(params, model, calib_batch, record=amax)
    prog.e_input = quant.po2_exponent(amax["__input__"], bits)
    prog.steps = _lower(model, params, amax, prog.e_input, bits)
    return prog


def _lower(model: CNNModel, params: Params, amax: dict[str, float],
           e_input: int, bits: int) -> list[EngineStep]:
    steps: list[EngineStep] = []
    compute = [l for l in model.layers if l.kind != "pool"]
    last = compute[-1]
    hw = model.input_hw
    e_act = e_input
    for lyr in model.layers:
        pad = lyr.padding(hw)
        if lyr.kind == "pool":
            steps.append(EngineStep(name=lyr.name, kind="pool", layer=lyr,
                                    pad=pad))
            hw = lyr.out_hw(hw)
            continue
        w = params[lyr.name]["w"]
        b = params[lyr.name]["b"]
        e_w = np.asarray(quant.po2_scale(w, axis=-1, bits=bits), np.int64)
        is_last = lyr is last
        e_out = quant.po2_exponent(amax[lyr.name], bits)
        # Floor each channel's weight format so (a) its bias fits the
        # int32 accumulator and (b) the output shift stays within the
        # 31-bit shifter. Without this, a channel with numerically-dead
        # weights but a significant bias would get an absurdly fine
        # accumulator scale, saturating bias_q and silently dropping the
        # bias; flooring e_w instead rounds the dead weights to zero and
        # keeps the bias exactly representable.
        b_np = np.asarray(b, np.float64)
        nz = np.abs(b_np) > 0
        b_mag = np.full(b_np.shape, -(10 ** 9), np.int64)
        b_mag[nz] = np.ceil(np.log2(np.abs(b_np[nz])))
        e_w = np.maximum(e_w, np.maximum(b_mag - 30, e_out - 31) - e_act)
        # Quantize weights once onto the (possibly floored) formats.
        qmax = 2 ** (bits - 1) - 1
        scale = jnp.exp2(-jnp.asarray(e_w, jnp.float32)).reshape(
            (1,) * (w.ndim - 1) + (-1,))
        wq = jnp.clip(jnp.round(w * scale), -qmax - 1, qmax).astype(
            jnp.int8 if bits <= 8 else jnp.int16)
        # Bias pre-scaled onto this engine's 32-bit accumulator format
        # (value = q * 2^(e_in + e_w[m])).
        acc_e = e_act + e_w
        bias_q = np.clip(np.round(b_np / np.exp2(acc_e)),
                         np.iinfo(np.int32).min, np.iinfo(np.int32).max
                         ).astype(np.int32)
        shift = np.clip(e_out - acc_e, -31, 31).astype(np.int32)
        steps.append(EngineStep(
            name=lyr.name, kind=lyr.kind, layer=lyr, pad=pad,
            wq=jnp.asarray(wq), bias_q=jnp.asarray(bias_q),
            shift=jnp.asarray(shift), e_in=e_act, e_w=e_w, e_out=e_out,
            relu=not is_last, requantize=not is_last))
        e_act = e_out
        hw = lyr.out_hw(hw)
    return steps
