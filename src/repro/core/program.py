"""Compiled engine programs: one plan drives execution, simulation and
benchmarks.

The paper's central object is a *balanced plan*: per-layer workloads
(Section 3), the multiplier/buffer allocation that balances them
(Algorithms 1/2), and the fixed-point formats the engines exchange
(Fig. 3(c)). :func:`compile_model` materializes that plan once as an
:class:`EngineProgram`:

1. **allocate** — Algorithms 1 and 2 run once over the model's
   :class:`~repro.core.workload.LayerWorkload` graph, producing the
   per-engine ``LayerAlloc``s every consumer shares (``program.allocs``
   feeds ``simulator.simulate`` and the throughput model directly).
2. **calibrate** — a float forward over ``calib_batch`` records per-layer
   activation ranges; per-tensor activation exponents and per-output-channel
   weight exponents are frozen, weights are quantized *once* (int8 + a shift
   schedule), and biases are pre-scaled onto each engine's 32-bit
   accumulator format.
3. **lower** — each layer becomes an :class:`EngineStep` whose bias-add,
   ReLU and requantize-to-int8 are fused into the GEMM epilogue
   (`kernels/conv2d_int8`), so activations stay int8 end-to-end: no
   per-forward ``quantize_po2``, no float32 bounce between layers.

``run(x)`` executes the program either through the Pallas PE-array kernels
(``use_kernel=True``; interpret mode on CPU) or through a pure-jnp integer
oracle — the two are bit-identical, which is what ``tests/test_program.py``
pins down.

For serving, :meth:`EngineProgram.compile_runner` lowers the *whole* step
chain into one ``jax.jit``-compiled function (weights, bias and shift
schedules captured as constants, the int8 activation buffer donated), so a
stream of frames runs as a single fused device program instead of the
eager per-step loop — the software analogue of switching the paper's
engines from frame-at-a-time operation to the steady-state pipeline.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant
from repro.core.allocator import (LayerAlloc, allocate_buffers,
                                  allocate_compute)
from repro.core.workload import CNNModel, ConvLayer

Params = dict[str, Any]

# ZC706-class board defaults (the paper's Table I setting).
DEFAULT_THETA = 900
DEFAULT_BRAM = 1090
DEFAULT_BW = 4.2e9
DEFAULT_FREQ = 200e6


# ---------------------------------------------------------------------------
# Shared float executor (the calibration reference and the fp32 model path)
# ---------------------------------------------------------------------------


def float_forward(params: Params, model: CNNModel, x: jnp.ndarray,
                  record: dict[str, float] | None = None) -> jnp.ndarray:
    """Reference float forward over the model graph (NHWC). With ``record``
    it doubles as the calibration pass: per-layer output amax (post-ReLU
    for hidden layers — what the next engine actually consumes) is stored
    under the layer name, the network input under ``"__input__"``."""
    if record is not None:
        record["__input__"] = float(jnp.max(jnp.abs(x)))
    hw = x.shape[1]
    last = [l for l in model.layers if l.kind != "pool"][-1]
    for lyr in model.layers:
        out_hw = lyr.out_hw(hw)
        if lyr.kind == "pool":
            lo, hi = lyr.padding(hw)
            x = -jax.lax.reduce_window(
                -x, jnp.inf, jax.lax.min,
                (1, lyr.kernel, lyr.kernel, 1),
                (1, lyr.stride, lyr.stride, 1),
                ((0, 0), (lo, hi), (lo, hi), (0, 0)))
        elif lyr.kind == "fc":
            x = x.reshape(x.shape[0], -1)
            w, b = params[lyr.name]["w"], params[lyr.name]["b"]
            x = x @ w + b
            if lyr is not last:
                x = jax.nn.relu(x)
            if record is not None:
                record[lyr.name] = float(jnp.max(jnp.abs(x)))
        else:
            w, b = params[lyr.name]["w"], params[lyr.name]["b"]
            lo, hi = lyr.padding(hw)
            x = jax.lax.conv_general_dilated(
                x, w, (lyr.stride, lyr.stride), ((lo, hi), (lo, hi)),
                dimension_numbers=("NHWC", "HWIO", "NHWC"),
                feature_group_count=lyr.groups)
            x = x + b
            if lyr is not last:
                x = jax.nn.relu(x)
            if record is not None:
                record[lyr.name] = float(jnp.max(jnp.abs(x)))
        hw = out_hw
    return x


# ---------------------------------------------------------------------------
# Lowered steps
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class EngineStep:
    """One pipeline engine, fully lowered: quantized weights, the frozen
    shift schedule, and the spatial plumbing the kernel needs."""

    name: str
    kind: str                      # "conv" | "fc" | "pool"
    layer: ConvLayer
    pad: tuple[int, int]           # (lo, hi), both spatial dims
    # compute-step payload (None for pool):
    wq: jnp.ndarray | None = None          # int8/int16 quantized weights
    bias_q: jnp.ndarray | None = None      # int32 bias on the acc format
    shift: jnp.ndarray | None = None       # int32 [M]: e_out - (e_in+e_w)
    e_in: int = 0                          # input activation exponent
    e_w: np.ndarray | None = None          # int [M] weight exponents
    e_out: int = 0                         # output activation exponent
    relu: bool = False
    requantize: bool = True        # False on the last engine (emit acc32)


@dataclasses.dataclass
class EngineProgram:
    """The compiled plan. ``allocs`` is the single source of truth for
    cycles (simulator / throughput model / Table I); ``steps`` is the
    executable lowering of the same layers."""

    model: CNNModel
    bits: int
    theta_total: int
    allocs: list[LayerAlloc]
    steps: list[EngineStep] | None = None
    e_input: int = 0
    freq_hz: float = DEFAULT_FREQ

    # -- analytics ----------------------------------------------------------

    @property
    def gop(self) -> float:
        return self.model.gop

    def frame_cycles(self) -> float:
        from repro.core import throughput as T
        return T.frame_cycles(self.allocs)

    def fps(self) -> float:
        from repro.core import throughput as T
        return T.pipeline_fps(self.allocs, freq_hz=self.freq_hz)

    # -- execution ----------------------------------------------------------

    def out_scale(self) -> np.ndarray:
        """Per-channel float32 po2 scale of the final engine's int32
        accumulators (logits = acc * out_scale, exactly)."""
        last = [s for s in self.steps if s.kind != "pool"][-1]
        return np.exp2(np.asarray(last.e_in + last.e_w, np.float32))

    def run(self, x: jnp.ndarray, *, use_kernel: bool = False,
            interpret: bool | None = None) -> jnp.ndarray:
        """Fixed-point forward, eagerly step by step. ``x`` is float NHWC;
        returns float logits (the final engine's 32-bit accumulators on
        their exact po2 scale). All intermediate activations are int8
        (int16 for bits=16). This is the per-sample reference path; for
        throughput use :meth:`compile_runner`."""
        if self.steps is None:
            raise ValueError(
                "plan-only program (compiled without params) cannot run")
        if interpret is None:
            interpret = jax.devices()[0].platform != "tpu"
        if use_kernel:
            require_kernel(self.bits)
        xq = quant.quantize_to_exponent(x, self.e_input, self.bits)
        for step in self.steps:
            if step.kind == "pool":
                xq = _pool_int(xq, step)
            elif use_kernel:
                xq = _step_kernel(xq, step, interpret)
            else:
                xq = _step_oracle(xq, step, self.bits)
        scale = jnp.asarray(self.out_scale())
        return xq.astype(jnp.float32) \
            * scale.reshape((1,) * (xq.ndim - 1) + (-1,))

    def _resolve_route(self, route: str | None,
                       steps: tuple[EngineStep, ...]) -> str:
        """Validate a MAC-route request against ``steps`` (shared by the
        whole-chain and stage runners so a stage cannot silently accept a
        lowering the full chain would refuse)."""
        if route is None:
            route = "oracle" if self.bits > 8 else "f32"
        if route not in ("f32", "oracle", "kernel"):
            raise ValueError(f"unknown route {route!r}")
        if route == "kernel":
            require_kernel(self.bits)
        if route == "f32" and self.bits > 8:
            raise NotImplementedError(
                "the exact-f32 route holds only for int8 products "
                "(<= 2^14 per MAC); bits=16 uses route='oracle'")
        if route == "f32":
            # The exactness proof chunks the reduction over channels; a
            # single (r, s) tap plane is its floor. Kernels wider than
            # 32x32 (none in the paper's models) would overflow 2^24
            # within one chunk — refuse rather than silently lose bits.
            for s in steps:
                if s.kind == "conv" and \
                        s.layer.kernel ** 2 > _F32_CHUNK_MACS:
                    raise NotImplementedError(
                        f"step {s.name}: {s.layer.kernel}x"
                        f"{s.layer.kernel} kernel exceeds the exact-f32 "
                        f"chunk bound ({_F32_CHUNK_MACS} MACs); use "
                        f"route='oracle'")
        return route

    def compile_runner(self, *, route: str | None = None,
                       interpret: bool | None = None,
                       donate: bool | None = None) -> "CompiledRunner":
        """Lower the whole step chain into ONE jitted function over a batch
        of already-quantized frames and wrap it as a :class:`CompiledRunner`.

        ``route`` selects the MAC lowering (every route computes the exact
        same integers — pinned by ``tests/test_executor.py``):

        * ``"f32"`` (default for bits=8) — the int8 MACs run as chunked
          float32 convolutions/GEMMs: each partial sum accumulates at most
          1024 products of magnitude <= 2^14, so every intermediate is an
          integer <= 2^24 and float32 arithmetic is *bit-exact* (MACs are
          pinned to ``Precision.HIGHEST`` so GPU TF32 / TPU bf16 lowering
          cannot degrade them — see :func:`_step_exact_f32`). This hits
          the backend's fast f32 conv/GEMM paths (XLA CPU has no fast
          integer conv), ~10x over the int32 oracle on CPU.
        * ``"oracle"`` — the pure-jnp int32 oracle (default for bits=16,
          whose 48-bit accumulator model is already float).
        * ``"kernel"`` — the Pallas PE-array kernel (interpret mode off
          TPU). Availability is checked here, once, not per step.

        ``donate`` donates the int8 activation buffer to the call so XLA
        reuses it for intermediates instead of round-tripping fresh
        allocations (defaults to True off-CPU; CPU ignores donation).
        """
        if self.steps is None:
            raise ValueError(
                "plan-only program (compiled without params) cannot run")
        return self.compile_stage_runner(0, len(self.steps), route=route,
                                         interpret=interpret, donate=donate)

    def compile_stage_runner(self, start: int, stop: int, *,
                             route: str | None = None,
                             interpret: bool | None = None,
                             donate: bool | None = None,
                             device=None) -> "CompiledRunner":
        """Jit the contiguous step range ``[start, stop)`` as one device
        program — one *stage* of the software layer-wise pipeline
        (``repro.serving``). Activations cross stage boundaries as the same
        int8 (int16 for bits=16) tensors the full chain passes between
        steps, so chaining stage runners end to end reproduces
        :meth:`compile_runner` bit-exactly for every route (pinned by
        ``tests/test_serving.py``). ``compile_runner`` itself is the
        degenerate single-stage case ``[0, len(steps))``.

        ``device`` pins the stage to one ``jax.Device``: inputs are
        ``jax.device_put`` onto it before dispatch, so the jit traces,
        compiles, and runs there (weights, captured as constants, follow).
        This is how the serving pipeline places each stage on its own
        device — the software analogue of each paper engine owning its
        own DSP/BRAM partition. Placement never changes the integers:
        every route is bit-exact on any backend, so placed output ==
        unplaced output (pinned by ``tests/test_serving.py``)."""
        if self.steps is None:
            raise ValueError(
                "plan-only program (compiled without params) cannot run")
        if not (0 <= start < stop <= len(self.steps)):
            raise ValueError(
                f"stage range [{start}, {stop}) outside the "
                f"{len(self.steps)}-step chain")
        steps = tuple(self.steps[start:stop])
        route = self._resolve_route(route, steps)
        if interpret is None:
            interpret = jax.devices()[0].platform != "tpu"
        if donate is None:
            donate = jax.devices()[0].platform != "cpu"
        bits = self.bits

        def chain(xq: jnp.ndarray) -> jnp.ndarray:
            for step in steps:
                if step.kind == "pool":
                    xq = _pool_int(xq, step)
                elif route == "kernel":
                    xq = _step_kernel(xq, step, interpret)
                elif route == "f32":
                    xq = _step_exact_f32(xq, step)
                else:
                    xq = _step_oracle(xq, step, bits)
            return xq

        fn = jax.jit(chain, donate_argnums=(0,) if donate else ())
        return CompiledRunner(program=self, route=route, donate=donate,
                              fn=fn, start=start, stop=stop, device=device)


@dataclasses.dataclass
class CompiledRunner:
    """One jitted device program for a contiguous step range of the engine
    chain — the whole chain for :meth:`EngineProgram.compile_runner`
    (``start == 0``, ``stop == len(steps)``), or one pipeline stage for
    :meth:`EngineProgram.compile_stage_runner`.

    ``fn`` maps an int8 (int16 for bits=16) activation batch
    ``[B, H, W, C]`` to the range's output — raw final accumulators when
    the range includes the last engine, int8 activations otherwise —
    with weights/bias/shift schedules captured as constants, so a fixed
    batch shape compiles exactly once (``cache_size`` is the recompile
    guard the tests pin). Host-side quantize-in and argmax/dequant-out
    live here so the executor can overlap them with device compute; they
    exist only at the matching end of the chain (first / last stage).
    """

    program: EngineProgram
    route: str
    donate: bool
    fn: Callable[[jnp.ndarray], jnp.ndarray]
    start: int = 0
    stop: int = -1          # -1 == len(program.steps) (whole chain)
    device: object = None   # jax.Device pin (None = backend default)

    def __post_init__(self):
        if self.stop < 0:
            self.stop = len(self.program.steps)

    @property
    def is_first(self) -> bool:
        return self.start == 0

    @property
    def is_last(self) -> bool:
        return self.stop == len(self.program.steps)

    def quantize(self, x: np.ndarray) -> np.ndarray:
        """Host-side quantize onto the program's frozen input format
        (numpy twin of ``quant.quantize_to_exponent`` — bit-identical).
        Only the first stage consumes float frames."""
        if not self.is_first:
            raise ValueError(
                f"stage [{self.start}, {self.stop}) does not start the "
                f"chain; it consumes the previous stage's quantized "
                f"activations, not float frames")
        return quant.quantize_to_exponent_np(
            x, self.program.e_input, self.program.bits)

    def __call__(self, xq) -> jnp.ndarray:
        """Dispatch one quantized batch; returns the device future of the
        final accumulators (async — block or fetch to synchronize). With
        donation on, a jnp input is copied first — ``jnp.asarray`` would
        alias the caller's buffer, and donating that alias invalidates
        the caller's array (host numpy input is always staged fresh).
        A ``device`` pin commits the input there first, so jit executes
        the stage on that device. The donation guard copies only when
        the input would otherwise alias: ``device_put`` onto the
        array's *current* device can return the same buffer, but a
        cross-device transfer already yields a fresh one — copying
        there too would waste an activation copy per micro-batch on
        the placed multi-device hot path."""
        if self.donate and isinstance(xq, jax.Array) and \
                (self.device is None or xq.devices() == {self.device}):
            xq = jnp.array(xq, copy=True)
        if self.device is not None:
            xq = jax.device_put(xq, self.device)
        return self.fn(jnp.asarray(xq))

    def dequantize(self, acc) -> np.ndarray:
        """Raw final accumulators -> float32 logits on their exact po2
        scale (host side). Only the last stage emits accumulators."""
        if not self.is_last:
            raise ValueError(
                f"stage [{self.start}, {self.stop}) does not end the "
                f"chain; it emits quantized activations, not final "
                f"accumulators")
        acc = np.asarray(acc)
        scale = self.program.out_scale()
        return acc.astype(np.float32) * scale.reshape(
            (1,) * (acc.ndim - 1) + (-1,))

    def logits(self, x) -> np.ndarray:
        """Blocking convenience: float frames -> float logits. Bit-identical
        to ``program.run`` on the same route's arithmetic."""
        return self.dequantize(self(self.quantize(np.asarray(x))))

    def classify(self, x) -> np.ndarray:
        """Blocking convenience: float frames -> int class ids."""
        out = self.logits(x)
        return np.argmax(out.reshape(out.shape[0], -1), axis=-1)

    def cache_size(self) -> int:
        """Number of distinct XLA executables behind ``fn`` (recompile
        guard: one batch shape must stay at 1). Reads a private JAX API;
        returns -1 ("unknown") on jax versions that don't expose it
        rather than breaking the serve path."""
        probe = getattr(self.fn, "_cache_size", None)
        return int(probe()) if callable(probe) else -1


def kernel_available(bits: int = 8) -> tuple[bool, str]:
    """Probe the Pallas kernel route once: importable and applicable."""
    if bits > 8:
        return False, ("the Pallas PE-array kernel is int8; bits=16 runs "
                       "the jnp oracle (48-bit DSP accumulation model)")
    try:
        from repro.kernels.conv2d_int8 import ops  # noqa: F401
    except Exception as e:  # pragma: no cover - depends on install
        return False, f"Pallas conv2d_int8 kernel unavailable: {e!r}"
    return True, ""


def require_kernel(bits: int = 8) -> None:
    """Raise up front (at compile/jit time, not per step) when the kernel
    route is requested but cannot run — a CI run asking for the kernel
    must not silently green-light the oracle."""
    ok, why = kernel_available(bits)
    if not ok:
        raise NotImplementedError(why)


# ---------------------------------------------------------------------------
# Step executors
# ---------------------------------------------------------------------------


def _pool_int(xq: jnp.ndarray, step: EngineStep) -> jnp.ndarray:
    """Max pool directly on the integer activations — max is monotone in
    the po2 format, so this is exact and the exponent passes through."""
    lyr = step.layer
    lo, hi = step.pad
    # bits=16 models accumulators in float32, so the last engine's output
    # (requantize=False) may reach a trailing pool as floats.
    init = jnp.array(-jnp.inf if jnp.issubdtype(xq.dtype, jnp.floating)
                     else jnp.iinfo(xq.dtype).min, xq.dtype)
    return jax.lax.reduce_window(
        xq, init, jax.lax.max,
        (1, lyr.kernel, lyr.kernel, 1), (1, lyr.stride, lyr.stride, 1),
        ((0, 0), (lo, hi), (lo, hi), (0, 0)))


def _step_kernel(xq: jnp.ndarray, step: EngineStep,
                 interpret: bool) -> jnp.ndarray:
    from repro.kernels.conv2d_int8.ops import conv2d_int8, fc_int8
    lyr = step.layer
    emit = not step.requantize
    if step.kind == "fc":
        return fc_int8(xq.reshape(xq.shape[0], -1), step.wq, step.shift,
                       step.bias_q, relu=step.relu, interpret=interpret,
                       emit_int32=emit)
    return conv2d_int8(xq, step.wq, step.shift, step.bias_q,
                       stride=lyr.stride, padding=(step.pad, step.pad),
                       groups=lyr.groups, relu=step.relu,
                       interpret=interpret, emit_int32=emit)


def _step_oracle(xq: jnp.ndarray, step: EngineStep, bits: int) -> jnp.ndarray:
    """Pure-jnp integer oracle with the identical fused epilogue. For
    bits<=8 the arithmetic is exact int32 (bit-identical to the Pallas
    kernel); bits=16 models the DSP48's 48-bit accumulate in float32."""
    lyr = step.layer
    exact = bits <= 8
    acc_dt = jnp.int32 if exact else jnp.float32
    if step.kind == "fc":
        acc = jnp.matmul(xq.reshape(xq.shape[0], -1).astype(acc_dt),
                         step.wq.astype(acc_dt),
                         preferred_element_type=acc_dt)
    else:
        lo, hi = step.pad
        acc = jax.lax.conv_general_dilated(
            xq.astype(acc_dt), step.wq.astype(acc_dt),
            (lyr.stride, lyr.stride), ((lo, hi), (lo, hi)),
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            feature_group_count=lyr.groups,
            preferred_element_type=acc_dt)
    if exact:
        # Same fused epilogue as the kernel, from the shared oracle.
        return _epilogue_int32(acc, step)
    bias = step.bias_q.astype(acc_dt)
    acc = acc + bias.reshape((1,) * (acc.ndim - 1) + (-1,))
    if step.relu:
        acc = jnp.maximum(acc, 0)
    if not step.requantize:
        return acc
    # bits=16 only from here: floor(acc / 2^sh) — shifter truncation in float.
    sh = step.shift.reshape((1,) * (acc.ndim - 1) + (-1,))
    y = jnp.floor(acc * jnp.exp2(-sh.astype(jnp.float32)))
    qmax = 2 ** (bits - 1) - 1
    return jnp.clip(y, -qmax - 1, qmax).astype(jnp.int16)


# Max MAC terms per float32 partial sum on the exact-f32 route: every
# int8*int8 product has |p| <= 2^14, and float32 represents all integers
# up to 2^24 exactly, so chains of <= 2^24 / 2^14 = 1024 products (and any
# partial reordering XLA picks) stay bit-exact.
_F32_CHUNK_MACS = 1024


def _step_exact_f32(xq: jnp.ndarray, step: EngineStep) -> jnp.ndarray:
    """int8 conv/fc via *exact* float32 arithmetic: the reduction dim is
    chunked so no partial sum can exceed 2^24, chunk results are summed in
    int32, and the identical fused epilogue requantizes. Bit-identical to
    the int32 oracle and the Pallas kernel, but it reaches the backend's
    fast f32 conv/GEMM code paths (XLA CPU lowers integer convs to slow
    generic loops).

    The proof needs *true* IEEE float32 MACs, so every dot/conv here pins
    ``Precision.HIGHEST``: with the default precision XLA lowers f32 on
    Ampere+ GPUs to TF32 and on TPU to bf16 MXU passes, whose ~8-11-bit
    mantissas cannot hold the 15-24-bit integer partial sums. HIGHEST
    forces full-f32 arithmetic on GPU and the f32-exact multi-pass
    algorithm on TPU."""
    lyr = step.layer
    wq = step.wq
    if step.kind == "fc":
        x2 = xq.reshape(xq.shape[0], -1).astype(jnp.float32)
        wf = wq.astype(jnp.float32)
        acc = jnp.zeros((x2.shape[0], wq.shape[-1]), jnp.int32)
        for k0 in range(0, x2.shape[1], _F32_CHUNK_MACS):
            part = jnp.matmul(x2[:, k0:k0 + _F32_CHUNK_MACS],
                              wf[k0:k0 + _F32_CHUNK_MACS],
                              precision=jax.lax.Precision.HIGHEST)
            acc = acc + part.astype(jnp.int32)
    else:
        R, S, Cg, M = wq.shape
        xf = xq.astype(jnp.float32)
        wf = wq.astype(jnp.float32)
        lo, hi = step.pad
        groups = lyr.groups
        c_chunk = max(1, _F32_CHUNK_MACS // (R * S))
        acc = None
        for c0 in range(0, Cg, c_chunk):
            cc = min(c_chunk, Cg - c0)
            if groups == 1:
                xs = xf[..., c0:c0 + cc]
            else:
                xs = jnp.concatenate(
                    [xf[..., g * Cg + c0:g * Cg + c0 + cc]
                     for g in range(groups)], axis=-1)
            part = jax.lax.conv_general_dilated(
                xs, wf[:, :, c0:c0 + cc, :],
                (lyr.stride, lyr.stride), ((lo, hi), (lo, hi)),
                dimension_numbers=("NHWC", "HWIO", "NHWC"),
                feature_group_count=groups,
                precision=jax.lax.Precision.HIGHEST).astype(jnp.int32)
            acc = part if acc is None else acc + part
    return _epilogue_int32(acc, step)


def _epilogue_int32(acc: jnp.ndarray, step: EngineStep) -> jnp.ndarray:
    """The shared fused output stage on exact int32 accumulators."""
    if step.requantize:
        from repro.kernels.conv2d_int8.ref import requantize_ref
        flat = requantize_ref(acc.reshape(-1, acc.shape[-1]), step.shift,
                              step.bias_q, step.relu)
        return flat.reshape(acc.shape)
    acc = acc + step.bias_q.reshape((1,) * (acc.ndim - 1) + (-1,))
    if step.relu:
        acc = jnp.maximum(acc, 0)
    return acc


# ---------------------------------------------------------------------------
# Compilation
# ---------------------------------------------------------------------------


def compile_model(model: CNNModel, params: Params | None = None, *,
                  theta: int = DEFAULT_THETA, bits: int = 8,
                  calib_batch: jnp.ndarray | None = None,
                  bram_total: int | None = DEFAULT_BRAM,
                  bandwidth_bytes: float = DEFAULT_BW,
                  freq_hz: float = DEFAULT_FREQ,
                  bram_weights: bool = False,
                  objective: str = "optimal") -> EngineProgram:
    """Workload -> allocation -> execution, compiled once.

    Without ``params`` this produces a *plan-only* program (Algorithms 1/2
    only) for the simulator and benchmarks. With ``params`` (and a
    ``calib_batch`` for activation ranges) the program is fully lowered and
    runnable. ``bram_total=None`` skips Algorithm 2 (compute allocation
    only, all K=1). ``bram_weights=True`` makes Algorithm 2 charge weight
    buffers against the BRAM budget and pin hot weight sets on-chip (the
    Table I BRAM-column model; plan-only analytics, never the arithmetic).
    """
    workloads = model.layer_workloads(weight_bits=bits)
    allocs = allocate_compute(workloads, theta, objective=objective)
    if bram_total is not None:
        allocate_buffers(allocs, bram_total=bram_total,
                         bandwidth_bytes=bandwidth_bytes, freq_hz=freq_hz,
                         act_bytes=bits // 8, weights=bram_weights)
    prog = EngineProgram(model=model, bits=bits, theta_total=theta,
                         allocs=allocs, freq_hz=freq_hz)
    if params is None:
        return prog

    if calib_batch is None:
        raise ValueError("compiling an executable program needs a "
                         "calib_batch to freeze activation formats")
    amax: dict[str, float] = {}
    float_forward(params, model, calib_batch, record=amax)
    prog.e_input = quant.po2_exponent(amax["__input__"], bits)
    prog.steps = _lower(model, params, amax, prog.e_input, bits)
    return prog


def _lower(model: CNNModel, params: Params, amax: dict[str, float],
           e_input: int, bits: int) -> list[EngineStep]:
    steps: list[EngineStep] = []
    compute = [l for l in model.layers if l.kind != "pool"]
    last = compute[-1]
    hw = model.input_hw
    e_act = e_input
    for lyr in model.layers:
        pad = lyr.padding(hw)
        if lyr.kind == "pool":
            steps.append(EngineStep(name=lyr.name, kind="pool", layer=lyr,
                                    pad=pad))
            hw = lyr.out_hw(hw)
            continue
        w = params[lyr.name]["w"]
        b = params[lyr.name]["b"]
        e_w = np.asarray(quant.po2_scale(w, axis=-1, bits=bits), np.int64)
        is_last = lyr is last
        e_out = quant.po2_exponent(amax[lyr.name], bits)
        # Floor each channel's weight format so (a) its bias fits the
        # int32 accumulator and (b) the output shift stays within the
        # 31-bit shifter. Without this, a channel with numerically-dead
        # weights but a significant bias would get an absurdly fine
        # accumulator scale, saturating bias_q and silently dropping the
        # bias; flooring e_w instead rounds the dead weights to zero and
        # keeps the bias exactly representable.
        b_np = np.asarray(b, np.float64)
        nz = np.abs(b_np) > 0
        b_mag = np.full(b_np.shape, -(10 ** 9), np.int64)
        b_mag[nz] = np.ceil(np.log2(np.abs(b_np[nz])))
        e_w = np.maximum(e_w, np.maximum(b_mag - 30, e_out - 31) - e_act)
        # Quantize weights once onto the (possibly floored) formats.
        qmax = 2 ** (bits - 1) - 1
        scale = jnp.exp2(-jnp.asarray(e_w, jnp.float32)).reshape(
            (1,) * (w.ndim - 1) + (-1,))
        wq = jnp.clip(jnp.round(w * scale), -qmax - 1, qmax).astype(
            jnp.int8 if bits <= 8 else jnp.int16)
        # Bias pre-scaled onto this engine's 32-bit accumulator format
        # (value = q * 2^(e_in + e_w[m])).
        acc_e = e_act + e_w
        bias_q = np.clip(np.round(b_np / np.exp2(acc_e)),
                         np.iinfo(np.int32).min, np.iinfo(np.int32).max
                         ).astype(np.int32)
        shift = np.clip(e_out - acc_e, -31, 31).astype(np.int32)
        steps.append(EngineStep(
            name=lyr.name, kind=lyr.kind, layer=lyr, pad=pad,
            wq=jnp.asarray(wq), bias_q=jnp.asarray(bias_q),
            shift=jnp.asarray(shift), e_in=e_act, e_w=e_w, e_out=e_out,
            relu=not is_last, requantize=not is_last))
        e_act = e_out
        hw = lyr.out_hw(hw)
    return steps
