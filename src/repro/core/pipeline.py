"""Flexible layer-wise pipeline executor (the paper's architecture on a TPU
mesh).

The pod's ``model`` axis is factored into ``stage x tp`` (chosen by the
mesh-mode allocator, core/allocator.plan_pipeline — the Algorithm-1
analogue). All stages are resident simultaneously; microbatches stream
through via ``lax.ppermute`` on the stage axis (the activation line-buffer
analogue), with a GPipe fill/drain schedule driven by ``lax.scan`` so the
whole computation is reverse-differentiable. Within a stage, layers run
Megatron-style tensor parallel over the ``tp`` axis with manual psums.

Embedding and LM head run *outside* the shard_map body (sharded over the
full stage*tp product via NamedSharding) so their large vocab GEMMs are
computed once at full parallelism instead of once per stage per tick — the
analogue of the paper keeping the FC engines out of the row pipeline.

Correspondence to the FPGA original (DESIGN.md §2): engines = device
groups, cycles = seconds, K-row groups = microbatches; the flexible
activation buffer's producer/consumer re-layout becomes the inter-stage
collective, which is what frees the allocator to give different stages
different parallelisms (DNNBuilder's constraint, lifted).
"""

from __future__ import annotations

import dataclasses
import math
import re
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import recurrent as R

from repro.compat import shard_map

Params = dict[str, Any]

SUPPORTED_UNIT_KINDS = ("attn", "attn_local", "moe", "mla", "mla_moe",
                        "rwkv")


def make_pipeline_mesh(n_data: int, n_stage: int, n_tp: int,
                       n_pod: int = 1) -> Mesh:
    """Factor the pod's model axis into (stage, tp); same devices as the
    production (data, model) mesh, viewed as the pipeline grid."""
    shape = (n_pod, n_data, n_stage, n_tp) if n_pod > 1 else \
        (n_data, n_stage, n_tp)
    axes = (("pod", "data", "stage", "tp") if n_pod > 1 else
            ("data", "stage", "tp"))
    return jax.make_mesh(shape, axes)


# ---------------------------------------------------------------------------
# Stage-stacked parameters
# ---------------------------------------------------------------------------


def stage_stack(unit_params: Params, boundaries: tuple[int, ...]):
    """[n_units, ...] leaves -> ([S, Lmax, ...] padded, mask [S, Lmax]).

    `boundaries` may be non-uniform — that is Algorithm 1's output when the
    units (or the stage prologue/epilogue work) are heterogeneous."""
    S = len(boundaries) - 1
    counts = [boundaries[i + 1] - boundaries[i] for i in range(S)]
    lmax = max(counts)
    idx = np.zeros((S, lmax), np.int32)
    mask = np.zeros((S, lmax), np.bool_)
    for s in range(S):
        for j in range(lmax):
            idx[s, j] = boundaries[s] + min(j, max(counts[s] - 1, 0))
            mask[s, j] = j < counts[s]
    stacked = jax.tree.map(lambda t: t[idx], unit_params)
    return stacked, jnp.asarray(mask)


def uniform_boundaries(n_units: int, S: int) -> tuple[int, ...]:
    base, rem = divmod(n_units, S)
    bounds = [0]
    for s in range(S):
        bounds.append(bounds[-1] + base + (1 if s < rem else 0))
    return tuple(bounds)


# ---------------------------------------------------------------------------
# Manual-TP unit application (runs inside shard_map)
# ---------------------------------------------------------------------------


def _tp_view(cfg: ModelConfig, T: int) -> ModelConfig:
    """Per-tp-rank view: heads / ff / experts divided by T (kv heads
    replicated when T > n_kv_heads)."""
    return cfg.scaled(
        n_heads=cfg.n_heads // T,
        n_kv_heads=(cfg.n_kv_heads // T if cfg.n_kv_heads % T == 0
                    else cfg.n_kv_heads),
        d_ff=cfg.d_ff // T,
        moe_n_experts=(cfg.moe_n_experts // T if cfg.moe_n_experts else 0),
    )


def _apply_unit_tp(kind: str, cfg: ModelConfig, lp: Params, x, positions,
                   T: int):
    """One transformer unit, tensor-parallel over mesh axis 'tp'. Parameter
    leaves arrive pre-sliced; block outputs are psummed so the residual
    stream stays replicated within the stage."""
    lcfg = _tp_view(cfg, T)
    h_in = L.rms_norm(lp["ln1"], x)
    if kind == "rwkv":
        h, _ = R.rwkv6_block_apply(lp["rwkv"], lcfg, h_in, state=None)
        x = x + jax.lax.psum(h, "tp")
        h2, _ = R.rwkv6_channel_mix(lp["rwkv"], L.rms_norm(lp["ln2"], x),
                                    jnp.zeros_like(x[:, 0]))
        return x + jax.lax.psum(h2, "tp")
    if kind in ("mla", "mla_moe"):
        h, _ = L.mla_apply(lp["attn"], lcfg, h_in, positions)
    else:
        h, _ = L.gqa_apply(lp["attn"], lcfg, h_in, positions,
                           window=cfg.window if kind == "attn_local" else 0)
    x = x + jax.lax.psum(h, "tp")
    h_in2 = L.rms_norm(lp["ln2"], x)
    if kind.endswith("moe"):
        h2 = _moe_apply_tp(lp["mlp"], cfg, h_in2, T)
    else:
        h2 = L.mlp_apply(lp["mlp"], h_in2, cfg.mlp_kind)
    return x + jax.lax.psum(h2, "tp")


def _moe_apply_tp(p: Params, cfg, x, T):
    """Expert-parallel MoE: identical routing on every tp rank (router
    replicated); each rank runs its E/T local experts; the caller's psum
    combines (EP without an explicit all-to-all — the dispatch stays local
    because activations are tp-replicated)."""
    B, S, D = x.shape
    E, k = cfg.moe_n_experts, cfg.moe_top_k
    E_loc = E // T
    Tk = B * S
    C = max(1, int(math.ceil(k * Tk / E * cfg.moe_capacity_factor)))
    xt = x.reshape(Tk, D)
    logits = L.apply_dense(p["router"], xt.astype(jnp.float32))
    gates = jax.nn.softmax(logits, -1)
    topv, topi = jax.lax.top_k(gates, k)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)
    off = jax.lax.axis_index("tp") * E_loc
    flat_e = topi.reshape(-1) - off
    flat_w = topv.reshape(-1).astype(xt.dtype)
    in_range = (flat_e >= 0) & (flat_e < E_loc)
    flat_e_c = jnp.where(in_range, flat_e, E_loc)
    order = jnp.argsort(flat_e_c)
    tok_of_slot = order // k
    counts = jax.ops.segment_sum(in_range.astype(jnp.int32), flat_e_c,
                                 num_segments=E_loc + 1)[:E_loc]
    offsets = jnp.cumsum(counts) - counts
    slot = offsets[:, None] + jnp.arange(C)[None, :]
    valid = (jnp.arange(C)[None, :] < counts[:, None]) & (slot < Tk * k)
    slot = jnp.clip(slot, 0, Tk * k - 1)
    tok_idx = tok_of_slot[slot]
    xe = jnp.take(xt, tok_idx.reshape(-1), axis=0).reshape(E_loc, C, D)
    xe = xe * valid[..., None].astype(xt.dtype)
    h = jnp.einsum("ecd,edf->ecf", xe, p["wi"])
    g = jnp.einsum("ecd,edf->ecf", xe, p["wg"])
    ye = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * h, p["wo"])
    w_slot = flat_w[order][slot] * valid.astype(xt.dtype)
    yt = jnp.zeros((Tk, D), xt.dtype).at[tok_idx.reshape(-1)].add(
        (ye * w_slot[..., None]).reshape(E_loc * C, D))
    y = yt.reshape(B, S, D)
    if "shared" in p:
        y = y + L.mlp_apply(p["shared"], x, "swiglu")
    return y


def _tp_dim_for(path: str, ndim: int, cfg: ModelConfig, T: int,
                shape: tuple) -> int | None:
    """Which dim of a stacked [S, Lmax, ...] unit leaf is tp-sharded.

    Patterns are anchored at a path-segment boundary so e.g. `cm_wv/w`
    (row-sharded) never matches the generic `wv/w` column rule."""
    col = [r"(^|/)(wq|wk|wv)/w$", r"mlp/(wi|wg)/w$", r"(wq_b|wkv_b)/w$",
           r"shared/(wi|wg)/w$", r"rwkv/(wr|wk|wv|wg)/w$",
           r"rwkv/cm_wk/w$"]
    row = [r"(^|/)wo/w$", r"shared/wo/w$", r"rwkv/cm_wv/w$"]
    if re.search(r"mlp/(wi|wg|wo)$", path):        # MoE stacks [S,L,E,D,F]
        return 2
    if re.search(r"rwkv/(w0|ln_x_scale|ln_x_bias|dec_w2)$", path) \
            or re.search(r"(^|/)(wq|wk|wv|wi|wg)/b$", path):
        return ndim - 1
    if re.search(r"rwkv/u$", path):
        return ndim - 2
    for pat in row:
        if re.search(pat, path):
            return ndim - 2
    for pat in col:
        if re.search(pat, path):
            if re.search(r"(^|/)(wk|wv)/w$", path) \
                    and cfg.n_kv_heads % T != 0:
                return None                         # replicate small kv
            return ndim - 1
    return None


def _unit_specs(cfg: ModelConfig, T: int, units_shape) -> Any:
    def one(path, leaf):
        pstr = "/".join(str(getattr(k, "key", k)) for k in path)
        d = _tp_dim_for(pstr, leaf.ndim, cfg, T, leaf.shape)
        dims: list = ["stage"] + [None] * (leaf.ndim - 1)
        if d is not None and leaf.shape[d] % T == 0:
            dims[d] = "tp"
        return P(*dims)
    return jax.tree_util.tree_map_with_path(one, units_shape)


# ---------------------------------------------------------------------------
# The pipelined body + outer loss
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PipelineContext:
    cfg: ModelConfig
    unit_kind: str
    S: int                  # stages
    T: int                  # tensor parallel within stage
    n_micro: int
    remat: bool = True


def pipeline_body_fn(ctx: PipelineContext, mesh: Mesh, units_shape):
    """shard_mapped GPipe body: x0 [B,Seq,D] -> ys [S, B, Seq, D] (take
    [-1] outside). Stage s applies its unit slice; microbatches advance via
    ppermute each tick."""
    cfg, S, T, K = ctx.cfg, ctx.S, ctx.T, ctx.n_micro
    batch_axes = ("pod", "data") if "pod" in mesh.shape else ("data",)
    pos_ndim = 3 if cfg.mrope else 2
    unit_specs = _unit_specs(cfg, T, units_shape)

    @partial(shard_map, mesh=mesh,
             in_specs=(unit_specs, P("stage", None),
                       P(batch_axes, None, None), P(batch_axes, None)
                       if pos_ndim == 2 else P(batch_axes, None, None)),
             out_specs=P("stage", batch_axes, None, None),
             check_vma=False)
    def body(units, unit_mask, x0, positions):
        Bl, Seq, D = x0.shape
        mbB = Bl // K
        x_mb = x0.reshape(K, mbB, Seq, D)
        pos_mb = positions.reshape((K, mbB, Seq) + ((3,) if pos_ndim == 3
                                                    else ()))
        stage = jax.lax.axis_index("stage")
        my_units = jax.tree.map(lambda t: t[0], units)
        my_mask = unit_mask[0]
        perm = [(i, i + 1) for i in range(S - 1)]

        def apply_stage(x, pos):
            def unit_body(x, uj):
                up, msk = uj
                y = _apply_unit_tp(ctx.unit_kind, cfg, up, x, pos, T)
                return jnp.where(msk, y, x), None
            fn = jax.checkpoint(unit_body) if ctx.remat else unit_body
            x, _ = jax.lax.scan(fn, x, (my_units, my_mask))
            return x

        def tick(carry, t):
            buf, out = carry
            m = jnp.clip(t - stage, 0, K - 1)
            xm = jax.lax.dynamic_index_in_dim(x_mb, m, 0, False)
            pm = jax.lax.dynamic_index_in_dim(pos_mb, m, 0, False)
            x_in = jnp.where(stage == 0, xm, buf)
            y = apply_stage(x_in, pm)
            take = ((t - stage >= 0) & (t - stage < K) & (stage == S - 1))
            upd = jax.lax.dynamic_update_slice_in_dim(
                out, y[None].astype(out.dtype), m, 0)
            out = jnp.where(take, upd, out)
            buf = jax.lax.ppermute(y, "stage", perm) if S > 1 else y
            return (buf, out), None

        buf0 = jnp.zeros((mbB, Seq, D), x0.dtype)
        out0 = jnp.zeros((K, mbB, Seq, D), x0.dtype)
        (_, out), _ = jax.lax.scan(tick, (buf0, out0),
                                   jnp.arange(K + S - 1))
        return out.reshape(Bl, Seq, D)[None]      # [1(stage), Bl, Seq, D]

    return body


def pipeline_loss_fn(ctx: PipelineContext, mesh: Mesh, units_shape,
                     unit_mask=None):
    """Full pipelined training loss: embed -> pipeline body -> head + CE.

    Embed/head are sharded over ("stage","tp") jointly (= the pod's model
    axis) via sharding constraints, mirroring the paper's choice to keep FC
    engines outside the row pipeline."""
    cfg = ctx.cfg
    body = pipeline_body_fn(ctx, mesh, units_shape)
    batch_axes = ("pod", "data") if "pod" in mesh.shape else ("data",)
    vp = ("stage", "tp")

    def loss(params, batch):
        if "tokens" in batch:
            tokens = batch["tokens"]
            B, Seq = tokens.shape
            emb = jax.lax.with_sharding_constraint(
                params["embed"], NamedSharding(mesh, P(vp, None)))
            x0 = jnp.take(emb, tokens, axis=0)
        else:
            x0 = batch["embeds"]
            B, Seq = x0.shape[:2]
        x0 = jax.lax.with_sharding_constraint(
            x0, NamedSharding(mesh, P(batch_axes, None, None)))
        if "positions" in batch:
            positions = batch["positions"]
        else:
            positions = jnp.broadcast_to(jnp.arange(Seq)[None], (B, Seq))
            if cfg.mrope:
                positions = jnp.broadcast_to(positions[..., None],
                                             (B, Seq, 3))
        mask = unit_mask if unit_mask is not None else params["unit_mask"]
        ys = body(params["units"], mask, x0, positions)
        y = ys[-1]
        y = L.rms_norm(params["final_norm"], y)
        if cfg.tie_embeddings:
            head = jax.lax.with_sharding_constraint(
                params["embed"].T, NamedSharding(mesh, P(None, vp)))
        else:
            head = jax.lax.with_sharding_constraint(
                params["lm_head"]["w"], NamedSharding(mesh, P(None, vp)))
        logits = (y @ head).astype(jnp.float32)
        labels = batch["labels"]
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
        mask = (labels >= 0).astype(jnp.float32)
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)

    return loss


def pipeline_prefill_fn(ctx: PipelineContext, mesh: Mesh, units_shape,
                        unit_mask=None):
    """Forward-only pipelined prefill: embed -> body -> last-token logits.

    (Serving would additionally emit the per-stage KV caches; the collective
    and compute structure measured here is identical — the cache write is a
    local store.)"""
    cfg = ctx.cfg
    body = pipeline_body_fn(ctx, mesh, units_shape)
    batch_axes = ("pod", "data") if "pod" in mesh.shape else ("data",)
    vp = ("stage", "tp")

    def prefill(params, batch):
        tokens = batch["tokens"]
        B, Seq = tokens.shape
        emb = jax.lax.with_sharding_constraint(
            params["embed"], NamedSharding(mesh, P(vp, None)))
        x0 = jax.lax.with_sharding_constraint(
            jnp.take(emb, tokens, axis=0),
            NamedSharding(mesh, P(batch_axes, None, None)))
        positions = jnp.broadcast_to(jnp.arange(Seq)[None], (B, Seq))
        if cfg.mrope:
            positions = jnp.broadcast_to(positions[..., None], (B, Seq, 3))
        mask = unit_mask if unit_mask is not None else params["unit_mask"]
        ys = body(params["units"], mask, x0, positions)
        y = L.rms_norm(params["final_norm"], ys[-1][:, -1:])
        if cfg.tie_embeddings:
            head = params["embed"].T
        else:
            head = params["lm_head"]["w"]
        head = jax.lax.with_sharding_constraint(
            head, NamedSharding(mesh, P(None, vp)))
        return (y @ head).astype(jnp.float32)[:, 0]

    return prefill


# ---------------------------------------------------------------------------
# Building pipeline params from a config
# ---------------------------------------------------------------------------


def dominant_segment(cfg: ModelConfig):
    from repro.models import transformer as TF
    segs = TF.segments(cfg)
    return max(segs, key=lambda s: s.count)


def supports_pipeline(cfg: ModelConfig) -> bool:
    return dominant_segment(cfg).kind in SUPPORTED_UNIT_KINDS


def build_pipeline_params(cfg: ModelConfig, S: int,
                          boundaries: tuple[int, ...] | None = None,
                          abstract: bool = False) -> tuple[Params, str]:
    """Returns (params, unit_kind). The dominant homogeneous segment forms
    the pipeline units; remaining small segments are folded into the nearest
    stage... (v1: the dominant segment covers the pipeline; for every
    assigned arch it is >= 93% of FLOPs — leading dense layers of the MoE
    archs ride along in stage 0's unit list only if same-kind)."""
    from repro.models import transformer as TF

    main = dominant_segment(cfg)
    if main.kind not in SUPPORTED_UNIT_KINDS:
        raise ValueError(f"pipeline unsupported for unit kind {main.kind}")
    bounds = boundaries or uniform_boundaries(main.count, S)
    dtype = jnp.dtype(cfg.dtype)

    def make():
        key = jax.random.PRNGKey(0)
        units = [TF._layer_init(main.kind, cfg, jax.random.fold_in(key, i),
                                dtype) for i in range(main.count)]
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *units)
        staged, mask = stage_stack(stacked, bounds)
        return {
            "embed": (jax.random.normal(key, (cfg.vocab, cfg.d_model),
                                        jnp.float32) * 0.02).astype(dtype),
            "units": staged,
            "unit_mask": mask,
            "final_norm": L.rms_norm_init(cfg.d_model, dtype),
            **({} if cfg.tie_embeddings else
               {"lm_head": L.dense(jax.random.fold_in(key, 99),
                                   cfg.d_model, cfg.vocab, dtype)}),
        }

    params = jax.eval_shape(make) if abstract else make()
    return params, main.kind
