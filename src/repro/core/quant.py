"""Channel-wise fixed-point quantization (paper Section 3.3, Fig. 3(c)).

The paper computes int8/int16 MACs into 32-bit partial sums; different
channels may use different fixed-point formats (power-of-2 scales = "shift
bits"), aligned by left-shifters before accumulation, then right-shifted and
truncated when writing output activations. We reproduce exactly that
arithmetic so the Pallas conv kernel and the pure-jnp oracle agree bit-for-bit
with the hardware-style pipeline.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def po2_scale(x: jnp.ndarray, axis, bits: int = 8) -> jnp.ndarray:
    """Per-channel power-of-2 exponent e such that x / 2^e fits int<bits>.

    Returns integer exponents (can be negative). Reduction over all axes
    except `axis`.
    """
    qmax = 2 ** (bits - 1) - 1
    red = tuple(i for i in range(x.ndim) if i != axis % x.ndim)
    amax = jnp.max(jnp.abs(x), axis=red, keepdims=False)
    amax = jnp.maximum(amax, 1e-12)
    # smallest e with amax / 2^e <= qmax
    e = jnp.ceil(jnp.log2(amax / qmax)).astype(jnp.int32)
    return e


def po2_exponent(amax: float, bits: int = 8) -> int:
    """Smallest integer e with ``amax / 2^e <= qmax`` — the frozen
    per-tensor activation format a calibration pass records."""
    import math
    qmax = 2 ** (bits - 1) - 1
    return math.ceil(math.log2(max(float(amax), 1e-12) / qmax))


def quantize_to_exponent(x: jnp.ndarray, e: int, bits: int = 8):
    """Quantize onto a *given* po2 format (compile-time frozen scale):
    ``q = clip(round(x / 2^e))`` as int8/int16."""
    qmax = 2 ** (bits - 1) - 1
    q = jnp.clip(jnp.round(x * (2.0 ** (-e))), -qmax - 1, qmax)
    return q.astype(jnp.int8 if bits <= 8 else jnp.int16)


def quantize_to_exponent_np(x, e: int, bits: int = 8):
    """Numpy twin of :func:`quantize_to_exponent` for host-side
    quantize-in (the serving executor overlaps it with device compute).
    Bit-identical: same float32 multiply, same round-half-to-even, same
    clip (``tests/test_executor.py::test_quantize_np_twin_bit_identical``
    pins the equivalence)."""
    import numpy as np
    qmax = 2 ** (bits - 1) - 1
    q = np.clip(np.rint(np.asarray(x, np.float32) * np.float32(2.0 ** (-e))),
                -qmax - 1, qmax)
    return q.astype(np.int8 if bits <= 8 else np.int16)


def quantize_po2(x: jnp.ndarray, axis: int, bits: int = 8):
    """-> (q int8/int16, e int32 per-channel): x ~= q * 2^e."""
    e = po2_scale(x, axis, bits)
    shape = [1] * x.ndim
    shape[axis % x.ndim] = -1
    scale = jnp.exp2(-e.astype(jnp.float32)).reshape(shape)
    qmax = 2 ** (bits - 1) - 1
    q = jnp.clip(jnp.round(x * scale), -qmax - 1, qmax)
    dt = jnp.int8 if bits <= 8 else jnp.int16
    return q.astype(dt), e


def dequantize_po2(q: jnp.ndarray, e: jnp.ndarray, axis: int) -> jnp.ndarray:
    shape = [1] * q.ndim
    shape[axis % q.ndim] = -1
    return q.astype(jnp.float32) * jnp.exp2(e.astype(jnp.float32)).reshape(shape)


def align_partial_sums(psum: jnp.ndarray, e_in: jnp.ndarray,
                       e_common: jnp.ndarray, axis: int) -> jnp.ndarray:
    """Left-shift partial sums of per-channel formats onto a common scale
    (the adder-tree alignment in Fig. 3(c)). int32 in, int32 out."""
    shape = [1] * psum.ndim
    shape[axis % psum.ndim] = -1
    sh = (e_in - e_common).reshape(shape)
    return jnp.left_shift(psum, jnp.maximum(sh, 0)) >> jnp.maximum(-sh, 0)


def saturating_signed_shift(acc32: jnp.ndarray,
                            shift: jnp.ndarray) -> jnp.ndarray:
    """``acc >> shift`` with truncation for ``shift >= 0`` and a
    *saturating* left shift for ``shift < 0`` — no int32 wraparound, so a
    downstream clip onto int8/int16 rails sees the true sign.

    The left-shift amount is capped at 16: every nonzero value shifted
    left 16 already exceeds the int16 (a fortiori int8) rails, so the cap
    is bit-neutral for any consumer clipping to <= 16-bit outputs, and it
    keeps the preimage clamp nondegenerate (at a full 31-bit shift the
    clamp bound collapses to 0 and would zero positive values). Plain jnp
    ops — shared by :func:`requantize_output` and the Pallas GEMM epilogue
    (`kernels/conv2d_int8/kernel.py`)."""
    sh = jnp.asarray(shift, jnp.int32)
    sl = jnp.minimum(jnp.maximum(-sh, 0), 16)
    lo32 = jnp.right_shift(jnp.iinfo(jnp.int32).min, sl)
    hi32 = jnp.right_shift(jnp.iinfo(jnp.int32).max, sl)
    return jnp.where(sh >= 0,
                     jnp.right_shift(acc32, jnp.minimum(sh, 31)),
                     jnp.left_shift(jnp.clip(acc32, lo32, hi32), sl))


def requantize_output(acc32: jnp.ndarray, e_acc: jnp.ndarray | int,
                      e_out: jnp.ndarray | int, bits: int = 8) -> jnp.ndarray:
    """Right-shift + truncate 32-bit accumulators to the output activation
    format (paper: "partial sums should be right shifted and truncated")."""
    y = saturating_signed_shift(acc32, jnp.asarray(e_out - e_acc, jnp.int32))
    qmax = 2 ** (bits - 1) - 1
    dt = jnp.int8 if bits <= 8 else jnp.int16
    return jnp.clip(y, -qmax - 1, qmax).astype(dt)
