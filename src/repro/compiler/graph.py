"""Framework-neutral CNN graph IR — the importer's front door.

The serving zoo executes :class:`~repro.core.workload.CNNModel` graphs
(a linear chain of conv / fc / pool engine layers with ReLU fused into
every non-final engine). Arbitrary CNNs arrive as *graphs* with explicit
activation and pooling nodes, so the importer needs a small neutral IR
between "whatever the source framework says" and "what the engine can
lower": typed nodes for ``conv`` / ``fc`` / ``relu`` / ``maxpool`` /
``avgpool`` / ``flatten`` / ``add``, NHWC shapes inferred and checked at
import time, and topological validation (defs before uses, arity, one
terminal output).

Two ingestion paths build this IR:

* :func:`from_spec` — a pure-Python JSON/dict graph spec (no new
  dependency; what the tests, the example, and CI exercise);
* :mod:`repro.compiler.onnx_import` — an optional ONNX reader, guarded
  by ``importlib`` so the no-onnx environment stays fully functional.

The IR deliberately represents *more* than the engine supports
(``avgpool``, ``add``): rejection with a typed
:class:`UnsupportedOpError` naming the offending node is the lowering
pass's job (:mod:`repro.compiler.lower`), while malformed structure and
shape mismatches are :class:`GraphError`\\ s raised here, at import.

Conventions: NHWC activations, square spatial dims (the engine's
``CNNModel`` carries one ``input_hw``), batch dimension implicit.
Weights may ride on nodes (``weight`` / ``bias`` attrs, numpy arrays:
conv HWIO, fc ``(in, out)``) — the ONNX path fills them, the JSON path
usually leaves them to seeded init at quantization time.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Mapping, Sequence

import numpy as np

INPUT = "input"                 # reserved name: the graph's input tensor

#: op -> (required attrs, optional attrs with defaults)
OP_ATTRS: dict[str, tuple[tuple[str, ...], dict[str, Any]]] = {
    "conv": (("out_channels", "kernel"),
             {"stride": 1, "padding": "same", "groups": 1,
              "in_channels": None, "weight": None, "bias": None}),
    "fc": (("out_features",),
           {"in_features": None, "weight": None, "bias": None}),
    "relu": ((), {}),
    "maxpool": (("kernel",), {"stride": None, "padding": "valid"}),
    "avgpool": (("kernel",), {"stride": None, "padding": "valid"}),
    "flatten": ((), {}),
    "add": ((), {}),
}
OPS = tuple(OP_ATTRS)
_BINARY_OPS = ("add",)


class GraphError(ValueError):
    """Malformed graph structure or a shape mismatch, rejected at
    import time (before any lowering or compilation)."""


class UnsupportedOpError(GraphError):
    """A node the importer cannot take — an op outside the IR, or (from
    the lowering pass) an IR op / attribute combination the engine
    cannot represent. Always names the node."""

    def __init__(self, node: str, why: str):
        self.node = node
        super().__init__(f"node {node!r}: {why}")


@dataclasses.dataclass(frozen=True)
class Node:
    """One typed IR node. ``attrs`` holds the op's validated attribute
    dict (schema per op in :data:`OP_ATTRS`, defaults filled in)."""

    op: str
    name: str
    inputs: tuple[str, ...]
    attrs: Mapping[str, Any] = dataclasses.field(default_factory=dict)

    def attr(self, key: str):
        return self.attrs.get(key)


def _square(node: str, what: str, v) -> int:
    """Accept an int or a square [k, k] pair; anything rectangular is a
    typed legalization failure (the engine's layers are R == S)."""
    if isinstance(v, bool):
        raise GraphError(f"node {node!r}: {what} must be an int, got {v!r}")
    if isinstance(v, int):
        if v <= 0:
            raise GraphError(f"node {node!r}: {what}={v} must be positive")
        return v
    if isinstance(v, (list, tuple)) and len(v) == 2:
        a, b = v
        if a != b:
            raise UnsupportedOpError(
                node, f"non-square {what} {list(v)} (the engine's layers "
                      f"are square: R == S)")
        return _square(node, what, a)
    raise GraphError(f"node {node!r}: {what} must be an int or [k, k], "
                     f"got {v!r}")


def resolve_padding(in_hw: int, kernel: int, stride: int, padding,
                    node: str) -> tuple[int, int, int]:
    """-> ``(lo, hi, out_hw)`` for one spatial dim under the declared
    padding: ``"same"`` (ceil(in/stride), TF SAME split), ``"valid"``
    (no padding), or a symmetric integer pad. Shared by shape inference
    here and re-derivation checks in the lowering pass."""
    if padding == "same":
        out = -(-in_hw // stride)
        need = max((out - 1) * stride + kernel - in_hw, 0)
        lo = need // 2
        return lo, need - lo, out
    if padding == "valid":
        if in_hw < kernel:
            raise GraphError(
                f"node {node!r}: kernel {kernel} exceeds input size "
                f"{in_hw} under 'valid' padding")
        return 0, 0, (in_hw - kernel) // stride + 1
    if isinstance(padding, int) and not isinstance(padding, bool):
        if padding < 0:
            raise GraphError(f"node {node!r}: padding {padding} < 0")
        out = (in_hw + 2 * padding - kernel) // stride + 1
        if out < 1:
            raise GraphError(
                f"node {node!r}: kernel {kernel} stride {stride} padding "
                f"{padding} leaves no output rows on input {in_hw}")
        return padding, padding, out
    raise GraphError(f"node {node!r}: padding must be 'same', 'valid' or "
                     f"a non-negative int, got {padding!r}")


@dataclasses.dataclass
class Graph:
    """A validated importer graph: topologically ordered typed nodes
    over one square NHWC input, with every node's output shape inferred
    (``shapes[name]`` is ``(h, w, c)`` spatial or ``(features,)`` flat;
    the reserved name ``"input"`` maps to the input tensor)."""

    name: str
    input_hw: int
    input_ch: int
    nodes: tuple[Node, ...]
    shapes: dict[str, tuple[int, ...]]
    output: str

    @classmethod
    def build(cls, name: str, input_hw: int, input_ch: int,
              nodes: Sequence[Node]) -> "Graph":
        """Validate structure + infer shapes (the import-time gate)."""
        if input_hw < 1 or input_ch < 1:
            raise GraphError(f"graph {name!r}: input {input_hw}x{input_hw}"
                             f"x{input_ch} is not a tensor")
        if not nodes:
            raise GraphError(f"graph {name!r} has no nodes")
        shapes: dict[str, tuple[int, ...]] = {
            INPUT: (input_hw, input_hw, input_ch)}
        consumed: dict[str, int] = {}
        for node in nodes:
            if node.op not in OPS:
                raise UnsupportedOpError(
                    node.name, f"unknown op {node.op!r} (importable ops: "
                               f"{', '.join(OPS)})")
            if node.name in shapes:
                raise GraphError(f"duplicate node name {node.name!r}"
                                 + (" (reserved)" if node.name == INPUT
                                    else ""))
            want_arity = 2 if node.op in _BINARY_OPS else 1
            if len(node.inputs) != want_arity:
                raise GraphError(
                    f"node {node.name!r}: op {node.op!r} takes "
                    f"{want_arity} input(s), got {list(node.inputs)}")
            for src in node.inputs:
                if src not in shapes:
                    raise GraphError(
                        f"node {node.name!r}: input {src!r} is not "
                        f"defined before use (nodes must be listed in "
                        f"topological order; the input tensor is "
                        f"{INPUT!r})")
                consumed[src] = consumed.get(src, 0) + 1
            shapes[node.name] = _infer_shape(node, shapes)
        terminals = [n.name for n in nodes if n.name not in consumed]
        if len(terminals) != 1:
            raise GraphError(
                f"graph {name!r} must have exactly one output (a single "
                f"unconsumed terminal node), found {len(terminals)}: "
                f"{terminals}")
        return cls(name=str(name), input_hw=int(input_hw),
                   input_ch=int(input_ch), nodes=tuple(nodes),
                   shapes=shapes, output=terminals[0])

    def consumers(self) -> dict[str, list[Node]]:
        out: dict[str, list[Node]] = {}
        for node in self.nodes:
            for src in node.inputs:
                out.setdefault(src, []).append(node)
        return out


def _infer_shape(node: Node, shapes: dict[str, tuple[int, ...]]
                 ) -> tuple[int, ...]:
    """Per-op NHWC shape inference with the import-time mismatch checks
    (declared channels/features vs producer, weight array shapes)."""
    a = node.attrs
    src = shapes[node.inputs[0]]
    if node.op == "conv":
        if len(src) != 3:
            raise GraphError(f"node {node.name!r}: conv needs a spatial "
                             f"(h, w, c) producer, got shape {src}")
        hw, _, cin = src
        k = _square(node.name, "kernel", a["kernel"])
        stride = _square(node.name, "stride", a["stride"])
        groups = int(a["groups"])
        cout = int(a["out_channels"])
        if a["in_channels"] is not None and int(a["in_channels"]) != cin:
            raise GraphError(
                f"node {node.name!r}: declared in_channels="
                f"{a['in_channels']} but producer {node.inputs[0]!r} "
                f"has {cin} channels")
        if groups < 1 or cin % groups or cout % groups:
            raise GraphError(
                f"node {node.name!r}: groups={groups} must divide "
                f"in_channels={cin} and out_channels={cout}")
        w = a["weight"]
        if w is not None and tuple(np.shape(w)) != (k, k, cin // groups,
                                                    cout):
            raise GraphError(
                f"node {node.name!r}: weight shape "
                f"{tuple(np.shape(w))} != HWIO "
                f"{(k, k, cin // groups, cout)}")
        _check_bias(node, cout)
        _, _, out = resolve_padding(hw, k, stride, a["padding"], node.name)
        return (out, out, cout)
    if node.op == "fc":
        if len(src) != 1:
            raise GraphError(
                f"node {node.name!r}: fc needs a flat (features,) "
                f"producer, got shape {src} — insert a 'flatten' node")
        (fin,) = src
        fout = int(a["out_features"])
        if a["in_features"] is not None and int(a["in_features"]) != fin:
            raise GraphError(
                f"node {node.name!r}: declared in_features="
                f"{a['in_features']} but producer {node.inputs[0]!r} "
                f"has {fin} features")
        w = a["weight"]
        if w is not None and tuple(np.shape(w)) != (fin, fout):
            raise GraphError(
                f"node {node.name!r}: weight shape "
                f"{tuple(np.shape(w))} != (in, out) {(fin, fout)}")
        _check_bias(node, fout)
        return (fout,)
    if node.op in ("maxpool", "avgpool"):
        if len(src) != 3:
            raise GraphError(f"node {node.name!r}: {node.op} needs a "
                             f"spatial producer, got shape {src}")
        hw, _, c = src
        k = _square(node.name, "kernel", a["kernel"])
        stride = _square(node.name, "stride",
                         a["stride"] if a["stride"] is not None else k)
        _, _, out = resolve_padding(hw, k, stride, a["padding"], node.name)
        return (out, out, c)
    if node.op == "flatten":
        return (int(np.prod(src)),)
    if node.op == "relu":
        return src
    if node.op == "add":
        other = shapes[node.inputs[1]]
        if src != other:
            raise GraphError(
                f"node {node.name!r}: add operands disagree: "
                f"{node.inputs[0]!r} {src} vs {node.inputs[1]!r} {other}")
        return src
    raise UnsupportedOpError(node.name, f"unknown op {node.op!r}")


def _check_bias(node: Node, cout: int) -> None:
    b = node.attrs.get("bias")
    if b is not None and tuple(np.shape(b)) != (cout,):
        raise GraphError(f"node {node.name!r}: bias shape "
                         f"{tuple(np.shape(b))} != ({cout},)")


# ---------------------------------------------------------------------------
# JSON / dict spec ingestion (the dependency-free path)
# ---------------------------------------------------------------------------


def from_spec(spec: Mapping[str, Any]) -> Graph:
    """Build a validated :class:`Graph` from the pure-Python spec::

        {"name": "lenet",
         "input": {"hw": 28, "channels": 1},
         "nodes": [
           {"op": "conv", "name": "c1", "input": "input",
            "out_channels": 6, "kernel": 5, "padding": "same"},
           {"op": "relu", "name": "r1", "input": "c1"},
           ...]}

    Each node entry carries ``op``, ``name``, ``input`` (or ``inputs``
    for binary ops) plus the op's attrs (:data:`OP_ATTRS`). Unknown
    keys are rejected — a typo'd attribute must not silently become a
    default.
    """
    if not isinstance(spec, Mapping):
        raise GraphError(f"graph spec must be a mapping, got "
                         f"{type(spec).__name__}")
    missing = {"name", "input", "nodes"} - set(spec)
    if missing:
        raise GraphError(f"graph spec is missing {sorted(missing)}")
    inp = spec["input"]
    if not isinstance(inp, Mapping) or {"hw", "channels"} - set(inp):
        raise GraphError("spec 'input' must be {'hw': H, 'channels': C}")
    nodes = []
    for i, entry in enumerate(spec["nodes"]):
        if "op" not in entry or "name" not in entry:
            raise GraphError(f"spec node #{i} needs 'op' and 'name': "
                             f"{dict(entry)!r}")
        op, name = str(entry["op"]), str(entry["name"])
        if op not in OP_ATTRS:
            raise UnsupportedOpError(
                name, f"unknown op {op!r} (importable ops: "
                      f"{', '.join(OPS)})")
        if op in _BINARY_OPS:
            inputs = tuple(entry.get("inputs", ()))
        else:
            inputs = (entry["input"],) if "input" in entry else ()
        required, optional = OP_ATTRS[op]
        attrs: dict[str, Any] = dict(optional)
        known = set(required) | set(optional)
        for key, val in entry.items():
            if key in ("op", "name", "input", "inputs"):
                continue
            if key not in known:
                raise GraphError(
                    f"node {name!r}: unknown attribute {key!r} for op "
                    f"{op!r} (takes: {', '.join(sorted(known)) or 'none'})")
            attrs[key] = val
        for key in required:
            if attrs.get(key) is None:
                raise GraphError(f"node {name!r}: op {op!r} requires "
                                 f"attribute {key!r}")
        nodes.append(Node(op=op, name=name, inputs=inputs, attrs=attrs))
    return Graph.build(str(spec["name"]), int(inp["hw"]),
                       int(inp["channels"]), nodes)


def load_spec(path: str | os.PathLike) -> Graph:
    """Read a JSON graph spec file and build the validated graph."""
    with open(path) as f:
        try:
            spec = json.load(f)
        except json.JSONDecodeError as e:
            raise GraphError(f"{path}: not valid JSON: {e}") from None
    return from_spec(spec)
