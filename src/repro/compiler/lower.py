"""Lower an importer :class:`~repro.compiler.graph.Graph` onto the
engine's contract.

The engine executes a *linear chain* of :class:`~repro.core.workload
.ConvLayer` records with a fixed fusion schedule: every non-final
conv/fc engine applies bias + ReLU + requantize in its epilogue, the
final engine emits raw accumulators, and max pooling runs as its own
integer stage between engines (``core/program.py::_lower``). Lowering
therefore has to *normalize* the explicit graph onto that shape:

* **ReLU folding** — a ``relu`` node folds into the conv/fc that feeds
  it. It may also sit *after* an intervening max pool (``conv -> pool
  -> relu``): max and ReLU commute (both monotone), so the fold through
  the pool is exact and the engine's ``conv(+relu) -> pool`` order
  reproduces the source float semantics bit-for-bit.
* **Contract checks** — every non-final compute layer must end up with
  a ReLU (the engine fuses one unconditionally) and the final layer
  must not (it emits accumulators); violations are typed
  :class:`UnsupportedOpError`\\ s naming the layer rather than silently
  computing something else.
* **Legalization** — stride / padding / groups are re-derived through
  the existing :class:`ConvLayer` fields: the layer's own
  ``padding(in_hw)`` must reproduce the graph's declared (lo, hi) pads
  exactly, otherwise the engine's window positions would shift.
* **Rejection** — ops the IR carries but the engine cannot run
  (``avgpool``: the integer pool stage is max-only; ``add``: no
  residual datapath across the linear engine chain) raise
  :class:`UnsupportedOpError`.

The output is a ready-to-compile ``(CNNModel, params-or-None)`` pair:
params are assembled when the graph nodes carry weights (the ONNX
path), otherwise ``None`` and the caller seeds them
(:func:`repro.compiler.calibrate.quantize`).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.compiler.graph import (Graph, Node, UnsupportedOpError,
                                  _square, resolve_padding)
from repro.core.workload import CNNModel, ConvLayer

_REJECT_WHY = {
    "avgpool": "average pooling is not representable — the engine's "
               "integer pool stage is max-only (exact on the po2 "
               "format; an average needs a divider the fabric lacks)",
    "add": "residual add is not representable — the engine executes a "
           "linear chain of pipelined stages with no cross-stage "
           "adder datapath",
}


def lower_graph(graph: Graph) -> tuple[CNNModel, dict | None]:
    """Normalize + legalize ``graph`` into an engine-ready
    :class:`CNNModel` (plus assembled params when the graph carries
    weights). Raises :class:`UnsupportedOpError` naming the first node
    the engine cannot take."""
    _require_chain(graph)
    layers: list[ConvLayer] = []
    # Per compute layer: (node, relu_seen). The engine decides relu by
    # position (all but last), so we collect then verify.
    relu_of: dict[str, bool] = {}
    compute_nodes: list[Node] = []
    flattened = False
    hw = graph.input_hw
    for node in graph.nodes:
        if node.op in _REJECT_WHY:
            raise UnsupportedOpError(node.name, _REJECT_WHY[node.op])
        if node.op == "conv":
            if flattened:
                raise UnsupportedOpError(
                    node.name, "conv after flatten/fc — the engine "
                               "chain cannot return to spatial layout")
            layers.append(_lower_conv(node, hw, graph))
            hw = graph.shapes[node.name][0]
            compute_nodes.append(node)
            relu_of[node.name] = False
        elif node.op == "maxpool":
            layers.append(_lower_pool(node, hw, graph))
            hw = graph.shapes[node.name][0]
        elif node.op == "fc":
            flattened = True
            fin = graph.shapes[node.inputs[0]][0]
            layers.append(ConvLayer(node.name, fin,
                                    int(node.attr("out_features")), 1,
                                    kind="fc"))
            compute_nodes.append(node)
            relu_of[node.name] = False
        elif node.op == "relu":
            producer = _relu_producer(node, graph)
            if producer is None or producer.name not in relu_of:
                raise UnsupportedOpError(
                    node.name, "ReLU must follow a conv/fc engine "
                               "(optionally through max pools, where "
                               "the fold commutes exactly)")
            if relu_of[producer.name]:
                raise UnsupportedOpError(
                    node.name, f"second ReLU folding into "
                               f"{producer.name!r} — the engine epilogue "
                               f"applies one")
            relu_of[producer.name] = True
        elif node.op == "flatten":
            if len(graph.shapes[node.inputs[0]]) == 1:
                continue                      # flat already: a no-op
            flattened = True                  # engine folds it into fc
        else:  # pragma: no cover - Graph.build already rejected it
            raise UnsupportedOpError(node.name, f"op {node.op!r}")
    if not compute_nodes:
        raise UnsupportedOpError(
            graph.output, "graph has no conv/fc compute layer — nothing "
                          "for the engine to run")
    # The engine's fusion schedule: ReLU on every engine but the last.
    for node in compute_nodes[:-1]:
        if not relu_of[node.name]:
            raise UnsupportedOpError(
                node.name, "no ReLU activation — the engine fuses "
                           "bias+ReLU+requantize into every non-final "
                           "engine's epilogue and cannot skip the ReLU")
    last = compute_nodes[-1]
    if relu_of[last.name]:
        raise UnsupportedOpError(
            last.name, "trailing ReLU on the final layer — the final "
                       "engine emits raw accumulators (logits); fold "
                       "the activation into the consumer instead")
    model = CNNModel(graph.name, graph.input_hw, graph.input_ch,
                     tuple(layers))
    return model, _collect_params(graph, compute_nodes)


def _require_chain(graph: Graph) -> None:
    """The engine pipeline is linear: every node feeds exactly one
    consumer (the terminal feeds none). Branching means a residual/
    multi-head topology the chain cannot hold."""
    consumers = graph.consumers()
    for node in graph.nodes:
        n = len(consumers.get(node.name, ()))
        if n > 1:
            names = [c.name for c in consumers[node.name]]
            raise UnsupportedOpError(
                node.name, f"feeds {n} consumers ({', '.join(names)}) — "
                           f"the engine chain is linear (no fan-out)")


def _lower_conv(node: Node, in_hw: int, graph: Graph) -> ConvLayer:
    k = _square(node.name, "kernel", node.attr("kernel"))
    stride = _square(node.name, "stride", node.attr("stride"))
    lo, hi, out = resolve_padding(in_hw, k, stride, node.attr("padding"),
                                  node.name)
    cin = graph.shapes[node.inputs[0]][2]
    layer = ConvLayer(node.name, cin, int(node.attr("out_channels")), k,
                      stride=stride, groups=int(node.attr("groups")),
                      out_size=out)
    got = layer.padding(in_hw)
    if got != (lo, hi):
        raise UnsupportedOpError(
            node.name, f"declared padding {node.attr('padding')!r} pads "
                       f"(lo, hi)=({lo}, {hi}) but the engine derives "
                       f"{got} for out={out} stride={stride} kernel={k} "
                       f"on input {in_hw} — the window positions would "
                       f"shift; use 'same', 'valid', or a symmetric pad "
                       f"the output arithmetic reproduces")
    return layer


def _lower_pool(node: Node, in_hw: int, graph: Graph) -> ConvLayer:
    k = _square(node.name, "kernel", node.attr("kernel"))
    stride = _square(node.name, "stride",
                     node.attr("stride") if node.attr("stride") is not None
                     else k)
    lo, hi, out = resolve_padding(in_hw, k, stride, node.attr("padding"),
                                  node.name)
    ch = graph.shapes[node.name][2]
    layer = ConvLayer(node.name, ch, ch, k, stride=stride, kind="pool",
                      out_size=out)
    got = layer.padding(in_hw)
    if got != (lo, hi):
        raise UnsupportedOpError(
            node.name, f"declared padding {node.attr('padding')!r} pads "
                       f"(lo, hi)=({lo}, {hi}) but the engine derives "
                       f"{got} — max-pool windows would shift")
    return layer


def _relu_producer(node: Node, graph: Graph) -> Node | None:
    """Walk back through max pools (and no-op flattens) to the conv/fc
    a ReLU folds into. Max pool commutes with ReLU exactly, so the fold
    is semantics-preserving; anything else in between breaks it."""
    by_name = {n.name: n for n in graph.nodes}
    cur = by_name.get(node.inputs[0])
    while cur is not None and cur.op in ("maxpool", "flatten"):
        cur = by_name.get(cur.inputs[0])
    if cur is not None and cur.op in ("conv", "fc"):
        return cur
    return None


def _collect_params(graph: Graph, compute_nodes: list[Node]) -> dict | None:
    """Assemble a ``cnn.init_params``-shaped dict from node-attached
    weights. All-or-nothing: a graph with weights on only some compute
    layers is a broken export, not a half-seeded model."""
    with_w = [n for n in compute_nodes if n.attr("weight") is not None]
    if not with_w:
        return None
    if len(with_w) != len(compute_nodes):
        missing = [n.name for n in compute_nodes
                   if n.attr("weight") is None]
        raise UnsupportedOpError(
            missing[0], f"graph carries weights for "
                        f"{len(with_w)}/{len(compute_nodes)} compute "
                        f"layers (missing: {', '.join(missing)}) — "
                        f"provide all or none (none = seeded init)")
    params: dict = {}
    for n in compute_nodes:
        w = jnp.asarray(np.asarray(n.attr("weight"), np.float32))
        cout = w.shape[-1]
        b = n.attr("bias")
        b = (jnp.zeros((cout,), jnp.float32) if b is None
             else jnp.asarray(np.asarray(b, np.float32)))
        params[n.name] = {"w": w, "b": b}
    return params
