"""Post-training quantization + golden parity artifacts for imported
models.

The paper's flow freezes every fixed-point format at compile time from
a calibration pass (``core/program.py::compile_model``); this module is
the importer's front end to that machinery plus the *proof obligation*
that comes with an imported model: a machine-checkable int8 golden, the
way ``tests/golden/`` pins YOLO/ZF.

* :func:`quantize` — seed params if the graph carried none, draw the
  seeded calibration batch, run the float graph through the shared
  calibration pass, and compile the :class:`EngineProgram` (per-channel
  po2 weight exponents, per-tensor activation exponents, int32 bias /
  shift schedules — all frozen here, once).
* :func:`make_golden` / :func:`check_golden` — generate the golden
  record (raw accumulator sample + crc over the full buffer, top-1 ids,
  frozen exponents) on one MAC route and verify it on another: the
  exact-f32, int32-oracle and Pallas routes are bit-identical by
  construction, so an imported model that reproduces its golden across
  routes is running the same integers the engine would.

Seeding follows the repo convention (params ``PRNGKey(seed)``, calib
``PRNGKey(seed + 1)``, frames ``default_rng(seed + 2)``) so an import
is reproducible from ``(spec, seed)`` alone.
"""

from __future__ import annotations

import zlib

import jax
import numpy as np

from repro.core.program import EngineProgram, compile_model
from repro.core.workload import CNNModel
from repro.models import cnn

N_GOLDEN_FRAMES = 2
N_ACC_SAMPLE = 32


class GoldenMismatch(AssertionError):
    """An imported program's int8 execution diverged from its golden —
    the quantization or lowering no longer reproduces the artifact."""


def calib_batch(model: CNNModel, n: int = 1, seed: int = 0):
    """The seeded float calibration batch (activation-range pass)."""
    return jax.random.normal(
        jax.random.PRNGKey(seed + 1),
        (n, model.input_hw, model.input_hw, model.input_ch))


def golden_frames(model: CNNModel, n: int = N_GOLDEN_FRAMES,
                  seed: int = 0) -> np.ndarray:
    """The seeded float frames golden records are computed over (and
    serve smokes replay) — explicit RNG, identical across machines."""
    rng = np.random.default_rng(seed + 2)
    return rng.standard_normal(
        (n, model.input_hw, model.input_hw, model.input_ch),
        dtype=np.float32)


def quantize(model: CNNModel, params=None, *, bits: int = 8,
             seed: int = 0, calib=None, theta: int | None = None,
             **compile_kwargs) -> EngineProgram:
    """Compile an imported model into a runnable fixed-point
    :class:`EngineProgram`: seeded init when the import carried no
    weights, seeded calibration batch when none is given, Table I's
    double-pumped DSP budget convention for the bit width (matching
    ``serving.server.compile_for_serving`` so imported and paper models
    are planned on the same fabric)."""
    if params is None:
        params = cnn.init_params(model, jax.random.PRNGKey(seed))
    if calib is None:
        calib = calib_batch(model, 1, seed)
    if theta is None:
        theta = 2 * 900 - len(model.layers) if bits == 8 else 900
    compile_kwargs.setdefault("bram_total", None if bits == 8 else 545)
    return compile_model(model, params, bits=bits, calib_batch=calib,
                         theta=theta, **compile_kwargs)


def make_golden(prog: EngineProgram, frames: np.ndarray | None = None,
                *, seed: int = 0, route: str = "f32") -> dict:
    """Generate the golden parity record for a compiled program (the
    ``tests/golden/generate.py`` schema): first ``N_ACC_SAMPLE`` raw
    int32 accumulators of frame 0, crc32 of the full accumulator
    buffer, per-frame top-1 ids, and the frozen activation exponents."""
    if frames is None:
        frames = golden_frames(prog.model, seed=seed)
    runner = prog.compile_runner(route=route)
    acc = np.asarray(runner(runner.quantize(np.asarray(frames))))
    logits = runner.dequantize(acc)
    return {
        "acc_sample": acc[0].reshape(-1)[:N_ACC_SAMPLE].astype(np.int32),
        "acc_crc": np.int64(zlib.crc32(np.ascontiguousarray(acc)
                                       .tobytes())),
        "top1": np.argmax(logits.reshape(len(frames), -1),
                          -1).astype(np.int64),
        "e_input": np.int64(prog.e_input),
        "e_out": np.asarray([s.e_out for s in prog.steps
                             if s.kind != "pool"], np.int64),
    }


def check_golden(prog: EngineProgram, golden, frames=None, *,
                 seed: int = 0, route: str = "oracle") -> None:
    """Re-execute ``prog`` on ``route`` and verify it reproduces the
    golden bit-exactly. Raises :class:`GoldenMismatch` listing every
    diverging field. Checking on a *different* route than the one that
    generated the golden cross-checks the MAC lowerings against each
    other (f32 / int32-oracle / Pallas are bit-identical by contract)."""
    got = make_golden(prog, frames, seed=seed, route=route)
    bad = []
    for key in ("e_input", "acc_crc"):
        if int(got[key]) != int(golden[key]):
            bad.append(f"{key}: got {int(got[key])}, golden "
                       f"{int(golden[key])}")
    for key in ("acc_sample", "top1", "e_out"):
        if not np.array_equal(np.asarray(got[key]),
                              np.asarray(golden[key])):
            bad.append(f"{key}: got {np.asarray(got[key]).tolist()}, "
                       f"golden {np.asarray(golden[key]).tolist()}")
    if bad:
        raise GoldenMismatch(
            f"model {prog.model.name!r} (route={route!r}) diverged from "
            f"its golden: " + "; ".join(bad))


def save_golden(path, golden) -> None:
    """Persist a golden record as ``.npz`` (the tests/golden format)."""
    np.savez(path, **golden)


def load_golden(path) -> dict:
    with np.load(path) as z:
        return {k: z[k] for k in z.files}
