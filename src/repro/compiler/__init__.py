"""Compiler front door: import arbitrary CNNs into the serving zoo.

The paper's flexible-pipeline flow (workload -> Algorithm-1/2
allocation -> pipelined engines) is model-agnostic by construction;
this package supplies the missing mapping layer that FPGA toolflows
put in front of such a fabric (Guo et al., arXiv:1712.08934):

``graph``        framework-neutral IR + JSON/dict ingestion (no deps)
``onnx_import``  optional ONNX ingestion (importlib-guarded)
``lower``        normalize/legalize the IR onto the engine contract
``calibrate``    PTQ calibration + int8 golden parity artifacts

:func:`import_source` is the one-call entry: anything describing a CNN
(in-memory :class:`Graph`, spec dict, ``.json`` path, ``.onnx`` path)
-> ``(CNNModel, params-or-None)`` ready for
``core.program.compile_model``.
"""

from __future__ import annotations

import os
from typing import Any

from repro.compiler.calibrate import (GoldenMismatch, check_golden,
                                      golden_frames, load_golden,
                                      make_golden, quantize, save_golden)
from repro.compiler.graph import (Graph, GraphError, Node,
                                  UnsupportedOpError, from_spec,
                                  load_spec)
from repro.compiler.lower import lower_graph
from repro.compiler.onnx_import import load_onnx, onnx_available


def import_graph(source: Any) -> Graph:
    """Resolve any supported source into the neutral :class:`Graph`:
    a ``Graph`` passes through, a dict goes through :func:`from_spec`,
    a path dispatches on suffix (``.onnx`` -> the guarded ONNX reader,
    anything else -> the JSON spec loader)."""
    if isinstance(source, Graph):
        return source
    if isinstance(source, dict):
        return from_spec(source)
    if isinstance(source, (str, os.PathLike)):
        if str(source).lower().endswith(".onnx"):
            return load_onnx(source)
        return load_spec(source)
    raise TypeError(
        f"cannot import from {type(source).__name__}: expected a Graph, "
        f"a spec dict, or a path to a .json spec / .onnx file")


def import_source(source: Any):
    """Import + lower in one call: ``source`` -> engine-ready
    ``(CNNModel, params-or-None)``. Raises :class:`GraphError` /
    :class:`UnsupportedOpError` at the front door for anything the
    engine cannot run."""
    return lower_graph(import_graph(source))


__all__ = [
    "Graph", "GraphError", "Node", "UnsupportedOpError",
    "from_spec", "load_spec", "load_onnx", "onnx_available",
    "lower_graph", "import_graph", "import_source",
    "quantize", "make_golden", "check_golden", "GoldenMismatch",
    "golden_frames", "save_golden", "load_golden",
]
